//! Functional modules for §5.3: the 5-tap FIR filter (Table 1) and the
//! 16×16 systolic array (Table 2), assembled from generated multiplier /
//! MAC designs so every method is evaluated inside the same larger-scale
//! context the paper uses.
//!
//! Sequential elements are modelled with NanGate-like DFF constants
//! (area/energy): the synthesizable combinational path between register
//! boundaries comes from the real generated netlists, and module-level
//! area/power aggregate the per-instance STA reports plus register costs.

pub mod fir;
pub mod systolic;

pub use fir::{build_fir_stage, fir_report, FirReport};
pub use systolic::{build_pe, systolic_report, SystolicReport};

/// NanGate45 DFF_X1-like flip-flop model.
pub const DFF_AREA_UM2: f64 = 4.522;
/// Switching energy of one pipeline register bit (fJ/cycle).
pub const DFF_ENERGY_FJ: f64 = 2.5;

/// A module-level synthesis report row (one cell of Table 1/2).
#[derive(Debug, Clone)]
pub struct ModuleReport {
    /// Clock target (Hz).
    pub freq_hz: f64,
    /// Worst negative slack at the clock target (ns).
    pub wns_ns: f64,
    /// Total area including registers (µm²).
    pub area_um2: f64,
    /// Dynamic power at the clock target (mW).
    pub power_mw: f64,
}

impl ModuleReport {
    /// Clock period implied by the report's frequency target (ns).
    pub fn period_ns(&self) -> f64 {
        1e9 / self.freq_hz
    }
}
