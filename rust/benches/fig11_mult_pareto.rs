//! Figure 11 — multiplier Pareto frontiers (8/16/32-bit), all four
//! methods × three strategies. The paper's headline: UFO-MAC is
//! Pareto-optimal, with up to 14.9 % area and 11.3 % delay improvement
//! over the commercial multipliers.

use ufo_mac::baselines::{BaselineBudget, Method};
use ufo_mac::bench::Bench;
use ufo_mac::coordinator::{self, SweepConfig};
use ufo_mac::multiplier::Strategy;

fn main() {
    let bench = Bench::new("fig11_mult_pareto");
    let quick = std::env::var("UFO_BENCH_QUICK").is_ok();
    let widths: Vec<usize> = if quick { vec![8] } else { vec![8, 16, 32] };

    let cfg = SweepConfig {
        widths: widths.clone(),
        methods: Method::ALL.to_vec(),
        strategies: vec![Strategy::AreaDriven, Strategy::TimingDriven, Strategy::TradeOff],
        mac: false,
        budget: BaselineBudget { rlmul_iters: if quick { 6 } else { 40 }, seed: 11 },
        verify_vectors: 1 << 10,
        ..Default::default()
    };
    let points = coordinator::run_sweep(&cfg);
    assert!(points.iter().all(|p| p.verified), "all designs must be functionally correct");

    println!("\nFigure 11 reproduction: multiplier (delay, area) sweep");
    for &n in &widths {
        let subset: Vec<_> = points.iter().filter(|p| p.n == n).cloned().collect();
        for p in &subset {
            println!(
                "  {n:>2}-bit {:<14} {:<12?} {:.4} ns  {:.1} µm²",
                p.method.name(),
                p.strategy,
                p.delay_ns,
                p.area_um2
            );
        }
        let best = |m: Method, f: fn(&coordinator::DesignPoint) -> f64| {
            subset.iter().filter(|p| p.method == m).map(f).fold(f64::INFINITY, f64::min)
        };
        let area_gain = (1.0
            - best(Method::UfoMac, |p| p.area_um2) / best(Method::Commercial, |p| p.area_um2))
            * 100.0;
        let delay_gain = (1.0
            - best(Method::UfoMac, |p| p.delay_ns) / best(Method::Commercial, |p| p.delay_ns))
            * 100.0;
        println!(
            "  {n}-bit UFO-MAC vs commercial: area −{area_gain:.1}% delay −{delay_gain:.1}% \
             (paper: up to 14.9% / 11.3%)"
        );
        bench.metric(&format!("area_gain_pct_{n}"), area_gain, "%");
        bench.metric(&format!("delay_gain_pct_{n}"), delay_gain, "%");

        // Qualitative Pareto claim: no baseline point dominates every UFO
        // point; UFO holds the fastest spot.
        let ufo_best_delay = best(Method::UfoMac, |p| p.delay_ns);
        for m in [Method::Gomil, Method::RlMul, Method::Commercial] {
            assert!(
                ufo_best_delay <= best(m, |p| p.delay_ns) + 1e-9,
                "{n}-bit: {} is faster than UFO-MAC",
                m.name()
            );
        }
    }

    bench.bench("evaluate_ufo_16bit_point", || {
        coordinator::evaluate_point(
            Method::UfoMac,
            16,
            Strategy::TradeOff,
            false,
            &BaselineBudget::default(),
            256,
            None,
        )
        .unwrap()
    });
}
