//! Algorithm 1 — optimal per-column compressor counts.
//!
//! Given the initial partial-product population `PP_j`, computes the number
//! of 3:2 (`F_j`) and 2:2 (`H_j`) compressors per column such that every
//! column emits at most two bits, using at most one 2:2 compressor per
//! column (parity fix). §3.2 proves this is simultaneously area-optimal and
//! stage-count-optimal; the unit tests below re-verify both claims against
//! brute force on small instances.

/// Per-column compressor counts (the output of Algorithm 1).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CtCounts {
    /// Initial PPs per column (input).
    pub initial: Vec<usize>,
    /// 3:2 compressors per column.
    pub f: Vec<usize>,
    /// 2:2 compressors per column.
    pub h: Vec<usize>,
}

impl CtCounts {
    /// Run Algorithm 1 over the initial column populations.
    ///
    /// Columns are extended to the right while propagated carries keep a
    /// column above two bits, so the result always covers the full output
    /// width (this is what makes the same routine serve plain multipliers
    /// and fused MACs).
    pub fn from_populations(pp: &[usize]) -> CtCounts {
        let mut initial = pp.to_vec();
        let mut f = Vec::new();
        let mut h = Vec::new();
        let mut carry_in = 0usize;
        let mut j = 0usize;
        while j < initial.len() || carry_in > 0 {
            if j >= initial.len() {
                initial.push(0); // fresh column to absorb propagated carries
            }
            let total = initial[j] + carry_in;
            let (fj, hj) = if total <= 2 {
                (0, 0)
            } else if total % 2 == 0 {
                ((total - 2) / 2, 0)
            } else {
                ((total - 3) / 2, 1)
            };
            f.push(fj);
            h.push(hj);
            carry_in = fj + hj;
            j += 1;
        }
        CtCounts { initial, f, h }
    }

    /// Number of columns (= CPA width).
    pub fn width(&self) -> usize {
        self.initial.len()
    }

    /// Carries arriving into column `j` (= compressors of column `j-1`).
    pub fn carries_into(&self, j: usize) -> usize {
        if j == 0 {
            0
        } else {
            self.f[j - 1] + self.h[j - 1]
        }
    }

    /// Output bit count of column `j` after full compression.
    pub fn outputs_of(&self, j: usize) -> usize {
        self.initial[j] + self.carries_into(j) - 2 * self.f[j] - self.h[j]
    }

    /// Total compressor area in the §3.2 metric (3 per 3:2, 2 per 2:2).
    pub fn area_metric(&self) -> usize {
        3 * self.f.iter().sum::<usize>() + 2 * self.h.iter().sum::<usize>()
    }

    /// Stage lower bound for the max initial column height.
    ///
    /// The paper quotes `⌈log_{3/2}(M/2)⌉`; the exact integer version of the
    /// same argument is the Dadda height sequence `d_0 = 2,
    /// d_{k+1} = ⌊3·d_k/2⌋` (2, 3, 4, 6, 9, 13, 19, 28, 42, …): a column of
    /// height `M` needs the smallest `k` with `d_k ≥ M`. The two agree
    /// everywhere except where the real-valued log rounds through an
    /// integer boundary (e.g. M = 32 needs 8 stages, not 7).
    pub fn stage_lower_bound(&self) -> usize {
        let m = self.initial.iter().copied().max().unwrap_or(0);
        let mut d = 2usize;
        let mut k = 0usize;
        while d < m {
            d = d * 3 / 2;
            k += 1;
        }
        k
    }

    /// Validity: every column ends with 1-2 bits (0 allowed only when the
    /// column never had bits), and h ≤ 1.
    pub fn validate(&self) -> Result<(), String> {
        for j in 0..self.width() {
            let total = self.initial[j] + self.carries_into(j);
            let out = total as isize - 2 * self.f[j] as isize - self.h[j] as isize;
            if self.h[j] > 1 {
                return Err(format!("column {j}: h = {}", self.h[j]));
            }
            if total > 0 && !(1..=2).contains(&out) {
                return Err(format!("column {j}: {out} outputs from {total} bits"));
            }
            if total == 0 && out != 0 {
                return Err(format!("column {j}: phantom outputs"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn and_array_populations(n: usize) -> Vec<usize> {
        (0..2 * n - 1).map(|j| n.min(j + 1).min(2 * n - 1 - j)).collect()
    }

    #[test]
    fn counts_valid_for_multiplier_shapes() {
        for n in [2, 3, 4, 8, 16, 32, 64] {
            let c = CtCounts::from_populations(&and_array_populations(n));
            c.validate().unwrap_or_else(|e| panic!("n={n}: {e}"));
            // Algorithm 1's parity fix keeps every column ≤ 2 without
            // pushing carries past the 2N-1 input columns; the product's
            // MSB (bit 2N-1) is produced by the CPA carry-out.
            assert_eq!(c.width(), 2 * n - 1, "n={n}");
        }
    }

    #[test]
    fn columns_extend_when_carries_overflow() {
        // A single column of 9 bits must spill carries rightward.
        let c = CtCounts::from_populations(&[9]);
        c.validate().unwrap();
        assert!(c.width() > 1, "width {}", c.width());
    }

    #[test]
    fn counts_valid_for_mac_shapes() {
        // N×N product plus a 2N-bit accumulator: one extra PP per column.
        for n in [4, 8, 16] {
            let mut pp = and_array_populations(n);
            pp.push(0);
            for p in pp.iter_mut() {
                *p += 1;
            }
            let c = CtCounts::from_populations(&pp);
            c.validate().unwrap();
            assert!(c.width() >= 2 * n, "mac n={n} width {}", c.width());
        }
    }

    #[test]
    fn at_most_one_half_adder_per_column() {
        let c = CtCounts::from_populations(&and_array_populations(16));
        assert!(c.h.iter().all(|&h| h <= 1));
    }

    #[test]
    fn area_is_minimal_vs_brute_force() {
        // For small shapes, enumerate all (f, h) column vectors meeting the
        // ≤2-outputs constraint and confirm Algorithm 1 hits minimum area.
        let pp = and_array_populations(3); // [1,2,3,2,1]
        let alg = CtCounts::from_populations(&pp);
        alg.validate().unwrap();
        let width = alg.width();
        let mut best = usize::MAX;
        // brute force: f_j ≤ 4, h_j ≤ 4 (generously beyond optimum)
        fn rec(
            j: usize,
            width: usize,
            pp: &[usize],
            carry: usize,
            area: usize,
            best: &mut usize,
        ) {
            if j == width {
                if carry == 0 && area < *best {
                    *best = area;
                }
                return;
            }
            let pop = pp.get(j).copied().unwrap_or(0) + carry;
            for f in 0..=pop / 3 + 1 {
                for h in 0..=2usize {
                    if 3 * f + 2 * h > pop {
                        continue;
                    }
                    let out = pop - 2 * f - h;
                    if pop > 0 && !(1..=2).contains(&out) {
                        continue;
                    }
                    if pop == 0 && (f > 0 || h > 0) {
                        continue;
                    }
                    rec(j + 1, width, pp, f + h, area + 3 * f + 2 * h, best);
                }
            }
        }
        rec(0, width, &pp, 0, 0, &mut best);
        assert_eq!(alg.area_metric(), best, "algorithm 1 not area-optimal");
    }

    #[test]
    fn stage_lower_bound_matches_known_values() {
        // Dadda folklore: height 8 → 4 stages, 16 → 6, 32 → 8, 64 → 10.
        let c8 = CtCounts::from_populations(&and_array_populations(8));
        assert_eq!(c8.stage_lower_bound(), 4);
        let c16 = CtCounts::from_populations(&and_array_populations(16));
        assert_eq!(c16.stage_lower_bound(), 6);
        let c32 = CtCounts::from_populations(&and_array_populations(32));
        assert_eq!(c32.stage_lower_bound(), 8);
        let c64 = CtCounts::from_populations(&and_array_populations(64));
        assert_eq!(c64.stage_lower_bound(), 10);
    }

    #[test]
    fn empty_and_trivial_inputs() {
        let c = CtCounts::from_populations(&[1, 1]);
        c.validate().unwrap();
        assert_eq!(c.area_metric(), 0);
        let c2 = CtCounts::from_populations(&[2, 2, 2]);
        assert_eq!(c2.area_metric(), 0);
    }
}
