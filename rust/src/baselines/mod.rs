//! Baseline design generators the paper compares against (§5.1):
//! GOMIL, RL-MUL, and the commercial-IP proxy.
//!
//! Each baseline produces a [`MultiplierSpec`] (or a searched CT plan) so
//! every method flows through the identical synthesis + STA pipeline — the
//! property that keeps the comparison honest. The substitution rationale
//! for each proxy is documented in DESIGN.md §1.

pub mod rlmul;

use crate::cpa::PrefixStructure;
use crate::ct::CtArchitecture;
use crate::multiplier::{CpaChoice, Design, MultiplierSpec, Strategy};
use crate::ppg::{OperandFormat, PpgKind, Signedness};
use crate::Result;

/// The four methods of the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// The paper's unified framework.
    UfoMac,
    /// GOMIL proxy baseline.
    Gomil,
    /// RL-MUL search-based baseline.
    RlMul,
    /// Commercial-IP proxy (Booth + Dadda + regular CPA).
    Commercial,
}

impl Method {
    /// Every method, in the order the paper's tables list them.
    pub const ALL: [Method; 4] =
        [Method::UfoMac, Method::Gomil, Method::RlMul, Method::Commercial];

    /// Human-readable name used in reports.
    pub fn name(&self) -> &'static str {
        match self {
            Method::UfoMac => "UFO-MAC",
            Method::Gomil => "GOMIL",
            Method::RlMul => "RL-MUL",
            Method::Commercial => "Commercial IP",
        }
    }

    /// Stable machine-readable key (CLI flag value, request serialization).
    pub fn key(&self) -> &'static str {
        match self {
            Method::UfoMac => "ufo",
            Method::Gomil => "gomil",
            Method::RlMul => "rlmul",
            Method::Commercial => "commercial",
        }
    }
}

impl std::str::FromStr for Method {
    type Err = anyhow::Error;

    /// Strict parse: unknown names are an error listing the valid values
    /// (no silent fallback).
    fn from_str(s: &str) -> Result<Method> {
        match s {
            "ufo" | "ufo-mac" | "ufomac" => Ok(Method::UfoMac),
            "gomil" => Ok(Method::Gomil),
            "rlmul" | "rl-mul" => Ok(Method::RlMul),
            "commercial" => Ok(Method::Commercial),
            _ => Err(anyhow::anyhow!(
                "unknown method '{s}' (valid: ufo, gomil, rlmul, commercial)"
            )),
        }
    }
}

/// Budget knobs for the search-based baseline.
#[derive(Debug, Clone, Copy)]
pub struct BaselineBudget {
    /// SA iterations for RL-MUL (the paper runs 3000 RL steps; scale to
    /// the testbed).
    pub rlmul_iters: usize,
    /// RNG seed for the search.
    pub seed: u64,
}

impl Default for BaselineBudget {
    fn default() -> Self {
        BaselineBudget { rlmul_iters: 60, seed: 0xB00C }
    }
}

/// Build the spec for `method` at width `n` under a synthesis `strategy`
/// (unsigned square operands — the legacy default).
pub fn spec_for(method: Method, n: usize, strategy: Strategy, mac: bool) -> MultiplierSpec {
    spec_for_fmt(method, OperandFormat::unsigned(n), strategy, mac)
}

/// [`spec_for`] over an explicit [`OperandFormat`] — the coordinator's
/// format sweep axis (signed DSP-style MACs run through every baseline).
pub fn spec_for_fmt(
    method: Method,
    format: OperandFormat,
    strategy: Strategy,
    mac: bool,
) -> MultiplierSpec {
    let base = MultiplierSpec::new_fmt(format).strategy(strategy).fused_mac(mac);
    match method {
        // UFO-MAC: optimal CT + optimized order + profile-driven CPA.
        Method::UfoMac => base,
        // GOMIL: area-optimal CT counts, no stage objective (column-serial),
        // naive order, logic-level-minimal CPA (Sklansky).
        Method::Gomil => base
            .ct(CtArchitecture::Gomil)
            .cpa(CpaChoice::Regular(PrefixStructure::Sklansky)),
        // RL-MUL: searched CT plan attached by `build_design`; tool-default
        // CPA (Brent-Kung).
        Method::RlMul => base.cpa(CpaChoice::Regular(PrefixStructure::BrentKung)),
        // Commercial IP proxy: Dadda CT, strategy-selected regular CPA
        // (timing → Kogge-Stone, area → Brent-Kung, trade-off → Sklansky).
        Method::Commercial => {
            let cpa = match strategy {
                Strategy::TimingDriven => PrefixStructure::KoggeStone,
                Strategy::AreaDriven => PrefixStructure::BrentKung,
                Strategy::TradeOff => PrefixStructure::Sklansky,
            };
            base.ct(CtArchitecture::Dadda).cpa(CpaChoice::Regular(cpa)).ppg(PpgKind::AndArray)
        }
    }
}

/// Resolve `method` to the fully explicit [`MultiplierSpec`] it denotes,
/// running the RL-MUL annealing search when the method requires it. This
/// is the engine's uncached inner path; results are deterministic in
/// `(method, n, strategy, mac, budget)`. `lib` is the caller's shared
/// cell library (the engine passes its own — no per-call
/// re-characterization).
pub fn method_spec(
    method: Method,
    n: usize,
    strategy: Strategy,
    mac: bool,
    budget: &BaselineBudget,
    lib: &crate::ir::CellLib,
) -> MultiplierSpec {
    method_spec_fmt(method, OperandFormat::unsigned(n), strategy, mac, budget, lib)
}

/// [`method_spec`] over an explicit [`OperandFormat`]: the RL-MUL probe
/// matrix is generated with the format's own PPG shape (Baugh–Wooley rows
/// and the accumulator sign-extension column for signed formats), so the
/// searched stage plan matches what the builder will actually compress.
pub fn method_spec_fmt(
    method: Method,
    format: OperandFormat,
    strategy: Strategy,
    mac: bool,
    budget: &BaselineBudget,
    lib: &crate::ir::CellLib,
) -> MultiplierSpec {
    let spec = spec_for_fmt(method, format, strategy, mac);
    if method != Method::RlMul {
        return spec;
    }
    // Search the CT plan on the real PP shape (incl. MAC addend rows).
    let (na, nb) = (format.a_bits, format.b_bits);
    let out_w = na + nb;
    let mut scratch = crate::ir::Netlist::new("pp-probe");
    let a: Vec<_> = (0..na).map(|i| scratch.input(format!("a{i}"))).collect();
    let b: Vec<_> = (0..nb).map(|i| scratch.input(format!("b{i}"))).collect();
    let mut m = match format.signedness {
        Signedness::Unsigned => crate::ppg::and_array(&mut scratch, lib, &a, &b),
        Signedness::Signed => {
            let cols = if mac { out_w + 1 } else { out_w };
            crate::ppg::and_array_signed(&mut scratch, lib, &a, &b, cols)
        }
    };
    if mac {
        let c: Vec<_> = (0..out_w)
            .map(|i| {
                let id = scratch.input(format!("c{i}"));
                crate::synth::Sig::new(id, 0.0)
            })
            .collect();
        if format.is_signed() {
            m.add_addend_signed(&c);
        } else {
            m.add_addend(&c);
        }
    }
    let res = rlmul::search(&m.columns, budget.rlmul_iters, budget.seed);
    spec.with_plan(res.plan)
}

/// Build a complete design for `method` (runs the RL-MUL search when
/// needed).
///
/// Shim over the unified engine: the call is captured as a
/// [`crate::api::DesignRequest::Method`] and served from the process-global
/// engine's cache. New code should compile requests directly.
pub fn build_design(
    method: Method,
    n: usize,
    strategy: Strategy,
    mac: bool,
    budget: &BaselineBudget,
) -> Result<Design> {
    let req = crate::api::DesignRequest::Method(crate::api::MethodRequest {
        method,
        n,
        signedness: Signedness::Unsigned,
        strategy,
        mac,
        budget: *budget,
    });
    let art = crate::api::engine().compile(&req)?;
    Ok(art.design().expect("method artifact carries a design").clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{lane_value, pack_lanes, Simulator};
    use crate::sta::Sta;

    fn exhaustive(d: &Design) {
        let n = d.n;
        let mut sim = Simulator::new();
        let na = 1u32 << n;
        let mask = (1u32 << (2 * n)) - 1;
        let all: Vec<(u32, u32, u32)> = (0..na)
            .flat_map(|x| (0..na).map(move |y| (x, y, x.wrapping_mul(97).wrapping_add(y) & mask)))
            .collect();
        for chunk in all.chunks(64) {
            let assigns: Vec<Vec<bool>> = chunk
                .iter()
                .map(|(x, y, z)| {
                    let mut v: Vec<bool> = (0..n).map(|k| x >> k & 1 != 0).collect();
                    v.extend((0..n).map(|k| y >> k & 1 != 0));
                    if d.is_mac {
                        v.extend((0..2 * n).map(|k| z >> k & 1 != 0));
                    }
                    v
                })
                .collect();
            let words = pack_lanes(&assigns);
            let vals = sim.run(&d.netlist, &words).to_vec();
            for (lane, (x, y, z)) in chunk.iter().enumerate() {
                let got = lane_value(&vals, &d.product, lane as u32);
                assert_eq!(got, d.golden((*x).into(), (*y).into(), (*z).into()));
            }
        }
    }

    #[test]
    fn all_methods_functional_4x4() {
        let budget = BaselineBudget { rlmul_iters: 10, seed: 1 };
        for m in Method::ALL {
            let d = build_design(m, 4, Strategy::TradeOff, false, &budget).unwrap();
            exhaustive(&d);
        }
    }

    #[test]
    fn all_methods_functional_3x3_mac() {
        let budget = BaselineBudget { rlmul_iters: 8, seed: 2 };
        for m in Method::ALL {
            let d = build_design(m, 3, Strategy::TimingDriven, true, &budget).unwrap();
            exhaustive(&d);
        }
    }

    #[test]
    fn ufo_pareto_dominates_gomil_8bit() {
        // The paper's core claim at one data point: UFO-MAC is no worse in
        // both area and delay than the GOMIL proxy under the same strategy.
        let budget = BaselineBudget::default();
        let sta = Sta::default();
        let ufo = build_design(Method::UfoMac, 8, Strategy::TimingDriven, false, &budget).unwrap();
        let gom = build_design(Method::Gomil, 8, Strategy::TimingDriven, false, &budget).unwrap();
        let ru = sta.analyze(&ufo.netlist);
        let rg = sta.analyze(&gom.netlist);
        assert!(
            ru.critical_delay_ns <= rg.critical_delay_ns,
            "delay {} vs {}",
            ru.critical_delay_ns,
            rg.critical_delay_ns
        );
    }
}
