//! Cross-module integration & property tests over the public API.
//!
//! These complement the per-module unit tests with randomized invariant
//! checks spanning the whole stack: every generator config must produce a
//! functionally correct, structurally valid netlist whose reports obey the
//! physics (more compressors ⇒ more area, tighter strategy ⇒ no slower,
//! etc.). Deterministic seeds keep failures reproducible.

use ufo_mac::baselines::{build_design, BaselineBudget, Method};
use ufo_mac::cpa::{self, PrefixStructure};
use ufo_mac::ct::{self, CtArchitecture, CtCounts, OrderStrategy};
use ufo_mac::multiplier::{CpaChoice, MultiplierSpec, Strategy};
use ufo_mac::ppg::PpgKind;
use ufo_mac::sim::{CompiledNetlist, Simulator};
use ufo_mac::sta::Sta;
use ufo_mac::util::Rng;

// ---------------------------------------------------------------------
// Property: every spec in a randomized config space builds + verifies.
// ---------------------------------------------------------------------
#[test]
fn property_random_specs_build_and_verify() {
    let mut rng = Rng::seed_from_u64(0x1A7E57);
    for trial in 0..24 {
        let n = [3, 4, 5, 6][rng.index(4)];
        let ppg = if rng.bool() { PpgKind::AndArray } else { PpgKind::Booth4 };
        let ct = [
            CtArchitecture::UfoMac,
            CtArchitecture::Wallace,
            CtArchitecture::Dadda,
            CtArchitecture::Gomil,
        ][rng.index(4)];
        let cpa = if rng.bool() {
            CpaChoice::ProfileOptimized
        } else {
            CpaChoice::Regular(
                [
                    PrefixStructure::Sklansky,
                    PrefixStructure::KoggeStone,
                    PrefixStructure::BrentKung,
                    PrefixStructure::HanCarlson,
                    PrefixStructure::Ripple,
                    PrefixStructure::CarryIncrement(3),
                ][rng.index(6)],
            )
        };
        let strategy = [Strategy::AreaDriven, Strategy::TimingDriven, Strategy::TradeOff]
            [rng.index(3)];
        let mac = rng.index(3) == 0;
        let spec = MultiplierSpec::new(n)
            .ppg(ppg)
            .ct(ct)
            .cpa(cpa)
            .strategy(strategy)
            .fused_mac(mac);
        let design = spec.build().unwrap_or_else(|e| panic!("trial {trial}: build: {e}"));
        design.netlist.validate().unwrap();
        let rep = ufo_mac::equiv::check_multiplier_with(&design, 1 << 10)
            .unwrap_or_else(|e| panic!("trial {trial}: equiv: {e}"));
        assert!(
            rep.passed,
            "trial {trial}: {ppg:?}/{ct:?}/{strategy:?} mac={mac} n={n} cex={:?}",
            rep.counterexample
        );
    }
}

// ---------------------------------------------------------------------
// Property: interconnect order never changes function, only timing.
// ---------------------------------------------------------------------
#[test]
fn property_order_is_function_invariant() {
    for seed in [1u64, 2, 3, 4, 5] {
        let d = MultiplierSpec::new(5)
            .order(OrderStrategy::Random(seed))
            .build()
            .unwrap();
        let rep = ufo_mac::equiv::check_multiplier(&d).unwrap();
        assert!(rep.passed && rep.exhaustive, "seed {seed}");
    }
}

// ---------------------------------------------------------------------
// Property: Algorithm-1 counts are area-minimal vs random legal counts.
// ---------------------------------------------------------------------
#[test]
fn property_alg1_counts_never_beaten_by_random_outputs() {
    let mut rng = Rng::seed_from_u64(42);
    for n in [4usize, 6, 8] {
        let pp: Vec<usize> = (0..2 * n - 1).map(|j| n.min(j + 1).min(2 * n - 1 - j)).collect();
        let alg1 = CtCounts::from_populations(&pp);
        for _ in 0..10 {
            // Random legal alternative via RL-MUL's output-choice space.
            let o: Vec<usize> =
                (0..pp.len() + 2).map(|_| 1 + rng.index(2)).collect();
            let alt = ufo_mac::baselines::rlmul::counts_from_outputs(&pp, &o);
            if alt.validate().is_ok() {
                assert!(
                    alg1.area_metric() <= alt.area_metric(),
                    "n={n}: alg1 {} vs alt {}",
                    alg1.area_metric(),
                    alt.area_metric()
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Property: CompiledNetlist ≡ Simulator on random designs/vectors.
// ---------------------------------------------------------------------
#[test]
fn property_compiled_sim_matches_interpreter() {
    let mut rng = Rng::seed_from_u64(0xC0DE);
    for n in [4usize, 6, 8] {
        let d = MultiplierSpec::new(n).build().unwrap();
        let comp = CompiledNetlist::compile(&d.netlist);
        let mut sim = Simulator::new();
        let mut buf = Vec::new();
        for _ in 0..8 {
            let words: Vec<u64> =
                (0..d.netlist.num_inputs()).map(|_| rng.next_u64()).collect();
            let vals = sim.run(&d.netlist, &words).to_vec();
            comp.run_into(&mut buf, &words);
            assert_eq!(buf, vals, "n={n}");
        }
    }
}

// ---------------------------------------------------------------------
// Property: STA reports respect basic physics across the method grid.
// ---------------------------------------------------------------------
#[test]
fn property_reports_are_physical() {
    let sta = Sta { activity_rounds: 4, ..Sta::default() };
    let budget = BaselineBudget { rlmul_iters: 4, seed: 9 };
    for m in Method::ALL {
        for n in [4usize, 8] {
            let d = build_design(m, n, Strategy::TradeOff, false, &budget).unwrap();
            let r = sta.analyze(&d.netlist);
            assert!(r.critical_delay_ns > 0.0);
            assert!(r.area_um2 > 0.0);
            assert!(r.power_mw > 0.0);
            assert!(r.depth as usize >= 2);
            assert_eq!(r.output_arrivals_ns.len(), 2 * n);
            // bigger width ⇒ strictly more area for the same method
            if n == 8 {
                let d4 = build_design(m, 4, Strategy::TradeOff, false, &budget).unwrap();
                let r4 = sta.analyze(&d4.netlist);
                assert!(r.area_um2 > r4.area_um2, "{m:?}");
                assert!(r.critical_delay_ns > r4.critical_delay_ns, "{m:?}");
            }
        }
    }
}

// ---------------------------------------------------------------------
// Property: prefix-graph GRAPHOPT transformations preserve the function
// under random application sequences (the Algorithm-2 safety net).
// ---------------------------------------------------------------------
#[test]
fn property_graphopt_sequences_preserve_addition() {
    let mut rng = Rng::seed_from_u64(77);
    for trial in 0..12 {
        let n = 4 + rng.index(9); // 4..12
        let mut g = match rng.index(3) {
            0 => cpa::build(PrefixStructure::Sklansky, n),
            1 => cpa::build(PrefixStructure::BrentKung, n),
            _ => cpa::build(PrefixStructure::Ripple, n),
        };
        for _ in 0..rng.index(12) {
            let cands: Vec<usize> = (g.n..g.nodes.len())
                .filter(|&i| {
                    let nd = g.node(i);
                    !nd.is_leaf() && !g.node(nd.ntf).is_leaf()
                })
                .collect();
            if cands.is_empty() {
                break;
            }
            let p = cands[rng.index(cands.len())];
            cpa::optimize::graphopt(&mut g, p);
        }
        g.prune();
        g.validate().unwrap_or_else(|e| panic!("trial {trial}: {e}"));
        // exhaustive add check up to 2^(2n) ≤ 2^16… cap at n ≤ 8 exhaustive
        let (nl, sum) = cpa::standalone_adder(&g, None);
        let comp = CompiledNetlist::compile(&nl);
        let mut buf = Vec::new();
        let mask = (1u64 << n) - 1;
        for _ in 0..4 {
            let mut words = vec![0u64; 2 * n];
            let mut lanes: Vec<(u64, u64)> = Vec::new();
            for lane in 0..64 {
                let a = rng.next_u64() & mask;
                let b = rng.next_u64() & mask;
                for k in 0..n {
                    if a >> k & 1 == 1 {
                        words[2 * k] |= 1 << lane;
                    }
                    if b >> k & 1 == 1 {
                        words[2 * k + 1] |= 1 << lane;
                    }
                }
                lanes.push((a, b));
            }
            comp.run_into(&mut buf, &words);
            for (lane, (a, b)) in lanes.iter().enumerate() {
                let got = ufo_mac::sim::lane_value(&buf, &sum, lane as u32);
                assert_eq!(got, u128::from(a + b), "trial {trial} n={n}");
            }
        }
    }
}

// ---------------------------------------------------------------------
// Integration: full coordinator sweep end-to-end with reports.
// ---------------------------------------------------------------------
#[test]
fn integration_sweep_pareto_and_reports() {
    let cfg = ufo_mac::coordinator::SweepConfig {
        widths: vec![4, 6],
        methods: vec![Method::UfoMac, Method::Commercial],
        strategies: vec![Strategy::TradeOff, Strategy::TimingDriven],
        mac: false,
        workers: 2,
        budget: BaselineBudget { rlmul_iters: 2, seed: 5 },
        verify_vectors: 256,
        use_pjrt: false,
        ..Default::default()
    };
    let points = ufo_mac::coordinator::run_sweep(&cfg);
    assert_eq!(points.len(), 8);
    assert!(points.iter().all(|p| p.verified));
    for &n in &[4usize, 6] {
        let subset: Vec<_> = points.iter().filter(|p| p.n == n).cloned().collect();
        let front = ufo_mac::coordinator::pareto_front(&subset);
        assert!(!front.is_empty());
        // No point on the front is dominated by any other point.
        for &i in &front {
            for (j, q) in subset.iter().enumerate() {
                if i != j {
                    assert!(
                        !ufo_mac::coordinator::dominates(q, &subset[i]),
                        "front point dominated"
                    );
                }
            }
        }
    }
    let json = ufo_mac::coordinator::points_json(&points).render();
    assert!(json.contains("delay_ns") && json.starts_with('['));
}

// ---------------------------------------------------------------------
// Integration: verilog emission round-trip (structure spot checks on a
// verified design, all methods).
// ---------------------------------------------------------------------
#[test]
fn integration_verilog_for_all_methods() {
    let budget = BaselineBudget { rlmul_iters: 2, seed: 8 };
    for m in Method::ALL {
        let d = build_design(m, 4, Strategy::TradeOff, false, &budget).unwrap();
        let v = ufo_mac::synth::verilog::emit(&d.netlist);
        assert!(v.contains("module "), "{m:?}");
        assert!(v.contains("endmodule"), "{m:?}");
        assert_eq!(v.matches("assign p").count(), 8, "{m:?}");
    }
}

// ---------------------------------------------------------------------
// Integration: FIR and systolic module reports across methods.
// ---------------------------------------------------------------------
#[test]
fn integration_module_reports() {
    for m in [Method::UfoMac, Method::Commercial] {
        let fir = ufo_mac::modules::fir_report(m, 4, Strategy::TradeOff, 1e9).unwrap();
        assert!(fir.area_um2 > 0.0 && fir.power_mw > 0.0);
        let sys = ufo_mac::modules::systolic_report(m, 4, Strategy::TradeOff, 1e9).unwrap();
        assert!(sys.area_um2 > fir.area_um2, "256 PEs outweigh a 5-tap FIR");
    }
}

// ---------------------------------------------------------------------
// Property: ILP solver agrees with brute force on random small MILPs.
// ---------------------------------------------------------------------
#[test]
fn property_milp_matches_bruteforce() {
    use ufo_mac::ilp::{solve, LinExpr, Model, Sense, SolveOptions};
    let mut rng = Rng::seed_from_u64(0x111);
    for trial in 0..15 {
        // max c·x  s.t.  one ≤ row, x binary, 4 vars.
        let nv = 4;
        let c: Vec<f64> = (0..nv).map(|_| (rng.index(19) as f64) - 9.0).collect();
        let w: Vec<f64> = (0..nv).map(|_| 1.0 + rng.index(5) as f64).collect();
        let cap = 2.0 + rng.index(8) as f64;
        let mut m = Model::new();
        let vars: Vec<_> = (0..nv).map(|i| m.bin(format!("x{i}"))).collect();
        let row: Vec<_> = vars.iter().zip(&w).map(|(&v, &wi)| (v, wi)).collect();
        m.constrain(LinExpr::of(&row), Sense::Le, cap);
        let obj: Vec<_> = vars.iter().zip(&c).map(|(&v, &ci)| (v, -ci)).collect();
        m.minimize(LinExpr::of(&obj));
        let sol = solve(&m, &SolveOptions::default());
        // brute force
        let mut best = 0.0f64;
        for mask in 0..1u32 << nv {
            let weight: f64 =
                (0..nv).filter(|&i| mask >> i & 1 == 1).map(|i| w[i]).sum();
            if weight <= cap {
                let val: f64 =
                    (0..nv).filter(|&i| mask >> i & 1 == 1).map(|i| c[i]).sum();
                best = best.max(val);
            }
        }
        assert!(sol.ok(), "trial {trial}");
        assert!((-sol.objective - best).abs() < 1e-6, "trial {trial}: {} vs {best}", -sol.objective);
    }
}

// ---------------------------------------------------------------------
// Failure injection: the equivalence checker catches seeded faults in
// arbitrary gates (not just output remaps).
// ---------------------------------------------------------------------
#[test]
fn failure_injection_detected() {
    use ufo_mac::ir::{CellKind, Netlist, Node};
    let mut rng = Rng::seed_from_u64(0xBAD);
    let base = MultiplierSpec::new(4).build().unwrap();
    let mut caught = 0;
    let trials = 10;
    for _ in 0..trials {
        let mut d = base.clone();
        // Flip one random gate kind to a different function.
        let gates: Vec<usize> = d
            .netlist
            .iter()
            .enumerate()
            .filter(|(_, n)| matches!(n, Node::Gate { kind, .. } if kind.arity() == 2))
            .map(|(i, _)| i)
            .collect();
        let pick = gates[rng.index(gates.len())];
        let mut nl = Netlist::new(d.netlist.name.clone());
        for (i, node) in d.netlist.iter().enumerate() {
            match node {
                Node::Input { name, arrival_ns } => {
                    nl.input_at(name, arrival_ns);
                }
                Node::Const(v) => {
                    nl.constant(v);
                }
                Node::Gate { kind, fanin } => {
                    let k = if i == pick {
                        match kind {
                            CellKind::Xor2 => CellKind::Xnor2,
                            CellKind::And2 => CellKind::Or2,
                            CellKind::Nand2 => CellKind::Nor2,
                            CellKind::Or2 => CellKind::And2,
                            CellKind::Nor2 => CellKind::Nand2,
                            other => other,
                        }
                    } else {
                        kind
                    };
                    nl.gate(k, fanin);
                }
                Node::Reg { .. } => unreachable!("tier-1 families are combinational"),
            }
        }
        for (name, id) in d.netlist.outputs() {
            nl.output(name, id);
        }
        d.netlist = nl;
        let rep = ufo_mac::equiv::check_multiplier(&d).unwrap();
        if !rep.passed {
            caught += 1;
        }
    }
    // A few flips may be functionally benign (e.g. redundant logic), but
    // the vast majority must be caught.
    assert!(caught >= trials - 2, "caught only {caught}/{trials}");
}
