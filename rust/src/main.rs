//! `ufo-mac` — CLI for the UFO-MAC arithmetic-synthesis framework.
//!
//! Every subcommand compiles its designs through the unified
//! [`ufo_mac::api::SynthEngine`] (the process-global instance), so repeated
//! designs inside one invocation are synthesized once.
//!
//! Subcommands:
//!   generate  --width N [--bwidth M] [--signed]
//!             [--method ufo|gomil|rlmul|commercial]
//!             [--strategy area|timing|tradeoff] [--mac] [--booth]
//!             [--pipeline K]
//!             Generate one design, verify it, print the STA report.
//!             `--signed` selects two's-complement operands (any method);
//!             `--bwidth` selects a rectangular a×b format (UFO-MAC spec
//!             path only). `--pipeline K` inserts K register ranks at
//!             STA-balanced depth cuts (UFO-MAC spec path only) and
//!             verifies through the clocked simulator.
//!   sweep     --widths 8,16,32 [--mac] [--signed] [--pjrt] [--out reports/]
//!             Full method×strategy DSE sweep; prints Pareto frontiers.
//!   profile   --width N   Print the CT output arrival profile (Figure 1).
//!   fir       --width N --freq 1e9     Table-1 style FIR report.
//!   systolic  --width N --freq 1e9     Table-2 style systolic report.
//!   verify    --width N [--mac]        Simulator + PJRT equivalence.
//!   ablation  --width N                Per-ingredient ablation table.
//!   lint      [--width N] [--request '<json>'] [--json] [--deny SEV]
//!             Static analysis (LINTS.md codes). With no `--request`,
//!             sweeps the tier-1 design families × operand formats at
//!             `--width` (default 8). Exits nonzero when any design
//!             carries a diagnostic at or above `--deny` (error, warning
//!             or info; default error) — `--deny warning` lets CI fail on
//!             warnings too.
//!   analyze   [--width N] [--request '<json>'] [--json] [--deny SEV]
//!             Bit-level abstract interpretation (UFO4xx semantic codes):
//!             proven constants, static switching activity, word-level
//!             output intervals. Same sweep/flags as `lint`.
//!   request   --json '<request>'       Compile a serialized DesignRequest.
//!   serve     [--transport tcp|stdio] [--addr 127.0.0.1:7878]
//!             [--cache-dir DIR|none] [--workers N] [--verify N]
//!             [--metrics]
//!             Long-lived compile service over newline-delimited JSON
//!             (PROTOCOL.md); artifacts persist in the on-disk cache and
//!             survive restarts. Requests are priority-scheduled (cache
//!             hits preempt in-flight sweeps) and `"stream": true`
//!             requests get per-design-point progress frames. `--metrics`
//!             prints the observability snapshot (queue depths, cache
//!             tiers, latency histograms) to stderr every 30 s — the same
//!             JSON the `metrics` wire command returns.
//!   bench-check [--baseline FILE] [--current FILE] [--max-ratio 2.0]
//!             [--update]
//!             Compare a `BENCH_*.json` run against the committed baseline
//!             (CI's bench-smoke gate): every timed baseline entry must be
//!             present and no more than `max-ratio` slower; metric entries
//!             (speedups) must not fall below `baseline / max-ratio`.
//!             `--update` snapshots the current run as the new baseline.
//!
//! Unknown `--method` / `--strategy` / `--transport` values are hard
//! errors listing the valid choices — no silent fallback.

use ufo_mac::api::{engine, DesignRequest};
use ufo_mac::baselines::Method;
use ufo_mac::coordinator::{self, SweepConfig};
use ufo_mac::ct::CtArchitecture;
use ufo_mac::multiplier::{MultiplierSpec, OperandFormat, Strategy};
use ufo_mac::ppg::{PpgKind, Signedness};
use ufo_mac::util::{Args, Table};
use ufo_mac::Result;

fn parse_method(s: &str) -> Result<Method> {
    s.parse()
}

fn parse_strategy(s: &str) -> Result<Strategy> {
    s.parse()
}

fn cmd_generate(args: &Args) -> Result<()> {
    let n = args.get_usize("width", 8);
    let method = parse_method(args.get("method").unwrap_or("ufo"))?;
    let strategy = parse_strategy(args.get("strategy").unwrap_or("tradeoff"))?;
    let mac = args.has("mac");
    let booth = args.has("booth");
    let signed = args.has("signed");
    let b_width = args.get_usize("bwidth", n);
    let rect = b_width != n;
    let pipeline = strict_usize(args, "pipeline", 0)?;
    if (booth || rect || pipeline > 0) && method != Method::UfoMac {
        anyhow::bail!(
            "--booth/--bwidth/--pipeline select the UFO-MAC spec path; drop --method {}",
            method.key()
        );
    }
    let fmt = if signed {
        OperandFormat::signed_rect(n, b_width)
    } else {
        OperandFormat::rect(n, b_width)
    };
    let req = if booth || rect || pipeline > 0 {
        DesignRequest::from_spec(
            &MultiplierSpec::new_fmt(fmt)
                .strategy(strategy)
                .fused_mac(mac)
                .ppg(if booth { PpgKind::Booth4 } else { PpgKind::AndArray })
                .pipeline_stages(pipeline),
        )
    } else if signed {
        // Square signed designs are reachable for every method family.
        DesignRequest::method_with(method, n, strategy, mac, Signedness::Signed)
    } else {
        DesignRequest::method(method, n, strategy, mac)
    };
    let art = engine().compile(&req)?;
    let design = art.design().expect("design request");
    let equiv = ufo_mac::equiv::check_multiplier(design)?;
    println!(
        "{}{} {}{}×{}{} [{strategy:?}]",
        method.name(),
        if booth { " (Booth-4)" } else { "" },
        if signed { "signed " } else { "" },
        n,
        b_width,
        if mac { " fused-MAC" } else { "" }
    );
    println!("  fingerprint: {}", art.fingerprint);
    println!("  gates:       {}", art.sta.num_gates);
    println!("  area:        {:.1} µm²", art.sta.area_um2);
    println!("  delay:       {:.4} ns", art.sta.critical_delay_ns);
    println!("  power@1GHz:  {:.4} mW", art.sta.power_mw);
    println!("  CT stages:   {}", design.ct_stages);
    if let Some(p) = &design.pipeline {
        println!(
            "  pipeline:    {} stage(s), latency {} cycle(s), {} registers",
            p.stages,
            p.latency(),
            design.netlist.num_regs()
        );
    }
    println!(
        "  equivalence: {} ({} vectors{}{})",
        if equiv.passed { "PASS" } else { "FAIL" },
        equiv.vectors,
        if equiv.exhaustive { ", exhaustive" } else { "" },
        if design.pipeline.is_some() { ", clocked" } else { "" }
    );
    if let Some(path) = args.get("verilog") {
        std::fs::write(path, ufo_mac::synth::verilog::emit_design(design))?;
        println!("  verilog:     {path}");
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let n = args.get_usize("width", 16);
    let art = engine().compile(&DesignRequest::multiplier(n))?;
    let design = art.design().expect("design request");
    println!("CT output arrival profile ({n}×{n}, model estimate, ns):");
    let max = design.profile.iter().copied().fold(0.0f64, f64::max);
    for (j, t) in design.profile.iter().enumerate() {
        let bar = "#".repeat((t / max.max(1e-12) * 50.0) as usize);
        println!("  col {j:>3}  {t:>7.4}  {bar}");
    }
    let (r1, r2) = ufo_mac::cpa::detect_regions(&design.profile);
    println!("regions: 1 = [0,{r1}), 2 = [{r1},{r2}), 3 = [{r2},{})", design.profile.len());
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let widths: Vec<usize> = args
        .get("widths")
        .unwrap_or("8,16")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let cfg = SweepConfig {
        widths,
        mac: args.has("mac"),
        signedness: if args.has("signed") {
            vec![ufo_mac::ppg::Signedness::Signed]
        } else {
            vec![ufo_mac::ppg::Signedness::Unsigned]
        },
        use_pjrt: args.has("pjrt"),
        ..Default::default()
    };
    let points = coordinator::run_sweep(&cfg);
    let mut table = Table::new(&[
        "method", "n", "strategy", "delay(ns)", "area(µm²)", "power(mW)", "ok",
    ]);
    for p in &points {
        table.row(vec![
            p.method.name().into(),
            p.n.to_string(),
            format!("{:?}", p.strategy),
            format!("{:.4}", p.delay_ns),
            format!("{:.1}", p.area_um2),
            format!("{:.3}", p.power_mw),
            format!(
                "{}{}",
                if p.verified { "sim" } else { "SIM-FAIL" },
                match p.pjrt_verified {
                    Some(true) => "+pjrt",
                    Some(false) => "+PJRT-FAIL",
                    None => "",
                }
            ),
        ]);
    }
    println!("{}", table.render());
    for &n in &cfg.widths {
        let subset: Vec<_> = points.iter().filter(|p| p.n == n).cloned().collect();
        let front = coordinator::pareto_front(&subset);
        let names: Vec<String> = front
            .iter()
            .map(|&i| format!("{}/{:?}", subset[i].method.name(), subset[i].strategy))
            .collect();
        println!("pareto {n}-bit: {}", names.join(", "));
    }
    if let Some(dir) = args.get("out") {
        coordinator::save_report(dir, "sweep", &coordinator::points_json(&points))?;
        println!("report written to {dir}/sweep.json");
    }
    Ok(())
}

fn cmd_fir(args: &Args) -> Result<()> {
    let n = args.get_usize("width", 8);
    let freq = args.get_f64("freq", 1e9);
    let mut table = Table::new(&["method", "freq(MHz)", "WNS(ns)", "area(µm²)", "power(mW)"]);
    for m in Method::ALL {
        let art = engine().compile(&DesignRequest::fir(m, n, Strategy::TradeOff, freq))?;
        let r = art.module_report().expect("fir report");
        table.row(vec![
            m.name().into(),
            format!("{:.0}", freq / 1e6),
            format!("{:.4}", r.wns_ns),
            format!("{:.0}", r.area_um2),
            format!("{:.3}", r.power_mw),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_systolic(args: &Args) -> Result<()> {
    let n = args.get_usize("width", 8);
    let freq = args.get_f64("freq", 1e9);
    let mut table = Table::new(&["method", "freq(MHz)", "WNS(ns)", "area(µm²)", "power(mW)"]);
    for m in Method::ALL {
        let art = engine().compile(&DesignRequest::systolic(m, n, Strategy::TradeOff, freq))?;
        let r = art.module_report().expect("systolic report");
        table.row(vec![
            m.name().into(),
            format!("{:.0}", freq / 1e6),
            format!("{:.4}", r.wns_ns),
            format!("{:.0}", r.area_um2),
            format!("{:.3}", r.power_mw),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let n = args.get_usize("width", 8);
    let mac = args.has("mac");
    let art =
        engine().compile(&DesignRequest::from_spec(&MultiplierSpec::new(n).fused_mac(mac)))?;
    let design = art.design().expect("design request");
    let equiv = ufo_mac::equiv::check_multiplier(design)?;
    println!(
        "simulator equivalence: {} ({} vectors)",
        if equiv.passed { "PASS" } else { "FAIL" },
        equiv.vectors
    );
    let dir = ufo_mac::runtime::default_artifact_dir();
    let rt = ufo_mac::runtime::Runtime::new(&dir)?;
    if rt.has_artifact("netlist_eval_small") {
        let ok = ufo_mac::runtime::verify_design_pjrt(&rt, design, 4)?;
        println!(
            "PJRT artifact equivalence ({}): {}",
            rt.platform(),
            if ok { "PASS" } else { "FAIL" }
        );
    } else {
        println!("PJRT artifacts not built (run `make artifacts`)");
    }
    Ok(())
}

fn cmd_ablation(args: &Args) -> Result<()> {
    // Ablation: isolate each UFO-MAC ingredient (DESIGN.md §4).
    let n = args.get_usize("width", 16);
    let mut table = Table::new(&["variant", "delay(ns)", "area(µm²)", "stages"]);
    let variants: Vec<(&str, MultiplierSpec)> = vec![
        ("full UFO-MAC", MultiplierSpec::new(n)),
        (
            "naive interconnect order",
            MultiplierSpec::new(n).order(ufo_mac::ct::OrderStrategy::Naive),
        ),
        (
            "no stage optimization (column-serial)",
            MultiplierSpec::new(n).ct(CtArchitecture::Gomil),
        ),
        (
            "regular Sklansky CPA (no profile opt)",
            MultiplierSpec::new(n).cpa(ufo_mac::multiplier::CpaChoice::Regular(
                ufo_mac::cpa::PrefixStructure::Sklansky,
            )),
        ),
        ("wallace CT", MultiplierSpec::new(n).ct(CtArchitecture::Wallace)),
        ("dadda CT", MultiplierSpec::new(n).ct(CtArchitecture::Dadda)),
    ];
    for (name, spec) in variants {
        let art = engine().compile(&DesignRequest::from_spec(&spec))?;
        let design = art.design().expect("design request");
        table.row(vec![
            name.into(),
            format!("{:.4}", art.sta.critical_delay_ns),
            format!("{:.1}", art.sta.area_um2),
            design.ct_stages.to_string(),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

/// Strict parse of the `--deny <severity>` flag shared by `lint` and
/// `analyze`; absent means the historical gate, Error.
fn parse_deny(args: &Args) -> Result<ufo_mac::lint::Severity> {
    match args.get("deny") {
        None => Ok(ufo_mac::lint::Severity::Error),
        Some(v) => ufo_mac::lint::Severity::from_key(v)
            .map_err(|e| anyhow::anyhow!("invalid --deny: {e}")),
    }
}

fn cmd_lint(args: &Args) -> Result<()> {
    let n = args.get_usize("width", 8);
    let deny = parse_deny(args)?;
    let reqs: Vec<DesignRequest> = match args.get("request") {
        Some(text) => vec![DesignRequest::parse(text)?],
        None => ufo_mac::api::tier1_requests(n),
    };
    // A reporting engine: the deny gate is off so a dirty design comes
    // back as a report to print — the exit code carries the verdict.
    let eng = ufo_mac::api::SynthEngine::new(ufo_mac::api::EngineConfig {
        lint_deny: None,
        ..Default::default()
    });
    let as_json = args.has("json");
    let mut denied = 0usize;
    let mut rows: Vec<ufo_mac::util::Json> = Vec::new();
    for req in &reqs {
        let (report, art, _) = eng.lint(req)?;
        if report.denies(deny) {
            denied += 1;
        }
        if as_json {
            let ufo_mac::util::Json::Obj(mut m) = report.summary_json() else {
                unreachable!("lint summary must be an object");
            };
            m.insert("canonical".to_string(), art.request.to_json());
            m.insert(
                "fingerprint".to_string(),
                ufo_mac::util::Json::str(art.fingerprint.to_string()),
            );
            rows.push(ufo_mac::util::Json::Obj(m));
        } else {
            println!(
                "{} {}",
                if report.is_clean() { "clean" } else { "DIRTY" },
                art.request.to_json_string()
            );
            for d in &report.diagnostics {
                println!("  {d}");
            }
        }
    }
    if as_json {
        let doc = ufo_mac::util::Json::obj(vec![
            ("clean", ufo_mac::util::Json::Bool(denied == 0)),
            ("designs", ufo_mac::util::Json::Arr(rows)),
        ]);
        println!("{}", doc.render());
    } else {
        println!(
            "lint: {} design(s), {denied} at or above --deny {}",
            reqs.len(),
            deny.key()
        );
    }
    if denied > 0 {
        anyhow::bail!(
            "lint found {}-or-worse diagnostics in {denied} design(s)",
            deny.key()
        );
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let n = args.get_usize("width", 8);
    let deny = parse_deny(args)?;
    let reqs: Vec<DesignRequest> = match args.get("request") {
        Some(text) => vec![DesignRequest::parse(text)?],
        None => ufo_mac::api::tier1_requests(n),
    };
    let eng = ufo_mac::api::SynthEngine::new(ufo_mac::api::EngineConfig {
        lint_deny: None,
        ..Default::default()
    });
    let as_json = args.has("json");
    let mut denied = 0usize;
    let mut rows: Vec<ufo_mac::util::Json> = Vec::new();
    for req in &reqs {
        let (report, art, _) = eng.analyze(req)?;
        if report.denies(deny) {
            denied += 1;
        }
        if as_json {
            let ufo_mac::util::Json::Obj(mut m) = report.summary_json() else {
                unreachable!("analysis summary must be an object");
            };
            m.insert("canonical".to_string(), art.request.to_json());
            m.insert(
                "fingerprint".to_string(),
                ufo_mac::util::Json::str(art.fingerprint.to_string()),
            );
            rows.push(ufo_mac::util::Json::Obj(m));
        } else {
            println!(
                "{} {}",
                if report.is_clean() { "clean" } else { "FLAGGED" },
                art.request.to_json_string()
            );
            println!("  {report}");
        }
    }
    if as_json {
        let doc = ufo_mac::util::Json::obj(vec![
            ("clean", ufo_mac::util::Json::Bool(denied == 0)),
            ("designs", ufo_mac::util::Json::Arr(rows)),
        ]);
        println!("{}", doc.render());
    } else {
        println!(
            "analyze: {} design(s), {denied} at or above --deny {}",
            reqs.len(),
            deny.key()
        );
    }
    if denied > 0 {
        anyhow::bail!(
            "analysis found {}-or-worse diagnostics in {denied} design(s)",
            deny.key()
        );
    }
    Ok(())
}

fn cmd_request(args: &Args) -> Result<()> {
    // Compile a serialized request — the service-style entry point.
    let json = args
        .get("json")
        .ok_or_else(|| anyhow::anyhow!("usage: ufo-mac request --json '<DesignRequest json>'"))?;
    let req = DesignRequest::parse(json)?;
    let art = engine().compile(&req)?;
    println!("fingerprint: {}", art.fingerprint);
    println!("canonical:   {}", art.request.to_json_string());
    println!(
        "sta: {} gates, {:.1} µm², {:.4} ns, {:.4} mW",
        art.sta.num_gates, art.sta.area_um2, art.sta.critical_delay_ns, art.sta.power_mw
    );
    if let Some(r) = art.module_report() {
        println!(
            "module: WNS {:.4} ns @ {:.0} MHz, {:.0} µm², {:.3} mW",
            r.wns_ns,
            r.freq_hz / 1e6,
            r.area_um2,
            r.power_mw
        );
    }
    Ok(())
}

/// Strict numeric flag parse: a present-but-invalid value is a hard error
/// naming the valid form (the `--method`/`--strategy` convention), never a
/// silent fallback to the default.
fn strict_usize(args: &Args, key: &str, default: usize) -> Result<usize> {
    match args.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid --{key} '{v}' (valid: a non-negative integer)")),
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let workers = strict_usize(
        args,
        "workers",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
    )?;
    let verify = strict_usize(args, "verify", 0)?;
    // `--cache-dir none` opts out of persistence; any other value is the
    // cache directory (created on demand). Default: the workspace cache.
    let cache_dir = match args.get("cache-dir") {
        None => Some(ufo_mac::runtime::default_cache_dir()),
        Some("none") => None,
        Some(dir) => Some(std::path::PathBuf::from(dir)),
    };
    let engine = std::sync::Arc::new(ufo_mac::api::SynthEngine::new(ufo_mac::api::EngineConfig {
        verify_vectors: verify,
        workers,
        cache_dir: cache_dir.clone(),
        ..Default::default()
    }));
    let server = std::sync::Arc::new(ufo_mac::server::Server::new(engine));
    // `--metrics`: a detached reporter prints the observability snapshot
    // (the same JSON the `metrics` wire command returns) to stderr every
    // 30 s. Stderr, so stdio-transport stdout stays pure NDJSON.
    if args.has("metrics") {
        let reporter = std::sync::Arc::clone(&server);
        std::thread::spawn(move || loop {
            std::thread::sleep(std::time::Duration::from_secs(30));
            eprintln!("ufo-mac serve: metrics {}", reporter.metrics_json().render());
        });
    }
    match args.get("transport").unwrap_or("tcp") {
        "tcp" => {
            let addr = args.get("addr").unwrap_or("127.0.0.1:7878");
            match &cache_dir {
                Some(d) => println!("ufo-mac serve: persistent cache at {}", d.display()),
                None => println!("ufo-mac serve: in-memory cache only (--cache-dir none)"),
            }
            server.serve_tcp(addr)
        }
        "stdio" => {
            // Keep stdout pure NDJSON; banners go to stderr.
            match &cache_dir {
                Some(d) => eprintln!("ufo-mac serve: persistent cache at {}", d.display()),
                None => eprintln!("ufo-mac serve: in-memory cache only (--cache-dir none)"),
            }
            let stdin = std::io::BufReader::new(std::io::stdin());
            let out = server.serve(stdin, std::io::stdout(), workers);
            if args.has("metrics") {
                // Final snapshot so short-lived piped sessions still get
                // one report even when they finish inside the first tick.
                eprintln!("ufo-mac serve: metrics {}", server.metrics_json().render());
            }
            out
        }
        other => anyhow::bail!("unknown transport '{other}' (valid: stdio, tcp)"),
    }
}

/// Bench records from one `BENCH_*.json` suite file: `(name, min_ns,
/// metric value)` — timed entries carry `min_ns`, metric entries `value`.
fn load_bench_results(
    path: &std::path::Path,
) -> Result<Vec<(String, Option<f64>, Option<f64>)>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    let doc = ufo_mac::util::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
    let results = doc
        .get("results")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| anyhow::anyhow!("{}: missing 'results' array", path.display()))?;
    let mut out = Vec::new();
    for r in results {
        let name = r.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string();
        if name.is_empty() {
            continue;
        }
        let min_ns = r.get("min_ns").and_then(|v| v.as_f64());
        let value = r.get("value").and_then(|v| v.as_f64());
        out.push((name, min_ns, value));
    }
    Ok(out)
}

/// Resolve a repo-relative file against both the repo root and the cargo
/// package root: cargo runs benches with `rust/` as cwd, while CI and
/// humans usually sit at the repo root, so both spellings must work. When
/// the file exists nowhere (e.g. `--update` writing a fresh baseline),
/// falls back to the path as given.
fn resolve_bench_path(path: &str) -> std::path::PathBuf {
    for candidate in [path.to_string(), format!("rust/{path}"), format!("../{path}")] {
        let p = std::path::PathBuf::from(candidate);
        if p.exists() {
            return p;
        }
    }
    std::path::PathBuf::from(path)
}

fn cmd_bench_check(args: &Args) -> Result<()> {
    let baseline_arg = args.get("baseline").unwrap_or("rust/benches/baseline_hotpath.json");
    let current_arg = args.get("current").unwrap_or("BENCH_hotpath.json");
    let max_ratio = args.get_f64("max-ratio", 2.0);
    let baseline_path = resolve_bench_path(baseline_arg);
    let current_file = resolve_bench_path(current_arg);
    if !current_file.exists() {
        anyhow::bail!(
            "current bench file '{current_arg}' not found — run \
             `cargo bench --bench hotpath` first"
        );
    }
    if args.has("update") {
        std::fs::copy(&current_file, &baseline_path)
            .map_err(|e| anyhow::anyhow!("write {}: {e}", baseline_path.display()))?;
        println!(
            "bench-check: baseline {} updated from {}",
            baseline_path.display(),
            current_file.display()
        );
        return Ok(());
    }
    // A baseline may be marked `"provisional": true` at the top level:
    // authored as an order-of-magnitude envelope rather than recorded on
    // real hardware. The comparison still runs, but say so loudly — the
    // ratios are advisory until someone re-records with `--update`.
    let provisional = std::fs::read_to_string(&baseline_path)
        .ok()
        .and_then(|t| ufo_mac::util::Json::parse(&t).ok())
        .and_then(|d| d.get("provisional").and_then(|p| p.as_bool()))
        .unwrap_or(false);
    if provisional {
        println!("bench-check: ****************************************************************");
        println!("bench-check: ** PROVISIONAL BASELINE — {} ", baseline_path.display());
        println!("bench-check: ** was authored as an envelope estimate, not measured on this");
        println!("bench-check: ** hardware. Ratios below are advisory; re-record with");
        println!("bench-check: ** `cargo bench --bench hotpath && ufo-mac bench-check --update`.");
        println!("bench-check: ****************************************************************");
    }
    let base = load_bench_results(&baseline_path)?;
    let cur = load_bench_results(&current_file)?;
    let cur_map: std::collections::HashMap<&str, (Option<f64>, Option<f64>)> =
        cur.iter().map(|(n, m, v)| (n.as_str(), (*m, *v))).collect();
    let mut failures: Vec<String> = Vec::new();
    let mut compared = 0usize;
    for (name, min_ns, value) in &base {
        let Some(&(cur_min, cur_val)) = cur_map.get(name.as_str()) else {
            // Entry-set drift is surfaced but does not block: a renamed or
            // conditionally-skipped bench should be fixed in review, while
            // a hard failure here would make the gate brittle.
            println!("bench-check WARNING: {name} in baseline but missing from current run");
            continue;
        };
        if let (Some(b), Some(c)) = (*min_ns, cur_min) {
            let ratio = c / b.max(1.0);
            println!("bench-check {name}: {c:.0} ns vs baseline {b:.0} ns ({ratio:.2}x)");
            if ratio > max_ratio {
                failures.push(format!(
                    "{name}: {ratio:.2}x slower than baseline (limit {max_ratio:.2}x)"
                ));
            }
            compared += 1;
        }
        if let (Some(b), Some(c)) = (*value, cur_val) {
            let floor = b / max_ratio;
            println!("bench-check {name}: {c:.3} vs baseline floor {floor:.3}");
            if c < floor {
                failures.push(format!(
                    "{name}: metric {c:.3} fell below {floor:.3} (baseline {b:.3} / {max_ratio:.2})"
                ));
            }
            compared += 1;
        }
    }
    if failures.is_empty() {
        println!(
            "bench-check: {compared} baseline entries OK (no hot path regressed >{max_ratio:.1}x){}",
            if provisional { " [PROVISIONAL baseline]" } else { "" }
        );
        Ok(())
    } else {
        anyhow::bail!("bench-check failed:\n  {}", failures.join("\n  "))
    }
}

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let result = match cmd {
        "generate" => cmd_generate(&args),
        "sweep" => cmd_sweep(&args),
        "profile" => cmd_profile(&args),
        "fir" => cmd_fir(&args),
        "systolic" => cmd_systolic(&args),
        "verify" => cmd_verify(&args),
        "ablation" => cmd_ablation(&args),
        "lint" => cmd_lint(&args),
        "analyze" => cmd_analyze(&args),
        "request" => cmd_request(&args),
        "serve" => cmd_serve(&args),
        "bench-check" => cmd_bench_check(&args),
        _ => {
            println!(
                "ufo-mac — UFO-MAC multiplier/MAC optimization framework\n\
                 usage: ufo-mac <generate|sweep|profile|fir|systolic|verify|ablation|lint|analyze|request|serve|bench-check> [flags]\n\
                 methods: ufo, gomil, rlmul, commercial; strategies: area, timing, tradeoff\n\
                 generate: --pipeline K inserts K register ranks (clocked verify + always_ff RTL)\n\
                 lint: --width N (tier-1 sweep), --request '<json>' (one design), --json,\n\
                       --deny error|warning|info (exit-code gate, default error)\n\
                 analyze: abstract interpretation (UFO4xx); same flags as lint\n\
                 serve: --transport tcp|stdio (default tcp), --addr HOST:PORT,\n\
                        --cache-dir DIR|none (default: workspace design_cache/),\n\
                        --workers N, --verify N, --metrics (30s stderr snapshots)\n\
                        — wire format and streaming in PROTOCOL.md\n\
                 bench-check: --baseline FILE --current FILE --max-ratio X --update\n\
                 see rust/src/main.rs header for all flags"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
