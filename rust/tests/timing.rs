//! Incremental-timing acceptance tests: on every tier-1 design family the
//! incremental engine's arrival times must be *identical* (bit-for-bit) to
//! full re-timing, across arbitrary sequences of optimization-move-style
//! edits.

use ufo_mac::api::{DesignRequest, EngineConfig, SynthEngine};
use ufo_mac::baselines::Method;
use ufo_mac::cpa::{self, PrefixStructure};
use ufo_mac::ir::Netlist;
use ufo_mac::multiplier::{MultiplierSpec, Strategy};
use ufo_mac::ppg::PpgKind;
use ufo_mac::sta::{IncrementalSta, Sta};
use ufo_mac::util::Rng;

fn assert_identical(inc: &IncrementalSta, sta: &Sta, nl: &Netlist, ctx: &str) {
    let full = sta.arrivals_ns(nl);
    assert_eq!(inc.arrivals(), &full[..], "{ctx}: incremental != full re-timing");
}

/// Perturb random input arrivals (what CT/CPA optimization moves do to the
/// CPA's arrival profile) and check identity after every single move.
fn fuzz_moves(nl: &mut Netlist, moves: usize, seed: u64, ctx: &str) {
    let sta = Sta { activity_rounds: 0, ..Sta::default() };
    let mut inc = IncrementalSta::new(&sta, nl);
    assert_identical(&inc, &sta, nl, ctx);
    let inputs = nl.inputs();
    let mut rng = Rng::seed_from_u64(seed);
    for mv in 0..moves {
        let id = inputs[rng.index(inputs.len())];
        let t = rng.f64() * 0.5;
        nl.set_input_arrival(id, t);
        inc.touch(id);
        inc.propagate(nl);
        assert_identical(&inc, &sta, nl, &format!("{ctx} move {mv}"));
    }
    let stats = inc.stats();
    assert!(
        stats.nodes_retimed < stats.nodes_total,
        "{ctx}: incremental engine did no better than full re-timing: {stats:?}"
    );
}

#[test]
fn incremental_identical_on_ufo_multipliers() {
    for n in [4usize, 8] {
        let mut d = MultiplierSpec::new(n).build().unwrap();
        fuzz_moves(&mut d.netlist, 24, n as u64, &format!("ufo {n}x{n}"));
    }
}

#[test]
fn incremental_identical_on_booth_and_mac() {
    let mut booth = MultiplierSpec::new(4).ppg(PpgKind::Booth4).build().unwrap();
    fuzz_moves(&mut booth.netlist, 16, 11, "booth 4x4");
    let mut mac = MultiplierSpec::new(4).fused_mac(true).build().unwrap();
    fuzz_moves(&mut mac.netlist, 16, 12, "fused mac 4x4");
}

#[test]
fn incremental_identical_on_baseline_methods() {
    for method in [Method::Gomil, Method::Commercial] {
        let eng = SynthEngine::new(EngineConfig::default());
        let art = eng.compile(&DesignRequest::method(method, 6, Strategy::TradeOff, false)).unwrap();
        let mut nl = art.netlist().clone();
        fuzz_moves(&mut nl, 16, 13, &format!("{method:?} 6x6"));
    }
}

#[test]
fn incremental_identical_on_profiled_adder() {
    // The CPA-under-trapezoid case the optimization loop actually re-times.
    let profile: Vec<f64> =
        (0..24).map(|i| 0.2 + 0.15 * (12.0 - (i as f64 - 12.0).abs()) / 12.0).collect();
    let g = cpa::build(PrefixStructure::KoggeStone, 24);
    let (mut nl, _) = cpa::standalone_adder(&g, Some(&profile));
    fuzz_moves(&mut nl, 32, 14, "kogge-stone 24b profiled adder");
}

#[test]
fn incremental_absorbs_netlist_growth_mid_run() {
    // Moves interleaved with netlist growth (appended gates change loads
    // of existing drivers): sync() + propagate() must stay identical to a
    // full sweep.
    let g = cpa::build(PrefixStructure::Sklansky, 12);
    let (mut nl, sum) = cpa::standalone_adder(&g, None);
    let sta = Sta { activity_rounds: 0, ..Sta::default() };
    let mut inc = IncrementalSta::new(&sta, &nl);
    let mut rng = Rng::seed_from_u64(15);
    for round in 0..6 {
        // Append a consumer of an existing sum bit.
        let a = sum[rng.index(sum.len())];
        let b = sum[rng.index(sum.len())];
        let extra = if a != b { nl.xor2(a, b) } else { nl.inv(a) };
        nl.output(format!("x{round}"), extra);
        inc.sync(&nl);
        inc.propagate(&nl);
        assert_identical(&inc, &sta, &nl, &format!("growth round {round}"));
        // And a move on top of the grown netlist.
        let inputs = nl.inputs();
        let id = inputs[rng.index(inputs.len())];
        nl.set_input_arrival(id, rng.f64() * 0.4);
        inc.touch(id);
        inc.propagate(&nl);
        assert_identical(&inc, &sta, &nl, &format!("growth+move round {round}"));
    }
    assert_eq!(inc.critical_delay_ns(&nl), sta.analyze(&nl).critical_delay_ns);
}
