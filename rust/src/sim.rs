//! Bit-parallel netlist simulation.
//!
//! Simulates a [`Netlist`] on 64 input vectors at a time by packing one
//! vector per bit lane of a `u64` word — the classic "parallel pattern"
//! simulation trick. This is the engine behind equivalence checking
//! ([`crate::equiv`]) and the toggle-based dynamic-power estimate in
//! [`crate::sta`]; the same levelized evaluation is what the Pallas
//! `netlist_eval` kernel performs on the PJRT side with u32 lanes.
//!
//! Since the netlist IR itself stores nodes as flat opcode/fanin arrays,
//! [`CompiledNetlist`] is a **zero-copy borrow** of those arrays — the
//! seed implementation paid an O(nodes) re-flattening pass (enum walk +
//! per-gate `Vec` deref) before every equivalence run; construction is now
//! free (EXPERIMENTS.md §Perf).

use crate::ir::netlist::{OP_CONST0, OP_CONST1, OP_INPUT};
use crate::ir::{Netlist, NodeId};

/// A netlist viewed as a flat instruction stream: one `(op, f0, f1, f2)`
/// record per node, no per-gate heap indirection. This is a zero-copy
/// borrow of the netlist's own struct-of-arrays storage (the IR and the
/// simulator share one encoding: opcodes 0–10 = `CellKind::opcode`,
/// [`OP_CONST0`], [`OP_CONST1`], [`OP_INPUT`] with the input ordinal in
/// `f0`) — the §Perf-optimized inner loop for equivalence checking and
/// toggle extraction, identical to the PJRT artifact encoding.
#[derive(Debug, Clone, Copy)]
pub struct CompiledNetlist<'a> {
    ops: &'a [u8],
    fanin: &'a [[u32; 3]],
    n_inputs: usize,
}

impl<'a> CompiledNetlist<'a> {
    /// Borrow a netlist as the simulator's flat op list. Zero-copy: the
    /// netlist already stores this encoding.
    pub fn compile(nl: &'a Netlist) -> Self {
        CompiledNetlist { ops: nl.ops(), fanin: nl.fanin_records(), n_inputs: nl.num_inputs() }
    }

    /// Number of compiled ops (== netlist nodes).
    pub fn len(&self) -> usize {
        self.ops.len()
    }
    /// Whether the program is empty.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
    /// Number of primary inputs the program samples.
    pub fn num_inputs(&self) -> usize {
        self.n_inputs
    }

    /// Evaluate into `buf` (resized as needed). `input_words[k]` feeds the
    /// k-th primary input.
    pub fn run_into(&self, buf: &mut Vec<u64>, input_words: &[u64]) {
        assert_eq!(input_words.len(), self.n_inputs, "input word count");
        if buf.len() != self.ops.len() {
            buf.resize(self.ops.len(), 0);
        }
        let b = buf.as_mut_slice();
        for i in 0..self.ops.len() {
            let [f0, f1, f2] = self.fanin[i];
            // SAFETY: the fanin records come straight from a `Netlist`
            // whose construction (`Netlist::gate`) enforces `fanin < i <
            // len`, and input ordinals are bounded by the asserted
            // `input_words` length. Dropping the bounds checks is worth
            // ~20% on the equivalence-sweep hot loop (EXPERIMENTS.md §Perf).
            let v = unsafe {
                let g = |k: u32| *b.get_unchecked(k as usize);
                match self.ops[i] {
                    0 => g(f0),
                    1 => !g(f0),
                    2 => g(f0) & g(f1),
                    3 => g(f0) | g(f1),
                    4 => !(g(f0) & g(f1)),
                    5 => !(g(f0) | g(f1)),
                    6 => g(f0) ^ g(f1),
                    7 => !(g(f0) ^ g(f1)),
                    8 => !((g(f0) & g(f1)) | g(f2)),
                    9 => !((g(f0) | g(f1)) & g(f2)),
                    10 => {
                        let (a, bb, c) = (g(f0), g(f1), g(f2));
                        (a & bb) | (a & c) | (bb & c)
                    }
                    OP_CONST0 => 0,
                    OP_CONST1 => !0,
                    _ => *input_words.get_unchecked(f0 as usize),
                }
            };
            b[i] = v;
        }
    }
}

/// Reusable simulation buffer (one word per node).
#[derive(Debug, Default)]
pub struct Simulator {
    words: Vec<u64>,
}

impl Simulator {
    /// Fresh simulator (the per-netlist "program" is the netlist's own
    /// flat storage, so there is nothing to cache beyond the word buffer).
    pub fn new() -> Self {
        Self::default()
    }

    /// Evaluate the netlist on 64 packed input vectors.
    ///
    /// `input_words[k]` holds lane-packed values for the k-th primary input
    /// (in creation order). Returns the packed words of every node; index
    /// with [`NodeId::index`].
    pub fn run(&mut self, nl: &Netlist, input_words: &[u64]) -> &[u64] {
        let comp = CompiledNetlist::compile(nl);
        comp.run_into(&mut self.words, input_words);
        &self.words
    }

    /// Packed word for one node after [`Simulator::run`].
    #[inline]
    pub fn word(&self, id: NodeId) -> u64 {
        self.words[id.index()]
    }

    /// Extract the named outputs as packed words.
    pub fn output_words(&self, nl: &Netlist) -> Vec<(String, u64)> {
        nl.outputs().map(|(n, id)| (n.to_string(), self.words[id.index()])).collect()
    }
}

/// Interpret a slice of output nodes as a little-endian unsigned integer for
/// one specific lane.
pub fn lane_value(words: &[u64], bits: &[NodeId], lane: u32) -> u128 {
    let mut v = 0u128;
    for (k, b) in bits.iter().enumerate() {
        v |= u128::from(words[b.index()] >> lane & 1) << k;
    }
    v
}

/// Interpret a slice of output nodes as a little-endian **two's-complement**
/// integer for one specific lane (the MSB is the sign bit) — the signed
/// counterpart of [`lane_value`] used to verify signed operand formats.
pub fn lane_value_signed(words: &[u64], bits: &[NodeId], lane: u32) -> i128 {
    crate::util::sign_extend(lane_value(words, bits, lane), bits.len())
}

/// Pack per-lane bit values into input words: `assignments[lane][input]`.
pub fn pack_lanes(assignments: &[Vec<bool>]) -> Vec<u64> {
    assert!(!assignments.is_empty() && assignments.len() <= 64);
    let n_inputs = assignments[0].len();
    let mut words = vec![0u64; n_inputs];
    for (lane, assign) in assignments.iter().enumerate() {
        assert_eq!(assign.len(), n_inputs);
        for (i, bit) in assign.iter().enumerate() {
            if *bit {
                words[i] |= 1u64 << lane;
            }
        }
    }
    words
}

/// Count output toggles between consecutive random vectors for every node —
/// the activity factor feeding the dynamic-power report.
///
/// Runs `rounds`×64 random vectors (xorshift-seeded, deterministic) and
/// returns per-node toggle probability in [0,1]. All buffers (current and
/// previous node words, input words) are allocated once and reused across
/// rounds — the seed implementation cloned the first round's buffer and
/// allocated a fresh input-word `Vec` per round (EXPERIMENTS.md §Perf).
pub fn toggle_activity(nl: &Netlist, rounds: usize, seed: u64) -> Vec<f64> {
    let comp = CompiledNetlist::compile(nl);
    let mut state = seed | 1;
    let mut rng = move || {
        // xorshift64* — deterministic, dependency-free
        state ^= state >> 12;
        state ^= state << 25;
        state ^= state >> 27;
        state.wrapping_mul(0x2545_F491_4F6C_DD1D)
    };
    let n_in = nl.num_inputs();
    let mut toggles = vec![0u64; nl.len()];
    let mut total_pairs = 0u64;
    let mut cur: Vec<u64> = Vec::new();
    let mut prev: Vec<u64> = Vec::new();
    let mut words = vec![0u64; n_in];
    for round in 0..rounds {
        for w in words.iter_mut() {
            *w = rng();
        }
        comp.run_into(&mut cur, &words);
        if round > 0 {
            for i in 0..cur.len() {
                toggles[i] += (cur[i] ^ prev[i]).count_ones() as u64;
            }
            total_pairs += 64;
        }
        std::mem::swap(&mut cur, &mut prev);
    }
    toggles
        .iter()
        .map(|&t| if total_pairs == 0 { 0.0 } else { t as f64 / total_pairs as f64 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Netlist;

    /// 2-bit ripple adder built from discrete gates.
    fn adder2() -> (Netlist, Vec<NodeId>) {
        let mut nl = Netlist::new("add2");
        let a: Vec<_> = (0..2).map(|i| nl.input(format!("a{i}"))).collect();
        let b: Vec<_> = (0..2).map(|i| nl.input(format!("b{i}"))).collect();
        // bit 0: half adder
        let s0 = nl.xor2(a[0], b[0]);
        let c0 = nl.and2(a[0], b[0]);
        // bit 1: full adder
        let x1 = nl.xor2(a[1], b[1]);
        let s1 = nl.xor2(x1, c0);
        let g1 = nl.and2(a[1], b[1]);
        let p1 = nl.and2(x1, c0);
        let c1 = nl.or2(g1, p1);
        nl.output("s0", s0);
        nl.output("s1", s1);
        nl.output("c", c1);
        (nl, vec![s0, s1, c1])
    }

    #[test]
    fn adder2_exhaustive() {
        let (nl, bits) = adder2();
        // all 16 combinations fit in 16 lanes
        let assigns: Vec<Vec<bool>> = (0..16u32)
            .map(|v| vec![v & 1 != 0, v >> 1 & 1 != 0, v >> 2 & 1 != 0, v >> 3 & 1 != 0])
            .collect();
        let words = pack_lanes(&assigns);
        let mut sim = Simulator::new();
        let vals = sim.run(&nl, &words).to_vec();
        for v in 0..16u32 {
            let a = v & 3;
            let b = v >> 2 & 3;
            let got = lane_value(&vals, &bits, v);
            assert_eq!(got, u128::from(a + b), "a={a} b={b}");
        }
    }

    #[test]
    fn lane_value_signed_reads_twos_complement() {
        let (nl, bits) = adder2();
        // a = 3, b = 2 → s = 5 = 0b101 → signed over 3 bits = -3.
        let words = pack_lanes(&[vec![true, true, false, true]]);
        let mut sim = Simulator::new();
        let vals = sim.run(&nl, &words).to_vec();
        assert_eq!(lane_value(&vals, &bits, 0), 5);
        assert_eq!(lane_value_signed(&vals, &bits, 0), -3);
        assert_eq!(lane_value_signed(&vals, &bits[..2], 0), 1); // 0b01
        assert_eq!(lane_value_signed(&vals, &[], 0), 0);
    }

    #[test]
    fn constants_evaluate() {
        let mut nl = Netlist::new("c");
        let one = nl.constant(true);
        let zero = nl.constant(false);
        let o = nl.and2(one, zero);
        let o2 = nl.or2(one, zero);
        nl.output("and", o);
        nl.output("or", o2);
        let mut sim = Simulator::new();
        sim.run(&nl, &[]);
        assert_eq!(sim.word(o), 0);
        assert_eq!(sim.word(o2), !0);
    }

    #[test]
    fn compiled_is_zero_copy_of_the_netlist() {
        let (nl, _) = adder2();
        let comp = CompiledNetlist::compile(&nl);
        assert_eq!(comp.len(), nl.len());
        assert_eq!(comp.num_inputs(), nl.num_inputs());
        assert!(std::ptr::eq(comp.ops.as_ptr(), nl.ops().as_ptr()));
        assert!(std::ptr::eq(comp.fanin.as_ptr(), nl.fanin_records().as_ptr()));
    }

    #[test]
    fn toggle_activity_sane() {
        let (nl, _) = adder2();
        let act = toggle_activity(&nl, 32, 42);
        // inputs are random ⇒ toggle prob near 0.5; all activities in [0,1]
        for (i, a) in act.iter().enumerate() {
            assert!((0.0..=1.0).contains(a), "node {i} activity {a}");
        }
        let inputs = nl.inputs();
        for id in inputs {
            assert!((act[id.index()] - 0.5).abs() < 0.1);
        }
    }
}
