//! Ternary (0/1/X) constant propagation — the proof-bearing domain.
//!
//! Each node is abstracted to one of three values: proven constant 0,
//! proven constant 1, or unknown (`X`, the lattice top). The gate
//! transfer enumerates every concrete assignment of the unknown fanins
//! through [`crate::ir::CellKind::eval`] — the crate's semantic ground
//! truth — so a node is reported constant **iff the gate function forces
//! it** given what is already proven about its fanins. That is what
//! upgrades the heuristic structural lints (const-foldable / dead-gate
//! UFO0xx, const-0 enable UFO301) into proofs: the UFO4xx diagnostics in
//! [`crate::analysis`] cite a node the domain *proved* constant, not one
//! that merely looks suspicious.
//!
//! Soundness invariant (pinned by `rust/tests/analysis.rs`): for every
//! node proven `Zero`/`One`, every concrete simulation — combinational
//! 64-lane sweeps and multi-cycle [`crate::sim::ClockedSim`] traces from
//! any reachable register state — produces that bit on every lane.

use super::fixpoint::Domain;
use crate::ir::{CellKind, Netlist};

/// One point of the ternary lattice: `Zero < Unknown`, `One < Unknown`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tern {
    /// Proven constant 0 on every lane, every cycle.
    Zero,
    /// Proven constant 1 on every lane, every cycle.
    One,
    /// Not proven constant (the lattice top).
    Unknown,
}

impl Tern {
    /// The proven constant, or `None` for [`Tern::Unknown`].
    pub fn known(self) -> Option<bool> {
        match self {
            Tern::Zero => Some(false),
            Tern::One => Some(true),
            Tern::Unknown => None,
        }
    }

    /// Abstraction of a concrete bit.
    pub fn from_bool(b: bool) -> Tern {
        if b {
            Tern::One
        } else {
            Tern::Zero
        }
    }

    /// Lattice join (least upper bound).
    pub fn join(self, other: Tern) -> Tern {
        if self == other {
            self
        } else {
            Tern::Unknown
        }
    }
}

/// The constant-propagation domain. Stateless: all knobs live in the
/// engine call.
#[derive(Debug, Clone, Copy, Default)]
pub struct TernaryDomain;

/// Ternary multiplexer `s ? t : e` (join of both arms when the selector
/// is unknown).
fn mux(s: Tern, t: Tern, e: Tern) -> Tern {
    match s {
        Tern::One => t,
        Tern::Zero => e,
        Tern::Unknown => t.join(e),
    }
}

impl Domain for TernaryDomain {
    type Value = Tern;

    fn input(&self, _ordinal: usize) -> Tern {
        Tern::Unknown
    }

    fn constant(&self, one: bool) -> Tern {
        Tern::from_bool(one)
    }

    fn reg_start(&self, init: bool) -> Tern {
        Tern::from_bool(init)
    }

    fn transfer(&self, nl: &Netlist, vals: &[Tern], i: usize) -> Tern {
        let kind = CellKind::ALL[nl.ops()[i] as usize];
        let arity = kind.arity();
        let rec = nl.fanin_records()[i];
        let mut t = [Tern::Zero; 3];
        for (k, slot) in t.iter_mut().enumerate().take(arity) {
            *slot = vals[rec[k] as usize];
        }
        // Enumerate every fanin assignment consistent with what is proven
        // (≤ 2^3 rows) through the concrete truth table. If all rows
        // agree, the output is forced.
        let (mut seen0, mut seen1) = (false, false);
        for mask in 0..(1u32 << arity) {
            let mut consistent = true;
            let mut bits = [0u64; 3];
            for (k, bit) in bits.iter_mut().enumerate().take(arity) {
                let b = (mask >> k) & 1;
                match t[k] {
                    Tern::Zero if b == 1 => consistent = false,
                    Tern::One if b == 0 => consistent = false,
                    _ => {}
                }
                *bit = u64::from(b);
            }
            if !consistent {
                continue;
            }
            if kind.eval(bits[0], bits[1], bits[2]) & 1 == 1 {
                seen1 = true;
            } else {
                seen0 = true;
            }
            if seen0 && seen1 {
                break;
            }
        }
        match (seen0, seen1) {
            (true, false) => Tern::Zero,
            (false, true) => Tern::One,
            _ => Tern::Unknown,
        }
    }

    fn latch(&self, d: Tern, en: Tern, clr: Tern, q: Tern, init: bool) -> Tern {
        mux(clr, Tern::from_bool(init), mux(en, d, q))
    }

    fn widen(&self, old: Tern, next: Tern) -> Tern {
        old.join(next)
    }

    fn converged(&self, old: Tern, new: Tern) -> bool {
        old == new
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::fixpoint;
    use crate::ir::Netlist;

    #[test]
    fn gate_transfer_matches_truth_tables() {
        // and2(X, 0) = 0, or2(X, 1) = 1, xor2(X, 0) = X, inv(1) = 0,
        // aoi21(X, X, 1) = 0.
        let mut nl = Netlist::new("t");
        let x = nl.input("x");
        let y = nl.input("y");
        let zero = nl.constant(false);
        let one = nl.constant(true);
        let a = nl.and2(x, zero);
        let o = nl.or2(x, one);
        let xo = nl.xor2(x, zero);
        let inv = nl.inv(one);
        let aoi = nl.gate(CellKind::Aoi21, &[x, y, one]);
        nl.output("a", a);
        nl.output("o", o);
        nl.output("xo", xo);
        nl.output("i", inv);
        nl.output("g", aoi);
        let run = fixpoint::run(&nl, &TernaryDomain, 1, 8);
        assert_eq!(run.sweeps, 1);
        assert_eq!(run.values[a.index()], Tern::Zero);
        assert_eq!(run.values[o.index()], Tern::One);
        assert_eq!(run.values[xo.index()], Tern::Unknown);
        assert_eq!(run.values[inv.index()], Tern::Zero);
        assert_eq!(run.values[aoi.index()], Tern::Zero);
    }

    #[test]
    fn stuck_enable_register_is_proven_constant() {
        // en = and2(const0, x): a const-0 *chain*, not a direct constant —
        // the register can never load, so q is proven stuck at its init.
        let mut nl = Netlist::new("stuck");
        let x = nl.input("x");
        let d = nl.input("d");
        let zero = nl.constant(false);
        let en = nl.and2(zero, x);
        let q = nl.reg(d, en, zero, true);
        let out = nl.inv(q);
        nl.output("y", out);
        let run = fixpoint::run(&nl, &TernaryDomain, 1, 8);
        assert_eq!(run.values[en.index()], Tern::Zero);
        assert_eq!(run.values[q.index()], Tern::One, "stuck at init = 1");
        assert_eq!(run.values[out.index()], Tern::Zero);
    }

    #[test]
    fn live_register_joins_to_unknown() {
        // Feedback toggle FF with a real enable: the register state joins
        // init (0) with the toggled value (1) and lands at Unknown — as do
        // the nodes downstream of it.
        let mut nl = Netlist::new("tff");
        let en = nl.input("en");
        let clr = nl.input("clr");
        let q = nl.reg_raw(0, en.0, clr.0, false);
        let nq = nl.inv(q);
        nl.set_reg_data(q, nq);
        nl.output("q", q);
        let run = fixpoint::run(&nl, &TernaryDomain, 1, 8);
        assert_eq!(run.values[q.index()], Tern::Unknown);
        assert_eq!(run.values[nq.index()], Tern::Unknown);
        assert!(run.sweeps >= 2, "register fixpoint iterated");
    }
}
