"""Kernel vs. reference correctness — the core build-time signal.

The Pallas netlist evaluator must agree bit-for-bit with the pure-jnp
reference and the python-int golden model on random netlist encodings,
including a hand-rolled ripple-carry adder whose product we can check
against integer arithmetic.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import netlist_eval as ne
from compile.kernels import ref


def pad_encoding(ops, f0, f1, f2, size="small"):
    max_nodes, _ = ne.SIZES[size]
    assert len(ops) <= max_nodes
    pad = max_nodes - len(ops)
    ops = np.asarray(ops + [ne.OP_CONST0] * pad, dtype=np.int32)
    f0 = np.asarray(f0 + [0] * pad, dtype=np.int32)
    f1 = np.asarray(f1 + [0] * pad, dtype=np.int32)
    f2 = np.asarray(f2 + [0] * pad, dtype=np.int32)
    return ops, f0, f1, f2


def pad_words(words, size="small"):
    _, max_inputs = ne.SIZES[size]
    out = np.zeros((ne.BATCH, max_inputs), dtype=np.uint32)
    arr = np.asarray(words, dtype=np.uint32)
    out[:, : arr.shape[1]] = arr
    return out


def random_netlist(rng, n_inputs, n_gates):
    """Random topologically-ordered netlist encoding."""
    ops = [ne.OP_INPUT] * n_inputs
    f0 = list(range(n_inputs))
    f1 = [0] * n_inputs
    f2 = [0] * n_inputs
    two_in = [ne.OP_AND2, ne.OP_OR2, ne.OP_NAND2, ne.OP_NOR2, ne.OP_XOR2, ne.OP_XNOR2]
    for i in range(n_inputs, n_inputs + n_gates):
        kind = rng.integers(0, 5)
        if kind == 0:
            ops.append(int(rng.choice([ne.OP_BUF, ne.OP_INV])))
            f0.append(int(rng.integers(0, i)))
            f1.append(0)
            f2.append(0)
        elif kind <= 3:
            ops.append(int(rng.choice(two_in)))
            f0.append(int(rng.integers(0, i)))
            f1.append(int(rng.integers(0, i)))
            f2.append(0)
        else:
            ops.append(int(rng.choice([ne.OP_AOI21, ne.OP_OAI21, ne.OP_MAJ3])))
            f0.append(int(rng.integers(0, i)))
            f1.append(int(rng.integers(0, i)))
            f2.append(int(rng.integers(0, i)))
    return ops, f0, f1, f2


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_kernel_matches_ref_random_netlists(seed):
    rng = np.random.default_rng(seed)
    n_inputs, n_gates = 8, 64
    ops, f0, f1, f2 = random_netlist(rng, n_inputs, n_gates)
    opsa, f0a, f1a, f2a = pad_encoding(ops, f0, f1, f2)
    words = pad_words(rng.integers(0, 2**32, size=(ne.BATCH, n_inputs), dtype=np.uint32))
    out_kernel = np.asarray(ne.netlist_eval(opsa, f0a, f1a, f2a, words, size="small"))
    out_ref = np.asarray(ref.netlist_eval_ref(opsa, f0a, f1a, f2a, words))
    np.testing.assert_array_equal(out_kernel, out_ref)


def test_kernel_matches_python_golden_small():
    rng = np.random.default_rng(42)
    n_inputs, n_gates = 4, 12
    ops, f0, f1, f2 = random_netlist(rng, n_inputs, n_gates)
    words_np = rng.integers(0, 2**32, size=(ne.BATCH, n_inputs), dtype=np.uint32)
    opsa, f0a, f1a, f2a = pad_encoding(ops, f0, f1, f2)
    out = np.asarray(ne.netlist_eval(opsa, f0a, f1a, f2a, pad_words(words_np), size="small"))
    golden = ref.eval_netlist_python(ops, f0, f1, f2, words_np.tolist())
    n = len(ops)
    for lane in range(ne.BATCH):
        np.testing.assert_array_equal(
            out[lane, :n], np.asarray(golden[lane], dtype=np.uint32) & 0xFFFFFFFF
        )


def ripple_adder_encoding(n):
    """Gate-level n-bit ripple adder over the netlist encoding.

    Inputs: a0..a(n-1), b0..b(n-1). Outputs: sum slots, carry slot.
    """
    ops, f0, f1, f2 = [], [], [], []

    def add(op, x=0, y=0, z=0):
        ops.append(op)
        f0.append(x)
        f1.append(y)
        f2.append(z)
        return len(ops) - 1

    a = [add(ne.OP_INPUT, i) for i in range(n)]
    b = [add(ne.OP_INPUT, n + i) for i in range(n)]
    sums = []
    carry = None
    for i in range(n):
        if carry is None:
            sums.append(add(ne.OP_XOR2, a[i], b[i]))
            carry = add(ne.OP_AND2, a[i], b[i])
        else:
            x = add(ne.OP_XOR2, a[i], b[i])
            sums.append(add(ne.OP_XOR2, x, carry))
            carry = add(ne.OP_MAJ3, a[i], b[i], carry)
    return (ops, f0, f1, f2), sums, carry


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_ripple_adder_sums_correctly(n, seed):
    (ops, f0, f1, f2), sums, carry = ripple_adder_encoding(n)
    rng = np.random.default_rng(seed)
    mask = (1 << n) - 1
    avals = rng.integers(0, mask + 1, size=ne.BATCH, dtype=np.uint64)
    bvals = rng.integers(0, mask + 1, size=ne.BATCH, dtype=np.uint64)
    # Lane l of word w encodes bit l of test vector (w*32+l)… here we use
    # one scalar test per word (all 32 lanes identical) for readability.
    words = np.zeros((ne.BATCH, 2 * n), dtype=np.uint32)
    for w in range(ne.BATCH):
        for k in range(n):
            words[w, k] = 0xFFFFFFFF if (int(avals[w]) >> k) & 1 else 0
            words[w, n + k] = 0xFFFFFFFF if (int(bvals[w]) >> k) & 1 else 0
    opsa, f0a, f1a, f2a = pad_encoding(ops, f0, f1, f2)
    out = np.asarray(ne.netlist_eval(opsa, f0a, f1a, f2a, pad_words(words), size="small"))
    for w in range(ne.BATCH):
        got = 0
        for k, slot in enumerate(sums):
            got |= (int(out[w, slot]) & 1) << k
        got |= (int(out[w, carry]) & 1) << n
        assert got == int(avals[w]) + int(bvals[w])


@settings(max_examples=8, deadline=None)
@given(
    n_inputs=st.integers(min_value=1, max_value=16),
    n_gates=st.integers(min_value=1, max_value=128),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_vs_ref_hypothesis_shapes(n_inputs, n_gates, seed):
    """Hypothesis sweep over encoding sizes: kernel == ref everywhere."""
    rng = np.random.default_rng(seed)
    ops, f0, f1, f2 = random_netlist(rng, n_inputs, n_gates)
    opsa, f0a, f1a, f2a = pad_encoding(ops, f0, f1, f2)
    words = pad_words(rng.integers(0, 2**32, size=(ne.BATCH, n_inputs), dtype=np.uint32))
    out_kernel = np.asarray(ne.netlist_eval(opsa, f0a, f1a, f2a, words, size="small"))
    out_ref = np.asarray(ref.netlist_eval_ref(opsa, f0a, f1a, f2a, words))
    np.testing.assert_array_equal(out_kernel, out_ref)


def test_constants_and_padding_are_inert():
    # An encoding that is all padding evaluates to zeros.
    opsa, f0a, f1a, f2a = pad_encoding([], [], [], [])
    words = pad_words(np.zeros((ne.BATCH, 1), dtype=np.uint32))
    out = np.asarray(ne.netlist_eval(opsa, f0a, f1a, f2a, words, size="small"))
    assert (out == 0).all()
    # CONST1 slots read all-ones.
    ops2, f02, f12, f22 = pad_encoding([ne.OP_CONST1], [0], [0], [0])
    out2 = np.asarray(ne.netlist_eval(ops2, f02, f12, f22, words, size="small"))
    assert (out2[:, 0] == 0xFFFFFFFF).all()
