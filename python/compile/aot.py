"""AOT lowering: JAX → HLO **text** artifacts for the Rust PJRT runtime.

HLO text (not a serialized HloModuleProto) is the interchange format: the
image's xla_extension 0.5.1 rejects jax ≥ 0.5 protos (64-bit instruction
ids); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
``artifacts`` target). Also writes ``manifest.json`` describing each
artifact's argument shapes so the Rust side can size its buffers.
"""

import argparse
import functools
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


ARTIFACTS = {
    "netlist_eval_small": (
        functools.partial(model.verify_netlist, size="small"),
        ("netlist", "small"),
    ),
    "netlist_eval_large": (
        functools.partial(model.verify_netlist, size="large"),
        ("netlist", "large"),
    ),
    "systolic": (model.systolic_workload, ("systolic", None)),
}


def build(out_dir: str, only=None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for name, (fn, (kind, size)) in ARTIFACTS.items():
        if only and name not in only:
            continue
        args = model.example_args(kind, size or "small")
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "args": [
                {"shape": list(a.shape), "dtype": str(a.dtype)} for a in args
            ],
            "hlo_chars": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")
    man_path = os.path.join(out_dir, "manifest.json")
    existing = {}
    if os.path.exists(man_path):
        with open(man_path) as f:
            existing = json.load(f)
    existing.update(manifest)
    with open(man_path, "w") as f:
        json.dump(existing, f, indent=2, sort_keys=True)
    print(f"wrote {man_path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()
    build(args.out_dir, args.only)


if __name__ == "__main__":
    main()
