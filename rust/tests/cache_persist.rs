//! Integration tests for the persistent design-cache tier: artifacts
//! survive an engine drop/recreate, corrupted or truncated entries fall
//! back to recompute (and are rewritten), a format-version bump
//! invalidates cleanly, and concurrent writers never interleave entries.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use ufo_mac::api::{persist, CompileSource, DesignRequest, EngineConfig, SynthEngine};

/// Unique scratch directory per test (no tempfile crate in the image).
fn scratch(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "ufo_cache_persist_{tag}_{}_{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn engine_at(dir: &PathBuf) -> SynthEngine {
    SynthEngine::new(EngineConfig { cache_dir: Some(dir.clone()), ..EngineConfig::default() })
}

#[test]
fn roundtrip_across_engine_drop_and_recreate() {
    let dir = scratch("roundtrip");
    let req = DesignRequest::multiplier(6);
    let (gates, fp) = {
        let first = engine_at(&dir);
        let (art, src) = first.compile_traced(&req).unwrap();
        assert_eq!(src, CompileSource::Compiled);
        (art.sta.num_gates, art.fingerprint)
    }; // engine dropped — only the disk entry survives
    let second = engine_at(&dir);
    let (art, src) = second.compile_traced(&req).unwrap();
    assert_eq!(src, CompileSource::Disk, "fresh engine must hit the disk tier");
    assert_eq!(art.fingerprint, fp);
    assert_eq!(art.sta.num_gates, gates);
    let s = second.cache_stats();
    assert_eq!((s.hits, s.disk_hits, s.misses), (0, 1, 0), "{s:?}");
    // The served design is fully functional, not just metadata.
    let design = art.design().expect("multiplier artifact");
    assert!(ufo_mac::equiv::check_multiplier(design).unwrap().passed);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn module_artifacts_roundtrip_through_disk() {
    use ufo_mac::baselines::Method;
    use ufo_mac::multiplier::Strategy;
    let dir = scratch("module");
    let fir = DesignRequest::fir(Method::UfoMac, 4, Strategy::TradeOff, 1e9);
    let sys = DesignRequest::systolic(Method::UfoMac, 4, Strategy::TradeOff, 1e9);
    let wns = {
        let eng = engine_at(&dir);
        eng.compile(&sys).unwrap();
        eng.compile(&fir).unwrap().module_report().unwrap().wns_ns
    };
    let eng = engine_at(&dir);
    let (art, src) = eng.compile_traced(&fir).unwrap();
    assert_eq!(src, CompileSource::Disk);
    assert_eq!(art.module_report().unwrap().wns_ns, wns);
    let (art, src) = eng.compile_traced(&sys).unwrap();
    assert_eq!(src, CompileSource::Disk);
    assert!(art.design().is_some(), "systolic PE artifact carries its design");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupted_and_truncated_entries_recompute_and_rewrite() {
    let dir = scratch("corrupt");
    let req = DesignRequest::multiplier(5);
    let fp = {
        let eng = engine_at(&dir);
        eng.compile(&req).unwrap().fingerprint
    };
    let path = persist::entry_path(&dir, fp);
    let good = std::fs::read_to_string(&path).unwrap();

    // Truncated entry (torn write simulation): recompute, not a panic.
    std::fs::write(&path, &good[..good.len() / 2]).unwrap();
    let eng = engine_at(&dir);
    let (_, src) = eng.compile_traced(&req).unwrap();
    assert_eq!(src, CompileSource::Compiled, "truncated entry must recompute");
    // ...and the recompute rewrote a valid entry.
    assert!(persist::read_entry(&dir, fp).is_ok(), "entry must be rewritten");

    // Bit-rot inside the payload: caught by the checksum.
    let rewritten = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, rewritten.replacen("\"ct_stages\":", "\"ct_stages \":", 1)).unwrap();
    let eng = engine_at(&dir);
    let (_, src) = eng.compile_traced(&req).unwrap();
    assert_eq!(src, CompileSource::Compiled, "corrupted entry must recompute");
    assert!(persist::read_entry(&dir, fp).is_ok());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn format_version_bump_invalidates_cleanly() {
    let dir = scratch("version");
    let req = DesignRequest::multiplier(4);
    let fp = {
        let eng = engine_at(&dir);
        eng.compile(&req).unwrap().fingerprint
    };
    let path = persist::entry_path(&dir, fp);
    let text = std::fs::read_to_string(&path).unwrap();
    let needle = format!("\"version\":{}", persist::CACHE_FORMAT_VERSION);
    assert!(text.contains(&needle), "{text:.120}");
    std::fs::write(&path, text.replacen(&needle, "\"version\":999999", 1)).unwrap();
    // A stale-version entry is a miss (future-proofing both directions:
    // an old binary reading a new cache, and vice versa).
    assert!(persist::read_entry(&dir, fp).is_err());
    let eng = engine_at(&dir);
    let (_, src) = eng.compile_traced(&req).unwrap();
    assert_eq!(src, CompileSource::Compiled);
    // The recompute wrote the current version back.
    assert!(std::fs::read_to_string(&path).unwrap().contains(&needle));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn concurrent_writers_do_not_interleave_entries() {
    let dir = scratch("writers");
    // Eight engines (eight independent caches, like eight processes)
    // write the same fingerprints into one directory at once.
    let reqs: Vec<DesignRequest> = (4..=6).map(DesignRequest::multiplier).collect();
    std::thread::scope(|s| {
        for _ in 0..8 {
            s.spawn(|| {
                let eng = engine_at(&dir);
                for r in &reqs {
                    eng.compile(r).unwrap();
                }
            });
        }
    });
    // Every entry parses and checksum-validates — no torn or interleaved
    // writes — and no temp files are left behind.
    let eng = engine_at(&dir);
    for r in &reqs {
        let (art, src) = eng.compile_traced(r).unwrap();
        assert_eq!(src, CompileSource::Disk, "{r:?}");
        assert!(persist::read_entry(&dir, art.fingerprint).is_ok());
    }
    for f in std::fs::read_dir(&dir).unwrap() {
        let name = f.unwrap().file_name().to_string_lossy().to_string();
        assert!(name.ends_with(".json"), "leftover temp file {name}");
    }
    std::fs::remove_dir_all(&dir).ok();
}
