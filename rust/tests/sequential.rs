//! Cycle-accurate tests of the sequential IR: pipelined designs driven
//! through [`ufo_mac::sim::ClockedSim`] against the combinational golden
//! model, reset / enable-stall / synchronous-clear semantics, worker-count
//! independence of the bounded sequential equivalence sweep, and the
//! end-to-end acceptance path (build → verify → disk cache → Verilog) for
//! a 16×16 two-stage fused MAC.
//!
//! Every randomized test derives its RNG from an explicit per-trial seed
//! and includes that seed in the panic message, so a failure is
//! reproducible by pinning the printed value.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use ufo_mac::api::{CompileSource, DesignRequest, EngineConfig, SynthEngine};
use ufo_mac::equiv::{check_multiplier, check_pipelined, check_pipelined_with, EquivOptions};
use ufo_mac::multiplier::{Design, MultiplierSpec, OperandFormat};
use ufo_mac::ppg::PpgKind;
use ufo_mac::sim::{lane_value, ClockedSim, CompiledNetlist};
use ufo_mac::util::Rng;

/// Unique scratch directory per test (no tempfile crate in the image).
fn scratch(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "ufo_sequential_{tag}_{}_{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Pack a ≤64-lane batch of `(a, b, c)` operand triples into input words
/// using the design's input-ordinal layout (`a` bits, `b` bits, `c` bits),
/// then append the `pipe_en` / `pipe_clr` lane masks. The same layout as
/// the equivalence sweep's internal packer, reproduced here so the tests
/// cross-check it rather than reuse it.
fn pack(design: &Design, batch: &[(u128, u128, u128)], en: u64, clr: u64) -> Vec<u64> {
    let (aw, bw, cw) = (design.a.len(), design.b.len(), design.c.len());
    let mut words = vec![0u64; aw + bw + cw + 2];
    for (lane, &(a, b, c)) in batch.iter().enumerate() {
        let bit = 1u64 << lane;
        for k in 0..aw {
            if a >> k & 1 == 1 {
                words[k] |= bit;
            }
        }
        for k in 0..bw {
            if b >> k & 1 == 1 {
                words[aw + k] |= bit;
            }
        }
        for k in 0..cw {
            if c >> k & 1 == 1 {
                words[aw + bw + k] |= bit;
            }
        }
    }
    words[aw + bw + cw] = en;
    words[aw + bw + cw + 1] = clr;
    words
}

// ---------------------------------------------------------------------
// Property: every pipelined spec in a randomized config space matches
// the combinational golden model through the clocked sweep.
// ---------------------------------------------------------------------
#[test]
fn property_random_pipelined_specs_match_the_golden_model() {
    for trial in 0..18u64 {
        let seed = 0x5E9_0000 + trial;
        let mut rng = Rng::seed_from_u64(seed);
        let ppg = if rng.bool() { PpgKind::Booth4 } else { PpgKind::AndArray };
        let signed = rng.bool();
        // 0 = plain multiplier, 1 = fused MAC, 2 = separate MAC. MAC modes
        // stay at n ≤ 4 so the auto-exhaustive sweep (operand space at most
        // 2^20) remains cheap in debug builds.
        let mode = rng.index(3);
        let n = if mode == 0 { [3, 4, 5][rng.index(3)] } else { [3, 4][rng.index(2)] };
        let stages = 1 + rng.index(3);
        let fmt = if signed { OperandFormat::signed(n) } else { OperandFormat::unsigned(n) };
        let spec = MultiplierSpec::new_fmt(fmt)
            .ppg(ppg)
            .fused_mac(mode == 1)
            .separate_mac(mode == 2)
            .pipeline_stages(stages);
        let design = spec.build().unwrap_or_else(|e| panic!("seed {seed:#x}: build: {e}"));
        let info =
            design.pipeline.as_ref().unwrap_or_else(|| panic!("seed {seed:#x}: no pipeline"));
        assert_eq!(info.stages, stages, "seed {seed:#x}");
        assert_eq!(info.latency(), stages, "seed {seed:#x}");
        // Every product bit is registered at the final rank (deeper
        // drivers may enter the pipeline at a later slice, so the total
        // is at least one register per output, not `stages` per output).
        assert!(
            design.netlist.num_regs() >= design.product.len(),
            "seed {seed:#x}: {} regs for {} stages over {} product bits",
            design.netlist.num_regs(),
            stages,
            design.product.len()
        );
        let rep = check_pipelined_with(&design, 1 << 8)
            .unwrap_or_else(|e| panic!("seed {seed:#x}: equiv: {e}"));
        assert!(
            rep.passed,
            "seed {seed:#x}: ppg={ppg:?} signed={signed} mode={mode} n={n} stages={stages} \
             cex={:?}",
            rep.counterexample
        );
    }
}

// ---------------------------------------------------------------------
// Property: a pipeline is a pure delay — lane-for-lane identical to the
// combinational twin built from the same spec, `latency` cycles later.
// ---------------------------------------------------------------------
#[test]
fn pipeline_is_a_pure_delay_of_the_combinational_twin() {
    for &(n, stages, seed) in &[(4usize, 1usize, 0xDE1A_1u64), (5, 2, 0xDE1A_2), (4, 3, 0xDE1A_3)]
    {
        let mut rng = Rng::seed_from_u64(seed);
        let comb = MultiplierSpec::new(n).build().unwrap();
        let pipe = MultiplierSpec::new(n).pipeline_stages(stages).build().unwrap();
        let batch: Vec<(u128, u128, u128)> = (0..64)
            .map(|_| (u128::from(rng.below(1 << n)), u128::from(rng.below(1 << n)), 0))
            .collect();

        let comp = CompiledNetlist::compile(&comb.netlist);
        let mut buf = Vec::new();
        let words = pack(&comb, &batch, 0, 0);
        comp.run_into(&mut buf, &words[..words.len() - 2]);

        let mut sim = ClockedSim::new(&pipe.netlist);
        sim.reset();
        let words = pack(&pipe, &batch, !0, 0);
        for _ in 0..stages {
            sim.step(&words);
        }
        let view = sim.step(&words);
        for (lane, &(a, b, _)) in batch.iter().enumerate() {
            let golden = lane_value(&buf, &comb.product, lane as u32);
            let clocked = lane_value(view, &pipe.product, lane as u32);
            assert_eq!(
                clocked, golden,
                "seed {seed:#x}: n={n} stages={stages} lane {lane} a={a} b={b}"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Streaming: initiation interval 1 — a new operand pair every cycle, one
// result per cycle once the pipeline has filled.
// ---------------------------------------------------------------------
#[test]
fn streaming_produces_one_result_per_cycle_after_fill() {
    let design = MultiplierSpec::new(4).pipeline_stages(3).build().unwrap();
    let lat = design.pipeline.as_ref().unwrap().latency();
    let seed = 0x57AB_u64;
    let mut rng = Rng::seed_from_u64(seed);
    let stream: Vec<(u128, u128, u128)> =
        (0..20).map(|_| (u128::from(rng.below(16)), u128::from(rng.below(16)), 0)).collect();
    let mut sim = ClockedSim::new(&design.netlist);
    sim.reset();
    for (t, &(a, b, c)) in stream.iter().enumerate() {
        let view = sim.step(&pack(&design, &[(a, b, c)], !0, 0));
        if t >= lat {
            let (ea, eb, ec) = stream[t - lat];
            assert_eq!(
                lane_value(view, &design.product, 0),
                design.expected(ea, eb, ec),
                "seed {seed:#x}: cycle {t} must expose the result issued {lat} cycles earlier"
            );
        } else {
            assert_eq!(
                lane_value(view, &design.product, 0),
                0,
                "seed {seed:#x}: cycle {t} is still inside the fill latency"
            );
        }
    }
    assert_eq!(sim.cycles(), stream.len() as u64);
}

// ---------------------------------------------------------------------
// Reset, enable-stall, and synchronous-clear semantics.
// ---------------------------------------------------------------------
#[test]
fn reset_stall_and_clear_semantics() {
    let design = MultiplierSpec::new(4).pipeline_stages(2).build().unwrap();
    let mut sim = ClockedSim::new(&design.netlist);
    sim.reset();
    assert_eq!(sim.cycles(), 0);

    // Cold pipeline: the first pre-edge view is the all-init reset state.
    let va = pack(&design, &[(11, 13, 0)], !0, 0);
    let view = sim.step(&va);
    assert_eq!(lane_value(view, &design.product, 0), 0, "product registers reset to init");

    // Fill: the result is visible after `latency` edges.
    sim.step(&va);
    let view = sim.step(&va);
    let want_a = design.expected(11, 13, 0);
    assert_eq!(lane_value(view, &design.product, 0), want_a);

    // Stall: with pipe_en low every rank holds, whatever the data inputs do.
    let garbage = pack(&design, &[(5, 7, 0)], 0, 0);
    for k in 0..3 {
        let view = sim.step(&garbage);
        assert_eq!(
            lane_value(view, &design.product, 0),
            want_a,
            "stalled pipeline must hold its output (stall cycle {k})"
        );
    }

    // Resume: in-flight ranks drain first, the new result lands
    // `latency` edges after re-enable.
    let vb = pack(&design, &[(9, 3, 0)], !0, 0);
    sim.step(&vb);
    let view = sim.step(&vb);
    assert_eq!(lane_value(view, &design.product, 0), want_a, "old result drains out first");
    let view = sim.step(&vb);
    assert_eq!(lane_value(view, &design.product, 0), design.expected(9, 3, 0));

    // Clear: one pipe_clr pulse reloads every init, overriding pipe_en.
    let clr = pack(&design, &[(9, 3, 0)], !0, !0);
    sim.step(&clr);
    let view = sim.step(&vb);
    assert_eq!(lane_value(view, &design.product, 0), 0, "clr overrides en and data");
}

// ---------------------------------------------------------------------
// The en / clr controls are lane masks, not globals: each of the 64
// simulated lanes carries its own control bit.
// ---------------------------------------------------------------------
#[test]
fn enable_and_clear_are_per_lane() {
    let design = MultiplierSpec::new(3).pipeline_stages(1).build().unwrap();
    let mut sim = ClockedSim::new(&design.netlist);
    sim.reset();

    // Lane 0 runs, lane 1 stays stalled in the reset state.
    let w = pack(&design, &[(5, 6, 0), (7, 7, 0)], 0b01, 0);
    sim.step(&w);
    let view = sim.step(&w);
    assert_eq!(lane_value(view, &design.product, 0), design.expected(5, 6, 0));
    assert_eq!(lane_value(view, &design.product, 1), 0, "lane 1 is disabled");

    // Now clear lane 0 only while enabling lane 1.
    let w2 = pack(&design, &[(5, 6, 0), (7, 7, 0)], 0b10, 0b01);
    sim.step(&w2);
    let view = sim.step(&w2);
    assert_eq!(lane_value(view, &design.product, 0), 0, "lane 0 cleared back to init");
    assert_eq!(lane_value(view, &design.product, 1), design.expected(7, 7, 0));
}

// ---------------------------------------------------------------------
// Worker-count independence of the clocked sweep (passing design).
// ---------------------------------------------------------------------
#[test]
fn worker_count_never_changes_the_report() {
    let design = MultiplierSpec::new(4).fused_mac(true).pipeline_stages(2).build().unwrap();
    let reports: Vec<_> = [(1usize, 1usize), (2, 1), (4, 4), (7, 8)]
        .iter()
        .map(|&(t, w)| {
            check_pipelined(&design, &EquivOptions { budget: 1 << 8, threads: t, width: w })
                .unwrap()
        })
        .collect();
    assert!(reports[0].passed && reports[0].exhaustive);
    assert_eq!(reports[0].vectors, 1 << 16, "4+4+8 operand bits sweep exhaustively");
    for (k, r) in reports.iter().enumerate().skip(1) {
        assert_eq!(r.passed, reports[0].passed, "threads/width run {k}");
        assert_eq!(r.vectors, reports[0].vectors, "threads/width run {k}");
        assert_eq!(r.exhaustive, reports[0].exhaustive, "threads/width run {k}");
        assert_eq!(r.counterexample, reports[0].counterexample, "threads/width run {k}");
    }
}

// ---------------------------------------------------------------------
// Worker-count independence of the counterexample: an injected fault in
// a pipelined netlist reports the identical first failure for every
// thread count (the deterministic minimum-failing-batch rule).
// ---------------------------------------------------------------------
#[test]
fn injected_fault_counterexample_is_worker_count_independent() {
    use ufo_mac::ir::{CellKind, Netlist, Node};
    // 6×6 plain (12 operand bits → 64 exhaustive batches, enough for the
    // parallel sweep path; fewer than 8 batches falls back to one worker).
    let mut design = MultiplierSpec::new(6).pipeline_stages(2).build().unwrap();
    let pick = design
        .netlist
        .iter()
        .enumerate()
        .filter(|(_, n)| matches!(n, Node::Gate { kind: CellKind::Xor2, .. }))
        .map(|(i, _)| i)
        .last()
        .expect("a 6x6 multiplier CPA has XOR cells");
    let mut nl = Netlist::new(design.netlist.name.clone());
    for (i, node) in design.netlist.iter().enumerate() {
        match node {
            Node::Input { name, arrival_ns } => {
                nl.input_at(name, arrival_ns);
            }
            Node::Const(v) => {
                nl.constant(v);
            }
            Node::Gate { kind, fanin } => {
                let k = if i == pick { CellKind::Xnor2 } else { kind };
                nl.gate(k, fanin);
            }
            Node::Reg { d, en, clr, init } => {
                nl.reg_raw(d.0, en.0, clr.0, init);
            }
        }
    }
    for (name, id) in design.netlist.outputs() {
        nl.output(name, id);
    }
    design.netlist = nl;
    design.netlist.validate().unwrap();

    let reports: Vec<_> = [(1usize, 1usize), (2, 2), (4, 4), (7, 8)]
        .iter()
        .map(|&(t, w)| {
            check_pipelined(&design, &EquivOptions { budget: 1 << 8, threads: t, width: w })
                .unwrap()
        })
        .collect();
    assert!(!reports[0].passed, "an inverted CPA xor must be caught");
    let cex = reports[0].counterexample.expect("failing run reports a counterexample");
    for (k, r) in reports.iter().enumerate().skip(1) {
        assert_eq!(
            (r.passed, r.vectors, r.counterexample),
            (false, reports[0].vectors, Some(cex)),
            "threads/width run {k} must report the identical first failure"
        );
    }
}

// ---------------------------------------------------------------------
// Acceptance: a 16×16 two-stage pipelined fused MAC builds, verifies
// through the engine's clocked sweep, round-trips the disk cache, passes
// bounded sequential equivalence on the restored design, and emits
// clocked Verilog. Small pipelines cross the auto-exhaustive threshold.
// ---------------------------------------------------------------------
#[test]
fn acceptance_16x16_two_stage_fused_mac() {
    let dir = scratch("accept");
    let req = DesignRequest::from_spec(
        &MultiplierSpec::new(16).fused_mac(true).pipeline_stages(2),
    );
    let fp = {
        let eng = SynthEngine::new(EngineConfig {
            cache_dir: Some(dir.clone()),
            verify_vectors: 256,
            ..EngineConfig::default()
        });
        let (art, src) = eng.compile_traced(&req).unwrap();
        assert_eq!(src, CompileSource::Compiled);
        assert_eq!(art.verified, Some(true), "engine verifies through the clocked sweep");
        let p = art.pipeline().expect("pipelined artifact");
        assert_eq!((p.stages, p.latency()), (2, 2));
        art.fingerprint
    }; // engine dropped — only the disk entry survives

    let eng = SynthEngine::new(EngineConfig {
        cache_dir: Some(dir.clone()),
        ..EngineConfig::default()
    });
    let (art, src) = eng.compile_traced(&req).unwrap();
    assert_eq!(src, CompileSource::Disk, "fresh engine must hit the disk tier");
    assert_eq!(art.fingerprint, fp);
    let design = art.design().expect("multiplier artifact carries its design");
    let info = design.pipeline.as_ref().expect("restored design keeps its pipeline");
    assert_eq!(info.stages, 2);
    assert!(design.netlist.is_sequential());

    // Bounded sequential equivalence on the restored (disk-tier) design.
    let rep = check_pipelined_with(design, 1 << 10).unwrap();
    assert!(rep.passed, "cex={:?}", rep.counterexample);
    assert!(!rep.exhaustive, "16+16+32 operand bits is beyond the 2^20 exhaustive bound");

    // The auto-routed checker covers small pipelines exhaustively.
    let small = MultiplierSpec::new(4).fused_mac(true).pipeline_stages(2).build().unwrap();
    let rep = check_multiplier(&small).unwrap();
    assert!(rep.passed && rep.exhaustive);
    assert_eq!(rep.vectors, 1 << 16);

    // Clocked Verilog with the sequential ports and one always_ff block.
    let v = ufo_mac::synth::verilog::emit_design(design);
    assert!(v.contains("always_ff @(posedge clk or negedge rst_n)"), "{v:.400}");
    assert!(v.contains("input  wire clk"), "{v:.400}");
    assert!(v.contains("input  wire rst_n"), "{v:.400}");
    assert_eq!(v.matches("always_ff").count(), 1, "one shared (en, clr) register group");
    std::fs::remove_dir_all(&dir).ok();
}
