//! Gate-level netlist IR.
//!
//! A [`Netlist`] is a topologically-ordered DAG of standard cells over
//! primary inputs and constants. Nodes are created append-only and may only
//! reference already-created nodes, so every forward pass (simulation, STA,
//! power) is a single linear sweep — the property the coordinator's hot
//! paths rely on.

use super::cell::{CellKind, CellLib};

use std::collections::HashMap;

/// Index of a node (primary input, constant, or gate output) in a netlist.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl NodeId {
    #[inline]
    /// The node's position in the netlist.
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// A netlist node.
#[derive(Debug, Clone)]
pub enum Node {
    /// Primary input with an externally supplied arrival time (ns).
    Input { name: String, arrival_ns: f64 },
    /// Constant 0 / 1.
    Const(bool),
    /// A standard cell instance; `fanin.len() == kind.arity()`.
    Gate { kind: CellKind, fanin: Vec<NodeId> },
}

/// Gate-level netlist with named primary outputs.
#[derive(Debug, Clone, Default)]
pub struct Netlist {
    /// Diagnostic name (used in error messages and reports).
    pub name: String,
    nodes: Vec<Node>,
    outputs: Vec<(String, NodeId)>,
    n_inputs: usize,
}

impl Netlist {
    /// Empty netlist with a diagnostic name.
    pub fn new(name: impl Into<String>) -> Self {
        Netlist { name: name.into(), ..Default::default() }
    }

    /// Add a primary input arriving at t=0.
    pub fn input(&mut self, name: impl Into<String>) -> NodeId {
        self.input_at(name, 0.0)
    }

    /// Add a primary input with a non-zero arrival time (ns) — the mechanism
    /// behind the paper's non-uniform CPA arrival profiles.
    pub fn input_at(&mut self, name: impl Into<String>, arrival_ns: f64) -> NodeId {
        let id = NodeId(self.nodes.len() as u32);
        self.nodes.push(Node::Input { name: name.into(), arrival_ns });
        self.n_inputs += 1;
        id
    }

    /// Change the arrival time (ns) of an existing primary input — the
    /// mutation an optimization move makes when an upstream change (a CT
    /// interconnect swap, a revised column profile) shifts when this
    /// input's data shows up. [`crate::sta::IncrementalSta`] re-times only
    /// the input's fan-out cone after such an edit. Panics if `id` is not
    /// an input.
    pub fn set_input_arrival(&mut self, id: NodeId, arrival_ns: f64) {
        match &mut self.nodes[id.index()] {
            Node::Input { arrival_ns: t, .. } => *t = arrival_ns,
            other => panic!("set_input_arrival on non-input node {other:?}"),
        }
    }

    /// Add a constant node.
    pub fn constant(&mut self, value: bool) -> NodeId {
        let id = NodeId(self.nodes.len() as u32);
        self.nodes.push(Node::Const(value));
        id
    }

    /// Instantiate a gate. Panics if arity mismatches or a fanin is a
    /// forward reference (which would break topological order).
    pub fn gate(&mut self, kind: CellKind, fanin: &[NodeId]) -> NodeId {
        assert_eq!(fanin.len(), kind.arity(), "{kind:?} arity");
        let id = NodeId(self.nodes.len() as u32);
        for f in fanin {
            assert!(f.0 < id.0, "fanin {f:?} is a forward reference");
        }
        self.nodes.push(Node::Gate { kind, fanin: fanin.to_vec() });
        id
    }

    // -- convenience constructors used throughout the synthesizer --------
    /// `a · b`.
    pub fn and2(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.gate(CellKind::And2, &[a, b])
    }
    /// `a + b`.
    pub fn or2(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.gate(CellKind::Or2, &[a, b])
    }
    /// `!(a · b)`.
    pub fn nand2(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.gate(CellKind::Nand2, &[a, b])
    }
    /// `!(a + b)`.
    pub fn nor2(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.gate(CellKind::Nor2, &[a, b])
    }
    /// `a ⊕ b`.
    pub fn xor2(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.gate(CellKind::Xor2, &[a, b])
    }
    /// `!(a ⊕ b)`.
    pub fn xnor2(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.gate(CellKind::Xnor2, &[a, b])
    }
    /// `!a`.
    pub fn inv(&mut self, a: NodeId) -> NodeId {
        self.gate(CellKind::Inv, &[a])
    }
    /// Buffer (`a`).
    pub fn buf(&mut self, a: NodeId) -> NodeId {
        self.gate(CellKind::Buf, &[a])
    }
    /// `!((a · b) + c)`.
    pub fn aoi21(&mut self, a: NodeId, b: NodeId, c: NodeId) -> NodeId {
        self.gate(CellKind::Aoi21, &[a, b, c])
    }
    /// `!((a + b) · c)`.
    pub fn oai21(&mut self, a: NodeId, b: NodeId, c: NodeId) -> NodeId {
        self.gate(CellKind::Oai21, &[a, b, c])
    }
    /// Majority of three (the full-adder carry).
    pub fn maj3(&mut self, a: NodeId, b: NodeId, c: NodeId) -> NodeId {
        self.gate(CellKind::Maj3, &[a, b, c])
    }

    /// Register a named primary output.
    pub fn output(&mut self, name: impl Into<String>, id: NodeId) {
        self.outputs.push((name.into(), id));
    }

    // -- accessors --------------------------------------------------------
    /// All nodes in topological order.
    #[inline]
    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }
    /// One node by id.
    #[inline]
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.index()]
    }
    /// Node count (inputs + constants + gates).
    #[inline]
    pub fn len(&self) -> usize {
        self.nodes.len()
    }
    /// Whether the netlist has no nodes.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
    /// Named primary outputs in registration order.
    pub fn outputs(&self) -> &[(String, NodeId)] {
        &self.outputs
    }
    /// Primary-input count.
    pub fn num_inputs(&self) -> usize {
        self.n_inputs
    }

    /// Number of gate instances (excludes inputs/constants).
    pub fn num_gates(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Node::Gate { .. })).count()
    }

    /// Primary inputs in creation order.
    pub fn inputs(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| matches!(n, Node::Input { .. }))
            .map(|(i, _)| NodeId(i as u32))
            .collect()
    }

    /// Map input name → node id.
    pub fn input_map(&self) -> HashMap<String, NodeId> {
        self.nodes
            .iter()
            .enumerate()
            .filter_map(|(i, n)| match n {
                Node::Input { name, .. } => Some((name.clone(), NodeId(i as u32))),
                _ => None,
            })
            .collect()
    }

    /// Total cell area in µm².
    pub fn area_um2(&self, lib: &CellLib) -> f64 {
        self.nodes
            .iter()
            .map(|n| match n {
                Node::Gate { kind, .. } => lib.params(*kind).area_um2,
                _ => 0.0,
            })
            .sum()
    }

    /// Fanout count per node (number of gate inputs each node drives;
    /// primary outputs add `1` each).
    pub fn fanout_counts(&self) -> Vec<u32> {
        let mut fo = vec![0u32; self.nodes.len()];
        for n in &self.nodes {
            if let Node::Gate { fanin, .. } = n {
                for f in fanin {
                    fo[f.index()] += 1;
                }
            }
        }
        for (_, id) in &self.outputs {
            fo[id.index()] += 1;
        }
        fo
    }

    /// Capacitive load per node in unit loads (sum of driven input caps;
    /// primary outputs add `lib.output_load`).
    pub fn loads(&self, lib: &CellLib) -> Vec<f64> {
        let mut load = vec![0.0f64; self.nodes.len()];
        for n in &self.nodes {
            if let Node::Gate { kind, fanin } = n {
                let cin = lib.params(*kind).input_cap;
                for f in fanin {
                    load[f.index()] += cin;
                }
            }
        }
        for (_, id) in &self.outputs {
            load[id.index()] += lib.output_load;
        }
        load
    }

    /// Logic depth (gate count) per node; inputs/constants are depth 0.
    pub fn depths(&self) -> Vec<u32> {
        let mut d = vec![0u32; self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            if let Node::Gate { fanin, .. } = n {
                d[i] = 1 + fanin.iter().map(|f| d[f.index()]).max().unwrap_or(0);
            }
        }
        d
    }

    /// Maximum logic depth over primary outputs.
    pub fn depth(&self) -> u32 {
        let d = self.depths();
        self.outputs.iter().map(|(_, id)| d[id.index()]).max().unwrap_or(0)
    }

    /// Histogram of cell kinds, for reports.
    pub fn cell_histogram(&self) -> HashMap<CellKind, usize> {
        let mut h = HashMap::new();
        for n in &self.nodes {
            if let Node::Gate { kind, .. } = n {
                *h.entry(*kind).or_insert(0) += 1;
            }
        }
        h
    }

    /// Structural validation: arities and topological order. Returns a
    /// human-readable error description on failure.
    pub fn validate(&self) -> Result<(), String> {
        for (i, n) in self.nodes.iter().enumerate() {
            if let Node::Gate { kind, fanin } = n {
                if fanin.len() != kind.arity() {
                    return Err(format!("node {i}: {kind:?} with {} fanins", fanin.len()));
                }
                for f in fanin {
                    if f.index() >= i {
                        return Err(format!("node {i}: forward/self reference to {}", f.0));
                    }
                }
            }
        }
        for (name, id) in &self.outputs {
            if id.index() >= self.nodes.len() {
                return Err(format!("output {name}: dangling node {}", id.0));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xor_chain(n: usize) -> Netlist {
        let mut nl = Netlist::new("xorchain");
        let mut prev = nl.input("i0");
        for k in 1..=n {
            let i = nl.input(format!("i{k}"));
            prev = nl.xor2(prev, i);
        }
        nl.output("o", prev);
        nl
    }

    #[test]
    fn builds_and_validates() {
        let nl = xor_chain(7);
        nl.validate().unwrap();
        assert_eq!(nl.num_inputs(), 8);
        assert_eq!(nl.num_gates(), 7);
        assert_eq!(nl.depth(), 7);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let mut nl = Netlist::new("bad");
        let a = nl.input("a");
        nl.gate(CellKind::Xor2, &[a]);
    }

    #[test]
    fn fanout_and_load_accounting() {
        let mut nl = Netlist::new("fan");
        let a = nl.input("a");
        let b = nl.input("b");
        let x = nl.xor2(a, b);
        let y = nl.and2(x, a);
        let z = nl.or2(x, y);
        nl.output("z", z);
        let fo = nl.fanout_counts();
        assert_eq!(fo[x.index()], 2); // x drives y and z
        assert_eq!(fo[a.index()], 2); // a drives x and y
        let lib = CellLib::nangate45();
        let loads = nl.loads(&lib);
        let expect = lib.params(CellKind::And2).input_cap + lib.params(CellKind::Or2).input_cap;
        assert!((loads[x.index()] - expect).abs() < 1e-12);
        // output z carries the default output load
        assert!((loads[z.index()] - lib.output_load).abs() < 1e-12);
    }

    #[test]
    fn area_sums_cells_only() {
        let nl = xor_chain(3);
        let lib = CellLib::nangate45();
        let expect = 3.0 * lib.params(CellKind::Xor2).area_um2;
        assert!((nl.area_um2(&lib) - expect).abs() < 1e-9);
    }
}
