//! End-to-end multiplier and fused-MAC assembly (PPG → CT → CPA).
//!
//! [`MultiplierSpec`] is the public entry point: pick an operand format
//! (signedness + per-operand widths), a CT architecture, a CPA choice and a
//! strategy, call [`MultiplierSpec::build`] and get a [`Design`] — a
//! self-contained gate netlist with named operand inputs and product
//! outputs, plus the structural metadata the benchmarks report. The
//! fused-MAC path (§2.3) injects the accumulator rows into the CT; the
//! non-fused variant (conventional MAC: multiply, then add) exists as the
//! ablation the paper's Figure-12 discussion implies — and its second CPA
//! is optimized against the *measured* arrival profile of the first CPA's
//! sum, the same §2.2 information flow the paper prescribes for the CT→CPA
//! boundary.

use crate::cpa::{self, CpaColumn, CpaStrategy, FdcModel, PrefixGraph, PrefixStructure};
use crate::ct::{self, CtArchitecture, CtCounts, OrderStrategy, StagePlan};
use crate::ir::{CellLib, Netlist, NodeId};
use crate::ppg::{self, PpgKind, Signedness};
use crate::sta::TimingStats;
use crate::synth::{CompressorTiming, Sig};
use crate::util::sign_extend;
use crate::Result;
use anyhow::bail;

pub use crate::ppg::OperandFormat;

pub mod pipeline;
pub use pipeline::{insert_pipeline, PipelineInfo, PipelinedNetlist};

/// Which CPA the design uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CpaChoice {
    /// UFO-MAC §4: hybrid initial structure from the CT profile +
    /// Algorithm-2 timing-driven optimization.
    ProfileOptimized,
    /// A fixed regular prefix structure (baselines).
    Regular(PrefixStructure),
}

/// Overall design strategy (maps to the paper's three synthesis presets).
pub type Strategy = CpaStrategy;

/// Specification for a multiplier / MAC design.
#[derive(Debug, Clone)]
pub struct MultiplierSpec {
    /// Wider operand width (reporting; equals both widths for square
    /// formats). [`MultiplierSpec::format`] is the source of truth.
    pub n: usize,
    /// Operand format: signedness + per-operand widths.
    pub format: OperandFormat,
    /// Partial-product generator.
    pub ppg: PpgKind,
    /// Compressor-tree architecture.
    pub ct: CtArchitecture,
    /// Interconnect-order override.
    pub order_override: Option<OrderStrategy>,
    /// Custom stage plan (used by the RL-MUL baseline's searched trees).
    pub ct_plan: Option<StagePlan>,
    /// Carry-propagate adder choice.
    pub cpa: CpaChoice,
    /// Synthesis strategy preset.
    pub strategy: Strategy,
    /// Fuse an `(a_bits+b_bits)`-bit accumulator into the CT (§2.3).
    pub fused_mac: bool,
    /// Conventional MAC: multiply then add with a separate CPA.
    pub separate_mac: bool,
    /// FDC timing model driving CPA optimization.
    pub fdc_model: FdcModel,
    /// Register ranks to cut into the datapath (`0` = combinational).
    /// Cuts are placed along the STA arrival profile; see
    /// [`pipeline::insert_pipeline`].
    pub pipeline_stages: usize,
}

impl MultiplierSpec {
    /// UFO-MAC defaults for an unsigned `n×n` multiplier.
    pub fn new(n: usize) -> Self {
        MultiplierSpec {
            n,
            format: OperandFormat::unsigned(n),
            ppg: PpgKind::AndArray,
            ct: CtArchitecture::UfoMac,
            order_override: None,
            ct_plan: None,
            cpa: CpaChoice::ProfileOptimized,
            strategy: CpaStrategy::TradeOff,
            fused_mac: false,
            separate_mac: false,
            fdc_model: FdcModel::default_prior(),
            pipeline_stages: 0,
        }
    }

    /// UFO-MAC defaults for an explicit operand format (signed and/or
    /// rectangular designs).
    pub fn new_fmt(format: OperandFormat) -> Self {
        MultiplierSpec { format, ..MultiplierSpec::new(format.max_bits()) }
    }

    /// Set the operand format (also refreshes the reporting width).
    pub fn format(mut self, f: OperandFormat) -> Self {
        self.format = f;
        self.n = f.max_bits();
        self
    }
    /// Toggle two's-complement operand interpretation.
    pub fn signed(mut self, yes: bool) -> Self {
        self.format.signedness = if yes { Signedness::Signed } else { Signedness::Unsigned };
        self
    }
    /// Set the synthesis strategy preset.
    pub fn strategy(mut self, s: Strategy) -> Self {
        self.strategy = s;
        self
    }
    /// Set the compressor-tree architecture.
    pub fn ct(mut self, ct: CtArchitecture) -> Self {
        self.ct = ct;
        self
    }
    /// Set the CPA choice.
    pub fn cpa(mut self, cpa: CpaChoice) -> Self {
        self.cpa = cpa;
        self
    }
    /// Set the partial-product generator.
    pub fn ppg(mut self, ppg: PpgKind) -> Self {
        self.ppg = ppg;
        self
    }
    /// Toggle the §2.3 fused accumulator.
    pub fn fused_mac(mut self, yes: bool) -> Self {
        self.fused_mac = yes;
        self
    }
    /// Toggle the conventional multiply-then-add MAC.
    pub fn separate_mac(mut self, yes: bool) -> Self {
        self.separate_mac = yes;
        self
    }
    /// Force an interconnect-order strategy.
    pub fn order(mut self, o: OrderStrategy) -> Self {
        self.order_override = Some(o);
        self
    }
    /// Use a custom CT stage plan (RL-MUL searched trees).
    pub fn with_plan(mut self, plan: StagePlan) -> Self {
        self.ct_plan = Some(plan);
        self
    }
    /// Use a fitted FDC timing model.
    pub fn fdc(mut self, m: FdcModel) -> Self {
        self.fdc_model = m;
        self
    }
    /// Cut `k` register ranks into the datapath along the STA arrival
    /// profile (`0` keeps the design combinational). The built design
    /// then has a `k`-cycle latency and shared `pipe_en`/`pipe_clr`
    /// control inputs.
    pub fn pipeline_stages(mut self, k: usize) -> Self {
        self.pipeline_stages = k;
        self
    }

    /// Build the gate-level design.
    ///
    /// Shim over the unified engine: the spec is captured as a
    /// [`crate::api::DesignRequest`] and compiled by the process-global
    /// [`crate::api::SynthEngine`], so repeated identical builds are
    /// served from the content-addressed design cache. New code should
    /// compile requests directly.
    pub fn build(&self) -> Result<Design> {
        // Validate the one state a DesignRequest cannot represent.
        if self.fused_mac && self.separate_mac {
            bail!("fused_mac and separate_mac are mutually exclusive");
        }
        let art = crate::api::engine().compile(&crate::api::DesignRequest::from_spec(self))?;
        Ok(art.design().expect("multiplier artifact carries a design").clone())
    }

    /// Build against a caller-provided cell library and timing model —
    /// the engine's uncached inner path. Prefer [`MultiplierSpec::build`]
    /// (cached) unless you are the engine.
    pub fn build_with(&self, lib: &CellLib, tm: &CompressorTiming) -> Result<Design> {
        Ok(self.build_with_trace(lib, tm)?.0)
    }

    /// [`MultiplierSpec::build_with`] that also returns the
    /// [`DatapathTrace`] — the stage plan, counts, recorded arrival
    /// profiles and prefix graphs the build executed — so
    /// [`crate::lint::lint_design`] can cross-check the netlist against
    /// the evidence instead of re-deriving the datapath from gates.
    pub fn build_with_trace(
        &self,
        lib: &CellLib,
        tm: &CompressorTiming,
    ) -> Result<(Design, DatapathTrace)> {
        let fmt = self.format;
        if let Err(e) = fmt.validate() {
            bail!("invalid operand format: {e}");
        }
        if self.fused_mac && self.separate_mac {
            bail!("fused_mac and separate_mac are mutually exclusive");
        }
        let (na, nb) = (fmt.a_bits, fmt.b_bits);
        let out_w = na + nb;
        let is_mac = self.fused_mac || self.separate_mac;
        let signed = fmt.is_signed();
        let mut nl = Netlist::new(format!(
            "{}{}{}x{}",
            if signed { "s" } else { "" },
            if is_mac { "mac" } else { "mul" },
            na,
            nb
        ));
        let a: Vec<NodeId> = (0..na).map(|i| nl.input(format!("a{i}"))).collect();
        let b: Vec<NodeId> = (0..nb).map(|i| nl.input(format!("b{i}"))).collect();
        let c: Vec<NodeId> = if is_mac {
            (0..out_w).map(|i| nl.input(format!("c{i}"))).collect()
        } else {
            vec![]
        };

        // Whole-datapath capacity estimate so the PPG → CT → CPA pipeline
        // grows the node arrays at most once: ~n·m PPG terms, 5 gates per
        // 3:2 compressor over ~n·m matrix bits, and ~6 gates per CPA
        // column. The stage-exact reservations inside `build_ct` /
        // `cpa::expand` refine this; an over-estimate only costs transient
        // capacity (EXPERIMENTS.md §Perf, `netlist_build_64x64`).
        nl.reserve(7 * na * nb + 8 * out_w + 64);

        // PPG. A fused MAC produces an (a+b+1)-bit result, so the modular
        // generators (Booth compaction, Baugh–Wooley sign correction) must
        // stay exact one column further.
        let gen_cols = if self.fused_mac { out_w + 1 } else { out_w };
        let mut matrix = match (self.ppg, fmt.signedness) {
            (PpgKind::AndArray, Signedness::Unsigned) => ppg::and_array(&mut nl, lib, &a, &b),
            (PpgKind::AndArray, Signedness::Signed) => {
                ppg::and_array_signed(&mut nl, lib, &a, &b, gen_cols)
            }
            (PpgKind::Booth4, s) => ppg::booth4_fmt(&mut nl, lib, &a, &b, s, gen_cols),
        };
        if self.fused_mac {
            let addend: Vec<Sig> = c.iter().map(|&id| Sig::new(id, 0.0)).collect();
            if signed {
                // c is an (a+b)-bit two's-complement addend; mod 2^{a+b+1}
                // its sign bit also carries weight 2^{a+b}.
                matrix.add_addend_signed(&addend);
            } else {
                matrix.add_addend(&addend);
            }
        }

        // CT.
        let initial_pops: Vec<usize> = matrix.columns.iter().map(Vec::len).collect();
        let (ct_out, ct_plan_used, ct_counts) = match &self.ct_plan {
            Some(plan) => {
                let mut cols = matrix.columns;
                cols.resize(plan.width().max(cols.len()), Vec::new());
                // Lint gate on externally-supplied plans (RL-MUL searched
                // trees, server requests): `build_ct` panics on malformed
                // schedules, so vet the plan first and fail with the
                // diagnostic instead. This is the cheap always-on subset
                // guarding the candidate-evaluation loops.
                let pops: Vec<usize> = cols.iter().map(Vec::len).collect();
                if let Some(d) = crate::lint::check_plan(&pops, plan).into_iter().next() {
                    bail!("invalid CT stage plan: {d}");
                }
                let out = ct::build_ct(
                    &mut nl,
                    tm,
                    cols,
                    plan,
                    self.order_override.unwrap_or(OrderStrategy::Naive),
                );
                (out, plan.clone(), None)
            }
            None => {
                let t =
                    ct::synthesize_traced(&mut nl, tm, matrix.columns, self.ct, self.order_override);
                (t.out, t.plan, t.counts)
            }
        };
        let final_rows: Vec<usize> = ct_out.rows.iter().map(Vec::len).collect();

        // CPA over the two compressed rows.
        let width = ct_out.rows.len();
        let cpa_cols: Vec<CpaColumn> = (0..width)
            .map(|j| {
                let col = &ct_out.rows[j];
                match col.len() {
                    0 => {
                        let z = nl.constant(false);
                        CpaColumn { a: Sig::new(z, 0.0), b: None }
                    }
                    1 => CpaColumn { a: col[0], b: None },
                    _ => CpaColumn { a: col[0], b: Some(col[1]) },
                }
            })
            .collect();
        let (graph, mut cpa_timing) = match self.cpa {
            CpaChoice::ProfileOptimized => {
                let (g, rep) =
                    cpa::synthesize_for_profile(&ct_out.profile, self.strategy, &self.fdc_model);
                (g, rep.timing)
            }
            CpaChoice::Regular(s) => (cpa::build(s, width), TimingStats::default()),
        };
        let cpa_out = cpa::expand(&mut nl, &graph, &cpa_cols);
        let mut cpa_nodes = graph.size();

        // Product bits: a+b for a multiplier, a+b+1 for a fused MAC (the
        // separate MAC's extra bit comes from its own second CPA below).
        let want_mul = if self.fused_mac { out_w + 1 } else { out_w };
        let mut product: Vec<NodeId> = cpa_out.sum;
        // The CPA yields width+1 bits; pad (degenerate narrow trees) or
        // trim to the product width.
        while product.len() < want_mul {
            let z = nl.constant(false);
            product.push(z);
        }
        product.truncate(want_mul);

        // Conventional MAC: a second, separate CPA adds the accumulator.
        let mut cpa2_profile: Option<Vec<f64>> = None;
        let mut prefix2: Option<PrefixGraph> = None;
        let mut mac_trace: Option<MacProfileTrace> = None;
        if self.separate_mac {
            let add_w = out_w;
            // §2.2 arrival-profile propagation (the headline fix): the
            // second CPA's inputs do NOT arrive uniformly — each product
            // bit lands at the arrival time STA measures for the first
            // CPA's sum, while the accumulator pins arrive at t = 0.
            let sta = crate::sta::Sta {
                activity_rounds: 0,
                ..crate::sta::Sta::with_lib(lib.clone())
            };
            let at = sta.arrivals_ns(&nl);
            cpa_timing.merge(&TimingStats::full_pass(nl.len()));
            let cols2: Vec<CpaColumn> = (0..add_w)
                .map(|j| CpaColumn {
                    a: Sig::new(product[j], at[product[j].index()]),
                    b: Some(Sig::new(c[j], 0.0)),
                })
                .collect();
            let profile2: Vec<f64> = cols2
                .iter()
                .map(|col| col.a.t.max(col.b.map_or(0.0, |s| s.t)))
                .collect();
            let g2 = match self.cpa {
                CpaChoice::Regular(s) => cpa::build(s, add_w),
                CpaChoice::ProfileOptimized => {
                    // Honor the request: synthesize the second CPA for the
                    // measured profile instead of a uniform Sklansky.
                    let (g, rep) =
                        cpa::synthesize_for_profile(&profile2, self.strategy, &self.fdc_model);
                    cpa_timing.merge(&rep.timing);
                    g
                }
            };
            let out2 = cpa::expand(&mut nl, &g2, &cols2);
            cpa_nodes += g2.size();
            mac_trace = Some(MacProfileTrace {
                sum_nodes: product[..add_w].to_vec(),
                measured: (0..add_w).map(|j| at[product[j].index()]).collect(),
                basis: profile2.clone(),
            });
            prefix2 = Some(g2);
            let mut sum2 = out2.sum;
            if signed {
                // (a·b + c) mod 2^{w+1} for w-bit two's-complement addends:
                // the MSB is carry ⊕ p_{w-1} ⊕ c_{w-1} (both addends
                // sign-extend by one bit above the adder).
                let x = nl.xor2(sum2[add_w], product[add_w - 1]);
                sum2[add_w] = nl.xor2(x, c[add_w - 1]);
            }
            product = sum2;
            product.truncate(out_w + 1);
            cpa2_profile = Some(profile2);
        }

        for (i, &p) in product.iter().enumerate() {
            nl.output(format!("p{i}"), p);
        }
        nl.validate().map_err(|e| anyhow::anyhow!("netlist invalid: {e}"))?;
        let trace = DatapathTrace {
            initial_pops,
            plan: ct_plan_used,
            counts: ct_counts,
            stage_profiles: ct_out.stage_profiles,
            final_rows,
            prefix: graph,
            prefix2,
            mac: mac_trace,
        };
        let mut design = Design {
            n: fmt.max_bits(),
            format: fmt,
            is_mac,
            netlist: nl,
            a,
            b,
            c,
            product,
            ct_stages: ct_out.stages,
            profile: ct_out.profile,
            cpa_nodes,
            timing: cpa_timing,
            cpa2_profile,
            pipeline: None,
        };
        if self.pipeline_stages > 0 {
            // Rebuild the validated combinational netlist with register
            // ranks cut along its arrival profile, then remap the
            // interface metadata into the new id space. The slicing pass
            // runs one STA sweep, accounted in the timing counters.
            let p = pipeline::insert_pipeline(&design.netlist, lib, self.pipeline_stages);
            design.timing.merge(&TimingStats::full_pass(design.netlist.len()));
            let remap = |bits: &[NodeId]| -> Vec<NodeId> {
                bits.iter().map(|id| p.base[id.index()]).collect()
            };
            design.a = remap(&design.a);
            design.b = remap(&design.b);
            design.c = remap(&design.c);
            design.product = p.outputs.clone();
            design.netlist = p.netlist;
            design.pipeline = Some(p.info);
            design
                .netlist
                .validate()
                .map_err(|e| anyhow::anyhow!("pipelined netlist invalid: {e}"))?;
        }
        Ok((design, trace))
    }
}

/// A built design: netlist + interface + structural metadata.
#[derive(Debug, Clone)]
pub struct Design {
    /// Wider operand width (square designs: the operand width).
    pub n: usize,
    /// Operand format the design implements.
    pub format: OperandFormat,
    /// Whether the design accumulates (`a·b + c`).
    pub is_mac: bool,
    /// The gate-level netlist.
    pub netlist: Netlist,
    /// Operand `a` input bits, LSB first.
    pub a: Vec<NodeId>,
    /// Operand `b` input bits, LSB first.
    pub b: Vec<NodeId>,
    /// Accumulator input bits (empty for plain multipliers).
    pub c: Vec<NodeId>,
    /// Product output bits, LSB first.
    pub product: Vec<NodeId>,
    /// Compressor-tree stage count realized.
    pub ct_stages: usize,
    /// CT output arrival-estimate profile (ns) per column.
    pub profile: Vec<f64>,
    /// CPA prefix-node count over *all* CPAs of the design (area proxy).
    pub cpa_nodes: usize,
    /// Timing-evaluation work the CPA optimization performed while
    /// building this design (incremental vs full, see [`TimingStats`]).
    pub timing: TimingStats,
    /// Separate-MAC only: the measured per-bit arrival profile the second
    /// CPA was synthesized against (`max` of the first CPA's sum arrival
    /// and the accumulator pin arrival per column).
    pub cpa2_profile: Option<Vec<f64>>,
    /// Set when the datapath was pipelined: stage count and the shared
    /// `pipe_en`/`pipe_clr` control inputs. `None` = combinational.
    pub pipeline: Option<PipelineInfo>,
}

/// Datapath evidence captured by [`MultiplierSpec::build_with_trace`]:
/// everything the build decided (schedules, counts, recorded profiles,
/// prefix graphs) that a gate-level netlist alone no longer shows. The
/// lint subsystem's `UFO1xx`/`UFO2xx` passes cross-check the design
/// against this record; it is never persisted.
#[derive(Debug, Clone)]
pub struct DatapathTrace {
    /// Partial-product population per column entering the CT (pre-resize).
    pub initial_pops: Vec<usize>,
    /// The stage plan the CT executed.
    pub plan: StagePlan,
    /// Algorithm-1 counts the plan implements (`None` for explicit
    /// searched plans and the population-driven Wallace/Dadda schedules).
    pub counts: Option<CtCounts>,
    /// Exact per-stage arrival snapshots recorded while building the CT.
    pub stage_profiles: Vec<Vec<f64>>,
    /// Bits per column after the final CT stage (must be ≤ 2).
    pub final_rows: Vec<usize>,
    /// The first (product) CPA's prefix graph.
    pub prefix: PrefixGraph,
    /// The separate-MAC second CPA's prefix graph, when one was built.
    pub prefix2: Option<PrefixGraph>,
    /// Separate-MAC arrival-handoff record (the PR-3 bug class evidence).
    pub mac: Option<MacProfileTrace>,
}

/// The separate-MAC §2.2 arrival handoff, as recorded at build time: which
/// first-CPA sum nodes fed the second CPA, what STA measured at them, and
/// the profile the second CPA was actually synthesized against.
#[derive(Debug, Clone)]
pub struct MacProfileTrace {
    /// First-CPA sum bits (LSB first) that feed the second CPA.
    pub sum_nodes: Vec<NodeId>,
    /// STA-measured arrival (ns) at each of [`MacProfileTrace::sum_nodes`]
    /// when the second CPA was synthesized.
    pub measured: Vec<f64>,
    /// The per-column profile handed to the second CPA's optimizer
    /// (`max(measured, accumulator arrival)`).
    pub basis: Vec<f64>,
}

impl Design {
    /// Reference model: what the hardware must compute, interpreted per the
    /// design's [`OperandFormat`] — operands are masked to their own widths
    /// and, for signed formats, read as two's complement; the result is the
    /// low `product.len()` bits of `a·b (+ c)`.
    pub fn expected(&self, a: u128, b: u128, c: u128) -> u128 {
        let w = self.product.len();
        let mask = (1u128 << w) - 1;
        let am = a & ((1u128 << self.a.len()) - 1);
        let bm = b & ((1u128 << self.b.len()) - 1);
        match self.format.signedness {
            Signedness::Unsigned => {
                let cm = if self.is_mac { c & ((1u128 << self.c.len()) - 1) } else { 0 };
                (am * bm + cm) & mask
            }
            Signedness::Signed => {
                let sa = sign_extend(am, self.a.len());
                let sb = sign_extend(bm, self.b.len());
                let sc = if self.is_mac { sign_extend(c, self.c.len()) } else { 0 };
                sa.wrapping_mul(sb).wrapping_add(sc) as u128 & mask
            }
        }
    }

    /// Legacy name of [`Design::expected`].
    pub fn golden(&self, a: u128, b: u128, c: u128) -> u128 {
        self.expected(a, b, c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Exhaustive simulator equivalence against [`Design::expected`].
    fn exhaustive(spec: &MultiplierSpec) {
        let d = spec.build().unwrap();
        let rep = crate::equiv::check_multiplier(&d).unwrap();
        assert!(rep.exhaustive, "{spec:?} too wide for exhaustive check");
        assert!(rep.passed, "{spec:?}: cex {:?}", rep.counterexample);
    }

    #[test]
    fn ufo_multiplier_4x4_exhaustive() {
        exhaustive(&MultiplierSpec::new(4));
    }

    #[test]
    fn ufo_multiplier_strategies_4x4() {
        for s in [CpaStrategy::AreaDriven, CpaStrategy::TimingDriven] {
            exhaustive(&MultiplierSpec::new(4).strategy(s));
        }
    }

    #[test]
    fn baseline_cts_4x4() {
        for ct in [CtArchitecture::Wallace, CtArchitecture::Dadda, CtArchitecture::Gomil] {
            exhaustive(
                &MultiplierSpec::new(4)
                    .ct(ct)
                    .cpa(CpaChoice::Regular(PrefixStructure::KoggeStone)),
            );
        }
    }

    #[test]
    fn booth_multiplier_4x4() {
        exhaustive(&MultiplierSpec::new(4).ppg(PpgKind::Booth4));
    }

    #[test]
    fn signed_multipliers_4x4() {
        for ppg in [PpgKind::AndArray, PpgKind::Booth4] {
            exhaustive(&MultiplierSpec::new_fmt(OperandFormat::signed(4)).ppg(ppg));
        }
    }

    #[test]
    fn rectangular_multiplier_3x5() {
        for fmt in [OperandFormat::rect(3, 5), OperandFormat::signed_rect(3, 5)] {
            exhaustive(&MultiplierSpec::new_fmt(fmt));
        }
    }

    #[test]
    fn fused_mac_3x3_exhaustive() {
        exhaustive(&MultiplierSpec::new(3).fused_mac(true));
    }

    #[test]
    fn signed_fused_mac_3x3_exhaustive() {
        for ppg in [PpgKind::AndArray, PpgKind::Booth4] {
            exhaustive(&MultiplierSpec::new_fmt(OperandFormat::signed(3)).ppg(ppg).fused_mac(true));
        }
    }

    #[test]
    fn separate_mac_3x3_exhaustive() {
        exhaustive(
            &MultiplierSpec::new(3)
                .separate_mac(true)
                .cpa(CpaChoice::Regular(PrefixStructure::Sklansky)),
        );
    }

    #[test]
    fn signed_separate_mac_3x3_exhaustive() {
        exhaustive(&MultiplierSpec::new_fmt(OperandFormat::signed(3)).separate_mac(true));
    }

    #[test]
    fn degenerate_width_1_builds_and_verifies() {
        for ppg in [PpgKind::AndArray, PpgKind::Booth4] {
            exhaustive(&MultiplierSpec::new(1).ppg(ppg));
            exhaustive(&MultiplierSpec::new(1).ppg(ppg).fused_mac(true));
            exhaustive(&MultiplierSpec::new(1).ppg(ppg).separate_mac(true));
        }
    }

    #[test]
    fn fused_mac_beats_separate_mac() {
        // §2.3: fusing the accumulator into the CT eliminates a whole CPA
        // stage. With an identical CPA structure on both variants, the
        // fused design must be strictly faster and no more than marginally
        // larger (it trades a full prefix network for ~2n compressors).
        let sta = crate::sta::Sta::default();
        let fused = MultiplierSpec::new(8)
            .fused_mac(true)
            .cpa(CpaChoice::Regular(PrefixStructure::Sklansky))
            .build()
            .unwrap();
        let sep = MultiplierSpec::new(8)
            .separate_mac(true)
            .cpa(CpaChoice::Regular(PrefixStructure::Sklansky))
            .build()
            .unwrap();
        let rf = sta.analyze(&fused.netlist);
        let rs = sta.analyze(&sep.netlist);
        assert!(
            rf.critical_delay_ns < rs.critical_delay_ns,
            "delay {} vs {}",
            rf.critical_delay_ns,
            rs.critical_delay_ns
        );
        assert!(rf.area_um2 < rs.area_um2 * 1.05, "area {} vs {}", rf.area_um2, rs.area_um2);
    }

    #[test]
    fn separate_mac_second_cpa_sees_the_arrival_profile() {
        // Headline regression (§2.2): the separate MAC's second CPA must be
        // synthesized against the measured arrival profile of the first
        // CPA's sum — not a uniform-arrival Sklansky fallback.
        let d = MultiplierSpec::new(16)
            .separate_mac(true)
            .strategy(CpaStrategy::TimingDriven)
            .build()
            .unwrap();
        let profile = d.cpa2_profile.clone().expect("separate MAC records its second-CPA profile");
        assert_eq!(profile.len(), 32);
        // The first CPA's sum arrives non-uniformly — LSBs early, MSBs
        // late. A flat profile would mean the fix regressed to the old
        // uniform-arrival assumption.
        let max = profile.iter().copied().fold(0.0f64, f64::max);
        let min = profile.iter().copied().fold(f64::INFINITY, f64::min);
        assert!(max > min + 1e-9, "second-CPA profile is uniform: {profile:?}");
        // Honoring ProfileOptimized beats the old uniform-Sklansky fallback
        // on that very profile, by the same STA metric the design is
        // judged with.
        let sta = crate::sta::Sta { activity_rounds: 0, ..Default::default() };
        let model = FdcModel::default_prior();
        let (g, _) = cpa::synthesize_for_profile(&profile, CpaStrategy::TimingDriven, &model);
        let (nl_opt, _) = cpa::standalone_adder(&g, Some(&profile));
        let skl = cpa::build(PrefixStructure::Sklansky, profile.len());
        let (nl_skl, _) = cpa::standalone_adder(&skl, Some(&profile));
        let t_opt = sta.analyze(&nl_opt).critical_delay_ns;
        let t_skl = sta.analyze(&nl_skl).critical_delay_ns;
        assert!(t_opt < t_skl, "profile-optimized {t_opt} vs sklansky fallback {t_skl}");
    }

    #[test]
    fn regular_separate_mac_has_no_second_profile_surprises() {
        // Regular CPA choices keep their fixed second CPA, but the profile
        // is still recorded for reports.
        let d = MultiplierSpec::new(4)
            .separate_mac(true)
            .cpa(CpaChoice::Regular(PrefixStructure::Sklansky))
            .build()
            .unwrap();
        assert!(d.cpa2_profile.is_some());
        let d2 = MultiplierSpec::new(4).build().unwrap();
        assert!(d2.cpa2_profile.is_none());
    }

    #[test]
    fn profile_is_trapezoidal_for_16bit() {
        // Figure 1: middle columns arrive last.
        let d = MultiplierSpec::new(16).build().unwrap();
        let w = d.profile.len();
        let mid = d.profile[w / 2];
        assert!(mid >= d.profile[1], "mid {} vs lsb {}", mid, d.profile[1]);
        assert!(mid >= d.profile[w - 1], "mid {} vs msb {}", mid, d.profile[w - 1]);
        assert!(mid > 0.0);
    }

    #[test]
    fn expected_models_twos_complement() {
        let d = MultiplierSpec::new_fmt(OperandFormat::signed(4)).build().unwrap();
        // (-8) × (-8) = 64; (-1) × 3 = -3 ≡ 0xFD mod 2^8.
        assert_eq!(d.expected(8, 8, 0), 64);
        assert_eq!(d.expected(0xF, 3, 0), 0xFD);
        let u = MultiplierSpec::new(4).build().unwrap();
        assert_eq!(u.expected(8, 8, 0), 64);
        assert_eq!(u.expected(0xF, 3, 0), 45);
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(MultiplierSpec::new(0).build().is_err());
        assert!(MultiplierSpec::new_fmt(OperandFormat::rect(4, 0)).build().is_err());
        assert!(MultiplierSpec::new(4).fused_mac(true).separate_mac(true).build().is_err());
        // Degenerate-but-legal widths build (the old code rejected n = 1).
        assert!(MultiplierSpec::new(1).build().is_ok());
    }
}
