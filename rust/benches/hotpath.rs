//! Hot-path microbenchmarks — the profiling substrate for the §Perf pass
//! (EXPERIMENTS.md): STA sweeps dominate the Pareto experiments, the
//! bit-parallel simulator dominates equivalence checks + power estimation,
//! bottleneck assignment dominates CT construction, and full design
//! builds dominate the coordinator's jobs.
//!
//! Comparative groups anchor the perf trajectory:
//!
//! - **full vs incremental STA** on the repeated-optimization-move path
//!   (one input arrival shifts per move, as CT/CPA optimization does);
//! - **serial vs parallel branch & bound** on the §3.3 stage-assignment
//!   ILP;
//! - **legacy enum IR vs flat SoA IR** (the PR-5 tentpole): a faithful
//!   seed-layout netlist (one enum node + heap `Vec` fanin per gate) is
//!   rebuilt in this harness and swept side-by-side with the flat IR on
//!   identical 64×64 designs, so every run measures the before/after
//!   delta — `sta_full_64x64` vs `sta_full_64x64_legacy_ir`,
//!   `compiled_build_run_64x64` vs its `_legacy_ir` twin;
//! - **serial vs parallel equivalence** at 32×32
//!   (`equiv_sampled_32x32_parallel`, deterministic counterexamples);
//! - **narrow vs wide lanes** (the PR-10 tentpole): the width-4 bit-slice
//!   kernel swept against four width-1 runs over the same 256 vectors
//!   (`sim_run_16bit_256lanes_w4` vs `_w1x4`), the equivalence sweep at
//!   width 4, and the width-pinned toggle-activity extraction;
//! - **end-to-end `designs_per_second`**: a small coordinator sweep
//!   through a fresh engine (cold) and a warm content-addressed cache,
//!   reported as a throughput *metric* so `ufo-mac bench-check` floors it
//!   (a drop below baseline/ratio fails CI) — served throughput as a
//!   headline number, not just micro-latency.
//!
//! Results land in `BENCH_hotpath.json` via `Bench::finish`; the CI
//! bench-smoke gate (`ufo-mac bench-check`) compares them against
//! `rust/benches/baseline_hotpath.json`.

use ufo_mac::api::{DesignRequest, EngineConfig, SynthEngine};
use ufo_mac::bench::Bench;
use ufo_mac::cpa::{self, PrefixStructure};
use ufo_mac::equiv::EquivOptions;
use ufo_mac::ilp::assignment::bottleneck_assignment;
use ufo_mac::ilp::SolveOptions;
use ufo_mac::ir::{CellKind, CellLib, Netlist, Node, NodeId};
use ufo_mac::multiplier::MultiplierSpec;
use ufo_mac::sim::{CompiledNetlist, Simulator};
use ufo_mac::sta::{IncrementalSta, Sta};
use ufo_mac::util::Rng;

fn main() {
    let bench = Bench::new("hotpath");

    // Pre-built 16-bit design shared by the passive benches.
    let design = MultiplierSpec::new(16).build().unwrap();
    let nl = &design.netlist;
    println!("16-bit UFO multiplier: {} nodes / {} gates", nl.len(), nl.num_gates());

    // STA arrival sweep (the Pareto-sweep inner loop).
    let sta = Sta { activity_rounds: 0, ..Sta::default() };
    bench.bench("sta_arrivals_16bit", || sta.arrivals_ns(nl));
    bench.bench("sta_analyze_16bit_no_power_sim", || sta.analyze(nl));

    // Bit-parallel simulation (equivalence + toggle power inner loop).
    let mut sim = Simulator::new();
    let mut rng = Rng::seed_from_u64(1);
    let words: Vec<u64> = (0..nl.num_inputs()).map(|_| rng.next_u64()).collect();
    bench.bench("sim_run_16bit_64lanes", || {
        sim.run(nl, &words);
        sim.word(design.product[0])
    });

    // Wide-lane kernel: 256 vectors in one width-4 sweep vs four width-1
    // sweeps over the same slabs. Results are bit-identical by
    // construction; the delta is pure per-walk amortization.
    let mut wrng = Rng::seed_from_u64(9);
    let wide_slab: Vec<u64> = (0..nl.num_inputs() * 4).map(|_| wrng.next_u64()).collect();
    let mut wide_buf: Vec<u64> = Vec::new();
    let mut narrow_buf: Vec<u64> = Vec::new();
    let comp16 = CompiledNetlist::compile(nl);
    let wide4 = bench.bench("sim_run_16bit_256lanes_w4", || {
        comp16.run_wide_into(4, &mut wide_buf, &wide_slab);
        wide_buf[design.product[0].index() * 4]
    });
    let mut narrow_in = vec![0u64; nl.num_inputs()];
    let narrow4 = bench.bench("sim_run_16bit_256lanes_w1x4", || {
        let mut acc = 0u64;
        for w in 0..4 {
            for (k, word) in narrow_in.iter_mut().enumerate() {
                *word = wide_slab[k * 4 + w];
            }
            comp16.run_into(&mut narrow_buf, &narrow_in);
            acc ^= narrow_buf[design.product[0].index()];
        }
        acc
    });
    bench.metric("sim_wide_speedup_16bit_w4", narrow4.mean_ns / wide4.mean_ns.max(1.0), "x");

    // Toggle-activity power extraction (16 rounds × 64 lanes), width-pinned
    // so the entry is comparable across environments regardless of
    // UFO_SIM_WIDTH; the w4 twin measures the wide production default.
    bench.bench("toggle_activity_16bit_16rounds", || {
        ufo_mac::sim::toggle_activity_wide(nl, 16, 7, 1)
    });
    bench.bench("toggle_activity_16bit_16rounds_w4", || {
        ufo_mac::sim::toggle_activity_wide(nl, 16, 7, 4)
    });

    // Bottleneck assignment at CT-slice scale (m = 16 and 32).
    for m in [16usize, 32] {
        let mut r = Rng::seed_from_u64(m as u64);
        let cost: Vec<Vec<f64>> =
            (0..m).map(|_| (0..m).map(|_| r.f64()).collect()).collect();
        bench.bench(&format!("bottleneck_assignment_{m}x{m}"), || {
            bottleneck_assignment(&cost)
        });
    }

    // Full design construction (the coordinator job body).
    bench.bench("build_ufo_multiplier_8bit", || MultiplierSpec::new(8).build().unwrap());
    bench.bench("build_ufo_multiplier_16bit", || MultiplierSpec::new(16).build().unwrap());

    // Signed 16×16 fused MAC through the uncached inner path: the
    // operand-format subsystem's hot build (Baugh–Wooley rows + fused
    // accumulator + profile-driven CPA), measured without the design
    // cache so every sample pays the real synthesis cost.
    let lib = ufo_mac::ir::CellLib::nangate45();
    let tm = ufo_mac::synth::CompressorTiming::from_lib(&lib);
    let smac_spec =
        MultiplierSpec::new_fmt(ufo_mac::multiplier::OperandFormat::signed(16)).fused_mac(true);
    bench.bench("build_signed_fused_mac_16x16_uncached", || {
        smac_spec.build_with(&lib, &tm).unwrap().netlist.len()
    });

    // Stage assignment at 32/64 bits (greedy hot path).
    for n in [32usize, 64] {
        let pp: Vec<usize> =
            (0..2 * n - 1).map(|j| n.min(j + 1).min(2 * n - 1 - j)).collect();
        let counts = ufo_mac::ct::CtCounts::from_populations(&pp);
        bench.bench(&format!("assign_greedy_{n}bit"), || {
            ufo_mac::ct::assign_greedy(&counts)
        });
    }

    // Netlist encoding for the PJRT bridge.
    bench.bench("encode_netlist_16bit", || {
        ufo_mac::runtime::encode_netlist(nl).unwrap()
    });

    // Equivalence sampling batch (64 vectors incl. packing).
    let d8 = MultiplierSpec::new(8).build().unwrap();
    bench.bench("equiv_sampled_1k_8bit", || {
        ufo_mac::equiv::check_multiplier_with(&d8, 1024).unwrap()
    });

    // Clocked simulation: a 2-stage pipelined 16×16 multiplier stepped
    // through 1000 edges with rotating 64-lane input words — the
    // sequential-equivalence inner loop (`equiv::check_pipelined`) at
    // steady state.
    let p16 = MultiplierSpec::new(16).pipeline_stages(2).build().unwrap();
    let n_in = p16.netlist.num_inputs();
    let mut crng = Rng::seed_from_u64(16);
    let cwords: Vec<Vec<u64>> = (0..8)
        .map(|_| {
            let mut w: Vec<u64> = (0..n_in).map(|_| crng.next_u64()).collect();
            w[n_in - 2] = !0; // pipe_en held high
            w[n_in - 1] = 0; // pipe_clr held low
            w
        })
        .collect();
    let mut csim = ufo_mac::sim::ClockedSim::new(&p16.netlist);
    bench.bench("clocked_sim_1k_cycles_16x16", || {
        csim.reset();
        let mut acc = 0u64;
        for k in 0..1000 {
            let out = csim.step(&cwords[k % cwords.len()]);
            acc ^= out[p16.product[0].index()];
        }
        acc
    });

    // ---- Flat SoA IR: before/after on identical 64×64 designs ----
    //
    // `LegacyNetlist::of` rebuilds the seed storage layout (enum node +
    // heap Vec fanin per gate) from the same design, so the `_legacy_ir`
    // entries measure exactly what the flat IR replaced (EXPERIMENTS.md
    // §Perf).

    // Full 64×64 design construction through the uncached inner path
    // (PPG → CT → CPA on the flat IR; the engine cache would reduce every
    // sample after the first to a lookup).
    bench.bench("netlist_build_64x64", || {
        MultiplierSpec::new(64).build_with(&lib, &tm).unwrap().netlist.len()
    });

    let d64 = MultiplierSpec::new(64).build().unwrap();
    println!(
        "64-bit UFO multiplier: {} nodes / {} gates",
        d64.netlist.len(),
        d64.netlist.num_gates()
    );
    let legacy64 = LegacyNetlist::of(&d64.netlist);

    // Whole-netlist STA report (arrivals + area + power fallback + gate
    // count + depth). The flat engine serves gate count in O(1) and depth
    // from the cached topology; the legacy engine pays the seed's three
    // extra enum sweeps per report.
    let full64 = bench.bench("sta_full_64x64", || sta.analyze(&d64.netlist));
    let legacy_full64 =
        bench.bench("sta_full_64x64_legacy_ir", || legacy64.analyze(&sta.lib));
    bench.metric(
        "sta_soa_speedup_64x64",
        legacy_full64.mean_ns / full64.mean_ns.max(1.0),
        "x",
    );

    // Simulator program construction + one 64-lane run. Flat IR:
    // construction is a zero-copy borrow. Legacy IR: the seed's O(nodes)
    // re-flattening walk (enum match + Vec deref per gate).
    let mut rng64 = Rng::seed_from_u64(64);
    let words64: Vec<u64> =
        (0..d64.netlist.num_inputs()).map(|_| rng64.next_u64()).collect();
    let mut cbuf: Vec<u64> = Vec::new();
    let run64 = bench.bench("compiled_build_run_64x64", || {
        let comp = CompiledNetlist::compile(&d64.netlist);
        comp.run_into(&mut cbuf, &words64);
        cbuf[d64.product[0].index()]
    });
    let legacy_run64 = bench.bench("compiled_build_run_64x64_legacy_ir", || {
        let comp = legacy64.compile();
        comp.run_into(&mut cbuf, &words64);
        cbuf[d64.product[0].index()]
    });
    bench.metric(
        "compiled_soa_speedup_64x64",
        legacy_run64.mean_ns / run64.mean_ns.max(1.0),
        "x",
    );

    // Full static-analysis sweep on a 32×32 design: the structural passes
    // over the cached CSR topology plus every datapath check the build
    // trace supports — the per-compile cost the engine's lint gate adds.
    let (d32, d32_trace) = MultiplierSpec::new(32).build_with_trace(&lib, &tm).unwrap();
    bench.bench("lint_full_32x32", || {
        ufo_mac::lint::lint_design(
            &d32,
            Some(&d32_trace),
            &lib,
            &ufo_mac::lint::LintOptions::default(),
        )
        .diagnostics
        .len()
    });

    // Full abstract-interpretation sweep on the same 32×32 design: all
    // three domains (ternary fixpoint, windowed probability propagation,
    // output-group intervals) plus report assembly — the per-compile cost
    // the engine's analysis pass adds on top of lint.
    bench.bench("analyze_full_32x32", || {
        ufo_mac::analysis::analyze_design(
            &d32,
            &ufo_mac::analysis::AnalysisOptions::default(),
        )
        .report
        .diagnostics
        .len()
    });

    // Sampled equivalence at 32×32: one worker vs all cores over the same
    // deterministic batch plan (identical counterexamples by design), then
    // the width-4 wide-lane sweep on both thread counts — every variant
    // reports byte-identical results; only the wall-clock moves.
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2);
    let eq_budget = 1usize << 14;
    let eq_ser = bench.bench("equiv_sampled_32x32_serial", || {
        ufo_mac::equiv::check_multiplier_opts(
            &d32,
            &EquivOptions { budget: eq_budget, threads: 1, width: 1 },
        )
        .unwrap()
        .vectors
    });
    let eq_par = bench.bench("equiv_sampled_32x32_parallel", || {
        ufo_mac::equiv::check_multiplier_opts(
            &d32,
            &EquivOptions { budget: eq_budget, threads, width: 1 },
        )
        .unwrap()
        .vectors
    });
    bench.metric(
        "equiv_parallel_speedup_32x32",
        eq_ser.mean_ns / eq_par.mean_ns.max(1.0),
        "x",
    );
    let eq_wide = bench.bench("equiv_sampled_32x32_wide4_serial", || {
        ufo_mac::equiv::check_multiplier_opts(
            &d32,
            &EquivOptions { budget: eq_budget, threads: 1, width: 4 },
        )
        .unwrap()
        .vectors
    });
    bench.bench("equiv_sampled_32x32_wide4_parallel", || {
        ufo_mac::equiv::check_multiplier_opts(
            &d32,
            &EquivOptions { budget: eq_budget, threads, width: 4 },
        )
        .unwrap()
        .vectors
    });
    bench.metric(
        "equiv_wide_speedup_32x32_w4",
        eq_ser.mean_ns / eq_wide.mean_ns.max(1.0),
        "x",
    );

    // Unified-engine compile path: cold (fresh engine per call — pays the
    // full library/timing-model construction plus synthesis, the pre-API
    // per-call behaviour) vs cached (content-addressed hit on a warm
    // engine — the DSE-sweep steady state).
    let req = DesignRequest::multiplier(16);
    bench.bench("engine_compile_16bit_cold", || {
        let eng = SynthEngine::new(EngineConfig::default());
        eng.compile(&req).unwrap().sta.num_gates
    });
    let warm = SynthEngine::new(EngineConfig::default());
    warm.compile(&req).unwrap();
    bench.bench("engine_compile_16bit_cached", || {
        warm.compile(&req).unwrap().sta.num_gates
    });
    let s = warm.cache_stats();
    bench.metric("engine_cache_hit_rate_16bit", s.hit_rate(), "fraction");
    let art = warm.compile(&req).unwrap();
    bench.metric("engine_timing_retime_fraction_16bit", art.timing.retime_fraction(), "fraction");

    // Persistent-cache tiers: cold compile (above) vs warm in-memory hit
    // (above) vs warm *disk* hit — the restarted-service steady state.
    // Clearing the memory tier before each sample forces every compile to
    // deserialize + checksum-verify the on-disk entry.
    let disk_dir = std::env::temp_dir().join(format!("ufo_hotpath_disk_{}", std::process::id()));
    std::fs::remove_dir_all(&disk_dir).ok();
    let disk = SynthEngine::new(EngineConfig {
        cache_dir: Some(disk_dir.clone()),
        ..EngineConfig::default()
    });
    disk.compile(&req).unwrap(); // prime both tiers
    bench.bench("engine_compile_16bit_warm_disk", || {
        disk.clear_cache(); // memory tier only; the disk entry survives
        disk.compile(&req).unwrap().sta.num_gates
    });
    let s = disk.cache_stats();
    bench.metric("engine_disk_hits_16bit", s.disk_hits as f64, "count");
    std::fs::remove_dir_all(&disk_dir).ok();

    // Full vs incremental STA on the repeated-optimization-move path: each
    // "move" shifts one middle-column input arrival of a 32-bit adder
    // carrying a trapezoidal CT profile (what a CT interconnect swap or a
    // revised column profile does to the CPA), then re-times. The full
    // path re-runs whole-netlist STA; the incremental path re-times only
    // the touched fan-out cone.
    let n_bits = 32usize;
    let profile: Vec<f64> = (0..n_bits)
        .map(|i| 0.2 + 0.15 * (16.0 - (i as f64 - 16.0).abs()) / 16.0)
        .collect();
    let g = cpa::build(PrefixStructure::Sklansky, n_bits);
    let (mut nl_full, _) = cpa::standalone_adder(&g, Some(&profile));
    let (mut nl_inc, _) = cpa::standalone_adder(&g, Some(&profile));
    let sta_fast = Sta { activity_rounds: 0, ..Sta::default() };
    let inputs_full = nl_full.inputs();
    let inputs_inc = nl_inc.inputs();
    let mut k = 0usize;
    let full_stats = bench.bench("sta_move_full_retime_32bit_adder", || {
        let id = inputs_full[16 + (k % 24)];
        nl_full.set_input_arrival(id, 0.2 + 0.01 * ((k % 7) as f64));
        k += 1;
        sta_fast.arrivals_ns(&nl_full).iter().copied().fold(0.0f64, f64::max)
    });
    let mut inc = IncrementalSta::new(&sta_fast, &nl_inc);
    let mut k2 = 0usize;
    let inc_stats = bench.bench("sta_move_incremental_retime_32bit_adder", || {
        let id = inputs_inc[16 + (k2 % 24)];
        nl_inc.set_input_arrival(id, 0.2 + 0.01 * ((k2 % 7) as f64));
        k2 += 1;
        inc.touch(id);
        inc.propagate(&nl_inc);
        inc.arrivals().iter().copied().fold(0.0f64, f64::max)
    });
    bench.metric(
        "sta_incremental_speedup_move_path",
        full_stats.mean_ns / inc_stats.mean_ns.max(1.0),
        "x",
    );
    bench.metric("sta_incremental_retime_fraction", inc.stats().retime_fraction(), "fraction");

    // Serial vs parallel branch & bound on the §3.3 stage-assignment ILP.
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2);
    let n_ilp = 6usize;
    let pp: Vec<usize> =
        (0..2 * n_ilp - 1).map(|j| n_ilp.min(j + 1).min(2 * n_ilp - 1 - j)).collect();
    let counts = ufo_mac::ct::CtCounts::from_populations(&pp);
    let ilp_opts = |threads: usize| SolveOptions {
        time_limit: std::time::Duration::from_secs(15),
        threads,
        ..Default::default()
    };
    let ser = bench.bench(&format!("stage_ilp_{n_ilp}bit_serial"), || {
        ufo_mac::ct::assign_ilp(&counts, &ilp_opts(1)).0.stages()
    });
    let par = bench.bench(&format!("stage_ilp_{n_ilp}bit_parallel_{threads}t"), || {
        ufo_mac::ct::assign_ilp(&counts, &ilp_opts(threads)).0.stages()
    });
    bench.metric("ilp_parallel_speedup", ser.mean_ns / par.mean_ns.max(1.0), "x");

    // ---- End-to-end served throughput: designs per second ----
    //
    // A small but real coordinator sweep (method × strategy grid at one
    // width, sampled verification on) through `run_sweep_with` — the exact
    // code path the server's `sweep` command and the CLI's DSE drive. Two
    // variants: a fresh engine per sample (cold — every point pays
    // synthesis + verification) and one warm engine reused across samples
    // (every point is a content-addressed cache hit — the steady state a
    // long-running service converges to). Both are reported as *metrics*
    // so `ufo-mac bench-check` floors them: a future PR that drops served
    // throughput below baseline/ratio fails CI even if every
    // microbenchmark above still passes.
    let sweep_cfg = ufo_mac::coordinator::SweepConfig {
        widths: vec![8],
        // Closed-form methods only: RL-MUL's 60-iteration search would
        // dominate the sample and measure the search loop, not the
        // synthesize→analyze→verify pipeline this gate protects.
        methods: vec![
            ufo_mac::baselines::Method::UfoMac,
            ufo_mac::baselines::Method::Gomil,
            ufo_mac::baselines::Method::Commercial,
        ],
        strategies: vec![
            ufo_mac::multiplier::Strategy::TradeOff,
            ufo_mac::multiplier::Strategy::AreaDriven,
        ],
        signedness: vec![ufo_mac::ppg::Signedness::Unsigned],
        workers: threads,
        verify_vectors: 1 << 10,
        use_pjrt: false,
        ..Default::default()
    };
    let sweep_points = ufo_mac::coordinator::sweep_requests(&sweep_cfg).len() as f64;
    let cold = bench.bench("coordinator_sweep_8bit_cold", || {
        let eng = SynthEngine::new(EngineConfig {
            verify_vectors: sweep_cfg.verify_vectors,
            workers: sweep_cfg.workers,
            ..EngineConfig::default()
        });
        ufo_mac::coordinator::run_sweep_with(&eng, &sweep_cfg).len()
    });
    let warm_eng = SynthEngine::new(EngineConfig {
        verify_vectors: sweep_cfg.verify_vectors,
        workers: sweep_cfg.workers,
        ..EngineConfig::default()
    });
    ufo_mac::coordinator::run_sweep_with(&warm_eng, &sweep_cfg); // prime the cache
    let warm = bench.bench("coordinator_sweep_8bit_warm", || {
        ufo_mac::coordinator::run_sweep_with(&warm_eng, &sweep_cfg).len()
    });
    bench.metric("designs_per_second", sweep_points / (cold.min_ns / 1e9), "designs/s");
    bench.metric(
        "designs_per_second_warm",
        sweep_points / (warm.min_ns / 1e9),
        "designs/s",
    );

    bench.finish().expect("write BENCH_hotpath.json");
}

// ---------------------------------------------------------------------
// Seed-layout reference IR (the PR-5 "before"): one enum value per node
// with a heap-allocated `Vec<NodeId>` fanin per gate, swept with the
// seed's exact analysis loops. Rebuilt from a flat netlist so the
// `_legacy_ir` benches run on identical designs.
// ---------------------------------------------------------------------

enum LegacyNode {
    Input { arrival_ns: f64 },
    Const(bool),
    Gate { kind: CellKind, fanin: Vec<NodeId> },
}

struct LegacyNetlist {
    nodes: Vec<LegacyNode>,
    outputs: Vec<NodeId>,
    output_load: f64,
}

struct LegacyCompiled {
    ops: Vec<u8>,
    fanin: Vec<[u32; 3]>,
    n_inputs: usize,
}

impl LegacyNetlist {
    fn of(nl: &Netlist) -> LegacyNetlist {
        let nodes = nl
            .iter()
            .map(|n| match n {
                Node::Input { arrival_ns, .. } => LegacyNode::Input { arrival_ns },
                Node::Const(v) => LegacyNode::Const(v),
                Node::Gate { kind, fanin } => {
                    LegacyNode::Gate { kind, fanin: fanin.to_vec() }
                }
                // The seed IR predates registers; the comparator only ever
                // rebuilds combinational benchmark designs.
                Node::Reg { .. } => unreachable!("legacy comparator is combinational-only"),
            })
            .collect();
        LegacyNetlist {
            nodes,
            outputs: nl.outputs().map(|(_, id)| id).collect(),
            output_load: CellLib::nangate45().output_load,
        }
    }

    fn loads(&self, lib: &CellLib) -> Vec<f64> {
        let mut load = vec![0.0f64; self.nodes.len()];
        for n in &self.nodes {
            if let LegacyNode::Gate { kind, fanin } = n {
                let cin = lib.params(*kind).input_cap;
                for f in fanin {
                    load[f.index()] += cin;
                }
            }
        }
        for id in &self.outputs {
            load[id.index()] += self.output_load;
        }
        load
    }

    fn arrivals(&self, lib: &CellLib) -> Vec<f64> {
        let loads = self.loads(lib);
        let mut at = vec![0.0f64; self.nodes.len()];
        for (i, node) in self.nodes.iter().enumerate() {
            at[i] = match node {
                LegacyNode::Input { arrival_ns } => *arrival_ns,
                LegacyNode::Const(_) => 0.0,
                LegacyNode::Gate { kind, fanin } => {
                    let worst =
                        fanin.iter().map(|f| at[f.index()]).fold(f64::MIN, f64::max);
                    worst + lib.delay_ns(*kind, loads[i])
                }
            };
        }
        at
    }

    /// The seed `Sta::analyze` sweep set (activity_rounds = 0): arrivals,
    /// area, constant-activity power, plus the three extra enum sweeps the
    /// flat engine eliminated (gate count, depths, depth-over-outputs).
    fn analyze(&self, lib: &CellLib) -> (f64, f64, f64, usize, u32) {
        let at = self.arrivals(lib);
        let critical =
            self.outputs.iter().map(|id| at[id.index()]).fold(0.0f64, f64::max);
        let area: f64 = self
            .nodes
            .iter()
            .map(|n| match n {
                LegacyNode::Gate { kind, .. } => lib.params(*kind).area_um2,
                _ => 0.0,
            })
            .sum();
        let power: f64 = self
            .nodes
            .iter()
            .map(|n| match n {
                LegacyNode::Gate { kind, .. } => {
                    0.15 * lib.params(*kind).switch_energy_fj
                }
                _ => 0.0,
            })
            .sum::<f64>()
            / 1000.0;
        let num_gates = self
            .nodes
            .iter()
            .filter(|n| matches!(n, LegacyNode::Gate { .. }))
            .count();
        let mut depths = vec![0u32; self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            if let LegacyNode::Gate { fanin, .. } = n {
                depths[i] = 1 + fanin.iter().map(|f| depths[f.index()]).max().unwrap_or(0);
            }
        }
        let depth =
            self.outputs.iter().map(|id| depths[id.index()]).max().unwrap_or(0);
        (critical, area, power, num_gates, depth)
    }

    /// The seed `CompiledNetlist::compile` re-flattening walk.
    fn compile(&self) -> LegacyCompiled {
        let mut ops = Vec::with_capacity(self.nodes.len());
        let mut fanin = Vec::with_capacity(self.nodes.len());
        let mut next_input = 0u32;
        for node in &self.nodes {
            match node {
                LegacyNode::Input { .. } => {
                    ops.push(13u8);
                    fanin.push([next_input, 0, 0]);
                    next_input += 1;
                }
                LegacyNode::Const(v) => {
                    ops.push(if *v { 12 } else { 11 });
                    fanin.push([0, 0, 0]);
                }
                LegacyNode::Gate { kind, fanin: f } => {
                    ops.push(kind.opcode() as u8);
                    let mut rec = [0u32; 3];
                    for (k, id) in f.iter().enumerate() {
                        rec[k] = id.0;
                    }
                    fanin.push(rec);
                }
            }
        }
        LegacyCompiled { ops, fanin, n_inputs: next_input as usize }
    }
}

impl LegacyCompiled {
    /// The seed evaluation loop, byte-for-byte (same unchecked reads), so
    /// the `_legacy_ir` twin differs only in program *construction* cost.
    fn run_into(&self, buf: &mut Vec<u64>, input_words: &[u64]) {
        assert_eq!(input_words.len(), self.n_inputs, "input word count");
        if buf.len() != self.ops.len() {
            buf.resize(self.ops.len(), 0);
        }
        let b = buf.as_mut_slice();
        for i in 0..self.ops.len() {
            let [f0, f1, f2] = self.fanin[i];
            // SAFETY: fanins come from a validated netlist (fanin < i) and
            // input ordinals are bounded by the asserted input_words length.
            let v = unsafe {
                let g = |k: u32| *b.get_unchecked(k as usize);
                match self.ops[i] {
                    0 => g(f0),
                    1 => !g(f0),
                    2 => g(f0) & g(f1),
                    3 => g(f0) | g(f1),
                    4 => !(g(f0) & g(f1)),
                    5 => !(g(f0) | g(f1)),
                    6 => g(f0) ^ g(f1),
                    7 => !(g(f0) ^ g(f1)),
                    8 => !((g(f0) & g(f1)) | g(f2)),
                    9 => !((g(f0) | g(f1)) & g(f2)),
                    10 => {
                        let (a, bb, c) = (g(f0), g(f1), g(f2));
                        (a & bb) | (a & c) | (bb & c)
                    }
                    11 => 0,
                    12 => !0,
                    _ => *input_words.get_unchecked(f0 as usize),
                }
            };
            b[i] = v;
        }
    }
}
