//! Fault-injection harness for the TCP transport: truncated frames,
//! garbage bytes, mid-stream disconnects, oversized request lines, and a
//! stalled reader. Every fault must be absorbed as an error envelope or
//! the loss of the *one* faulty connection — never a poisoned handler
//! pool. Each test proves recovery by opening a fresh connection
//! afterwards and compiling successfully.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;
use ufo_mac::api::{DesignRequest, EngineConfig, SynthEngine};
use ufo_mac::server::{compile_line, Server};
use ufo_mac::util::Json;

/// Start a 2-handler TCP server on an ephemeral port. The accept loop
/// runs forever on a detached thread; it dies with the test binary.
fn spawn_server() -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let engine = Arc::new(SynthEngine::new(EngineConfig {
        workers: 2,
        ..EngineConfig::default()
    }));
    let srv = Arc::new(Server::new(engine));
    std::thread::spawn(move || {
        let _ = srv.serve_listener(listener);
    });
    addr
}

fn connect(addr: SocketAddr) -> TcpStream {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    stream
}

/// The recovery probe: a fresh connection must still compile.
fn fresh_connection_compiles(addr: SocketAddr, width: usize) {
    let mut stream = connect(addr);
    writeln!(stream, "{}", compile_line(99, &DesignRequest::multiplier(width))).unwrap();
    stream.flush().unwrap();
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).unwrap();
    let doc = Json::parse(&line).unwrap();
    assert_eq!(doc.get("ok").unwrap().as_bool(), Some(true), "pool poisoned: {line}");
}

// ---------------------------------------------------------------------
// A frame truncated by connection close (no trailing newline) is still
// parsed — matching BufRead::read_line semantics — and answered with an
// error envelope before the connection drains shut.
// ---------------------------------------------------------------------
#[test]
fn truncated_frame_gets_error_envelope_then_eof() {
    let addr = spawn_server();
    let mut stream = connect(addr);
    stream.write_all(br#"{"cmd":"compile","id":7"#).unwrap();
    stream.flush().unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let doc = Json::parse(&line).unwrap();
    assert_eq!(doc.get("ok").unwrap().as_bool(), Some(false), "{line}");
    assert!(
        doc.get("error").unwrap().as_str().unwrap().contains("not valid JSON"),
        "{line}"
    );
    // Then EOF: the truncated connection closes after the one envelope.
    let mut rest = String::new();
    assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "expected EOF, got {rest}");
    fresh_connection_compiles(addr, 4);
}

// ---------------------------------------------------------------------
// Garbage bytes (not even UTF-8) mid-stream cost one error envelope; the
// *same* connection keeps working for the next well-formed line.
// ---------------------------------------------------------------------
#[test]
fn garbage_bytes_then_valid_request_on_same_connection() {
    let addr = spawn_server();
    let mut stream = connect(addr);
    stream.write_all(b"\x00\xff\xfegarbage\n").unwrap();
    writeln!(stream, "{}", r#"{"cmd":"stats","id":42}"#).unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    // Two handlers race, so correlate by id rather than arrival order.
    let (mut saw_err, mut saw_stats) = (false, false);
    for _ in 0..2 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let doc = Json::parse(&line).unwrap();
        match doc.get("id") {
            Some(Json::Null) | None => {
                assert_eq!(doc.get("ok").unwrap().as_bool(), Some(false), "{line}");
                saw_err = true;
            }
            Some(id) => {
                assert_eq!(id.as_f64(), Some(42.0), "{line}");
                assert_eq!(doc.get("ok").unwrap().as_bool(), Some(true), "{line}");
                saw_stats = true;
            }
        }
    }
    assert!(saw_err && saw_stats);
    fresh_connection_compiles(addr, 4);
}

// ---------------------------------------------------------------------
// A client that disconnects mid-streamed-sweep loses only its own
// results: remaining sweep steps are dropped (dead connection) and the
// pool keeps serving fresh connections.
// ---------------------------------------------------------------------
#[test]
fn client_disconnect_mid_sweep_does_not_poison_pool() {
    let addr = spawn_server();
    {
        let mut stream = connect(addr);
        writeln!(
            stream,
            "{}",
            r#"{"cmd":"sweep","id":1,"methods":["ufo","gomil"],"strategies":["tradeoff"],"stream":true,"widths":[5,6]}"#
        )
        .unwrap();
        stream.flush().unwrap();
        // Read exactly one progress frame, then hang up mid-stream.
        let mut reader = BufReader::new(stream);
        let mut frame = String::new();
        reader.read_line(&mut frame).unwrap();
        let doc = Json::parse(&frame).unwrap();
        assert_eq!(doc.get("event").unwrap().as_str(), Some("progress"), "{frame}");
    } // connection dropped here with 3 design points outstanding
    fresh_connection_compiles(addr, 4);
    // ...and uses the cache entries the aborted sweep still populated.
    fresh_connection_compiles(addr, 5);
}

// ---------------------------------------------------------------------
// An unterminated line beyond the 1 MiB cap costs that connection (with
// a best-effort error envelope) — it cannot grow the read buffer without
// bound or wedge the multiplexer.
// ---------------------------------------------------------------------
#[test]
fn oversized_request_line_drops_only_that_connection() {
    let addr = spawn_server();
    let mut stream = connect(addr);
    let chunk = vec![b'a'; 64 * 1024];
    // Push well past the cap; the server may hang up mid-write, so write
    // errors here are expected and ignored.
    for _ in 0..20 {
        if stream.write_all(&chunk).is_err() {
            break;
        }
    }
    let _ = stream.flush();
    // Best-effort read of the error envelope (the server may have reset
    // the connection first; either way it must not take the pool down).
    let mut line = String::new();
    if BufReader::new(stream).read_line(&mut line).is_ok() && !line.is_empty() {
        assert!(line.contains("request line exceeds"), "{line}");
    }
    fresh_connection_compiles(addr, 4);
}

// ---------------------------------------------------------------------
// A connection that streams a sweep but never reads must not stall
// responses to other connections (per-connection writers, shared pool).
// ---------------------------------------------------------------------
#[test]
fn stalled_reader_does_not_stall_other_connections() {
    let addr = spawn_server();
    let mut stalled = connect(addr);
    writeln!(
        stalled,
        "{}",
        r#"{"cmd":"sweep","id":1,"methods":["ufo","gomil"],"strategies":["area","timing","tradeoff"],"stream":true,"widths":[7]}"#
    )
    .unwrap();
    stalled.flush().unwrap();
    // Never read `stalled`; its frames sit in the socket buffer while a
    // second connection gets served.
    fresh_connection_compiles(addr, 4);
    // The stalled connection is still alive and eventually delivers all
    // six frames plus the final envelope.
    let mut reader = BufReader::new(stalled);
    let mut frames = 0;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let doc = Json::parse(&line).unwrap();
        if doc.get("event").is_some() {
            frames += 1;
        } else {
            assert_eq!(doc.get("ok").unwrap().as_bool(), Some(true), "{line}");
            assert_eq!(
                doc.get("result").unwrap().get("count").unwrap().as_f64(),
                Some(6.0),
                "{line}"
            );
            break;
        }
    }
    assert_eq!(frames, 6);
}
