//! Operand-format integration tests: exhaustive simulator equivalence for
//! every `OperandFormat` × PPG × {plain, fused, separate} combination at
//! small widths, degenerate-width coverage (the old builder rejected
//! `n = 1` and the Booth `n ≤ 2` cases), and 64-lane randomized
//! verification of wide signed designs through
//! [`ufo_mac::sim::lane_value_signed`].

use ufo_mac::multiplier::{MultiplierSpec, OperandFormat};
use ufo_mac::ppg::PpgKind;
use ufo_mac::sim::{lane_value_signed, pack_lanes, Simulator};
use ufo_mac::util::Rng;

/// The three accumulator modes.
fn mac_modes() -> [(bool, bool); 3] {
    [(false, false), (true, false), (false, true)]
}

fn exhaustive(spec: &MultiplierSpec) {
    let d = spec.build().unwrap_or_else(|e| panic!("{spec:?}: build: {e}"));
    d.netlist.validate().unwrap();
    let rep = ufo_mac::equiv::check_multiplier(&d)
        .unwrap_or_else(|e| panic!("{spec:?}: equiv: {e}"));
    assert!(rep.exhaustive, "{spec:?}: input space too large for exhaustive");
    assert!(rep.passed, "{spec:?}: cex {:?}", rep.counterexample);
}

// ---------------------------------------------------------------------
// Acceptance: every format × PPG × MAC mode at widths ≤ 6, exhaustively.
// ---------------------------------------------------------------------
#[test]
fn all_formats_all_ppgs_all_modes_exhaustive() {
    let formats = [
        OperandFormat::unsigned(3),
        OperandFormat::signed(3),
        OperandFormat::signed(4),
        OperandFormat::rect(2, 5),
        OperandFormat::signed_rect(2, 4),
        OperandFormat::signed_rect(4, 6),
    ];
    for fmt in formats {
        for ppg in [PpgKind::AndArray, PpgKind::Booth4] {
            for (fused, separate) in mac_modes() {
                // MAC input spaces: a + b + (a+b) bits; 4×6 → 20 bits, the
                // exhaustive-check ceiling.
                exhaustive(
                    &MultiplierSpec::new_fmt(fmt)
                        .ppg(ppg)
                        .fused_mac(fused)
                        .separate_mac(separate),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Degenerate widths: 1–3 × {AndArray, Booth4} × {plain, fused, separate}
// must build, validate and verify (the old builder bailed on n < 2, and
// the Booth n ≤ 2 cases used to meet a 2n-bit product expectation with a
// 2n-1-column matrix).
// ---------------------------------------------------------------------
#[test]
fn degenerate_widths_build_and_verify() {
    for n in 1..=3usize {
        for ppg in [PpgKind::AndArray, PpgKind::Booth4] {
            for (fused, separate) in mac_modes() {
                for fmt in [OperandFormat::unsigned(n), OperandFormat::signed(n)] {
                    exhaustive(
                        &MultiplierSpec::new_fmt(fmt)
                            .ppg(ppg)
                            .fused_mac(fused)
                            .separate_mac(separate),
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Randomized 64-lane verification at 16/24-bit product widths with sign
// interpretation (sampled equivalence plus a direct lane_value_signed
// cross-check against the i128 reference).
// ---------------------------------------------------------------------
#[test]
fn randomized_wide_signed_products_via_lane_value_signed() {
    for (na, nb, fused) in [(8usize, 8usize, false), (12, 12, true)] {
        let d = MultiplierSpec::new_fmt(OperandFormat::signed_rect(na, nb))
            .fused_mac(fused)
            .build()
            .unwrap();
        let out_w = na + nb;
        let mut rng = Rng::seed_from_u64(0xF0F0 + out_w as u64);
        let mut sim = Simulator::new();
        for _round in 0..4 {
            let lanes: Vec<(u64, u64, u64)> = (0..64)
                .map(|_| {
                    (
                        rng.next_u64() & ((1 << na) - 1),
                        rng.next_u64() & ((1 << nb) - 1),
                        rng.next_u64() & ((1 << out_w) - 1),
                    )
                })
                .collect();
            let assigns: Vec<Vec<bool>> = lanes
                .iter()
                .map(|(x, y, z)| {
                    let mut v: Vec<bool> = (0..na).map(|k| x >> k & 1 != 0).collect();
                    v.extend((0..nb).map(|k| y >> k & 1 != 0));
                    if fused {
                        v.extend((0..out_w).map(|k| z >> k & 1 != 0));
                    }
                    v
                })
                .collect();
            let words = pack_lanes(&assigns);
            let vals = sim.run(&d.netlist, &words).to_vec();
            let sext = |x: u64, bits: usize| ufo_mac::util::sign_extend(u128::from(x), bits);
            for (lane, (x, y, z)) in lanes.iter().enumerate() {
                let got = lane_value_signed(&vals, &d.product, lane as u32);
                let want = sext(*x, na) * sext(*y, nb)
                    + if fused { sext(*z, out_w) } else { 0 };
                assert_eq!(got, want, "{na}x{nb} fused={fused} a={x} b={y} c={z}");
            }
        }
    }
}

// ---------------------------------------------------------------------
// Formats flow through the whole unified API: request JSON → engine →
// verified artifact, with distinct cache entries per format.
// ---------------------------------------------------------------------
#[test]
fn formats_flow_through_the_engine() {
    use ufo_mac::api::{DesignRequest, EngineConfig, SynthEngine};
    let engine = SynthEngine::new(EngineConfig { verify_vectors: 512, ..Default::default() });
    let unsigned = DesignRequest::multiplier(6);
    let signed =
        DesignRequest::from_spec(&MultiplierSpec::new_fmt(OperandFormat::signed(6)));
    let au = engine.compile(&unsigned).unwrap();
    let as_ = engine.compile(&signed).unwrap();
    assert_ne!(au.fingerprint, as_.fingerprint);
    assert_eq!(au.verified, Some(true));
    assert_eq!(as_.verified, Some(true));
    // JSON round-trip hits the same cache entry.
    let again = engine.compile(&DesignRequest::parse(&signed.to_json_string()).unwrap()).unwrap();
    assert_eq!(again.fingerprint, as_.fingerprint);
}
