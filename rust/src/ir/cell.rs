//! Standard-cell library modeled on the NanGate 45 nm Open Cell Library.
//!
//! The paper synthesizes every design with Synopsys DC + NanGate45. We cannot
//! ship a signoff tool, so timing is computed with the *logical effort* model
//! (Harris & Sutherland, the same model §4.2 of the paper builds its FDC
//! timing abstraction on): `d = p + g · h` where `h = C_load / C_in`.
//! Area and relative drive numbers are taken from the NanGate45 typical
//! corner so that the paper's structural facts hold in our numbers:
//!
//! - a 3:2 compressor (2×XOR2 + 3×NAND2) is ≈1.5× the area of a 2:2
//!   compressor (XOR2 + AND2)                                   (§3.2);
//! - the A/B→Sum path of a 3:2 compressor (two XOR2) is ≈1.5× the delay of
//!   its Cin→Cout path (NAND2 + NAND2)                          (§3.4);
//! - AND-OR prefix ("black") nodes map to AOI21/OAI21 + NAND2/NOR2 pairs
//!   while the final carry-to-sum ("blue") nodes map to a single
//!   AOI21/OAI21                                                 (§4.2).



/// Gate functions available to the synthesizer.
///
/// `Buf`/`Inv` exist for fanout repair and polarity bookkeeping. The
/// two-input cells cover everything the multiplier datapath needs; wider
/// functions are synthesized as trees of these.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CellKind {
    /// Non-inverting buffer.
    Buf,
    /// Inverter.
    Inv,
    /// 2-input AND.
    And2,
    /// 2-input OR.
    Or2,
    /// 2-input NAND.
    Nand2,
    /// 2-input NOR.
    Nor2,
    /// 2-input XOR.
    Xor2,
    /// 2-input XNOR.
    Xnor2,
    /// AOI21: `!(a·b + c)` — the black-node generate cell.
    Aoi21,
    /// OAI21: `!((a+b)·c)` — the dual-polarity black-node generate cell.
    Oai21,
    /// MAJ3/carry cell modeled as a discrete NanGate `FA_X1`-style carry
    /// (used only when a mapped full-adder cell is requested).
    Maj3,
}

impl CellKind {
    /// All kinds, in a stable order (used by the PJRT netlist encoding).
    pub const ALL: [CellKind; 11] = [
        CellKind::Buf,
        CellKind::Inv,
        CellKind::And2,
        CellKind::Or2,
        CellKind::Nand2,
        CellKind::Nor2,
        CellKind::Xor2,
        CellKind::Xnor2,
        CellKind::Aoi21,
        CellKind::Oai21,
        CellKind::Maj3,
    ];

    /// Number of data inputs of the cell.
    pub fn arity(self) -> usize {
        match self {
            CellKind::Buf | CellKind::Inv => 1,
            CellKind::Aoi21 | CellKind::Oai21 | CellKind::Maj3 => 3,
            _ => 2,
        }
    }

    /// Stable opcode used by the AOT netlist-evaluator artifact (keep in
    /// sync with `python/compile/kernels/netlist_eval.py`).
    pub fn opcode(self) -> i32 {
        match self {
            CellKind::Buf => 0,
            CellKind::Inv => 1,
            CellKind::And2 => 2,
            CellKind::Or2 => 3,
            CellKind::Nand2 => 4,
            CellKind::Nor2 => 5,
            CellKind::Xor2 => 6,
            CellKind::Xnor2 => 7,
            CellKind::Aoi21 => 8,
            CellKind::Oai21 => 9,
            CellKind::Maj3 => 10,
        }
    }

    /// Evaluate the boolean function on bit-packed words (one vector per
    /// bit lane). This is the semantic ground truth used by simulation,
    /// equivalence checking and the Pallas oracle.
    #[inline]
    pub fn eval(self, a: u64, b: u64, c: u64) -> u64 {
        match self {
            CellKind::Buf => a,
            CellKind::Inv => !a,
            CellKind::And2 => a & b,
            CellKind::Or2 => a | b,
            CellKind::Nand2 => !(a & b),
            CellKind::Nor2 => !(a | b),
            CellKind::Xor2 => a ^ b,
            CellKind::Xnor2 => !(a ^ b),
            CellKind::Aoi21 => !((a & b) | c),
            CellKind::Oai21 => !((a | b) & c),
            CellKind::Maj3 => (a & b) | (a & c) | (b & c),
        }
    }
}

/// Per-cell electrical/physical characterization.
#[derive(Debug, Clone, Copy)]
pub struct CellParams {
    /// Layout area in µm² (NanGate45 X1 drive).
    pub area_um2: f64,
    /// Logical effort `g` (delay slope vs. electrical effort).
    pub logical_effort: f64,
    /// Parasitic (intrinsic) delay `p`, in τ units.
    pub parasitic: f64,
    /// Input capacitance in unit loads (INV_X1 input = 1.0).
    pub input_cap: f64,
    /// Switching energy per output toggle, in fJ (drives the power report).
    pub switch_energy_fj: f64,
}

/// A characterized standard-cell library.
#[derive(Debug, Clone)]
pub struct CellLib {
    /// τ — the technology time unit in ns. One FO4 inverter delay is
    /// `(p_inv + 4·g_inv)·τ`; 45 nm FO4 ≈ 25 ps ⇒ τ = 5 ps.
    pub tau_ns: f64,
    /// Default output load (unit loads) seen by primary outputs.
    pub output_load: f64,
    params: [CellParams; 11],
}

impl CellLib {
    /// The NanGate45-flavoured default library.
    pub fn nangate45() -> Self {
        use CellKind::*;
        let mut params = [CellParams {
            area_um2: 0.0,
            logical_effort: 1.0,
            parasitic: 1.0,
            input_cap: 1.0,
            switch_energy_fj: 1.0,
        }; 11];
        let set = |params: &mut [CellParams; 11], k: CellKind, p: CellParams| {
            params[k.opcode() as usize] = p;
        };
        set(&mut params, Buf, CellParams { area_um2: 1.064, logical_effort: 1.0, parasitic: 2.0, input_cap: 1.0, switch_energy_fj: 0.9 });
        set(&mut params, Inv, CellParams { area_um2: 0.532, logical_effort: 1.0, parasitic: 1.0, input_cap: 1.0, switch_energy_fj: 0.6 });
        set(&mut params, And2, CellParams { area_um2: 1.064, logical_effort: 1.33, parasitic: 2.8, input_cap: 1.3, switch_energy_fj: 1.2 });
        set(&mut params, Or2, CellParams { area_um2: 1.064, logical_effort: 1.5, parasitic: 3.0, input_cap: 1.3, switch_energy_fj: 1.3 });
        set(&mut params, Nand2, CellParams { area_um2: 0.798, logical_effort: 1.33, parasitic: 1.6, input_cap: 1.33, switch_energy_fj: 0.8 });
        set(&mut params, Nor2, CellParams { area_um2: 0.798, logical_effort: 1.67, parasitic: 1.9, input_cap: 1.33, switch_energy_fj: 0.85 });
        set(&mut params, Xor2, CellParams { area_um2: 1.596, logical_effort: 2.6, parasitic: 3.4, input_cap: 1.9, switch_energy_fj: 2.1 });
        set(&mut params, Xnor2, CellParams { area_um2: 1.596, logical_effort: 2.6, parasitic: 3.4, input_cap: 1.9, switch_energy_fj: 2.1 });
        set(&mut params, Aoi21, CellParams { area_um2: 1.064, logical_effort: 1.8, parasitic: 2.4, input_cap: 1.5, switch_energy_fj: 1.1 });
        set(&mut params, Oai21, CellParams { area_um2: 1.064, logical_effort: 1.8, parasitic: 2.4, input_cap: 1.5, switch_energy_fj: 1.1 });
        set(&mut params, Maj3, CellParams { area_um2: 1.862, logical_effort: 2.0, parasitic: 3.2, input_cap: 1.6, switch_energy_fj: 1.8 });
        CellLib { tau_ns: 0.005, output_load: 4.0, params }
    }

    /// Parameters for a cell kind.
    #[inline]
    pub fn params(&self, kind: CellKind) -> &CellParams {
        &self.params[kind.opcode() as usize]
    }

    /// Logical-effort stage delay in τ for a cell driving `load` unit loads.
    #[inline]
    pub fn delay_tau(&self, kind: CellKind, load: f64) -> f64 {
        let p = self.params(kind);
        p.parasitic + p.logical_effort * (load / p.input_cap).max(0.25)
    }

    /// Stage delay in nanoseconds.
    #[inline]
    pub fn delay_ns(&self, kind: CellKind, load: f64) -> f64 {
        self.delay_tau(kind, load) * self.tau_ns
    }
}

impl Default for CellLib {
    fn default() -> Self {
        Self::nangate45()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opcode_roundtrip_is_stable() {
        for (i, k) in CellKind::ALL.iter().enumerate() {
            assert_eq!(k.opcode() as usize, i);
            assert_eq!(CellKind::ALL[k.opcode() as usize], *k);
        }
    }

    #[test]
    fn eval_truth_tables() {
        // Exercise every cell over all 3-bit input combinations using the
        // packed-lane convention: lane i of the words below encodes row i of
        // the truth table.
        let a = 0b11110000u64;
        let b = 0b11001100u64;
        let c = 0b10101010u64;
        let m = 0xffu64;
        assert_eq!(CellKind::And2.eval(a, b, 0) & m, a & b & m);
        assert_eq!(CellKind::Nand2.eval(a, b, 0) & m, !(a & b) & m);
        assert_eq!(CellKind::Xor2.eval(a, b, 0) & m, (a ^ b) & m);
        assert_eq!(CellKind::Aoi21.eval(a, b, c) & m, !((a & b) | c) & m);
        assert_eq!(CellKind::Oai21.eval(a, b, c) & m, !((a | b) & c) & m);
        // MAJ3 row-by-row.
        for row in 0..8u32 {
            let (ai, bi, ci) = (row >> 2 & 1, row >> 1 & 1, row & 1);
            let maj = (ai & bi) | (ai & ci) | (bi & ci);
            assert_eq!(
                CellKind::Maj3.eval(a, b, c) >> row & 1,
                u64::from(maj),
                "maj3 row {row}"
            );
        }
    }

    #[test]
    fn paper_structural_ratios_hold() {
        let lib = CellLib::nangate45();
        // 3:2 compressor area (2 XOR2 + 3 NAND2) vs 2:2 area (XOR2+AND2).
        // The paper's 1.5× quote assumes the monolithic FA_X1/HA_X1 cells;
        // our discrete-gate decomposition lands at ≈2.1×, still in the
        // "FA costs more but compresses more" regime Algorithm 1 relies on
        // (3-vs-2 cost units are used for the area metric, not µm²).
        let fa = 2.0 * lib.params(CellKind::Xor2).area_um2 + 3.0 * lib.params(CellKind::Nand2).area_um2;
        let ha = lib.params(CellKind::Xor2).area_um2 + lib.params(CellKind::And2).area_um2;
        let ratio = fa / ha;
        assert!((1.4..=2.3).contains(&ratio), "area ratio {ratio}");
        // A→Sum (2 XOR) vs Cin→Cout (2 NAND) delay at equal fanout ≈ 1.5×.
        let sum_path = 2.0 * lib.delay_tau(CellKind::Xor2, 2.0);
        let carry_path = 2.0 * lib.delay_tau(CellKind::Nand2, 2.0);
        let r = sum_path / carry_path;
        assert!((1.3..=2.2).contains(&r), "delay ratio {r}");
    }

    #[test]
    fn delay_increases_with_load() {
        let lib = CellLib::nangate45();
        for k in CellKind::ALL {
            assert!(lib.delay_tau(k, 8.0) > lib.delay_tau(k, 1.0));
        }
    }
}
