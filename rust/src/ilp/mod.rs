//! From-scratch MILP solver.
//!
//! The paper drives both of its optimization passes (compressor-tree stage
//! assignment, §3.3, and interconnection-order optimization, §3.5) with
//! Gurobi. This module is the in-repo substitute: a dense primal simplex for
//! LP relaxations ([`simplex`]), a best-first branch-and-bound wrapper for
//! integrality ([`branch_bound`]), and an exact bottleneck-assignment solver
//! ([`assignment`]) for the per-slice interconnect permutation problem
//! (which is an assignment polytope and deserves a combinatorial algorithm
//! rather than a tableau).
//!
//! The public surface is the [`Model`] builder + [`solve`]. Branch & bound
//! runs serially by default and in parallel over the coordinator's scoped
//! worker team when [`SolveOptions::threads`] `> 1` (shared atomic
//! incumbent, best-bound subproblem queue with work stealing — see
//! [`branch_bound`]).

pub mod assignment;
pub mod branch_bound;
pub mod simplex;



/// Variable handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Var(pub usize);

/// Constraint sense.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sense {
    /// `expr <= rhs`.
    Le,
    /// `expr >= rhs`.
    Ge,
    /// `expr == rhs`.
    Eq,
}

/// A linear expression `Σ coef·var`.
#[derive(Debug, Clone, Default)]
pub struct LinExpr {
    /// `(variable, coefficient)` terms; repeated variables accumulate.
    pub terms: Vec<(Var, f64)>,
}

impl LinExpr {
    /// Empty expression.
    pub fn new() -> Self {
        Self::default()
    }
    /// Append a term (builder style).
    pub fn term(mut self, v: Var, c: f64) -> Self {
        self.terms.push((v, c));
        self
    }
    /// Append a term in place.
    pub fn add(&mut self, v: Var, c: f64) -> &mut Self {
        self.terms.push((v, c));
        self
    }
    /// Expression from a term slice.
    pub fn of(terms: &[(Var, f64)]) -> Self {
        LinExpr { terms: terms.to_vec() }
    }
    /// Evaluate against a solution vector.
    pub fn eval(&self, x: &[f64]) -> f64 {
        self.terms.iter().map(|(v, c)| c * x[v.0]).sum()
    }
}

/// A model variable: bounds plus integrality.
#[derive(Debug, Clone)]
pub struct VarDef {
    /// Diagnostic name.
    pub name: String,
    /// Lower bound.
    pub lb: f64,
    /// Upper bound (may be `f64::INFINITY`).
    pub ub: f64,
    /// Whether the variable must take integer values.
    pub integer: bool,
}

/// One linear constraint `expr (<=|>=|==) rhs`.
#[derive(Debug, Clone)]
pub struct Constraint {
    /// Left-hand side.
    pub expr: LinExpr,
    /// Relation.
    pub sense: Sense,
    /// Right-hand side.
    pub rhs: f64,
}

/// MILP model builder (minimization).
#[derive(Debug, Clone, Default)]
pub struct Model {
    /// Variables in creation order (a [`Var`] indexes this).
    pub vars: Vec<VarDef>,
    /// Constraints in creation order.
    pub cons: Vec<Constraint>,
    /// Minimization objective.
    pub objective: LinExpr,
}

impl Model {
    /// Empty model.
    pub fn new() -> Self {
        Self::default()
    }

    /// Continuous variable in `[lb, ub]` (`ub` may be `f64::INFINITY`).
    pub fn cont(&mut self, name: impl Into<String>, lb: f64, ub: f64) -> Var {
        self.vars.push(VarDef { name: name.into(), lb, ub, integer: false });
        Var(self.vars.len() - 1)
    }

    /// Integer variable in `[lb, ub]`.
    pub fn int(&mut self, name: impl Into<String>, lb: f64, ub: f64) -> Var {
        self.vars.push(VarDef { name: name.into(), lb, ub, integer: true });
        Var(self.vars.len() - 1)
    }

    /// Binary variable.
    pub fn bin(&mut self, name: impl Into<String>) -> Var {
        self.int(name, 0.0, 1.0)
    }

    /// Add the constraint `expr (sense) rhs`.
    pub fn constrain(&mut self, expr: LinExpr, sense: Sense, rhs: f64) {
        self.cons.push(Constraint { expr, sense, rhs });
    }

    /// Set the (minimization) objective.
    pub fn minimize(&mut self, expr: LinExpr) {
        self.objective = expr;
    }

    /// Number of variables.
    pub fn num_vars(&self) -> usize {
        self.vars.len()
    }
    /// Number of constraints.
    pub fn num_cons(&self) -> usize {
        self.cons.len()
    }

    /// Check a candidate point against all constraints/bounds.
    pub fn is_feasible(&self, x: &[f64], tol: f64) -> bool {
        for (i, v) in self.vars.iter().enumerate() {
            if x[i] < v.lb - tol || x[i] > v.ub + tol {
                return false;
            }
            if v.integer && (x[i] - x[i].round()).abs() > tol {
                return false;
            }
        }
        self.cons.iter().all(|c| {
            let lhs = c.expr.eval(x);
            match c.sense {
                Sense::Le => lhs <= c.rhs + tol,
                Sense::Ge => lhs >= c.rhs - tol,
                Sense::Eq => (lhs - c.rhs).abs() <= tol,
            }
        })
    }
}

/// Solve status.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// Proven optimal.
    Optimal,
    /// Feasible incumbent returned but optimality not proven (time limit).
    Feasible,
    /// No feasible point exists.
    Infeasible,
    /// Objective unbounded below.
    Unbounded,
    /// No incumbent found within the time limit.
    TimeLimit,
}

/// Solution returned by the solvers.
#[derive(Debug, Clone)]
pub struct Solution {
    /// Outcome of the solve.
    pub status: Status,
    /// Objective value of `values`.
    pub objective: f64,
    /// Variable assignment (indexed by [`Var`]).
    pub values: Vec<f64>,
    /// Branch-and-bound nodes explored (0 for pure LPs).
    pub nodes: u64,
}

impl Solution {
    /// Value of one variable.
    pub fn value(&self, v: Var) -> f64 {
        self.values[v.0]
    }
    /// Value of one integer variable, rounded exactly.
    pub fn int_value(&self, v: Var) -> i64 {
        self.values[v.0].round() as i64
    }
    /// Whether a usable assignment came back (optimal or feasible).
    pub fn ok(&self) -> bool {
        matches!(self.status, Status::Optimal | Status::Feasible)
    }
}

/// Solver knobs.
#[derive(Debug, Clone, Copy)]
pub struct SolveOptions {
    /// Wall-clock budget; the incumbent (if any) is returned at expiry.
    pub time_limit: std::time::Duration,
    /// Relative MIP gap at which B&B stops.
    pub mip_gap: f64,
    /// Branch-and-bound node budget.
    pub max_nodes: u64,
    /// Worker threads for branch & bound. `1` (the default) runs the
    /// serial best-first search; `> 1` runs the parallel search over the
    /// coordinator worker team — workers share an atomic incumbent bound
    /// and a best-bound subproblem queue, each diving on one child locally
    /// and publishing the other for stealing. Run to completion, both
    /// modes return the same objective (the search order differs, the
    /// optimum does not).
    pub threads: usize,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            time_limit: std::time::Duration::from_secs(60),
            mip_gap: 1e-6,
            max_nodes: 2_000_000,
            threads: 1,
        }
    }
}

impl SolveOptions {
    /// Default options with branch & bound parallelized over all available
    /// cores.
    pub fn parallel() -> Self {
        SolveOptions {
            threads: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
            ..Default::default()
        }
    }

    /// Set the worker-thread count (builder style).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }
}

/// Solve a model: pure LP via simplex, MILP via branch & bound.
pub fn solve(model: &Model, opts: &SolveOptions) -> Solution {
    if model.vars.iter().any(|v| v.integer) {
        branch_bound::solve_milp(model, opts)
    } else {
        simplex::solve_lp(model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_builder_and_feasibility() {
        let mut m = Model::new();
        let x = m.cont("x", 0.0, 10.0);
        let y = m.int("y", 0.0, 5.0);
        m.constrain(LinExpr::of(&[(x, 1.0), (y, 2.0)]), Sense::Le, 8.0);
        m.minimize(LinExpr::of(&[(x, -1.0), (y, -1.0)]));
        assert!(m.is_feasible(&[2.0, 3.0], 1e-9));
        assert!(!m.is_feasible(&[2.0, 3.5], 1e-9)); // fractional integer
        assert!(!m.is_feasible(&[9.0, 0.0], 1e-9)); // violates constraint? 9 <= 8 no
    }
}
