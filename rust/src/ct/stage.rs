//! §3.3 — compressor-to-stage assignment.
//!
//! Given Algorithm 1's per-column counts, decide at which stage each
//! compressor fires. Two engines:
//!
//! - [`assign_greedy`] — ASAP placement (each stage consumes as many of the
//!   column's remaining compressors as its current population permits).
//!   This realizes the minimum stage count for Algorithm-1 count vectors
//!   (§3.2's optimality argument) in O(stages × columns).
//! - [`assign_ilp`] — the paper's exact ILP (Eq. 6-12) solved with the
//!   in-tree MILP engine; used at small-to-medium widths and by the Fig-13
//!   runtime study. Tests assert it matches the greedy stage count.
//!
//! GOMIL's behaviour (no stage objective) is modelled by
//! [`assign_column_serial`], which compresses each column depth-first and
//! produces the taller trees the paper criticizes.

use super::counts::CtCounts;
use crate::ilp::{self, LinExpr, Model, Sense, SolveOptions};

/// A stage-by-column placement: `f[i][j]` 3:2s and `h[i][j]` 2:2s fire at
/// stage `i` in column `j`.
#[derive(Debug, Clone)]
pub struct StagePlan {
    pub f: Vec<Vec<usize>>,
    pub h: Vec<Vec<usize>>,
}

impl StagePlan {
    pub fn stages(&self) -> usize {
        self.f.len()
    }
    pub fn width(&self) -> usize {
        self.f.first().map_or(0, |r| r.len())
    }

    /// Verify the plan against the counts: totals match (Eq. 6/7), stagewise
    /// populations never go negative and support the placed compressors
    /// (Eq. 8/9), and the final population is ≤ 2 per column.
    pub fn validate(&self, counts: &CtCounts) -> Result<(), String> {
        let w = counts.width();
        let mut tot_f = vec![0usize; w];
        let mut tot_h = vec![0usize; w];
        let mut avail: Vec<usize> = counts.initial.clone();
        for i in 0..self.stages() {
            let mut next = avail.clone();
            for j in 0..w {
                let (fij, hij) = (self.f[i][j], self.h[i][j]);
                if 3 * fij + 2 * hij > avail[j] {
                    return Err(format!(
                        "stage {i} col {j}: {fij}×3:2+{hij}×2:2 exceeds population {}",
                        avail[j]
                    ));
                }
                tot_f[j] += fij;
                tot_h[j] += hij;
                next[j] -= 2 * fij + hij; // 3 consumed, 1 sum emitted (net −2)
                if j + 1 < w {
                    next[j + 1] += fij + hij;
                }
            }
            avail = next;
        }
        if tot_f != counts.f || tot_h != counts.h {
            return Err("stage totals disagree with Algorithm 1 counts".into());
        }
        for (j, &a) in avail.iter().enumerate() {
            if a > 2 {
                return Err(format!("column {j}: {a} bits remain after final stage"));
            }
        }
        Ok(())
    }
}

/// ASAP greedy assignment (minimum stages for Algorithm-1 counts).
pub fn assign_greedy(counts: &CtCounts) -> StagePlan {
    let w = counts.width();
    let mut rem_f = counts.f.clone();
    let mut rem_h = counts.h.clone();
    let mut avail: Vec<usize> = counts.initial.clone();
    let mut plan = StagePlan { f: vec![], h: vec![] };
    let max_stages = 4 * counts.stage_lower_bound() + 8;
    for _ in 0..max_stages {
        if rem_f.iter().all(|&x| x == 0) && rem_h.iter().all(|&x| x == 0) {
            break;
        }
        let mut fi = vec![0usize; w];
        let mut hi = vec![0usize; w];
        let mut next = avail.clone();
        for j in 0..w {
            let mut pop = avail[j];
            let fij = rem_f[j].min(pop / 3);
            pop -= 3 * fij;
            let hij = rem_h[j].min(pop / 2);
            fi[j] = fij;
            hi[j] = hij;
            rem_f[j] -= fij;
            rem_h[j] -= hij;
            next[j] -= 2 * fij + hij;
            if j + 1 < w {
                next[j + 1] += fij + hij;
            }
        }
        plan.f.push(fi);
        plan.h.push(hi);
        avail = next;
    }
    debug_assert!(
        rem_f.iter().all(|&x| x == 0) && rem_h.iter().all(|&x| x == 0),
        "greedy stage assignment did not converge"
    );
    plan
}

/// GOMIL-style column-serial assignment: each column is fully compressed by
/// chaining its compressors depth-first (one per stage), ignoring the global
/// stage count — reproducing the baseline's taller CT.
pub fn assign_column_serial(counts: &CtCounts) -> StagePlan {
    let w = counts.width();
    let mut rem_f = counts.f.clone();
    let mut rem_h = counts.h.clone();
    let mut avail: Vec<usize> = counts.initial.clone();
    let mut plan = StagePlan { f: vec![], h: vec![] };
    // Upper bound: total compressors (each fires on its own stage at worst).
    let cap: usize = counts.f.iter().sum::<usize>() + counts.h.iter().sum::<usize>() + 2;
    for _ in 0..cap {
        if rem_f.iter().all(|&x| x == 0) && rem_h.iter().all(|&x| x == 0) {
            break;
        }
        let mut fi = vec![0usize; w];
        let mut hi = vec![0usize; w];
        let mut next = avail.clone();
        for j in 0..w {
            // at most ONE compressor per column per stage (serial chains)
            let mut pop = avail[j];
            if rem_f[j] > 0 && pop >= 3 {
                fi[j] = 1;
                rem_f[j] -= 1;
                pop -= 3;
                next[j] -= 2;
                if j + 1 < w {
                    next[j + 1] += 1;
                }
            } else if rem_h[j] > 0 && pop >= 2 {
                hi[j] = 1;
                rem_h[j] -= 1;
                next[j] -= 1;
                if j + 1 < w {
                    next[j + 1] += 1;
                }
            }
            let _ = pop;
        }
        plan.f.push(fi);
        plan.h.push(hi);
        avail = next;
    }
    plan
}

/// Exact §3.3 ILP (Eq. 6-12). Returns the plan and the solver's node count
/// (reported by the Fig-13 bench). Falls back to the greedy plan if the
/// solver hits its limits without an incumbent.
pub fn assign_ilp(counts: &CtCounts, opts: &SolveOptions) -> (StagePlan, u64) {
    let w = counts.width();
    let greedy = assign_greedy(counts);
    let stage_max = greedy.stages().max(1); // optimum is ≤ greedy
    let mut m = Model::new();

    // Variables.
    let fmax = *counts.f.iter().max().unwrap_or(&0) as f64;
    let hmax = *counts.h.iter().max().unwrap_or(&0) as f64;
    let f_v: Vec<Vec<_>> = (0..stage_max)
        .map(|i| (0..w).map(|j| m.int(format!("f{i}_{j}"), 0.0, fmax)).collect())
        .collect();
    let h_v: Vec<Vec<_>> = (0..stage_max)
        .map(|i| (0..w).map(|j| m.int(format!("h{i}_{j}"), 0.0, hmax)).collect())
        .collect();
    let pp_v: Vec<Vec<_>> = (0..=stage_max)
        .map(|i| (0..w).map(|j| m.cont(format!("pp{i}_{j}"), 0.0, 1e4)).collect())
        .collect();
    let y_v: Vec<Vec<_>> = (0..stage_max)
        .map(|i| (0..w).map(|j| m.bin(format!("y{i}_{j}"))).collect())
        .collect();
    let s_v = m.cont("S", 0.0, stage_max as f64);
    let big = 1e3;

    for j in 0..w {
        // Eq. 6/7: totals match Algorithm 1.
        let fsum: Vec<_> = (0..stage_max).map(|i| (f_v[i][j], 1.0)).collect();
        m.constrain(LinExpr::of(&fsum), Sense::Eq, counts.f[j] as f64);
        let hsum: Vec<_> = (0..stage_max).map(|i| (h_v[i][j], 1.0)).collect();
        m.constrain(LinExpr::of(&hsum), Sense::Eq, counts.h[j] as f64);
        // Initial populations.
        m.constrain(LinExpr::of(&[(pp_v[0][j], 1.0)]), Sense::Eq, counts.initial[j] as f64);
    }
    for i in 0..stage_max {
        for j in 0..w {
            // Eq. 8: population recurrence.
            let mut e = LinExpr::new();
            e.add(pp_v[i + 1][j], 1.0);
            e.add(pp_v[i][j], -1.0);
            e.add(f_v[i][j], 2.0);
            e.add(h_v[i][j], 1.0);
            if j > 0 {
                e.add(f_v[i][j - 1], -1.0);
                e.add(h_v[i][j - 1], -1.0);
            }
            m.constrain(e, Sense::Eq, 0.0);
            // Eq. 9: compressors fit the population.
            m.constrain(
                LinExpr::of(&[(f_v[i][j], 3.0), (h_v[i][j], 2.0), (pp_v[i][j], -1.0)]),
                Sense::Le,
                0.0,
            );
            // Eq. 10/11: stage-use indicators.
            m.constrain(
                LinExpr::of(&[(s_v, 1.0), (y_v[i][j], -((i + 1) as f64))]),
                Sense::Ge,
                0.0,
            );
            m.constrain(
                LinExpr::of(&[(y_v[i][j], big), (f_v[i][j], -1.0), (h_v[i][j], -1.0)]),
                Sense::Ge,
                0.0,
            );
        }
    }
    // Final populations ≤ 2 (the two-row output requirement).
    for j in 0..w {
        m.constrain(LinExpr::of(&[(pp_v[stage_max][j], 1.0)]), Sense::Le, 2.0);
    }
    m.minimize(LinExpr::of(&[(s_v, 1.0)]));

    let sol = ilp::solve(&m, opts);
    if !sol.ok() {
        return (greedy, sol.nodes);
    }
    let used = sol.value(s_v).round() as usize;
    let mut plan = StagePlan {
        f: vec![vec![0; w]; used.max(1)],
        h: vec![vec![0; w]; used.max(1)],
    };
    for i in 0..used.max(1).min(stage_max) {
        for j in 0..w {
            plan.f[i][j] = sol.int_value(f_v[i][j]) as usize;
            plan.h[i][j] = sol.int_value(h_v[i][j]) as usize;
        }
    }
    if plan.validate(counts).is_err() {
        return (greedy, sol.nodes);
    }
    (plan, sol.nodes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mult_counts(n: usize) -> CtCounts {
        let pp: Vec<usize> = (0..2 * n - 1).map(|j| n.min(j + 1).min(2 * n - 1 - j)).collect();
        CtCounts::from_populations(&pp)
    }

    #[test]
    fn greedy_is_valid_and_hits_lower_bound() {
        for n in [3, 4, 8, 16, 32] {
            let c = mult_counts(n);
            let plan = assign_greedy(&c);
            plan.validate(&c).unwrap_or_else(|e| panic!("n={n}: {e}"));
            assert_eq!(
                plan.stages(),
                c.stage_lower_bound(),
                "n={n}: greedy {} vs bound {}",
                plan.stages(),
                c.stage_lower_bound()
            );
        }
    }

    #[test]
    fn column_serial_is_valid_but_taller() {
        let c = mult_counts(8);
        let serial = assign_column_serial(&c);
        serial.validate(&c).unwrap();
        let greedy = assign_greedy(&c);
        assert!(
            serial.stages() > greedy.stages(),
            "serial {} vs greedy {}",
            serial.stages(),
            greedy.stages()
        );
    }

    #[test]
    fn ilp_matches_greedy_optimum_small() {
        for n in [3, 4] {
            let c = mult_counts(n);
            let opts = SolveOptions {
                time_limit: std::time::Duration::from_secs(20),
                ..Default::default()
            };
            let (plan, _) = assign_ilp(&c, &opts);
            plan.validate(&c).unwrap();
            assert_eq!(plan.stages(), assign_greedy(&c).stages(), "n={n}");
        }
    }

    #[test]
    fn mac_shapes_assign_cleanly() {
        for n in [4, 8] {
            let mut pp: Vec<usize> =
                (0..2 * n - 1).map(|j| n.min(j + 1).min(2 * n - 1 - j)).collect();
            pp.push(0);
            for p in pp.iter_mut() {
                *p += 1;
            }
            let c = CtCounts::from_populations(&pp);
            let plan = assign_greedy(&c);
            plan.validate(&c).unwrap();
        }
    }
}
