//! Bit-level abstract interpretation over the SoA netlist.
//!
//! Where [`crate::lint`] checks *structure* (and the build's own trace
//! evidence), this subsystem proves facts about *values*: a generic
//! forward dataflow engine ([`fixpoint`]) runs level-ordered sweeps over
//! the cached CSR topology — parallelized per topological level, with a
//! register-aware outer fixpoint — instantiated with three domains:
//!
//! 1. **Ternary constant propagation** ([`ternary`]) — proves nodes
//!    constant 0/1 through [`crate::ir::CellKind::eval`] itself, turning
//!    the heuristic const-foldable/dead-gate Info lints into
//!    proof-backed **UFO4xx** diagnostics (proven-constant output
//!    `UFO401`, dead register `UFO402`, stuck enable `UFO403`).
//! 2. **Signal probability / switching activity** ([`prob`]) —
//!    Parker–McCluskey-style propagation with a correlation-depth cap;
//!    replaces the constant-activity fallback in the dynamic-power
//!    report ([`crate::sta::Sta::dynamic_power_mw`]) for combinational
//!    *and* pipelined netlists.
//! 3. **Word-level intervals** ([`interval`]) — proven value ranges per
//!    output weight group, unreachable-carry detection (`UFO404`) and
//!    the operand weight-conservation cross-check (`UFO405`).
//!
//! The cheap-but-sound scoring signal matters beyond diagnostics:
//! ranking thousands of candidate compressor trees (the DOMAC /
//! AC-Refiner style searches the ROADMAP targets) needs power and range
//! estimates that don't cost a Monte-Carlo simulation per candidate.
//!
//! Integration mirrors lint end-to-end: [`crate::api::SynthEngine`] runs
//! [`analyze_design`] on fresh designs and persists the
//! [`AnalysisReport`] on the artifact, `ufo-mac analyze` sweeps the
//! tier-1 families from the CLI, and the server answers an `analyze`
//! command (PROTOCOL.md). `rust/tests/analysis.rs` is the soundness
//! harness: concrete 64-lane simulation values (and clocked traces for
//! pipelined variants) must lie inside the abstract results on every
//! tier-1 design family, for any worker count.

pub mod fixpoint;
pub mod interval;
pub mod prob;
pub mod report;
pub mod ternary;

pub use fixpoint::{Domain, FixpointRun};
pub use interval::{group_interval, output_groups, unreachable_carry_run, OutputGroup};
pub use prob::{switching_activity, ProbDomain};
pub use report::{AnalysisReport, GroupSummary};
pub use ternary::{Tern, TernaryDomain};

use crate::ir::netlist::OP_REG;
use crate::ir::Netlist;
use crate::lint::{Diagnostic, Locus, UFO401, UFO402, UFO403, UFO404, UFO405};
use crate::multiplier::Design;

/// Knobs of an analysis run. The defaults are what the engine and CLI
/// use; every setting is output-deterministic (worker count included —
/// the level schedule writes disjoint indices).
#[derive(Debug, Clone, Copy)]
pub struct AnalysisOptions {
    /// Worker threads for the per-level parallel sweeps.
    pub workers: usize,
    /// Correlation-depth cap of the probability domain (`1` =
    /// independence over direct fanins).
    pub correlation_depth: usize,
    /// Frontier cap of the probability enumeration window.
    pub correlation_sources: usize,
    /// Iteration budget for the probability register fixpoint (the
    /// ternary fixpoint needs no budget: it converges in ≤ registers + 1
    /// sweeps).
    pub max_prob_sweeps: usize,
}

impl Default for AnalysisOptions {
    fn default() -> Self {
        AnalysisOptions {
            workers: 1,
            correlation_depth: 2,
            correlation_sources: 8,
            max_prob_sweeps: 64,
        }
    }
}

impl AnalysisOptions {
    /// The allocation-free configuration the STA power fallback uses:
    /// depth-1 independence propagation, serial, with a small iteration
    /// budget — strictly cheaper than even one round of toggle
    /// simulation.
    pub fn fast() -> Self {
        AnalysisOptions { correlation_depth: 1, max_prob_sweeps: 16, ..Default::default() }
    }
}

/// Full in-memory result of one analysis run: the per-node abstract
/// vectors of every domain plus the persistable [`AnalysisReport`]
/// summary.
#[derive(Debug, Clone)]
pub struct AnalysisOutcome {
    /// Ternary value per node.
    pub ternary: Vec<Tern>,
    /// `P(node = 1)` per node.
    pub prob: Vec<f64>,
    /// Static per-cycle switching activity per node.
    pub activity: Vec<f64>,
    /// Output weight groups the intervals were computed over.
    pub groups: Vec<OutputGroup>,
    /// The persistable summary.
    pub report: AnalysisReport,
}

/// Static switching-activity estimate per node — the probability domain
/// alone, for callers (the STA power model) that need activities without
/// proofs or intervals.
pub fn static_activity(nl: &Netlist, opts: &AnalysisOptions) -> Vec<f64> {
    let dom = ProbDomain { depth: opts.correlation_depth, sources: opts.correlation_sources };
    let run = fixpoint::run(nl, &dom, opts.workers, opts.max_prob_sweeps);
    switching_activity(&run.values)
}

/// Analyze a bare netlist: run all three domains and assemble the report
/// with the UFO4xx diagnostics (in code order: 401 per output, 402/403
/// per register, 404 per group — each in ascending id order).
pub fn analyze_netlist(nl: &Netlist, opts: &AnalysisOptions) -> AnalysisOutcome {
    let tern_run = fixpoint::run(nl, &TernaryDomain, opts.workers, nl.num_regs() + 2);
    let dom = ProbDomain { depth: opts.correlation_depth, sources: opts.correlation_sources };
    let prob_run = fixpoint::run(nl, &dom, opts.workers, opts.max_prob_sweeps);
    let activity = switching_activity(&prob_run.values);
    let tern = tern_run.values;
    let ops = nl.ops();

    let (mut proven_zero, mut proven_one) = (0usize, 0usize);
    let (mut act_sum, mut act_n) = (0.0f64, 0usize);
    for i in 0..ops.len() {
        if ops[i] <= 10 || ops[i] == OP_REG {
            match tern[i] {
                Tern::Zero => proven_zero += 1,
                Tern::One => proven_one += 1,
                Tern::Unknown => {}
            }
        }
        if ops[i] <= 10 {
            act_sum += activity[i];
            act_n += 1;
        }
    }

    let mut diagnostics = Vec::new();
    // UFO401 — proven-constant primary output. Only gate-driven outputs:
    // an output wired straight to a constant node is an intentional tie,
    // and register-driven constants are the UFO402 story.
    for (ordinal, (name, id)) in nl.outputs().enumerate() {
        if ops[id.index()] <= 10 {
            if let Some(v) = tern[id.index()].known() {
                diagnostics.push(Diagnostic::new(
                    UFO401,
                    Locus::Output(ordinal),
                    format!("output '{name}' proven constant {}", u8::from(v)),
                ));
            }
        }
    }
    // UFO402 — dead register: the state can never leave one proven value.
    for &(r, init) in nl.registers() {
        if let Some(v) = tern[r as usize].known() {
            diagnostics.push(Diagnostic::new(
                UFO402,
                Locus::Node(r),
                format!(
                    "dead register: state proven constant {} (init {})",
                    u8::from(v),
                    u8::from(init)
                ),
            ));
        }
    }
    // UFO403 — enable provably stuck at 0 (the proof-backed upgrade of
    // the structural UFO301, which only sees a *directly* tied constant).
    for &(r, _) in nl.registers() {
        let en = nl.fanin_records()[r as usize][1];
        if tern[en as usize] == Tern::Zero {
            diagnostics.push(Diagnostic::new(
                UFO403,
                Locus::Node(r),
                format!("register enable (node {en}) proven stuck at 0: can never capture data"),
            ));
        }
    }
    // UFO404 — unreachable carry columns at the MSB end of a group.
    let groups = output_groups(nl);
    let mut summaries = Vec::with_capacity(groups.len());
    for g in &groups {
        if let Some((run, ordinal)) = unreachable_carry_run(g, &tern) {
            diagnostics.push(Diagnostic::new(
                UFO404,
                Locus::Output(ordinal),
                format!(
                    "unreachable carry: top {run} bit(s) of output group '{}' proven constant 0",
                    g.name
                ),
            ));
        }
        if let Some((lo, hi)) = group_interval(g, &tern) {
            summaries.push(GroupSummary {
                name: g.name.clone(),
                output: g.ordinals[0],
                bits: g.bits.len(),
                lo,
                hi,
            });
        }
    }

    let report = AnalysisReport {
        nodes: nl.len(),
        proven_zero,
        proven_one,
        tern_sweeps: tern_run.sweeps,
        prob_sweeps: prob_run.sweeps,
        correlation_depth: opts.correlation_depth,
        mean_activity: if act_n == 0 { 0.0 } else { act_sum / act_n as f64 },
        groups: summaries,
        diagnostics,
    };
    AnalysisOutcome { ternary: tern, prob: prob_run.values, activity, groups, report }
}

/// Analyze a built [`Design`]: [`analyze_netlist`] plus the word-level
/// weight-conservation cross-check. For unsigned formats the product
/// bits, read as a little-endian word, must be able to cover the
/// operand-implied range `[0, maxA·maxB + maxC]`; a proven interval that
/// *cannot* contain it means a compressor-tree stage lost or invented
/// bit weight (`UFO405`). Signed formats are skipped (two's-complement
/// bit patterns span the full unsigned range by design), as are operand
/// widths beyond `u128` headroom.
pub fn analyze_design(design: &Design, opts: &AnalysisOptions) -> AnalysisOutcome {
    let mut out = analyze_netlist(&design.netlist, opts);
    let (na, nb, nc) = (design.a.len(), design.b.len(), design.c.len());
    if !design.format.is_signed() && na + nb <= 120 && nc <= 120 {
        let group = OutputGroup {
            name: "product".to_string(),
            ordinals: vec![0],
            bits: design.product.iter().map(|id| id.0).collect(),
        };
        if let Some((lo, hi)) = group_interval(&group, &out.ternary) {
            let max = ((1u128 << na) - 1) * ((1u128 << nb) - 1)
                + if nc == 0 { 0 } else { (1u128 << nc) - 1 };
            if lo > 0 || hi < max {
                out.report.diagnostics.push(Diagnostic::new(
                    UFO405,
                    Locus::Design,
                    format!(
                        "product interval [{lo}, {hi}] cannot contain the operand-implied \
                         range [0, {max}]"
                    ),
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::Severity;

    #[test]
    fn clean_combinational_netlist_analyzes_in_one_sweep() {
        let mut nl = Netlist::new("mini");
        let a = nl.input("a");
        let b = nl.input("b");
        let s = nl.xor2(a, b);
        let c = nl.and2(a, b);
        nl.output("s0", s);
        nl.output("s1", c);
        let out = analyze_netlist(&nl, &AnalysisOptions::default());
        assert!(out.report.is_clean());
        assert_eq!(out.report.tern_sweeps, 1);
        assert_eq!(out.report.prob_sweeps, 1);
        assert_eq!(out.report.nodes, nl.len());
        assert_eq!(out.report.groups.len(), 1);
        assert_eq!(out.report.groups[0].bits, 2);
        assert_eq!(out.report.groups[0].lo, 0);
        assert_eq!(out.report.groups[0].hi, 3);
        assert!(out.report.mean_activity > 0.0);
    }

    #[test]
    fn stuck_enable_chain_raises_the_semantic_family() {
        // en = and2(const0, x): UFO403 (stuck enable) + UFO402 (dead
        // register) — and the proven-constant output over it gets UFO401.
        let mut nl = Netlist::new("stuck");
        let x = nl.input("x");
        let d = nl.input("d");
        let zero = nl.constant(false);
        let en = nl.and2(zero, x);
        let q = nl.reg(d, en, zero, false);
        let y = nl.or2(q, zero);
        nl.output("y", y);
        let out = analyze_netlist(&nl, &AnalysisOptions::default());
        let codes: Vec<&str> = out.report.diagnostics.iter().map(|d| d.code).collect();
        assert_eq!(codes, vec!["UFO401", "UFO402", "UFO403"]);
        assert_eq!(out.report.max_severity(), Some(Severity::Error));
        assert!(out.report.denies(Severity::Error));
    }
}
