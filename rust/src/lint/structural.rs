//! Structural lint passes over the flat SoA [`Netlist`] (`UFO0xx` codes).
//!
//! The passes are staged: reference integrity ([`UFO001`]/[`UFO002`]/
//! [`UFO005`]) runs first, and the topology-dependent passes (dead gates,
//! duplicates) only run when it found nothing — walking consumers of a
//! netlist with dangling references would index out of bounds.

use crate::ir::{CellKind, Netlist, OP_CONST0, OP_CONST1, OP_INPUT, OP_REG};

use super::report::{
    Diagnostic, LintOptions, Locus, UFO001, UFO002, UFO003, UFO004, UFO005, UFO006, UFO007,
};

/// Run every structural pass over `nl` and return the findings in pass
/// order. This is the netlist half of [`super::lint_design`]; it is also
/// the whole lint for module bodies that carry no datapath evidence.
pub fn lint_netlist(nl: &Netlist, opts: &LintOptions) -> Vec<Diagnostic> {
    let mut diags = pass_references(nl);
    // Register pins follow sequential rules (forward data is feedback,
    // not a cycle), so their reference integrity is a separate pass —
    // but it gates the topology-dependent passes exactly like the
    // combinational reference findings do.
    diags.extend(super::sequential::pass_registers(nl));
    let refs_ok = diags.is_empty();
    diags.extend(pass_output_names(nl));
    if refs_ok && opts.pedantic {
        diags.extend(pass_dead_gates(nl));
        diags.extend(pass_const_foldable(nl));
        diags.extend(pass_duplicate_gates(nl));
        diags.extend(super::sequential::pass_stage_balance(nl));
    }
    diags
}

/// Reference integrity: opcode validity ([`UFO005`]), input-ordinal
/// consistency ([`UFO005`]), dangling fanins/outputs ([`UFO002`]) and
/// topological-order violations ([`UFO001`]).
///
/// The append-only IR stores nodes in topological order, so a fanin
/// pointing at the node itself or forward *is* a combinational cycle: any
/// cyclic netlist flattened into the SoA arrays must contain at least one
/// such edge.
fn pass_references(nl: &Netlist) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let ops = nl.ops();
    let fanin = nl.fanin_records();
    let n = nl.len();
    for i in 0..n {
        let op = ops[i];
        match op {
            OP_CONST0 | OP_CONST1 => {}
            // Registers are checked by `sequential::pass_registers` — the
            // data pin may legally reference forward (feedback).
            OP_REG => {}
            OP_INPUT => {
                let ord = fanin[i][0] as usize;
                let ok = nl.input_ids().get(ord).is_some_and(|id| id.index() == i);
                if !ok {
                    diags.push(Diagnostic::new(
                        UFO005,
                        Locus::Node(i as u32),
                        format!("input node {i} carries corrupt ordinal {ord}"),
                    ));
                }
            }
            op if (op as usize) < CellKind::ALL.len() => {
                let kind = CellKind::ALL[op as usize];
                for slot in 0..kind.arity() {
                    let f = fanin[i][slot] as usize;
                    if f >= n {
                        diags.push(Diagnostic::new(
                            UFO002,
                            Locus::Node(i as u32),
                            format!("{kind:?} node {i} fanin {slot} dangles (points at {f}, netlist has {n} nodes)"),
                        ));
                    } else if f >= i {
                        diags.push(Diagnostic::new(
                            UFO001,
                            Locus::Node(i as u32),
                            format!("{kind:?} node {i} fanin {slot} references node {f}: topological order is violated (combinational cycle)"),
                        ));
                    }
                }
            }
            other => {
                diags.push(Diagnostic::new(
                    UFO005,
                    Locus::Node(i as u32),
                    format!("node {i} has unknown opcode {other}"),
                ));
            }
        }
    }
    for (slot, (name, id)) in nl.outputs().enumerate() {
        if id.index() >= n {
            diags.push(Diagnostic::new(
                UFO002,
                Locus::Output(slot),
                format!("output '{name}' dangles (points at node {}, netlist has {n} nodes)", id.index()),
            ));
        }
    }
    diags
}

/// Multiply-defined output names ([`UFO004`]). Two registrations of the
/// same name are a defect even when they point at the same node: whichever
/// consumer resolves the name gets an arbitrary winner.
fn pass_output_names(nl: &Netlist) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let mut first = std::collections::HashMap::new();
    for (slot, (name, _)) in nl.outputs().enumerate() {
        if let Some(prev) = first.insert(name.to_string(), slot) {
            diags.push(Diagnostic::new(
                UFO004,
                Locus::Output(slot),
                format!("output '{name}' multiply defined (slots {prev} and {slot})"),
            ));
        }
    }
    diags
}

/// Dead gates ([`UFO003`], pedantic): gates from which no primary output
/// is reachable. Seeds a worklist with unconsumed non-output gates from
/// the cached CSR topology's fanout counts, then grows the dead set
/// through `consumers()`: a gate all of whose consumers are dead is dead.
///
/// Arithmetic netlists produce these legitimately — a compressor whose
/// carry would land past the output width still instantiates its carry
/// gate, and truncated products orphan the top CPA bits — which is why the
/// pass is informational and off by default.
fn pass_dead_gates(nl: &Netlist) -> Vec<Diagnostic> {
    let n = nl.len();
    let topo = nl.topology();
    let ops = nl.ops();
    let fanin = nl.fanin_records();
    let is_gate = |i: usize| (ops[i] as usize) < CellKind::ALL.len();
    let mut is_output = vec![false; n];
    for (_, id) in nl.outputs() {
        is_output[id.index()] = true;
    }
    let mut dead = vec![false; n];
    let mut stack: Vec<usize> = (0..n)
        .filter(|&i| is_gate(i) && !is_output[i] && topo.fanout_counts()[i] == 0)
        .collect();
    for &i in &stack {
        dead[i] = true;
    }
    while let Some(i) = stack.pop() {
        for slot in 0..CellKind::ALL[ops[i] as usize].arity() {
            let f = fanin[i][slot] as usize;
            if dead[f] || is_output[f] || !is_gate(f) {
                continue;
            }
            // Registers and outputs bump the fanout count but have no CSR
            // consumer rows; a count exceeding the row length means a
            // consumer the walk can't see — the node is live.
            let rows = topo.consumers(f);
            if topo.fanout_counts()[f] as usize > rows.len() {
                continue;
            }
            if rows.iter().all(|&c| dead[c as usize]) {
                dead[f] = true;
                stack.push(f);
            }
        }
    }
    let mut diags = Vec::new();
    for (i, &d) in dead.iter().enumerate() {
        if d {
            diags.push(Diagnostic::new(
                UFO003,
                Locus::Node(i as u32),
                format!(
                    "{:?} node {i} is unreachable from every primary output",
                    CellKind::ALL[ops[i] as usize]
                ),
            ));
        }
    }
    diags
}

/// Constant-foldable gates ([`UFO006`], pedantic): every fanin is a
/// constant, or a binary gate reads the same node twice.
fn pass_const_foldable(nl: &Netlist) -> Vec<Diagnostic> {
    let ops = nl.ops();
    let fanin = nl.fanin_records();
    let mut diags = Vec::new();
    for i in 0..nl.len() {
        let op = ops[i] as usize;
        if op >= CellKind::ALL.len() {
            continue;
        }
        let kind = CellKind::ALL[op];
        let arity = kind.arity();
        let is_const =
            |slot: usize| matches!(ops[fanin[i][slot] as usize], OP_CONST0 | OP_CONST1);
        if (0..arity).all(is_const) {
            diags.push(Diagnostic::new(
                UFO006,
                Locus::Node(i as u32),
                format!("{kind:?} node {i} reads only constants"),
            ));
        } else if arity == 2 && fanin[i][0] == fanin[i][1] {
            diags.push(Diagnostic::new(
                UFO006,
                Locus::Node(i as u32),
                format!("{kind:?} node {i} reads node {} on both pins", fanin[i][0]),
            ));
        }
    }
    diags
}

/// Structurally duplicate gates ([`UFO007`], pedantic): same opcode and
/// same fanin record as an earlier gate. Commutativity is deliberately not
/// canonicalized — `and2(a, b)` vs `and2(b, a)` have different pin timing
/// in the cell library, so only exact duplicates are flagged.
fn pass_duplicate_gates(nl: &Netlist) -> Vec<Diagnostic> {
    let ops = nl.ops();
    let fanin = nl.fanin_records();
    let mut seen = std::collections::HashMap::new();
    let mut diags = Vec::new();
    for i in 0..nl.len() {
        if (ops[i] as usize) >= CellKind::ALL.len() {
            continue;
        }
        if let Some(prev) = seen.insert((ops[i], fanin[i]), i) {
            diags.push(Diagnostic::new(
                UFO007,
                Locus::Node(i as u32),
                format!(
                    "{:?} node {i} duplicates node {prev} (same opcode and fanins)",
                    CellKind::ALL[ops[i] as usize]
                ),
            ));
        }
    }
    diags
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.code).collect()
    }

    #[test]
    fn clean_netlist_has_no_findings() {
        let mut nl = Netlist::new("clean");
        let a = nl.input("a");
        let b = nl.input("b");
        let y = nl.and2(a, b);
        nl.output("y", y);
        assert!(lint_netlist(&nl, &LintOptions { pedantic: true }).is_empty());
    }

    #[test]
    fn forward_reference_is_a_cycle() {
        let mut nl = Netlist::new("cyc");
        let a = nl.input("a");
        // and2 whose second fanin points at itself: a 1-cycle.
        let id = nl.push_raw(CellKind::And2.opcode() as u8, [a.0, 2, 0]);
        nl.output("y", id);
        let diags = lint_netlist(&nl, &LintOptions::default());
        assert_eq!(codes(&diags), [UFO001]);
    }

    #[test]
    fn dangling_fanin_and_output() {
        let mut nl = Netlist::new("dangle");
        let a = nl.input("a");
        let id = nl.push_raw(CellKind::Inv.opcode() as u8, [99, 0, 0]);
        nl.output("y", id);
        nl.output("z", crate::ir::NodeId(500));
        let _ = a;
        let diags = lint_netlist(&nl, &LintOptions::default());
        assert_eq!(codes(&diags), [UFO002, UFO002]);
    }

    #[test]
    fn duplicate_output_name() {
        let mut nl = Netlist::new("dup");
        let a = nl.input("a");
        nl.output("y", a);
        nl.output("y", a);
        let diags = lint_netlist(&nl, &LintOptions::default());
        assert_eq!(codes(&diags), [UFO004]);
    }

    #[test]
    fn unknown_opcode_and_corrupt_ordinal() {
        let mut nl = Netlist::new("op");
        let a = nl.input("a");
        nl.output("a", a);
        let _bad = nl.push_raw(42, [0, 0, 0]);
        let _fake_input = nl.push_raw(crate::ir::OP_INPUT, [7, 0, 0]);
        let diags = lint_netlist(&nl, &LintOptions::default());
        assert_eq!(codes(&diags), [UFO005, UFO005]);
    }

    #[test]
    fn pedantic_passes_flag_dead_const_and_duplicate_gates() {
        let mut nl = Netlist::new("pedantic");
        let a = nl.input("a");
        let b = nl.input("b");
        let k = nl.constant(true);
        let dead = nl.xor2(a, b); // never consumed, not an output
        let folded = nl.and2(k, k); // all-constant fanins
        let y1 = nl.or2(a, b);
        let y2 = nl.or2(a, b); // exact duplicate of y1
        nl.output("f", folded);
        nl.output("y1", y1);
        nl.output("y2", y2);
        let _ = dead;
        let quiet = lint_netlist(&nl, &LintOptions::default());
        assert!(quiet.is_empty(), "{quiet:?}");
        let diags = lint_netlist(&nl, &LintOptions { pedantic: true });
        assert_eq!(codes(&diags), [UFO003, UFO006, UFO007]);
    }
}
