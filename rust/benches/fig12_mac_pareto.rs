//! Figure 12 — fused-MAC Pareto frontiers (8/16/32-bit). Paper headline:
//! up to 18.1 % area and 13.9 % delay reduction vs commercial MACs, plus
//! the fused-vs-separate ablation (§2.3: fusion removes an adder stage).

use ufo_mac::baselines::{BaselineBudget, Method};
use ufo_mac::bench::Bench;
use ufo_mac::coordinator::{self, SweepConfig};
use ufo_mac::cpa::PrefixStructure;
use ufo_mac::multiplier::{CpaChoice, MultiplierSpec, Strategy};
use ufo_mac::sta::Sta;

fn main() {
    let bench = Bench::new("fig12_mac_pareto");
    let quick = std::env::var("UFO_BENCH_QUICK").is_ok();
    let widths: Vec<usize> = if quick { vec![8] } else { vec![8, 16, 32] };

    let cfg = SweepConfig {
        widths: widths.clone(),
        methods: Method::ALL.to_vec(),
        strategies: vec![Strategy::AreaDriven, Strategy::TimingDriven, Strategy::TradeOff],
        mac: true,
        budget: BaselineBudget { rlmul_iters: if quick { 6 } else { 30 }, seed: 12 },
        verify_vectors: 1 << 10,
        ..Default::default()
    };
    let points = coordinator::run_sweep(&cfg);
    assert!(points.iter().all(|p| p.verified), "all MACs must be functionally correct");

    println!("\nFigure 12 reproduction: fused-MAC (delay, area) sweep");
    for &n in &widths {
        let subset: Vec<_> = points.iter().filter(|p| p.n == n).cloned().collect();
        for p in &subset {
            println!(
                "  {n:>2}-bit {:<14} {:<12?} {:.4} ns  {:.1} µm²",
                p.method.name(),
                p.strategy,
                p.delay_ns,
                p.area_um2
            );
        }
        let best = |m: Method, f: fn(&coordinator::DesignPoint) -> f64| {
            subset.iter().filter(|p| p.method == m).map(f).fold(f64::INFINITY, f64::min)
        };
        let area_gain = (1.0
            - best(Method::UfoMac, |p| p.area_um2) / best(Method::Commercial, |p| p.area_um2))
            * 100.0;
        let delay_gain = (1.0
            - best(Method::UfoMac, |p| p.delay_ns) / best(Method::Commercial, |p| p.delay_ns))
            * 100.0;
        println!(
            "  {n}-bit UFO-MAC vs commercial MAC: area −{area_gain:.1}% delay −{delay_gain:.1}% \
             (paper: up to 18.1% / 13.9%)"
        );
        bench.metric(&format!("area_gain_pct_{n}"), area_gain, "%");
        bench.metric(&format!("delay_gain_pct_{n}"), delay_gain, "%");
        // UFO-MAC must be at least competitive on delay (ties within 1%
        // happen where both portfolios select the same CPA family and the
        // CT difference is within measurement granularity) and must win
        // at least one axis outright.
        let ufo_d = best(Method::UfoMac, |p| p.delay_ns);
        let com_d = best(Method::Commercial, |p| p.delay_ns);
        let ufo_a = best(Method::UfoMac, |p| p.area_um2);
        let com_a = best(Method::Commercial, |p| p.area_um2);
        assert!(ufo_d <= com_d * 1.01, "{n}-bit: commercial MAC faster by >1%");
        assert!(ufo_a <= com_a * 1.01, "{n}-bit: commercial MAC smaller by >1%");
    }

    // Fusion ablation (the architectural claim behind the MAC gains).
    let sta = Sta { activity_rounds: 0, ..Sta::default() };
    for &n in &widths {
        let fused = MultiplierSpec::new(n)
            .fused_mac(true)
            .cpa(CpaChoice::Regular(PrefixStructure::Sklansky))
            .build()
            .unwrap();
        let sep = MultiplierSpec::new(n)
            .separate_mac(true)
            .cpa(CpaChoice::Regular(PrefixStructure::Sklansky))
            .build()
            .unwrap();
        let rf = sta.analyze(&fused.netlist);
        let rs = sta.analyze(&sep.netlist);
        println!(
            "  fusion ablation {n}-bit: fused {:.4} ns / {:.0} µm²  vs separate {:.4} ns / {:.0} µm²",
            rf.critical_delay_ns, rf.area_um2, rs.critical_delay_ns, rs.area_um2
        );
        bench.metric(
            &format!("fusion_delay_saving_pct_{n}"),
            (1.0 - rf.critical_delay_ns / rs.critical_delay_ns) * 100.0,
            "%",
        );
        assert!(rf.critical_delay_ns < rs.critical_delay_ns);
    }

    bench.bench("build_ufo_mac_8bit", || {
        MultiplierSpec::new(8).fused_mac(true).build().unwrap()
    });
}
