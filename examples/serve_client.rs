//! Minimal client for the `ufo-mac serve` compile service.
//!
//! Start the server in one terminal, then run this in another:
//!
//! ```text
//! cargo run --release --bin ufo-mac -- serve --addr 127.0.0.1:7878
//! cargo run --release --example serve_client -- 127.0.0.1:7878
//! ```
//!
//! It sends the same compile twice plus a `stats` probe, prints the three
//! response lines, and demonstrates the cache doing its job: the second
//! compile answers with `"source":"memory"` (or `"disk"` when the server
//! was restarted over a persistent `--cache-dir`). The wire format is
//! documented in `PROTOCOL.md`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn main() -> std::io::Result<()> {
    let addr = std::env::args().nth(1).unwrap_or_else(|| "127.0.0.1:7878".to_string());
    let mut stream = TcpStream::connect(&addr)?;

    let compile = |id: u32| {
        format!(
            "{{\"cmd\":\"compile\",\"id\":{id},\"request\":{{\"kind\":\"method\",\
             \"method\":\"ufo\",\"n\":16,\"strategy\":\"tradeoff\",\"mac\":false}}}}"
        )
    };
    let requests = [compile(1), compile(2), "{\"cmd\":\"stats\",\"id\":3}".to_string()];
    for line in &requests {
        writeln!(stream, "{line}")?;
    }
    stream.flush()?;

    // Responses arrive in completion order; correlate by "id".
    let reader = BufReader::new(stream.try_clone()?);
    for response in reader.lines().take(requests.len()) {
        println!("{}", response?);
    }
    Ok(())
}
