//! Best-first branch & bound over the simplex LP relaxation.
//!
//! Branches on the most-fractional integer variable, explores nodes in
//! best-LP-bound order (binary heap), seeds an incumbent by rounding the
//! root relaxation, and honours the time limit / node limit / MIP gap in
//! [`super::SolveOptions`] — the same stopping semantics the paper gives
//! Gurobi (3600 s cap with the incumbent returned).
//!
//! With `SolveOptions::threads > 1` the search runs on the coordinator's
//! scoped worker team ([`crate::coordinator::pool::scoped_workers`]):
//! workers share an **atomic incumbent bound** (lock-free pruning reads; a
//! mutex only on improvement) and a **best-bound subproblem queue** with
//! idle-count termination. Each worker dives depth-first on one child of
//! every branching (its private stack) and publishes the sibling for other
//! workers to steal, which keeps the queue hot without serializing on it.
//! Both modes prove the same optimum when run to completion; only the
//! exploration order differs.

use super::simplex::solve_lp;
use super::{Model, Solution, SolveOptions, Status};
use crate::coordinator::pool;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering as AtomicOrdering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

const INT_TOL: f64 = 1e-6;

#[derive(Debug)]
struct BbNode {
    bound: f64,
    /// Extra bounds layered on the base model: (var index, is_upper, value).
    fixes: Vec<(usize, bool, f64)>,
}

impl PartialEq for BbNode {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for BbNode {}
impl PartialOrd for BbNode {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for BbNode {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap on -bound ⇒ best (lowest) bound first.
        other.bound.partial_cmp(&self.bound).unwrap_or(Ordering::Equal)
    }
}

/// Most-fractional branching variable of a relaxation point, if any.
fn pick_branch(int_vars: &[usize], values: &[f64]) -> Option<(usize, f64)> {
    let mut branch: Option<(usize, f64)> = None;
    let mut best_frac = INT_TOL;
    for &vi in int_vars {
        let x = values[vi];
        let frac = (x - x.round()).abs();
        let dist = (x - x.floor()).min(x.ceil() - x);
        if frac > INT_TOL && dist > best_frac {
            best_frac = dist;
            branch = Some((vi, x));
        }
    }
    branch
}

/// Solve a mixed-integer model (serial when `opts.threads <= 1`, else the
/// parallel worker-team search — see the module docs).
pub fn solve_milp(model: &Model, opts: &SolveOptions) -> Solution {
    if opts.threads > 1 {
        return solve_milp_parallel(model, opts);
    }
    let start = Instant::now();
    let int_vars: Vec<usize> =
        model.vars.iter().enumerate().filter(|(_, v)| v.integer).map(|(i, _)| i).collect();

    let mut work = model.clone();
    let root = solve_lp(&work);
    match root.status {
        Status::Infeasible => return root,
        Status::Unbounded => return root,
        _ => {}
    }

    let mut incumbent: Option<Solution> = None;
    // Rounding heuristic on the root relaxation.
    if let Some(r) = round_heuristic(model, &root.values) {
        incumbent = Some(r);
    }

    let mut heap = BinaryHeap::new();
    heap.push(BbNode { bound: root.objective, fixes: vec![] });
    let mut nodes = 0u64;
    let mut best_bound = root.objective;

    while let Some(node) = heap.pop() {
        nodes += 1;
        best_bound = node.bound;
        if nodes > opts.max_nodes || start.elapsed() > opts.time_limit {
            break;
        }
        if let Some(inc) = &incumbent {
            let gap = (inc.objective - node.bound).abs() / inc.objective.abs().max(1.0);
            if node.bound >= inc.objective - INT_TOL || gap <= opts.mip_gap {
                // Heap is bound-ordered: nothing better remains.
                best_bound = node.bound;
                break;
            }
        }

        // Apply fixes to a scratch copy of the bounds.
        for (vi, is_upper, val) in &node.fixes {
            if *is_upper {
                work.vars[*vi].ub = work.vars[*vi].ub.min(*val);
            } else {
                work.vars[*vi].lb = work.vars[*vi].lb.max(*val);
            }
        }
        let relax = solve_lp(&work);
        // Restore bounds.
        for (vi, _, _) in &node.fixes {
            work.vars[*vi].lb = model.vars[*vi].lb;
            work.vars[*vi].ub = model.vars[*vi].ub;
        }

        if relax.status != Status::Optimal {
            continue;
        }
        if let Some(inc) = &incumbent {
            if relax.objective >= inc.objective - INT_TOL {
                continue;
            }
        }

        match pick_branch(&int_vars, &relax.values) {
            None => {
                // Integral ⇒ candidate incumbent.
                let better = incumbent
                    .as_ref()
                    .map_or(true, |inc| relax.objective < inc.objective - INT_TOL);
                if better {
                    incumbent = Some(Solution { status: Status::Feasible, ..relax });
                }
            }
            Some((vi, x)) => {
                let mut down = node.fixes.clone();
                down.push((vi, true, x.floor()));
                let mut up = node.fixes.clone();
                up.push((vi, false, x.ceil()));
                heap.push(BbNode { bound: relax.objective, fixes: down });
                heap.push(BbNode { bound: relax.objective, fixes: up });
            }
        }
    }

    match incumbent {
        Some(mut inc) => {
            // Snap integers exactly.
            for &vi in &int_vars {
                inc.values[vi] = inc.values[vi].round();
            }
            inc.objective = model.objective.eval(&inc.values);
            let proven = heap
                .peek()
                .map_or(true, |n| n.bound >= inc.objective - INT_TOL)
                && nodes <= opts.max_nodes
                && start.elapsed() <= opts.time_limit;
            inc.status = if proven { Status::Optimal } else { Status::Feasible };
            inc.nodes = nodes;
            let _ = best_bound;
            inc
        }
        None => Solution {
            status: if start.elapsed() > opts.time_limit {
                Status::TimeLimit
            } else {
                Status::Infeasible
            },
            objective: f64::INFINITY,
            values: vec![0.0; model.vars.len()],
            nodes,
        },
    }
}

// ---------------------------------------------------------------------------
// Parallel search
// ---------------------------------------------------------------------------

/// Shared best-bound subproblem queue with idle-count termination.
struct SharedQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
    workers: usize,
}

struct QueueState {
    heap: BinaryHeap<BbNode>,
    idle: usize,
    done: bool,
}

impl SharedQueue {
    fn new(workers: usize) -> Self {
        SharedQueue {
            state: Mutex::new(QueueState { heap: BinaryHeap::new(), idle: 0, done: false }),
            cv: Condvar::new(),
            workers,
        }
    }

    fn push(&self, node: BbNode) {
        self.state.lock().unwrap().heap.push(node);
        self.cv.notify_one();
    }

    /// Pop the best-bound subproblem, blocking while other workers may
    /// still produce work. Returns `None` once every worker is idle with
    /// an empty queue (search exhausted) or after [`SharedQueue::close`].
    fn pop(&self) -> Option<BbNode> {
        let mut q = self.state.lock().unwrap();
        loop {
            if q.done {
                return None;
            }
            if let Some(n) = q.heap.pop() {
                return Some(n);
            }
            q.idle += 1;
            if q.idle == self.workers {
                q.done = true;
                self.cv.notify_all();
                return None;
            }
            q = self.cv.wait(q).unwrap();
            q.idle -= 1;
        }
    }

    /// Terminate the search (limits hit): wake and drain every worker.
    fn close(&self) {
        self.state.lock().unwrap().done = true;
        self.cv.notify_all();
    }
}

/// Shared incumbent: the objective doubles as an atomic for lock-free
/// pruning reads; the full solution sits behind a mutex taken only on
/// improvement.
struct SharedIncumbent {
    best: Mutex<Option<Solution>>,
    objective_bits: AtomicU64,
}

impl SharedIncumbent {
    fn new(seed: Option<Solution>) -> Self {
        let bits = seed.as_ref().map_or(f64::INFINITY, |s| s.objective).to_bits();
        SharedIncumbent { best: Mutex::new(seed), objective_bits: AtomicU64::new(bits) }
    }

    fn objective(&self) -> f64 {
        f64::from_bits(self.objective_bits.load(AtomicOrdering::Acquire))
    }

    fn offer(&self, sol: Solution) {
        let mut best = self.best.lock().unwrap();
        if best.as_ref().map_or(true, |b| sol.objective < b.objective - INT_TOL) {
            self.objective_bits.store(sol.objective.to_bits(), AtomicOrdering::Release);
            *best = Some(sol);
        }
    }
}

/// The parallel worker-team search behind [`solve_milp`].
fn solve_milp_parallel(model: &Model, opts: &SolveOptions) -> Solution {
    let start = Instant::now();
    let int_vars: Vec<usize> =
        model.vars.iter().enumerate().filter(|(_, v)| v.integer).map(|(i, _)| i).collect();

    let root = solve_lp(model);
    match root.status {
        Status::Infeasible | Status::Unbounded => return root,
        _ => {}
    }
    let incumbent = SharedIncumbent::new(round_heuristic(model, &root.values));

    let workers = opts.threads.max(2);
    let queue = SharedQueue::new(workers);
    queue.push(BbNode { bound: root.objective, fixes: vec![] });
    let node_count = AtomicU64::new(0);
    let limit_hit = AtomicBool::new(false);
    // Set when a node is discarded *only* because it fell inside the MIP
    // gap (its bound was still strictly better than the incumbent): the
    // search then ends within tolerance but without an optimality proof,
    // mirroring the serial solver's `proven` check.
    let gap_pruned = AtomicBool::new(false);

    pool::scoped_workers(workers, |_w| {
        // Thread-local scratch model: fixes are layered onto its bounds
        // and restored after each LP, exactly as in the serial search.
        let mut work = model.clone();
        // Private dive stack: one child of every branching stays local
        // (depth-first descent toward integral leaves), the sibling goes
        // to the shared queue for stealing.
        let mut local: Vec<BbNode> = Vec::new();
        loop {
            if limit_hit.load(AtomicOrdering::Relaxed) {
                break;
            }
            let node = match local.pop() {
                Some(n) => n,
                None => match queue.pop() {
                    Some(n) => n,
                    None => break,
                },
            };
            let seen = node_count.fetch_add(1, AtomicOrdering::Relaxed) + 1;
            if seen > opts.max_nodes || start.elapsed() > opts.time_limit {
                limit_hit.store(true, AtomicOrdering::Relaxed);
                queue.close();
                break;
            }
            // Prune against the shared incumbent before paying for an LP.
            let inc_obj = incumbent.objective();
            if inc_obj.is_finite() {
                if node.bound >= inc_obj - INT_TOL {
                    continue;
                }
                let gap = (inc_obj - node.bound).abs() / inc_obj.abs().max(1.0);
                if gap <= opts.mip_gap {
                    gap_pruned.store(true, AtomicOrdering::Relaxed);
                    continue;
                }
            }

            for (vi, is_upper, val) in &node.fixes {
                if *is_upper {
                    work.vars[*vi].ub = work.vars[*vi].ub.min(*val);
                } else {
                    work.vars[*vi].lb = work.vars[*vi].lb.max(*val);
                }
            }
            let relax = solve_lp(&work);
            for (vi, _, _) in &node.fixes {
                work.vars[*vi].lb = model.vars[*vi].lb;
                work.vars[*vi].ub = model.vars[*vi].ub;
            }

            if relax.status != Status::Optimal {
                continue;
            }
            if relax.objective >= incumbent.objective() - INT_TOL {
                continue;
            }

            match pick_branch(&int_vars, &relax.values) {
                None => incumbent.offer(Solution { status: Status::Feasible, ..relax }),
                Some((vi, x)) => {
                    let mut down = node.fixes.clone();
                    down.push((vi, true, x.floor()));
                    let mut up = node.fixes.clone();
                    up.push((vi, false, x.ceil()));
                    local.push(BbNode { bound: relax.objective, fixes: down });
                    queue.push(BbNode { bound: relax.objective, fixes: up });
                }
            }
        }
    });

    let nodes = node_count.load(AtomicOrdering::Relaxed);
    let limited = limit_hit.load(AtomicOrdering::Relaxed);
    match incumbent.best.into_inner().unwrap() {
        Some(mut inc) => {
            for &vi in &int_vars {
                inc.values[vi] = inc.values[vi].round();
            }
            inc.objective = model.objective.eval(&inc.values);
            // Optimality is proven only when the queue drained with every
            // open node pruned against the incumbent *bound* — a limit hit
            // or a gap-window prune leaves the incumbent merely Feasible,
            // exactly as the serial solver's `proven` check does.
            let proven = !limited && !gap_pruned.load(AtomicOrdering::Relaxed);
            inc.status = if proven { Status::Optimal } else { Status::Feasible };
            inc.nodes = nodes;
            inc
        }
        None => Solution {
            status: if limited { Status::TimeLimit } else { Status::Infeasible },
            objective: f64::INFINITY,
            values: vec![0.0; model.vars.len()],
            nodes,
        },
    }
}

/// Try rounding a fractional point to a feasible integral one.
fn round_heuristic(model: &Model, x: &[f64]) -> Option<Solution> {
    let mut cand = x.to_vec();
    for (i, v) in model.vars.iter().enumerate() {
        if v.integer {
            cand[i] = cand[i].round().clamp(v.lb, v.ub);
        }
    }
    if model.is_feasible(&cand, 1e-6) {
        let objective = model.objective.eval(&cand);
        Some(Solution { status: Status::Feasible, objective, values: cand, nodes: 0 })
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ilp::{solve, LinExpr, Model, Sense, SolveOptions};

    #[test]
    fn knapsack_small() {
        // max 10a + 13b + 7c, 3a + 4b + 2c <= 6, binary → a=0,b=1,c=1 (20).
        let mut m = Model::new();
        let a = m.bin("a");
        let b = m.bin("b");
        let c = m.bin("c");
        m.constrain(LinExpr::of(&[(a, 3.0), (b, 4.0), (c, 2.0)]), Sense::Le, 6.0);
        m.minimize(LinExpr::of(&[(a, -10.0), (b, -13.0), (c, -7.0)]));
        let s = solve(&m, &SolveOptions::default());
        assert!(s.ok());
        assert!((s.objective + 20.0).abs() < 1e-6, "obj {}", s.objective);
        assert_eq!(s.int_value(b), 1);
        assert_eq!(s.int_value(c), 1);
    }

    #[test]
    fn integer_rounding_matters() {
        // max x + y s.t. 2x + 3y <= 12, 3x + 2y <= 12, int → LP opt (2.4,2.4)
        // obj 4.8; IP opt obj 4 (e.g. 2,2 or 0,4... 3y<=12 → (0,4): 3*0+2*4=8 ok → obj 4).
        let mut m = Model::new();
        let x = m.int("x", 0.0, 10.0);
        let y = m.int("y", 0.0, 10.0);
        m.constrain(LinExpr::of(&[(x, 2.0), (y, 3.0)]), Sense::Le, 12.0);
        m.constrain(LinExpr::of(&[(x, 3.0), (y, 2.0)]), Sense::Le, 12.0);
        m.minimize(LinExpr::of(&[(x, -1.0), (y, -1.0)]));
        let s = solve(&m, &SolveOptions::default());
        assert!(s.ok());
        assert!((s.objective + 4.0).abs() < 1e-6, "obj {}", s.objective);
    }

    #[test]
    fn infeasible_ip() {
        let mut m = Model::new();
        let x = m.int("x", 0.0, 1.0);
        let y = m.int("y", 0.0, 1.0);
        // x + y = 1 and x + y >= 2 conflict.
        m.constrain(LinExpr::of(&[(x, 1.0), (y, 1.0)]), Sense::Eq, 1.0);
        m.constrain(LinExpr::of(&[(x, 1.0), (y, 1.0)]), Sense::Ge, 2.0);
        m.minimize(LinExpr::of(&[(x, 1.0)]));
        assert_eq!(solve(&m, &SolveOptions::default()).status, Status::Infeasible);
    }

    #[test]
    fn big_m_indicator_pattern() {
        // The §3.3 pattern: minimize S with S >= i*y_i, M*y_i >= load_i.
        let mut m = Model::new();
        let s = m.cont("S", 0.0, 100.0);
        let mut obj = LinExpr::new();
        obj.add(s, 1.0);
        for i in 0..5 {
            let y = m.bin(format!("y{i}"));
            let load = m.int(format!("f{i}"), 0.0, 10.0);
            // stage i carries load 2 when i <= 2 else 0 (forced).
            m.constrain(LinExpr::of(&[(load, 1.0)]), Sense::Eq, if i <= 2 { 2.0 } else { 0.0 });
            m.constrain(LinExpr::of(&[(load, 1.0), (y, -100.0)]), Sense::Le, 0.0);
            m.constrain(LinExpr::of(&[(s, 1.0), (y, -(i as f64))]), Sense::Ge, 0.0);
        }
        m.minimize(obj);
        let sol = solve(&m, &SolveOptions::default());
        assert!(sol.ok());
        assert!((sol.value(s) - 2.0).abs() < 1e-5, "S={}", sol.value(s));
    }

    #[test]
    fn parallel_matches_serial_objective() {
        // A knapsack with enough branching to keep several workers busy.
        let build = || {
            let mut m = Model::new();
            let mut cap = LinExpr::new();
            let mut obj = LinExpr::new();
            for i in 0..14 {
                let v = m.bin(format!("b{i}"));
                cap.add(v, 1.0 + (i as f64 * 0.37) % 3.0);
                obj.add(v, -(1.0 + (i as f64 * 0.91) % 5.0));
            }
            m.constrain(cap, Sense::Le, 9.0);
            m.minimize(obj);
            m
        };
        let serial = solve(&build(), &SolveOptions::default());
        let parallel = solve(&build(), &SolveOptions::default().with_threads(4));
        assert!(serial.ok() && parallel.ok());
        assert_eq!(serial.status, Status::Optimal);
        assert_eq!(parallel.status, Status::Optimal);
        assert!(
            (serial.objective - parallel.objective).abs() < 1e-6,
            "serial {} vs parallel {}",
            serial.objective,
            parallel.objective
        );
    }

    #[test]
    fn parallel_detects_infeasible_and_integral_root() {
        // IP-infeasible (LP relaxation feasible): 2x + 2y = 3 over ints.
        let mut m = Model::new();
        let x = m.int("x", 0.0, 3.0);
        let y = m.int("y", 0.0, 3.0);
        m.constrain(LinExpr::of(&[(x, 2.0), (y, 2.0)]), Sense::Eq, 3.0);
        m.minimize(LinExpr::of(&[(x, 1.0), (y, 1.0)]));
        assert_eq!(solve(&m, &SolveOptions::default().with_threads(3)).status, Status::Infeasible);

        // Integral root relaxation: solved without any branching.
        let mut m2 = Model::new();
        let z = m2.int("z", 0.0, 5.0);
        m2.constrain(LinExpr::of(&[(z, 1.0)]), Sense::Le, 3.0);
        m2.minimize(LinExpr::of(&[(z, -1.0)]));
        let s = solve(&m2, &SolveOptions::default().with_threads(3));
        assert!(s.ok());
        assert_eq!(s.int_value(z), 3);
    }

    #[test]
    fn respects_time_limit() {
        // A 12-var knapsack-ish IP with a 0 ms budget returns quickly.
        let mut m = Model::new();
        let mut cap = LinExpr::new();
        let mut obj = LinExpr::new();
        for i in 0..12 {
            let v = m.bin(format!("b{i}"));
            cap.add(v, 1.0 + (i as f64 * 0.37) % 3.0);
            obj.add(v, -(1.0 + (i as f64 * 0.91) % 5.0));
        }
        m.constrain(cap, Sense::Le, 7.0);
        m.minimize(obj);
        let opts = SolveOptions {
            time_limit: std::time::Duration::from_millis(0),
            ..Default::default()
        };
        let t = Instant::now();
        let _ = solve(&m, &opts);
        assert!(t.elapsed() < std::time::Duration::from_secs(5));
    }
}
