//! Minimal std-only thread pool (the image vendors no async runtime).
//!
//! Three fan-out shapes:
//!
//! - [`run_jobs`] / [`par_map`] — `'static` jobs, results in completion
//!   order;
//! - [`par_map_scoped`] — borrowed closures, results in input order (the
//!   `SynthEngine::compile_batch` fan-out);
//! - [`scoped_workers`] — a *worker team*: `n` scoped threads all running
//!   one borrowed closure against shared state until it returns. This is
//!   the substrate for the parallel branch-and-bound search in
//!   [`crate::ilp::branch_bound`], where workers pull subproblems from a
//!   shared best-bound queue rather than from a pre-split job list, and
//!   for the compile service's request loop
//!   ([`crate::server::Server::serve`]), where worker 0 reads
//!   newline-delimited JSON and workers 1..=N run jobs popped from a
//!   shared priority scheduler ([`crate::server::sched`]).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job<T> = Box<dyn FnOnce() -> T + Send + 'static>;

/// Run `jobs` on `workers` threads, returning results in completion order.
pub fn run_jobs<T: Send + 'static>(workers: usize, jobs: Vec<Job<T>>) -> Vec<T> {
    let workers = workers.max(1);
    let queue = Arc::new(Mutex::new(jobs));
    let (tx, rx) = mpsc::channel();
    let mut handles = Vec::new();
    for _ in 0..workers {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        handles.push(thread::spawn(move || loop {
            let job = { queue.lock().unwrap().pop() };
            match job {
                Some(j) => {
                    // A panicking job poisons nothing: catch and skip.
                    if let Ok(v) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(j)) {
                        let _ = tx.send(v);
                    }
                }
                None => break,
            }
        }));
    }
    drop(tx);
    let results: Vec<T> = rx.into_iter().collect();
    for h in handles {
        let _ = h.join();
    }
    results
}

/// Convenience: map a function over items in parallel.
pub fn par_map<I, T, F>(workers: usize, items: Vec<I>, f: F) -> Vec<T>
where
    I: Send + 'static,
    T: Send + 'static,
    F: Fn(I) -> T + Send + Sync + Clone + 'static,
{
    let jobs: Vec<Job<T>> = items
        .into_iter()
        .map(|item| {
            let f = f.clone();
            Box::new(move || f(item)) as Job<T>
        })
        .collect();
    run_jobs(workers, jobs)
}

/// Scoped parallel map: `f` and its captures are *borrowed* (no `'static`
/// bound), and results come back in **input order**. This is the fan-out
/// used by `api::SynthEngine::compile_batch`, which borrows the engine
/// (cache, cell library) across the workers.
///
/// Unlike [`par_map`], a panic in `f` propagates out of the scope (the
/// 1:1 input→output mapping leaves no slot to skip) — callers that need
/// containment catch around `f` itself, as `compile_batch` does.
pub fn par_map_scoped<I, T, F>(workers: usize, items: Vec<I>, f: F) -> Vec<T>
where
    I: Send,
    T: Send,
    F: Fn(I) -> T + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    // LIFO queue of (input index, item); indices restore order at the end.
    let queue: Mutex<Vec<(usize, I)>> = Mutex::new(items.into_iter().enumerate().collect());
    let results: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let next = { queue.lock().unwrap().pop() };
                match next {
                    Some((i, item)) => {
                        let v = f(item);
                        results.lock().unwrap().push((i, v));
                    }
                    None => break,
                }
            });
        }
    });
    let mut out = results.into_inner().unwrap();
    out.sort_by_key(|(i, _)| *i);
    out.into_iter().map(|(_, v)| v).collect()
}

/// Run `workers` scoped threads, each executing `f(worker_index)` once
/// over borrowed shared state, and join them all before returning.
///
/// Unlike [`par_map_scoped`] there is no job list: the closure is expected
/// to loop over some shared work source (a queue, a deque, an atomic
/// cursor) until it is drained. A panicking worker propagates after the
/// scope joins, as with any scoped thread.
pub fn scoped_workers<F>(workers: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let workers = workers.max(1);
    if workers == 1 {
        f(0);
        return;
    }
    std::thread::scope(|s| {
        for w in 0..workers {
            let f = &f;
            s.spawn(move || f(w));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_jobs() {
        let mut out = par_map(4, (0..100).collect::<Vec<i32>>(), |x| x * 2);
        out.sort();
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_works() {
        let out = par_map(1, vec![1, 2, 3], |x| x + 1);
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn scoped_map_preserves_order_and_borrows() {
        let offset = 100; // borrowed by the closure — no 'static needed
        let out = par_map_scoped(4, (0..64).collect::<Vec<i32>>(), |x| x + offset);
        assert_eq!(out, (100..164).collect::<Vec<_>>());
        assert!(par_map_scoped(3, Vec::<i32>::new(), |x| x).is_empty());
    }

    #[test]
    fn scoped_workers_drain_a_shared_queue() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let queue: Mutex<Vec<usize>> = Mutex::new((0..100).collect());
        let sum = AtomicUsize::new(0);
        scoped_workers(4, |_w| loop {
            let item = { queue.lock().unwrap().pop() };
            match item {
                Some(x) => {
                    sum.fetch_add(x, Ordering::Relaxed);
                }
                None => break,
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn panicking_job_is_skipped() {
        let out = par_map(2, vec![0, 1, 2, 3], |x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
        assert_eq!(out.len(), 3);
    }
}
