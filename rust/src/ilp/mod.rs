//! From-scratch MILP solver.
//!
//! The paper drives both of its optimization passes (compressor-tree stage
//! assignment, §3.3, and interconnection-order optimization, §3.5) with
//! Gurobi. This module is the in-repo substitute: a dense primal simplex for
//! LP relaxations ([`simplex`]), a best-first branch-and-bound wrapper for
//! integrality ([`branch_bound`]), and an exact bottleneck-assignment solver
//! ([`assignment`]) for the per-slice interconnect permutation problem
//! (which is an assignment polytope and deserves a combinatorial algorithm
//! rather than a tableau).
//!
//! The public surface is the [`Model`] builder + [`solve`].

pub mod assignment;
pub mod branch_bound;
pub mod simplex;



/// Variable handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Var(pub usize);

/// Constraint sense.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sense {
    Le,
    Ge,
    Eq,
}

/// A linear expression `Σ coef·var`.
#[derive(Debug, Clone, Default)]
pub struct LinExpr {
    pub terms: Vec<(Var, f64)>,
}

impl LinExpr {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn term(mut self, v: Var, c: f64) -> Self {
        self.terms.push((v, c));
        self
    }
    pub fn add(&mut self, v: Var, c: f64) -> &mut Self {
        self.terms.push((v, c));
        self
    }
    pub fn of(terms: &[(Var, f64)]) -> Self {
        LinExpr { terms: terms.to_vec() }
    }
    /// Evaluate against a solution vector.
    pub fn eval(&self, x: &[f64]) -> f64 {
        self.terms.iter().map(|(v, c)| c * x[v.0]).sum()
    }
}

#[derive(Debug, Clone)]
pub struct VarDef {
    pub name: String,
    pub lb: f64,
    pub ub: f64,
    pub integer: bool,
}

#[derive(Debug, Clone)]
pub struct Constraint {
    pub expr: LinExpr,
    pub sense: Sense,
    pub rhs: f64,
}

/// MILP model builder (minimization).
#[derive(Debug, Clone, Default)]
pub struct Model {
    pub vars: Vec<VarDef>,
    pub cons: Vec<Constraint>,
    pub objective: LinExpr,
}

impl Model {
    pub fn new() -> Self {
        Self::default()
    }

    /// Continuous variable in `[lb, ub]` (`ub` may be `f64::INFINITY`).
    pub fn cont(&mut self, name: impl Into<String>, lb: f64, ub: f64) -> Var {
        self.vars.push(VarDef { name: name.into(), lb, ub, integer: false });
        Var(self.vars.len() - 1)
    }

    /// Integer variable in `[lb, ub]`.
    pub fn int(&mut self, name: impl Into<String>, lb: f64, ub: f64) -> Var {
        self.vars.push(VarDef { name: name.into(), lb, ub, integer: true });
        Var(self.vars.len() - 1)
    }

    /// Binary variable.
    pub fn bin(&mut self, name: impl Into<String>) -> Var {
        self.int(name, 0.0, 1.0)
    }

    pub fn constrain(&mut self, expr: LinExpr, sense: Sense, rhs: f64) {
        self.cons.push(Constraint { expr, sense, rhs });
    }

    /// Set the (minimization) objective.
    pub fn minimize(&mut self, expr: LinExpr) {
        self.objective = expr;
    }

    pub fn num_vars(&self) -> usize {
        self.vars.len()
    }
    pub fn num_cons(&self) -> usize {
        self.cons.len()
    }

    /// Check a candidate point against all constraints/bounds.
    pub fn is_feasible(&self, x: &[f64], tol: f64) -> bool {
        for (i, v) in self.vars.iter().enumerate() {
            if x[i] < v.lb - tol || x[i] > v.ub + tol {
                return false;
            }
            if v.integer && (x[i] - x[i].round()).abs() > tol {
                return false;
            }
        }
        self.cons.iter().all(|c| {
            let lhs = c.expr.eval(x);
            match c.sense {
                Sense::Le => lhs <= c.rhs + tol,
                Sense::Ge => lhs >= c.rhs - tol,
                Sense::Eq => (lhs - c.rhs).abs() <= tol,
            }
        })
    }
}

/// Solve status.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    Optimal,
    /// Feasible incumbent returned but optimality not proven (time limit).
    Feasible,
    Infeasible,
    Unbounded,
    /// No incumbent found within the time limit.
    TimeLimit,
}

/// Solution returned by the solvers.
#[derive(Debug, Clone)]
pub struct Solution {
    pub status: Status,
    pub objective: f64,
    pub values: Vec<f64>,
    /// Branch-and-bound nodes explored (0 for pure LPs).
    pub nodes: u64,
}

impl Solution {
    pub fn value(&self, v: Var) -> f64 {
        self.values[v.0]
    }
    pub fn int_value(&self, v: Var) -> i64 {
        self.values[v.0].round() as i64
    }
    pub fn ok(&self) -> bool {
        matches!(self.status, Status::Optimal | Status::Feasible)
    }
}

/// Solver knobs.
#[derive(Debug, Clone, Copy)]
pub struct SolveOptions {
    pub time_limit: std::time::Duration,
    /// Relative MIP gap at which B&B stops.
    pub mip_gap: f64,
    pub max_nodes: u64,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            time_limit: std::time::Duration::from_secs(60),
            mip_gap: 1e-6,
            max_nodes: 2_000_000,
        }
    }
}

/// Solve a model: pure LP via simplex, MILP via branch & bound.
pub fn solve(model: &Model, opts: &SolveOptions) -> Solution {
    if model.vars.iter().any(|v| v.integer) {
        branch_bound::solve_milp(model, opts)
    } else {
        simplex::solve_lp(model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_builder_and_feasibility() {
        let mut m = Model::new();
        let x = m.cont("x", 0.0, 10.0);
        let y = m.int("y", 0.0, 5.0);
        m.constrain(LinExpr::of(&[(x, 1.0), (y, 2.0)]), Sense::Le, 8.0);
        m.minimize(LinExpr::of(&[(x, -1.0), (y, -1.0)]));
        assert!(m.is_feasible(&[2.0, 3.0], 1e-9));
        assert!(!m.is_feasible(&[2.0, 3.5], 1e-9)); // fractional integer
        assert!(!m.is_feasible(&[9.0, 0.0], 1e-9)); // violates constraint? 9 <= 8 no
    }
}
