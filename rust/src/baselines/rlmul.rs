//! RL-MUL baseline (Zuo et al., DAC'23), reproduced as the same search
//! space driven by simulated annealing.
//!
//! RL-MUL's agent edits the per-column compressor counts of the tree. With
//! the two-output constraint, a count vector is fully determined by the
//! per-column output-row choice `o_j ∈ {1, 2}` (plus parity fix-up), so the
//! search space *is* the `o` vector; the RL policy and our annealer walk the
//! same space with the same cost signal (model-estimated delay + area).
//! The CPA is a synthesis-tool default (Brent-Kung), matching the paper's
//! note that RL-MUL leaves the adder to the tool.

use crate::ct::{assign_greedy, CtCounts, StagePlan};
use crate::ir::CellLib;
use crate::synth::{CompressorTiming, Sig};
use crate::util::Rng;

/// Derive per-column counts from initial populations and an output-row
/// choice vector `o` (1 or 2 outputs per column).
pub fn counts_from_outputs(pp: &[usize], o: &[usize]) -> CtCounts {
    let mut initial = pp.to_vec();
    let mut f = Vec::new();
    let mut h = Vec::new();
    let mut carry = 0usize;
    let mut j = 0usize;
    while j < initial.len() || carry > 0 {
        if j >= initial.len() {
            initial.push(0);
        }
        let total = initial[j] + carry;
        let target = o.get(j).copied().unwrap_or(2).clamp(1, 2).min(total.max(1));
        let (fj, hj) = if total <= target {
            (0, 0)
        } else if (total - target) % 2 == 0 {
            ((total - target) / 2, 0)
        } else {
            ((total - target - 1) / 2, 1)
        };
        f.push(fj);
        h.push(hj);
        carry = fj + hj;
        j += 1;
    }
    CtCounts { initial, f, h }
}

/// Cost of a candidate: model-estimated CT delay (ns) + λ·area-metric.
///
/// Scored through [`StagePlan::timing_with_arrivals`] — the stage plan's
/// precomputed arrival snapshot — instead of dry-running the candidate
/// tree into a scratch netlist, so the annealer's inner loop instantiates
/// no gates at all.
fn evaluate(pp_columns: &[Vec<Sig>], counts: &CtCounts, lambda: f64, tm: &CompressorTiming) -> f64 {
    let plan = assign_greedy(counts);
    let pops: Vec<usize> = pp_columns.iter().map(|c| c.len()).collect();
    let arrivals: Vec<f64> = pp_columns
        .iter()
        .map(|c| c.iter().map(|s| s.t).fold(0.0f64, f64::max))
        .collect();
    let st = plan.timing_with_arrivals(&pops, &arrivals, tm);
    let worst = st.final_profile().iter().copied().fold(0.0f64, f64::max);
    worst + lambda * counts.area_metric() as f64
}

/// Result of the annealing search.
#[derive(Debug, Clone)]
pub struct RlMulResult {
    /// Best stage plan found.
    pub plan: StagePlan,
    /// Compressor counts of the searched tree.
    pub counts: CtCounts,
    /// Cost of the best plan under the search objective.
    pub cost: f64,
    /// Candidate evaluations performed.
    pub evals: usize,
}

/// Search the output-row space with simulated annealing (the RL-MUL
/// action space under our compute budget).
pub fn search(pp_columns: &[Vec<Sig>], budget: usize, seed: u64) -> RlMulResult {
    let pp: Vec<usize> = pp_columns.iter().map(|c| c.len()).collect();
    let mut rng = Rng::seed_from_u64(seed);
    let w = pp.len() + 2;
    let lambda = 1e-4; // delay-dominant cost, area as a tie-breaker
    let tm = CompressorTiming::from_lib(&CellLib::nangate45());

    let mut cur: Vec<usize> = vec![2; w];
    let mut cur_counts = counts_from_outputs(&pp, &cur);
    let mut cur_cost = evaluate(pp_columns, &cur_counts, lambda, &tm);
    let mut best = cur.clone();
    let mut best_counts = cur_counts.clone();
    let mut best_cost = cur_cost;
    let mut evals = 1usize;

    let t0 = 0.05f64;
    for step in 0..budget {
        let temp = t0 * (1.0 - step as f64 / budget.max(1) as f64) + 1e-4;
        let mut cand = cur.clone();
        let j = rng.index(w);
        cand[j] = if cand[j] == 2 { 1 } else { 2 };
        let cand_counts = counts_from_outputs(&pp, &cand);
        // Always-on cheap lint subset: infeasible candidates (UFO103
        // class) are skipped before the cost model is paid for.
        if !crate::lint::check_counts(&cand_counts).is_empty() {
            continue;
        }
        let cand_cost = evaluate(pp_columns, &cand_counts, lambda, &tm);
        evals += 1;
        let accept = cand_cost < cur_cost
            || rng.f64() < (-(cand_cost - cur_cost) / temp.max(1e-9)).exp();
        if accept {
            cur = cand;
            cur_counts = cand_counts;
            cur_cost = cand_cost;
            if cur_cost < best_cost {
                best = cur.clone();
                best_counts = cur_counts.clone();
                best_cost = cur_cost;
            }
        }
    }
    let _ = best;
    RlMulResult { plan: assign_greedy(&best_counts), counts: best_counts, cost: best_cost, evals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{CellLib, Netlist};

    fn pp_sigs(n: usize) -> Vec<Vec<Sig>> {
        let lib = CellLib::nangate45();
        let mut nl = Netlist::new("pp");
        let a: Vec<_> = (0..n).map(|i| nl.input(format!("a{i}"))).collect();
        let b: Vec<_> = (0..n).map(|i| nl.input(format!("b{i}"))).collect();
        crate::ppg::and_array(&mut nl, &lib, &a, &b).columns
    }

    #[test]
    fn counts_from_outputs_all_two_matches_algorithm_1() {
        let pp: Vec<usize> = (0..15).map(|j| 8usize.min(j + 1).min(15 - j)).collect();
        let o = vec![2usize; pp.len() + 2];
        let c = counts_from_outputs(&pp, &o);
        let alg1 = CtCounts::from_populations(&pp);
        assert_eq!(c.f, alg1.f);
        assert_eq!(c.h, alg1.h);
    }

    #[test]
    fn counts_from_outputs_single_row_valid() {
        let pp = vec![1usize, 2, 3, 4, 3, 2, 1];
        let o = vec![1usize; 10];
        let c = counts_from_outputs(&pp, &o);
        // o=1 compresses harder; every column ends with ≤ 2 (here 1).
        for j in 0..c.width() {
            let total = c.initial[j] + c.carries_into(j);
            let out = total + 0 - 2 * c.f[j] - c.h[j];
            assert!(out <= 2, "col {j}: {out}");
        }
    }

    #[test]
    fn search_returns_valid_plan_and_improves_or_matches_start() {
        let cols = pp_sigs(8);
        let res = search(&cols, 24, 7);
        res.plan.validate(&res.counts).unwrap();
        assert!(res.evals >= 1);
        // cost of the all-2 start
        let pp: Vec<usize> = cols.iter().map(|c| c.len()).collect();
        let start = counts_from_outputs(&pp, &vec![2; pp.len() + 2]);
        let tm = CompressorTiming::from_lib(&CellLib::nangate45());
        let start_cost = evaluate(&cols, &start, 1e-4, &tm);
        assert!(res.cost <= start_cost + 1e-9);
    }
}
