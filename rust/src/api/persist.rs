//! On-disk serialization of compiled [`DesignArtifact`]s — the persistent
//! tier of the content-addressed design cache.
//!
//! Every entry is one JSON file named after the request fingerprint,
//! wrapped in a versioned, checksummed envelope (see `PROTOCOL.md` at the
//! repository root for the byte-level contract):
//!
//! ```json
//! {
//!   "magic": "ufo-mac-design-cache",
//!   "version": 1,
//!   "fingerprint": "<32 hex digits>",
//!   "checksum": "<32 hex digits>",
//!   "artifact": { "...": "the serialized DesignArtifact" }
//! }
//! ```
//!
//! The checksum is the same FNV-128 hash the request fingerprints use
//! ([`Fingerprint::of_bytes`]), computed over the rendered `artifact`
//! subtree. [`Json`] renders objects with sorted keys and shortest
//! round-tripping floats, so render → parse → render is byte-identical and
//! the checksum can be re-verified after parsing.
//!
//! Recovery semantics: [`read_entry`] fails (and the caller falls back to
//! recompute) on *any* defect — unreadable file, malformed JSON, wrong
//! magic, version or fingerprint mismatch, checksum mismatch, or a payload
//! that no longer deserializes. The next [`write_entry`] for the same
//! fingerprint atomically replaces the damaged file (write to a unique
//! temp name, then rename), so concurrent writers never interleave bytes
//! and readers never observe a half-written entry.

use super::engine::{ArtifactBody, DesignArtifact};
use super::request::{DesignRequest, Fingerprint};
use crate::analysis::AnalysisReport;
use crate::ir::{CellKind, Netlist, Node, NodeId};
use crate::lint::LintReport;
use crate::modules::ModuleReport;
use crate::multiplier::{Design, PipelineInfo};
use crate::ppg::{OperandFormat, Signedness};
use crate::sta::{StaReport, TimingStats};
use crate::util::Json;
use crate::Result;
use anyhow::{anyhow, bail};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Version of the on-disk entry layout *and* of the fingerprint schema the
/// keys were computed under. Bump it whenever either changes shape: every
/// existing entry then fails [`read_entry`]'s version check and is lazily
/// recomputed and rewritten.
pub const CACHE_FORMAT_VERSION: u64 = 1;

/// Magic string identifying a design-cache entry file.
pub const CACHE_MAGIC: &str = "ufo-mac-design-cache";

/// Path of the cache entry for a fingerprint under `dir`.
pub fn entry_path(dir: &Path, fp: Fingerprint) -> PathBuf {
    dir.join(format!("{fp}.json"))
}

// -------------------------------------------------------------------
// Entry envelope.
// -------------------------------------------------------------------

/// Atomically persist `artifact` under `dir`, keyed by `fp`.
///
/// The document is first written to a unique temporary file in `dir` and
/// then renamed over the final path, so a concurrent [`read_entry`] sees
/// either the old complete entry or the new complete entry — never a
/// partial write — and concurrent writers of the same fingerprint cannot
/// interleave (last rename wins; both wrote identical content anyway,
/// since the engine guarantees identical request ⇒ identical artifact).
pub fn write_entry(dir: &Path, fp: Fingerprint, artifact: &DesignArtifact) -> Result<PathBuf> {
    static NONCE: AtomicU64 = AtomicU64::new(0);
    std::fs::create_dir_all(dir)?;
    let payload = artifact_to_json(artifact).render();
    let checksum = Fingerprint::of_bytes(payload.as_bytes());
    // Assemble the envelope textually so the embedded payload is the exact
    // byte sequence the checksum covers (object-level assembly would
    // re-render it identically, but this makes the contract visible).
    let doc = format!(
        "{{\"artifact\":{payload},\"checksum\":\"{checksum}\",\"fingerprint\":\"{fp}\",\
         \"magic\":\"{CACHE_MAGIC}\",\"version\":{CACHE_FORMAT_VERSION}}}"
    );
    let tmp = dir.join(format!(
        "{fp}.{}.{}.tmp",
        std::process::id(),
        NONCE.fetch_add(1, Ordering::Relaxed)
    ));
    let path = entry_path(dir, fp);
    std::fs::write(&tmp, doc.as_bytes())?;
    std::fs::rename(&tmp, &path)?;
    Ok(path)
}

/// Load and fully validate the entry for `fp` under `dir`.
///
/// Any defect — missing file, malformed JSON, magic/version/fingerprint
/// mismatch, checksum failure, undeserializable payload — is an error; the
/// cache treats it as a miss and recompiles (rewriting the entry).
pub fn read_entry(dir: &Path, fp: Fingerprint) -> Result<DesignArtifact> {
    let path = entry_path(dir, fp);
    let text = std::fs::read_to_string(&path)?;
    let doc = Json::parse(&text).map_err(|e| anyhow!("cache entry {}: {e}", path.display()))?;
    let magic = doc.get("magic").and_then(|m| m.as_str()).unwrap_or("");
    if magic != CACHE_MAGIC {
        bail!("cache entry {}: bad magic '{magic}'", path.display());
    }
    let version = doc.get("version").and_then(|v| v.as_f64()).unwrap_or(-1.0);
    if version != CACHE_FORMAT_VERSION as f64 {
        bail!(
            "cache entry {}: version {version} != {CACHE_FORMAT_VERSION} (stale schema)",
            path.display()
        );
    }
    let stored_fp = fingerprint_from_json(&doc, "fingerprint")?;
    if stored_fp != fp {
        bail!("cache entry {}: fingerprint mismatch (stored {stored_fp})", path.display());
    }
    let payload = doc
        .get("artifact")
        .ok_or_else(|| anyhow!("cache entry {}: missing 'artifact'", path.display()))?;
    let checksum = fingerprint_from_json(&doc, "checksum")?;
    let rendered = payload.render();
    let actual = Fingerprint::of_bytes(rendered.as_bytes());
    if actual != checksum {
        bail!(
            "cache entry {}: checksum mismatch (recorded {checksum}, computed {actual})",
            path.display()
        );
    }
    let artifact = artifact_from_json(payload)?;
    if artifact.fingerprint != fp {
        bail!("cache entry {}: payload fingerprint mismatch", path.display());
    }
    Ok(artifact)
}

fn fingerprint_from_json(j: &Json, key: &str) -> Result<Fingerprint> {
    let s = j
        .get(key)
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("missing or non-string field '{key}'"))?;
    let bits =
        u128::from_str_radix(s, 16).map_err(|_| anyhow!("field '{key}': bad hex '{s}'"))?;
    Ok(Fingerprint(bits))
}

// -------------------------------------------------------------------
// Artifact <-> JSON.
// -------------------------------------------------------------------

/// Serialize a compiled artifact (the `artifact` payload of a cache entry).
pub fn artifact_to_json(a: &DesignArtifact) -> Json {
    let body = match &a.body {
        ArtifactBody::Design(d) => {
            Json::obj(vec![("kind", Json::str("design")), ("design", design_to_json(d))])
        }
        ArtifactBody::FirStage { netlist, y, report } => Json::obj(vec![
            ("kind", Json::str("fir_stage")),
            ("netlist", netlist_to_json(netlist)),
            ("y", ids_to_json(y)),
            ("report", report_to_json(report)),
        ]),
        ArtifactBody::SystolicPe { pe, report } => Json::obj(vec![
            ("kind", Json::str("systolic_pe")),
            ("pe", design_to_json(pe)),
            ("report", report_to_json(report)),
        ]),
    };
    Json::obj(vec![
        ("request", a.request.to_json()),
        ("fingerprint", Json::str(a.fingerprint.to_string())),
        ("sta", sta_to_json(&a.sta)),
        ("timing", timing_to_json(&a.timing)),
        ("body", body),
        ("verified", opt_bool(a.verified)),
        ("pjrt_verified", opt_bool(a.pjrt_verified)),
        // Always present (null when absent) so the rendered bytes are a
        // pure function of the artifact, never of the writer's version.
        (
            "lint",
            match &a.lint {
                None => Json::Null,
                Some(r) => r.to_json(),
            },
        ),
        (
            "analysis",
            match &a.analysis {
                None => Json::Null,
                Some(r) => r.to_json(),
            },
        ),
    ])
}

/// Deserialize an artifact payload written by [`artifact_to_json`].
pub fn artifact_from_json(j: &Json) -> Result<DesignArtifact> {
    let body_j = j.get("body").ok_or_else(|| anyhow!("missing field 'body'"))?;
    let kind = body_j
        .get("kind")
        .and_then(|k| k.as_str())
        .ok_or_else(|| anyhow!("body.kind must be a string"))?;
    let body = match kind {
        "design" => ArtifactBody::Design(design_from_json(
            body_j.get("design").ok_or_else(|| anyhow!("missing body.design"))?,
        )?),
        "fir_stage" => ArtifactBody::FirStage {
            netlist: netlist_from_json(
                body_j.get("netlist").ok_or_else(|| anyhow!("missing body.netlist"))?,
            )?,
            y: ids_from_json(body_j, "y")?,
            report: report_from_json(
                body_j.get("report").ok_or_else(|| anyhow!("missing body.report"))?,
            )?,
        },
        "systolic_pe" => ArtifactBody::SystolicPe {
            pe: design_from_json(body_j.get("pe").ok_or_else(|| anyhow!("missing body.pe"))?)?,
            report: report_from_json(
                body_j.get("report").ok_or_else(|| anyhow!("missing body.report"))?,
            )?,
        },
        other => bail!("unknown body kind '{other}' (valid: design, fir_stage, systolic_pe)"),
    };
    Ok(DesignArtifact {
        request: DesignRequest::from_json(
            j.get("request").ok_or_else(|| anyhow!("missing field 'request'"))?,
        )?,
        fingerprint: fingerprint_from_json(j, "fingerprint")?,
        sta: sta_from_json(j.get("sta").ok_or_else(|| anyhow!("missing field 'sta'"))?)?,
        timing: timing_from_json(
            j.get("timing").ok_or_else(|| anyhow!("missing field 'timing'"))?,
        )?,
        body,
        verified: opt_bool_from(j, "verified")?,
        pjrt_verified: opt_bool_from(j, "pjrt_verified")?,
        // Tolerant: entries written before the lint/analysis subsystems
        // carry no key; either spelling of absence reads back as None.
        lint: match j.get("lint") {
            None | Some(Json::Null) => None,
            Some(l) => Some(LintReport::from_json(l)?),
        },
        analysis: match j.get("analysis") {
            None | Some(Json::Null) => None,
            Some(a) => {
                Some(AnalysisReport::from_json(a).map_err(|e| anyhow!("analysis: {e}"))?)
            }
        },
    })
}

// -------------------------------------------------------------------
// Component serializers.
// -------------------------------------------------------------------

/// Serialize a gate-level netlist. Nodes travel positionally (node ids are
/// their indices), each as a compact array: `["i", name, arrival_ns]` for
/// a primary input, `["k", 0|1]` for a constant, `["r", d, en, clr, 0|1]`
/// for a register (pin order matches [`Netlist::reg`]; the trailing flag
/// is the init/reset value), `[opcode, fanin…]` for a gate (opcodes are
/// [`CellKind::opcode`], stable across versions). The records are read
/// column-wise off the IR's flat arrays — no `Node` reconstruction — and
/// combinational netlists render byte-identically to the pre-sequential
/// encoding, so existing disk-cache entries stay valid.
pub fn netlist_to_json(nl: &Netlist) -> Json {
    let ops = nl.ops();
    let fan = nl.fanin_records();
    let nodes = (0..nl.len())
        .map(|i| match nl.kind_at(i) {
            Some(kind) => {
                let mut xs = vec![Json::num(kind.opcode() as f64)];
                let rec = fan[i];
                xs.extend(rec.iter().take(kind.arity()).map(|&f| Json::num(f as f64)));
                Json::arr(xs)
            }
            None if ops[i] == crate::ir::OP_INPUT => match nl.node(NodeId(i as u32)) {
                Node::Input { name, arrival_ns } => Json::arr(vec![
                    Json::str("i"),
                    Json::str(name),
                    Json::num(arrival_ns),
                ]),
                _ => unreachable!("OP_INPUT node must view as Node::Input"),
            },
            None if ops[i] == crate::ir::OP_REG => {
                let rec = fan[i];
                Json::arr(vec![
                    Json::str("r"),
                    Json::num(rec[0] as f64),
                    Json::num(rec[1] as f64),
                    Json::num(rec[2] as f64),
                    Json::num(if nl.reg_init(NodeId(i as u32)) { 1.0 } else { 0.0 }),
                ])
            }
            None => Json::arr(vec![
                Json::str("k"),
                Json::num(if ops[i] == crate::ir::OP_CONST1 { 1.0 } else { 0.0 }),
            ]),
        })
        .collect();
    Json::obj(vec![
        ("name", Json::str(nl.name.clone())),
        ("nodes", Json::Arr(nodes)),
        (
            "outputs",
            Json::arr(
                nl.outputs()
                    .map(|(name, id)| {
                        Json::arr(vec![Json::str(name), Json::num(id.0 as f64)])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Rebuild a netlist written by [`netlist_to_json`], re-validating arities
/// and topological order (corrupted entries must fail cleanly, not panic).
pub fn netlist_from_json(j: &Json) -> Result<Netlist> {
    let name = j
        .get("name")
        .and_then(|n| n.as_str())
        .ok_or_else(|| anyhow!("netlist.name must be a string"))?;
    let mut nl = Netlist::new(name);
    let nodes =
        j.get("nodes").and_then(|n| n.as_arr()).ok_or_else(|| anyhow!("netlist.nodes missing"))?;
    for (i, node) in nodes.iter().enumerate() {
        let parts = node.as_arr().ok_or_else(|| anyhow!("node {i} must be an array"))?;
        if parts.is_empty() {
            bail!("node {i}: empty record");
        }
        match &parts[0] {
            Json::Str(tag) if tag == "i" => {
                let (name, arr) = match parts {
                    [_, Json::Str(name), Json::Num(t)] => (name.clone(), *t),
                    _ => bail!("node {i}: input record must be [\"i\", name, arrival_ns]"),
                };
                nl.input_at(name, arr);
            }
            Json::Str(tag) if tag == "k" => {
                let v = parts
                    .get(1)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| anyhow!("node {i}: constant record must be [\"k\", 0|1]"))?;
                nl.constant(v != 0.0);
            }
            Json::Str(tag) if tag == "r" => {
                let (d, en, clr, init) = match parts {
                    [_, Json::Num(d), Json::Num(en), Json::Num(clr), Json::Num(init)] => {
                        (*d as u32, *en as u32, *clr as u32, *init != 0.0)
                    }
                    _ => bail!("node {i}: register record must be [\"r\", d, en, clr, 0|1]"),
                };
                // `reg_raw` places no ordering constraints of its own; the
                // final `validate()` below re-checks every register pin
                // (forward `d` is legal feedback, `en`/`clr` must be
                // strictly earlier), so corrupted entries fail cleanly.
                nl.reg_raw(d, en, clr, init);
            }
            Json::Num(op) => {
                let op = *op as usize;
                let kind = *CellKind::ALL
                    .get(op)
                    .ok_or_else(|| anyhow!("node {i}: unknown opcode {op}"))?;
                let fanin: Vec<NodeId> = parts[1..]
                    .iter()
                    .map(|f| {
                        f.as_f64()
                            .map(|x| NodeId(x as u32))
                            .ok_or_else(|| anyhow!("node {i}: fanin must be numeric"))
                    })
                    .collect::<Result<_>>()?;
                if fanin.len() != kind.arity() {
                    bail!("node {i}: {kind:?} with {} fanins", fanin.len());
                }
                if fanin.iter().any(|f| f.index() >= i) {
                    bail!("node {i}: forward fanin reference");
                }
                nl.gate(kind, &fanin);
            }
            _ => bail!("node {i}: unrecognized record"),
        }
    }
    let outputs = j
        .get("outputs")
        .and_then(|o| o.as_arr())
        .ok_or_else(|| anyhow!("netlist.outputs missing"))?;
    for (i, out) in outputs.iter().enumerate() {
        match out.as_arr() {
            Some([Json::Str(name), Json::Num(id)]) if (*id as usize) < nl.len() => {
                nl.output(name.clone(), NodeId(*id as u32));
            }
            _ => bail!("output {i}: must be [name, valid node id]"),
        }
    }
    nl.validate().map_err(|e| anyhow!("deserialized netlist invalid: {e}"))?;
    Ok(nl)
}

fn design_to_json(d: &Design) -> Json {
    Json::obj(vec![
        ("n", Json::num(d.n as f64)),
        ("format", format_to_json(d.format)),
        ("is_mac", Json::Bool(d.is_mac)),
        ("netlist", netlist_to_json(&d.netlist)),
        ("a", ids_to_json(&d.a)),
        ("b", ids_to_json(&d.b)),
        ("c", ids_to_json(&d.c)),
        ("product", ids_to_json(&d.product)),
        ("ct_stages", Json::num(d.ct_stages as f64)),
        ("profile", Json::arr(d.profile.iter().map(|&x| Json::num(x)).collect())),
        ("cpa_nodes", Json::num(d.cpa_nodes as f64)),
        ("timing", timing_to_json(&d.timing)),
        (
            "cpa2_profile",
            match &d.cpa2_profile {
                None => Json::Null,
                Some(p) => Json::arr(p.iter().map(|&x| Json::num(x)).collect()),
            },
        ),
        // Always present (null for combinational designs) so the rendered
        // bytes are a pure function of the design, never of the writer's
        // version; pre-sequential entries carry no key and read as None.
        (
            "pipeline",
            match &d.pipeline {
                None => Json::Null,
                Some(p) => Json::obj(vec![
                    ("stages", Json::num(p.stages as f64)),
                    ("en", Json::num(p.en.0 as f64)),
                    ("clr", Json::num(p.clr.0 as f64)),
                ]),
            },
        ),
    ])
}

fn design_from_json(j: &Json) -> Result<Design> {
    let netlist = netlist_from_json(
        j.get("netlist").ok_or_else(|| anyhow!("design.netlist missing"))?,
    )?;
    let check_ids = |ids: &[NodeId]| ids.iter().all(|id| id.index() < netlist.len());
    let a = ids_from_json(j, "a")?;
    let b = ids_from_json(j, "b")?;
    let c = ids_from_json(j, "c")?;
    let product = ids_from_json(j, "product")?;
    if !(check_ids(&a) && check_ids(&b) && check_ids(&c) && check_ids(&product)) {
        bail!("design interface references nodes outside the netlist");
    }
    let pipeline = match j.get("pipeline") {
        None | Some(Json::Null) => None,
        Some(p) => {
            let info = PipelineInfo {
                stages: num_field(p, "stages")? as usize,
                en: NodeId(num_field(p, "en")? as u32),
                clr: NodeId(num_field(p, "clr")? as u32),
            };
            if info.stages == 0 {
                bail!("design.pipeline.stages must be positive");
            }
            if !check_ids(&[info.en, info.clr]) {
                bail!("design.pipeline references nodes outside the netlist");
            }
            Some(info)
        }
    };
    Ok(Design {
        n: num_field(j, "n")? as usize,
        format: format_from_json(j.get("format").ok_or_else(|| anyhow!("design.format"))?)?,
        is_mac: bool_field(j, "is_mac")?,
        netlist,
        a,
        b,
        c,
        product,
        ct_stages: num_field(j, "ct_stages")? as usize,
        profile: f64s_from_json(j, "profile")?,
        cpa_nodes: num_field(j, "cpa_nodes")? as usize,
        timing: timing_from_json(j.get("timing").ok_or_else(|| anyhow!("design.timing"))?)?,
        cpa2_profile: match j.get("cpa2_profile") {
            None | Some(Json::Null) => None,
            Some(_) => Some(f64s_from_json(j, "cpa2_profile")?),
        },
        pipeline,
    })
}

fn format_to_json(f: OperandFormat) -> Json {
    Json::obj(vec![
        ("a_bits", Json::num(f.a_bits as f64)),
        ("b_bits", Json::num(f.b_bits as f64)),
        ("signed", Json::Bool(f.is_signed())),
    ])
}

fn format_from_json(j: &Json) -> Result<OperandFormat> {
    Ok(OperandFormat {
        signedness: if bool_field(j, "signed")? {
            Signedness::Signed
        } else {
            Signedness::Unsigned
        },
        a_bits: num_field(j, "a_bits")? as usize,
        b_bits: num_field(j, "b_bits")? as usize,
    })
}

/// Serialize an STA report (used by the wire protocol's compile responses
/// as well as the disk entries).
pub fn sta_to_json(r: &StaReport) -> Json {
    Json::obj(vec![
        ("critical_delay_ns", Json::num(r.critical_delay_ns)),
        ("area_um2", Json::num(r.area_um2)),
        ("power_mw", Json::num(r.power_mw)),
        ("output_arrivals_ns", Json::arr(r.output_arrivals_ns.iter().map(|&x| Json::num(x)).collect())),
        ("num_gates", Json::num(r.num_gates as f64)),
        ("depth", Json::num(r.depth as f64)),
    ])
}

fn sta_from_json(j: &Json) -> Result<StaReport> {
    Ok(StaReport {
        critical_delay_ns: num_field(j, "critical_delay_ns")?,
        area_um2: num_field(j, "area_um2")?,
        power_mw: num_field(j, "power_mw")?,
        output_arrivals_ns: f64s_from_json(j, "output_arrivals_ns")?,
        num_gates: num_field(j, "num_gates")? as usize,
        depth: num_field(j, "depth")? as u32,
    })
}

/// Serialize timing-work counters (`u64`s travel as decimal strings to
/// stay lossless, the request-serialization idiom).
pub fn timing_to_json(t: &TimingStats) -> Json {
    Json::obj(vec![
        ("full_passes", Json::str(t.full_passes.to_string())),
        ("incremental_passes", Json::str(t.incremental_passes.to_string())),
        ("nodes_retimed", Json::str(t.nodes_retimed.to_string())),
        ("nodes_total", Json::str(t.nodes_total.to_string())),
    ])
}

fn timing_from_json(j: &Json) -> Result<TimingStats> {
    let u64_field = |key: &str| -> Result<u64> {
        let s = j
            .get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("timing.{key} must be a decimal string"))?;
        s.parse().map_err(|_| anyhow!("timing.{key}: bad u64 '{s}'"))
    };
    Ok(TimingStats {
        full_passes: u64_field("full_passes")?,
        incremental_passes: u64_field("incremental_passes")?,
        nodes_retimed: u64_field("nodes_retimed")?,
        nodes_total: u64_field("nodes_total")?,
    })
}

/// Serialize a clocked module report (FIR stage / systolic PE).
pub fn report_to_json(r: &ModuleReport) -> Json {
    Json::obj(vec![
        ("freq_hz", Json::num(r.freq_hz)),
        ("wns_ns", Json::num(r.wns_ns)),
        ("area_um2", Json::num(r.area_um2)),
        ("power_mw", Json::num(r.power_mw)),
    ])
}

fn report_from_json(j: &Json) -> Result<ModuleReport> {
    Ok(ModuleReport {
        freq_hz: num_field(j, "freq_hz")?,
        wns_ns: num_field(j, "wns_ns")?,
        area_um2: num_field(j, "area_um2")?,
        power_mw: num_field(j, "power_mw")?,
    })
}

// -------------------------------------------------------------------
// Small field helpers.
// -------------------------------------------------------------------

fn ids_to_json(ids: &[NodeId]) -> Json {
    Json::arr(ids.iter().map(|id| Json::num(id.0 as f64)).collect())
}

fn ids_from_json(j: &Json, key: &str) -> Result<Vec<NodeId>> {
    j.get(key)
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("field '{key}' must be an array"))?
        .iter()
        .map(|x| {
            x.as_f64()
                .map(|v| NodeId(v as u32))
                .ok_or_else(|| anyhow!("field '{key}': non-numeric id"))
        })
        .collect()
}

fn f64s_from_json(j: &Json, key: &str) -> Result<Vec<f64>> {
    j.get(key)
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("field '{key}' must be an array"))?
        .iter()
        .map(|x| x.as_f64().ok_or_else(|| anyhow!("field '{key}': non-numeric entry")))
        .collect()
}

fn num_field(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow!("missing or non-numeric field '{key}'"))
}

fn bool_field(j: &Json, key: &str) -> Result<bool> {
    j.get(key)
        .and_then(|v| v.as_bool())
        .ok_or_else(|| anyhow!("missing or non-bool field '{key}'"))
}

/// `Option<bool>` → JSON `null`/bool — the tri-state encoding shared by
/// the disk entries and the wire protocol's `verified`/`pjrt_verified`
/// fields.
pub(crate) fn opt_bool(v: Option<bool>) -> Json {
    match v {
        None => Json::Null,
        Some(b) => Json::Bool(b),
    }
}

fn opt_bool_from(j: &Json, key: &str) -> Result<Option<bool>> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Bool(b)) => Ok(Some(*b)),
        Some(_) => bail!("field '{key}' must be bool or null"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{DesignRequest, EngineConfig, SynthEngine};
    use crate::baselines::Method;
    use crate::multiplier::Strategy;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("ufo_persist_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn artifact_json_roundtrip_is_stable() {
        let eng = SynthEngine::new(EngineConfig::default());
        for req in [
            DesignRequest::multiplier(4),
            DesignRequest::fir(Method::UfoMac, 4, Strategy::TradeOff, 1e9),
            DesignRequest::systolic(Method::UfoMac, 4, Strategy::TradeOff, 1e9),
        ] {
            let art = eng.compile(&req).unwrap();
            let j = artifact_to_json(&art);
            let back = artifact_from_json(&j).unwrap();
            // Byte-stable round-trip: re-serialization is identical, and
            // the reconstructed netlist is the same graph.
            assert_eq!(j.render(), artifact_to_json(&back).render());
            assert_eq!(back.fingerprint, art.fingerprint);
            assert_eq!(back.netlist().len(), art.netlist().len());
            assert_eq!(back.netlist().outputs().len(), art.netlist().outputs().len());
        }
    }

    #[test]
    fn lint_roundtrips_and_pre_lint_entries_read_as_none() {
        let eng = SynthEngine::new(EngineConfig::default());
        let art = eng.compile(&DesignRequest::multiplier(4)).unwrap();
        let j = artifact_to_json(&art);
        let back = artifact_from_json(&j).unwrap();
        assert!(back.lint.as_ref().expect("lint persisted").is_clean());
        // An entry written before the lint subsystem (no "lint" key) must
        // still deserialize — as an artifact without a stored report.
        let mut obj = match j {
            Json::Obj(m) => m,
            other => panic!("artifact payload must be an object, got {other:?}"),
        };
        obj.remove("lint");
        let old = artifact_from_json(&Json::Obj(obj)).unwrap();
        assert!(old.lint.is_none());
    }

    #[test]
    fn analysis_roundtrips_and_pre_analysis_entries_read_as_none() {
        let eng = SynthEngine::new(EngineConfig::default());
        let art = eng.compile(&DesignRequest::multiplier(4)).unwrap();
        let j = artifact_to_json(&art);
        let back = artifact_from_json(&j).unwrap();
        let rep = back.analysis.as_ref().expect("analysis persisted");
        assert_eq!(Some(rep), art.analysis.as_ref());
        assert_eq!(rep.nodes, art.netlist().len());
        // An entry written before the analysis subsystem (no "analysis"
        // key) must still deserialize — without a stored report.
        let mut obj = match j {
            Json::Obj(m) => m,
            other => panic!("artifact payload must be an object, got {other:?}"),
        };
        obj.remove("analysis");
        let old = artifact_from_json(&Json::Obj(obj)).unwrap();
        assert!(old.analysis.is_none());
    }

    #[test]
    fn entry_roundtrip_and_validation() {
        let dir = temp_dir("entry");
        let eng = SynthEngine::new(EngineConfig::default());
        let art = eng.compile(&DesignRequest::multiplier(4)).unwrap();
        let fp = art.fingerprint;
        let path = write_entry(&dir, fp, &art).unwrap();
        let back = read_entry(&dir, fp).unwrap();
        assert_eq!(back.fingerprint, fp);
        // A flipped payload byte fails the checksum, not the parser.
        let text = std::fs::read_to_string(&path).unwrap();
        let bad = text.replacen("\"kind\":\"design\"", "\"kind\":\"design \"", 1);
        std::fs::write(&path, bad).unwrap();
        let err = read_entry(&dir, fp).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        // Rewriting recovers.
        write_entry(&dir, fp, &art).unwrap();
        assert!(read_entry(&dir, fp).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pipelined_design_roundtrips_registers_and_metadata() {
        let eng = SynthEngine::new(EngineConfig::default());
        let req = DesignRequest::from_spec(
            &crate::multiplier::MultiplierSpec::new(4).fused_mac(true).pipeline_stages(2),
        );
        let art = eng.compile(&req).unwrap();
        let j = artifact_to_json(&art);
        let back = artifact_from_json(&j).unwrap();
        assert_eq!(j.render(), artifact_to_json(&back).render());
        let (orig, restored) = match (&art.body, &back.body) {
            (ArtifactBody::Design(o), ArtifactBody::Design(r)) => (o, r),
            other => panic!("wrong bodies {other:?}"),
        };
        let info = restored.pipeline.as_ref().expect("pipeline metadata persisted");
        assert_eq!(Some(info), orig.pipeline.as_ref());
        assert_eq!(info.stages, 2);
        assert!(restored.netlist.is_sequential());
        assert_eq!(restored.netlist.num_regs(), orig.netlist.num_regs());
        // Register init values survive the trip (all pipeline regs reset
        // to 0, and every one is re-validated by netlist_from_json).
        for &(r, init) in restored.netlist.registers() {
            assert_eq!(init, orig.netlist.reg_init(NodeId(r)));
        }
        // The restored sequential design still passes bounded equivalence.
        let rep = crate::equiv::check_multiplier(restored).unwrap();
        assert!(rep.exhaustive && rep.passed, "{rep:?}");
    }

    #[test]
    fn deserialized_design_still_simulates_correctly() {
        let eng = SynthEngine::new(EngineConfig::default());
        let art = eng.compile(&DesignRequest::multiplier(4)).unwrap();
        let back = artifact_from_json(&artifact_to_json(&art)).unwrap();
        let design = match &back.body {
            ArtifactBody::Design(d) => d,
            other => panic!("wrong body {other:?}"),
        };
        let rep = crate::equiv::check_multiplier(design).unwrap();
        assert!(rep.exhaustive && rep.passed, "{rep:?}");
    }
}
