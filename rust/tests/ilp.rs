//! ILP edge-case coverage: infeasible systems, degenerate simplex pivots,
//! and branch-and-bound determinism (parallel result == serial result).

use std::time::Duration;
use ufo_mac::ct::{assign_greedy, assign_ilp, CtCounts};
use ufo_mac::ilp::{solve, LinExpr, Model, Sense, SolveOptions, Status};
use ufo_mac::util::Rng;

fn mult_counts(n: usize) -> CtCounts {
    let pp: Vec<usize> = (0..2 * n - 1).map(|j| n.min(j + 1).min(2 * n - 1 - j)).collect();
    CtCounts::from_populations(&pp)
}

// ---------------------------------------------------------------------------
// Infeasible systems
// ---------------------------------------------------------------------------

#[test]
fn infeasible_lp_conflicting_bounds_row() {
    // x ≤ 1 (bound) vs x ≥ 5 (row).
    let mut m = Model::new();
    let x = m.cont("x", 0.0, 1.0);
    m.constrain(LinExpr::of(&[(x, 1.0)]), Sense::Ge, 5.0);
    m.minimize(LinExpr::of(&[(x, 1.0)]));
    assert_eq!(solve(&m, &SolveOptions::default()).status, Status::Infeasible);
}

#[test]
fn infeasible_equality_system() {
    // x + y = 2 and x + y = 3 cannot both hold.
    let mut m = Model::new();
    let x = m.cont("x", 0.0, 10.0);
    let y = m.cont("y", 0.0, 10.0);
    m.constrain(LinExpr::of(&[(x, 1.0), (y, 1.0)]), Sense::Eq, 2.0);
    m.constrain(LinExpr::of(&[(x, 1.0), (y, 1.0)]), Sense::Eq, 3.0);
    m.minimize(LinExpr::of(&[(x, 1.0)]));
    assert_eq!(solve(&m, &SolveOptions::default()).status, Status::Infeasible);
}

#[test]
fn integrality_induced_infeasibility_serial_and_parallel() {
    // LP-relaxation feasible (x = y = 0.75), IP infeasible: 2x + 2y = 3.
    let build = || {
        let mut m = Model::new();
        let x = m.int("x", 0.0, 4.0);
        let y = m.int("y", 0.0, 4.0);
        m.constrain(LinExpr::of(&[(x, 2.0), (y, 2.0)]), Sense::Eq, 3.0);
        m.minimize(LinExpr::of(&[(x, 1.0), (y, 1.0)]));
        m
    };
    assert_eq!(solve(&build(), &SolveOptions::default()).status, Status::Infeasible);
    assert_eq!(
        solve(&build(), &SolveOptions::default().with_threads(4)).status,
        Status::Infeasible
    );
}

#[test]
fn empty_variable_range_is_infeasible() {
    let mut m = Model::new();
    let x = m.cont("x", 3.0, 1.0); // ub < lb
    m.minimize(LinExpr::of(&[(x, 1.0)]));
    assert_eq!(solve(&m, &SolveOptions::default()).status, Status::Infeasible);
}

// ---------------------------------------------------------------------------
// Degenerate simplex pivots
// ---------------------------------------------------------------------------

#[test]
fn degenerate_vertex_with_redundant_constraints() {
    // Three constraints meet at the optimum (2, 2): a degenerate vertex
    // forcing zero-progress pivots. The Bland fallback must terminate at
    // the right objective.
    let mut m = Model::new();
    let x = m.cont("x", 0.0, f64::INFINITY);
    let y = m.cont("y", 0.0, f64::INFINITY);
    m.constrain(LinExpr::of(&[(x, 1.0), (y, 1.0)]), Sense::Le, 4.0);
    m.constrain(LinExpr::of(&[(x, 1.0)]), Sense::Le, 2.0);
    m.constrain(LinExpr::of(&[(x, 2.0), (y, 2.0)]), Sense::Le, 8.0); // redundant copy
    m.constrain(LinExpr::of(&[(x, 3.0), (y, 1.0)]), Sense::Le, 8.0); // also through (2,2)
    m.minimize(LinExpr::of(&[(x, -1.0), (y, -1.0)]));
    let s = solve(&m, &SolveOptions::default());
    assert_eq!(s.status, Status::Optimal);
    assert!((s.objective + 4.0).abs() < 1e-6, "obj {}", s.objective);
}

#[test]
fn degenerate_zero_rhs_rows_terminate() {
    // Rows with rhs 0 make the origin a massively degenerate vertex.
    let mut m = Model::new();
    let v: Vec<_> = (0..5).map(|i| m.cont(format!("x{i}"), 0.0, 10.0)).collect();
    for i in 0..4 {
        m.constrain(LinExpr::of(&[(v[i], 1.0), (v[i + 1], -1.0)]), Sense::Le, 0.0);
    }
    m.constrain(LinExpr::of(&[(v[4], 1.0)]), Sense::Le, 3.0);
    // minimize -(x0 + … + x4): optimum pushes every var to 3.
    let mut obj = LinExpr::new();
    for &vi in &v {
        obj.add(vi, -1.0);
    }
    m.minimize(obj);
    let s = solve(&m, &SolveOptions::default());
    assert_eq!(s.status, Status::Optimal);
    assert!((s.objective + 15.0).abs() < 1e-6, "obj {}", s.objective);
}

// ---------------------------------------------------------------------------
// Branch-and-bound determinism: parallel == serial
// ---------------------------------------------------------------------------

/// A seeded knapsack family with enough branching to exercise the tree.
fn random_knapsack(seed: u64, items: usize) -> Model {
    let mut rng = Rng::seed_from_u64(seed);
    let mut m = Model::new();
    let mut cap = LinExpr::new();
    let mut obj = LinExpr::new();
    for i in 0..items {
        let v = m.bin(format!("b{i}"));
        cap.add(v, 1.0 + rng.f64() * 4.0);
        obj.add(v, -(1.0 + rng.f64() * 6.0));
    }
    m.constrain(cap, Sense::Le, items as f64 * 1.2);
    m.minimize(obj);
    m
}

#[test]
fn serial_solve_is_deterministic() {
    let a = solve(&random_knapsack(42, 12), &SolveOptions::default());
    let b = solve(&random_knapsack(42, 12), &SolveOptions::default());
    assert_eq!(a.status, b.status);
    assert_eq!(a.objective, b.objective, "same instance must give bitwise-equal objective");
    assert_eq!(a.values, b.values);
}

#[test]
fn parallel_objective_matches_serial_on_random_knapsacks() {
    for seed in [1u64, 7, 23, 77] {
        let serial = solve(&random_knapsack(seed, 13), &SolveOptions::default());
        let parallel =
            solve(&random_knapsack(seed, 13), &SolveOptions::default().with_threads(4));
        assert!(serial.ok() && parallel.ok(), "seed {seed}");
        assert!(
            (serial.objective - parallel.objective).abs() < 1e-6,
            "seed {seed}: serial {} vs parallel {}",
            serial.objective,
            parallel.objective
        );
    }
}

#[test]
fn parallel_stage_assignment_matches_serial_optimum() {
    // The §3.3 stage-assignment ILP: the parallel solver must reach the
    // same optimal stage count as the serial solver (and the greedy lower
    // bound) on small multipliers.
    for n in [3usize, 4] {
        let counts = mult_counts(n);
        let serial_opts =
            SolveOptions { time_limit: Duration::from_secs(30), ..Default::default() };
        let parallel_opts = serial_opts.with_threads(4);
        let (plan_s, _) = assign_ilp(&counts, &serial_opts);
        let (plan_p, _) = assign_ilp(&counts, &parallel_opts);
        plan_s.validate(&counts).unwrap();
        plan_p.validate(&counts).unwrap();
        assert_eq!(plan_s.stages(), plan_p.stages(), "n={n}");
        assert_eq!(plan_p.stages(), assign_greedy(&counts).stages(), "n={n}");
    }
}

#[test]
fn parallel_node_limit_never_claims_optimality() {
    // A 3-node budget cannot explore a 14-item knapsack tree: the solver
    // must come back as Feasible (incumbent found) or TimeLimit — never a
    // bogus Optimal claim.
    let m = random_knapsack(5, 14);
    let opts = SolveOptions { max_nodes: 3, ..SolveOptions::default().with_threads(3) };
    let s = solve(&m, &opts);
    assert!(
        matches!(s.status, Status::Feasible | Status::TimeLimit),
        "status {:?}",
        s.status
    );
}
