//! Minimal client for the `ufo-mac serve` compile service.
//!
//! Start the server in one terminal, then run this in another:
//!
//! ```text
//! cargo run --release --bin ufo-mac -- serve --addr 127.0.0.1:7878
//! cargo run --release --example serve_client -- 127.0.0.1:7878
//! ```
//!
//! It sends the same compile twice plus a `stats` probe, prints the
//! response lines, and demonstrates the cache doing its job: the second
//! compile answers with `"source":"memory"` (or `"disk"` when the server
//! was restarted over a persistent `--cache-dir`). It then runs a
//! streamed sweep — progress frames (`"event":"progress"`, one per design
//! point) arrive before the final envelope — and finishes with a
//! `metrics` probe showing the scheduler's queue/latency counters. The
//! wire format is documented in `PROTOCOL.md`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn main() -> std::io::Result<()> {
    let addr = std::env::args().nth(1).unwrap_or_else(|| "127.0.0.1:7878".to_string());
    let mut stream = TcpStream::connect(&addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    let next_line = |reader: &mut BufReader<TcpStream>, line: &mut String| {
        line.clear();
        reader.read_line(line).map(|_| ())
    };

    let compile = |id: u32| {
        format!(
            "{{\"cmd\":\"compile\",\"id\":{id},\"request\":{{\"kind\":\"method\",\
             \"method\":\"ufo\",\"n\":16,\"strategy\":\"tradeoff\",\"mac\":false}}}}"
        )
    };
    let requests = [compile(1), compile(2), "{\"cmd\":\"stats\",\"id\":3}".to_string()];
    for req in &requests {
        writeln!(stream, "{req}")?;
    }
    stream.flush()?;
    // Responses arrive in completion order; correlate by "id".
    for _ in 0..requests.len() {
        next_line(&mut reader, &mut line)?;
        print!("{line}");
    }

    // A streamed sweep: per-point progress frames (no "ok" key), then the
    // final envelope carrying the whole point list.
    writeln!(
        stream,
        "{}",
        "{\"cmd\":\"sweep\",\"id\":4,\"widths\":[8],\"methods\":[\"ufo\",\"gomil\"],\
         \"strategies\":[\"tradeoff\"],\"stream\":true}"
    )?;
    stream.flush()?;
    loop {
        next_line(&mut reader, &mut line)?;
        print!("{line}");
        if line.contains("\"ok\"") {
            break; // frames carry "event":"progress"; the envelope has "ok"
        }
    }

    // The observability snapshot: queue depths per priority class, cache
    // tiers, per-command latency histograms, jobs completed.
    writeln!(stream, "{}", "{\"cmd\":\"metrics\",\"id\":5}")?;
    stream.flush()?;
    next_line(&mut reader, &mut line)?;
    print!("{line}");
    Ok(())
}
