//! Long-lived design-compilation service in front of a [`SynthEngine`].
//!
//! The server speaks newline-delimited JSON (`PROTOCOL.md` at the
//! repository root is the normative wire description): each input line is
//! one command (`compile`, `batch`, `lint`, `analyze`, `sweep`, `stats`,
//! `metrics`, `shutdown`), each output line one response envelope carrying
//! the echoed request `id` — plus, for commands sent with `"stream":
//! true`, `{"event":"progress",…}` frames reporting per-design-point
//! completion before the final envelope.
//!
//! Commands are scheduled, not merely parallelized: every admitted line
//! becomes a job in a three-class priority queue ([`sched`]), a fixed
//! handler pool pops urgent work (cache hits, `stats`/`metrics`,
//! protocol errors) ahead of fresh syntheses, and multi-point jobs
//! (`sweep`, `batch`) *yield* between design points, so a 1 ms cache-hit
//! `compile` is answered while a multi-minute sweep is in flight — even
//! with one handler. Responses therefore arrive in *completion* order and
//! clients correlate them by `id`. Per-connection framed writers
//! ([`ConnWriter`]) write one complete line per lock acquisition, so
//! interleaved responses stay well-formed, and the [`metrics`] layer
//! keeps allocation-free latency histograms and queue gauges for the
//! `metrics` command / `ufo-mac serve --metrics`.
//!
//! Three properties make the service cheap to hit repeatedly:
//!
//! - **content-addressed caching** — identical requests (any spelling, see
//!   [`DesignRequest::canonical`]) resolve to one cache entry;
//! - **in-flight coalescing** — N simultaneous identical compiles trigger
//!   exactly one synthesis ([`SynthEngine::compile_traced`]);
//! - **a persistent disk tier** — engines built with
//!   [`EngineConfig::cache_dir`](crate::api::EngineConfig) write every
//!   artifact through to checksummed entry files, so warm designs survive
//!   restarts and a fresh process answers them from disk (`"source":
//!   "disk"` in the response) without recompiling.
//!
//! ```
//! use std::sync::Arc;
//! use ufo_mac::api::{EngineConfig, SynthEngine};
//! use ufo_mac::server::Server;
//!
//! let server = Server::new(Arc::new(SynthEngine::new(EngineConfig::default())));
//! let resp = server.handle_line(
//!     r#"{"cmd":"compile","id":1,"request":{"kind":"method","method":"ufo","n":4,"strategy":"tradeoff","mac":false}}"#,
//! );
//! assert!(resp.contains(r#""ok":true"#) && resp.contains(r#""source":"compiled""#));
//! ```

pub mod metrics;
mod protocol;
pub mod sched;

pub use protocol::Command;

use crate::api::{DesignRequest, SynthEngine};
use crate::coordinator::{self, pool, DesignPoint};
use crate::sta::TimingStats;
use crate::util::Json;
use crate::Result;
use anyhow::anyhow;
use metrics::Metrics;
use protocol::{
    analysis_summary, artifact_summary, envelope_err, envelope_ok, lint_summary, progress_frame,
    Request,
};
use sched::{Priority, Scheduler};
use std::io::{BufRead, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Hard cap on one request line. A connection that exceeds it without a
/// newline gets one error envelope and is dropped — it cannot grow the
/// read buffer without bound or wedge the multiplexer.
const MAX_LINE: usize = 1 << 20;

/// Write timeout on TCP connections: a reader slow enough to stall a
/// write this long only loses its *own* connection (the write fails, the
/// connection is marked dead, its remaining jobs are dropped).
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Multiplexer sleep when no connection made progress (std has no epoll;
/// this bounds the poll rate instead).
const POLL_IDLE: Duration = Duration::from_millis(2);

/// Per-connection framed writer: one complete NDJSON line per lock
/// acquisition, so progress frames and envelopes from concurrent handler
/// threads never interleave mid-line. A failed write marks the
/// connection dead; jobs for a dead connection are dropped instead of
/// poisoning the handler pool. The pending/closing pair implements
/// close-after-drain: `shutdown` (or reader EOF) stops admissions and
/// the connection closes once every already-admitted job has settled.
struct ConnWriter<W: Write> {
    w: Mutex<W>,
    /// Cleared on write failure, explicit kill, or drain completion.
    alive: AtomicBool,
    /// Admitted-but-unsettled jobs on this connection.
    pending: AtomicUsize,
    /// Set by `shutdown`/EOF: close once `pending` drains to zero.
    closing: AtomicBool,
}

impl<W: Write> ConnWriter<W> {
    fn new(w: W) -> ConnWriter<W> {
        ConnWriter {
            w: Mutex::new(w),
            alive: AtomicBool::new(true),
            pending: AtomicUsize::new(0),
            closing: AtomicBool::new(false),
        }
    }

    /// Write one complete line (plus newline) and flush. Returns whether
    /// the write succeeded; failure marks the connection dead.
    fn send(&self, line: &str) -> bool {
        if !self.alive.load(Ordering::Acquire) {
            return false;
        }
        let ok = {
            let mut w = self.w.lock().unwrap();
            writeln!(w, "{line}").and_then(|()| w.flush()).is_ok()
        };
        if !ok {
            self.alive.store(false, Ordering::Release);
        }
        ok
    }

    fn alive(&self) -> bool {
        self.alive.load(Ordering::Acquire)
    }

    fn kill(&self) {
        self.alive.store(false, Ordering::Release);
    }

    /// One more job admitted for this connection.
    fn begin(&self) {
        self.pending.fetch_add(1, Ordering::AcqRel);
    }

    /// One admitted job settled (answered or dropped); completes a
    /// requested close-after-drain when it was the last.
    fn settle(&self) {
        if self.pending.fetch_sub(1, Ordering::AcqRel) == 1 && self.closing.load(Ordering::Acquire)
        {
            self.alive.store(false, Ordering::Release);
        }
    }

    /// Stop admitting and close once all pending jobs settle.
    fn close_after_drain(&self) {
        self.closing.store(true, Ordering::Release);
        if self.pending.load(Ordering::Acquire) == 0 {
            self.alive.store(false, Ordering::Release);
        }
    }

    fn closing(&self) -> bool {
        self.closing.load(Ordering::Acquire)
    }
}

impl ConnWriter<Vec<u8>> {
    /// Drain the buffered output lines (the in-process transport behind
    /// [`Server::handle_line_all`]).
    fn take_lines(&self) -> Vec<String> {
        let buf = std::mem::take(&mut *self.w.lock().unwrap());
        String::from_utf8_lossy(&buf).lines().map(str::to_string).collect()
    }
}

/// One schedulable unit of work: a whole command, or one *step* of a
/// yielding command (`sweep`/`batch`), which re-enqueues its own tail.
struct Job<W: Write> {
    conn: Arc<ConnWriter<W>>,
    id: Json,
    class: Priority,
    /// Admission time — latency histograms measure admission → final
    /// envelope, so queueing delay is part of the observed latency.
    t0: Instant,
    kind: JobKind,
}

enum JobKind {
    /// Answer a non-yielding command in one step.
    Respond(Command, bool),
    /// Answer a protocol error (unparseable line or unknown command).
    Fail(String),
    /// A yielding sweep: one design point per handler slot.
    Sweep(SweepJob),
    /// A yielding batch: one request per handler slot.
    Batch(BatchJob),
}

struct SweepJob {
    reqs: Vec<DesignRequest>,
    points: Vec<DesignPoint>,
    next: usize,
    stream: bool,
}

struct BatchJob {
    reqs: Vec<DesignRequest>,
    rows: Vec<Json>,
    next: usize,
    stream: bool,
}

/// A TCP connection as the multiplexer sees it: the nonblocking read
/// half, its partial-line buffer, and the shared framed writer.
struct TcpConn {
    rd: TcpStream,
    buf: Vec<u8>,
    writer: Arc<ConnWriter<TcpStream>>,
}

/// The design-compilation server (see module docs).
pub struct Server {
    engine: Arc<SynthEngine>,
    /// Responses written over the server's lifetime.
    served: AtomicU64,
    /// Aggregate timing-evaluation work behind the artifacts this server
    /// compiled or served (`compile`/`batch` commands).
    timing: Mutex<TimingStats>,
    /// Observability counters (queue gauges, latency histograms, totals).
    metrics: Metrics,
}

impl Server {
    /// Wrap an engine. The engine is shared — several servers (or a server
    /// plus direct API callers) may compile through one engine and its
    /// cache.
    pub fn new(engine: Arc<SynthEngine>) -> Server {
        Server {
            engine,
            served: AtomicU64::new(0),
            timing: Mutex::new(TimingStats::default()),
            metrics: Metrics::new(),
        }
    }

    /// The engine this server compiles through.
    pub fn engine(&self) -> &Arc<SynthEngine> {
        &self.engine
    }

    /// Process one request line and return the final response line (no
    /// trailing newline). Progress frames of `"stream": true` commands
    /// are dropped; [`Server::handle_line_all`] returns them too.
    pub fn handle_line(&self, line: &str) -> String {
        self.handle_line_all(line).pop().unwrap_or_default()
    }

    /// Process one request line and return *every* output line it
    /// produces, in order: progress frames first (for `"stream": true`
    /// commands), the final envelope last. The serving loops emit the
    /// same lines over their transport as they are produced; this is the
    /// in-process equivalent, and what `rust/tests/server.rs` uses to
    /// replay the `PROTOCOL.md` streaming examples.
    pub fn handle_line_all(&self, line: &str) -> Vec<String> {
        let sched: Scheduler<Job<Vec<u8>>> = Scheduler::new();
        let conn = Arc::new(ConnWriter::new(Vec::new()));
        self.admit(line, &conn, &sched);
        sched.close();
        while let Some(job) = sched.pop() {
            self.run_job(job, &sched);
        }
        conn.take_lines()
    }

    /// Parse one request line, classify it, and enqueue the resulting
    /// job. Malformed lines become urgent [`JobKind::Fail`] jobs so the
    /// error envelope is never stuck behind bulk work.
    fn admit<W: Write>(&self, line: &str, conn: &Arc<ConnWriter<W>>, sched: &Scheduler<Job<W>>) {
        let t0 = Instant::now();
        let (id, req) = protocol::parse_line(line);
        let (class, kind) = match req {
            Ok(Request { cmd, stream }) => {
                let class = self.classify(&cmd);
                let kind = match cmd {
                    Command::Sweep(cfg) => JobKind::Sweep(SweepJob {
                        reqs: coordinator::sweep_requests(&cfg),
                        points: Vec::new(),
                        next: 0,
                        stream,
                    }),
                    Command::Batch(reqs) => JobKind::Batch(BatchJob {
                        rows: Vec::with_capacity(reqs.len()),
                        reqs,
                        next: 0,
                        stream,
                    }),
                    cmd => JobKind::Respond(cmd, stream),
                };
                (class, kind)
            }
            Err(e) => (Priority::Urgent, JobKind::Fail(format!("{e:#}"))),
        };
        conn.begin();
        self.metrics.job_admitted(class);
        sched.push(Job { conn: Arc::clone(conn), id, class, t0, kind }, class);
    }

    /// Priority class of a parsed command: constant-time answers and
    /// cache-resident compiles are urgent, a fresh synthesis is
    /// interactive, multi-point work is bulk (and yields).
    fn classify(&self, cmd: &Command) -> Priority {
        match cmd {
            Command::Stats | Command::Metrics | Command::Shutdown => Priority::Urgent,
            Command::Compile(req) | Command::Lint(req) | Command::Analyze(req) => {
                if self.engine.is_cached(req) {
                    Priority::Urgent
                } else {
                    Priority::Interactive
                }
            }
            Command::Batch(_) | Command::Sweep(_) => Priority::Bulk,
        }
    }

    /// Run one scheduled job, or one step of a yielding job (which
    /// re-enqueues its tail). Returns `true` when the job answered a
    /// `shutdown` command.
    fn run_job<W: Write>(&self, job: Job<W>, sched: &Scheduler<Job<W>>) -> bool {
        let Job { conn, id, class, t0, kind } = job;
        if !conn.alive() {
            // Client gone: drop the job (and any remaining sweep/batch
            // steps) without burning handler time on unsendable results.
            self.metrics.job_settled(class);
            conn.settle();
            return false;
        }
        match kind {
            JobKind::Fail(e) => {
                self.finish(&conn, class, t0, None, envelope_err(&id, &e));
                false
            }
            JobKind::Respond(cmd, stream) => {
                let key = cmd.key();
                let shutdown = matches!(cmd, Command::Shutdown);
                let envelope = match self.dispatch(cmd) {
                    Ok(result) => {
                        if stream {
                            // One-point stream: a single completion frame
                            // before the final envelope keeps client
                            // parsers uniform across compile and
                            // sweep/batch.
                            let src = result.get("source").cloned().unwrap_or(Json::Null);
                            self.emit_frame(&conn, &id, 1, 1, ("source", src));
                        }
                        envelope_ok(&id, result)
                    }
                    Err(e) => envelope_err(&id, &format!("{e:#}")),
                };
                self.finish(&conn, class, t0, Some(key), envelope);
                if shutdown {
                    conn.close_after_drain();
                }
                shutdown
            }
            JobKind::Sweep(mut sj) => {
                let total = sj.reqs.len();
                if sj.next < total {
                    let req = &sj.reqs[sj.next];
                    let point = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        coordinator::compile_point(&self.engine, req)
                    }))
                    .unwrap_or_else(|_| Err(anyhow!("synthesis panicked for {req:?}")));
                    sj.next += 1;
                    if sj.stream {
                        let payload = match &point {
                            Ok(p) => coordinator::point_json(p),
                            Err(_) => Json::Null,
                        };
                        self.emit_frame(&conn, &id, sj.next, total, ("point", payload));
                    }
                    if let Ok(p) = point {
                        sj.points.push(p);
                    }
                    if sj.next < total {
                        // Yield: re-enqueue the tail so urgent and
                        // interactive work runs between design points.
                        sched.push(Job { conn, id, class, t0, kind: JobKind::Sweep(sj) }, class);
                        return false;
                    }
                }
                let result = Json::obj(vec![
                    ("count", Json::num(sj.points.len() as f64)),
                    ("points", coordinator::points_json(&sj.points)),
                ]);
                self.finish(&conn, class, t0, Some("sweep"), envelope_ok(&id, result));
                false
            }
            JobKind::Batch(mut bj) => {
                let total = bj.reqs.len();
                if bj.next < total {
                    let req = &bj.reqs[bj.next];
                    // Contain synthesis panics to this row, as the old
                    // batch fan-out did.
                    let row = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        self.engine.compile_traced(req)
                    }))
                    .unwrap_or_else(|_| Err(anyhow!("synthesis panicked for {req:?}")));
                    bj.next += 1;
                    let row = match row {
                        Ok((art, source)) => {
                            self.timing.lock().unwrap().merge(&art.timing);
                            Json::obj(vec![
                                ("ok", Json::Bool(true)),
                                ("result", artifact_summary(&art, source)),
                            ])
                        }
                        Err(e) => Json::obj(vec![
                            ("ok", Json::Bool(false)),
                            ("error", Json::str(format!("{e:#}"))),
                        ]),
                    };
                    if bj.stream {
                        self.emit_frame(&conn, &id, bj.next, total, ("row", row.clone()));
                    }
                    bj.rows.push(row);
                    if bj.next < total {
                        sched.push(Job { conn, id, class, t0, kind: JobKind::Batch(bj) }, class);
                        return false;
                    }
                }
                let result = Json::obj(vec![
                    ("count", Json::num(bj.rows.len() as f64)),
                    ("results", Json::Arr(bj.rows)),
                ]);
                self.finish(&conn, class, t0, Some("batch"), envelope_ok(&id, result));
                false
            }
        }
    }

    /// Write one `{"event":"progress",…}` frame (frames never carry an
    /// `ok` key, so clients can always tell them from envelopes).
    fn emit_frame<W: Write>(
        &self,
        conn: &ConnWriter<W>,
        id: &Json,
        done: usize,
        total: usize,
        payload: (&str, Json),
    ) {
        if conn.send(&progress_frame(id, done, total, payload).render()) {
            self.metrics.frame_emitted();
        }
    }

    /// Write a final envelope and settle the job's accounting: queue
    /// gauge, served counter, jobs-completed total, and the per-command
    /// latency histogram (`cmd` is `None` for protocol errors, which have
    /// no command class).
    fn finish<W: Write>(
        &self,
        conn: &ConnWriter<W>,
        class: Priority,
        t0: Instant,
        cmd: Option<&'static str>,
        envelope: Json,
    ) {
        conn.send(&envelope.render());
        self.metrics.job_settled(class);
        self.metrics.job_completed(cmd, t0.elapsed());
        self.served.fetch_add(1, Ordering::Relaxed);
        conn.settle();
    }

    /// Answer a non-yielding command (`sweep`/`batch` run as yielding
    /// jobs in [`Server::run_job`] instead).
    fn dispatch(&self, cmd: Command) -> Result<Json> {
        match cmd {
            Command::Compile(req) => {
                // Contain synthesis panics to this command: one poison
                // request must produce an error envelope, not tear down
                // the handler pool.
                let (art, source) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || self.engine.compile_traced(&req),
                ))
                .unwrap_or_else(|_| Err(anyhow!("synthesis panicked for {req:?}")))?;
                self.timing.lock().unwrap().merge(&art.timing);
                Ok(artifact_summary(&art, source))
            }
            Command::Lint(req) => {
                // Same panic containment as `compile`: linting an uncached
                // request synthesizes it first.
                let (report, art, source) = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| self.engine.lint(&req)),
                )
                .unwrap_or_else(|_| Err(anyhow!("synthesis panicked for {req:?}")))?;
                self.timing.lock().unwrap().merge(&art.timing);
                Ok(lint_summary(&report, &art, source))
            }
            Command::Analyze(req) => {
                // Same panic containment as `lint`: analyzing an uncached
                // request synthesizes it first.
                let (report, art, source) = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| self.engine.analyze(&req)),
                )
                .unwrap_or_else(|_| Err(anyhow!("synthesis panicked for {req:?}")))?;
                self.timing.lock().unwrap().merge(&art.timing);
                Ok(analysis_summary(&report, &art, source))
            }
            Command::Stats => Ok(self.stats_json()),
            Command::Metrics => Ok(self.metrics_json()),
            Command::Shutdown => Ok(Json::str("shutting down")),
            Command::Batch(_) | Command::Sweep(_) => {
                unreachable!("yielding commands are scheduled as jobs, not dispatched")
            }
        }
    }

    /// Cache counters shared by `stats` and `metrics`.
    fn cache_json(&self) -> Json {
        let s = self.engine.cache_stats();
        Json::obj(vec![
            ("hits", Json::num(s.hits as f64)),
            ("disk_hits", Json::num(s.disk_hits as f64)),
            ("misses", Json::num(s.misses as f64)),
            ("coalesced", Json::num(s.coalesced as f64)),
            ("entries", Json::num(s.entries as f64)),
            ("hit_rate", Json::num(s.hit_rate())),
        ])
    }

    /// The `stats` response body.
    fn stats_json(&self) -> Json {
        let t = *self.timing.lock().unwrap();
        Json::obj(vec![
            ("cache", self.cache_json()),
            (
                "timing",
                Json::obj(vec![
                    ("full_passes", Json::num(t.full_passes as f64)),
                    ("incremental_passes", Json::num(t.incremental_passes as f64)),
                    ("nodes_retimed", Json::num(t.nodes_retimed as f64)),
                    ("nodes_total", Json::num(t.nodes_total as f64)),
                    ("retime_fraction", Json::num(t.retime_fraction())),
                ]),
            ),
            ("queue_depth", Json::num(self.metrics.queue_depth_total() as f64)),
            ("served", Json::num(self.served.load(Ordering::Relaxed) as f64)),
            ("workers", Json::num(self.engine.config().workers as f64)),
        ])
    }

    /// The `metrics` response body: cache tiers, per-class queue depths,
    /// per-command latency histograms (log-2 µs buckets, admission →
    /// final envelope), uptime, and lifetime totals. Also printed by
    /// `ufo-mac serve --metrics`.
    pub fn metrics_json(&self) -> Json {
        Json::obj(vec![
            ("cache", self.cache_json()),
            ("jobs_completed", Json::num(self.metrics.jobs_completed() as f64)),
            ("latency_us", self.metrics.latency_json()),
            ("progress_frames", Json::num(self.metrics.progress_frames() as f64)),
            ("queue", self.metrics.queue_json()),
            ("uptime_s", Json::num(self.metrics.uptime().as_secs_f64())),
            ("workers", Json::num(self.engine.config().workers as f64)),
        ])
    }

    /// Serve newline-delimited JSON from `reader` to `writer` with
    /// `workers` concurrent job handlers (plus one reader thread), all on
    /// [`pool::scoped_workers`] draining one priority [`Scheduler`].
    /// Returns when the input reaches EOF or the stream errors. After a
    /// `shutdown` command has been answered the queue is drained and the
    /// loop stops at the reader's *next* wakeup — immediate for
    /// transports with a read timeout, at the next line/EOF for a plain
    /// blocking reader such as stdin. Piped stdio clients therefore need
    /// no explicit `shutdown`: closing the pipe is enough.
    ///
    /// ```
    /// use std::sync::Arc;
    /// use ufo_mac::api::{EngineConfig, SynthEngine};
    /// use ufo_mac::server::Server;
    ///
    /// let server = Server::new(Arc::new(SynthEngine::new(EngineConfig::default())));
    /// let input: &[u8] = b"{\"cmd\":\"stats\",\"id\":1}\n";
    /// let mut output = Vec::new();
    /// server.serve(input, &mut output, 2)?;
    /// assert!(String::from_utf8(output)?.contains(r#""ok":true"#));
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn serve<R, W>(&self, reader: R, writer: W, workers: usize) -> Result<()>
    where
        R: BufRead + Send,
        W: Write + Send,
    {
        let workers = workers.max(1);
        let sched: Scheduler<Job<W>> = Scheduler::new();
        let conn = Arc::new(ConnWriter::new(writer));
        let reader_cell = Mutex::new(Some(reader));
        // Worker 0 is the reader; workers 1..=N run scheduled jobs.
        pool::scoped_workers(workers + 1, |w| {
            if w == 0 {
                let mut reader = reader_cell.lock().unwrap().take().expect("one reader");
                let mut buf = String::new();
                loop {
                    if !conn.alive() || conn.closing() {
                        break;
                    }
                    match reader.read_line(&mut buf) {
                        Ok(0) => break, // EOF
                        Ok(_) => {
                            let line = buf.trim();
                            if !line.is_empty() {
                                self.admit(line, &conn, &sched);
                            }
                            buf.clear();
                        }
                        // Read timeouts keep any partial line in `buf`
                        // and try again.
                        Err(e)
                            if matches!(
                                e.kind(),
                                std::io::ErrorKind::WouldBlock
                                    | std::io::ErrorKind::TimedOut
                                    | std::io::ErrorKind::Interrupted
                            ) => {}
                        Err(_) => break,
                    }
                }
                sched.close();
            } else {
                while let Some(job) = sched.pop() {
                    if self.run_job(job, &sched) {
                        // `shutdown` answered: stop admitting, drain the
                        // already-queued commands, then everyone exits.
                        sched.close();
                    }
                }
            }
        });
        Ok(())
    }

    /// Accept TCP connections forever on a multiplexed readiness core:
    /// one acceptor thread, one multiplexer thread polling every
    /// connection for readable lines, and a fixed pool of
    /// `engine.config().workers` handler threads — all connections share
    /// the pool and one priority [`Scheduler`], so a cache-hit `compile`
    /// on one connection preempts another connection's in-flight sweep.
    /// A `shutdown` command drains and closes its own connection; the
    /// listener keeps accepting.
    pub fn serve_listener(&self, listener: TcpListener) -> Result<()> {
        let workers = self.engine.config().workers.max(1);
        let sched: Scheduler<Job<TcpStream>> = Scheduler::new();
        let fresh: Mutex<Vec<TcpStream>> = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            s.spawn(|| {
                for conn in listener.incoming() {
                    let Ok(stream) = conn else { continue };
                    fresh.lock().unwrap().push(stream);
                }
            });
            for _ in 0..workers {
                s.spawn(|| {
                    while let Some(job) = sched.pop() {
                        self.run_job(job, &sched);
                    }
                });
            }
            // The multiplexer runs on the scope's own thread.
            self.multiplex(&fresh, &sched);
        });
        Ok(())
    }

    /// Readiness-polling loop over all live connections: drain readable
    /// bytes into per-connection buffers, admit complete lines, retire
    /// dead or drained connections.
    fn multiplex(&self, fresh: &Mutex<Vec<TcpStream>>, sched: &Scheduler<Job<TcpStream>>) {
        let mut conns: Vec<TcpConn> = Vec::new();
        loop {
            for stream in fresh.lock().unwrap().drain(..) {
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
                let Ok(wr) = stream.try_clone() else { continue };
                conns.push(TcpConn {
                    rd: stream,
                    buf: Vec::new(),
                    writer: Arc::new(ConnWriter::new(wr)),
                });
            }
            let mut progressed = false;
            conns.retain_mut(|c| {
                if !c.writer.alive() {
                    return false; // dead or fully drained: drop the socket
                }
                if c.writer.closing() {
                    return true; // draining after shutdown/EOF: stop reading
                }
                let mut chunk = [0u8; 4096];
                loop {
                    match c.rd.read(&mut chunk) {
                        Ok(0) => {
                            // EOF. A trailing unterminated line is still
                            // a request (matching `BufRead::read_line`),
                            // then close once pending work drains.
                            let bytes = std::mem::take(&mut c.buf);
                            let line = String::from_utf8_lossy(&bytes);
                            let line = line.trim();
                            if !line.is_empty() {
                                self.admit(line, &c.writer, sched);
                            }
                            c.writer.close_after_drain();
                            return c.writer.alive();
                        }
                        Ok(n) => {
                            progressed = true;
                            c.buf.extend_from_slice(&chunk[..n]);
                            if !self.admit_buffered(c, sched) {
                                return false;
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                        Err(_) => {
                            c.writer.kill();
                            return false;
                        }
                    }
                }
                true
            });
            if !progressed {
                std::thread::sleep(POLL_IDLE);
            }
        }
    }

    /// Split complete lines out of a connection's read buffer and admit
    /// them. Returns `false` when the connection must be dropped: a
    /// single line exceeded [`MAX_LINE`], in which case the client gets
    /// one error envelope and only *this* connection closes.
    fn admit_buffered(&self, c: &mut TcpConn, sched: &Scheduler<Job<TcpStream>>) -> bool {
        while let Some(pos) = c.buf.iter().position(|&b| b == b'\n') {
            let rest = c.buf.split_off(pos + 1);
            let line_bytes = std::mem::replace(&mut c.buf, rest);
            let line = String::from_utf8_lossy(&line_bytes);
            let line = line.trim();
            if !line.is_empty() {
                self.admit(line, &c.writer, sched);
            }
        }
        if c.buf.len() > MAX_LINE {
            c.writer.send(
                &envelope_err(&Json::Null, &format!("request line exceeds {MAX_LINE} bytes"))
                    .render(),
            );
            c.writer.kill();
            return false;
        }
        true
    }

    /// Bind `addr` and [`Server::serve_listener`] on it. Prints one
    /// "listening" line to stdout and then runs until the process is
    /// killed.
    ///
    /// ```no_run
    /// use std::sync::Arc;
    /// use ufo_mac::api::{EngineConfig, SynthEngine};
    /// use ufo_mac::server::Server;
    ///
    /// let engine = Arc::new(SynthEngine::new(EngineConfig {
    ///     cache_dir: Some(ufo_mac::runtime::default_cache_dir()),
    ///     ..EngineConfig::default()
    /// }));
    /// Server::new(engine).serve_tcp("127.0.0.1:7878")?;
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn serve_tcp(&self, addr: &str) -> Result<()> {
        let listener =
            TcpListener::bind(addr).map_err(|e| anyhow!("cannot bind '{addr}': {e}"))?;
        let local = listener.local_addr()?;
        println!("ufo-mac serve: listening on {local} (newline-delimited JSON, see PROTOCOL.md)");
        self.serve_listener(listener)
    }
}

/// Convenience used by tests and examples: render one `compile` request
/// line (NDJSON) for `req` with the given `id`.
pub fn compile_line(id: u64, req: &DesignRequest) -> String {
    Json::obj(vec![
        ("cmd", Json::str("compile")),
        ("id", Json::num(id as f64)),
        ("request", req.to_json()),
    ])
    .render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::EngineConfig;

    fn server() -> Server {
        Server::new(Arc::new(SynthEngine::new(EngineConfig::default())))
    }

    #[test]
    fn unknown_cmd_lists_valid_values() {
        let resp = server().handle_line(r#"{"cmd":"warp","id":9}"#);
        assert!(resp.contains(r#""ok":false"#), "{resp}");
        assert!(
            resp.contains("valid: analyze, batch, compile, lint, metrics, shutdown, stats, sweep"),
            "{resp}"
        );
        assert!(resp.contains(r#""id":9"#), "{resp}");
    }

    #[test]
    fn malformed_line_is_an_error_envelope() {
        let resp = server().handle_line("not json at all");
        assert!(resp.contains(r#""ok":false"#), "{resp}");
        assert!(resp.contains(r#""id":null"#), "{resp}");
    }

    #[test]
    fn stream_flag_must_be_a_bool() {
        let resp = server().handle_line(r#"{"cmd":"stats","id":1,"stream":"yes"}"#);
        assert!(resp.contains(r#""ok":false"#), "{resp}");
        assert!(resp.contains("'stream' must be a bool"), "{resp}");
    }

    #[test]
    fn compile_then_hit_then_stats() {
        let srv = server();
        let req = DesignRequest::multiplier(4);
        let first = srv.handle_line(&compile_line(1, &req));
        assert!(first.contains(r#""source":"compiled""#), "{first}");
        let second = srv.handle_line(&compile_line(2, &req));
        assert!(second.contains(r#""source":"memory""#), "{second}");
        let stats = srv.handle_line(r#"{"cmd":"stats","id":3}"#);
        let doc = Json::parse(&stats).unwrap();
        let cache = doc.get("result").unwrap().get("cache").unwrap();
        assert!(cache.get("hits").unwrap().as_f64().unwrap() >= 1.0, "{stats}");
    }

    #[test]
    fn streamed_compile_emits_one_frame_then_envelope() {
        let srv = server();
        let lines = srv.handle_line_all(
            r#"{"cmd":"compile","id":7,"request":{"kind":"method","method":"ufo","n":4,"strategy":"tradeoff","mac":false},"stream":true}"#,
        );
        assert_eq!(lines.len(), 2, "{lines:?}");
        assert!(lines[0].contains(r#""event":"progress""#), "{lines:?}");
        assert!(lines[0].contains(r#""done":1"#) && lines[0].contains(r#""total":1"#), "{lines:?}");
        assert!(lines[0].contains(r#""source":"compiled""#), "{lines:?}");
        assert!(!lines[0].contains(r#""ok""#), "frames carry no ok key: {lines:?}");
        assert!(lines[1].contains(r#""ok":true"#), "{lines:?}");
        // Without the flag, the same request produces only the envelope.
        let quiet = srv.handle_line_all(
            r#"{"cmd":"compile","id":8,"request":{"kind":"method","method":"ufo","n":4,"strategy":"tradeoff","mac":false}}"#,
        );
        assert_eq!(quiet.len(), 1, "{quiet:?}");
    }

    #[test]
    fn streamed_sweep_frames_are_monotone_then_final() {
        let srv = server();
        let lines = srv.handle_line_all(
            r#"{"cmd":"sweep","id":6,"methods":["ufo","gomil"],"strategies":["tradeoff"],"stream":true,"widths":[4]}"#,
        );
        assert_eq!(lines.len(), 3, "{lines:?}");
        for (i, frame) in lines[..2].iter().enumerate() {
            let doc = Json::parse(frame).unwrap();
            assert_eq!(doc.get("event").unwrap().as_str().unwrap(), "progress", "{frame}");
            assert_eq!(doc.get("done").unwrap().as_f64().unwrap(), (i + 1) as f64, "{frame}");
            assert_eq!(doc.get("total").unwrap().as_f64().unwrap(), 2.0, "{frame}");
            assert!(doc.get("point").unwrap().get("delay_ns").is_some(), "{frame}");
            assert!(doc.get("ok").is_none(), "{frame}");
        }
        let fin = Json::parse(&lines[2]).unwrap();
        assert_eq!(fin.get("result").unwrap().get("count").unwrap().as_f64().unwrap(), 2.0);
    }

    #[test]
    fn metrics_reports_queue_latency_and_totals() {
        let srv = server();
        let _ = srv.handle_line(&compile_line(1, &DesignRequest::multiplier(4)));
        let resp = srv.handle_line(r#"{"cmd":"metrics","id":2}"#);
        let doc = Json::parse(&resp).unwrap();
        let result = doc.get("result").unwrap();
        assert!(result.get("jobs_completed").unwrap().as_f64().unwrap() >= 1.0, "{resp}");
        let q = result.get("queue").unwrap();
        for class in ["urgent", "interactive", "bulk"] {
            assert_eq!(q.get(class).unwrap().as_f64().unwrap(), 0.0, "{resp}");
        }
        let lat = result.get("latency_us").unwrap().get("compile").unwrap();
        assert!(lat.get("count").unwrap().as_f64().unwrap() >= 1.0, "{resp}");
        assert!(result.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0, "{resp}");
        assert!(result.get("cache").unwrap().get("misses").is_some(), "{resp}");
    }

    #[test]
    fn lint_reports_clean_design_with_cache_provenance() {
        let srv = server();
        let line = r#"{"cmd":"lint","id":4,"request":{"kind":"method","method":"ufo","n":4,"strategy":"tradeoff","mac":false}}"#;
        let resp = srv.handle_line(line);
        assert!(resp.contains(r#""ok":true"#), "{resp}");
        assert!(resp.contains(r#""clean":true"#), "{resp}");
        assert!(resp.contains(r#""source":"compiled""#), "{resp}");
        // A `compile` of the same request shares the cache entry, so the
        // second lint is a memory hit.
        let again = srv.handle_line(line);
        assert!(again.contains(r#""source":"memory""#), "{again}");
    }

    #[test]
    fn analyze_reports_proven_constants_with_cache_provenance() {
        let srv = server();
        let line = r#"{"cmd":"analyze","id":5,"request":{"kind":"method","method":"ufo","n":4,"strategy":"tradeoff","mac":false}}"#;
        let resp = srv.handle_line(line);
        assert!(resp.contains(r#""ok":true"#), "{resp}");
        assert!(resp.contains(r#""proven_const""#), "{resp}");
        assert!(resp.contains(r#""mean_activity""#), "{resp}");
        assert!(resp.contains(r#""source":"compiled""#), "{resp}");
        // A repeat shares the cache entry (and its stored report).
        let again = srv.handle_line(line);
        assert!(again.contains(r#""source":"memory""#), "{again}");
    }

    #[test]
    fn sweep_rejects_unknown_axis_values_strictly() {
        let srv = server();
        let resp = srv.handle_line(r#"{"cmd":"sweep","id":1,"methods":["alien"]}"#);
        assert!(resp.contains("valid: ufo, gomil, rlmul, commercial"), "{resp}");
        let resp = srv.handle_line(r#"{"cmd":"sweep","id":1,"strategies":["fast"]}"#);
        assert!(resp.contains("valid: area, timing, tradeoff"), "{resp}");
        let resp = srv.handle_line(r#"{"cmd":"sweep","id":1,"signedness":["sorta"]}"#);
        assert!(resp.contains("valid: signed, unsigned"), "{resp}");
    }
}
