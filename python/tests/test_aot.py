"""AOT lowering smoke tests: every artifact lowers to parseable HLO text
with the expected entry signature markers."""

import os

import pytest

from compile import aot, model


def test_lowering_produces_hlo_text(tmp_path):
    aot.build(str(tmp_path), only=["systolic"])
    path = tmp_path / "systolic.hlo.txt"
    assert path.exists()
    text = path.read_text()
    assert "HloModule" in text
    assert "ENTRY" in text
    # int32 operands/accumulators must appear in the signature.
    assert "s32[" in text
    assert (tmp_path / "manifest.json").exists()


@pytest.mark.slow
def test_netlist_artifact_lowering(tmp_path):
    aot.build(str(tmp_path), only=["netlist_eval_small"])
    text = (tmp_path / "netlist_eval_small.hlo.txt").read_text()
    assert "HloModule" in text
    assert "u32[" in text
    # The gate scan lowers to a while loop.
    assert "while" in text


def test_example_args_shapes():
    a, b, c = model.example_args("systolic")
    assert a.shape == (16, 64) and b.shape == (64, 16) and c.shape == (16, 16)
    ops, f0, f1, f2, words = model.example_args("netlist", "small")
    assert ops.shape == f0.shape == f1.shape == f2.shape
    assert words.ndim == 2


def test_repeated_build_is_idempotent(tmp_path):
    aot.build(str(tmp_path), only=["systolic"])
    first = (tmp_path / "systolic.hlo.txt").read_text()
    aot.build(str(tmp_path), only=["systolic"])
    second = (tmp_path / "systolic.hlo.txt").read_text()
    assert first == second


def test_manifest_merges(tmp_path):
    aot.build(str(tmp_path), only=["systolic"])
    aot.build(str(tmp_path), only=["netlist_eval_small"])
    import json

    man = json.loads((tmp_path / "manifest.json").read_text())
    assert "systolic" in man and "netlist_eval_small" in man
