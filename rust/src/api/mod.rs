//! # Unified API: `DesignRequest` → [`SynthEngine`] → `Arc<DesignArtifact>`
//!
//! UFO-MAC is a *unified* framework, and this module is the unification
//! point: one canonical request type, one engine that compiles it, and a
//! content-addressed cache so identical requests — the common case in DSE
//! sweeps and Pareto studies — are synthesized exactly once per process.
//!
//! ```no_run
//! use ufo_mac::api::{DesignRequest, EngineConfig, SynthEngine};
//! use ufo_mac::baselines::Method;
//! use ufo_mac::multiplier::Strategy;
//!
//! let engine = SynthEngine::new(EngineConfig::default());
//! let art = engine.compile(&DesignRequest::multiplier(16))?;
//! println!("{} gates, {:.3} ns", art.sta.num_gates, art.sta.critical_delay_ns);
//!
//! // A whole sweep in one call; duplicates collapse onto the cache.
//! let reqs: Vec<_> = [8usize, 16, 32]
//!     .iter()
//!     .map(|&n| DesignRequest::method(Method::UfoMac, n, Strategy::TradeOff, false))
//!     .collect();
//! let arts = engine.compile_batch(&reqs);
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! ## Migrating from the legacy entry points
//!
//! The old constructors still work as thin shims over the process-global
//! engine ([`engine()`]), but new code should speak requests:
//!
//! | legacy call | request form |
//! |---|---|
//! | `MultiplierSpec::new(n).build()` | [`DesignRequest::multiplier`]`(n)` / [`DesignRequest::from_spec`] |
//! | `baselines::build_design(m, n, s, mac, budget)` | [`DesignRequest::method`]`(m, n, s, mac)` |
//! | `coordinator::evaluate_point(…)` | [`DesignRequest::method`] + [`SynthEngine::compile`] |
//! | `modules::fir_report(m, n, s, f)` | [`DesignRequest::fir`]`(m, n, s, f)` |
//! | `modules::systolic_report(m, n, s, f)` | [`DesignRequest::systolic`]`(m, n, s, f)` |
//! | `modules::build_pe(m, n, s)` | [`DesignRequest::systolic`] → [`DesignArtifact::design`] |
//!
//! Requests serialize to JSON ([`DesignRequest::to_json_string`] /
//! [`DesignRequest::parse`]) and hash to a stable [`Fingerprint`] over
//! their canonical form — see [`DesignRequest::canonical`] for what the
//! normal form collapses.

mod cache;
mod engine;
pub mod persist;
mod request;

pub use cache::{CacheStats, CacheTier, DesignCache};
pub use engine::{
    global as engine, ArtifactBody, CompileSource, DesignArtifact, EngineConfig, SynthEngine,
};
pub use request::{
    tier1_requests, DesignRequest, Fingerprint, MacMode, MethodRequest, ModuleKind, ModuleRequest,
    MulRequest,
};

pub use crate::ppg::{OperandFormat, Signedness};
