//! Dense primal simplex with Big-M artificials.
//!
//! Solves the LP relaxation of a [`Model`]: minimize `c'x` subject to the
//! model's linear constraints and variable bounds. Variables are shifted to
//! `x' = x - lb ≥ 0`; finite upper bounds become explicit rows. `≥`/`=`
//! rows receive artificial variables priced at Big-M.
//!
//! This is deliberately a straightforward tableau implementation — the
//! paper's ILPs are small and structured; robustness (Bland's rule
//! anti-cycling fallback, relative tolerances) matters more than sparse
//! factorization here. The bottleneck-assignment solver handles the one
//! family that would genuinely be large.

use super::{Model, Sense, Solution, Status};

const EPS: f64 = 1e-9;
/// Reduced-cost tolerance.
const RC_TOL: f64 = 1e-7;

/// Solve the LP relaxation of `model` (integrality dropped).
pub fn solve_lp(model: &Model) -> Solution {
    let n = model.vars.len();

    // Shift lower bounds to zero: x = x' + lb.
    let lbs: Vec<f64> = model.vars.iter().map(|v| v.lb).collect();

    // Build row list: model constraints with adjusted rhs, then finite
    // upper-bound rows x' <= ub - lb.
    struct Row {
        coefs: Vec<(usize, f64)>,
        sense: Sense,
        rhs: f64,
    }
    let mut rows: Vec<Row> = Vec::with_capacity(model.cons.len());
    for c in &model.cons {
        let mut shift = 0.0;
        let mut merged: std::collections::HashMap<usize, f64> = std::collections::HashMap::new();
        for (v, coef) in &c.expr.terms {
            shift += coef * lbs[v.0];
            *merged.entry(v.0).or_insert(0.0) += coef;
        }
        rows.push(Row {
            coefs: merged.into_iter().filter(|(_, c)| c.abs() > EPS).collect(),
            sense: c.sense,
            rhs: c.rhs - shift,
        });
    }
    for (i, v) in model.vars.iter().enumerate() {
        if v.ub.is_finite() {
            let span = v.ub - v.lb;
            if span < -EPS {
                return infeasible(n);
            }
            rows.push(Row { coefs: vec![(i, 1.0)], sense: Sense::Le, rhs: span });
        }
    }

    // Normalize rhs >= 0.
    for r in rows.iter_mut() {
        if r.rhs < 0.0 {
            r.rhs = -r.rhs;
            for (_, c) in r.coefs.iter_mut() {
                *c = -*c;
            }
            r.sense = match r.sense {
                Sense::Le => Sense::Ge,
                Sense::Ge => Sense::Le,
                Sense::Eq => Sense::Eq,
            };
        }
    }

    let m = rows.len();
    // Column layout: [structural n][slack/surplus s][artificial a].
    let n_slack = rows.iter().filter(|r| r.sense != Sense::Eq).count();
    let n_art = rows.iter().filter(|r| r.sense != Sense::Le).count();
    let total = n + n_slack + n_art;

    // Big-M scaled to the objective magnitude.
    let cmax = model
        .objective
        .terms
        .iter()
        .map(|(_, c)| c.abs())
        .fold(1.0f64, f64::max);
    let big_m = cmax * 1e7;

    // Tableau: m rows × (total + 1) columns (last = rhs).
    let w = total + 1;
    let mut t = vec![0.0f64; m * w];
    let mut basis = vec![0usize; m];
    let mut cost = vec![0.0f64; total];
    for (v, c) in &model.objective.terms {
        cost[v.0] += *c;
    }

    let mut s_idx = n;
    let mut a_idx = n + n_slack;
    for (ri, r) in rows.iter().enumerate() {
        for (vi, c) in &r.coefs {
            t[ri * w + vi] += c;
        }
        t[ri * w + total] = r.rhs;
        match r.sense {
            Sense::Le => {
                t[ri * w + s_idx] = 1.0;
                basis[ri] = s_idx;
                s_idx += 1;
            }
            Sense::Ge => {
                t[ri * w + s_idx] = -1.0;
                s_idx += 1;
                t[ri * w + a_idx] = 1.0;
                cost[a_idx] = big_m;
                basis[ri] = a_idx;
                a_idx += 1;
            }
            Sense::Eq => {
                t[ri * w + a_idx] = 1.0;
                cost[a_idx] = big_m;
                basis[ri] = a_idx;
                a_idx += 1;
            }
        }
    }

    // Reduced-cost row: z_j - c_j computed incrementally. Start with
    // objective row = -cost, then add M-weighted basis rows (standard Big-M
    // tableau: objective row r0[j] = Σ_B c_B·a_ij − c_j).
    let mut obj = vec![0.0f64; w];
    for j in 0..total {
        obj[j] = -cost[j];
    }
    for ri in 0..m {
        let cb = cost[basis[ri]];
        if cb != 0.0 {
            for j in 0..w {
                obj[j] += cb * t[ri * w + j];
            }
        }
    }

    let max_iters = 50 * (m + total).max(100);
    let mut iters = 0usize;
    loop {
        iters += 1;
        if iters > max_iters {
            // Numerical trouble; report best effort as infeasible.
            return infeasible(n);
        }
        let use_bland = iters > 10 * (m + total).max(50);
        // Entering column: most positive obj[j] (z_j - c_j > 0 improves min).
        let mut enter = None;
        if use_bland {
            for j in 0..total {
                if obj[j] > RC_TOL {
                    enter = Some(j);
                    break;
                }
            }
        } else {
            let mut best = RC_TOL;
            for j in 0..total {
                if obj[j] > best {
                    best = obj[j];
                    enter = Some(j);
                }
            }
        }
        let Some(e) = enter else { break };

        // Ratio test.
        let mut leave = None;
        let mut best_ratio = f64::INFINITY;
        for ri in 0..m {
            let a = t[ri * w + e];
            if a > EPS {
                let ratio = t[ri * w + total] / a;
                if ratio < best_ratio - EPS
                    || (ratio < best_ratio + EPS
                        && leave.map_or(true, |l: usize| basis[ri] < basis[l]))
                {
                    best_ratio = ratio;
                    leave = Some(ri);
                }
            }
        }
        let Some(l) = leave else {
            return Solution {
                status: Status::Unbounded,
                objective: f64::NEG_INFINITY,
                values: vec![0.0; n],
                nodes: 0,
            };
        };

        // Pivot on (l, e).
        let piv = t[l * w + e];
        for j in 0..w {
            t[l * w + j] /= piv;
        }
        for ri in 0..m {
            if ri != l {
                let f = t[ri * w + e];
                if f.abs() > EPS {
                    for j in 0..w {
                        t[ri * w + j] -= f * t[l * w + j];
                    }
                }
            }
        }
        let f = obj[e];
        if f.abs() > EPS {
            for j in 0..w {
                obj[j] -= f * t[l * w + j];
            }
        }
        basis[l] = e;
    }

    // Artificials still basic at positive level ⇒ infeasible.
    for ri in 0..m {
        if basis[ri] >= n + n_slack && t[ri * w + total] > 1e-6 {
            return infeasible(n);
        }
    }

    let mut x = vec![0.0f64; n];
    for ri in 0..m {
        if basis[ri] < n {
            x[basis[ri]] = t[ri * w + total];
        }
    }
    // Un-shift bounds.
    for i in 0..n {
        x[i] += lbs[i];
    }
    let objective = model.objective.eval(&x);
    Solution { status: Status::Optimal, objective, values: x, nodes: 0 }
}

fn infeasible(n: usize) -> Solution {
    Solution {
        status: Status::Infeasible,
        objective: f64::INFINITY,
        values: vec![0.0; n],
        nodes: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ilp::{LinExpr, Model};

    #[test]
    fn textbook_lp() {
        // max 3x + 2y s.t. x + y <= 4, x + 3y <= 6, x,y >= 0 → (4,0), obj 12.
        let mut m = Model::new();
        let x = m.cont("x", 0.0, f64::INFINITY);
        let y = m.cont("y", 0.0, f64::INFINITY);
        m.constrain(LinExpr::of(&[(x, 1.0), (y, 1.0)]), Sense::Le, 4.0);
        m.constrain(LinExpr::of(&[(x, 1.0), (y, 3.0)]), Sense::Le, 6.0);
        m.minimize(LinExpr::of(&[(x, -3.0), (y, -2.0)]));
        let s = solve_lp(&m);
        assert_eq!(s.status, Status::Optimal);
        assert!((s.objective + 12.0).abs() < 1e-6, "obj {}", s.objective);
        assert!((s.value(x) - 4.0).abs() < 1e-6);
    }

    #[test]
    fn equality_and_ge_constraints() {
        // min x + y s.t. x + y = 10, x >= 3, y >= 2 → obj 10.
        let mut m = Model::new();
        let x = m.cont("x", 0.0, f64::INFINITY);
        let y = m.cont("y", 0.0, f64::INFINITY);
        m.constrain(LinExpr::of(&[(x, 1.0), (y, 1.0)]), Sense::Eq, 10.0);
        m.constrain(LinExpr::of(&[(x, 1.0)]), Sense::Ge, 3.0);
        m.constrain(LinExpr::of(&[(y, 1.0)]), Sense::Ge, 2.0);
        m.minimize(LinExpr::of(&[(x, 1.0), (y, 1.0)]));
        let s = solve_lp(&m);
        assert_eq!(s.status, Status::Optimal);
        assert!((s.objective - 10.0).abs() < 1e-6);
    }

    #[test]
    fn detects_infeasible() {
        let mut m = Model::new();
        let x = m.cont("x", 0.0, 1.0);
        m.constrain(LinExpr::of(&[(x, 1.0)]), Sense::Ge, 5.0);
        m.minimize(LinExpr::of(&[(x, 1.0)]));
        assert_eq!(solve_lp(&m).status, Status::Infeasible);
    }

    #[test]
    fn detects_unbounded() {
        let mut m = Model::new();
        let x = m.cont("x", 0.0, f64::INFINITY);
        m.minimize(LinExpr::of(&[(x, -1.0)]));
        assert_eq!(solve_lp(&m).status, Status::Unbounded);
    }

    #[test]
    fn respects_bounds_and_shifts() {
        // min x s.t. x >= 0 with lb 2.5, ub 7 → 2.5; max → 7.
        let mut m = Model::new();
        let x = m.cont("x", 2.5, 7.0);
        m.minimize(LinExpr::of(&[(x, 1.0)]));
        let s = solve_lp(&m);
        assert!((s.value(x) - 2.5).abs() < 1e-6);
        let mut m2 = Model::new();
        let x2 = m2.cont("x", 2.5, 7.0);
        m2.minimize(LinExpr::of(&[(x2, -1.0)]));
        let s2 = solve_lp(&m2);
        assert!((s2.value(x2) - 7.0).abs() < 1e-6, "{}", s2.value(x2));
    }

    #[test]
    fn negative_lower_bounds() {
        // min x + y, x in [-5, 5], y in [-2, 2], x + y >= -4 → obj -4... but
        // unconstrained pair hits (-5,-2) = -7 < -4 violating; optimum -4.
        let mut m = Model::new();
        let x = m.cont("x", -5.0, 5.0);
        let y = m.cont("y", -2.0, 2.0);
        m.constrain(LinExpr::of(&[(x, 1.0), (y, 1.0)]), Sense::Ge, -4.0);
        m.minimize(LinExpr::of(&[(x, 1.0), (y, 1.0)]));
        let s = solve_lp(&m);
        assert_eq!(s.status, Status::Optimal);
        assert!((s.objective + 4.0).abs() < 1e-6, "obj {}", s.objective);
    }

    #[test]
    fn degenerate_lp_terminates() {
        // Known cycling-prone structure; Bland fallback must terminate.
        let mut m = Model::new();
        let v: Vec<_> = (0..4).map(|i| m.cont(format!("x{i}"), 0.0, f64::INFINITY)).collect();
        m.constrain(
            LinExpr::of(&[(v[0], 0.25), (v[1], -8.0), (v[2], -1.0), (v[3], 9.0)]),
            Sense::Le,
            0.0,
        );
        m.constrain(
            LinExpr::of(&[(v[0], 0.5), (v[1], -12.0), (v[2], -0.5), (v[3], 3.0)]),
            Sense::Le,
            0.0,
        );
        m.constrain(LinExpr::of(&[(v[2], 1.0)]), Sense::Le, 1.0);
        m.minimize(LinExpr::of(&[(v[0], -0.75), (v[1], 150.0), (v[2], -0.02), (v[3], 6.0)]));
        let s = solve_lp(&m);
        assert_eq!(s.status, Status::Optimal);
        // Optimum: x = (1, 0, 1, 0) → obj = −0.75 − 0.02 = −0.77.
        assert!((s.objective + 0.77).abs() < 1e-6, "obj {}", s.objective);
    }
}
