//! Hot-path microbenchmarks — the profiling substrate for the §Perf pass
//! (EXPERIMENTS.md): STA sweeps dominate the Pareto experiments, the
//! bit-parallel simulator dominates equivalence checks + power estimation,
//! bottleneck assignment dominates CT construction, and full design
//! builds dominate the coordinator's jobs.

use ufo_mac::api::{DesignRequest, EngineConfig, SynthEngine};
use ufo_mac::bench::Bench;
use ufo_mac::ilp::assignment::bottleneck_assignment;
use ufo_mac::multiplier::MultiplierSpec;
use ufo_mac::sim::Simulator;
use ufo_mac::sta::Sta;
use ufo_mac::util::Rng;

fn main() {
    let bench = Bench::new("hotpath");

    // Pre-built 16-bit design shared by the passive benches.
    let design = MultiplierSpec::new(16).build().unwrap();
    let nl = &design.netlist;
    println!("16-bit UFO multiplier: {} nodes / {} gates", nl.len(), nl.num_gates());

    // STA arrival sweep (the Pareto-sweep inner loop).
    let sta = Sta { activity_rounds: 0, ..Sta::default() };
    bench.bench("sta_arrivals_16bit", || sta.arrivals_ns(nl));
    bench.bench("sta_analyze_16bit_no_power_sim", || sta.analyze(nl));

    // Bit-parallel simulation (equivalence + toggle power inner loop).
    let mut sim = Simulator::new();
    let mut rng = Rng::seed_from_u64(1);
    let words: Vec<u64> = (0..nl.num_inputs()).map(|_| rng.next_u64()).collect();
    bench.bench("sim_run_16bit_64lanes", || {
        sim.run(nl, &words);
        sim.word(design.product[0])
    });

    // Toggle-activity power extraction (16 rounds × 64 lanes).
    bench.bench("toggle_activity_16bit_16rounds", || {
        ufo_mac::sim::toggle_activity(nl, 16, 7)
    });

    // Bottleneck assignment at CT-slice scale (m = 16 and 32).
    for m in [16usize, 32] {
        let mut r = Rng::seed_from_u64(m as u64);
        let cost: Vec<Vec<f64>> =
            (0..m).map(|_| (0..m).map(|_| r.f64()).collect()).collect();
        bench.bench(&format!("bottleneck_assignment_{m}x{m}"), || {
            bottleneck_assignment(&cost)
        });
    }

    // Full design construction (the coordinator job body).
    bench.bench("build_ufo_multiplier_8bit", || MultiplierSpec::new(8).build().unwrap());
    bench.bench("build_ufo_multiplier_16bit", || MultiplierSpec::new(16).build().unwrap());

    // Stage assignment at 32/64 bits (greedy hot path).
    for n in [32usize, 64] {
        let pp: Vec<usize> =
            (0..2 * n - 1).map(|j| n.min(j + 1).min(2 * n - 1 - j)).collect();
        let counts = ufo_mac::ct::CtCounts::from_populations(&pp);
        bench.bench(&format!("assign_greedy_{n}bit"), || {
            ufo_mac::ct::assign_greedy(&counts)
        });
    }

    // Netlist encoding for the PJRT bridge.
    bench.bench("encode_netlist_16bit", || {
        ufo_mac::runtime::encode_netlist(nl).unwrap()
    });

    // Equivalence sampling batch (64 vectors incl. packing).
    let d8 = MultiplierSpec::new(8).build().unwrap();
    bench.bench("equiv_sampled_1k_8bit", || {
        ufo_mac::equiv::check_multiplier_with(&d8, 1024).unwrap()
    });

    // Unified-engine compile path: cold (fresh engine per call — pays the
    // full library/timing-model construction plus synthesis, the pre-API
    // per-call behaviour) vs cached (content-addressed hit on a warm
    // engine — the DSE-sweep steady state).
    let req = DesignRequest::multiplier(16);
    bench.bench("engine_compile_16bit_cold", || {
        let eng = SynthEngine::new(EngineConfig::default());
        eng.compile(&req).unwrap().sta.num_gates
    });
    let warm = SynthEngine::new(EngineConfig::default());
    warm.compile(&req).unwrap();
    bench.bench("engine_compile_16bit_cached", || {
        warm.compile(&req).unwrap().sta.num_gates
    });
    let s = warm.cache_stats();
    bench.metric("engine_cache_hit_rate_16bit", s.hit_rate(), "fraction");
}
