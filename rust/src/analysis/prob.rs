//! Static signal-probability and switching-activity propagation.
//!
//! Each node carries `P(node = 1)` under the uniform stimulus model the
//! measured path also uses: every primary input is an independent fair
//! coin redrawn each cycle ([`crate::sim::toggle_activity`] drives fresh
//! xorshift words per round/cycle). Propagation is Parker–McCluskey
//! style: for each gate the engine enumerates the concrete truth table of
//! a *window* of logic feeding it — every reconvergent path inside the
//! window is handled exactly, only the window frontier is assumed
//! independent. [`ProbDomain::depth`] caps how far below the gate the
//! window reaches (the correlation-depth cap) and
//! [`ProbDomain::sources`] caps the frontier width; a window that would
//! exceed the source cap falls back to `depth = 1`, i.e. the classic
//! independence assumption over the gate's (deduplicated) direct fanins.
//!
//! `depth = 1` never allocates, which is what makes the static estimate
//! cheap enough to replace the old constant-activity fallback on the
//! `activity_rounds == 0` fast path of [`crate::sta::Sta`].
//!
//! Registers iterate through the outer fixpoint: the abstract latch
//! `P(q') = P(clr)·init + (1−P(clr))·(P(en)·P(d) + (1−P(en))·P(q))` is a
//! convex combination, so probabilities stay in `[0,1]` whether or not
//! the iteration budget suffices for full convergence. The per-cycle
//! toggle estimate is [`switching_activity`]: `2·p·(1−p)`, the transition
//! probability of a signal resampled independently each cycle — exact for
//! combinational logic under the stimulus model above, an estimate for
//! state-correlated register cones.

use super::fixpoint::Domain;
use crate::ir::{CellKind, Netlist};

/// Signal-probability domain with a correlation window.
#[derive(Debug, Clone, Copy)]
pub struct ProbDomain {
    /// Correlation-depth cap: how many gate levels below a node the exact
    /// enumeration window extends. `1` = independence over direct fanins.
    pub depth: usize,
    /// Maximum window frontier width (enumeration is `2^sources` rows).
    pub sources: usize,
}

impl Default for ProbDomain {
    fn default() -> Self {
        ProbDomain { depth: 2, sources: 8 }
    }
}

/// Absolute register-probability change below which the outer fixpoint is
/// considered converged.
pub const PROB_EPSILON: f64 = 1e-12;

/// Per-cycle switching activity of a signal with 1-probability `p` under
/// independently resampled cycles: `2·p·(1−p)`.
pub fn switching_activity(prob: &[f64]) -> Vec<f64> {
    prob.iter().map(|&p| 2.0 * p * (1.0 - p)).collect()
}

/// Exact enumeration over the ≤3 *deduplicated* direct fanins of gate
/// `i`, treating them as independent. Allocation-free; also the fallback
/// when the deep window overflows its source cap. Deduplication makes
/// same-signal fanins exact (`xor2(x, x)` is 0, not `2p(1−p)`).
fn direct_prob(kind: CellKind, rec: [u32; 3], vals: &[f64]) -> f64 {
    let arity = kind.arity();
    // Dedup fanin ids into ≤3 sources; src_of[k] maps slot → source.
    let mut srcs = [0u32; 3];
    let mut n_src = 0usize;
    let mut src_of = [0usize; 3];
    for k in 0..arity {
        match srcs[..n_src].iter().position(|&s| s == rec[k]) {
            Some(j) => src_of[k] = j,
            None => {
                srcs[n_src] = rec[k];
                src_of[k] = n_src;
                n_src += 1;
            }
        }
    }
    let mut p1 = 0.0f64;
    for mask in 0..(1u32 << n_src) {
        let mut w = 1.0f64;
        for (j, &s) in srcs.iter().enumerate().take(n_src) {
            let p = vals[s as usize];
            w *= if (mask >> j) & 1 == 1 { p } else { 1.0 - p };
        }
        if w == 0.0 {
            continue;
        }
        let mut bits = [0u64; 3];
        for k in 0..arity {
            bits[k] = u64::from((mask >> src_of[k]) & 1);
        }
        if kind.eval(bits[0], bits[1], bits[2]) & 1 == 1 {
            p1 += w;
        }
    }
    p1.clamp(0.0, 1.0)
}

/// A collected enumeration window rooted at one gate: `cone` lists every
/// member ascending by node id (= topological order), `frontier[j]` is
/// the cone position of the j-th independent source, and `evals` replays
/// the interior gates in order.
struct Window {
    cone: Vec<u32>,
    frontier: Vec<usize>,
    evals: Vec<(usize, CellKind, [usize; 3])>,
    root: usize,
}

/// Collect the exact-enumeration window for gate `i`: expand gates
/// breadth-first up to `depth` levels below the root; everything else
/// reached (non-gates, or gates at the depth horizon) becomes frontier.
/// Returns `None` when the frontier would exceed `sources`.
fn window(nl: &Netlist, i: usize, depth: usize, sources: usize) -> Option<Window> {
    use std::collections::BTreeSet;
    let ops = nl.ops();
    let fan = nl.fanin_records();
    let mut interior: BTreeSet<u32> = BTreeSet::new();
    let mut frontier: BTreeSet<u32> = BTreeSet::new();
    interior.insert(i as u32);
    let mut ring = vec![i as u32];
    for d in 0..depth {
        let mut next = Vec::new();
        for &g in &ring {
            let kind = CellKind::ALL[ops[g as usize] as usize];
            for slot in 0..kind.arity() {
                let f = fan[g as usize][slot];
                if interior.contains(&f) || frontier.contains(&f) {
                    continue;
                }
                if ops[f as usize] <= 10 && d + 1 < depth {
                    interior.insert(f);
                    next.push(f);
                } else {
                    frontier.insert(f);
                    if frontier.len() > sources {
                        return None;
                    }
                }
            }
        }
        ring = next;
    }
    // Cone in ascending id order; ids are topological, so interior gates
    // replay correctly in this order.
    let cone: Vec<u32> = interior.iter().chain(frontier.iter()).copied().collect();
    let mut cone = cone;
    cone.sort_unstable();
    let pos = |id: u32| cone.binary_search(&id).expect("cone member");
    let frontier: Vec<usize> = frontier.iter().map(|&f| pos(f)).collect();
    let mut evals: Vec<(usize, CellKind, [usize; 3])> = Vec::with_capacity(interior.len());
    for &g in &interior {
        let kind = CellKind::ALL[ops[g as usize] as usize];
        let mut ops3 = [0usize; 3];
        for (slot, o) in ops3.iter_mut().enumerate().take(kind.arity()) {
            *o = pos(fan[g as usize][slot]);
        }
        evals.push((pos(g), kind, ops3));
    }
    evals.sort_unstable_by_key(|&(p, _, _)| p);
    Some(Window { frontier, evals, root: pos(i as u32), cone })
}

impl Domain for ProbDomain {
    type Value = f64;

    fn input(&self, _ordinal: usize) -> f64 {
        0.5
    }

    fn constant(&self, one: bool) -> f64 {
        if one {
            1.0
        } else {
            0.0
        }
    }

    fn reg_start(&self, init: bool) -> f64 {
        if init {
            1.0
        } else {
            0.0
        }
    }

    fn transfer(&self, nl: &Netlist, vals: &[f64], i: usize) -> f64 {
        let kind = CellKind::ALL[nl.ops()[i] as usize];
        let rec = nl.fanin_records()[i];
        if self.depth <= 1 {
            return direct_prob(kind, rec, vals);
        }
        let Some(win) = window(nl, i, self.depth, self.sources) else {
            return direct_prob(kind, rec, vals);
        };
        let s = win.frontier.len();
        let probs: Vec<f64> = win.frontier.iter().map(|&p| vals[win.cone[p] as usize]).collect();
        let mut bits = vec![0u8; win.cone.len()];
        let mut p1 = 0.0f64;
        for mask in 0..(1u64 << s) {
            let mut w = 1.0f64;
            for (j, &fp) in win.frontier.iter().enumerate() {
                let b = (mask >> j) & 1;
                w *= if b == 1 { probs[j] } else { 1.0 - probs[j] };
                bits[fp] = b as u8;
            }
            if w == 0.0 {
                continue;
            }
            for &(p, k, o) in &win.evals {
                bits[p] = (k.eval(
                    u64::from(bits[o[0]]),
                    u64::from(bits[o[1]]),
                    u64::from(bits[o[2]]),
                ) & 1) as u8;
            }
            if bits[win.root] == 1 {
                p1 += w;
            }
        }
        p1.clamp(0.0, 1.0)
    }

    fn latch(&self, d: f64, en: f64, clr: f64, q: f64, init: bool) -> f64 {
        let pi = if init { 1.0 } else { 0.0 };
        (clr * pi + (1.0 - clr) * (en * d + (1.0 - en) * q)).clamp(0.0, 1.0)
    }

    fn widen(&self, _old: f64, next: f64) -> f64 {
        next
    }

    fn converged(&self, old: f64, new: f64) -> bool {
        (old - new).abs() <= PROB_EPSILON
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::fixpoint;
    use crate::ir::Netlist;

    #[test]
    fn direct_probabilities_are_exact_for_independent_fanins() {
        let mut nl = Netlist::new("p");
        let x = nl.input("x");
        let y = nl.input("y");
        let a = nl.and2(x, y);
        let o = nl.or2(x, y);
        let xo = nl.xor2(x, y);
        nl.output("a", a);
        nl.output("o", o);
        nl.output("x", xo);
        let run = fixpoint::run(&nl, &ProbDomain { depth: 1, sources: 8 }, 1, 8);
        assert_eq!(run.values[x.index()], 0.5);
        assert!((run.values[a.index()] - 0.25).abs() < 1e-15);
        assert!((run.values[o.index()] - 0.75).abs() < 1e-15);
        assert!((run.values[xo.index()] - 0.5).abs() < 1e-15);
    }

    #[test]
    fn reconvergence_is_exact_inside_the_window() {
        // y = and2(x, inv(x)) ≡ 0. Independence (depth 1 at the and2 sees
        // two *distinct* fanins) predicts 0.25; a depth-2 window catches
        // the reconvergence and proves probability 0.
        let mut nl = Netlist::new("reconv");
        let x = nl.input("x");
        let nx = nl.inv(x);
        let y = nl.and2(x, nx);
        nl.output("y", y);
        let shallow = fixpoint::run(&nl, &ProbDomain { depth: 1, sources: 8 }, 1, 8);
        assert!((shallow.values[y.index()] - 0.25).abs() < 1e-15);
        let deep = fixpoint::run(&nl, &ProbDomain { depth: 2, sources: 8 }, 1, 8);
        assert_eq!(deep.values[y.index()], 0.0);
    }

    #[test]
    fn duplicate_fanins_are_exact_even_at_depth_one() {
        let mut nl = Netlist::new("dup");
        let x = nl.input("x");
        let y = nl.xor2(x, x); // ≡ 0
        let z = nl.and2(x, x); // ≡ x
        nl.output("y", y);
        nl.output("z", z);
        let run = fixpoint::run(&nl, &ProbDomain { depth: 1, sources: 8 }, 1, 8);
        assert_eq!(run.values[y.index()], 0.0);
        assert_eq!(run.values[z.index()], 0.5);
    }

    #[test]
    fn register_probability_stays_in_unit_interval() {
        let mut nl = Netlist::new("tff");
        let en = nl.input("en");
        let clr = nl.input("clr");
        let q = nl.reg_raw(0, en.0, clr.0, false);
        let nq = nl.inv(q);
        nl.set_reg_data(q, nq);
        nl.output("q", q);
        let run = fixpoint::run(&nl, &ProbDomain::default(), 1, 64);
        for (i, &p) in run.values.iter().enumerate() {
            assert!((0.0..=1.0).contains(&p), "node {i}: {p}");
        }
        assert!(run.sweeps > 1, "feedback register iterated");
        let act = switching_activity(&run.values);
        for a in &act {
            assert!((0.0..=0.5 + 1e-12).contains(a));
        }
    }
}
