//! Datapath lint passes (`UFO1xx`) and timing cross-checks (`UFO2xx`).
//!
//! These are domain-aware: they know what a compressor tree and a parallel
//! prefix adder are supposed to look like, and check the *evidence* a
//! build leaves behind (Algorithm-1 counts, the stage plan, recorded stage
//! arrival profiles, the prefix graphs, and the separate-MAC arrival
//! handoff) rather than re-deriving the datapath from gates.

use crate::cpa::{PrefixGraph, NONE};
use crate::ct::{CtCounts, StagePlan};

use super::report::{Diagnostic, Locus, UFO101, UFO102, UFO103, UFO104, UFO105, UFO201, UFO202};

/// Tolerance for arrival-time comparisons (ns). STA is deterministic
/// `f64` arithmetic, so this only needs to absorb association order.
pub const ARRIVAL_EPS_NS: f64 = 1e-9;

/// Check Algorithm-1 counts for internal consistency ([`UFO103`]).
///
/// This wraps [`CtCounts::validate`] into a diagnostic and is the cheap
/// always-on guard the RL-MUL / ILP candidate loops run on every sampled
/// compressor allocation before paying for timing evaluation.
pub fn check_counts(counts: &CtCounts) -> Vec<Diagnostic> {
    match counts.validate() {
        Ok(()) => Vec::new(),
        Err(e) => vec![Diagnostic::new(UFO103, Locus::Design, format!("Algorithm-1 counts invalid: {e}"))],
    }
}

/// Simulate a [`StagePlan`] over initial column populations and check the
/// per-stage weight bookkeeping.
///
/// Emits [`UFO105`] for infeasible slices (a stage schedules more
/// compressor inputs than the column holds), [`UFO101`] for weight leaks
/// (carries scheduled out of the top column, or ragged plan rows that make
/// the bookkeeping undefined), and [`UFO102`] for columns still holding
/// more than two bits after the final stage.
pub fn check_plan(initial: &[usize], plan: &StagePlan) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let w = plan.width();
    if plan.h.len() != plan.f.len() {
        diags.push(Diagnostic::new(
            UFO101,
            Locus::Design,
            format!("plan has {} f-stages but {} h-stages", plan.f.len(), plan.h.len()),
        ));
        return diags;
    }
    if initial.len() > w {
        diags.push(Diagnostic::new(
            UFO101,
            Locus::Design,
            format!("plan width {w} narrower than the {} input columns", initial.len()),
        ));
        return diags;
    }
    for (i, (fr, hr)) in plan.f.iter().zip(plan.h.iter()).enumerate() {
        if fr.len() != w || hr.len() != w {
            diags.push(Diagnostic::new(
                UFO101,
                Locus::Stage { stage: i, column: 0 },
                format!("stage {i}: ragged rows ({}×f, {}×h, plan width {w})", fr.len(), hr.len()),
            ));
            return diags;
        }
    }
    let mut pop = vec![0usize; w];
    pop[..initial.len()].copy_from_slice(initial);
    for i in 0..plan.stages() {
        let mut next = pop.clone();
        for j in 0..w {
            let (fij, hij) = (plan.f[i][j], plan.h[i][j]);
            if fij == 0 && hij == 0 {
                continue;
            }
            if 3 * fij + 2 * hij > pop[j] {
                diags.push(Diagnostic::new(
                    UFO105,
                    Locus::Stage { stage: i, column: j },
                    format!(
                        "stage {i} col {j}: {fij}×3:2 + {hij}×2:2 need {} bits, column holds {}",
                        3 * fij + 2 * hij,
                        pop[j]
                    ),
                ));
                continue;
            }
            // A 3:2 turns 3 bits into 1 sum + 1 carry; a 2:2 turns 2 bits
            // into 1 + 1. Sum bits stay in column j, carries move to j+1.
            next[j] -= 2 * fij + hij;
            if j + 1 < w {
                next[j + 1] += fij + hij;
            } else {
                diags.push(Diagnostic::new(
                    UFO101,
                    Locus::Stage { stage: i, column: j },
                    format!(
                        "stage {i} col {j}: {} carries leak past the plan width {w} — bit weight 2^{w} is silently dropped",
                        fij + hij
                    ),
                ));
            }
        }
        pop = next;
    }
    for (j, &p) in pop.iter().enumerate() {
        if p > 2 {
            diags.push(Diagnostic::new(
                UFO102,
                Locus::Column(j),
                format!("column {j} still holds {p} bits after the final stage (CPA accepts at most 2)"),
            ));
        }
    }
    diags
}

/// Check a stage plan against the Algorithm-1 counts it claims to
/// implement: runs [`check_counts`] and [`check_plan`], then compares
/// per-column compressor totals ([`UFO103`]).
pub fn check_plan_counts(counts: &CtCounts, plan: &StagePlan) -> Vec<Diagnostic> {
    let mut diags = check_counts(counts);
    diags.extend(check_plan(&counts.initial, plan));
    let w = plan.width();
    for j in 0..w.min(counts.width()) {
        let (tf, th): (usize, usize) =
            (0..plan.stages()).map(|i| (plan.f[i][j], plan.h[i][j])).fold((0, 0), |a, x| {
                (a.0 + x.0, a.1 + x.1)
            });
        let (cf, ch) = (counts.f[j], counts.h[j]);
        if (tf, th) != (cf, ch) {
            diags.push(Diagnostic::new(
                UFO103,
                Locus::Column(j),
                format!("column {j}: plan schedules {tf}×3:2 + {th}×2:2, Algorithm 1 requires {cf} + {ch}"),
            ));
        }
    }
    diags
}

/// Check a CPA prefix graph for coverage and contiguity ([`UFO104`]).
///
/// Every output bit must have a root computing the prefix over
/// `[bit:0]`; every internal node must combine an adjacent
/// (trivial-fanin, non-trivial-fanin) pair of earlier nodes. This is
/// [`PrefixGraph::validate`] re-expressed as per-locus diagnostics so a
/// gapped graph reports every gap, not just the first.
pub fn check_prefix(g: &PrefixGraph) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    for (i, nd) in g.nodes.iter().enumerate() {
        if nd.is_leaf() {
            if nd.ntf != NONE || nd.msb != nd.lsb {
                diags.push(Diagnostic::new(
                    UFO104,
                    Locus::Bit(nd.msb),
                    format!("prefix node {i}: malformed leaf [{}:{}]", nd.msb, nd.lsb),
                ));
            }
            continue;
        }
        if nd.tf >= i || nd.ntf >= i {
            diags.push(Diagnostic::new(
                UFO104,
                Locus::Bit(nd.msb),
                format!("prefix node {i}: fan-in is not an earlier node"),
            ));
            continue;
        }
        let (tf, ntf) = (&g.nodes[nd.tf], &g.nodes[nd.ntf]);
        if tf.msb != nd.msb || ntf.lsb != nd.lsb || tf.lsb != ntf.msb + 1 {
            diags.push(Diagnostic::new(
                UFO104,
                Locus::Bit(nd.msb),
                format!(
                    "prefix node {i} [{}:{}] is not the adjacent combine of [{}:{}] and [{}:{}]",
                    nd.msb, nd.lsb, tf.msb, tf.lsb, ntf.msb, ntf.lsb
                ),
            ));
        }
    }
    for bit in 0..g.n {
        match g.roots.get(bit).copied() {
            None | Some(NONE) => diags.push(Diagnostic::new(
                UFO104,
                Locus::Bit(bit),
                format!("bit {bit}: no root computes its carry (prefix coverage gap)"),
            )),
            Some(r) if r >= g.nodes.len() => diags.push(Diagnostic::new(
                UFO104,
                Locus::Bit(bit),
                format!("bit {bit}: root index {r} out of range"),
            )),
            Some(r) => {
                let nd = &g.nodes[r];
                if nd.msb != bit || nd.lsb != 0 {
                    diags.push(Diagnostic::new(
                        UFO104,
                        Locus::Bit(bit),
                        format!("bit {bit}: root covers [{}:{}], want [{bit}:0]", nd.msb, nd.lsb),
                    ));
                }
            }
        }
    }
    diags
}

/// Check the bits-per-column record of the built CT's final rows: every
/// column must hold at most two bits for the CPA to accept it
/// ([`UFO102`]).
pub fn check_final_rows(final_rows: &[usize]) -> Vec<Diagnostic> {
    final_rows
        .iter()
        .enumerate()
        .filter(|&(_, &r)| r > 2)
        .map(|(j, &r)| {
            Diagnostic::new(
                UFO102,
                Locus::Column(j),
                format!("built CT hands {r} bits in column {j} to the CPA (max 2)"),
            )
        })
        .collect()
}

/// Check recorded per-stage arrival snapshots for sane timing values
/// ([`UFO202`]) and consistent widths across stages ([`UFO101`]).
pub fn check_stage_profiles(stage_profiles: &[Vec<f64>]) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let w = stage_profiles.first().map_or(0, Vec::len);
    for (i, snap) in stage_profiles.iter().enumerate() {
        if snap.len() != w {
            diags.push(Diagnostic::new(
                UFO101,
                Locus::Stage { stage: i, column: 0 },
                format!("stage {i} snapshot has {} columns, stage 0 has {w}", snap.len()),
            ));
        }
        for (j, &t) in snap.iter().enumerate() {
            if !t.is_finite() || t < 0.0 {
                diags.push(Diagnostic::new(
                    UFO202,
                    Locus::Stage { stage: i, column: j },
                    format!("stage {i} col {j}: arrival {t} ns is not a valid time"),
                ));
            }
        }
    }
    diags
}

/// Cross-check the separate-MAC second-CPA arrival handoff ([`UFO201`]).
///
/// `measured` is the STA arrival profile read off the first CPA's sum
/// bits when the second CPA was synthesized; `basis` is the profile that
/// was actually handed to the prefix optimizer; `recomputed` is the same
/// set of sum-bit arrivals re-derived from the *final* netlist. The PR-3
/// bug class — synthesizing the second CPA against a profile that is not
/// the first CPA's — shows up as `basis` dropping below `measured`, and a
/// stale `measured` shows up as exceeding `recomputed` (adding the second
/// CPA only ever increases load, so real arrivals never shrink).
pub fn check_mac_profile(
    measured: &[f64],
    basis: &[f64],
    recomputed: &[f64],
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    if basis.len() != measured.len() || recomputed.len() != measured.len() {
        diags.push(Diagnostic::new(
            UFO201,
            Locus::Design,
            format!(
                "second-CPA profile width mismatch: {} measured, {} basis, {} recomputed",
                measured.len(),
                basis.len(),
                recomputed.len()
            ),
        ));
        return diags;
    }
    for j in 0..measured.len() {
        if basis[j] + ARRIVAL_EPS_NS < measured[j] {
            diags.push(Diagnostic::new(
                UFO201,
                Locus::Bit(j),
                format!(
                    "bit {j}: second CPA was optimized for arrival {:.4} ns but the first CPA delivers {:.4} ns",
                    basis[j], measured[j]
                ),
            ));
        }
        if measured[j] > recomputed[j] + ARRIVAL_EPS_NS {
            diags.push(Diagnostic::new(
                UFO201,
                Locus::Bit(j),
                format!(
                    "bit {j}: recorded first-CPA arrival {:.4} ns exceeds the netlist's own {:.4} ns",
                    measured[j], recomputed[j]
                ),
            ));
        }
    }
    diags
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.code).collect()
    }

    #[test]
    fn valid_counts_and_plan_are_clean() {
        let pops = [1usize, 2, 3, 2, 1];
        let counts = CtCounts::from_populations(&pops);
        let plan = crate::ct::assign_greedy(&counts);
        assert!(check_plan_counts(&counts, &plan).is_empty());
    }

    #[test]
    fn weight_leak_and_overfull_column_are_flagged() {
        // One column of 3 bits, plan width 1: the 3:2's carry has nowhere
        // to go.
        let plan = StagePlan { f: vec![vec![1]], h: vec![vec![0]] };
        let diags = check_plan(&[3], &plan);
        assert_eq!(codes(&diags), [UFO101]);
        // No compression at all: column keeps its 3 bits.
        let lazy = StagePlan { f: vec![vec![0, 0]], h: vec![vec![0, 0]] };
        assert_eq!(codes(&check_plan(&[3, 0], &lazy)), [UFO102]);
    }

    #[test]
    fn infeasible_slice_is_flagged() {
        let plan = StagePlan { f: vec![vec![2, 0]], h: vec![vec![0, 0]] };
        let diags = check_plan(&[3, 1], &plan);
        assert_eq!(codes(&diags), [UFO105]);
    }

    #[test]
    fn totals_mismatch_is_flagged() {
        let counts = CtCounts::from_populations(&[3, 1]);
        // Plan that compresses with a 2:2 where Algorithm 1 wants a 3:2.
        let plan = StagePlan { f: vec![vec![0, 0]], h: vec![vec![1, 0]] };
        let diags = check_plan_counts(&counts, &plan);
        assert!(diags.iter().any(|d| d.code == UFO103), "{diags:?}");
    }

    #[test]
    fn gapped_prefix_graph_reports_every_gap() {
        let mut g = PrefixGraph::leaves(4);
        let r1 = g.combine(1, 0);
        g.roots[1] = r1;
        g.roots[2] = NONE; // gap
        g.roots[3] = NONE; // gap
        let diags = check_prefix(&g);
        assert_eq!(codes(&diags), [UFO104, UFO104]);
        assert_eq!(diags[0].locus, crate::lint::Locus::Bit(2));
    }

    #[test]
    fn bad_profiles_are_flagged() {
        assert!(check_stage_profiles(&[vec![0.0, 0.1]]).is_empty());
        let diags = check_stage_profiles(&[vec![0.0, f64::NAN], vec![0.0]]);
        assert_eq!(codes(&diags), [UFO202, UFO101]);
    }

    #[test]
    fn mac_profile_mismatch_is_flagged() {
        let measured = [0.5, 0.7];
        let recomputed = [0.5, 0.7];
        assert!(check_mac_profile(&measured, &[0.5, 0.7], &recomputed).is_empty());
        // PR-3 bug class: second CPA synthesized against uniform zeros.
        let diags = check_mac_profile(&measured, &[0.0, 0.0], &recomputed);
        assert_eq!(codes(&diags), [UFO201, UFO201]);
        // Stale recording: netlist says arrivals are earlier than recorded.
        let diags = check_mac_profile(&measured, &[0.5, 0.7], &[0.5, 0.3]);
        assert_eq!(codes(&diags), [UFO201]);
    }
}
