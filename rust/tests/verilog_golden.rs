//! Golden-file snapshot tests for the Verilog backend.
//!
//! Each test renders a design to SystemVerilog and compares the text
//! byte-for-byte against a committed snapshot under `tests/golden/`. A
//! missing snapshot is **blessed**: the rendered text is written to the
//! golden path and the test passes, so the first run on a machine with a
//! toolchain creates the files to commit (see `tests/golden/README.md`).
//! Set `UFO_UPDATE_GOLDEN=1` to re-bless after an intentional backend
//! change; the diff then shows up in review as a change to the `.sv`
//! files themselves.
//!
//! Structural invariants (ports, `always_ff` count, combinational purity)
//! are asserted unconditionally — they hold even on a blessing run, so a
//! backend regression cannot silently bless itself in.

use std::path::PathBuf;
use ufo_mac::multiplier::MultiplierSpec;
use ufo_mac::synth::verilog;

fn golden_dir() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden"))
}

/// Compare `rendered` against the snapshot `name`, blessing it when the
/// file is absent or `UFO_UPDATE_GOLDEN=1` is set.
fn assert_matches_golden(name: &str, rendered: &str) {
    let path = golden_dir().join(name);
    let bless = std::env::var_os("UFO_UPDATE_GOLDEN").is_some_and(|v| v == "1");
    if bless || !path.exists() {
        std::fs::create_dir_all(golden_dir()).unwrap();
        std::fs::write(&path, rendered).unwrap();
        eprintln!("blessed golden snapshot {}", path.display());
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    if rendered != want {
        // Locate the first diverging line for a readable failure.
        let mut line = 1usize;
        for (g, w) in rendered.lines().zip(want.lines()) {
            if g != w {
                panic!(
                    "golden mismatch {} at line {line}:\n  got:  {g}\n  want: {w}\n\
                     re-bless with UFO_UPDATE_GOLDEN=1 if the change is intentional",
                    path.display()
                );
            }
            line += 1;
        }
        panic!(
            "golden mismatch {}: lengths differ ({} vs {} bytes); \
             re-bless with UFO_UPDATE_GOLDEN=1 if the change is intentional",
            path.display(),
            rendered.len(),
            want.len()
        );
    }
}

#[test]
fn golden_pipelined_mac_16x16() {
    let design = MultiplierSpec::new(16).fused_mac(true).pipeline_stages(2).build().unwrap();
    let v = verilog::emit_design(&design);

    // Unconditional structural invariants.
    assert!(v.contains("// pipeline: 2 stage(s)"), "{v:.200}");
    assert!(v.contains("input  wire clk"), "{v:.200}");
    assert!(v.contains("input  wire rst_n"), "{v:.200}");
    assert_eq!(
        v.matches("always_ff @(posedge clk or negedge rst_n)").count(),
        1,
        "all pipeline registers share one (en, clr) group"
    );
    assert!(v.contains("if (!rst_n) begin"), "async reset branch comes first");
    assert_eq!(v.matches("endmodule").count(), 1);

    assert_matches_golden("mac16x16_p2.sv", &v);
}

#[test]
fn golden_combinational_multiplier_8x8() {
    let design = MultiplierSpec::new(8).build().unwrap();
    let v = verilog::emit_design(&design);

    // A combinational design must stay free of any sequential artifacts.
    assert!(!v.contains("clk"), "{v:.200}");
    assert!(!v.contains("always_ff"), "{v:.200}");
    assert!(!v.contains(" reg "), "{v:.200}");
    assert_eq!(v.matches("endmodule").count(), 1);

    assert_matches_golden("mul8x8_comb.sv", &v);
}
