//! Design-space-exploration coordinator.
//!
//! Orchestrates the experiment sweeps behind the paper's Pareto plots and
//! tables: fan out (method × width × strategy) generation jobs over a
//! thread pool, evaluate each design with the STA engine (and optionally
//! verify it through the PJRT netlist-eval artifact), extract Pareto
//! frontiers, and persist JSON reports.

pub mod pool;

use crate::api::{DesignArtifact, DesignRequest, EngineConfig, MethodRequest, SynthEngine};
use crate::baselines::{BaselineBudget, Method};
use crate::multiplier::Strategy;
use crate::ppg::Signedness;
use crate::runtime::Runtime;
use crate::util::Json;
use crate::Result;
use std::path::Path;

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    /// Method family of the point.
    pub method: Method,
    /// Operand bit width.
    pub n: usize,
    /// Synthesis strategy preset.
    pub strategy: Strategy,
    /// Fused-MAC variant.
    pub mac: bool,
    /// Two's-complement operand interpretation.
    pub signed: bool,
    /// STA critical delay (ns).
    pub delay_ns: f64,
    /// Cell area (µm²).
    pub area_um2: f64,
    /// Dynamic power (mW).
    pub power_mw: f64,
    /// Gate count.
    pub num_gates: usize,
    /// Realized compressor-tree stages.
    pub ct_stages: usize,
    /// Simulator-based equivalence result.
    pub verified: bool,
    /// PJRT artifact cross-check (None if artifacts unavailable).
    pub pjrt_verified: Option<bool>,
}

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Bit widths to sweep.
    pub widths: Vec<usize>,
    /// Method families to sweep.
    pub methods: Vec<Method>,
    /// Strategy presets to sweep.
    pub strategies: Vec<Strategy>,
    /// Sweep the fused-MAC variant instead of plain multipliers.
    pub mac: bool,
    /// Operand signednesses to sweep (the format axis).
    pub signedness: Vec<Signedness>,
    /// Thread-pool width for the batch compile.
    pub workers: usize,
    /// Search budget for the search-based baselines.
    pub budget: BaselineBudget,
    /// Sampled-equivalence vector budget for non-exhaustive widths.
    pub verify_vectors: usize,
    /// Cross-check through PJRT when artifacts exist.
    pub use_pjrt: bool,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            widths: vec![8, 16, 32],
            methods: Method::ALL.to_vec(),
            strategies: vec![
                Strategy::AreaDriven,
                Strategy::TimingDriven,
                Strategy::TradeOff,
            ],
            mac: false,
            signedness: vec![Signedness::Unsigned],
            workers: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
            budget: BaselineBudget::default(),
            verify_vectors: 1 << 12,
            use_pjrt: false,
        }
    }
}

/// The request grid a sweep compiles (method × width × strategy ×
/// signedness).
pub fn sweep_requests(cfg: &SweepConfig) -> Vec<DesignRequest> {
    let mut reqs = Vec::new();
    for &n in &cfg.widths {
        for &m in &cfg.methods {
            for &s in &cfg.strategies {
                for &sg in &cfg.signedness {
                    reqs.push(DesignRequest::Method(MethodRequest {
                        method: m,
                        n,
                        signedness: sg,
                        strategy: s,
                        mac: cfg.mac,
                        budget: cfg.budget,
                    }));
                }
            }
        }
    }
    reqs
}

/// Project an engine artifact onto a sweep row.
fn point_from_artifact(
    method: Method,
    n: usize,
    strategy: Strategy,
    mac: bool,
    signed: bool,
    art: &DesignArtifact,
) -> DesignPoint {
    let ct_stages = art.design().map(|d| d.ct_stages).unwrap_or(0);
    DesignPoint {
        method,
        n,
        strategy,
        mac,
        signed,
        delay_ns: art.sta.critical_delay_ns,
        area_um2: art.sta.area_um2,
        power_mw: art.sta.power_mw,
        num_gates: art.sta.num_gates,
        ct_stages,
        verified: art.verified.unwrap_or(false),
        pjrt_verified: art.pjrt_verified,
    }
}

/// Evaluate one (method, width, strategy) point.
///
/// Shim over the unified engine (the design itself is served from the
/// process-global cache); the per-call `verify_vectors` / `rt` knobs are
/// honoured locally. New code should use [`run_sweep_with`] or compile a
/// [`DesignRequest`] directly.
pub fn evaluate_point(
    method: Method,
    n: usize,
    strategy: Strategy,
    mac: bool,
    budget: &BaselineBudget,
    verify_vectors: usize,
    rt: Option<&Runtime>,
) -> Result<DesignPoint> {
    evaluate_point_fmt(method, n, Signedness::Unsigned, strategy, mac, budget, verify_vectors, rt)
}

/// [`evaluate_point`] with an explicit operand signedness — the
/// single-point counterpart of the sweep grid's format axis.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_point_fmt(
    method: Method,
    n: usize,
    signedness: Signedness,
    strategy: Strategy,
    mac: bool,
    budget: &BaselineBudget,
    verify_vectors: usize,
    rt: Option<&Runtime>,
) -> Result<DesignPoint> {
    let req = DesignRequest::Method(MethodRequest {
        method,
        n,
        signedness,
        strategy,
        mac,
        budget: *budget,
    });
    let art = crate::api::engine().compile(&req)?;
    let design = art.design().expect("method artifact carries a design");
    // threads: 1 — sweep points already run on the coordinator's worker
    // pool; a parallel inner verify would oversubscribe the cores. The
    // lane width rides the process-wide default (wide sweeps are a pure
    // throughput knob; reports are width-independent).
    let equiv = crate::equiv::check_multiplier_opts(
        design,
        &crate::equiv::EquivOptions { budget: verify_vectors, threads: 1, ..Default::default() },
    )?;
    let pjrt_verified = match rt {
        Some(rt) if rt.has_artifact("netlist_eval_small") => {
            crate::runtime::verify_design_pjrt(rt, design, 1).ok()
        }
        _ => art.pjrt_verified,
    };
    let mut p =
        point_from_artifact(method, n, strategy, mac, signedness == Signedness::Signed, &art);
    p.verified = equiv.passed;
    p.pjrt_verified = pjrt_verified;
    Ok(p)
}

/// Run a full sweep through a caller-provided engine: one
/// [`SynthEngine::compile_batch`] fan-out over the request grid. Rows come
/// back in grid order; failed compiles are dropped.
///
/// Re-running the same sweep on the same engine serves every design from
/// the content-addressed cache (`engine.cache_stats()` shows the hits).
///
/// `DesignPoint::verified` reports the engine's per-compile equivalence
/// check, so configure the engine with `verify_vectors > 0` (as
/// [`run_sweep`] does from `cfg.verify_vectors`); on an engine that skips
/// verification every row reports `verified: false` ("not known good"),
/// not "checked and failed".
pub fn run_sweep_with(engine: &SynthEngine, cfg: &SweepConfig) -> Vec<DesignPoint> {
    let reqs = sweep_requests(cfg);
    let arts = engine.compile_batch(&reqs);
    let mut out = Vec::with_capacity(arts.len());
    for (req, art) in reqs.iter().zip(arts) {
        let (m, n, s, mac, sg) = match req {
            DesignRequest::Method(mr) => {
                (mr.method, mr.n, mr.strategy, mr.mac, mr.signedness)
            }
            _ => unreachable!("sweep grid is method requests"),
        };
        if let Ok(art) = art {
            out.push(point_from_artifact(m, n, s, mac, sg == Signedness::Signed, &art));
        }
    }
    out
}

/// Compile one grid request through `engine` and project the artifact
/// onto a sweep row. This is the single-point unit of work behind
/// [`run_sweep_with_progress`] and the server's yielding `sweep` jobs
/// (which compile one point per scheduler slot so urgent requests can
/// preempt between points).
pub fn compile_point(engine: &SynthEngine, req: &DesignRequest) -> Result<DesignPoint> {
    let DesignRequest::Method(mr) = req else {
        anyhow::bail!("sweep grids contain method requests only");
    };
    let art = engine.compile(req)?;
    Ok(point_from_artifact(
        mr.method,
        mr.n,
        mr.strategy,
        mr.mac,
        mr.signedness == Signedness::Signed,
        &art,
    ))
}

/// [`run_sweep_with`], one point at a time, reporting per-point progress:
/// `progress(done, total, point)` fires after each grid request, in grid
/// order, with `point: None` for a failed compile (the row is dropped
/// from the result, as in [`run_sweep_with`]). This is the callback
/// surface behind the server's streamed `sweep` (`stream: true` in
/// `PROTOCOL.md`), where each completed point becomes one
/// `{"event":"progress",…}` frame.
pub fn run_sweep_with_progress<F>(
    engine: &SynthEngine,
    cfg: &SweepConfig,
    mut progress: F,
) -> Vec<DesignPoint>
where
    F: FnMut(usize, usize, Option<&DesignPoint>),
{
    let reqs = sweep_requests(cfg);
    let total = reqs.len();
    let mut out = Vec::with_capacity(total);
    for (i, req) in reqs.iter().enumerate() {
        match compile_point(engine, req) {
            Ok(p) => {
                progress(i + 1, total, Some(&p));
                out.push(p);
            }
            Err(_) => progress(i + 1, total, None),
        }
    }
    out
}

/// Run a full sweep in parallel on a fresh engine configured from `cfg`
/// (verification budget, PJRT cross-check, workers).
pub fn run_sweep(cfg: &SweepConfig) -> Vec<DesignPoint> {
    let engine = SynthEngine::new(EngineConfig {
        verify_vectors: cfg.verify_vectors,
        use_pjrt: cfg.use_pjrt,
        workers: cfg.workers,
        ..EngineConfig::default()
    });
    run_sweep_with(&engine, cfg)
}

/// Indices of the (delay, area) Pareto frontier, sorted by delay.
pub fn pareto_front(points: &[DesignPoint]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        points[a]
            .delay_ns
            .partial_cmp(&points[b].delay_ns)
            .unwrap()
            .then(points[a].area_um2.partial_cmp(&points[b].area_um2).unwrap())
    });
    let mut front = Vec::new();
    let mut best_area = f64::INFINITY;
    for i in idx {
        if points[i].area_um2 < best_area - 1e-9 {
            best_area = points[i].area_um2;
            front.push(i);
        }
    }
    front
}

/// True iff `a` Pareto-dominates `b` (≤ in both, < in one).
pub fn dominates(a: &DesignPoint, b: &DesignPoint) -> bool {
    a.delay_ns <= b.delay_ns + 1e-12
        && a.area_um2 <= b.area_um2 + 1e-9
        && (a.delay_ns < b.delay_ns - 1e-12 || a.area_um2 < b.area_um2 - 1e-9)
}

/// Serialize one design point (also the `point` payload of streamed
/// sweep progress frames).
pub fn point_json(p: &DesignPoint) -> Json {
    Json::obj(vec![
        ("method", Json::str(p.method.name())),
        ("n", Json::num(p.n as f64)),
        ("strategy", Json::str(format!("{:?}", p.strategy))),
        ("mac", Json::Bool(p.mac)),
        ("signed", Json::Bool(p.signed)),
        ("delay_ns", Json::num(p.delay_ns)),
        ("area_um2", Json::num(p.area_um2)),
        ("power_mw", Json::num(p.power_mw)),
        ("num_gates", Json::num(p.num_gates as f64)),
        ("ct_stages", Json::num(p.ct_stages as f64)),
        ("verified", Json::Bool(p.verified)),
        (
            "pjrt_verified",
            match p.pjrt_verified {
                Some(v) => Json::Bool(v),
                None => Json::Null,
            },
        ),
    ])
}

/// Serialize points as a JSON report.
pub fn points_json(points: &[DesignPoint]) -> Json {
    Json::arr(points.iter().map(point_json).collect())
}

/// Persist a JSON report under `dir`.
pub fn save_report(dir: impl AsRef<Path>, name: &str, json: &Json) -> Result<()> {
    std::fs::create_dir_all(dir.as_ref())?;
    let path = dir.as_ref().join(format!("{name}.json"));
    std::fs::write(&path, json.render())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evaluate_point_verifies_and_reports() {
        let p = evaluate_point(
            Method::UfoMac,
            8,
            Strategy::TradeOff,
            false,
            &BaselineBudget { rlmul_iters: 4, seed: 3 },
            1 << 10,
            None,
        )
        .unwrap();
        assert!(p.verified);
        assert!(p.delay_ns > 0.0 && p.area_um2 > 0.0);
    }

    #[test]
    fn sweep_covers_grid() {
        let cfg = SweepConfig {
            widths: vec![4],
            methods: vec![Method::UfoMac, Method::Gomil],
            strategies: vec![Strategy::TradeOff],
            mac: false,
            workers: 2,
            budget: BaselineBudget { rlmul_iters: 2, seed: 1 },
            verify_vectors: 256,
            use_pjrt: false,
            ..Default::default()
        };
        let points = run_sweep(&cfg);
        assert_eq!(points.len(), 2);
        assert!(points.iter().all(|p| p.verified));
    }

    #[test]
    fn progress_sweep_reports_monotone_points_and_matches_batch_sweep() {
        let cfg = SweepConfig {
            widths: vec![4],
            methods: vec![Method::UfoMac, Method::Gomil],
            strategies: vec![Strategy::TradeOff],
            budget: BaselineBudget { rlmul_iters: 2, seed: 1 },
            verify_vectors: 256,
            ..Default::default()
        };
        let engine = SynthEngine::new(EngineConfig {
            verify_vectors: cfg.verify_vectors,
            ..EngineConfig::default()
        });
        let mut seen: Vec<(usize, usize)> = Vec::new();
        let streamed = run_sweep_with_progress(&engine, &cfg, |done, total, point| {
            assert!(point.is_some());
            seen.push((done, total));
        });
        assert_eq!(seen, vec![(1, 2), (2, 2)]);
        // Same rows (and the same serialized report) as the batch fan-out.
        let batch = run_sweep_with(&engine, &cfg);
        assert_eq!(points_json(&streamed).render(), points_json(&batch).render());
    }

    #[test]
    fn sweep_format_axis_doubles_the_grid() {
        let cfg = SweepConfig {
            widths: vec![4],
            methods: vec![Method::UfoMac],
            strategies: vec![Strategy::TradeOff],
            signedness: vec![Signedness::Unsigned, Signedness::Signed],
            mac: true,
            workers: 2,
            budget: BaselineBudget { rlmul_iters: 2, seed: 1 },
            verify_vectors: 256,
            use_pjrt: false,
        };
        assert_eq!(sweep_requests(&cfg).len(), 2);
        let points = run_sweep(&cfg);
        assert_eq!(points.len(), 2);
        assert!(points.iter().all(|p| p.verified), "{points:?}");
        assert!(points.iter().any(|p| p.signed) && points.iter().any(|p| !p.signed));
    }

    #[test]
    fn pareto_front_is_monotone() {
        let mk = |d: f64, a: f64| DesignPoint {
            method: Method::UfoMac,
            n: 8,
            strategy: Strategy::TradeOff,
            mac: false,
            signed: false,
            delay_ns: d,
            area_um2: a,
            power_mw: 0.0,
            num_gates: 0,
            ct_stages: 0,
            verified: true,
            pjrt_verified: None,
        };
        let pts = vec![mk(1.0, 10.0), mk(2.0, 5.0), mk(1.5, 20.0), mk(3.0, 4.0), mk(0.5, 30.0)];
        let front = pareto_front(&pts);
        // Front: (0.5,30) (1.0,10) (2.0,5) (3.0,4); (1.5,20) dominated.
        assert_eq!(front.len(), 4);
        assert!(!front.contains(&2));
        // strictly decreasing area along increasing delay
        for w in front.windows(2) {
            assert!(pts[w[0]].delay_ns <= pts[w[1]].delay_ns);
            assert!(pts[w[0]].area_um2 > pts[w[1]].area_um2);
        }
    }

    #[test]
    fn dominates_semantics() {
        let mk = |d: f64, a: f64| DesignPoint {
            method: Method::UfoMac,
            n: 8,
            strategy: Strategy::TradeOff,
            mac: false,
            signed: false,
            delay_ns: d,
            area_um2: a,
            power_mw: 0.0,
            num_gates: 0,
            ct_stages: 0,
            verified: true,
            pjrt_verified: None,
        };
        assert!(dominates(&mk(1.0, 1.0), &mk(2.0, 2.0)));
        assert!(dominates(&mk(1.0, 1.0), &mk(1.0, 2.0)));
        assert!(!dominates(&mk(1.0, 3.0), &mk(2.0, 2.0)));
        assert!(!dominates(&mk(1.0, 1.0), &mk(1.0, 1.0)));
    }

    #[test]
    fn report_serializes() {
        let p = evaluate_point(
            Method::Commercial,
            4,
            Strategy::AreaDriven,
            false,
            &BaselineBudget { rlmul_iters: 2, seed: 2 },
            256,
            None,
        )
        .unwrap();
        let j = points_json(&[p]);
        let s = j.render();
        assert!(s.contains("Commercial IP"));
        assert!(s.contains("delay_ns"));
    }
}
