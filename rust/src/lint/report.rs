//! Diagnostic types of the lint subsystem: codes, severities, loci,
//! [`Diagnostic`] records and the [`LintReport`] container that travels on
//! compile artifacts and over the wire. `LINTS.md` at the repository root
//! is the human-facing catalog; [`CODES`] is its machine-readable twin.

use crate::util::Json;

/// How bad a finding is. Ordered: `Info < Warning < Error`, so severity
/// thresholds compare directly (`d.severity >= deny`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Stylistic / informational — never fails a gate by default.
    Info,
    /// Suspicious but not provably wrong.
    Warning,
    /// A structural or datapath invariant is violated; the design is
    /// malformed.
    Error,
}

impl Severity {
    /// Stable machine-readable key (wire + persistence form).
    pub fn key(&self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }

    /// Strict parse of [`Severity::key`] — unknown names are an error
    /// listing the valid values.
    pub fn from_key(s: &str) -> Result<Severity, String> {
        match s {
            "info" => Ok(Severity::Info),
            "warning" => Ok(Severity::Warning),
            "error" => Ok(Severity::Error),
            other => Err(format!("unknown severity '{other}' (valid: error, info, warning)")),
        }
    }
}

/// Where a diagnostic points: a netlist node, an output slot, a CT stage ×
/// column slice, a column, a CPA bit — or the design as a whole.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Locus {
    /// The design as a whole (no narrower locus applies).
    Design,
    /// A netlist node by id.
    Node(u32),
    /// A primary output by registration index.
    Output(usize),
    /// A compressor-tree slice: stage `stage`, column `column`.
    Stage {
        /// Stage index (0-based).
        stage: usize,
        /// Column index (bit weight).
        column: usize,
    },
    /// A compressor-tree column (bit weight).
    Column(usize),
    /// A CPA output bit.
    Bit(usize),
}

impl Locus {
    /// Stable machine-readable key: `design`, `node:<id>`, `output:<i>`,
    /// `stage:<i>:<j>`, `col:<j>`, `bit:<i>`.
    pub fn key(&self) -> String {
        match self {
            Locus::Design => "design".to_string(),
            Locus::Node(id) => format!("node:{id}"),
            Locus::Output(i) => format!("output:{i}"),
            Locus::Stage { stage, column } => format!("stage:{stage}:{column}"),
            Locus::Column(j) => format!("col:{j}"),
            Locus::Bit(i) => format!("bit:{i}"),
        }
    }

    /// Parse the [`Locus::key`] form back.
    pub fn from_key(s: &str) -> Result<Locus, String> {
        let bad = |s: &str| format!("unparsable locus '{s}'");
        if s == "design" {
            return Ok(Locus::Design);
        }
        let mut parts = s.split(':');
        let head = parts.next().ok_or_else(|| bad(s))?;
        let mut num = |p: Option<&str>| -> Result<usize, String> {
            p.and_then(|v| v.parse::<usize>().ok()).ok_or_else(|| bad(s))
        };
        let locus = match head {
            "node" => Locus::Node(num(parts.next())? as u32),
            "output" => Locus::Output(num(parts.next())?),
            "stage" => Locus::Stage { stage: num(parts.next())?, column: num(parts.next())? },
            "col" => Locus::Column(num(parts.next())?),
            "bit" => Locus::Bit(num(parts.next())?),
            _ => return Err(bad(s)),
        };
        if parts.next().is_some() {
            return Err(bad(s));
        }
        Ok(locus)
    }
}

/// One catalog entry: the code, its default severity, and a one-line
/// summary (the long-form catalog lives in `LINTS.md`).
#[derive(Debug, Clone, Copy)]
pub struct CodeInfo {
    /// The `UFOxxx` code.
    pub code: &'static str,
    /// Severity every diagnostic with this code carries.
    pub severity: Severity,
    /// Whether the pass only runs with [`LintOptions::pedantic`].
    pub pedantic: bool,
    /// One-line summary of what the code means.
    pub summary: &'static str,
}

/// Combinational cycle / forward reference in the netlist DAG.
pub const UFO001: &str = "UFO001";
/// Dangling fanin or output: a reference past the end of the netlist.
pub const UFO002: &str = "UFO002";
/// Dead gate: unreachable from any primary output.
pub const UFO003: &str = "UFO003";
/// Multiply-defined primary output name.
pub const UFO004: &str = "UFO004";
/// Opcode / arity / input-ordinal corruption.
pub const UFO005: &str = "UFO005";
/// Constant-foldable gate (all-constant or self-identical fanins).
pub const UFO006: &str = "UFO006";
/// Structurally duplicate gate (same opcode and fanin record).
pub const UFO007: &str = "UFO007";
/// CT stage leaks bit weight (carry past the plan width, or ragged rows).
pub const UFO101: &str = "UFO101";
/// Final CT population exceeds two rows.
pub const UFO102: &str = "UFO102";
/// Compressor counts inconsistent with Algorithm-1 (`ct/counts.rs`).
pub const UFO103: &str = "UFO103";
/// CPA prefix graph does not cover `[bit:0]` contiguously.
pub const UFO104: &str = "UFO104";
/// Infeasible CT slice: compressors exceed the column population.
pub const UFO105: &str = "UFO105";
/// Separate-MAC second-CPA arrival profile disagrees with the netlist.
pub const UFO201: &str = "UFO201";
/// Non-finite or negative arrival time in a recorded stage profile.
pub const UFO202: &str = "UFO202";
/// Unclocked register: the enable pin is tied to constant 0, so the
/// register can never capture data.
pub const UFO301: &str = "UFO301";
/// Combinational loop through a register's control pins (en/clr must be
/// strictly earlier nodes; only the data pin may reference forward).
pub const UFO302: &str = "UFO302";
/// Pipeline stage imbalance: one combinational segment between register
/// ranks is much deeper than another.
pub const UFO303: &str = "UFO303";
/// Primary output proven constant by the ternary abstract domain
/// (`crate::analysis`): every lane, every cycle produces the same bit.
pub const UFO401: &str = "UFO401";
/// Dead register: abstract interpretation proves the state never leaves
/// one constant value from its init, so the flop is storage-free.
pub const UFO402: &str = "UFO402";
/// Register enable proven stuck at 0 through arbitrary logic — the
/// proof-backed upgrade of the structural `UFO301` (which only sees a
/// directly tied constant).
pub const UFO403: &str = "UFO403";
/// Unreachable carry: a proven-0 run at the MSB end of an output weight
/// group — those carry columns can never be asserted.
pub const UFO404: &str = "UFO404";
/// Word-level weight-conservation violation: an unsigned design's proven
/// product interval cannot contain the operand-implied value range.
pub const UFO405: &str = "UFO405";

/// The machine-readable diagnostic-code catalog (mirrors `LINTS.md`).
pub const CODES: &[CodeInfo] = &[
    CodeInfo {
        code: UFO001,
        severity: Severity::Error,
        pedantic: false,
        summary: "combinational cycle (forward/self reference breaks topological order)",
    },
    CodeInfo {
        code: UFO002,
        severity: Severity::Error,
        pedantic: false,
        summary: "dangling reference (fanin or output points past the netlist)",
    },
    CodeInfo {
        code: UFO003,
        severity: Severity::Info,
        pedantic: true,
        summary: "dead gate unreachable from any primary output",
    },
    CodeInfo {
        code: UFO004,
        severity: Severity::Error,
        pedantic: false,
        summary: "multiply-defined primary output name",
    },
    CodeInfo {
        code: UFO005,
        severity: Severity::Error,
        pedantic: false,
        summary: "opcode/arity/input-ordinal corruption",
    },
    CodeInfo {
        code: UFO006,
        severity: Severity::Info,
        pedantic: true,
        summary: "constant-foldable gate",
    },
    CodeInfo {
        code: UFO007,
        severity: Severity::Info,
        pedantic: true,
        summary: "structurally duplicate gate",
    },
    CodeInfo {
        code: UFO101,
        severity: Severity::Error,
        pedantic: false,
        summary: "CT stage leaks bit weight",
    },
    CodeInfo {
        code: UFO102,
        severity: Severity::Error,
        pedantic: false,
        summary: "final CT population exceeds two rows",
    },
    CodeInfo {
        code: UFO103,
        severity: Severity::Error,
        pedantic: false,
        summary: "compressor counts inconsistent with Algorithm 1",
    },
    CodeInfo {
        code: UFO104,
        severity: Severity::Error,
        pedantic: false,
        summary: "prefix graph coverage/contiguity violation",
    },
    CodeInfo {
        code: UFO105,
        severity: Severity::Error,
        pedantic: false,
        summary: "infeasible CT slice (compressors exceed population)",
    },
    CodeInfo {
        code: UFO201,
        severity: Severity::Error,
        pedantic: false,
        summary: "second-CPA arrival profile disagrees with the first CPA's netlist",
    },
    CodeInfo {
        code: UFO202,
        severity: Severity::Error,
        pedantic: false,
        summary: "non-finite or negative arrival in a recorded profile",
    },
    CodeInfo {
        code: UFO301,
        severity: Severity::Error,
        pedantic: false,
        summary: "unclocked register (enable tied to constant 0)",
    },
    CodeInfo {
        code: UFO302,
        severity: Severity::Error,
        pedantic: false,
        summary: "combinational loop through a register's control pins",
    },
    CodeInfo {
        code: UFO303,
        severity: Severity::Info,
        pedantic: true,
        summary: "pipeline stage imbalance (uneven combinational segments)",
    },
    CodeInfo {
        code: UFO401,
        severity: Severity::Warning,
        pedantic: false,
        summary: "primary output proven constant by abstract interpretation",
    },
    CodeInfo {
        code: UFO402,
        severity: Severity::Warning,
        pedantic: false,
        summary: "dead register (state proven constant from init)",
    },
    CodeInfo {
        code: UFO403,
        severity: Severity::Error,
        pedantic: false,
        summary: "register enable proven stuck at 0 (semantic UFO301)",
    },
    CodeInfo {
        code: UFO404,
        severity: Severity::Info,
        pedantic: false,
        summary: "unreachable carry columns at an output group's MSB end",
    },
    CodeInfo {
        code: UFO405,
        severity: Severity::Error,
        pedantic: false,
        summary: "product interval cannot contain the operand-implied range",
    },
];

/// Catalog lookup by code string (returns the interned static form).
pub fn code_info(code: &str) -> Option<&'static CodeInfo> {
    CODES.iter().find(|c| c.code == code)
}

/// One finding: a catalogued code, its severity, where it points, and a
/// human-readable message.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    /// Catalogued `UFOxxx` code.
    pub code: &'static str,
    /// Severity (always the catalog severity of `code`).
    pub severity: Severity,
    /// Node / stage / bit locus.
    pub locus: Locus,
    /// Human-readable description of the finding.
    pub message: String,
}

impl Diagnostic {
    /// Build a diagnostic for a catalogued code (severity comes from the
    /// catalog). Panics on an uncatalogued code — every emitting pass uses
    /// the `UFOxxx` constants above.
    pub fn new(code: &'static str, locus: Locus, message: impl Into<String>) -> Diagnostic {
        let info = code_info(code).unwrap_or_else(|| panic!("uncatalogued lint code {code}"));
        Diagnostic { code: info.code, severity: info.severity, locus, message: message.into() }
    }

    /// Wire/persistence form:
    /// `{"code":…,"locus":…,"message":…,"severity":…}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("code", Json::str(self.code)),
            ("locus", Json::str(self.locus.key())),
            ("message", Json::str(&self.message)),
            ("severity", Json::str(self.severity.key())),
        ])
    }

    /// Parse the [`Diagnostic::to_json`] form back. Unknown codes are an
    /// error (a cache entry written by a newer catalog reads as a defect
    /// and recompiles).
    pub fn from_json(j: &Json) -> Result<Diagnostic, String> {
        let s = |k: &str| -> Result<&str, String> {
            j.get(k)
                .and_then(|v| v.as_str())
                .ok_or_else(|| format!("diagnostic: missing string field '{k}'"))
        };
        let code =
            code_info(s("code")?).ok_or_else(|| format!("unknown lint code '{}'", s("code").unwrap_or("?")))?;
        let severity = Severity::from_key(s("severity")?)?;
        Ok(Diagnostic {
            code: code.code,
            severity,
            locus: Locus::from_key(s("locus")?)?,
            message: s("message")?.to_string(),
        })
    }
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} [{}] {}: {}",
            self.code,
            self.severity.key(),
            self.locus.key(),
            self.message
        )
    }
}

/// Knobs of a lint run.
#[derive(Debug, Clone, Copy, Default)]
pub struct LintOptions {
    /// Also run the informational passes (dead gates, const-foldable and
    /// duplicate gates — [`UFO003`]/[`UFO006`]/[`UFO007`]). Off by
    /// default: arithmetic netlists legitimately truncate overflow carries
    /// (modular products) and share constant injections (Baugh–Wooley),
    /// so these fire on perfectly correct designs.
    pub pedantic: bool,
}

/// The outcome of a lint run: every diagnostic the enabled passes emitted,
/// in pass order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LintReport {
    /// Findings in pass order (structural passes first, then datapath).
    pub diagnostics: Vec<Diagnostic>,
}

impl LintReport {
    /// Report over a finding list.
    pub fn from_diagnostics(diagnostics: Vec<Diagnostic>) -> LintReport {
        LintReport { diagnostics }
    }

    /// No findings at all.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Worst severity present, or `None` when clean.
    pub fn max_severity(&self) -> Option<Severity> {
        self.diagnostics.iter().map(|d| d.severity).max()
    }

    /// Number of findings at exactly `sev`.
    pub fn count(&self, sev: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == sev).count()
    }

    /// Whether any finding is at or above `deny` — the engine's gate
    /// predicate.
    pub fn denies(&self, deny: Severity) -> bool {
        self.max_severity().is_some_and(|m| m >= deny)
    }

    /// Wire/persistence form: `{"diagnostics":[…]}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "diagnostics",
            Json::Arr(self.diagnostics.iter().map(Diagnostic::to_json).collect()),
        )])
    }

    /// Parse the [`LintReport::to_json`] form back.
    pub fn from_json(j: &Json) -> Result<LintReport, String> {
        let rows = j
            .get("diagnostics")
            .and_then(|v| v.as_arr())
            .ok_or("lint report: missing 'diagnostics' array")?;
        let diagnostics =
            rows.iter().map(Diagnostic::from_json).collect::<Result<Vec<_>, _>>()?;
        Ok(LintReport { diagnostics })
    }

    /// Wire summary with counts, used by the server's `lint` command:
    /// `{"clean":…,"counts":{…},"diagnostics":[…]}`.
    pub fn summary_json(&self) -> Json {
        let counts = Json::obj(vec![
            ("error", Json::num(self.count(Severity::Error) as f64)),
            ("info", Json::num(self.count(Severity::Info) as f64)),
            ("warning", Json::num(self.count(Severity::Warning) as f64)),
        ]);
        Json::obj(vec![
            ("clean", Json::Bool(self.is_clean())),
            ("counts", counts),
            (
                "diagnostics",
                Json::Arr(self.diagnostics.iter().map(Diagnostic::to_json).collect()),
            ),
        ])
    }
}

impl std::fmt::Display for LintReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_clean() {
            return write!(f, "clean (0 diagnostics)");
        }
        for (i, d) in self.diagnostics.iter().enumerate() {
            if i > 0 {
                writeln!(f)?;
            }
            write!(f, "{d}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn severity_orders_and_roundtrips() {
        assert!(Severity::Info < Severity::Warning);
        assert!(Severity::Warning < Severity::Error);
        for s in [Severity::Info, Severity::Warning, Severity::Error] {
            assert_eq!(Severity::from_key(s.key()).unwrap(), s);
        }
        assert!(Severity::from_key("fatal").is_err());
    }

    #[test]
    fn locus_roundtrips() {
        for l in [
            Locus::Design,
            Locus::Node(17),
            Locus::Output(2),
            Locus::Stage { stage: 1, column: 9 },
            Locus::Column(5),
            Locus::Bit(3),
        ] {
            assert_eq!(Locus::from_key(&l.key()).unwrap(), l);
        }
        assert!(Locus::from_key("node:x").is_err());
        assert!(Locus::from_key("stage:1").is_err());
        assert!(Locus::from_key("node:1:2").is_err());
    }

    #[test]
    fn catalog_is_consistent() {
        // Codes unique, families well-formed, severities match the
        // documented policy (pedantic passes are Info).
        let mut seen = std::collections::BTreeSet::new();
        for c in CODES {
            assert!(seen.insert(c.code), "duplicate code {}", c.code);
            assert!(c.code.starts_with("UFO") && c.code.len() == 6, "{}", c.code);
            if c.pedantic {
                assert_eq!(c.severity, Severity::Info, "{}", c.code);
            }
        }
        assert!(code_info("UFO001").is_some());
        assert!(code_info("UFO999").is_none());
    }

    #[test]
    fn report_roundtrips_and_counts() {
        let rep = LintReport::from_diagnostics(vec![
            Diagnostic::new(UFO001, Locus::Node(4), "cycle via node 9"),
            Diagnostic::new(UFO006, Locus::Node(7), "const-foldable"),
        ]);
        assert!(!rep.is_clean());
        assert_eq!(rep.max_severity(), Some(Severity::Error));
        assert_eq!(rep.count(Severity::Error), 1);
        assert_eq!(rep.count(Severity::Info), 1);
        assert!(rep.denies(Severity::Error));
        assert!(!LintReport::default().denies(Severity::Info));
        let back = LintReport::from_json(&rep.to_json()).unwrap();
        assert_eq!(back, rep);
        assert_eq!(back.to_json().render(), rep.to_json().render());
    }
}
