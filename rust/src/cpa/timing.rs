//! §4.2 — timing models for prefix adders: depth, mpfo, and the paper's
//! fanout-depth-combination (FDC) model, plus the linear-regression fitting
//! and fidelity metrics behind Figure 8.
//!
//! FDC features for bit `i` are extracted from the sub-prefix tree rooted at
//! `roots[i]`: along the critical path (deepest; fanout-sum tie-break) we
//! accumulate the fanouts and counts of *black* nodes (internal nodes whose
//! group propagate is consumed) and *blue* nodes (generate-only, final-level
//! nodes driving a single sum), giving
//! `d_i = k0·F_black + k1·F_blue + k2·N_black + k3·N_blue + b`  (Eq. 27).

use super::graph::{PrefixGraph, NONE};

/// Per-bit feature vector of the FDC model.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FdcFeatures {
    /// Summed fanout of black nodes along the critical path.
    pub f_black: f64,
    /// Summed fanout of blue nodes along the critical path.
    pub f_blue: f64,
    /// Black-node count along the critical path.
    pub n_black: f64,
    /// Blue-node count along the critical path.
    pub n_blue: f64,
}

impl FdcFeatures {
    /// Features as the `[F_black, F_blue, N_black, N_blue]` vector.
    pub fn as_array(&self) -> [f64; 4] {
        [self.f_black, self.f_blue, self.n_black, self.n_blue]
    }
}

/// Fitted FDC coefficients (`k0..k3`, intercept `b`), in ns.
#[derive(Debug, Clone, Copy)]
pub struct FdcModel {
    /// Coefficients `k0..k3` of Eq. 27 (ns per feature unit).
    pub k: [f64; 4],
    /// Intercept (pg stage + final sum XOR), ns.
    pub b: f64,
}

impl FdcModel {
    /// A reasonable logical-effort-derived prior (used before fitting and
    /// by Algorithm 2 when the caller provides no fitted model).
    pub fn default_prior() -> Self {
        Self::from_lib(&crate::ir::CellLib::nangate45())
    }

    /// Derive the coefficients from a cell library: a black node is an
    /// And2→Or2 pair (G path) whose output load grows with fanout; blue
    /// nodes are the same pair driving a single sum XOR; the intercept
    /// carries the pg stage and the final sum XOR.
    pub fn from_lib(lib: &crate::ir::CellLib) -> Self {
        use crate::ir::CellKind::*;
        let tau = lib.tau_ns;
        let base_load = 1.5; // one downstream prefix input
        let intrinsic = |k: crate::ir::CellKind| lib.delay_ns(k, base_load);
        let black = intrinsic(And2) + intrinsic(Or2);
        // Extra delay per additional unit of fanout on the G output.
        let per_fanout = lib.params(Or2).logical_effort * 1.5 / lib.params(Or2).input_cap * tau;
        let pg = intrinsic(Xor2).max(intrinsic(And2));
        let sum = intrinsic(Xor2);
        FdcModel {
            k: [per_fanout, per_fanout * 0.8, black, black * 0.92],
            b: pg + sum,
        }
    }

    /// Eq. 27: `Σ k_i·x_i + b` (ns).
    pub fn predict(&self, f: &FdcFeatures) -> f64 {
        let x = f.as_array();
        self.k.iter().zip(x.iter()).map(|(k, v)| k * v).sum::<f64>() + self.b
    }
}

/// Which internal nodes are "blue" (generate-only): their group propagate
/// has no consumer among live nodes.
pub fn blue_mask(g: &PrefixGraph) -> Vec<bool> {
    let live = g.live_mask();
    // A node's P is consumed if the node is a tf of any live parent, or it
    // is an ntf of a live parent whose own P is consumed. Compute by
    // reverse-topological propagation of `p_needed`.
    let mut p_needed = vec![false; g.nodes.len()];
    for i in (g.n..g.nodes.len()).rev() {
        if !live[i] {
            continue;
        }
        let nd = g.node(i);
        // Parent consumes tf's P always (for its G and P).
        p_needed[nd.tf] = true;
        // Parent consumes ntf's P only if the parent's P is itself needed.
        if p_needed[i] {
            p_needed[nd.ntf] = true;
        }
    }
    (0..g.nodes.len())
        .map(|i| i >= g.n && live[i] && !p_needed[i])
        .collect()
}

/// Extract FDC features for every bit of the graph. `O(nodes)` per the
/// paper's complexity claim: one DP pass computes, per node, the critical
/// path (max depth, fanout-sum tie-break) feature accumulation.
pub fn fdc_features(g: &PrefixGraph) -> Vec<FdcFeatures> {
    let fo = g.fanouts();
    let blue = blue_mask(g);
    let depths = g.depths();
    // DP over nodes: features of the critical path from leaves to node i
    // (inclusive of node i's own contribution).
    let mut feat: Vec<FdcFeatures> = vec![FdcFeatures::default(); g.nodes.len()];
    let mut key: Vec<(usize, f64)> = vec![(0, 0.0); g.nodes.len()]; // (depth, fanout-sum)
    for i in g.n..g.nodes.len() {
        let nd = g.node(i);
        let (kt, ku) = (key[nd.tf], key[nd.ntf]);
        let child = if (depths[nd.tf], kt.1) >= (depths[nd.ntf], ku.1) { nd.tf } else { nd.ntf };
        let mut f = feat[child];
        if blue[i] {
            f.f_blue += fo[i] as f64;
            f.n_blue += 1.0;
        } else {
            f.f_black += fo[i] as f64;
            f.n_black += 1.0;
        }
        feat[i] = f;
        key[i] = (depths[i], key[child].1 + fo[i] as f64);
    }
    g.roots.iter().map(|&r| if r == NONE { FdcFeatures::default() } else { feat[r] }).collect()
}

/// Max-path-fanout (mpfo) per bit — the prior-work model the paper compares
/// against: max over root-to-leaf paths of the fanout sum.
pub fn mpfo(g: &PrefixGraph) -> Vec<f64> {
    let fo = g.fanouts();
    let mut acc = vec![0.0f64; g.nodes.len()];
    for i in g.n..g.nodes.len() {
        let nd = g.node(i);
        acc[i] = acc[nd.tf].max(acc[nd.ntf]) + fo[i] as f64;
    }
    g.roots.iter().map(|&r| if r == NONE { 0.0 } else { acc[r] }).collect()
}

/// Logic depth per bit (the GOMIL/Zimmermann-era model).
pub fn depth_per_bit(g: &PrefixGraph) -> Vec<f64> {
    let d = g.depths();
    g.roots.iter().map(|&r| if r == NONE { 0.0 } else { d[r] as f64 }).collect()
}

// ---------------------------------------------------------------------------
// Regression + fidelity metrics (Figure 8)
// ---------------------------------------------------------------------------

/// Ordinary least squares for `y ≈ X·w + b`. Returns `(w, b)`.
/// Solves the (k+1)-dimensional normal equations by Gaussian elimination.
pub fn least_squares(xs: &[Vec<f64>], ys: &[f64]) -> (Vec<f64>, f64) {
    let n = xs.len();
    assert!(n > 0 && n == ys.len());
    let k = xs[0].len();
    let dim = k + 1;
    // Normal matrix A = Zᵀ Z, rhs = Zᵀ y, where Z = [X | 1].
    let mut a = vec![vec![0.0f64; dim]; dim];
    let mut rhs = vec![0.0f64; dim];
    for (x, &y) in xs.iter().zip(ys.iter()) {
        let z: Vec<f64> = x.iter().copied().chain(std::iter::once(1.0)).collect();
        for i in 0..dim {
            for j in 0..dim {
                a[i][j] += z[i] * z[j];
            }
            rhs[i] += z[i] * y;
        }
    }
    // Ridge epsilon for singular feature sets.
    for (i, row) in a.iter_mut().enumerate() {
        row[i] += 1e-9;
    }
    // Gaussian elimination with partial pivoting.
    for col in 0..dim {
        let piv = (col..dim)
            .max_by(|&r1, &r2| a[r1][col].abs().partial_cmp(&a[r2][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        rhs.swap(col, piv);
        let d = a[col][col];
        for r in 0..dim {
            if r != col && a[r][col].abs() > 0.0 {
                let f = a[r][col] / d;
                for c in col..dim {
                    a[r][c] -= f * a[col][c];
                }
                rhs[r] -= f * rhs[col];
            }
        }
    }
    let w: Vec<f64> = (0..k).map(|i| rhs[i] / a[i][i]).collect();
    let b = rhs[k] / a[k][k];
    (w, b)
}

/// Fidelity metrics of a prediction vector.
#[derive(Debug, Clone, Copy)]
pub struct Fidelity {
    /// Coefficient of determination.
    pub r2: f64,
    /// Mean absolute percentage error.
    pub mape: f64,
}

/// R² and MAPE of `pred` against `truth`.
pub fn fidelity(pred: &[f64], truth: &[f64]) -> Fidelity {
    let n = truth.len() as f64;
    let mean = truth.iter().sum::<f64>() / n;
    let ss_tot: f64 = truth.iter().map(|y| (y - mean).powi(2)).sum();
    let ss_res: f64 = pred.iter().zip(truth).map(|(p, y)| (p - y).powi(2)).sum();
    let r2 = 1.0 - ss_res / ss_tot.max(1e-12);
    let mape = pred
        .iter()
        .zip(truth)
        .map(|(p, y)| ((p - y) / y.abs().max(1e-9)).abs())
        .sum::<f64>()
        / n;
    Fidelity { r2, mape }
}

/// Fit the FDC model on (features, measured delay) samples.
pub fn fit_fdc(samples: &[(FdcFeatures, f64)]) -> FdcModel {
    let xs: Vec<Vec<f64>> = samples.iter().map(|(f, _)| f.as_array().to_vec()).collect();
    let ys: Vec<f64> = samples.iter().map(|(_, y)| *y).collect();
    let (w, b) = least_squares(&xs, &ys);
    FdcModel { k: [w[0], w[1], w[2], w[3]], b }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpa::graph::{kogge_stone, ripple, sklansky};

    #[test]
    fn blue_nodes_are_final_level() {
        // In a ripple chain every root node except the last is consumed by
        // the next node as ntf — the parent's P is needed only when the
        // parent's P is consumed… top node's P is never consumed, so the
        // chain is blue from the top down until a node serves as tf.
        let g = ripple(8);
        let blue = blue_mask(&g);
        // leaf nodes are never blue
        for i in 0..g.n {
            assert!(!blue[i]);
        }
        // In a ripple graph no internal node is a tf of another node —
        // leaves are the tfs — so every internal node is blue.
        for i in g.n..g.nodes.len() {
            assert!(blue[i], "node {i}");
        }
        // Sklansky has true black nodes.
        let s = sklansky(16);
        let bs = blue_mask(&s);
        assert!(bs.iter().any(|&b| b));
        assert!((s.n..s.nodes.len()).any(|i| !bs[i]));
    }

    #[test]
    fn fdc_features_monotone_in_bit_position() {
        let g = ripple(16);
        let f = fdc_features(&g);
        // Deeper bits accumulate more nodes along the critical path.
        assert!(f[15].n_black + f[15].n_blue > f[3].n_black + f[3].n_blue);
    }

    #[test]
    fn mpfo_and_depth_sane() {
        let g = sklansky(16);
        let d = depth_per_bit(&g);
        assert_eq!(d[15], 4.0);
        assert!(d[1] <= 1.0 + 1e-9);
        let m = mpfo(&g);
        assert!(m[15] >= d[15], "mpfo accumulates fanout ≥ 1 per level");
        let ks = kogge_stone(16);
        // Kogge-Stone bounded fanout ⇒ lower mpfo at the MSB than Sklansky.
        assert!(mpfo(&ks)[15] <= m[15]);
    }

    #[test]
    fn least_squares_recovers_plane() {
        // y = 2x0 - 3x1 + 0.5 with a deterministic pseudo-random design.
        let mut rng = crate::util::Rng::seed_from_u64(5);
        let xs: Vec<Vec<f64>> =
            (0..200).map(|_| vec![rng.f64() * 10.0, rng.f64() * 4.0]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x[0] - 3.0 * x[1] + 0.5).collect();
        let (w, b) = least_squares(&xs, &ys);
        assert!((w[0] - 2.0).abs() < 1e-6);
        assert!((w[1] + 3.0).abs() < 1e-6);
        assert!((b - 0.5).abs() < 1e-6);
    }

    #[test]
    fn fidelity_metrics() {
        let truth = vec![1.0, 2.0, 3.0, 4.0];
        let perfect = fidelity(&truth, &truth);
        assert!((perfect.r2 - 1.0).abs() < 1e-12);
        assert!(perfect.mape < 1e-12);
        let off = fidelity(&[1.1, 2.2, 3.3, 4.4], &truth);
        assert!(off.r2 < 1.0 && off.r2 > 0.9);
        assert!((off.mape - 0.1).abs() < 1e-9);
    }

    #[test]
    fn fit_fdc_reduces_error_vs_prior() {
        // Synthetic ground truth generated from a known linear model.
        let truth_model = FdcModel { k: [0.01, 0.005, 0.04, 0.03], b: 0.06 };
        let mut samples = Vec::new();
        for n in [8usize, 12, 16, 24] {
            for g in [sklansky(n), kogge_stone(n), ripple(n)] {
                for f in fdc_features(&g) {
                    samples.push((f, truth_model.predict(&f)));
                }
            }
        }
        let fitted = fit_fdc(&samples);
        for (f, y) in &samples {
            assert!((fitted.predict(f) - y).abs() < 1e-6);
        }
    }
}
