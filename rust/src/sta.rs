//! Static timing analysis, area and power reporting.
//!
//! Replaces the paper's Synopsys Design Compiler reports with a
//! logical-effort timing engine (`d = p + g·h` per stage, load computed
//! from actual fanout) applied uniformly to every generator — preserving
//! the *relative* comparisons that the paper's tables and Pareto plots
//! report. Arrival times honour per-input arrival offsets, which is how the
//! CPA sees the compressor tree's non-uniform ("trapezoidal") profile.
//!
//! Two engines share one arrival formula, evaluated directly over the
//! netlist's flat struct-of-arrays storage (EXPERIMENTS.md §Perf):
//!
//! - [`Sta`] — the whole-netlist engine (one levelized sweep over the flat
//!   opcode/fanin arrays, plus area and toggle-based power).
//!   [`Sta::analyze`] serves gate count and depth from the netlist's
//!   cached [`crate::ir::Topology`] instead of re-sweeping — the seed
//!   implementation paid three extra full passes per report.
//! - [`IncrementalSta`] — the engine for workloads that edit one netlist
//!   repeatedly (arrival-profile perturbation loops, appended logic): it
//!   caches arrival times and loads, shares the netlist's cached CSR
//!   fan-out adjacency (no private adjacency rebuild), and after an edit
//!   (input-arrival change, appended gates) re-times **only the fan-out
//!   cones of the changed cells** through a dirty-set worklist.
//!   Arrival times are bit-identical to a full [`Sta::arrivals_ns`] sweep
//!   — both paths evaluate the same arrival formula — and
//!   [`TimingStats`] records how much work the incremental path avoided.

use crate::ir::netlist::OP_INPUT;
use crate::ir::{CellKind, CellLib, Netlist, Node, NodeId, Topology};
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

/// Timing/area/power report for one netlist.
#[derive(Debug, Clone)]
pub struct StaReport {
    /// Worst arrival time over timing endpoints — primary outputs and
    /// register data pins (registers cut paths at the clock edge), ns.
    pub critical_delay_ns: f64,
    /// Total standard-cell area, µm².
    pub area_um2: f64,
    /// Estimated dynamic power at `clock_ghz`, mW.
    pub power_mw: f64,
    /// Arrival time per primary output, ns (output order of the netlist).
    pub output_arrivals_ns: Vec<f64>,
    /// Gate count.
    pub num_gates: usize,
    /// Max logic depth over outputs.
    pub depth: u32,
}

impl StaReport {
    /// Worst negative slack against a clock period (ns): `period - delay`.
    /// Negative means the design misses timing (as in the paper's tables).
    pub fn wns_ns(&self, period_ns: f64) -> f64 {
        period_ns - self.critical_delay_ns
    }
}

/// The STA engine. Holds the cell library and power-model knobs.
#[derive(Debug, Clone)]
pub struct Sta {
    /// Characterized standard-cell library.
    pub lib: CellLib,
    /// Clock used to convert switching energy to power, GHz.
    pub clock_ghz: f64,
    /// Rounds of 64 random vectors (combinational) or clocked cycles
    /// (sequential) for toggle-rate extraction. `0` selects the static
    /// signal-probability estimate (fast path for huge module-level runs
    /// and candidate scoring).
    pub activity_rounds: usize,
    /// Legacy flat activity factor. Retained for configuration
    /// compatibility; since the static signal-probability fallback landed
    /// it no longer feeds [`Sta::dynamic_power_mw`].
    pub default_activity: f64,
}

impl Default for Sta {
    fn default() -> Self {
        Sta { lib: CellLib::nangate45(), clock_ghz: 1.0, activity_rounds: 16, default_activity: 0.15 }
    }
}

/// Arrival time of a node given the arrivals of its fan-ins and its
/// capacitive load, evaluated on a [`Node`] view — the reference form of
/// the one formula both engines implement. The hot loops evaluate the
/// private `arrival_flat` kernel over the flat arrays instead; the two
/// are operation-for-operation identical (`rust/tests/ir_flat.rs` pins
/// them bit-for-bit against each other).
#[inline]
pub fn node_arrival_ns(lib: &CellLib, node: Node<'_>, at: &[f64], load: f64) -> f64 {
    match node {
        Node::Input { arrival_ns, .. } => arrival_ns,
        Node::Const(_) => 0.0,
        Node::Gate { kind, fanin } => {
            let worst = fanin.iter().map(|f| at[f.index()]).fold(f64::MIN, f64::max);
            worst + lib.delay_ns(kind, load)
        }
        // A register's Q pin launches a fresh timing path at the clock
        // edge: registers are cut points, not combinational delay.
        Node::Reg { .. } => 0.0,
    }
}

/// The flat-array arrival kernel shared by [`Sta::arrivals_ns`] and
/// [`IncrementalSta::propagate`]: no enum construction, no per-gate heap
/// indirection. `ops`/`fan` are the netlist's flat node arrays, `arr` the
/// per-ordinal input arrivals.
#[inline]
fn arrival_flat(
    lib: &CellLib,
    ops: &[u8],
    fan: &[[u32; 3]],
    arr: &[f64],
    at: &[f64],
    load: f64,
    i: usize,
) -> f64 {
    let op = ops[i];
    if op <= 10 {
        let kind = CellKind::ALL[op as usize];
        let rec = fan[i];
        // Same fold order as the `Node`-view formula: left-to-right max
        // seeded by the first fanin ⇒ bit-identical floats.
        let mut worst = at[rec[0] as usize];
        let arity = kind.arity();
        if arity > 1 {
            worst = worst.max(at[rec[1] as usize]);
        }
        if arity > 2 {
            worst = worst.max(at[rec[2] as usize]);
        }
        worst + lib.delay_ns(kind, load)
    } else if op == OP_INPUT {
        arr[fan[i][0] as usize]
    } else {
        // Constants (time-invariant) and registers (OP_REG: the Q pin
        // launches a fresh path at the clock edge — a timing cut point)
        // both start new paths at t = 0, matching the `Node`-view formula.
        0.0
    }
}

impl Sta {
    /// Engine over a caller-provided cell library (other knobs default).
    pub fn with_lib(lib: CellLib) -> Self {
        Sta { lib, ..Default::default() }
    }

    /// Arrival time (ns) of every node: one levelized forward sweep over
    /// the flat arrays.
    pub fn arrivals_ns(&self, nl: &Netlist) -> Vec<f64> {
        let loads = nl.loads(&self.lib);
        let ops = nl.ops();
        let fan = nl.fanin_records();
        let arr = nl.input_arrivals();
        let mut at = vec![0.0f64; nl.len()];
        for i in 0..ops.len() {
            at[i] = arrival_flat(&self.lib, ops, fan, arr, &at, loads[i], i);
        }
        at
    }

    /// Full report: timing + area + toggle-based dynamic power. Gate count
    /// is O(1) and depth comes from the cached topology — no extra sweeps
    /// beyond the one arrival pass (and the power simulation when
    /// `activity_rounds > 0`).
    pub fn analyze(&self, nl: &Netlist) -> StaReport {
        let at = self.arrivals_ns(nl);
        let output_arrivals_ns: Vec<f64> =
            nl.outputs().map(|(_, id)| at[id.index()]).collect();
        let mut critical_delay_ns =
            output_arrivals_ns.iter().copied().fold(0.0f64, f64::max);
        // Sequential endpoints: each register's d pin ends a timing path at
        // the clock edge, so the deepest combinational *segment* — not the
        // (cut) end-to-end path — governs the achievable clock period.
        let fan = nl.fanin_records();
        for &(r, _) in nl.registers() {
            critical_delay_ns = critical_delay_ns.max(at[fan[r as usize][0] as usize]);
        }
        let area_um2 = nl.area_um2(&self.lib);
        let power_mw = self.dynamic_power_mw(nl);
        StaReport {
            critical_delay_ns,
            area_um2,
            power_mw,
            output_arrivals_ns,
            num_gates: nl.num_gates(),
            depth: nl.topology().depth(),
        }
    }

    /// Dynamic power: `P = Σ_g activity_g · E_g · f_clk`.
    ///
    /// Activity comes from toggle measurement when `activity_rounds > 0`:
    /// combinational netlists sweep the bit-parallel simulator, sequential
    /// ones run a cycle-accurate [`crate::sim::clocked_toggle_activity`]
    /// stimulus (both behind [`crate::sim::toggle_activity`]). With
    /// `activity_rounds == 0` — the hot candidate-scoring configuration —
    /// the estimate is the *static* switching activity from the
    /// signal-probability domain ([`crate::analysis::static_activity`]
    /// with the allocation-free depth-1 window), which replaces the old
    /// flat `default_activity` constant with a per-gate value while
    /// staying simulation-free.
    pub fn dynamic_power_mw(&self, nl: &Netlist) -> f64 {
        let activities: Vec<f64> = if self.activity_rounds > 0 && nl.num_inputs() > 0 {
            crate::sim::toggle_activity(nl, self.activity_rounds, 0x5eed)
        } else {
            crate::analysis::static_activity(nl, &crate::analysis::AnalysisOptions::fast())
        };
        let mut energy_fj_per_cycle = 0.0;
        for (i, &op) in nl.ops().iter().enumerate() {
            if op <= 10 {
                let kind = CellKind::ALL[op as usize];
                energy_fj_per_cycle += activities[i] * self.lib.params(kind).switch_energy_fj;
            }
        }
        // fJ/cycle × GHz = µW; report mW.
        energy_fj_per_cycle * self.clock_ghz / 1000.0
    }

    /// Arrival profile (ns) for a set of labelled output groups — used to
    /// extract the compressor tree's per-column profile that drives CPA
    /// optimization (Figure 1 of the paper).
    pub fn arrival_profile(&self, nl: &Netlist, groups: &[Vec<NodeId>]) -> Vec<f64> {
        let at = self.arrivals_ns(nl);
        groups
            .iter()
            .map(|g| g.iter().map(|id| at[id.index()]).fold(0.0f64, f64::max))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Incremental timing
// ---------------------------------------------------------------------------

/// Counters describing how much timing evaluation a pass (or a whole
/// compile) performed, and how much of it the incremental engines avoided.
///
/// `nodes_total` is the work a from-scratch evaluation would have done
/// (netlist length per pass); `nodes_retimed` is the work actually done
/// (full length for a full pass, dirty-cone size for an incremental one).
/// The same counters are used by the model-level delay cache in
/// [`crate::cpa::optimize`], where a "node" is a prefix-graph node rather
/// than a gate.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TimingStats {
    /// Whole-netlist (or whole-graph) evaluation sweeps.
    pub full_passes: u64,
    /// Dirty-set worklist propagations.
    pub incremental_passes: u64,
    /// Nodes actually re-evaluated across all passes.
    pub nodes_retimed: u64,
    /// Nodes a from-scratch evaluation of every pass would have visited.
    pub nodes_total: u64,
}

impl TimingStats {
    /// Stats of one from-scratch pass over `nodes` nodes.
    pub fn full_pass(nodes: usize) -> TimingStats {
        TimingStats {
            full_passes: 1,
            incremental_passes: 0,
            nodes_retimed: nodes as u64,
            nodes_total: nodes as u64,
        }
    }

    /// Accumulate another stats record (compiles merge the timing work of
    /// their inner artifacts this way).
    pub fn merge(&mut self, other: &TimingStats) {
        self.full_passes += other.full_passes;
        self.incremental_passes += other.incremental_passes;
        self.nodes_retimed += other.nodes_retimed;
        self.nodes_total += other.nodes_total;
    }

    /// Fraction of nodes actually re-evaluated, in `[0, 1]` (1.0 when no
    /// pass ran). Lower is better; `1 / retime_fraction` is the effective
    /// speedup over always re-timing from scratch.
    pub fn retime_fraction(&self) -> f64 {
        if self.nodes_total == 0 {
            1.0
        } else {
            self.nodes_retimed as f64 / self.nodes_total as f64
        }
    }
}

/// Incremental arrival-time engine over one netlist.
///
/// Holds the arrival vector and per-node loads of a netlist, shares the
/// netlist's cached CSR fan-out adjacency ([`Netlist::topology`] — no
/// private adjacency rebuild), and re-times **only the fan-out cones of
/// changed cells**:
///
/// - [`IncrementalSta::touch`] marks a cell whose inputs changed (e.g. an
///   input whose arrival was edited via
///   [`Netlist::set_input_arrival`]);
/// - [`IncrementalSta::sync`] absorbs gates appended to the netlist since
///   the last sync (netlists are append-only), refreshing the shared
///   topology and dirtying the appended cone *and* the existing drivers
///   whose loads the new gates increased;
/// - [`IncrementalSta::propagate`] drains the dirty set in topological
///   order, stopping each ray as soon as a recomputed arrival is unchanged.
///
/// Arrival times after `propagate` are bit-identical to a fresh
/// [`Sta::arrivals_ns`] sweep over the same netlist: both paths evaluate
/// the same flat arrival kernel with bit-identical load vectors, and a
/// node is skipped only when every quantity its arrival depends on is
/// unchanged.
#[derive(Debug, Clone)]
pub struct IncrementalSta {
    lib: CellLib,
    at: Vec<f64>,
    loads: Vec<f64>,
    /// Shared topology snapshot (CSR consumers) of the synced netlist.
    topo: Arc<Topology>,
    /// Netlist nodes already absorbed.
    synced_nodes: usize,
    /// Primary outputs already absorbed into the load vector.
    synced_outputs: usize,
    dirty: BinaryHeap<Reverse<u32>>,
    in_dirty: Vec<bool>,
    stats: TimingStats,
}

impl IncrementalSta {
    /// Build the engine with one full timing pass over `nl`.
    pub fn new(sta: &Sta, nl: &Netlist) -> Self {
        let topo = nl.topology();
        let loads = nl.loads(&sta.lib);
        let ops = nl.ops();
        let fan = nl.fanin_records();
        let arr = nl.input_arrivals();
        let mut at = vec![0.0f64; nl.len()];
        for i in 0..ops.len() {
            at[i] = arrival_flat(&sta.lib, ops, fan, arr, &at, loads[i], i);
        }
        IncrementalSta {
            lib: sta.lib.clone(),
            at,
            loads,
            topo,
            synced_nodes: nl.len(),
            synced_outputs: nl.num_outputs(),
            dirty: BinaryHeap::new(),
            in_dirty: vec![false; nl.len()],
            stats: TimingStats::full_pass(nl.len()),
        }
    }

    fn mark_dirty(&mut self, i: usize) {
        if !self.in_dirty[i] {
            self.in_dirty[i] = true;
            self.dirty.push(Reverse(i as u32));
        }
    }

    /// Mark a cell whose own definition changed (an input whose
    /// `arrival_ns` was edited, a constant repurposed). Its fan-out cone is
    /// re-timed by the next [`IncrementalSta::propagate`].
    pub fn touch(&mut self, id: NodeId) {
        self.mark_dirty(id.index());
    }

    /// Absorb nodes and outputs appended to `nl` since the last sync.
    ///
    /// Refreshes the shared topology (the netlist invalidated its cache on
    /// append, so this is one rebuild shared with every other consumer),
    /// then recomputes loads wholesale (bit-identical to
    /// [`Netlist::loads`]; cheap integer/float accumulation) and diffs
    /// them: an existing driver whose load grew is dirtied — its own delay
    /// changed — alongside every appended cell, so `propagate` re-times
    /// exactly the affected cones.
    pub fn sync(&mut self, nl: &Netlist) {
        if nl.len() == self.synced_nodes && nl.num_outputs() == self.synced_outputs {
            return;
        }
        assert!(
            nl.len() >= self.synced_nodes,
            "netlist shrank under an IncrementalSta (len {} < synced {})",
            nl.len(),
            self.synced_nodes
        );
        self.at.resize(nl.len(), 0.0);
        self.in_dirty.resize(nl.len(), false);
        self.topo = nl.topology();
        // Recompute loads exactly as a fresh pass would (same accumulation
        // order ⇒ same floats), then dirty every node whose load changed.
        let loads = nl.loads(&self.lib);
        for i in 0..self.synced_nodes {
            if loads[i] != self.loads[i] {
                self.mark_dirty(i);
            }
        }
        for i in self.synced_nodes..nl.len() {
            self.mark_dirty(i);
        }
        self.loads = loads;
        self.synced_nodes = nl.len();
        self.synced_outputs = nl.num_outputs();
    }

    /// Drain the dirty set in topological order, re-timing each dirty cell
    /// and dirtying its consumers when its arrival actually moved. Returns
    /// the number of cells re-timed.
    pub fn propagate(&mut self, nl: &Netlist) -> usize {
        debug_assert_eq!(nl.len(), self.synced_nodes, "sync() before propagate()");
        let topo = Arc::clone(&self.topo);
        let ops = nl.ops();
        let fan = nl.fanin_records();
        let arr = nl.input_arrivals();
        let mut retimed = 0usize;
        while let Some(Reverse(i)) = self.dirty.pop() {
            let i = i as usize;
            if !self.in_dirty[i] {
                continue; // stale duplicate heap entry
            }
            self.in_dirty[i] = false;
            let new = arrival_flat(&self.lib, ops, fan, arr, &self.at, self.loads[i], i);
            retimed += 1;
            if new != self.at[i] {
                self.at[i] = new;
                for &consumer in topo.consumers(i) {
                    let consumer = consumer as usize;
                    if !self.in_dirty[consumer] {
                        self.in_dirty[consumer] = true;
                        self.dirty.push(Reverse(consumer as u32));
                    }
                }
            }
        }
        self.stats.incremental_passes += 1;
        self.stats.nodes_retimed += retimed as u64;
        self.stats.nodes_total += nl.len() as u64;
        retimed
    }

    /// Arrival time (ns) of every node. Call after
    /// [`IncrementalSta::propagate`]; pending dirty cells are stale.
    pub fn arrivals(&self) -> &[f64] {
        &self.at
    }

    /// Arrival time (ns) of one node.
    pub fn arrival(&self, id: NodeId) -> f64 {
        self.at[id.index()]
    }

    /// Worst arrival over primary outputs (ns).
    pub fn critical_delay_ns(&self, nl: &Netlist) -> f64 {
        nl.outputs().map(|(_, id)| self.at[id.index()]).fold(0.0f64, f64::max)
    }

    /// Arrival time per primary output, in output order (ns).
    pub fn output_arrivals(&self, nl: &Netlist) -> Vec<f64> {
        nl.outputs().map(|(_, id)| self.at[id.index()]).collect()
    }

    /// Cumulative work counters for this engine.
    pub fn stats(&self) -> TimingStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Netlist;

    fn xor_chain(n: usize) -> Netlist {
        let mut nl = Netlist::new("xorchain");
        let mut prev = nl.input("i0");
        for k in 1..=n {
            let i = nl.input(format!("i{k}"));
            prev = nl.xor2(prev, i);
        }
        nl.output("o", prev);
        nl
    }

    #[test]
    fn delay_scales_with_depth() {
        let sta = Sta::default();
        let d4 = sta.analyze(&xor_chain(4)).critical_delay_ns;
        let d8 = sta.analyze(&xor_chain(8)).critical_delay_ns;
        assert!(d8 > d4 * 1.5, "d4={d4} d8={d8}");
    }

    #[test]
    fn input_arrival_offsets_propagate() {
        let mut nl = Netlist::new("arr");
        let a = nl.input_at("a", 1.0);
        let b = nl.input("b");
        let o = nl.xor2(a, b);
        nl.output("o", o);
        let sta = Sta::default();
        let rep = sta.analyze(&nl);
        assert!(rep.critical_delay_ns > 1.0);
        assert!(rep.critical_delay_ns < 1.2);
    }

    #[test]
    fn fanout_increases_delay() {
        // The same XOR driving 8 loads must be slower than driving 1 —
        // the premise of the paper's FDC model.
        let build = |fanout: usize| {
            let mut nl = Netlist::new("f");
            let a = nl.input("a");
            let b = nl.input("b");
            let x = nl.xor2(a, b);
            let mut last = x;
            for _ in 0..fanout {
                last = nl.inv(x);
            }
            nl.output("o", last);
            let _ = last;
            nl
        };
        let sta = Sta::default();
        let a1 = sta.arrivals_ns(&build(1));
        let a8 = sta.arrivals_ns(&build(8));
        // arrival at the XOR output node (index 2) grows with fanout
        assert!(a8[2] > a1[2]);
    }

    #[test]
    fn view_formula_matches_flat_kernel() {
        // node_arrival_ns (Node view) and arrival_flat (hot kernel) are the
        // same formula, bit for bit.
        let nl = xor_chain(9);
        let sta = Sta::default();
        let loads = nl.loads(&sta.lib);
        let flat = sta.arrivals_ns(&nl);
        let mut at = vec![0.0f64; nl.len()];
        for i in 0..nl.len() {
            at[i] = node_arrival_ns(&sta.lib, nl.node(NodeId(i as u32)), &at, loads[i]);
        }
        assert_eq!(at, flat);
    }

    #[test]
    fn registers_cut_timing_paths() {
        // Two 8-deep XOR chains in series, registered at the midpoint: the
        // critical delay is the worst *segment*, roughly half the uncut
        // end-to-end delay, and the register's d pin is a real endpoint.
        let build = |cut: bool| {
            let mut nl = Netlist::new("seg");
            let mut prev = nl.input("i0");
            for k in 1..=8 {
                let i = nl.input(format!("i{k}"));
                prev = nl.xor2(prev, i);
            }
            if cut {
                let en = nl.constant(true);
                let clr = nl.constant(false);
                prev = nl.reg(prev, en, clr, false);
            }
            for k in 9..=16 {
                let i = nl.input(format!("i{k}"));
                prev = nl.xor2(prev, i);
            }
            nl.output("o", prev);
            nl
        };
        let sta = Sta::default();
        let uncut = sta.analyze(&build(false));
        let cut = sta.analyze(&build(true));
        assert!(
            cut.critical_delay_ns < uncut.critical_delay_ns * 0.7,
            "cut={} uncut={}",
            cut.critical_delay_ns,
            uncut.critical_delay_ns
        );
        assert!(cut.critical_delay_ns > 0.0);
        // Sequential power runs the cycle-accurate clocked toggle sweep.
        assert!(sta.dynamic_power_mw(&build(true)) > 0.0);
    }

    #[test]
    fn register_endpoint_governs_critical_delay() {
        // Deep logic feeding ONLY a register d pin (output is the shallow
        // register itself): the endpoint sweep must still see the deep
        // segment.
        let mut nl = Netlist::new("endpoint");
        let mut prev = nl.input("i0");
        for k in 1..=8 {
            let i = nl.input(format!("i{k}"));
            prev = nl.xor2(prev, i);
        }
        let en = nl.constant(true);
        let clr = nl.constant(false);
        let q = nl.reg(prev, en, clr, false);
        nl.output("q", q);
        let sta = Sta::default();
        let rep = sta.analyze(&nl);
        let at = sta.arrivals_ns(&nl);
        assert_eq!(rep.critical_delay_ns, at[prev.index()]);
        assert_eq!(at[q.index()], 0.0, "Q launches a fresh path");
    }

    #[test]
    fn wns_sign_convention() {
        let rep = StaReport {
            critical_delay_ns: 1.5,
            area_um2: 0.0,
            power_mw: 0.0,
            output_arrivals_ns: vec![],
            num_gates: 0,
            depth: 0,
        };
        assert!(rep.wns_ns(1.0) < 0.0); // 1 GHz clock missed
        assert!(rep.wns_ns(2.0) > 0.0);
    }

    #[test]
    fn power_positive_and_activity_sensitive() {
        let nl = xor_chain(16);
        let sta = Sta::default();
        let p = sta.dynamic_power_mw(&nl);
        assert!(p > 0.0);
        let fast = Sta { activity_rounds: 0, ..Sta::default() };
        assert!(fast.dynamic_power_mw(&nl) > 0.0);
    }

    #[test]
    fn incremental_matches_full_at_build() {
        let nl = xor_chain(12);
        let sta = Sta::default();
        let inc = IncrementalSta::new(&sta, &nl);
        assert_eq!(inc.arrivals(), &sta.arrivals_ns(&nl)[..]);
        assert_eq!(inc.stats().full_passes, 1);
    }

    #[test]
    fn incremental_retimes_only_the_cone() {
        // Perturb one mid-chain input of a 32-stage XOR chain: only the
        // downstream suffix may be re-timed, and arrivals must stay
        // bit-identical to a full sweep.
        let mut nl = xor_chain(32);
        let sta = Sta::default();
        let mut inc = IncrementalSta::new(&sta, &nl);
        let inputs = nl.inputs();
        let mid = inputs[20];
        nl.set_input_arrival(mid, 0.7);
        inc.touch(mid);
        let retimed = inc.propagate(&nl);
        assert!(retimed > 0 && retimed < nl.len() / 2, "retimed {retimed} of {}", nl.len());
        assert_eq!(inc.arrivals(), &sta.arrivals_ns(&nl)[..]);
        // Reverting the edit restores the original arrivals exactly.
        nl.set_input_arrival(mid, 0.0);
        inc.touch(mid);
        inc.propagate(&nl);
        assert_eq!(inc.arrivals(), &sta.arrivals_ns(&nl)[..]);
        assert!(inc.stats().retime_fraction() < 1.0);
    }

    #[test]
    fn incremental_absorbs_appended_gates_and_load_changes() {
        // Appending a gate increases its drivers' loads, which slows the
        // drivers themselves — sync() must dirty them, not just the new
        // cone.
        let mut nl = xor_chain(6);
        let sta = Sta::default();
        let mut inc = IncrementalSta::new(&sta, &nl);
        let inputs = nl.inputs();
        // Tap a mid-chain *gate*: its load grows, so the gate itself and the
        // whole chain suffix behind it must re-time.
        let mid_gate = (0..nl.len())
            .filter(|&i| nl.kind_at(i).is_some())
            .map(|i| NodeId(i as u32))
            .nth(2)
            .unwrap();
        let tap = nl.xor2(mid_gate, inputs[3]);
        let top = nl.and2(tap, inputs[5]);
        nl.output("o2", top);
        inc.sync(&nl);
        inc.propagate(&nl);
        assert_eq!(inc.arrivals(), &sta.arrivals_ns(&nl)[..]);
        assert_eq!(inc.critical_delay_ns(&nl), sta.analyze(&nl).critical_delay_ns);
    }

    #[test]
    fn timing_stats_merge_and_fraction() {
        let mut a = TimingStats::full_pass(100);
        a.merge(&TimingStats {
            full_passes: 0,
            incremental_passes: 1,
            nodes_retimed: 10,
            nodes_total: 100,
        });
        assert_eq!(a.full_passes, 1);
        assert_eq!(a.incremental_passes, 1);
        assert!((a.retime_fraction() - 110.0 / 200.0).abs() < 1e-12);
        assert_eq!(TimingStats::default().retime_fraction(), 1.0);
    }
}
