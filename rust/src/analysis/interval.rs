//! Word-level interval analysis over output weight groups.
//!
//! Primary outputs registered as `name0, name1, …` (the builders'
//! LSB-first convention — product bits are `p0..p{2n-1}`) are grouped by
//! their digit-stripped prefix, and each group is read as a little-endian
//! word: bit `k` of the group is the k-th output registered under that
//! prefix. The proven word interval follows directly from the ternary
//! domain:
//!
//! - `lo` — every bit proven 1 contributes `2^k`;
//! - `hi` — `lo` plus `2^k` for every bit *not* proven 0.
//!
//! Bitwise intervals are sound by construction (each sampled word sets a
//! subset of the non-proven-0 bits and a superset of the proven-1 bits),
//! which is exactly the containment property `rust/tests/analysis.rs`
//! asserts against 64-lane simulation. On top of the raw intervals the
//! analysis derives:
//!
//! - **unreachable carries** — a run of proven-0 bits at the MSB end of a
//!   group means no operand combination ever carries into those columns
//!   (UFO404);
//! - **weight-conservation cross-checks** — for unsigned designs the
//!   product group's interval must contain the operand-implied range
//!   `[0, maxA·maxB + maxC]`; a violation means some compressor-tree
//!   stage lost or invented bit weight (UFO405). Groups wider than 128
//!   bits are skipped (no `u128` headroom), which no generated design
//!   approaches.

use super::ternary::Tern;
use crate::ir::Netlist;

/// One output weight group: consecutive bits of a little-endian word.
#[derive(Debug, Clone)]
pub struct OutputGroup {
    /// Digit-stripped output-name prefix (`p` for `p0..p15`).
    pub name: String,
    /// Output registration ordinal of each bit, LSB first.
    pub ordinals: Vec<usize>,
    /// Driving node of each bit, LSB first.
    pub bits: Vec<u32>,
}

/// Group primary outputs by digit-stripped name prefix, in first-seen
/// registration order; bits stay in registration order within a group.
pub fn output_groups(nl: &Netlist) -> Vec<OutputGroup> {
    let mut groups: Vec<OutputGroup> = Vec::new();
    for (ordinal, (name, id)) in nl.outputs().enumerate() {
        let stem = name.trim_end_matches(|c: char| c.is_ascii_digit());
        let key = if stem.is_empty() { name } else { stem };
        match groups.iter_mut().find(|g| g.name == key) {
            Some(g) => {
                g.ordinals.push(ordinal);
                g.bits.push(id.0);
            }
            None => groups.push(OutputGroup {
                name: key.to_string(),
                ordinals: vec![ordinal],
                bits: vec![id.0],
            }),
        }
    }
    groups
}

/// Proven word interval of a group under a ternary valuation, or `None`
/// for groups too wide for `u128`.
pub fn group_interval(group: &OutputGroup, tern: &[Tern]) -> Option<(u128, u128)> {
    if group.bits.len() > 128 {
        return None;
    }
    let (mut lo, mut hi) = (0u128, 0u128);
    for (k, &b) in group.bits.iter().enumerate() {
        match tern[b as usize] {
            Tern::One => {
                lo |= 1u128 << k;
                hi |= 1u128 << k;
            }
            Tern::Unknown => hi |= 1u128 << k,
            Tern::Zero => {}
        }
    }
    Some((lo, hi))
}

/// Length of the proven-0 run at the MSB end of a group (the unreachable
/// carry columns), and the registration ordinal of the run's lowest bit.
pub fn unreachable_carry_run(group: &OutputGroup, tern: &[Tern]) -> Option<(usize, usize)> {
    let mut run = 0usize;
    for &b in group.bits.iter().rev() {
        if tern[b as usize] == Tern::Zero {
            run += 1;
        } else {
            break;
        }
    }
    if run == 0 || run == group.bits.len() {
        // A fully proven-constant group is a proven-constant *output*
        // story (UFO401), not a carry-reachability one.
        return None;
    }
    Some((run, group.ordinals[group.bits.len() - run]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{fixpoint, ternary::TernaryDomain};
    use crate::ir::Netlist;

    /// 2-bit adder with both MSB operand bits tied to constant 0: the top
    /// carry is structurally present but provably never asserted.
    fn capped_adder() -> (Netlist, Vec<crate::ir::NodeId>) {
        let mut nl = Netlist::new("capped");
        let a0 = nl.input("a0");
        let b0 = nl.input("b0");
        let a1 = nl.constant(false);
        let b1 = nl.constant(false);
        let s0 = nl.xor2(a0, b0);
        let c0 = nl.and2(a0, b0);
        let x1 = nl.xor2(a1, b1);
        let s1 = nl.xor2(x1, c0);
        let g1 = nl.and2(a1, b1);
        let p1 = nl.and2(x1, c0);
        let c1 = nl.or2(g1, p1);
        nl.output("s0", s0);
        nl.output("s1", s1);
        nl.output("s2", c1);
        (nl, vec![s0, s1, c1])
    }

    #[test]
    fn groups_strip_trailing_digits_in_registration_order() {
        let (nl, _) = capped_adder();
        let groups = output_groups(&nl);
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].name, "s");
        assert_eq!(groups[0].ordinals, vec![0, 1, 2]);
    }

    #[test]
    fn interval_and_carry_run_from_proven_bits() {
        let (nl, _) = capped_adder();
        let run = fixpoint::run(&nl, &TernaryDomain, 1, 4);
        let groups = output_groups(&nl);
        // a1 = b1 = 0 ⇒ s2 proven 0 while s0/s1 stay unknown, so the
        // bitwise interval is [0, 3] and the top carry column is dead.
        let (lo, hi) = group_interval(&groups[0], &run.values).unwrap();
        assert_eq!(lo, 0);
        assert_eq!(hi, 3);
        let (carry_run, ordinal) = unreachable_carry_run(&groups[0], &run.values).unwrap();
        assert_eq!(carry_run, 1);
        assert_eq!(ordinal, 2);
    }
}
