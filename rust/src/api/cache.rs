//! Sharded, content-addressed design cache with an optional persistent
//! disk tier.
//!
//! Keys are request [`Fingerprint`]s (content hashes of canonical request
//! forms); values are immutable [`DesignArtifact`]s behind `Arc`, so a hit
//! is one shard-lock acquisition plus a refcount bump — no netlist is ever
//! copied. Sharding keeps the batch compiler's worker threads from
//! serializing on one mutex; statistics are lock-free atomics.
//!
//! When constructed with [`DesignCache::with_disk`], every insert is also
//! written through to a versioned, checksummed entry file (one JSON file
//! per fingerprint — see [`crate::api::persist`] and `PROTOCOL.md`), and a
//! memory miss falls back to the disk tier before reporting a miss. Warm
//! designs therefore survive process restarts: a fresh engine pointed at
//! the same directory serves them without recompiling. Disk defects
//! (corrupted, truncated, or stale-version entries) are treated as misses
//! and the entry is rewritten on the next insert.

use super::engine::DesignArtifact;
use super::persist;
use super::request::Fingerprint;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Where a cache lookup was satisfied (or not).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheTier {
    /// Served from the in-memory map.
    Memory,
    /// Served from the persistent disk tier (and promoted to memory).
    Disk,
}

/// Aggregate cache counters (monotone over the cache's lifetime).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from the in-memory tier.
    pub hits: u64,
    /// Lookups served from the persistent disk tier.
    pub disk_hits: u64,
    /// Lookups that required a fresh synthesis.
    pub misses: u64,
    /// Compiles avoided by in-flight coalescing (identical requests that
    /// waited on a concurrent compile instead of starting their own;
    /// maintained by [`crate::api::SynthEngine`], always 0 for a bare
    /// cache).
    pub coalesced: u64,
    /// Artifacts currently cached in memory.
    pub entries: usize,
}

impl CacheStats {
    /// Hit fraction in `[0, 1]` over both tiers (0 when never queried).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.disk_hits + self.misses;
        if total == 0 {
            0.0
        } else {
            (self.hits + self.disk_hits) as f64 / total as f64
        }
    }
}

/// Fingerprint → `Arc<DesignArtifact>` map, split over `shards` mutexes,
/// with an optional write-through disk tier.
pub struct DesignCache {
    shards: Vec<Mutex<HashMap<u128, Arc<DesignArtifact>>>>,
    disk_dir: Option<PathBuf>,
    hits: AtomicU64,
    disk_hits: AtomicU64,
    misses: AtomicU64,
}

impl DesignCache {
    /// Empty in-memory cache split over `shards` mutexes (min 1).
    pub fn new(shards: usize) -> Self {
        let shards = shards.max(1);
        DesignCache {
            shards: (0..shards).map(|_| Mutex::new(HashMap::new())).collect(),
            disk_dir: None,
            hits: AtomicU64::new(0),
            disk_hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// [`DesignCache::new`] plus a persistent disk tier rooted at `dir`.
    ///
    /// The directory is created eagerly; if that fails (read-only
    /// filesystem, permission error) the cache degrades to memory-only
    /// rather than poisoning every compile.
    pub fn with_disk(shards: usize, dir: PathBuf) -> Self {
        let mut cache = DesignCache::new(shards);
        match std::fs::create_dir_all(&dir) {
            Ok(()) => cache.disk_dir = Some(dir),
            Err(e) => eprintln!(
                "design cache: disabling disk tier ({}: {e})",
                dir.display()
            ),
        }
        cache
    }

    /// The disk-tier directory, when one is configured.
    pub fn disk_dir(&self) -> Option<&PathBuf> {
        self.disk_dir.as_ref()
    }

    fn shard(&self, fp: Fingerprint) -> &Mutex<HashMap<u128, Arc<DesignArtifact>>> {
        &self.shards[fp.shard(self.shards.len())]
    }

    /// Look up a fingerprint, recording a hit or miss.
    pub fn get(&self, fp: Fingerprint) -> Option<Arc<DesignArtifact>> {
        self.get_traced(fp).map(|(a, _)| a)
    }

    /// [`DesignCache::get`] plus *which tier* satisfied the lookup. A disk
    /// hit is promoted into the memory tier on the way out.
    pub fn get_traced(&self, fp: Fingerprint) -> Option<(Arc<DesignArtifact>, CacheTier)> {
        if let Some(hit) = self.shard(fp).lock().unwrap().get(&fp.0).cloned() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Some((hit, CacheTier::Memory));
        }
        if let Some(dir) = &self.disk_dir {
            if let Ok(art) = persist::read_entry(dir, fp) {
                self.disk_hits.fetch_add(1, Ordering::Relaxed);
                let arc = {
                    let mut shard = self.shard(fp).lock().unwrap();
                    shard.entry(fp.0).or_insert_with(|| Arc::new(art)).clone()
                };
                return Some((arc, CacheTier::Disk));
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Look up without touching the hit/miss counters (the engine's
    /// post-coalescing re-check).
    pub(crate) fn peek(&self, fp: Fingerprint) -> Option<Arc<DesignArtifact>> {
        self.shard(fp).lock().unwrap().get(&fp.0).cloned()
    }

    /// Whether `fp` is resident in the memory tier or has a disk-tier
    /// entry file — without touching counters, deserializing, or
    /// promoting anything. The server's scheduling probe: a resident
    /// design answers in near-constant time, so its compile is classified
    /// urgent. A stat on a corrupt entry file can report `true` for a
    /// lookup that will later miss; that skews priority, never results.
    pub(crate) fn contains(&self, fp: Fingerprint) -> bool {
        if self.shard(fp).lock().unwrap().contains_key(&fp.0) {
            return true;
        }
        match &self.disk_dir {
            Some(dir) => persist::entry_path(dir, fp).is_file(),
            None => false,
        }
    }

    /// Reclassify the caller's just-recorded miss after in-flight
    /// coalescing deduplicated it: the compile rode a concurrent
    /// synthesis, so no *fresh* synthesis was required and `misses` must
    /// not count it (the leader's miss already accounts for the one real
    /// build).
    pub(crate) fn forgive_miss(&self) {
        self.misses.fetch_sub(1, Ordering::Relaxed);
    }

    /// Reclassify a just-recorded miss as a memory hit: the leader found
    /// the artifact already inserted when it re-checked after registering
    /// its in-flight entry.
    pub(crate) fn miss_to_hit(&self) {
        self.misses.fetch_sub(1, Ordering::Relaxed);
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Insert an artifact, returning the canonical `Arc` for the key.
    ///
    /// If two workers compiled the same request concurrently, the first
    /// insert wins and both callers get the same pointer — the engine's
    /// "identical request ⇒ identical artifact" guarantee. The winning
    /// insert is written through to the disk tier (best-effort: an
    /// unwritable directory costs persistence, not correctness).
    pub fn insert(&self, fp: Fingerprint, artifact: DesignArtifact) -> Arc<DesignArtifact> {
        let (arc, fresh) = {
            let mut shard = self.shard(fp).lock().unwrap();
            let mut fresh = false;
            let arc = shard
                .entry(fp.0)
                .or_insert_with(|| {
                    fresh = true;
                    Arc::new(artifact)
                })
                .clone();
            (arc, fresh)
        };
        if fresh {
            if let Some(dir) = &self.disk_dir {
                if let Err(e) = persist::write_entry(dir, fp, &arc) {
                    eprintln!("design cache: disk write failed for {fp}: {e}");
                }
            }
        }
        arc
    }

    /// Number of cached artifacts in memory.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Whether the memory tier currently holds no artifacts.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every in-memory entry (counters and disk entries survive — the
    /// next lookup for a persisted design is a disk hit, not a recompute).
    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
    }

    /// Aggregate hit/miss/entry counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            coalesced: 0,
            entries: self.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp(x: u128) -> Fingerprint {
        Fingerprint(x)
    }

    fn dummy() -> DesignArtifact {
        // A tiny real artifact via the engine keeps this test honest but
        // slow; a unit-cache test only needs *an* artifact, so build the
        // smallest design directly.
        let eng = crate::api::SynthEngine::new(crate::api::EngineConfig::default());
        let art = eng.compile(&crate::api::DesignRequest::multiplier(2)).unwrap();
        (*art).clone()
    }

    #[test]
    fn hit_miss_accounting_and_identity() {
        let cache = DesignCache::new(4);
        assert!(cache.get(fp(1)).is_none());
        let a = cache.insert(fp(1), dummy());
        let b = cache.get(fp(1)).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert_eq!(s.disk_hits, 0);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn first_insert_wins() {
        let cache = DesignCache::new(2);
        let a = cache.insert(fp(7), dummy());
        let b = cache.insert(fp(7), dummy());
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 1);
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn disk_tier_survives_clear() {
        let dir = std::env::temp_dir()
            .join(format!("ufo_cache_unit_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cache = DesignCache::with_disk(2, dir.clone());
        let art = dummy();
        let key = art.fingerprint;
        cache.insert(key, art);
        cache.clear();
        assert!(cache.is_empty());
        let (_, tier) = cache.get_traced(key).unwrap();
        assert_eq!(tier, CacheTier::Disk);
        // ...and the disk hit promoted the entry back into memory.
        let (_, tier) = cache.get_traced(key).unwrap();
        assert_eq!(tier, CacheTier::Memory);
        let s = cache.stats();
        assert_eq!((s.hits, s.disk_hits, s.misses), (1, 1, 0));
        std::fs::remove_dir_all(&dir).ok();
    }
}
