//! Gate-level synthesis primitives shared by the CT and CPA generators.
//!
//! Maps the paper's structural elements — 3:2 / 2:2 compressors (Figure 2),
//! prefix pg/black/blue nodes (§2.2, §4.2) — onto [`crate::ir::CellKind`]
//! instances, and exports the port-to-port delay constants (`T_xy` of
//! Eq. 13-16) that the interconnect-order ILP consumes.

pub mod verilog;

use crate::ir::{CellLib, Netlist, NodeId};

/// A signal during datapath construction: netlist node + the arrival-time
/// estimate the ILP timing model tracks (Eq. 13-16).
#[derive(Debug, Clone, Copy)]
pub struct Sig {
    /// Netlist node carrying the signal.
    pub node: NodeId,
    /// Model arrival estimate (ns).
    pub t: f64,
}

impl Sig {
    /// Signal with an arrival estimate (ns).
    pub fn new(node: NodeId, t: f64) -> Self {
        Sig { node, t }
    }
}

/// Port-to-port delay constants (ns) of the compressor cells under a
/// nominal internal load — the `T_xy` of the paper's Eq. (13)/(14).
#[derive(Debug, Clone, Copy)]
pub struct CompressorTiming {
    // 3:2 compressor (full adder): sum = XOR(XOR(a,b),cin),
    // cout = NAND(NAND(a,b), NAND(XOR(a,b),cin)).
    /// A → sum delay.
    pub t_as: f64,
    /// B → sum delay.
    pub t_bs: f64,
    /// Cin → sum delay.
    pub t_cs: f64,
    /// A → carry delay.
    pub t_ac: f64,
    /// B → carry delay.
    pub t_bc: f64,
    /// Cin → carry delay.
    pub t_cc: f64,
    // 2:2 compressor (half adder): sum = XOR(a,b), carry = AND(a,b).
    /// Input → sum delay of the 2:2.
    pub h_as: f64,
    /// Input → carry delay of the 2:2.
    pub h_ac: f64,
}

impl CompressorTiming {
    /// Derive the constants from the cell library at a nominal load.
    pub fn from_lib(lib: &CellLib) -> Self {
        use crate::ir::CellKind::*;
        let nominal = 2.0;
        let dx = lib.delay_ns(Xor2, nominal);
        let dn = lib.delay_ns(Nand2, nominal);
        let da = lib.delay_ns(And2, nominal);
        CompressorTiming {
            t_as: 2.0 * dx,
            t_bs: 2.0 * dx,
            t_cs: dx,
            // a/b reach cout through XOR→NAND→NAND (via the shared p term)
            // and NAND→NAND (via the g term); the former dominates.
            t_ac: dx + 2.0 * dn,
            t_bc: dx + 2.0 * dn,
            t_cc: 2.0 * dn,
            h_as: dx,
            h_ac: da,
        }
    }

    /// Input→worst-output delay for 3:2 ports (0 = A, 1 = B, 2 = Cin).
    pub fn fa_port_worst(&self, port: usize) -> f64 {
        match port {
            0 => self.t_as.max(self.t_ac),
            1 => self.t_bs.max(self.t_bc),
            _ => self.t_cs.max(self.t_cc),
        }
    }

    /// Input→worst-output delay for 2:2 ports (both symmetric).
    pub fn ha_port_worst(&self) -> f64 {
        self.h_as.max(self.h_ac)
    }
}

/// Result of instantiating a compressor.
#[derive(Debug, Clone, Copy)]
pub struct CompOut {
    /// Sum bit (same column).
    pub sum: Sig,
    /// Carry bit (next column).
    pub carry: Sig,
}

/// Instantiate a 3:2 compressor (full adder). Returns sum (same column) and
/// carry (next column), with ILP-model arrival estimates attached.
pub fn full_adder(nl: &mut Netlist, tm: &CompressorTiming, a: Sig, b: Sig, cin: Sig) -> CompOut {
    let x = nl.xor2(a.node, b.node);
    let sum = nl.xor2(x, cin.node);
    let n1 = nl.nand2(a.node, b.node);
    let n2 = nl.nand2(x, cin.node);
    let cout = nl.nand2(n1, n2);
    let ts = (a.t + tm.t_as).max(b.t + tm.t_bs).max(cin.t + tm.t_cs);
    let tc = (a.t + tm.t_ac).max(b.t + tm.t_bc).max(cin.t + tm.t_cc);
    CompOut { sum: Sig::new(sum, ts), carry: Sig::new(cout, tc) }
}

/// Instantiate a 2:2 compressor (half adder).
pub fn half_adder(nl: &mut Netlist, tm: &CompressorTiming, a: Sig, b: Sig) -> CompOut {
    let sum = nl.xor2(a.node, b.node);
    let carry = nl.and2(a.node, b.node);
    let ts = a.t.max(b.t) + tm.h_as;
    let tc = a.t.max(b.t) + tm.h_ac;
    CompOut { sum: Sig::new(sum, ts), carry: Sig::new(carry, tc) }
}

/// Bitwise propagate/generate pair for CPA inputs (§2.2, Eq. 1):
/// `p = a ⊕ b`, `g = a · b`.
pub fn pg_pair(nl: &mut Netlist, a: NodeId, b: NodeId) -> (NodeId, NodeId) {
    let p = nl.xor2(a, b);
    let g = nl.and2(a, b);
    (p, g)
}

/// Black prefix node (§2.2 Eq. 2-4): combines `(G_hi, P_hi)` (the trivial
/// fan-in) with `(G_lo, P_lo)` (the non-trivial fan-in):
/// `G = G_hi + P_hi·G_lo`, `P = P_hi·P_lo`.
///
/// CMOS mapping note: real libraries interleave AOI21+NAND2 / OAI21+NOR2 by
/// level polarity; we instantiate the positive-logic composite (And2+Or2 for
/// G, And2 for P) whose cell parameters already embed the two-stage CMOS
/// cost, keeping every generator on an identical footing.
pub fn black_node(
    nl: &mut Netlist,
    g_hi: NodeId,
    p_hi: NodeId,
    g_lo: NodeId,
    p_lo: NodeId,
) -> (NodeId, NodeId) {
    let t = nl.and2(p_hi, g_lo);
    let g = nl.or2(g_hi, t);
    let p = nl.and2(p_hi, p_lo);
    (g, p)
}

/// Blue prefix node (§4.2): final-level node that only needs the group
/// generate (drives a single sum XOR). `G = G_hi + P_hi·G_lo`.
pub fn blue_node(nl: &mut Netlist, g_hi: NodeId, p_hi: NodeId, g_lo: NodeId) -> NodeId {
    let t = nl.and2(p_hi, g_lo);
    nl.or2(g_hi, t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::CellLib;
    use crate::sim::{lane_value, pack_lanes, Simulator};

    #[test]
    fn full_adder_truth_table() {
        let lib = CellLib::nangate45();
        let tm = CompressorTiming::from_lib(&lib);
        let mut nl = Netlist::new("fa");
        let a = nl.input("a");
        let b = nl.input("b");
        let c = nl.input("c");
        let out = full_adder(
            &mut nl,
            &tm,
            Sig::new(a, 0.0),
            Sig::new(b, 0.0),
            Sig::new(c, 0.0),
        );
        nl.output("s", out.sum.node);
        nl.output("co", out.carry.node);
        let assigns: Vec<Vec<bool>> =
            (0..8u32).map(|v| vec![v & 1 != 0, v >> 1 & 1 != 0, v >> 2 & 1 != 0]).collect();
        let words = pack_lanes(&assigns);
        let mut sim = Simulator::new();
        let vals = sim.run(&nl, &words).to_vec();
        for v in 0..8u32 {
            let total = (v & 1) + (v >> 1 & 1) + (v >> 2 & 1);
            let got = lane_value(&vals, &[out.sum.node, out.carry.node], v);
            assert_eq!(got, u128::from(total), "v={v}");
        }
    }

    #[test]
    fn half_adder_truth_table() {
        let lib = CellLib::nangate45();
        let tm = CompressorTiming::from_lib(&lib);
        let mut nl = Netlist::new("ha");
        let a = nl.input("a");
        let b = nl.input("b");
        let out = half_adder(&mut nl, &tm, Sig::new(a, 0.0), Sig::new(b, 0.0));
        let assigns: Vec<Vec<bool>> = (0..4u32).map(|v| vec![v & 1 != 0, v >> 1 & 1 != 0]).collect();
        let words = pack_lanes(&assigns);
        let mut sim = Simulator::new();
        let vals = sim.run(&nl, &words).to_vec();
        for v in 0..4u32 {
            let total = (v & 1) + (v >> 1 & 1);
            assert_eq!(lane_value(&vals, &[out.sum.node, out.carry.node], v), u128::from(total));
        }
    }

    #[test]
    fn timing_constants_match_paper_ratios() {
        let lib = CellLib::nangate45();
        let tm = CompressorTiming::from_lib(&lib);
        // The paper (§3.4): two-XOR sum path ≈ 1.5× the NAND/OAI carry path,
        // and Cin ports are faster than A/B ports.
        let r = tm.t_as / tm.t_cc;
        assert!((1.2..=2.2).contains(&r), "sum/carry ratio {r}");
        assert!(tm.fa_port_worst(2) < tm.fa_port_worst(0));
        assert!(tm.ha_port_worst() < tm.fa_port_worst(0));
    }

    #[test]
    fn black_blue_nodes_compute_prefix_functions() {
        let mut nl = Netlist::new("pfx");
        let ins: Vec<_> = (0..4).map(|i| nl.input(format!("i{i}"))).collect();
        let (a, b) = (ins[0], ins[1]);
        let (c, d) = (ins[2], ins[3]);
        let (p0, g0) = pg_pair(&mut nl, a, b);
        let (p1, g1) = pg_pair(&mut nl, c, d);
        let (gb, pb) = black_node(&mut nl, g1, p1, g0, p0);
        let gblue = blue_node(&mut nl, g1, p1, g0);
        nl.output("gb", gb);
        nl.output("pb", pb);
        nl.output("gblue", gblue);
        let assigns: Vec<Vec<bool>> = (0..16u32)
            .map(|v| (0..4).map(|k| v >> k & 1 != 0).collect())
            .collect();
        let words = pack_lanes(&assigns);
        let mut sim = Simulator::new();
        let vals = sim.run(&nl, &words).to_vec();
        for v in 0..16u32 {
            let bit = |n: u32| v >> n & 1 != 0;
            let (g0v, p0v) = (bit(0) & bit(1), bit(0) ^ bit(1));
            let (g1v, p1v) = (bit(2) & bit(3), bit(2) ^ bit(3));
            let expect_g = g1v || (p1v && g0v);
            let expect_p = p1v && p0v;
            assert_eq!(vals[gb.index()] >> v & 1 == 1, expect_g);
            assert_eq!(vals[pb.index()] >> v & 1 == 1, expect_p);
            assert_eq!(vals[gblue.index()] >> v & 1 == 1, expect_g);
        }
    }
}
