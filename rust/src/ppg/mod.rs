//! Partial product generation (§2.1), operand-format aware.
//!
//! Produces the column-wise partial-product bit matrix that the compressor
//! tree consumes, for any [`OperandFormat`] — unsigned or two's-complement
//! signed, square or rectangular `n×m`. Two generator families:
//!
//! - [`PpgKind::AndArray`] — the paper's baseline `n·m`-AND-gate PPG;
//!   the signed variant applies Baugh–Wooley sign-correction rows
//!   (inverted boundary terms plus a folded constant).
//! - [`PpgKind::Booth4`] — radix-4 (modified) Booth recoding of the `b`
//!   operand, halving the number of partial-product rows (the structure
//!   commercial multiplier IP uses at larger widths). Unsigned operands
//!   are zero-extended by two bits so the top digit is non-negative;
//!   signed operands use true sign extension of both the recoded digits
//!   and the multiplicand rows. Both share the `~s, s, s` sign-extension
//!   compaction.
//!
//! For the fused MAC architecture (§2.3) the accumulator operand is injected
//! directly as extra rows of the matrix (see [`PpMatrix::add_addend`]), so
//! the CT absorbs the accumulation for free — the paper's headline MAC
//! optimization.

use crate::ir::{CellLib, Netlist, NodeId};
use crate::synth::Sig;

/// Partial-product generator selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PpgKind {
    /// AND-gate array (Baugh–Wooley for signed operands).
    AndArray,
    /// Radix-4 modified Booth recoding.
    Booth4,
}

/// Two's-complement interpretation of the operands.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Signedness {
    /// Operands are plain binary magnitudes.
    Unsigned,
    /// Operands (and the accumulator, for MACs) are two's complement.
    Signed,
}

/// Operand format of a multiplier / MAC: per-operand widths plus the
/// signedness both operands share. The default format for a width-`n`
/// request is `Unsigned, n×n`; rectangular and signed formats open the
/// DSP-style workload families (asymmetric datapaths, signed activations).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct OperandFormat {
    /// Shared signedness of both operands.
    pub signedness: Signedness,
    /// Width of operand `a` (the multiplicand), bits.
    pub a_bits: usize,
    /// Width of operand `b` (the Booth-recoded operand), bits.
    pub b_bits: usize,
}

impl OperandFormat {
    /// Unsigned square `n×n` — the legacy default.
    pub fn unsigned(n: usize) -> OperandFormat {
        OperandFormat { signedness: Signedness::Unsigned, a_bits: n, b_bits: n }
    }

    /// Signed (two's complement) square `n×n`.
    pub fn signed(n: usize) -> OperandFormat {
        OperandFormat { signedness: Signedness::Signed, a_bits: n, b_bits: n }
    }

    /// Unsigned rectangular `a_bits × b_bits`.
    pub fn rect(a_bits: usize, b_bits: usize) -> OperandFormat {
        OperandFormat { signedness: Signedness::Unsigned, a_bits, b_bits }
    }

    /// Signed rectangular `a_bits × b_bits`.
    pub fn signed_rect(a_bits: usize, b_bits: usize) -> OperandFormat {
        OperandFormat { signedness: Signedness::Signed, a_bits, b_bits }
    }

    /// Whether operands are two's complement.
    pub fn is_signed(&self) -> bool {
        self.signedness == Signedness::Signed
    }

    /// Product width: `a_bits + b_bits` covers the full range in both the
    /// unsigned and the two's-complement interpretation.
    pub fn out_bits(&self) -> usize {
        self.a_bits + self.b_bits
    }

    /// Wider of the two operands (the reporting width).
    pub fn max_bits(&self) -> usize {
        self.a_bits.max(self.b_bits)
    }

    /// Structural validity: both operands non-empty and the product narrow
    /// enough for the `u128` reference model and modular constant folding
    /// (a fused MAC needs `a+b+1` exact columns and the reference model a
    /// `2^{a+b+1}` mask, so `a+b` is capped at 126).
    pub fn validate(&self) -> Result<(), String> {
        if self.a_bits == 0 || self.b_bits == 0 {
            return Err("operand widths must be >= 1".into());
        }
        if self.a_bits + self.b_bits > 126 {
            return Err(format!(
                "product width {} exceeds the 126-bit reference-model limit",
                self.a_bits + self.b_bits
            ));
        }
        Ok(())
    }
}

/// Column-indexed partial-product matrix: `columns[j]` holds the bits of
/// weight `2^j`, each with the timing-model arrival estimate.
#[derive(Debug, Clone)]
pub struct PpMatrix {
    /// `columns[j]` = partial-product bits of weight `2^j`.
    pub columns: Vec<Vec<Sig>>,
    /// Width of operand `a` that produced the matrix.
    pub a_bits: usize,
    /// Width of operand `b` that produced the matrix.
    pub b_bits: usize,
}

impl PpMatrix {
    /// Column population counts — the `PP_j` input of Algorithm 1.
    pub fn counts(&self) -> Vec<usize> {
        self.columns.iter().map(|c| c.len()).collect()
    }

    /// Widen to at least `n` columns.
    pub fn ensure_columns(&mut self, n: usize) {
        while self.columns.len() < n {
            self.columns.push(Vec::new());
        }
    }

    /// Inject an addend operand (for fused MACs): bit `k` of `bits` lands in
    /// column `k`.
    pub fn add_addend(&mut self, bits: &[Sig]) {
        self.ensure_columns(bits.len());
        for (k, s) in bits.iter().enumerate() {
            self.columns[k].push(*s);
        }
    }

    /// Inject a two's-complement addend (signed fused MACs): like
    /// [`PpMatrix::add_addend`], plus the sign bit replicated once at
    /// column `bits.len()` — a w-bit signed value mod `2^{w+1}` carries
    /// its MSB at weight `2^w` as well. One definition shared by the
    /// builder and the RL-MUL probe, so searched stage plans always match
    /// the matrix shape the builder compresses.
    pub fn add_addend_signed(&mut self, bits: &[Sig]) {
        self.add_addend(bits);
        if let Some(&msb) = bits.last() {
            self.ensure_columns(bits.len() + 1);
            self.columns[bits.len()].push(msb);
        }
    }

    /// Max column height (reported as the CT's input rank).
    pub fn max_height(&self) -> usize {
        self.columns.iter().map(|c| c.len()).max().unwrap_or(0)
    }
}

/// Build the unsigned AND-array PPG for `a[0..n] × b[0..m]` into `nl`.
///
/// Operands may be rectangular; the matrix spans `n+m-1` columns and
/// arrival estimates equal one AND stage at nominal load.
pub fn and_array(nl: &mut Netlist, lib: &CellLib, a: &[NodeId], b: &[NodeId]) -> PpMatrix {
    let n = a.len();
    let m = b.len();
    assert!(n >= 1 && m >= 1, "and_array needs non-empty operands");
    let d_and = lib.delay_ns(crate::ir::CellKind::And2, 2.0);
    // The array's shape is fully determined: n·m AND gates, column j
    // holding the parallelogram height. Reserving both up front keeps the
    // PPG allocation-free past this point (EXPERIMENTS.md §Perf).
    nl.reserve(n * m);
    let mut columns: Vec<Vec<Sig>> = (0..n + m - 1)
        .map(|j| Vec::with_capacity(n.min(m).min(j + 1).min(n + m - 1 - j)))
        .collect();
    for (i, &ai) in a.iter().enumerate() {
        for (j, &bj) in b.iter().enumerate() {
            let g = nl.and2(ai, bj);
            columns[i + j].push(Sig::new(g, d_and));
        }
    }
    PpMatrix { columns, a_bits: n, b_bits: m }
}

/// Build the Baugh–Wooley signed AND-array PPG for two's-complement
/// `a[0..n] × b[0..m]`, exact mod `2^out_cols`.
///
/// Writing `a = -a_{n-1}·2^{n-1} + Σ a_i 2^i` (and likewise `b`), every
/// product term with exactly one sign bit is negative. Each `-x·2^w` is
/// replaced by `x̄·2^w - 2^w` (one NAND-style inverted bit), and the `-2^w`
/// corrections fold into a single constant injected as constant-one bits —
/// the standard Baugh–Wooley sign-correction rows, made exact mod
/// `2^out_cols` so the same generator serves plain products (`n+m`
/// columns) and fused MACs (`n+m+1`).
pub fn and_array_signed(
    nl: &mut Netlist,
    lib: &CellLib,
    a: &[NodeId],
    b: &[NodeId],
    out_cols: usize,
) -> PpMatrix {
    let n = a.len();
    let m = b.len();
    assert!(n >= 1 && m >= 1, "and_array_signed needs non-empty operands");
    assert!(out_cols >= n + m - 1, "out_cols too narrow for the product");
    assert!(out_cols < 128, "out_cols exceeds the u128 folding range");
    let d_and = lib.delay_ns(crate::ir::CellKind::And2, 2.0);
    let d_nand = lib.delay_ns(crate::ir::CellKind::Nand2, 2.0);
    let modulus = 1u128 << out_cols;
    let mut c_const = 0u128;
    // n·m product terms plus at most one folded constant node; +1 column
    // capacity absorbs the Baugh–Wooley constant bits.
    nl.reserve(n * m + 1);
    let mut columns: Vec<Vec<Sig>> = (0..out_cols)
        .map(|j| {
            Vec::with_capacity(if j < n + m - 1 {
                n.min(m).min(j + 1).min(n + m - 1 - j) + 1
            } else {
                1
            })
        })
        .collect();
    for (i, &ai) in a.iter().enumerate() {
        for (j, &bj) in b.iter().enumerate() {
            let w = i + j;
            // Exactly one sign operand ⇒ the term is negative: one NAND
            // cell realizes the inverted Baugh–Wooley boundary bit.
            let negative = (i == n - 1) ^ (j == m - 1);
            if negative {
                let gn = nl.nand2(ai, bj);
                columns[w].push(Sig::new(gn, d_nand));
                c_const = (c_const + modulus - (1u128 << w)) % modulus;
            } else {
                let g = nl.and2(ai, bj);
                columns[w].push(Sig::new(g, d_and));
            }
        }
    }
    if c_const != 0 {
        let one_const = nl.constant(true);
        for (j, col) in columns.iter_mut().enumerate() {
            if c_const >> j & 1 == 1 {
                col.push(Sig::new(one_const, 0.0));
            }
        }
    }
    PpMatrix { columns, a_bits: n, b_bits: m }
}

/// Radix-4 Booth digit selector output for one row bit.
///
/// Digit `d ∈ {-2,-1,0,1,2}` is encoded by (neg, one, two):
/// `pp_bit_k = neg ⊕ (one·a_k + two·a_{k-1})`, with the +1 correction for
/// negative digits injected as a separate LSB bit.
struct BoothRow {
    bits: Vec<Sig>,
    neg: Sig,
}

/// Build a radix-4 Booth PPG for unsigned `a × b` over `a.len() + b.len()`
/// columns.
///
/// Unsigned operands are zero-extended by two bits so that the top digit is
/// non-negative; rows are sign-extended with the standard `~s, s, s`
/// compaction trick and negative rows add their `+1` correction bit into the
/// row's LSB column.
pub fn booth4(nl: &mut Netlist, lib: &CellLib, a: &[NodeId], b: &[NodeId]) -> PpMatrix {
    booth4_wide(nl, lib, a, b, a.len() + b.len())
}

/// Radix-4 Booth PPG for unsigned operands, exact mod `2^out_cols` — fused
/// MACs need one extra column (`n+m+1`) so the accumulator sum's MSB stays
/// exact.
pub fn booth4_wide(
    nl: &mut Netlist,
    lib: &CellLib,
    a: &[NodeId],
    b: &[NodeId],
    out_cols: usize,
) -> PpMatrix {
    booth4_fmt(nl, lib, a, b, Signedness::Unsigned, out_cols)
}

/// Radix-4 Booth PPG for either signedness, exact mod `2^out_cols`.
///
/// `b` is the recoded operand. Unsigned operands zero-extend (`m/2 + 1`
/// rows, non-negative top digit); signed operands use true sign extension
/// of both the digit window (`b` extends with `b_{m-1}`) and the
/// multiplicand rows (`a` extends with `a_{n-1}`), which needs only
/// `⌈m/2⌉` rows. Both variants share the `~s, s, s` sign-extension
/// compaction: the row's sign bit is the Booth `neg` signal for unsigned
/// magnitudes and the row's computed MSB for signed rows.
pub fn booth4_fmt(
    nl: &mut Netlist,
    lib: &CellLib,
    a: &[NodeId],
    b: &[NodeId],
    signedness: Signedness,
    out_cols: usize,
) -> PpMatrix {
    use crate::ir::CellKind::*;
    let n = a.len();
    let m = b.len();
    assert!(n >= 1 && m >= 1, "booth4 needs non-empty operands");
    assert!(out_cols >= n + m, "out_cols too narrow for the product");
    assert!(out_cols < 128, "out_cols exceeds the u128 folding range");
    let signed = signedness == Signedness::Signed;
    let zero = nl.constant(false);
    let d_sel = lib.delay_ns(Xor2, 2.0) + lib.delay_ns(Aoi21, 2.0) + lib.delay_ns(Inv, 2.0);

    // Booth digits over b: digit i looks at b[2i+1], b[2i], b[2i-1], with
    // zero extension (unsigned) or sign extension (signed) past the MSB.
    let n_rows = if signed { m.div_ceil(2) } else { m / 2 + 1 };
    // Per row: 7 digit-decode gates, 4 selector gates per row bit
    // (`0..=n`), and one sign-compaction inverter; plus the two shared
    // constants. An upper bound is fine — reserve trades transient
    // capacity for zero mid-build reallocation.
    nl.reserve(n_rows * (7 + 4 * (n + 1) + 1) + 2);
    let bit = |idx: isize| -> NodeId {
        if idx < 0 {
            zero
        } else if (idx as usize) < m {
            b[idx as usize]
        } else if signed {
            b[m - 1]
        } else {
            zero
        }
    };

    let mut rows: Vec<BoothRow> = Vec::with_capacity(n_rows);
    for r in 0..n_rows {
        let hi = bit(2 * r as isize + 1);
        let mid = bit(2 * r as isize);
        let lo = bit(2 * r as isize - 1);
        // one  = mid ⊕ lo  (|d| == 1)
        // two  = (hi ⊕ mid) · (mid ≡ lo)
        // neg  = hi·!(mid·lo)
        let one = nl.xor2(mid, lo);
        let eq_ml = nl.xnor2(mid, lo);
        let two = {
            let x = nl.xor2(hi, mid);
            nl.and2(x, eq_ml)
        };
        let neg = {
            let ml = nl.and2(mid, lo);
            let nml = nl.inv(ml);
            nl.and2(hi, nml)
        };
        // Row bits k = 0..n: pp_k = neg ⊕ (one·a_k | two·a_{k-1}), where
        // a_n is zero (unsigned) or the sign bit a_{n-1} (signed).
        let mut bits = Vec::with_capacity(n + 1);
        for k in 0..=n {
            let ak = if k < n {
                a[k]
            } else if signed {
                a[n - 1]
            } else {
                zero
            };
            let ak1 = if k >= 1 { a[k - 1] } else { zero };
            let t1 = nl.and2(one, ak);
            let t2 = nl.and2(two, ak1);
            let or = nl.or2(t1, t2);
            let pp = nl.xor2(or, neg);
            bits.push(Sig::new(pp, d_sel));
        }
        rows.push(BoothRow { bits, neg: Sig::new(neg, d_sel) });
    }

    // Assemble columns with exact sign-extension compaction. Row r (base
    // column 2r, bits over base..base+n) contributes, mod 2^out_cols:
    //
    //   bits  +  neg·2^base            (the +1 of the two's complement)
    //         +  s·(ones ≥ base+n+1)   (sign extension)
    //
    // where the row sign s is `neg` for unsigned magnitudes and the row's
    // computed MSB pp_n for signed rows, and
    // s·(ones ≥ base+n+1) ≡ (~s)·2^{base+n+1} − 2^{base+n+1}. The per-row
    // `−2^{base+n+1}` terms fold into one global constant C injected as
    // constant bits — the standard "(~s) + constant" trick, made exact mod
    // 2^out_cols.
    // Column height is bounded by the row count plus the per-row
    // correction and compaction bits that share a column.
    let mut columns: Vec<Vec<Sig>> =
        (0..out_cols).map(|_| Vec::with_capacity(n_rows + 2)).collect();
    for (r, row) in rows.iter().enumerate() {
        let base = 2 * r;
        for (k, s) in row.bits.iter().enumerate() {
            if base + k < columns.len() {
                columns[base + k].push(*s);
            }
        }
        // +1 correction for negative rows lands at the row LSB column.
        columns[base].push(row.neg);
        // (~s) at base+n+1.
        let sign = if signed { row.bits[n] } else { row.neg };
        if base + n + 1 < columns.len() {
            let ns = nl.inv(sign.node);
            columns[base + n + 1].push(Sig::new(ns, d_sel));
        }
    }
    // Global constant C = (− Σ_r 2^{2r+n+1}) mod 2^out_cols.
    let modulus = 1u128 << out_cols;
    let mut c_const = 0u128;
    for r in 0..rows.len() {
        let shift = 2 * r + n + 1;
        if shift < out_cols {
            c_const = (c_const + modulus - (1u128 << shift)) % modulus;
        }
    }
    if c_const != 0 {
        let one_const = nl.constant(true);
        for (j, col) in columns.iter_mut().enumerate() {
            if c_const >> j & 1 == 1 {
                col.push(Sig::new(one_const, 0.0));
            }
        }
    }
    PpMatrix { columns, a_bits: n, b_bits: m }
}

/// Build an unsigned PPG of the requested kind (legacy entry point; the
/// format-aware generators are called directly by the multiplier builder).
pub fn generate(
    nl: &mut Netlist,
    lib: &CellLib,
    kind: PpgKind,
    a: &[NodeId],
    b: &[NodeId],
) -> PpMatrix {
    match kind {
        PpgKind::AndArray => and_array(nl, lib, a, b),
        PpgKind::Booth4 => booth4(nl, lib, a, b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{CellLib, Netlist};
    use crate::sim::{pack_lanes, Simulator};

    /// Sum a PP matrix numerically per lane (golden reduction).
    fn matrix_value(vals: &[u64], m: &PpMatrix, lane: u32) -> u128 {
        let mut total = 0u128;
        for (j, col) in m.columns.iter().enumerate() {
            for s in col {
                total += u128::from(vals[s.node.index()] >> lane & 1) << j;
            }
        }
        total
    }

    use crate::util::sign_extend as sext;

    /// Build a PPG over an `na × nb` operand pair and check its column sum
    /// against the format's golden product, mod `2^mod_bits`.
    fn check_ppg_fmt(kind: PpgKind, fmt: OperandFormat, mod_bits: usize) {
        let lib = CellLib::nangate45();
        let mut nl = Netlist::new("ppg");
        let (na, nb) = (fmt.a_bits, fmt.b_bits);
        let a: Vec<_> = (0..na).map(|i| nl.input(format!("a{i}"))).collect();
        let b: Vec<_> = (0..nb).map(|i| nl.input(format!("b{i}"))).collect();
        let m = match (kind, fmt.signedness) {
            (PpgKind::AndArray, Signedness::Unsigned) => and_array(&mut nl, &lib, &a, &b),
            (PpgKind::AndArray, Signedness::Signed) => {
                and_array_signed(&mut nl, &lib, &a, &b, na + nb)
            }
            (PpgKind::Booth4, s) => booth4_fmt(&mut nl, &lib, &a, &b, s, na + nb),
        };
        nl.validate().unwrap();
        let mask = (1u128 << mod_bits) - 1;
        let modulus = 1i128 << mod_bits;
        let mut sim = Simulator::new();
        let all: Vec<(u32, u32)> = (0..1u32 << na)
            .flat_map(|x| (0..1u32 << nb).map(move |y| (x, y)))
            .collect();
        for chunk in all.chunks(64) {
            let assigns: Vec<Vec<bool>> = chunk
                .iter()
                .map(|(x, y)| {
                    (0..na)
                        .map(|k| x >> k & 1 != 0)
                        .chain((0..nb).map(|k| y >> k & 1 != 0))
                        .collect()
                })
                .collect();
            let words = pack_lanes(&assigns);
            let vals = sim.run(&nl, &words).to_vec();
            for (lane, (x, y)) in chunk.iter().enumerate() {
                let got = matrix_value(&vals, &m, lane as u32) & mask;
                let want = match fmt.signedness {
                    Signedness::Unsigned => u128::from(*x) * u128::from(*y) & mask,
                    Signedness::Signed => {
                        let p = sext(u128::from(*x), na) * sext(u128::from(*y), nb);
                        p.rem_euclid(modulus) as u128
                    }
                };
                assert_eq!(got, want, "{kind:?} {fmt:?} {x}*{y}");
            }
        }
    }

    fn check_ppg(kind: PpgKind, n: usize, mod_bits: usize) {
        check_ppg_fmt(kind, OperandFormat::unsigned(n), mod_bits);
    }

    #[test]
    fn and_array_4x4_exhaustive() {
        check_ppg(PpgKind::AndArray, 4, 8);
    }

    #[test]
    fn booth4_4x4_exhaustive_mod_2n() {
        // Booth rows are exact mod 2^(2n) after compaction-trim.
        check_ppg(PpgKind::Booth4, 4, 8);
    }

    #[test]
    fn booth4_3x3_exhaustive_mod_2n() {
        check_ppg(PpgKind::Booth4, 3, 6);
    }

    #[test]
    fn signed_generators_exhaustive() {
        for kind in [PpgKind::AndArray, PpgKind::Booth4] {
            for n in 1..=4 {
                check_ppg_fmt(kind, OperandFormat::signed(n), 2 * n);
            }
        }
    }

    #[test]
    fn rectangular_generators_exhaustive() {
        for kind in [PpgKind::AndArray, PpgKind::Booth4] {
            check_ppg_fmt(kind, OperandFormat::rect(2, 5), 7);
            check_ppg_fmt(kind, OperandFormat::rect(5, 2), 7);
            check_ppg_fmt(kind, OperandFormat::signed_rect(3, 5), 8);
            check_ppg_fmt(kind, OperandFormat::signed_rect(5, 3), 8);
        }
    }

    #[test]
    fn and_array_counts_are_triangular() {
        let lib = CellLib::nangate45();
        let mut nl = Netlist::new("ppg");
        let a: Vec<_> = (0..8).map(|i| nl.input(format!("a{i}"))).collect();
        let b: Vec<_> = (0..8).map(|i| nl.input(format!("b{i}"))).collect();
        let m = and_array(&mut nl, &lib, &a, &b);
        assert_eq!(m.counts(), vec![1, 2, 3, 4, 5, 6, 7, 8, 7, 6, 5, 4, 3, 2, 1]);
        assert_eq!(m.max_height(), 8);
        assert_eq!((m.a_bits, m.b_bits), (8, 8));
    }

    #[test]
    fn booth_has_fewer_rows() {
        let lib = CellLib::nangate45();
        let mut nl = Netlist::new("ppg");
        let a: Vec<_> = (0..16).map(|i| nl.input(format!("a{i}"))).collect();
        let b: Vec<_> = (0..16).map(|i| nl.input(format!("b{i}"))).collect();
        let mb = booth4(&mut nl, &lib, &a, &b);
        // Radix-4 Booth max column height ≈ n/2+2 < n for n = 16.
        assert!(mb.max_height() <= 11, "booth height {}", mb.max_height());
    }

    #[test]
    fn signed_booth_has_fewer_rows_than_unsigned() {
        let lib = CellLib::nangate45();
        let count = |s: Signedness| {
            let mut nl = Netlist::new("ppg");
            let a: Vec<_> = (0..16).map(|i| nl.input(format!("a{i}"))).collect();
            let b: Vec<_> = (0..16).map(|i| nl.input(format!("b{i}"))).collect();
            booth4_fmt(&mut nl, &lib, &a, &b, s, 32).max_height()
        };
        // True sign extension drops the zero-extension top row.
        assert!(count(Signedness::Signed) <= count(Signedness::Unsigned));
    }

    #[test]
    fn addend_injection_for_mac() {
        let lib = CellLib::nangate45();
        let mut nl = Netlist::new("mac-ppg");
        let a: Vec<_> = (0..4).map(|i| nl.input(format!("a{i}"))).collect();
        let b: Vec<_> = (0..4).map(|i| nl.input(format!("b{i}"))).collect();
        let c: Vec<_> = (0..8).map(|i| nl.input(format!("c{i}"))).collect();
        let mut m = and_array(&mut nl, &lib, &a, &b);
        m.add_addend(&c.iter().map(|&n| Sig::new(n, 0.0)).collect::<Vec<_>>());
        // columns 0..6 are the 4×4 triangle +1; column 7 holds only c7
        assert_eq!(m.counts(), vec![2, 3, 4, 5, 4, 3, 2, 1]);
    }

    #[test]
    fn operand_format_helpers() {
        let f = OperandFormat::signed_rect(4, 6);
        assert!(f.is_signed());
        assert_eq!(f.out_bits(), 10);
        assert_eq!(f.max_bits(), 6);
        f.validate().unwrap();
        assert!(OperandFormat::rect(0, 4).validate().is_err());
        assert!(OperandFormat::rect(100, 100).validate().is_err());
        assert_eq!(OperandFormat::unsigned(8), OperandFormat::rect(8, 8));
    }
}
