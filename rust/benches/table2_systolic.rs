//! Table 2 — 16×16 systolic arrays of fused MACs (8- and 16-bit PEs),
//! three constraint regimes, four methods. Reports Freq/WNS/Area/Power.

use ufo_mac::baselines::Method;
use ufo_mac::bench::Bench;
use ufo_mac::modules::systolic_report;
use ufo_mac::multiplier::Strategy;
use ufo_mac::util::Table;

fn main() {
    let bench = Bench::new("table2_systolic");
    let quick = std::env::var("UFO_BENCH_QUICK").is_ok();
    let widths: &[usize] = if quick { &[8] } else { &[8, 16] };

    // Paper's Table 2 clock targets: (8-bit, 16-bit).
    let regimes: [(&str, Strategy, [f64; 2]); 3] = [
        ("area-driven", Strategy::AreaDriven, [660e6, 400e6]),
        ("timing-driven", Strategy::TimingDriven, [2e9, 1e9]),
        ("trade-off", Strategy::TradeOff, [1e9, 660e6]),
    ];

    println!("\nTable 2 reproduction: 16×16 systolic arrays");
    for (label, strategy, freqs) in regimes {
        for (wi, &n) in widths.iter().enumerate() {
            let freq = freqs[wi];
            let mut table =
                Table::new(&["method", "freq", "WNS(ns)", "area(µm²)", "power(mW)"]);
            let mut rows = Vec::new();
            for m in Method::ALL {
                let r = systolic_report(m, n, strategy, freq).unwrap();
                table.row(vec![
                    m.name().into(),
                    format!("{:.0}M", freq / 1e6),
                    format!("{:.4}", r.wns_ns),
                    format!("{:.0}", r.area_um2),
                    format!("{:.3}", r.power_mw),
                ]);
                rows.push((m, r));
            }
            println!("\n{label}, {n}-bit PEs @ {:.0} MHz:\n{}", freq / 1e6, table.render());
            let ufo = rows.iter().find(|(m, _)| *m == Method::UfoMac).unwrap().1.clone();
            let com =
                rows.iter().find(|(m, _)| *m == Method::Commercial).unwrap().1.clone();
            bench.metric(&format!("{label}_{n}_ufo_area"), ufo.area_um2, "um2");
            bench.metric(&format!("{label}_{n}_ufo_wns"), ufo.wns_ns, "ns");
            bench.metric(&format!("{label}_{n}_commercial_area"), com.area_um2, "um2");
            bench.metric(&format!("{label}_{n}_commercial_wns"), com.wns_ns, "ns");
            // Table-2 shape: under the area regime UFO-MAC's array is the
            // smallest across methods (the paper's consistent outcome).
            if matches!(strategy, Strategy::AreaDriven) {
                let min_area =
                    rows.iter().map(|(_, r)| r.area_um2).fold(f64::INFINITY, f64::min);
                assert!(
                    ufo.area_um2 <= min_area * 1.001,
                    "{label} {n}-bit: UFO area {:.0} vs best {:.0}",
                    ufo.area_um2,
                    min_area
                );
            }
        }
    }

    bench.bench("systolic_report_ufo_8bit", || {
        systolic_report(Method::UfoMac, 8, Strategy::TradeOff, 1e9).unwrap()
    });
}
