//! Netlist intermediate representation and standard-cell library.
//!
//! This is the substrate every other module builds on: the paper's
//! generators emit [`Netlist`]s, the STA engine times them, the simulator
//! and the PJRT-backed evaluator execute them. The netlist is stored as
//! flat struct-of-arrays (opcode byte + inline fanin record per node) with
//! a lazily built, edit-invalidated [`Topology`] cache — see
//! [`netlist`] for the layout and invalidation rules.

pub mod cell;
pub mod netlist;

pub use cell::{CellKind, CellLib, CellParams};
pub use netlist::{Netlist, Node, NodeId, NodeIter, OutputIter, Topology};
pub use netlist::{OP_CONST0, OP_CONST1, OP_INPUT, OP_REG};
