//! Carry-propagate adder optimization (§4 of the paper).
//!
//! [`graph`] — prefix-graph IR + regular structures; [`timing`] — depth /
//! mpfo / FDC models and Figure-8 regression; [`optimize`] — Algorithm 2;
//! [`netlist`] — expansion to gates. This module adds the §4.1 region
//! segmentation of the CT's non-uniform arrival profile, the strategy
//! presets used in the experiments (area-driven / timing-driven /
//! trade-off), and the random-adder dataset generator behind Figure 8.

pub mod graph;
pub mod netlist;
pub mod optimize;
pub mod timing;

pub use graph::{build, hybrid_regions, PIdx, PNode, PrefixGraph, PrefixStructure, NONE};
pub use netlist::{expand, standalone_adder, CpaColumn, CpaOut};
pub use optimize::{estimate_bit_delays, optimize, OptReport};
pub use timing::{fdc_features, fit_fdc, FdcFeatures, FdcModel, Fidelity};

use crate::util::Rng;

/// CPA synthesis strategy (§5.1: the paper evaluates timing-driven,
/// area-driven and trade-off variants of Algorithm 2 for every design).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CpaStrategy {
    /// Area first: hybrid initial structure, no timing transforms beyond
    /// what the profile strictly requires (loose target).
    AreaDriven,
    /// Timing first: tight target (the profile's flat region delay).
    TimingDriven,
    /// Balanced target between the two.
    TradeOff,
}

impl CpaStrategy {
    /// Stable machine-readable key (CLI flag value, request serialization).
    pub fn key(&self) -> &'static str {
        match self {
            CpaStrategy::AreaDriven => "area",
            CpaStrategy::TimingDriven => "timing",
            CpaStrategy::TradeOff => "tradeoff",
        }
    }
}

impl std::str::FromStr for CpaStrategy {
    type Err = anyhow::Error;

    /// Strict parse: unknown names are an error listing the valid values
    /// (no silent fallback).
    fn from_str(s: &str) -> Result<CpaStrategy, anyhow::Error> {
        match s {
            "area" => Ok(CpaStrategy::AreaDriven),
            "timing" => Ok(CpaStrategy::TimingDriven),
            "tradeoff" | "trade-off" => Ok(CpaStrategy::TradeOff),
            _ => Err(anyhow::anyhow!(
                "unknown strategy '{s}' (valid: area, timing, tradeoff)"
            )),
        }
    }
}

/// §4.1 region boundaries detected from the CT arrival profile,
/// *cost-aware*: region 1 (RCA) extends only while a ripple chain over the
/// early-arriving LSBs still finishes before the flat region's data even
/// shows up (so the serial chain is free); region 3 (carry-increment)
/// extends down from the MSB while its serial block chain hides under the
/// flat arrival the same way. `dr` is the per-bit ripple-node delay (ns).
pub fn detect_regions_costed(profile: &[f64], dr: f64) -> (usize, usize) {
    let n = profile.len();
    if n == 0 {
        return (0, 0);
    }
    let t_flat = profile.iter().copied().fold(0.0f64, f64::max);
    if t_flat <= 0.0 {
        return (0, n);
    }
    // Region 1: rca_finish[j] = max(profile[j], rca_finish[j-1]) + dr.
    let mut r1 = 0usize;
    let mut finish = 0.0f64;
    for (j, &t) in profile.iter().enumerate() {
        finish = finish.max(t) + dr;
        if finish <= t_flat + 1e-12 {
            r1 = j + 1;
        } else {
            break;
        }
    }
    // Region 3: serial chain from the MSB downward hides under t_flat.
    let mut r2 = n;
    let mut chain = 0.0f64;
    for j in (0..n).rev() {
        chain = chain.max(profile[j]) + dr;
        if chain <= t_flat + 1e-12 && j > r1 {
            r2 = j;
        } else {
            break;
        }
    }
    (r1.min(n), r2.clamp(r1.min(n), n))
}

/// Convenience wrapper using the default-library ripple cost.
pub fn detect_regions(profile: &[f64]) -> (usize, usize) {
    let model = FdcModel::default_prior();
    detect_regions_costed(profile, model.k[3])
}

/// Build the §4.1 initial structure for a profile and run Algorithm 2
/// against the strategy's target. Returns the optimized graph and report.
pub fn synthesize_for_profile(
    profile: &[f64],
    strategy: CpaStrategy,
    model: &FdcModel,
) -> (PrefixGraph, OptReport) {
    let n = profile.len();
    let dr = model.k[3];
    let (r1, r2) = detect_regions_costed(profile, dr);
    let ci_block = (n / 4).clamp(2, 8);
    let max_arr = profile.iter().copied().fold(0.0f64, f64::max);
    // The flat region's data cannot finish before max_arr + the minimal
    // prefix delay over its span; targets are offsets above that floor.
    let floor = {
        let span2 = (r2 - r1).max(1) as f64;
        let min_depth_est = span2.log2().ceil().max(1.0) + 1.0;
        max_arr + model.b + model.k[2] * min_depth_est
    };
    let target = match strategy {
        CpaStrategy::TimingDriven => floor,
        CpaStrategy::TradeOff => floor * 1.1,
        CpaStrategy::AreaDriven => floor * 1.25,
    };

    // Candidate initial structures: the §4.1 region-segmented hybrid plus
    // the regular families, each refined by Algorithm 2 under the
    // strategy's target. The paper prescribes "area-efficient initial
    // structures, then timing-driven transformation"; a portfolio of
    // initials generalizes the selection step and guarantees the chosen
    // CPA is never worse than any single regular structure under the
    // arrival-aware FDC estimate.
    let mut candidates: Vec<PrefixGraph> = vec![
        hybrid_regions(n, r1, r2, ci_block),
        graph::sklansky(n),
        graph::han_carlson(n),
        graph::brent_kung(n),
        graph::carry_increment(n, ci_block),
    ];
    if matches!(strategy, CpaStrategy::TimingDriven | CpaStrategy::TradeOff) {
        candidates.push(graph::kogge_stone(n));
    }

    // Score each refined candidate with the STA engine on a standalone
    // adder carrying the CT's arrival profile — the same metric the final
    // design is judged by. Timing work (the candidates' incremental
    // optimize loops plus one STA pass each) is accumulated so the caller
    // can surface it in compile results.
    let sta = crate::sta::Sta { activity_rounds: 0, ..Default::default() };
    let mut timing = crate::sta::TimingStats::default();
    let mut scored: Vec<(f64, usize, PrefixGraph, OptReport)> = candidates
        .into_iter()
        .map(|mut g| {
            let rep = optimize(&mut g, profile, target, model, 40 * n);
            let (nl, _) = standalone_adder(&g, Some(profile));
            let delay = sta.analyze(&nl).critical_delay_ns;
            timing.merge(&rep.timing);
            timing.merge(&crate::sta::TimingStats::full_pass(nl.len()));
            (delay, g.size(), g, rep)
        })
        .collect();
    let best_delay =
        scored.iter().map(|(d, _, _, _)| *d).fold(f64::INFINITY, f64::min);
    // Delay slack allowed when trading for area.
    let slack = match strategy {
        CpaStrategy::TimingDriven => 1.0005,
        CpaStrategy::TradeOff => 1.08,
        CpaStrategy::AreaDriven => 1.4,
    };
    scored.sort_by(|a, b| {
        let a_ok = a.0 <= best_delay * slack;
        let b_ok = b.0 <= best_delay * slack;
        b_ok.cmp(&a_ok)
            .then(if a_ok && b_ok {
                a.1.cmp(&b.1) // both within slack: smaller wins
            } else {
                a.0.partial_cmp(&b.0).unwrap() // else faster wins
            })
    });
    let (est, _, mut g, mut rep) = scored.into_iter().next().unwrap();
    if matches!(strategy, CpaStrategy::TimingDriven) {
        // Squeeze pass: push below the best structure's estimate while
        // improvements exist (the paper's "iterative timing-driven
        // optimization until no further optimization is possible").
        let mut rep2 = optimize(&mut g, profile, est * 0.93, model, 20 * n);
        timing.merge(&rep2.timing);
        rep2.timing = timing;
        return (g, rep2);
    }
    rep.timing = timing;
    (g, rep)
}

/// Generate the Figure-8 dataset: `count` random legal prefix graphs over
/// widths in `widths`, produced by random GRAPHOPT walks from mixed seeds
/// (ripple/Sklansky/Brent-Kung starting points) — an open-source stand-in
/// for the 1100-adder dataset of [26].
pub fn random_adder_dataset(widths: &[usize], count: usize, seed: u64) -> Vec<PrefixGraph> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut out = Vec::with_capacity(count);
    while out.len() < count {
        let n = widths[rng.index(widths.len())];
        let mut g = match rng.index(3) {
            0 => graph::ripple(n),
            1 => graph::sklansky(n),
            _ => graph::brent_kung(n),
        };
        let steps = rng.index(3 * n) + 1;
        for _ in 0..steps {
            // random internal node with internal ntf
            let candidates: Vec<usize> = (g.n..g.nodes.len())
                .filter(|&i| {
                    let nd = g.node(i);
                    !nd.is_leaf() && !g.node(nd.ntf).is_leaf()
                })
                .collect();
            if candidates.is_empty() {
                break;
            }
            let p = candidates[rng.index(candidates.len())];
            optimize::graphopt(&mut g, p);
        }
        g.prune();
        // Release-mode invariant: the dataset feeds the FDC fit — one
        // malformed sample would poison the model silently.
        assert!(g.validate().is_ok(), "random adder sample failed validation");
        out.push(g);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{lane_value, pack_lanes, Simulator};

    #[test]
    fn region_detection_on_trapezoid() {
        // Typical CT profile: rise, flat top, fall.
        let profile: Vec<f64> = (0..16)
            .map(|i| match i {
                0..=4 => 0.1 + 0.08 * i as f64,
                5..=10 => 0.5,
                _ => 0.5 - 0.09 * (i - 10) as f64,
            })
            .collect();
        let (r1, r2) = detect_regions(&profile);
        assert!((3..=5).contains(&r1), "r1 {r1}");
        assert!((11..=13).contains(&r2), "r2 {r2}");
    }

    #[test]
    fn region_detection_degenerate() {
        assert_eq!(detect_regions(&[]), (0, 0));
        let (r1, r2) = detect_regions(&[0.0, 0.0, 0.0]);
        assert_eq!((r1, r2), (0, 3));
    }

    #[test]
    fn synthesize_for_profile_all_strategies_functional() {
        let profile: Vec<f64> = (0..12)
            .map(|i| 0.2 + 0.1 * (6.0 - (i as f64 - 6.0).abs()) / 6.0)
            .collect();
        let model = FdcModel::default_prior();
        for strat in [CpaStrategy::AreaDriven, CpaStrategy::TradeOff, CpaStrategy::TimingDriven] {
            let (g, _rep) = synthesize_for_profile(&profile, strat, &model);
            g.validate().unwrap();
            // functional check
            let (nl, sum) = standalone_adder(&g, Some(&profile));
            let mut rng = Rng::seed_from_u64(11);
            let mut sim = Simulator::new();
            let mask = (1u64 << 12) - 1;
            let pairs: Vec<(u64, u64)> =
                (0..64).map(|_| (rng.next_u64() & mask, rng.next_u64() & mask)).collect();
            let assigns: Vec<Vec<bool>> = pairs
                .iter()
                .map(|(x, y)| {
                    (0..12).flat_map(|k| [x >> k & 1 != 0, y >> k & 1 != 0]).collect()
                })
                .collect();
            let words = pack_lanes(&assigns);
            let vals = sim.run(&nl, &words).to_vec();
            for (lane, (x, y)) in pairs.iter().enumerate() {
                assert_eq!(lane_value(&vals, &sum, lane as u32), u128::from(x + y));
            }
        }
    }

    #[test]
    fn timing_strategy_is_not_slower_than_area_strategy() {
        // Compare measured (STA) delays of the two strategies' adders under
        // the same non-uniform arrival profile.
        let profile: Vec<f64> = (0..16)
            .map(|i| 0.2 + 0.15 * (8.0 - (i as f64 - 8.0).abs()) / 8.0)
            .collect();
        let model = FdcModel::default_prior();
        let sta = crate::sta::Sta { activity_rounds: 0, ..Default::default() };
        let measure = |s: CpaStrategy| {
            let (g, _) = synthesize_for_profile(&profile, s, &model);
            let (nl, _) = standalone_adder(&g, Some(&profile));
            sta.analyze(&nl).critical_delay_ns
        };
        let t = measure(CpaStrategy::TimingDriven);
        let a = measure(CpaStrategy::AreaDriven);
        assert!(t <= a + 1e-9, "timing {t} vs area {a}");
    }

    #[test]
    fn dataset_generator_is_diverse_and_valid() {
        let ds = random_adder_dataset(&[8, 12, 16], 40, 99);
        assert_eq!(ds.len(), 40);
        let mut depths = std::collections::BTreeSet::new();
        for g in &ds {
            g.validate().unwrap();
            depths.insert(g.depth());
        }
        assert!(depths.len() >= 3, "dataset lacks structural diversity");
    }
}
