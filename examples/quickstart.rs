//! Quickstart for the unified API: compile an 8×8 UFO-MAC multiplier
//! through the `SynthEngine`, verify it exhaustively, inspect the
//! compressor-tree arrival profile (the Figure-1 trapezoid), compare
//! against the commercial-IP proxy, and watch the content-addressed cache
//! collapse a repeated request onto the same artifact.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;
use ufo_mac::api::{DesignRequest, EngineConfig, SynthEngine};
use ufo_mac::baselines::Method;
use ufo_mac::multiplier::{MultiplierSpec, OperandFormat, Strategy};

fn main() -> ufo_mac::Result<()> {
    // One engine owns the cell library, timing models, STA and the cache.
    let engine = Arc::new(SynthEngine::new(EngineConfig::default()));

    // 1. One request: UFO-MAC 8×8 multiplier with the trade-off strategy.
    let req = DesignRequest::method(Method::UfoMac, 8, Strategy::TradeOff, false);
    let art = engine.compile(&req)?;
    let design = art.design().expect("multiplier design");
    println!("UFO-MAC 8×8 multiplier   [fingerprint {}]", art.fingerprint);
    println!(
        "  {} gates, {:.1} µm², {:.4} ns, {:.4} mW @1GHz",
        art.sta.num_gates, art.sta.area_um2, art.sta.critical_delay_ns, art.sta.power_mw
    );

    // 2. Exhaustive equivalence (all 65 536 operand pairs).
    let equiv = ufo_mac::equiv::check_multiplier(design)?;
    assert!(equiv.passed && equiv.exhaustive);
    println!("  equivalence: PASS ({} vectors, exhaustive)", equiv.vectors);

    // 3. The non-uniform CT output profile that drives CPA optimization.
    println!("\nCT arrival profile (ns):");
    let max = design.profile.iter().copied().fold(0.0f64, f64::max);
    for (j, t) in design.profile.iter().enumerate() {
        println!("  col {j:>2}  {t:.4}  {}", "#".repeat((t / max * 40.0) as usize));
    }
    let (r1, r2) = ufo_mac::cpa::detect_regions(&design.profile);
    println!("  → region 1 (RCA): [0,{r1})  region 2 (Sklansky): [{r1},{r2})  region 3 (carry-inc): [{r2},{})",
        design.profile.len());

    // 4. Head-to-head with the commercial proxy at the same strategy.
    let com = engine.compile(&DesignRequest::method(Method::Commercial, 8, Strategy::TradeOff, false))?;
    println!(
        "\nCommercial-IP proxy 8×8: {:.1} µm², {:.4} ns",
        com.sta.area_um2, com.sta.critical_delay_ns
    );
    println!(
        "UFO-MAC delta: area {:+.1}%, delay {:+.1}%",
        (art.sta.area_um2 / com.sta.area_um2 - 1.0) * 100.0,
        (art.sta.critical_delay_ns / com.sta.critical_delay_ns - 1.0) * 100.0
    );

    // 5. Identical request ⇒ same artifact, served from cache.
    let again = engine.compile(&req)?;
    assert!(Arc::ptr_eq(&art, &again), "repeat compile must be the cached Arc");
    let stats = engine.cache_stats();
    println!(
        "\ncache: {} entries, {} hits / {} misses ({:.0}% hit rate)",
        stats.entries,
        stats.hits,
        stats.misses,
        stats.hit_rate() * 100.0
    );

    // 6. Operand formats: the same pipeline synthesizes signed and
    // rectangular designs. A signed 4×6 fused MAC (a DSP-style datapath):
    // Baugh–Wooley PPG rows, an 11-bit two's-complement result, verified
    // exhaustively against the signed reference model.
    let smac_req = DesignRequest::from_spec(
        &MultiplierSpec::new_fmt(OperandFormat::signed_rect(4, 6)).fused_mac(true),
    );
    let smac = engine.compile(&smac_req)?;
    let sdesign = smac.design().expect("signed MAC design");
    let sequiv = ufo_mac::equiv::check_multiplier(sdesign)?;
    assert!(sequiv.passed && sequiv.exhaustive);
    println!(
        "\nsigned 4×6 fused MAC: {} gates, {:.4} ns, {}-bit product, equivalence PASS ({} vectors)",
        smac.sta.num_gates,
        smac.sta.critical_delay_ns,
        sdesign.product.len(),
        sequiv.vectors
    );

    // 7. Requests are plain JSON — the service-style entry point. Note the
    // `format` key appears only for non-default formats, so pre-format
    // request fingerprints (and their cache entries) are unchanged.
    println!("\nrequest json: {}", req.to_json_string());
    println!("signed request json: {}", smac_req.to_json_string());
    Ok(())
}
