//! # UFO-MAC — Unified Framework for Optimization of Multipliers and MACs
//!
//! A full reproduction of *"UFO-MAC: A Unified Framework for Optimization of
//! High-Performance Multipliers and Multiply-Accumulators"* (Zuo et al.,
//! ICCAD 2024), built as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the arithmetic-synthesis framework: partial
//!   product generation, optimal compressor trees with ILP stage assignment
//!   and interconnect-order optimization, non-uniform-arrival CPA synthesis
//!   with the FDC timing model, fused MACs, baselines (GOMIL, RL-MUL,
//!   commercial-IP proxy), a from-scratch MILP solver, a gate-level netlist
//!   IR with logical-effort STA, equivalence checking, functional modules
//!   (FIR filter, systolic array) and a design-space-exploration coordinator.
//! - **Layer 2 (python/compile/model.py)** — JAX evaluation workloads
//!   (batched netlist functional verification, systolic-array GEMM).
//! - **Layer 1 (python/compile/kernels/)** — Pallas kernels for those
//!   workloads, AOT-lowered to HLO text and executed from Rust via PJRT
//!   (`runtime` module). Python never runs on the request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use ufo_mac::multiplier::{MultiplierSpec, Strategy};
//! use ufo_mac::sta::Sta;
//!
//! let spec = MultiplierSpec::new(8).strategy(Strategy::TradeOff);
//! let design = spec.build().unwrap();
//! let report = Sta::default().analyze(&design.netlist);
//! assert!(report.critical_delay_ns > 0.0);
//! assert!(ufo_mac::equiv::check_multiplier(&design).unwrap().passed);
//! ```

pub mod baselines;
pub mod coordinator;
pub mod cpa;
pub mod ct;
pub mod equiv;
pub mod ilp;
pub mod ir;
pub mod modules;
pub mod multiplier;
pub mod ppg;
pub mod runtime;
pub mod sim;
pub mod sta;
pub mod synth;

pub mod bench;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
