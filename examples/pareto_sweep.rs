//! Design-space exploration through the unified API: reproduce the shape
//! of Figures 10-12 in one run — batch-compile the methods × strategies ×
//! widths grid on the `SynthEngine` thread pool, print Pareto frontiers
//! and the paper's headline deltas (UFO-MAC vs the commercial proxy),
//! persist a JSON report, then re-run the sweep to show the
//! content-addressed cache serving every design without re-synthesis.
//!
//! Run: `cargo run --release --example pareto_sweep -- --widths 8,16 [--mac] [--signed]`
//!
//! `--signed` sweeps the two's-complement operand format through every
//! method (the format axis the paper's DSP-style workloads need).

use std::sync::Arc;
use ufo_mac::api::{EngineConfig, SynthEngine};
use ufo_mac::baselines::Method;
use ufo_mac::coordinator::{self, SweepConfig};
use ufo_mac::util::{Args, Table};

fn main() -> ufo_mac::Result<()> {
    let args = Args::from_env();
    let widths: Vec<usize> = args
        .get("widths")
        .unwrap_or("8,16")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let mac = args.has("mac");
    let signedness = if args.has("signed") {
        vec![ufo_mac::ppg::Signedness::Signed]
    } else {
        vec![ufo_mac::ppg::Signedness::Unsigned]
    };

    let cfg = SweepConfig { widths: widths.clone(), mac, signedness, ..Default::default() };
    let engine = Arc::new(SynthEngine::new(EngineConfig {
        verify_vectors: cfg.verify_vectors,
        workers: cfg.workers,
        ..EngineConfig::default()
    }));
    let points = coordinator::run_sweep_with(&engine, &cfg);

    for &n in &widths {
        let subset: Vec<_> = points.iter().filter(|p| p.n == n).cloned().collect();
        let mut table = Table::new(&["method", "strategy", "delay(ns)", "area(µm²)", "pareto"]);
        let front = coordinator::pareto_front(&subset);
        for (i, p) in subset.iter().enumerate() {
            table.row(vec![
                p.method.name().into(),
                format!("{:?}", p.strategy),
                format!("{:.4}", p.delay_ns),
                format!("{:.1}", p.area_um2),
                if front.contains(&i) { "◆".into() } else { "".into() },
            ]);
        }
        println!(
            "\n{}-bit {}:\n{}",
            n,
            if mac { "MACs (fused)" } else { "multipliers" },
            table.render()
        );

        // Headline deltas: best UFO point vs best commercial point.
        let best = |m: Method, key: fn(&coordinator::DesignPoint) -> f64| {
            subset
                .iter()
                .filter(|p| p.method == m)
                .map(key)
                .fold(f64::INFINITY, f64::min)
        };
        let darea =
            (1.0 - best(Method::UfoMac, |p| p.area_um2) / best(Method::Commercial, |p| p.area_um2))
                * 100.0;
        let ddelay =
            (1.0 - best(Method::UfoMac, |p| p.delay_ns) / best(Method::Commercial, |p| p.delay_ns))
                * 100.0;
        println!("UFO-MAC vs commercial ({n}-bit): area −{darea:.1}%, delay −{ddelay:.1}%");

        // Pareto-dominance count (the paper's qualitative claim).
        let mut dominated = 0;
        for p in subset.iter().filter(|p| p.method != Method::UfoMac) {
            if subset
                .iter()
                .filter(|q| q.method == Method::UfoMac)
                .any(|q| coordinator::dominates(q, p))
            {
                dominated += 1;
            }
        }
        println!(
            "UFO-MAC dominates {dominated}/{} baseline points",
            subset.iter().filter(|p| p.method != Method::UfoMac).count()
        );
    }

    coordinator::save_report("target/reports", "pareto_sweep", &coordinator::points_json(&points))?;
    println!("\nreport: target/reports/pareto_sweep.json");

    // Re-run the identical sweep on the same engine: every design is a
    // cache hit, no re-synthesis.
    let cold = engine.cache_stats();
    let again = coordinator::run_sweep_with(&engine, &cfg);
    let warm = engine.cache_stats();
    assert_eq!(points.len(), again.len());
    println!(
        "repeat sweep: {} designs, {} new cache entries, {} hits (cache {} entries total)",
        again.len(),
        warm.entries - cold.entries,
        warm.hits - cold.hits,
        warm.entries
    );
    Ok(())
}
