//! Hot-path microbenchmarks — the profiling substrate for the §Perf pass
//! (EXPERIMENTS.md): STA sweeps dominate the Pareto experiments, the
//! bit-parallel simulator dominates equivalence checks + power estimation,
//! bottleneck assignment dominates CT construction, and full design
//! builds dominate the coordinator's jobs.
//!
//! Two comparative groups anchor the perf trajectory:
//!
//! - **full vs incremental STA** on the repeated-optimization-move path
//!   (one input arrival shifts per move, as CT/CPA optimization does);
//! - **serial vs parallel branch & bound** on the §3.3 stage-assignment
//!   ILP.
//!
//! Results land in `BENCH_hotpath.json` via `Bench::finish`.

use ufo_mac::api::{DesignRequest, EngineConfig, SynthEngine};
use ufo_mac::bench::Bench;
use ufo_mac::cpa::{self, PrefixStructure};
use ufo_mac::ilp::assignment::bottleneck_assignment;
use ufo_mac::ilp::SolveOptions;
use ufo_mac::multiplier::MultiplierSpec;
use ufo_mac::sim::Simulator;
use ufo_mac::sta::{IncrementalSta, Sta};
use ufo_mac::util::Rng;

fn main() {
    let bench = Bench::new("hotpath");

    // Pre-built 16-bit design shared by the passive benches.
    let design = MultiplierSpec::new(16).build().unwrap();
    let nl = &design.netlist;
    println!("16-bit UFO multiplier: {} nodes / {} gates", nl.len(), nl.num_gates());

    // STA arrival sweep (the Pareto-sweep inner loop).
    let sta = Sta { activity_rounds: 0, ..Sta::default() };
    bench.bench("sta_arrivals_16bit", || sta.arrivals_ns(nl));
    bench.bench("sta_analyze_16bit_no_power_sim", || sta.analyze(nl));

    // Bit-parallel simulation (equivalence + toggle power inner loop).
    let mut sim = Simulator::new();
    let mut rng = Rng::seed_from_u64(1);
    let words: Vec<u64> = (0..nl.num_inputs()).map(|_| rng.next_u64()).collect();
    bench.bench("sim_run_16bit_64lanes", || {
        sim.run(nl, &words);
        sim.word(design.product[0])
    });

    // Toggle-activity power extraction (16 rounds × 64 lanes).
    bench.bench("toggle_activity_16bit_16rounds", || {
        ufo_mac::sim::toggle_activity(nl, 16, 7)
    });

    // Bottleneck assignment at CT-slice scale (m = 16 and 32).
    for m in [16usize, 32] {
        let mut r = Rng::seed_from_u64(m as u64);
        let cost: Vec<Vec<f64>> =
            (0..m).map(|_| (0..m).map(|_| r.f64()).collect()).collect();
        bench.bench(&format!("bottleneck_assignment_{m}x{m}"), || {
            bottleneck_assignment(&cost)
        });
    }

    // Full design construction (the coordinator job body).
    bench.bench("build_ufo_multiplier_8bit", || MultiplierSpec::new(8).build().unwrap());
    bench.bench("build_ufo_multiplier_16bit", || MultiplierSpec::new(16).build().unwrap());

    // Signed 16×16 fused MAC through the uncached inner path: the
    // operand-format subsystem's hot build (Baugh–Wooley rows + fused
    // accumulator + profile-driven CPA), measured without the design
    // cache so every sample pays the real synthesis cost.
    let lib = ufo_mac::ir::CellLib::nangate45();
    let tm = ufo_mac::synth::CompressorTiming::from_lib(&lib);
    let smac_spec =
        MultiplierSpec::new_fmt(ufo_mac::multiplier::OperandFormat::signed(16)).fused_mac(true);
    bench.bench("build_signed_fused_mac_16x16_uncached", || {
        smac_spec.build_with(&lib, &tm).unwrap().netlist.len()
    });

    // Stage assignment at 32/64 bits (greedy hot path).
    for n in [32usize, 64] {
        let pp: Vec<usize> =
            (0..2 * n - 1).map(|j| n.min(j + 1).min(2 * n - 1 - j)).collect();
        let counts = ufo_mac::ct::CtCounts::from_populations(&pp);
        bench.bench(&format!("assign_greedy_{n}bit"), || {
            ufo_mac::ct::assign_greedy(&counts)
        });
    }

    // Netlist encoding for the PJRT bridge.
    bench.bench("encode_netlist_16bit", || {
        ufo_mac::runtime::encode_netlist(nl).unwrap()
    });

    // Equivalence sampling batch (64 vectors incl. packing).
    let d8 = MultiplierSpec::new(8).build().unwrap();
    bench.bench("equiv_sampled_1k_8bit", || {
        ufo_mac::equiv::check_multiplier_with(&d8, 1024).unwrap()
    });

    // Unified-engine compile path: cold (fresh engine per call — pays the
    // full library/timing-model construction plus synthesis, the pre-API
    // per-call behaviour) vs cached (content-addressed hit on a warm
    // engine — the DSE-sweep steady state).
    let req = DesignRequest::multiplier(16);
    bench.bench("engine_compile_16bit_cold", || {
        let eng = SynthEngine::new(EngineConfig::default());
        eng.compile(&req).unwrap().sta.num_gates
    });
    let warm = SynthEngine::new(EngineConfig::default());
    warm.compile(&req).unwrap();
    bench.bench("engine_compile_16bit_cached", || {
        warm.compile(&req).unwrap().sta.num_gates
    });
    let s = warm.cache_stats();
    bench.metric("engine_cache_hit_rate_16bit", s.hit_rate(), "fraction");
    let art = warm.compile(&req).unwrap();
    bench.metric("engine_timing_retime_fraction_16bit", art.timing.retime_fraction(), "fraction");

    // Persistent-cache tiers: cold compile (above) vs warm in-memory hit
    // (above) vs warm *disk* hit — the restarted-service steady state.
    // Clearing the memory tier before each sample forces every compile to
    // deserialize + checksum-verify the on-disk entry.
    let disk_dir = std::env::temp_dir().join(format!("ufo_hotpath_disk_{}", std::process::id()));
    std::fs::remove_dir_all(&disk_dir).ok();
    let disk = SynthEngine::new(EngineConfig {
        cache_dir: Some(disk_dir.clone()),
        ..EngineConfig::default()
    });
    disk.compile(&req).unwrap(); // prime both tiers
    bench.bench("engine_compile_16bit_warm_disk", || {
        disk.clear_cache(); // memory tier only; the disk entry survives
        disk.compile(&req).unwrap().sta.num_gates
    });
    let s = disk.cache_stats();
    bench.metric("engine_disk_hits_16bit", s.disk_hits as f64, "count");
    std::fs::remove_dir_all(&disk_dir).ok();

    // Full vs incremental STA on the repeated-optimization-move path: each
    // "move" shifts one middle-column input arrival of a 32-bit adder
    // carrying a trapezoidal CT profile (what a CT interconnect swap or a
    // revised column profile does to the CPA), then re-times. The full
    // path re-runs whole-netlist STA; the incremental path re-times only
    // the touched fan-out cone.
    let n_bits = 32usize;
    let profile: Vec<f64> = (0..n_bits)
        .map(|i| 0.2 + 0.15 * (16.0 - (i as f64 - 16.0).abs()) / 16.0)
        .collect();
    let g = cpa::build(PrefixStructure::Sklansky, n_bits);
    let (mut nl_full, _) = cpa::standalone_adder(&g, Some(&profile));
    let (mut nl_inc, _) = cpa::standalone_adder(&g, Some(&profile));
    let sta_fast = Sta { activity_rounds: 0, ..Sta::default() };
    let inputs_full = nl_full.inputs();
    let inputs_inc = nl_inc.inputs();
    let mut k = 0usize;
    let full_stats = bench.bench("sta_move_full_retime_32bit_adder", || {
        let id = inputs_full[16 + (k % 24)];
        nl_full.set_input_arrival(id, 0.2 + 0.01 * ((k % 7) as f64));
        k += 1;
        sta_fast.arrivals_ns(&nl_full).iter().copied().fold(0.0f64, f64::max)
    });
    let mut inc = IncrementalSta::new(&sta_fast, &nl_inc);
    let mut k2 = 0usize;
    let inc_stats = bench.bench("sta_move_incremental_retime_32bit_adder", || {
        let id = inputs_inc[16 + (k2 % 24)];
        nl_inc.set_input_arrival(id, 0.2 + 0.01 * ((k2 % 7) as f64));
        k2 += 1;
        inc.touch(id);
        inc.propagate(&nl_inc);
        inc.arrivals().iter().copied().fold(0.0f64, f64::max)
    });
    bench.metric(
        "sta_incremental_speedup_move_path",
        full_stats.mean_ns / inc_stats.mean_ns.max(1.0),
        "x",
    );
    bench.metric("sta_incremental_retime_fraction", inc.stats().retime_fraction(), "fraction");

    // Serial vs parallel branch & bound on the §3.3 stage-assignment ILP.
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2);
    let n_ilp = 6usize;
    let pp: Vec<usize> =
        (0..2 * n_ilp - 1).map(|j| n_ilp.min(j + 1).min(2 * n_ilp - 1 - j)).collect();
    let counts = ufo_mac::ct::CtCounts::from_populations(&pp);
    let ilp_opts = |threads: usize| SolveOptions {
        time_limit: std::time::Duration::from_secs(15),
        threads,
        ..Default::default()
    };
    let ser = bench.bench(&format!("stage_ilp_{n_ilp}bit_serial"), || {
        ufo_mac::ct::assign_ilp(&counts, &ilp_opts(1)).0.stages()
    });
    let par = bench.bench(&format!("stage_ilp_{n_ilp}bit_parallel_{threads}t"), || {
        ufo_mac::ct::assign_ilp(&counts, &ilp_opts(threads)).0.stages()
    });
    bench.metric("ilp_parallel_speedup", ser.mean_ns / par.mean_ns.max(1.0), "x");

    bench.finish().expect("write BENCH_hotpath.json");
}
