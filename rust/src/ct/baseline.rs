//! Baseline compressor-tree structures: Wallace and Dadda.
//!
//! These are the textbook reduction schedules the paper's comparisons build
//! on (the commercial-IP proxy uses Dadda; RL-MUL's search starts from a
//! Wallace-like column schedule). Both are expressed as [`StagePlan`]s so
//! they share the interconnect builder with the UFO-MAC tree.

use super::stage::StagePlan;

/// Wallace's row-grouping reduction: at each stage, rows are grouped in
/// threes; within a group a column holding 3 bits gets a full adder, 2 bits
/// a half adder, 1 bit passes — until at most two rows remain. Expressed
/// column-wise by treating the per-column population as rows dense from the
/// bottom (exact for multiplier-style matrices).
pub fn wallace_plan(initial: &[usize]) -> StagePlan {
    let w = initial.len() + 4;
    let mut avail = initial.to_vec();
    avail.resize(w, 0);
    let mut plan = StagePlan { f: vec![], h: vec![] };
    for _ in 0..64 {
        let maxh = avail.iter().copied().max().unwrap_or(0);
        if maxh <= 2 {
            break;
        }
        let groups = maxh / 3; // full groups of 3 rows; remainder passes
        let mut fi = vec![0usize; w];
        let mut hi = vec![0usize; w];
        let mut next = avail.clone();
        for j in 0..w {
            let mut f = 0usize;
            let mut h = 0usize;
            for k in 0..groups {
                let cnt = avail[j].saturating_sub(3 * k).min(3);
                match cnt {
                    3 => f += 1,
                    2 => h += 1,
                    _ => {}
                }
            }
            fi[j] = f;
            hi[j] = h;
            next[j] -= 2 * f + h;
            if j + 1 < w {
                next[j + 1] += f + h;
            }
        }
        plan.f.push(fi);
        plan.h.push(hi);
        avail = next;
    }
    trim_width(&mut plan, initial);
    plan
}

/// Dadda's just-in-time schedule: reduce only as much as needed to hit the
/// next height in the sequence 2, 3, 4, 6, 9, 13, 19, 28, 42, …
pub fn dadda_plan(initial: &[usize]) -> StagePlan {
    let max_h = initial.iter().copied().max().unwrap_or(0);
    // Height targets strictly below the current max, descending to 2.
    let mut seq = vec![2usize];
    while *seq.last().unwrap() < max_h {
        let d = *seq.last().unwrap();
        seq.push(d * 3 / 2);
    }
    seq.pop(); // last element ≥ max_h is not a target
    seq.reverse(); // descending targets

    let w = initial.len() + 4;
    let mut avail = initial.to_vec();
    avail.resize(w, 0);
    let mut plan = StagePlan { f: vec![], h: vec![] };
    for stage in 0..64 {
        if avail.iter().all(|&m| m <= 2) {
            break;
        }
        let target = seq.get(stage).copied().unwrap_or(2);
        let mut fi = vec![0usize; w];
        let mut hi = vec![0usize; w];
        let mut next = vec![0usize; w];
        let mut inflow = 0usize; // carries generated into column j this stage
        for j in 0..w {
            let m = avail[j] + inflow;
            let (mut f, mut h) = if m <= target {
                (0, 0)
            } else {
                let r = m - target;
                // each FA removes 2 from this column, each HA removes 1
                (r / 2, r % 2)
            };
            // Compressor inputs can only come from signals present at this
            // stage (carries produced this stage arrive at the next one);
            // legalize and let a later stage absorb any shortfall.
            if 3 * f + 2 * h > avail[j] {
                f = f.min(avail[j] / 3);
                h = h.min((avail[j] - 3 * f) / 2).min(1);
            }
            fi[j] = f;
            hi[j] = h;
            next[j] = m - 2 * f - h;
            inflow = f + h;
        }
        plan.f.push(fi);
        plan.h.push(hi);
        avail = next;
    }
    debug_assert!(avail.iter().all(|&m| m <= 2));
    trim_width(&mut plan, initial);
    plan
}

/// Shrink the plan's width to the columns that are actually used, keeping
/// at least the width implied by the initial populations + final carries.
fn trim_width(plan: &mut StagePlan, initial: &[usize]) {
    let w = plan.width();
    let mut used = initial.len();
    for j in (0..w).rev() {
        if (0..plan.stages()).any(|i| plan.f[i][j] + plan.h[i][j] > 0) {
            used = used.max(j + 2); // compressors in j carry into j+1
            break;
        }
    }
    let used = used.min(w);
    for i in 0..plan.stages() {
        plan.f[i].truncate(used);
        plan.h[i].truncate(used);
    }
}

/// Per-column totals of a plan (for area metrics / validation).
pub fn plan_totals(plan: &StagePlan) -> (Vec<usize>, Vec<usize>) {
    let w = plan.width();
    let mut f = vec![0usize; w];
    let mut h = vec![0usize; w];
    for i in 0..plan.stages() {
        for j in 0..w {
            f[j] += plan.f[i][j];
            h[j] += plan.h[i][j];
        }
    }
    (f, h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ct::counts::CtCounts;

    fn mult_pp(n: usize) -> Vec<usize> {
        (0..2 * n - 1).map(|j| n.min(j + 1).min(2 * n - 1 - j)).collect()
    }

    /// Replay a plan to check populations stay legal and end ≤ 2.
    fn replay(plan: &StagePlan, initial: &[usize]) {
        let w = plan.width();
        let mut avail = initial.to_vec();
        avail.resize(w, 0);
        for i in 0..plan.stages() {
            let mut next = avail.clone();
            for j in 0..w {
                let (f, h) = (plan.f[i][j], plan.h[i][j]);
                assert!(3 * f + 2 * h <= avail[j], "stage {i} col {j}");
                next[j] -= 2 * f + h;
                if j + 1 < w {
                    next[j + 1] += f + h;
                }
            }
            avail = next;
        }
        assert!(avail.iter().all(|&m| m <= 2), "final populations {avail:?}");
    }

    #[test]
    fn wallace_and_dadda_are_legal() {
        for n in [3, 4, 8, 16, 32] {
            replay(&wallace_plan(&mult_pp(n)), &mult_pp(n));
            replay(&dadda_plan(&mult_pp(n)), &mult_pp(n));
        }
    }

    #[test]
    fn dadda_uses_fewer_compressors_than_wallace() {
        let pp = mult_pp(16);
        let (wf, wh) = plan_totals(&wallace_plan(&pp));
        let (df, dh) = plan_totals(&dadda_plan(&pp));
        let warea: usize = 3 * wf.iter().sum::<usize>() + 2 * wh.iter().sum::<usize>();
        let darea: usize = 3 * df.iter().sum::<usize>() + 2 * dh.iter().sum::<usize>();
        assert!(darea <= warea, "dadda {darea} vs wallace {warea}");
    }

    #[test]
    fn stage_counts_match_theory() {
        for (n, expect) in [(8usize, 4usize), (16, 6), (32, 8)] {
            let wp = wallace_plan(&mult_pp(n));
            let dp = dadda_plan(&mult_pp(n));
            assert_eq!(dp.stages(), expect, "dadda n={n}");
            assert!(wp.stages() <= expect + 1, "wallace n={n}: {}", wp.stages());
        }
    }

    #[test]
    fn ufo_counts_beat_or_match_dadda_area() {
        // Algorithm 1 is area-optimal; Dadda should not use less.
        for n in [8, 16] {
            let pp = mult_pp(n);
            let c = CtCounts::from_populations(&pp);
            let (df, dh) = plan_totals(&dadda_plan(&pp));
            let darea = 3 * df.iter().sum::<usize>() + 2 * dh.iter().sum::<usize>();
            assert!(c.area_metric() <= darea, "n={n}");
        }
    }
}
