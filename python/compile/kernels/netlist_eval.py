"""Layer-1 Pallas kernel: bit-parallel gate-netlist evaluation.

Evaluates an encoded gate-level netlist (the designs emitted by the Rust
generators) on a batch of packed input vectors — 32 test vectors per uint32
lane, ``BATCH`` words deep, so one execution checks ``32 × BATCH`` vectors.
This is the functional-verification hot path the Rust coordinator drives
through PJRT (see ``rust/src/runtime``): Python runs only at build time.

Encoding (must match ``CellKind::opcode`` in ``rust/src/ir/cell.rs``):

========  =======================================
opcode    function
========  =======================================
0..10     BUF INV AND2 OR2 NAND2 NOR2 XOR2 XNOR2
          AOI21 OAI21 MAJ3
11        CONST0
12        CONST1
13        INPUT   (fanin0 = input ordinal)
========  =======================================

Node ``i``'s value lands in slot ``i`` of the evaluation buffer; fanin
indices always reference earlier slots (the Rust IR is topologically
ordered by construction).

TPU mapping note (DESIGN.md §Hardware-Adaptation): the evaluation is a
sequential scan over gates with a (BATCH,)-wide vector update per step —
on real hardware the buffer tiles into VMEM and the scan becomes the
grid's inner dimension; under ``interpret=True`` the same structure runs
on CPU for correctness.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

OP_BUF = 0
OP_INV = 1
OP_AND2 = 2
OP_OR2 = 3
OP_NAND2 = 4
OP_NOR2 = 5
OP_XOR2 = 6
OP_XNOR2 = 7
OP_AOI21 = 8
OP_OAI21 = 9
OP_MAJ3 = 10
OP_CONST0 = 11
OP_CONST1 = 12
OP_INPUT = 13

NUM_OPS = 14

# Artifact size buckets (padded): (max_nodes, max_inputs).
SIZES = {
    "small": (2048, 72),
    "large": (8192, 144),
}
BATCH = 8  # uint32 words per input node => 256 vectors per execution


def _gate_value(op, a, b, c, inp, ones):
    """Value of one gate given operand words (uint32)."""
    zeros = jnp.zeros_like(a)
    branches = [
        a,                                  # BUF
        ~a,                                 # INV
        a & b,                              # AND2
        a | b,                              # OR2
        ~(a & b),                           # NAND2
        ~(a | b),                           # NOR2
        a ^ b,                              # XOR2
        ~(a ^ b),                           # XNOR2
        ~((a & b) | c),                     # AOI21
        ~((a | b) & c),                     # OAI21
        (a & b) | (a & c) | (b & c),        # MAJ3
        zeros,                              # CONST0
        ones,                               # CONST1
        inp,                                # INPUT
    ]
    stacked = jnp.stack(branches)            # [NUM_OPS, BATCH]
    return jnp.take(stacked, op, axis=0)


def _eval_body(ops, f0, f1, f2, words):
    """Shared evaluation loop (used by the kernel and exported for ref)."""
    ops = jnp.asarray(ops)
    f0 = jnp.asarray(f0)
    f1 = jnp.asarray(f1)
    f2 = jnp.asarray(f2)
    words = jnp.asarray(words)
    n = ops.shape[0]
    batch = words.shape[0]
    ones = jnp.full((batch,), 0xFFFFFFFF, dtype=jnp.uint32)

    def step(i, buf):
        op = ops[i]
        a = jnp.take(buf, f0[i], axis=1)
        b = jnp.take(buf, f1[i], axis=1)
        c = jnp.take(buf, f2[i], axis=1)
        inp = jnp.take(words, jnp.minimum(f0[i], words.shape[1] - 1), axis=1)
        val = _gate_value(op, a, b, c, inp, ones)
        return jax.lax.dynamic_update_slice(buf, val[:, None], (0, i))

    buf0 = jnp.zeros((batch, n), dtype=jnp.uint32)
    return jax.lax.fori_loop(0, n, step, buf0)


def _kernel(ops_ref, f0_ref, f1_ref, f2_ref, words_ref, out_ref):
    out_ref[...] = _eval_body(
        ops_ref[...], f0_ref[...], f1_ref[...], f2_ref[...], words_ref[...]
    )


@functools.partial(jax.jit, static_argnames=("size",))
def netlist_eval(ops, f0, f1, f2, words, *, size="small"):
    """Evaluate a padded netlist encoding on packed vectors.

    Args:
      ops, f0, f1, f2: int32[max_nodes] padded with OP_CONST0.
      words: uint32[BATCH, max_inputs] packed input vectors.
      size: bucket name from ``SIZES``.

    Returns:
      uint32[BATCH, max_nodes] — the value of every node.
    """
    max_nodes, _ = SIZES[size]
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((words.shape[0], max_nodes), jnp.uint32),
        interpret=True,
    )(ops, f0, f1, f2, words)
