//! Integration tests for the unified API layer: fingerprint stability,
//! JSON round-trips, cache behaviour under repeated sweeps, and
//! batch-vs-serial compile equivalence.

use std::sync::Arc;
use ufo_mac::api::{DesignRequest, EngineConfig, SynthEngine};
use ufo_mac::baselines::Method;
use ufo_mac::coordinator::{self, SweepConfig};
use ufo_mac::multiplier::{MultiplierSpec, Strategy};

// ---------------------------------------------------------------------
// Fingerprints: same request ⇒ same hash; any field change ⇒ different.
// ---------------------------------------------------------------------
#[test]
fn fingerprint_stability_across_constructions() {
    let a = DesignRequest::method(Method::UfoMac, 8, Strategy::TradeOff, false);
    let b = DesignRequest::method(Method::UfoMac, 8, Strategy::TradeOff, false);
    assert_eq!(a.fingerprint(), b.fingerprint());
    assert_eq!(a.fingerprint().to_string(), b.fingerprint().to_string());

    // Field-by-field sensitivity over the method axis.
    let mutations = [
        DesignRequest::method(Method::Gomil, 8, Strategy::TradeOff, false),
        DesignRequest::method(Method::UfoMac, 16, Strategy::TradeOff, false),
        DesignRequest::method(Method::UfoMac, 8, Strategy::AreaDriven, false),
        DesignRequest::method(Method::UfoMac, 8, Strategy::TradeOff, true),
    ];
    for m in &mutations {
        assert_ne!(a.fingerprint(), m.fingerprint(), "{m:?}");
    }

    // Module requests: frequency is part of the identity.
    let f1 = DesignRequest::fir(Method::UfoMac, 8, Strategy::TradeOff, 1e9);
    let f2 = DesignRequest::fir(Method::UfoMac, 8, Strategy::TradeOff, 2e9);
    assert_ne!(f1.fingerprint(), f2.fingerprint());
}

// ---------------------------------------------------------------------
// JSON round-trip preserves identity for every request form.
// ---------------------------------------------------------------------
#[test]
fn json_roundtrip_preserves_fingerprint() {
    let reqs = vec![
        DesignRequest::multiplier(12),
        DesignRequest::from_spec(&MultiplierSpec::new(5).fused_mac(true)),
        DesignRequest::method(Method::RlMul, 8, Strategy::TimingDriven, false),
        DesignRequest::fir(Method::Commercial, 8, Strategy::AreaDriven, 660e6),
        DesignRequest::systolic(Method::UfoMac, 8, Strategy::TradeOff, 1e9),
    ];
    for r in reqs {
        let text = r.to_json_string();
        let back = DesignRequest::parse(&text).expect("parse back");
        assert_eq!(r.fingerprint(), back.fingerprint(), "{text}");
    }
}

// ---------------------------------------------------------------------
// Acceptance: a repeated identical request is served from cache, with
// identical Arc and fingerprint, and hits > 0.
// ---------------------------------------------------------------------
#[test]
fn repeated_request_hits_cache_with_identical_arc() {
    let engine = SynthEngine::new(EngineConfig::default());
    let req = DesignRequest::method(Method::UfoMac, 8, Strategy::TradeOff, false);
    let first = engine.compile(&req).unwrap();
    let second = engine.compile(&req).unwrap();
    assert!(Arc::ptr_eq(&first, &second));
    assert_eq!(first.fingerprint, second.fingerprint);
    let stats = engine.cache_stats();
    assert!(stats.hits > 0, "stats {stats:?}");
    assert_eq!(stats.entries, 1);
}

// ---------------------------------------------------------------------
// Repeated sweep: second pass is all cache hits, zero new entries.
// ---------------------------------------------------------------------
#[test]
fn repeated_sweep_is_served_from_cache() {
    let cfg = SweepConfig {
        widths: vec![4],
        methods: vec![Method::UfoMac, Method::Commercial],
        strategies: vec![Strategy::TradeOff, Strategy::AreaDriven],
        mac: false,
        workers: 2,
        budget: ufo_mac::baselines::BaselineBudget { rlmul_iters: 2, seed: 1 },
        verify_vectors: 128,
        use_pjrt: false,
        ..Default::default()
    };
    let engine = Arc::new(SynthEngine::new(EngineConfig {
        verify_vectors: cfg.verify_vectors,
        workers: cfg.workers,
        ..EngineConfig::default()
    }));
    let first = coordinator::run_sweep_with(&engine, &cfg);
    assert_eq!(first.len(), 4);
    assert!(first.iter().all(|p| p.verified));
    let cold = engine.cache_stats();
    assert_eq!(cold.entries, 4);

    let second = coordinator::run_sweep_with(&engine, &cfg);
    let warm = engine.cache_stats();
    assert_eq!(second.len(), 4);
    assert_eq!(warm.entries, cold.entries, "no new synthesis on the repeat sweep");
    assert!(warm.hits >= cold.hits + 4, "all four points must be cache hits");

    // The rows themselves are identical.
    for (a, b) in first.iter().zip(&second) {
        assert_eq!(a.delay_ns, b.delay_ns);
        assert_eq!(a.area_um2, b.area_um2);
        assert_eq!(a.num_gates, b.num_gates);
    }
}

// ---------------------------------------------------------------------
// Batch compile ≡ serial compile (same artifacts, same order).
// ---------------------------------------------------------------------
#[test]
fn batch_compile_matches_serial() {
    let reqs: Vec<DesignRequest> = [3usize, 4, 5]
        .into_iter()
        .flat_map(|n| {
            [Strategy::TradeOff, Strategy::AreaDriven]
                .into_iter()
                .map(move |s| DesignRequest::method(Method::UfoMac, n, s, false))
        })
        .collect();

    let serial_engine = Arc::new(SynthEngine::new(EngineConfig::default()));
    let serial: Vec<_> =
        reqs.iter().map(|r| serial_engine.compile(r).unwrap()).collect();

    let batch_engine = Arc::new(SynthEngine::new(EngineConfig {
        workers: 3,
        ..EngineConfig::default()
    }));
    let batch: Vec<_> = batch_engine
        .compile_batch(&reqs)
        .into_iter()
        .map(|r| r.unwrap())
        .collect();

    assert_eq!(serial.len(), batch.len());
    for (i, (s, b)) in serial.iter().zip(&batch).enumerate() {
        assert_eq!(s.fingerprint, b.fingerprint, "row {i} out of order");
        assert_eq!(s.sta.num_gates, b.sta.num_gates, "row {i}");
        assert_eq!(s.sta.critical_delay_ns, b.sta.critical_delay_ns, "row {i}");
        assert_eq!(s.sta.area_um2, b.sta.area_um2, "row {i}");
    }

    // Duplicates inside one batch collapse to the same Arc.
    let dup = vec![reqs[0].clone(), reqs[0].clone(), reqs[0].clone()];
    let arts: Vec<_> =
        batch_engine.compile_batch(&dup).into_iter().map(|r| r.unwrap()).collect();
    assert!(Arc::ptr_eq(&arts[0], &arts[1]) && Arc::ptr_eq(&arts[1], &arts[2]));
}

// ---------------------------------------------------------------------
// The legacy shims and the engine agree (they are the same path).
// ---------------------------------------------------------------------
#[test]
fn legacy_shims_share_the_global_engine_cache() {
    let spec = MultiplierSpec::new(7).strategy(Strategy::TimingDriven);
    let via_build = spec.build().unwrap();
    let via_engine = ufo_mac::api::engine()
        .compile(&DesignRequest::from_spec(&spec))
        .unwrap();
    let d = via_engine.design().unwrap();
    assert_eq!(via_build.netlist.len(), d.netlist.len());
    assert_eq!(via_build.ct_stages, d.ct_stages);
    assert_eq!(via_build.profile, d.profile);
}

// ---------------------------------------------------------------------
// Strict CLI-facing parsing (satellite): unknown names are errors that
// list the valid values.
// ---------------------------------------------------------------------
#[test]
fn method_and_strategy_parse_strictly() {
    assert_eq!("ufo".parse::<Method>().unwrap(), Method::UfoMac);
    assert_eq!("gomil".parse::<Method>().unwrap(), Method::Gomil);
    assert_eq!("rlmul".parse::<Method>().unwrap(), Method::RlMul);
    assert_eq!("commercial".parse::<Method>().unwrap(), Method::Commercial);
    let err = "warp".parse::<Method>().unwrap_err().to_string();
    assert!(err.contains("ufo") && err.contains("gomil") && err.contains("rlmul"), "{err}");

    assert_eq!("area".parse::<Strategy>().unwrap(), Strategy::AreaDriven);
    assert_eq!("timing".parse::<Strategy>().unwrap(), Strategy::TimingDriven);
    assert_eq!("tradeoff".parse::<Strategy>().unwrap(), Strategy::TradeOff);
    let err = "fast".parse::<Strategy>().unwrap_err().to_string();
    assert!(err.contains("area") && err.contains("timing") && err.contains("tradeoff"), "{err}");
}

// ---------------------------------------------------------------------
// Module requests through the engine produce the same reports as the
// legacy helpers and share the inner design cache entry.
// ---------------------------------------------------------------------
#[test]
fn module_requests_match_legacy_reports() {
    let engine = SynthEngine::new(EngineConfig::default());
    let art = engine
        .compile(&DesignRequest::fir(Method::UfoMac, 4, Strategy::TradeOff, 1e9))
        .unwrap();
    let via_engine = art.module_report().unwrap();
    let via_legacy =
        ufo_mac::modules::fir_report(Method::UfoMac, 4, Strategy::TradeOff, 1e9).unwrap();
    assert_eq!(via_engine.wns_ns, via_legacy.wns_ns);
    assert_eq!(via_engine.area_um2, via_legacy.area_um2);

    let sys = engine
        .compile(&DesignRequest::systolic(Method::UfoMac, 4, Strategy::TradeOff, 1e9))
        .unwrap();
    assert!(sys.design().unwrap().is_mac, "PE must be a fused MAC");
    let legacy =
        ufo_mac::modules::systolic_report(Method::UfoMac, 4, Strategy::TradeOff, 1e9).unwrap();
    assert_eq!(sys.module_report().unwrap().area_um2, legacy.area_um2);
}
