//! Parallel-prefix graph IR and the regular adder structures (§2.2, §4.1).
//!
//! A [`PrefixGraph`] computes, for every bit `i`, the group generate
//! `G[i:0]` (= carry `c_i` with `c_in = 0`) through a DAG of associative
//! `∘` nodes over the bitwise `(g_i, p_i)` leaves. Each internal node has
//! exactly two fan-ins: the *trivial* fan-in `tf` (shares the node's MSB)
//! and the *non-trivial* fan-in `ntf` (the lower span) — the vocabulary
//! Algorithm 2's transformations are written in.
//!
//! Provided constructions: ripple (serial), Sklansky, Kogge-Stone,
//! Brent-Kung, Han-Carlson, carry-increment, and the paper's §4.1
//! region-segmented hybrid for non-uniform arrival profiles.

use std::collections::HashMap;

/// Index into [`PrefixGraph::nodes`].
pub type PIdx = usize;

/// A prefix node covering the span `[msb:lsb]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PNode {
    /// Upper bit of the covered span.
    pub msb: usize,
    /// Lower bit of the covered span.
    pub lsb: usize,
    /// Trivial fan-in: covers `[msb:k]`. `NONE` for leaves.
    pub tf: PIdx,
    /// Non-trivial fan-in: covers `[k-1:lsb]`. `NONE` for leaves.
    pub ntf: PIdx,
}

/// Sentinel index: "no node" (leaf fan-ins, unassigned roots).
pub const NONE: PIdx = usize::MAX;

impl PNode {
    /// Whether this is a leaf `(i, i)` node.
    pub fn is_leaf(&self) -> bool {
        self.tf == NONE
    }
    /// Bits covered: `msb - lsb + 1`.
    pub fn span(&self) -> usize {
        self.msb - self.lsb + 1
    }
}

/// A prefix carry graph over `n` bits.
#[derive(Debug, Clone)]
pub struct PrefixGraph {
    /// Bit width.
    pub n: usize,
    /// `nodes[0..n]` are the leaves `(i,i)`; internal nodes follow in
    /// topological order (fan-ins precede consumers).
    pub nodes: Vec<PNode>,
    /// For each bit `i`, the node computing `G[i:0]`.
    pub roots: Vec<PIdx>,
}

impl PrefixGraph {
    /// Fresh graph with only the `n` leaves; `roots[i]` defaults to the
    /// leaf for bit 0 and `NONE` elsewhere until a builder fills them.
    pub fn leaves(n: usize) -> Self {
        assert!(n >= 1);
        let nodes = (0..n).map(|i| PNode { msb: i, lsb: i, tf: NONE, ntf: NONE }).collect();
        let mut roots = vec![NONE; n];
        roots[0] = 0;
        PrefixGraph { n, nodes, roots }
    }

    /// Add the combine node `[msb(tf) : lsb(ntf)] = tf ∘ ntf`.
    pub fn combine(&mut self, tf: PIdx, ntf: PIdx) -> PIdx {
        let (t, u) = (self.nodes[tf], self.nodes[ntf]);
        assert_eq!(t.lsb, u.msb + 1, "non-adjacent spans {t:?} ∘ {u:?}");
        self.nodes.push(PNode { msb: t.msb, lsb: u.lsb, tf, ntf });
        self.nodes.len() - 1
    }

    /// Node by index (copied; nodes are small).
    pub fn node(&self, i: PIdx) -> PNode {
        self.nodes[i]
    }

    /// Internal (non-leaf) node count — the size/area proxy used in the
    /// prefix-adder literature.
    pub fn size(&self) -> usize {
        self.nodes.len() - self.n
    }

    /// Logic depth per node (leaves = 0).
    pub fn depths(&self) -> Vec<usize> {
        let mut d = vec![0usize; self.nodes.len()];
        for i in self.n..self.nodes.len() {
            let nd = self.nodes[i];
            d[i] = 1 + d[nd.tf].max(d[nd.ntf]);
        }
        d
    }

    /// Max depth over live roots.
    pub fn depth(&self) -> usize {
        let d = self.depths();
        self.roots.iter().filter(|&&r| r != NONE).map(|&r| d[r]).max().unwrap_or(0)
    }

    /// Fanout per node counting tf/ntf consumers among live nodes, plus one
    /// for each root (the sum XOR it drives).
    pub fn fanouts(&self) -> Vec<usize> {
        let live = self.live_mask();
        let mut fo = vec![0usize; self.nodes.len()];
        for i in self.n..self.nodes.len() {
            if !live[i] {
                continue;
            }
            let nd = self.nodes[i];
            fo[nd.tf] += 1;
            fo[nd.ntf] += 1;
        }
        for &r in &self.roots {
            if r != NONE {
                fo[r] += 1;
            }
        }
        fo
    }

    /// Mask of nodes reachable from the live roots.
    pub fn live_mask(&self) -> Vec<bool> {
        let mut live = vec![false; self.nodes.len()];
        let mut stack: Vec<PIdx> = self.roots.iter().copied().filter(|&r| r != NONE).collect();
        while let Some(i) = stack.pop() {
            if live[i] {
                continue;
            }
            live[i] = true;
            let nd = self.nodes[i];
            if !nd.is_leaf() {
                stack.push(nd.tf);
                stack.push(nd.ntf);
            }
        }
        live
    }

    /// Drop dead internal nodes, preserving topological order.
    pub fn prune(&mut self) {
        let live = self.live_mask();
        let mut remap = vec![NONE; self.nodes.len()];
        let mut new_nodes = Vec::with_capacity(self.nodes.len());
        for (i, nd) in self.nodes.iter().enumerate() {
            if i < self.n || live[i] {
                let mut m = *nd;
                if !m.is_leaf() {
                    m.tf = remap[m.tf];
                    m.ntf = remap[m.ntf];
                    // Release-mode invariant (UFO104 class): a live node
                    // whose fan-in was pruned means the live mask and the
                    // node list disagree — expanding such a graph would
                    // index out of bounds far from the cause.
                    assert!(m.tf != NONE && m.ntf != NONE, "prune dropped a live fan-in");
                }
                remap[i] = new_nodes.len();
                new_nodes.push(m);
            }
        }
        for r in self.roots.iter_mut() {
            if *r != NONE {
                *r = remap[*r];
            }
        }
        self.nodes = new_nodes;
    }

    /// Structural validation: spans compose, roots cover `[i:0]`.
    pub fn validate(&self) -> Result<(), String> {
        for (i, nd) in self.nodes.iter().enumerate() {
            if i < self.n {
                if !nd.is_leaf() || nd.msb != i || nd.lsb != i {
                    return Err(format!("leaf {i} malformed: {nd:?}"));
                }
            } else {
                if nd.is_leaf() {
                    return Err(format!("internal node {i} has no fan-ins"));
                }
                if nd.tf >= i || nd.ntf >= i {
                    return Err(format!("node {i}: forward reference"));
                }
                let t = self.nodes[nd.tf];
                let u = self.nodes[nd.ntf];
                if t.lsb != u.msb + 1 || t.msb != nd.msb || u.lsb != nd.lsb {
                    return Err(format!("node {i}: bad span composition"));
                }
            }
        }
        for (bit, &r) in self.roots.iter().enumerate() {
            if r == NONE {
                return Err(format!("bit {bit}: no root"));
            }
            let nd = self.nodes[r];
            if nd.msb != bit || nd.lsb != 0 {
                return Err(format!("bit {bit}: root covers [{}:{}]", nd.msb, nd.lsb));
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Regular structures
// ---------------------------------------------------------------------------

/// Named regular prefix structures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefixStructure {
    /// Serial carry chain.
    Ripple,
    /// Minimum-depth, high-fanout divide-and-conquer.
    Sklansky,
    /// Minimum-depth, bounded-fanout, wire-heavy.
    KoggeStone,
    /// Area-lean tree/un-tree structure.
    BrentKung,
    /// Sparse Kogge-Stone hybrid.
    HanCarlson,
    /// Carry-increment with the given block size.
    CarryIncrement(usize),
}

/// Build a regular structure over `n` bits.
pub fn build(structure: PrefixStructure, n: usize) -> PrefixGraph {
    match structure {
        PrefixStructure::Ripple => ripple(n),
        PrefixStructure::Sklansky => sklansky(n),
        PrefixStructure::KoggeStone => kogge_stone(n),
        PrefixStructure::BrentKung => brent_kung(n),
        PrefixStructure::HanCarlson => han_carlson(n),
        PrefixStructure::CarryIncrement(b) => carry_increment(n, b.max(1)),
    }
}

/// Serial ripple chain: `roots[i] = leaf_i ∘ roots[i-1]`.
pub fn ripple(n: usize) -> PrefixGraph {
    let mut g = PrefixGraph::leaves(n);
    for i in 1..n {
        let r = g.combine(i, g.roots[i - 1]);
        g.roots[i] = r;
    }
    g
}

/// Sklansky (conditional-sum): recursive doubling with shared low spans —
/// minimal depth `⌈log₂ n⌉`, high fanout.
pub fn sklansky(n: usize) -> PrefixGraph {
    let mut g = PrefixGraph::leaves(n);
    // span_node[(msb, lsb)] memo; built recursively.
    let mut memo: HashMap<(usize, usize), PIdx> = HashMap::new();
    for i in 0..n {
        memo.insert((i, i), i);
    }
    fn span(
        g: &mut PrefixGraph,
        memo: &mut HashMap<(usize, usize), PIdx>,
        msb: usize,
        lsb: usize,
    ) -> PIdx {
        if let Some(&idx) = memo.get(&(msb, lsb)) {
            return idx;
        }
        let size = msb - lsb + 1;
        // Split at the largest power of two ≤ size-1 below msb:
        let half = (size.next_power_of_two()) / 2;
        let k = lsb + half; // low part [k-1:lsb] has `half` bits
        let hi = span(g, memo, msb, k);
        let lo = span(g, memo, k - 1, lsb);
        let idx = g.combine(hi, lo);
        memo.insert((msb, lsb), idx);
        idx
    }
    for i in 1..n {
        let r = span(&mut g, &mut memo, i, 0);
        g.roots[i] = r;
    }
    g
}

/// Kogge-Stone: minimal depth, fanout ≤ 2, many nodes.
pub fn kogge_stone(n: usize) -> PrefixGraph {
    let mut g = PrefixGraph::leaves(n);
    // cur[i] = node covering [i : i-2^level+1] (clamped at 0).
    let mut cur: Vec<PIdx> = (0..n).collect();
    let mut reach = vec![0usize; n]; // lsb of cur[i]
    for (i, r) in reach.iter_mut().enumerate() {
        *r = i;
    }
    let mut dist = 1usize;
    while dist < n {
        let prev = cur.clone();
        let prev_reach = reach.clone();
        for i in (0..n).rev() {
            if prev_reach[i] == 0 {
                continue; // already covers [i:0]
            }
            let j = prev_reach[i] - 1; // combine with span ending just below
            let lo = prev[j];
            let node = g.combine(prev[i], lo);
            cur[i] = node;
            reach[i] = prev_reach[j];
        }
        dist *= 2;
    }
    for i in 0..n {
        g.roots[i] = cur[i];
    }
    g.prune();
    g
}

/// Brent-Kung: up-sweep/down-sweep, ~2·log₂ n depth, minimal-ish size.
pub fn brent_kung(n: usize) -> PrefixGraph {
    let mut g = PrefixGraph::leaves(n);
    let mut memo: HashMap<(usize, usize), PIdx> = HashMap::new();
    for i in 0..n {
        memo.insert((i, i), i);
    }
    // Up-sweep: power-of-two aligned spans.
    let mut span = 2usize;
    while span <= n.next_power_of_two() {
        let mut msb = span - 1;
        while msb < n {
            let lsb = msb + 1 - span;
            let mid = lsb + span / 2;
            if let (Some(&hi), Some(&lo)) = (memo.get(&(msb, mid)), memo.get(&(mid - 1, lsb))) {
                let idx = g.combine(hi, lo);
                memo.insert((msb, lsb), idx);
            }
            msb += span;
        }
        span *= 2;
    }
    // Down-sweep: build [i:0] for every bit by combining aligned blocks.
    fn root_for(
        g: &mut PrefixGraph,
        memo: &mut HashMap<(usize, usize), PIdx>,
        i: usize,
    ) -> PIdx {
        if let Some(&idx) = memo.get(&(i, 0)) {
            return idx;
        }
        // Largest aligned block [i : k] with k = i+1 - 2^t dividing cleanly:
        // take the lowest set bit of (i+1).
        let blk = (i + 1) & (i + 1).wrapping_neg();
        let k = i + 1 - blk;
        // k = 0 would mean bit i is itself an aligned block, which the
        // memo-hit branch above already returned; recursing on k-1 with
        // k = 0 underflows, so keep this checked in release too.
        assert!(k > 0, "aligned-block decomposition bottomed out at bit {i}");
        let hi = *memo.get(&(i, k)).expect("aligned span missing");
        let lo = root_for(g, memo, k - 1);
        let idx = g.combine(hi, lo);
        memo.insert((i, 0), idx);
        idx
    }
    for i in 1..n {
        let r = root_for(&mut g, &mut memo, i);
        g.roots[i] = r;
    }
    g.prune();
    g
}

/// Han-Carlson: Kogge-Stone on even bits, one ripple level for odd bits.
pub fn han_carlson(n: usize) -> PrefixGraph {
    let mut g = PrefixGraph::leaves(n);
    if n <= 2 {
        return ripple(n);
    }
    // Pair up (2k, 2k+1) into spans, Kogge-Stone over pairs, then fix odds.
    let mut pair: Vec<PIdx> = Vec::new(); // pair[k] covers [2k+1 : 2k] (or last single)
    let mut pair_lsb: Vec<usize> = Vec::new();
    let mut k = 0;
    while 2 * k < n {
        if 2 * k + 1 < n {
            let node = g.combine(2 * k + 1, 2 * k);
            pair.push(node);
        } else {
            pair.push(2 * k);
        }
        pair_lsb.push(2 * k);
        k += 1;
    }
    let m = pair.len();
    // Kogge-Stone over the pair nodes.
    let mut cur = pair.clone();
    let mut reach = pair_lsb.clone();
    let mut dist = 1usize;
    while dist < m {
        let prev = cur.clone();
        let prev_reach = reach.clone();
        for i in (0..m).rev() {
            if prev_reach[i] == 0 {
                continue;
            }
            let j = prev_reach[i] / 2 - 1;
            let node = g.combine(prev[i], prev[j]);
            cur[i] = node;
            reach[i] = prev_reach[j];
        }
        dist *= 2;
    }
    // cur[k] covers [min(2k+1, n-1) : 0]; odd bits roots come directly,
    // even bits (>0) need one extra combine with the pair below.
    for i in 1..n {
        if i % 2 == 1 {
            g.roots[i] = cur[i / 2];
        } else {
            let node = g.combine(i, cur[i / 2 - 1]);
            g.roots[i] = node;
        }
    }
    g.prune();
    g
}

/// Carry-increment adder with fixed block size: serial chains inside each
/// block plus one increment combine per bit with the previous block's
/// carry — the §4.1 choice for the negative-slope region 3.
pub fn carry_increment(n: usize, block: usize) -> PrefixGraph {
    let mut g = PrefixGraph::leaves(n);
    let mut lo = 0usize;
    let mut prev_root: Option<PIdx> = None;
    while lo < n {
        let hi = (lo + block - 1).min(n - 1);
        // Local serial spans [i:lo].
        let mut local: Vec<PIdx> = Vec::with_capacity(hi - lo + 1);
        local.push(lo);
        for i in lo + 1..=hi {
            let node = g.combine(i, *local.last().unwrap());
            local.push(node);
        }
        for i in lo..=hi {
            let l = local[i - lo];
            g.roots[i] = match prev_root {
                None => l,
                Some(pr) => g.combine(l, pr),
            };
        }
        prev_root = Some(g.roots[hi]);
        lo = hi + 1;
    }
    g
}

/// §4.1 region-segmented hybrid initial structure for a non-uniform arrival
/// profile: ripple in the rising region 1, Sklansky in the flat (late)
/// region 2, carry-increment in the falling region 3. `r1 ≤ r2` are the
/// region boundaries (bit indices).
pub fn hybrid_regions(n: usize, r1: usize, r2: usize, ci_block: usize) -> PrefixGraph {
    let r1 = r1.min(n);
    let r2 = r2.clamp(r1, n);
    let mut g = PrefixGraph::leaves(n);
    // Region 1: ripple [0, r1)
    for i in 1..r1 {
        let r = g.combine(i, g.roots[i - 1]);
        g.roots[i] = r;
    }
    let mut prev_root = if r1 > 0 { Some(g.roots[r1 - 1]) } else { None };
    // Region 2: Sklansky over [r1, r2), each span [i:r1] + increment.
    if r2 > r1 {
        let mut memo: HashMap<(usize, usize), PIdx> = HashMap::new();
        for i in r1..r2 {
            memo.insert((i, i), i);
        }
        fn span(
            g: &mut PrefixGraph,
            memo: &mut HashMap<(usize, usize), PIdx>,
            msb: usize,
            lsb: usize,
        ) -> PIdx {
            if let Some(&idx) = memo.get(&(msb, lsb)) {
                return idx;
            }
            let size = msb - lsb + 1;
            let half = size.next_power_of_two() / 2;
            let k = lsb + half;
            let hi = span(g, memo, msb, k);
            let lo = span(g, memo, k - 1, lsb);
            let idx = g.combine(hi, lo);
            memo.insert((msb, lsb), idx);
            idx
        }
        for i in r1..r2 {
            let local = span(&mut g, &mut memo, i, r1);
            g.roots[i] = match prev_root {
                None => local,
                Some(pr) => g.combine(local, pr),
            };
        }
        prev_root = Some(g.roots[r2 - 1]);
    }
    // Region 3: carry-increment blocks over [r2, n).
    let mut lo = r2;
    while lo < n {
        let hi = (lo + ci_block - 1).min(n - 1);
        let mut chain = lo;
        g.roots[lo] = match prev_root {
            None => lo,
            Some(pr) => g.combine(lo, pr),
        };
        for i in lo + 1..=hi {
            chain = g.combine(i, chain);
            g.roots[i] = match prev_root {
                None => chain,
                Some(pr) => g.combine(chain, pr),
            };
        }
        prev_root = Some(g.roots[hi]);
        lo = hi + 1;
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_structures(n: usize) -> Vec<(&'static str, PrefixGraph)> {
        vec![
            ("ripple", ripple(n)),
            ("sklansky", sklansky(n)),
            ("kogge-stone", kogge_stone(n)),
            ("brent-kung", brent_kung(n)),
            ("han-carlson", han_carlson(n)),
            ("carry-increment", carry_increment(n, 4)),
            ("hybrid", hybrid_regions(n, n / 4, 3 * n / 4, 4)),
        ]
    }

    #[test]
    fn structures_validate_across_widths() {
        for n in [1, 2, 3, 5, 8, 13, 16, 24, 32, 64] {
            for (name, g) in all_structures(n) {
                g.validate().unwrap_or_else(|e| panic!("{name} n={n}: {e}"));
            }
        }
    }

    #[test]
    fn depth_properties() {
        let n = 32;
        assert_eq!(ripple(n).depth(), n - 1);
        assert_eq!(sklansky(n).depth(), 5); // ⌈log2 32⌉
        assert_eq!(kogge_stone(n).depth(), 5);
        let bk = brent_kung(n).depth();
        assert!(bk > 5 && bk <= 2 * 5, "brent-kung depth {bk}");
        let hc = han_carlson(n).depth();
        assert!(hc <= 6, "han-carlson depth {hc}");
    }

    #[test]
    fn size_properties() {
        let n = 32;
        // Kogge-Stone is the node-count heavyweight; ripple the lightest.
        assert!(kogge_stone(n).size() > sklansky(n).size());
        assert_eq!(ripple(n).size(), n - 1);
        // Brent-Kung ≈ 2n - log2 n - 2 nodes.
        assert!(brent_kung(n).size() < kogge_stone(n).size());
    }

    #[test]
    fn sklansky_fanout_exceeds_kogge_stone() {
        let n = 32;
        let fs = *sklansky(n).fanouts().iter().max().unwrap();
        let fk = *kogge_stone(n).fanouts().iter().max().unwrap();
        assert!(fs > fk, "sklansky {fs} vs kogge-stone {fk}");
    }

    #[test]
    fn prune_removes_dead_nodes() {
        let mut g = ripple(8);
        // Orphan node: combine leaves 5,4 (span [5:4]) never used as root.
        g.combine(5, 4);
        let before = g.nodes.len();
        g.prune();
        assert_eq!(g.nodes.len(), before - 1);
        g.validate().unwrap();
    }

    #[test]
    fn hybrid_degenerate_regions() {
        // all-region-1, all-region-2 and all-region-3 degenerate cleanly
        hybrid_regions(16, 16, 16, 4).validate().unwrap();
        hybrid_regions(16, 0, 16, 4).validate().unwrap();
        hybrid_regions(16, 0, 0, 4).validate().unwrap();
    }
}
