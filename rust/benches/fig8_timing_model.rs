//! Figure 8 — fidelity of the timing models (logic depth, mpfo, FDC).
//!
//! The paper fits each model on 10 000 paths from 1 100 adders and reports
//! R² / MAPE; FDC wins (0.816 / 4.63%) over depth (0.541 / 9.30%) and mpfo
//! (0.469 / 10.91%). We regenerate the experiment on a random prefix-adder
//! dataset, using the STA engine as delay ground truth, and check the
//! *ordering* (FDC > depth, FDC > mpfo).

use ufo_mac::bench::Bench;
use ufo_mac::cpa::netlist::standalone_adder;
use ufo_mac::cpa::timing::{
    depth_per_bit, fdc_features, fidelity, least_squares, mpfo,
};
use ufo_mac::cpa::random_adder_dataset;
use ufo_mac::sta::Sta;

fn main() {
    let bench = Bench::new("fig8_timing_model");
    let quick = std::env::var("UFO_BENCH_QUICK").is_ok();
    let n_adders = if quick { 60 } else { 1100 };
    let widths = [8usize, 12, 16, 24, 32];

    let dataset = random_adder_dataset(&widths, n_adders, 0xF16_8);
    let sta = Sta { activity_rounds: 0, ..Sta::default() };

    // Collect (features, truth) samples per model: one sample per output
    // bit of every adder (≈ n_adders × mean-width ≈ 10k paths at full size).
    let mut xs_fdc: Vec<Vec<f64>> = Vec::new();
    let mut xs_depth: Vec<Vec<f64>> = Vec::new();
    let mut xs_mpfo: Vec<Vec<f64>> = Vec::new();
    let mut truth: Vec<f64> = Vec::new();
    for g in &dataset {
        let (nl, sums) = standalone_adder(g, None);
        let at = sta.arrivals_ns(&nl);
        let fdc = fdc_features(g);
        let dep = depth_per_bit(g);
        let mp = mpfo(g);
        for bit in 1..g.n {
            // truth: measured arrival of sum bit `bit` (drives through
            // the sub-prefix tree rooted at bit-1's carry).
            let t = at[sums[bit].index()];
            if t <= 0.0 {
                continue;
            }
            truth.push(t);
            let f = &fdc[bit - 1];
            xs_fdc.push(vec![f.f_black, f.f_blue, f.n_black, f.n_blue]);
            xs_depth.push(vec![dep[bit - 1]]);
            xs_mpfo.push(vec![mp[bit - 1]]);
        }
    }
    println!("\nFigure 8 reproduction: {} paths from {} adders", truth.len(), dataset.len());

    let eval = |name: &str, xs: &[Vec<f64>]| {
        let (w, b) = least_squares(xs, &truth);
        let pred: Vec<f64> = xs
            .iter()
            .map(|x| x.iter().zip(&w).map(|(v, k)| v * k).sum::<f64>() + b)
            .collect();
        let fid = fidelity(&pred, &truth);
        println!("  {name:<12} R² {:.3}   MAPE {:.2}%", fid.r2, fid.mape * 100.0);
        fid
    };
    let f_depth = eval("logic depth", &xs_depth);
    let f_mpfo = eval("mpfo", &xs_mpfo);
    let f_fdc = eval("FDC", &xs_fdc);
    println!("  (paper: depth 0.541/9.30%, mpfo 0.469/10.91%, FDC 0.816/4.63%)");

    bench.metric("r2_depth", f_depth.r2, "");
    bench.metric("r2_mpfo", f_mpfo.r2, "");
    bench.metric("r2_fdc", f_fdc.r2, "");
    bench.metric("mape_depth_pct", f_depth.mape * 100.0, "%");
    bench.metric("mape_mpfo_pct", f_mpfo.mape * 100.0, "%");
    bench.metric("mape_fdc_pct", f_fdc.mape * 100.0, "%");

    // O(n) feature-extraction cost claim: time one 32-bit extraction.
    let g32 = &dataset[0];
    bench.bench("fdc_features_extract", || fdc_features(g32));

    assert!(f_fdc.r2 > f_depth.r2, "FDC must beat depth (paper's ordering)");
    assert!(f_fdc.r2 > f_mpfo.r2, "FDC must beat mpfo (paper's ordering)");
    assert!(f_fdc.mape < f_depth.mape && f_fdc.mape < f_mpfo.mape);
}
