//! Scheduler behavior under load: cache-hit compiles must preempt an
//! in-flight sweep (no starvation), streamed progress must be monotone,
//! and sweep results must not depend on the handler count.

use std::sync::Arc;
use ufo_mac::api::{DesignRequest, EngineConfig, SynthEngine};
use ufo_mac::server::{compile_line, Server};
use ufo_mac::util::Json;

fn server_with_workers(workers: usize) -> Server {
    Server::new(Arc::new(SynthEngine::new(EngineConfig {
        workers,
        ..EngineConfig::default()
    })))
}

const SWEEP: &str = r#"{"cmd":"sweep","id":100,"methods":["ufo","gomil"],"strategies":["tradeoff"],"stream":true,"widths":[6,7]}"#;

// ---------------------------------------------------------------------
// Starvation: a burst of cache-hit compiles admitted behind a long
// streamed sweep must all be answered before the sweep's final envelope —
// the sweep yields between design points and cache hits classify urgent.
// ---------------------------------------------------------------------
#[test]
fn cached_compiles_preempt_an_in_flight_sweep() {
    let srv = server_with_workers(2);
    // Prewarm one design so the burst classifies as cache hits (urgent).
    let warm = DesignRequest::multiplier(4);
    let resp = srv.handle_line(&compile_line(1, &warm));
    assert!(resp.contains(r#""source":"compiled""#), "{resp}");

    let mut input = format!("{SWEEP}\n");
    let burst = 8;
    for i in 0..burst {
        input.push_str(&compile_line(200 + i, &warm));
        input.push('\n');
    }
    let mut out = Vec::new();
    srv.serve(input.as_bytes(), &mut out, 2).unwrap();
    let lines: Vec<Json> = String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| Json::parse(l).unwrap())
        .collect();

    let sweep_final = lines
        .iter()
        .position(|l| {
            l.get("event").is_none() && l.get("id").and_then(|i| i.as_f64()) == Some(100.0)
        })
        .expect("sweep final envelope present");
    let compile_envelopes: Vec<usize> = lines
        .iter()
        .enumerate()
        .filter(|(_, l)| l.get("id").and_then(|i| i.as_f64()).unwrap_or(0.0) >= 200.0)
        .map(|(i, _)| i)
        .collect();
    assert_eq!(compile_envelopes.len() as u64, burst, "{lines:?}");
    for &pos in &compile_envelopes {
        assert!(
            pos < sweep_final,
            "cache-hit compile at line {pos} was starved past the sweep final at {sweep_final}"
        );
        assert_eq!(
            lines[pos].get("result").unwrap().get("source").unwrap().as_str(),
            Some("memory"),
            "{:?}",
            lines[pos]
        );
    }

    // Progress frames of the sweep stay strictly monotone even while the
    // burst preempts it between points.
    let dones: Vec<f64> = lines
        .iter()
        .filter(|l| l.get("event").is_some())
        .map(|l| l.get("done").unwrap().as_f64().unwrap())
        .collect();
    assert_eq!(dones, vec![1.0, 2.0, 3.0, 4.0], "{lines:?}");
    // And the final envelope still carries every point.
    assert_eq!(
        lines[sweep_final].get("result").unwrap().get("count").unwrap().as_f64(),
        Some(4.0)
    );
}

// ---------------------------------------------------------------------
// Handler-count independence: the same streamed sweep through 1, 2 and 4
// handlers yields byte-identical point lists and the same monotone frame
// sequence — scheduling may change *when* things run, never the results.
// ---------------------------------------------------------------------
#[test]
fn sweep_results_are_independent_of_worker_count() {
    let mut rendered: Vec<String> = Vec::new();
    for workers in [1usize, 2, 4] {
        let srv = server_with_workers(workers);
        let mut out = Vec::new();
        srv.serve(format!("{SWEEP}\n").as_bytes(), &mut out, workers).unwrap();
        let lines: Vec<Json> = String::from_utf8(out)
            .unwrap()
            .lines()
            .map(|l| Json::parse(l).unwrap())
            .collect();
        assert_eq!(lines.len(), 5, "4 frames + final with {workers} workers: {lines:?}");
        for (i, frame) in lines[..4].iter().enumerate() {
            assert_eq!(frame.get("event").unwrap().as_str(), Some("progress"));
            assert_eq!(frame.get("done").unwrap().as_f64(), Some((i + 1) as f64));
            assert_eq!(frame.get("total").unwrap().as_f64(), Some(4.0));
        }
        let result = lines[4].get("result").unwrap();
        assert_eq!(result.get("count").unwrap().as_f64(), Some(4.0));
        rendered.push(result.get("points").unwrap().render());
    }
    assert_eq!(rendered[0], rendered[1], "1 vs 2 workers");
    assert_eq!(rendered[1], rendered[2], "2 vs 4 workers");
}
