//! Priority job queue driving the compile service's handler pool.
//!
//! Every admitted request becomes a job in one of three priority classes
//! ([`Priority`]): a fixed pool of handler threads pops the highest class
//! first, FIFO within a class. Long-running commands (`sweep`, `batch`)
//! are *yielding* jobs — the server processes one design point per pop and
//! re-enqueues the remainder — so a cache-hit `compile` admitted while a
//! multi-minute sweep is in flight is answered at the next yield point
//! even with a single handler. `server/mod.rs` owns the job type and the
//! yield protocol; this module is the queue itself.
//!
//! The queue is a plain `Mutex<[VecDeque; 3]>` + `Condvar`: pushes are one
//! lock acquisition, a blocking [`Scheduler::pop`] sleeps on the condvar
//! until work or [`Scheduler::close`]. Closing means "no more external
//! admissions": handlers drain what remains (including re-enqueued tails
//! of yielding jobs, which are always pushed by a still-live handler) and
//! then `pop` returns `None`.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Priority class of a scheduled job. Lower ordinal pops first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Answerable in ~constant time: `stats`, `metrics`, `shutdown`,
    /// protocol errors, and `compile`/`lint`/`analyze` of designs already
    /// resident in a cache tier.
    Urgent = 0,
    /// A single fresh synthesis (`compile`/`lint`/`analyze` of an uncached
    /// design).
    Interactive = 1,
    /// Multi-point work (`sweep`, `batch`) that yields between design
    /// points.
    Bulk = 2,
}

impl Priority {
    /// All classes, highest priority first.
    pub const ALL: [Priority; 3] = [Priority::Urgent, Priority::Interactive, Priority::Bulk];

    /// Stable wire key (the `metrics` response's `queue` object).
    pub fn key(self) -> &'static str {
        match self {
            Priority::Urgent => "urgent",
            Priority::Interactive => "interactive",
            Priority::Bulk => "bulk",
        }
    }

    /// Index into per-class arrays (`0` = highest priority).
    pub fn index(self) -> usize {
        self as usize
    }
}

struct State<T> {
    queues: [VecDeque<T>; 3],
    closed: bool,
}

/// A closeable three-class priority queue (see module docs).
pub struct Scheduler<T> {
    state: Mutex<State<T>>,
    ready: Condvar,
}

impl<T> Scheduler<T> {
    /// Empty, open scheduler.
    pub fn new() -> Scheduler<T> {
        Scheduler {
            state: Mutex::new(State {
                queues: std::array::from_fn(|_| VecDeque::new()),
                closed: false,
            }),
            ready: Condvar::new(),
        }
    }

    /// Enqueue `item` at the back of its class. Pushes are accepted even
    /// after [`Scheduler::close`] — that is how yielding jobs re-enqueue
    /// their tails while the queue drains.
    pub fn push(&self, item: T, class: Priority) {
        self.state.lock().unwrap().queues[class.index()].push_back(item);
        self.ready.notify_one();
    }

    /// Pop the front of the highest non-empty class, blocking while the
    /// queue is empty but still open. Returns `None` once the scheduler is
    /// closed *and* drained — the handler-pool exit condition.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            for q in &mut st.queues {
                if let Some(item) = q.pop_front() {
                    return Some(item);
                }
            }
            if st.closed {
                return None;
            }
            st = self.ready.wait(st).unwrap();
        }
    }

    /// Declare the end of external admissions and wake every blocked
    /// popper. Idempotent.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.ready.notify_all();
    }

    /// Queued (not yet popped) items per class, highest priority first.
    /// A gauge for tests; the server's `metrics` command reports
    /// admitted-but-unanswered depths instead, which also cover popped
    /// jobs still being worked.
    pub fn depths(&self) -> [usize; 3] {
        let st = self.state.lock().unwrap();
        std::array::from_fn(|i| st.queues[i].len())
    }
}

impl<T> Default for Scheduler<T> {
    fn default() -> Self {
        Scheduler::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn higher_class_pops_first_fifo_within_class() {
        let s = Scheduler::new();
        s.push("bulk-1", Priority::Bulk);
        s.push("bulk-2", Priority::Bulk);
        s.push("urgent-1", Priority::Urgent);
        s.push("interactive-1", Priority::Interactive);
        s.push("urgent-2", Priority::Urgent);
        s.close();
        let order: Vec<_> = std::iter::from_fn(|| s.pop()).collect();
        assert_eq!(order, ["urgent-1", "urgent-2", "interactive-1", "bulk-1", "bulk-2"]);
    }

    #[test]
    fn close_unblocks_and_drains() {
        let s: Scheduler<u32> = Scheduler::new();
        std::thread::scope(|scope| {
            let popper = scope.spawn(|| s.pop());
            s.push(7, Priority::Bulk);
            assert_eq!(popper.join().unwrap(), Some(7));
            s.close();
            assert_eq!(s.pop(), None);
            // Re-pushes after close are still served before None.
            s.push(8, Priority::Urgent);
            assert_eq!(s.pop(), Some(8));
            assert_eq!(s.pop(), None);
        });
    }

    #[test]
    fn depths_track_classes() {
        let s = Scheduler::new();
        s.push((), Priority::Bulk);
        s.push((), Priority::Bulk);
        s.push((), Priority::Urgent);
        assert_eq!(s.depths(), [1, 0, 2]);
        assert_eq!(Priority::ALL.map(Priority::key), ["urgent", "interactive", "bulk"]);
    }
}
