//! Generic forward dataflow / fixpoint engine over the flat SoA netlist.
//!
//! One engine, three instantiations ([`crate::analysis::ternary`],
//! [`crate::analysis::prob`], and — derived from ternary —
//! [`crate::analysis::interval`]). The engine exploits two structural
//! facts the IR already maintains:
//!
//! - **Level schedule.** [`crate::ir::Topology::depths`] assigns every
//!   gate `1 + max(fanin depths)` and every input/constant/register depth
//!   0, so a node at level `d` reads only nodes at levels `< d`. A sweep
//!   therefore evaluates one level at a time, and *within* a level every
//!   transfer is independent — which is what lets big levels fan out over
//!   [`crate::coordinator::pool::scoped_workers`] with each worker
//!   producing values for a disjoint index range. The value of a node is
//!   a pure function of strictly-lower-level values, so the sweep result
//!   is byte-identical for any worker count (the same invariant the
//!   parallel equivalence sweep upholds).
//! - **Register outer fixpoint.** Registers are depth-0 cut points: a
//!   sweep reads each `OP_REG` node's *current* abstract state exactly as
//!   [`crate::sim::ClockedSim`] reads its latched word. After a sweep the
//!   engine applies the abstract latch transfer
//!   `q' = clr ? init : (en ? d : q)` per register, folds it into the
//!   accumulated state with [`Domain::widen`], and re-sweeps until no
//!   register moves (or `max_sweeps` is hit). Starting from `reg_inits`
//!   and widening monotonically makes the final state cover the initial
//!   state *and* every state reachable from it — the standard collecting
//!   semantics argument that makes the results sound for all cycles.
//!
//! Invalidation mirrors the topology cache: analysis results are derived
//! from a netlist snapshot and are recomputed from scratch after any
//! structural edit (the engine holds no incremental state).

use crate::coordinator::pool;
use crate::ir::Netlist;
use std::sync::Mutex;

/// An abstract lattice domain the fixpoint engine can run. Implementors
/// provide the per-opcode transfer functions; the engine owns scheduling,
/// parallelism and the register fixpoint.
pub trait Domain: Sync {
    /// Abstract value carried by every node.
    type Value: Copy + PartialEq + Send + Sync;

    /// Value of a primary input (`ordinal` is the input creation order).
    fn input(&self, ordinal: usize) -> Self::Value;

    /// Value of a constant node.
    fn constant(&self, one: bool) -> Self::Value;

    /// Starting register state, from the register's init bit (the state
    /// every lane holds after [`crate::sim::ClockedSim::reset`]).
    fn reg_start(&self, init: bool) -> Self::Value;

    /// Transfer of gate node `i` (opcode ≤ 10): read fanins from `vals`;
    /// the level schedule guarantees they are final for the current sweep.
    fn transfer(&self, nl: &Netlist, vals: &[Self::Value], i: usize) -> Self::Value;

    /// Abstract synchronous latch `q' = clr ? init : (en ? d : q)` — the
    /// per-lane update [`crate::sim::ClockedSim::step`] applies concretely.
    fn latch(
        &self,
        d: Self::Value,
        en: Self::Value,
        clr: Self::Value,
        q: Self::Value,
        init: bool,
    ) -> Self::Value;

    /// Fold the latch result into the accumulated register state. Lattice
    /// domains join (so the state covers every reachable cycle); numeric
    /// estimate domains may simply replace.
    fn widen(&self, old: Self::Value, next: Self::Value) -> Self::Value;

    /// Whether the accumulated register state stopped moving.
    fn converged(&self, old: Self::Value, new: Self::Value) -> bool;
}

/// Result of [`run`]: per-node abstract values plus the number of full
/// level-ordered sweeps the register fixpoint needed (1 for combinational
/// netlists).
#[derive(Debug, Clone)]
pub struct FixpointRun<V> {
    /// Abstract value per node (index with [`crate::ir::NodeId::index`]).
    pub values: Vec<V>,
    /// Full sweeps performed before the register state converged (or the
    /// sweep cap was reached).
    pub sweeps: usize,
}

/// Minimum gates in one level before the sweep fans out over the worker
/// team — below this the spawn cost dominates the transfer work. Serial
/// and parallel evaluation compute identical values, so the threshold
/// never changes results.
const PAR_LEVEL_MIN: usize = 256;

/// Gate node ids grouped by topological level (ascending id within each
/// level), from the netlist's cached topology. Level 0 (inputs, constants,
/// registers) is dropped: those nodes are initialized once, not swept.
fn gate_levels(nl: &Netlist) -> Vec<Vec<u32>> {
    let topo = nl.topology();
    let ops = nl.ops();
    topo.levels()
        .into_iter()
        .skip(1)
        .map(|level| level.into_iter().filter(|&i| ops[i as usize] <= 10).collect())
        .collect()
}

/// One level-ordered sweep: evaluate every gate level in depth order,
/// fanning large levels out over `workers` scoped threads.
fn sweep<D: Domain>(
    nl: &Netlist,
    dom: &D,
    levels: &[Vec<u32>],
    vals: &mut [D::Value],
    workers: usize,
) {
    for level in levels {
        if level.is_empty() {
            continue;
        }
        if workers <= 1 || level.len() < PAR_LEVEL_MIN {
            for &i in level {
                let v = dom.transfer(nl, vals, i as usize);
                vals[i as usize] = v;
            }
            continue;
        }
        // Parallel level: worker `w` computes values for the contiguous
        // chunk `[w·chunk, (w+1)·chunk)` of the level into its own slot;
        // the write-back below is serial, so no two threads ever alias a
        // value cell. Per-node values do not depend on the chunking, so
        // any worker count produces byte-identical sweeps.
        let chunk = level.len().div_ceil(workers);
        let slots: Vec<Mutex<Vec<D::Value>>> =
            (0..workers).map(|_| Mutex::new(Vec::new())).collect();
        {
            let read: &[D::Value] = vals;
            pool::scoped_workers(workers, |w| {
                let lo = (w * chunk).min(level.len());
                let hi = ((w + 1) * chunk).min(level.len());
                let mut out = Vec::with_capacity(hi - lo);
                for &i in &level[lo..hi] {
                    out.push(dom.transfer(nl, read, i as usize));
                }
                *slots[w].lock().unwrap() = out;
            });
        }
        for (w, slot) in slots.iter().enumerate() {
            let out = std::mem::take(&mut *slot.lock().unwrap());
            let lo = (w * chunk).min(level.len());
            for (k, v) in out.into_iter().enumerate() {
                vals[level[lo + k] as usize] = v;
            }
        }
    }
}

/// Run `dom` to fixpoint over `nl`.
///
/// Combinational netlists take exactly one sweep. Sequential netlists
/// iterate: sweep, apply the abstract latch per register (reading the
/// settled sweep, so feedback data pins see this sweep's value — the same
/// two-phase discipline as [`crate::sim::ClockedSim::step`]), widen, and
/// re-sweep until every register converges or `max_sweeps` is reached.
/// For a finite-height lattice with a joining [`Domain::widen`] the cap
/// is never the binding constraint; numeric domains use it as an
/// iteration budget.
pub fn run<D: Domain>(
    nl: &Netlist,
    dom: &D,
    workers: usize,
    max_sweeps: usize,
) -> FixpointRun<D::Value> {
    use crate::ir::netlist::{OP_CONST0, OP_CONST1, OP_INPUT, OP_REG};
    let ops = nl.ops();
    let fanin = nl.fanin_records();
    let mut vals: Vec<D::Value> = Vec::with_capacity(ops.len());
    for i in 0..ops.len() {
        vals.push(match ops[i] {
            OP_CONST0 => dom.constant(false),
            OP_CONST1 => dom.constant(true),
            OP_INPUT => dom.input(fanin[i][0] as usize),
            OP_REG => dom.reg_start(nl.reg_init(crate::ir::NodeId(i as u32))),
            // Gates are overwritten by the first sweep before any
            // same-or-higher-level node reads them.
            _ => dom.constant(false),
        });
    }
    let levels = gate_levels(nl);
    let regs = nl.registers();
    let mut sweeps = 0usize;
    loop {
        sweep(nl, dom, &levels, &mut vals, workers.max(1));
        sweeps += 1;
        if regs.is_empty() || sweeps >= max_sweeps.max(1) {
            break;
        }
        // Latch phase: read every d/en/clr from the settled sweep first,
        // then fold — mirroring the simulator's read-then-latch split.
        let nexts: Vec<D::Value> = regs
            .iter()
            .map(|&(r, init)| {
                let [d, en, clr] = fanin[r as usize];
                dom.latch(
                    vals[d as usize],
                    vals[en as usize],
                    vals[clr as usize],
                    vals[r as usize],
                    init,
                )
            })
            .collect();
        let mut changed = false;
        for (k, &(r, _)) in regs.iter().enumerate() {
            let widened = dom.widen(vals[r as usize], nexts[k]);
            if !dom.converged(vals[r as usize], widened) {
                vals[r as usize] = widened;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    FixpointRun { values: vals, sweeps }
}
