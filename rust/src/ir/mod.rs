//! Netlist intermediate representation and standard-cell library.
//!
//! This is the substrate every other module builds on: the paper's
//! generators emit [`Netlist`]s, the STA engine times them, the simulator
//! and the PJRT-backed evaluator execute them.

pub mod cell;
pub mod netlist;

pub use cell::{CellKind, CellLib, CellParams};
pub use netlist::{Netlist, Node, NodeId};
