//! Static timing analysis, area and power reporting.
//!
//! Replaces the paper's Synopsys Design Compiler reports with a
//! logical-effort timing engine (`d = p + g·h` per stage, load computed
//! from actual fanout) applied uniformly to every generator — preserving
//! the *relative* comparisons that the paper's tables and Pareto plots
//! report. Arrival times honour per-input arrival offsets, which is how the
//! CPA sees the compressor tree's non-uniform ("trapezoidal") profile.

use crate::ir::{CellLib, Netlist, Node, NodeId};


/// Timing/area/power report for one netlist.
#[derive(Debug, Clone)]
pub struct StaReport {
    /// Worst arrival time over primary outputs, ns.
    pub critical_delay_ns: f64,
    /// Total standard-cell area, µm².
    pub area_um2: f64,
    /// Estimated dynamic power at `clock_ghz`, mW.
    pub power_mw: f64,
    /// Arrival time per primary output, ns (output order of the netlist).
    pub output_arrivals_ns: Vec<f64>,
    /// Gate count.
    pub num_gates: usize,
    /// Max logic depth over outputs.
    pub depth: u32,
}

impl StaReport {
    /// Worst negative slack against a clock period (ns): `period - delay`.
    /// Negative means the design misses timing (as in the paper's tables).
    pub fn wns_ns(&self, period_ns: f64) -> f64 {
        period_ns - self.critical_delay_ns
    }
}

/// The STA engine. Holds the cell library and power-model knobs.
#[derive(Debug, Clone)]
pub struct Sta {
    pub lib: CellLib,
    /// Clock used to convert switching energy to power, GHz.
    pub clock_ghz: f64,
    /// Rounds of 64 random vectors for toggle-rate extraction. `0` selects a
    /// constant-activity fallback (fast path for huge module-level runs).
    pub activity_rounds: usize,
    /// Activity factor used when `activity_rounds == 0`.
    pub default_activity: f64,
}

impl Default for Sta {
    fn default() -> Self {
        Sta { lib: CellLib::nangate45(), clock_ghz: 1.0, activity_rounds: 16, default_activity: 0.15 }
    }
}

impl Sta {
    pub fn with_lib(lib: CellLib) -> Self {
        Sta { lib, ..Default::default() }
    }

    /// Arrival time (ns) of every node: one levelized forward sweep.
    pub fn arrivals_ns(&self, nl: &Netlist) -> Vec<f64> {
        let loads = nl.loads(&self.lib);
        let mut at = vec![0.0f64; nl.len()];
        for (i, node) in nl.nodes().iter().enumerate() {
            at[i] = match node {
                Node::Input { arrival_ns, .. } => *arrival_ns,
                Node::Const(_) => 0.0,
                Node::Gate { kind, fanin } => {
                    let worst = fanin.iter().map(|f| at[f.index()]).fold(f64::MIN, f64::max);
                    worst + self.lib.delay_ns(*kind, loads[i])
                }
            };
        }
        at
    }

    /// Full report: timing + area + toggle-based dynamic power.
    pub fn analyze(&self, nl: &Netlist) -> StaReport {
        let at = self.arrivals_ns(nl);
        let output_arrivals_ns: Vec<f64> =
            nl.outputs().iter().map(|(_, id)| at[id.index()]).collect();
        let critical_delay_ns =
            output_arrivals_ns.iter().copied().fold(0.0f64, f64::max);
        let area_um2 = nl.area_um2(&self.lib);
        let power_mw = self.dynamic_power_mw(nl);
        StaReport {
            critical_delay_ns,
            area_um2,
            power_mw,
            output_arrivals_ns,
            num_gates: nl.num_gates(),
            depth: nl.depth(),
        }
    }

    /// Dynamic power: `P = Σ_g activity_g · E_g · f_clk`.
    pub fn dynamic_power_mw(&self, nl: &Netlist) -> f64 {
        let activities: Vec<f64> = if self.activity_rounds > 0 && nl.num_inputs() > 0 {
            crate::sim::toggle_activity(nl, self.activity_rounds, 0x5eed)
        } else {
            vec![self.default_activity; nl.len()]
        };
        let mut energy_fj_per_cycle = 0.0;
        for (i, node) in nl.nodes().iter().enumerate() {
            if let Node::Gate { kind, .. } = node {
                energy_fj_per_cycle += activities[i] * self.lib.params(*kind).switch_energy_fj;
            }
        }
        // fJ/cycle × GHz = µW; report mW.
        energy_fj_per_cycle * self.clock_ghz / 1000.0
    }

    /// Arrival profile (ns) for a set of labelled output groups — used to
    /// extract the compressor tree's per-column profile that drives CPA
    /// optimization (Figure 1 of the paper).
    pub fn arrival_profile(&self, nl: &Netlist, groups: &[Vec<NodeId>]) -> Vec<f64> {
        let at = self.arrivals_ns(nl);
        groups
            .iter()
            .map(|g| g.iter().map(|id| at[id.index()]).fold(0.0f64, f64::max))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Netlist;

    fn xor_chain(n: usize) -> Netlist {
        let mut nl = Netlist::new("xorchain");
        let mut prev = nl.input("i0");
        for k in 1..=n {
            let i = nl.input(format!("i{k}"));
            prev = nl.xor2(prev, i);
        }
        nl.output("o", prev);
        nl
    }

    #[test]
    fn delay_scales_with_depth() {
        let sta = Sta::default();
        let d4 = sta.analyze(&xor_chain(4)).critical_delay_ns;
        let d8 = sta.analyze(&xor_chain(8)).critical_delay_ns;
        assert!(d8 > d4 * 1.5, "d4={d4} d8={d8}");
    }

    #[test]
    fn input_arrival_offsets_propagate() {
        let mut nl = Netlist::new("arr");
        let a = nl.input_at("a", 1.0);
        let b = nl.input("b");
        let o = nl.xor2(a, b);
        nl.output("o", o);
        let sta = Sta::default();
        let rep = sta.analyze(&nl);
        assert!(rep.critical_delay_ns > 1.0);
        assert!(rep.critical_delay_ns < 1.2);
    }

    #[test]
    fn fanout_increases_delay() {
        // The same XOR driving 8 loads must be slower than driving 1 —
        // the premise of the paper's FDC model.
        let build = |fanout: usize| {
            let mut nl = Netlist::new("f");
            let a = nl.input("a");
            let b = nl.input("b");
            let x = nl.xor2(a, b);
            let mut last = x;
            for _ in 0..fanout {
                last = nl.inv(x);
            }
            nl.output("o", last);
            let _ = last;
            nl
        };
        let sta = Sta::default();
        let a1 = sta.arrivals_ns(&build(1));
        let a8 = sta.arrivals_ns(&build(8));
        // arrival at the XOR output node (index 2) grows with fanout
        assert!(a8[2] > a1[2]);
    }

    #[test]
    fn wns_sign_convention() {
        let rep = StaReport {
            critical_delay_ns: 1.5,
            area_um2: 0.0,
            power_mw: 0.0,
            output_arrivals_ns: vec![],
            num_gates: 0,
            depth: 0,
        };
        assert!(rep.wns_ns(1.0) < 0.0); // 1 GHz clock missed
        assert!(rep.wns_ns(2.0) > 0.0);
    }

    #[test]
    fn power_positive_and_activity_sensitive() {
        let nl = xor_chain(16);
        let sta = Sta::default();
        let p = sta.dynamic_power_mw(&nl);
        assert!(p > 0.0);
        let fast = Sta { activity_rounds: 0, ..Sta::default() };
        assert!(fast.dynamic_power_mw(&nl) > 0.0);
    }
}
