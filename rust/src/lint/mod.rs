//! Static analysis over netlists and datapath build evidence.
//!
//! A staged lint engine with catalogued diagnostic codes (`LINTS.md`):
//!
//! - **`UFO0xx` structural** ([`structural`]) — passes over the flat SoA
//!   [`crate::ir::Netlist`] + its cached CSR topology: cycles/forward
//!   references, dangling fanins and outputs, multiply-defined output
//!   names, opcode corruption, and (pedantic) dead / constant-foldable /
//!   duplicate gates.
//! - **`UFO1xx` datapath** ([`datapath`]) — domain-aware checks over the
//!   evidence a build records ([`crate::multiplier::DatapathTrace`]):
//!   per-stage column weight conservation, the ≤2-row final CT
//!   requirement, compressor-count consistency against Algorithm 1
//!   (`ct/counts.rs`), and prefix-graph coverage/contiguity.
//! - **`UFO2xx` timing** ([`datapath`]) — recorded-profile sanity and the
//!   separate-MAC second-CPA arrival cross-check (the PR-3 bug class,
//!   detected statically).
//! - **`UFO3xx` sequential** ([`sequential`]) — register-pin reference
//!   integrity under the sequential rules (forward data is feedback, not
//!   a cycle), unclocked-register detection, and (pedantic) pipeline
//!   stage-balance analysis.
//! - **`UFO4xx` semantic** (emitted by [`crate::analysis`], catalogued
//!   here) — proof-backed findings from bit-level abstract
//!   interpretation: proven-constant outputs, dead registers, stuck
//!   enables, unreachable carries and word-level weight-conservation
//!   violations.
//!
//! Entry points: [`lint_netlist`] for a bare netlist, [`lint_design`] for
//! a built design plus its trace. The engine
//! ([`crate::api::SynthEngine`]) runs [`lint_design`] on every uncached
//! compile and stores the [`LintReport`] on the artifact; `ufo-mac lint`
//! and the server's `lint` command surface the same reports. The cheap
//! subset ([`check_counts`], [`check_plan`]) is always on inside the
//! RL-MUL / ILP candidate-evaluation loops.
#![forbid(unsafe_code)]

pub mod datapath;
pub mod report;
pub mod sequential;
pub mod structural;

pub use datapath::{
    check_counts, check_final_rows, check_mac_profile, check_plan, check_plan_counts,
    check_prefix, check_stage_profiles, ARRIVAL_EPS_NS,
};
pub use report::{
    code_info, CodeInfo, Diagnostic, LintOptions, LintReport, Locus, Severity, CODES, UFO401,
    UFO402, UFO403, UFO404, UFO405,
};
pub use sequential::{pass_registers, pass_stage_balance};
pub use structural::lint_netlist;

use crate::ir::CellLib;
use crate::multiplier::{DatapathTrace, Design};

/// Lint a built design: the structural netlist passes, plus every
/// datapath/timing pass the build evidence supports.
///
/// `trace` is the build's own record (from
/// [`crate::multiplier::MultiplierSpec::build_with_trace`]); without it
/// only the structural passes run (the
/// situation for designs rehydrated from the disk cache). `lib` must be
/// the cell library the design was built against — the separate-MAC
/// cross-check re-runs STA with it to compare arrivals exactly.
pub fn lint_design(
    design: &Design,
    trace: Option<&DatapathTrace>,
    lib: &CellLib,
    opts: &LintOptions,
) -> LintReport {
    let mut diags = structural::lint_netlist(&design.netlist, opts);
    if let Some(tr) = trace {
        match &tr.counts {
            Some(c) => diags.extend(datapath::check_plan_counts(c, &tr.plan)),
            None => diags.extend(datapath::check_plan(&tr.initial_pops, &tr.plan)),
        }
        diags.extend(datapath::check_stage_profiles(&tr.stage_profiles));
        diags.extend(datapath::check_final_rows(&tr.final_rows));
        diags.extend(datapath::check_prefix(&tr.prefix));
        if let Some(g2) = &tr.prefix2 {
            diags.extend(datapath::check_prefix(g2));
        }
        if let Some(mac) = tr.mac.as_ref().filter(|_| design.pipeline.is_none()) {
            // Re-derive the first CPA's sum arrivals from the final
            // netlist: recorded arrivals may only be ≤ these (the second
            // CPA added load), and the synthesis basis must cover them.
            // Skipped for pipelined designs: the trace's node ids refer to
            // the pre-pipeline netlist and do not survive the rebuild, so
            // the re-derived arrivals would compare the wrong nodes.
            let sta = crate::sta::Sta { activity_rounds: 0, ..crate::sta::Sta::with_lib(lib.clone()) };
            let at = sta.arrivals_ns(&design.netlist);
            let recomputed: Vec<f64> =
                mac.sum_nodes.iter().map(|id| at[id.index()]).collect();
            diags.extend(datapath::check_mac_profile(&mac.measured, &mac.basis, &recomputed));
        }
    }
    LintReport::from_diagnostics(diags)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::MultiplierSpec;
    use crate::synth::CompressorTiming;

    #[test]
    fn built_designs_lint_clean_with_full_evidence() {
        let lib = CellLib::nangate45();
        let tm = CompressorTiming::from_lib(&lib);
        for spec in [
            MultiplierSpec::new(4),
            MultiplierSpec::new(4).separate_mac(true),
            MultiplierSpec::new(3).fused_mac(true),
        ] {
            let (design, trace) = spec.build_with_trace(&lib, &tm).unwrap();
            let report = lint_design(&design, Some(&trace), &lib, &LintOptions::default());
            assert!(report.is_clean(), "{spec:?}: {report}");
        }
    }

    #[test]
    fn pipelined_designs_lint_clean_with_full_evidence() {
        let lib = CellLib::nangate45();
        let tm = CompressorTiming::from_lib(&lib);
        for spec in [
            MultiplierSpec::new(4).pipeline_stages(2),
            MultiplierSpec::new(3).fused_mac(true).pipeline_stages(2),
            MultiplierSpec::new(4).separate_mac(true).pipeline_stages(1),
        ] {
            let (design, trace) = spec.build_with_trace(&lib, &tm).unwrap();
            let report = lint_design(&design, Some(&trace), &lib, &LintOptions::default());
            assert!(report.is_clean(), "{spec:?}: {report}");
        }
    }

    #[test]
    fn tampered_trace_is_detected() {
        let lib = CellLib::nangate45();
        let tm = CompressorTiming::from_lib(&lib);
        let (design, mut trace) =
            MultiplierSpec::new(4).separate_mac(true).build_with_trace(&lib, &tm).unwrap();
        // Simulate the PR-3 bug: pretend the second CPA was synthesized
        // against a uniform-zero profile.
        for b in trace.mac.as_mut().unwrap().basis.iter_mut() {
            *b = 0.0;
        }
        let report = lint_design(&design, Some(&trace), &lib, &LintOptions::default());
        assert!(report.diagnostics.iter().any(|d| d.code == "UFO201"), "{report}");
    }
}
