"""Layer-1 Pallas kernel: output-stationary systolic-array MAC step.

Emulates the paper's 16×16 processing-element array (§5.3, Table 2): each
PE holds an accumulator and performs one fused multiply-accumulate per
cycle — exactly the datapath of the fused MAC the Rust generator builds in
gates. The kernel computes ``C += A @ B`` as `K` rank-1 MAC waves, the
dataflow an output-stationary array executes, with exact integer
arithmetic (int8/int16 operands, int32 accumulation).

TPU mapping (DESIGN.md §Hardware-Adaptation): the (16, 16) accumulator
tile lives in VMEM; the K-loop is a `fori_loop` whose body is the rank-1
MXU-feedable update. ``interpret=True`` executes the identical structure
on CPU for correctness and for the PJRT-driven example workload.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Array geometry (the paper's systolic arrays are 16×16 PEs).
PES = 16
# Workload depth per execution (columns of A / rows of B streamed through).
K_STEPS = 64


def _kernel(a_ref, b_ref, c_ref, out_ref):
    a = a_ref[...]                     # [PES, K] int32 (int8/int16-range)
    b = b_ref[...]                     # [K, PES]
    acc0 = c_ref[...]                  # [PES, PES] int32

    def step(k, acc):
        # One systolic wave: every PE(i,j) does acc += a[i,k] * b[k,j].
        col = jax.lax.dynamic_slice(a, (0, k), (PES, 1))   # [PES, 1]
        row = jax.lax.dynamic_slice(b, (k, 0), (1, PES))   # [1, PES]
        return acc + col * row

    out_ref[...] = jax.lax.fori_loop(0, a.shape[1], step, acc0)


@jax.jit
def systolic_mac(a, b, c):
    """C + A@B on the 16×16 output-stationary array.

    Operands travel as int32 (the PJRT bridge's narrowest integer literal)
    but carry int8/int16-range values — the Rust caller enforces the range
    contract of the hardware variant it is modelling; arithmetic is exact
    either way.

    Args:
      a: int32 [PES, K_STEPS] west-edge operand stream.
      b: int32 [K_STEPS, PES] north-edge operand stream.
      c: int32 [PES, PES] resident accumulators.

    Returns:
      int32 [PES, PES] updated accumulators.
    """
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((PES, PES), jnp.int32),
        interpret=True,
    )(a, b, c)
