//! Sequential lint passes over registered netlists (`UFO3xx` codes).
//!
//! Registers relax the IR's append-only ordering in exactly one place —
//! the data pin may reference forward (that *is* sequential feedback) —
//! so the structural reference pass skips `OP_REG` nodes and this module
//! re-checks every register pin under the sequential rules instead:
//!
//! - [`UFO302`]: `en`/`clr` must be strictly earlier nodes. A forward or
//!   self reference there is a combinational cycle through the register's
//!   control path, which no two-phase clocked evaluation can order.
//! - [`UFO002`]: any pin past the end of the netlist dangles, exactly as
//!   for gate fanins.
//! - [`UFO301`]: an enable tied to constant 0 means the register can
//!   never capture data — it is a reset-value generator, almost certainly
//!   a miswired pipeline control.
//! - [`UFO303`] (pedantic): the combinational segments between register
//!   ranks are wildly uneven, so the clock period is set by one deep
//!   segment while others idle — the cut placement is wasting registers.

use crate::ir::{Netlist, OP_CONST0, OP_REG};

use super::report::{Diagnostic, Locus, UFO002, UFO301, UFO302, UFO303};

/// Reference and clocking integrity of every register node. Returns
/// findings in node order; empty for combinational netlists.
pub fn pass_registers(nl: &Netlist) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let ops = nl.ops();
    let fanin = nl.fanin_records();
    let n = nl.len();
    for i in 0..n {
        if ops[i] != OP_REG {
            continue;
        }
        let [d, en, clr] = fanin[i];
        for (pin, f) in [("d", d), ("en", en), ("clr", clr)] {
            if f as usize >= n {
                diags.push(Diagnostic::new(
                    UFO002,
                    Locus::Node(i as u32),
                    format!("register {i} pin '{pin}' dangles (points at {f}, netlist has {n} nodes)"),
                ));
            }
        }
        // The data pin may legally point forward (feedback); the control
        // pins may not — their values gate this very edge's update.
        for (pin, f) in [("en", en), ("clr", clr)] {
            if (f as usize) < n && f as usize >= i {
                diags.push(Diagnostic::new(
                    UFO302,
                    Locus::Node(i as u32),
                    format!("register {i} pin '{pin}' references node {f}: control must be a strictly earlier node (combinational loop through the register)"),
                ));
            }
        }
        if (en as usize) < n && ops[en as usize] == OP_CONST0 {
            diags.push(Diagnostic::new(
                UFO301,
                Locus::Node(i as u32),
                format!("register {i} enable is tied to constant 0; it can never capture data"),
            ));
        }
    }
    diags
}

/// Pipeline stage balance ([`UFO303`], pedantic): compare the
/// combinational depth feeding every register's data pin (its *segment* —
/// registers restart the depth count, mirroring STA arrivals). A register
/// whose segment is less than half the deepest segment is flagged: the
/// clock period is set by the deep segment while this rank's slack idles.
///
/// Registers whose data pin is another register (back-to-back ranks over
/// a zero-depth net) are skipped — retiming staging like that is a
/// legitimate latency-matching idiom, not an imbalance.
///
/// Only meaningful on reference-clean netlists (the caller gates on the
/// reference passes, like every topology-dependent pass).
pub fn pass_stage_balance(nl: &Netlist) -> Vec<Diagnostic> {
    if !nl.is_sequential() {
        return Vec::new();
    }
    let topo = nl.topology();
    let depths = topo.depths();
    let ops = nl.ops();
    let segments: Vec<(usize, u32)> = nl
        .registers()
        .iter()
        .map(|&(r, _)| (r as usize, nl.fanin_records()[r as usize][0] as usize))
        .filter(|&(_, d)| ops[d] != OP_REG)
        .map(|(r, d)| (r, depths[d]))
        .collect();
    let Some(&(_, max_seg)) = segments.iter().max_by_key(|&&(_, s)| s) else {
        return Vec::new();
    };
    let mut diags = Vec::new();
    if max_seg < 2 {
        return diags;
    }
    for &(r, seg) in &segments {
        if seg * 2 < max_seg {
            diags.push(Diagnostic::new(
                UFO303,
                Locus::Node(r as u32),
                format!(
                    "register {r} closes a {seg}-deep combinational segment while the deepest segment is {max_seg}: the stage cut is imbalanced"
                ),
            ));
        }
    }
    diags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::{lint_netlist, LintOptions};

    fn codes(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.code).collect()
    }

    #[test]
    fn clean_pipeline_register_has_no_findings() {
        let mut nl = Netlist::new("seq_clean");
        let a = nl.input("a");
        let en = nl.input("en");
        let clr = nl.input("clr");
        let q = nl.reg(a, en, clr, false);
        nl.output("q", q);
        nl.validate().unwrap();
        assert!(lint_netlist(&nl, &LintOptions { pedantic: true }).is_empty());
    }

    #[test]
    fn feedback_through_the_data_pin_is_legal() {
        let mut nl = Netlist::new("seq_fb");
        let en = nl.input("en");
        let clr = nl.input("clr");
        let q = nl.reg_raw(0, en.0, clr.0, false);
        let nq = nl.inv(q);
        nl.set_reg_data(q, nq);
        nl.output("q", q);
        nl.validate().unwrap();
        assert!(lint_netlist(&nl, &LintOptions::default()).is_empty());
    }

    #[test]
    fn forward_control_pin_is_a_loop() {
        let mut nl = Netlist::new("seq_loop");
        let a = nl.input("a");
        let clr = nl.input("clr");
        // Enable points at the register itself: the edge's own update
        // gates the edge.
        let q = nl.reg_raw(a.0, 2, clr.0, false);
        nl.output("q", q);
        assert_eq!(codes(&pass_registers(&nl)), [UFO302]);
    }

    #[test]
    fn dangling_register_pins_are_reported_per_pin() {
        let mut nl = Netlist::new("seq_dangle");
        let _a = nl.input("a");
        let q = nl.reg_raw(99, 98, 0, false);
        nl.output("q", q);
        // d and en dangle (two UFO002); en also fails the earlier-node
        // rule only when in bounds, so no UFO302 piles on.
        assert_eq!(codes(&pass_registers(&nl)), [UFO002, UFO002]);
    }

    #[test]
    fn const0_enable_is_unclocked() {
        let mut nl = Netlist::new("seq_unclocked");
        let a = nl.input("a");
        let zero = nl.constant(false);
        let clr = nl.input("clr");
        let q = nl.reg(a, zero, clr, true);
        nl.output("q", q);
        nl.validate().unwrap();
        assert_eq!(codes(&pass_registers(&nl)), [UFO301]);
    }

    #[test]
    fn uneven_stage_cuts_are_pedantic_info() {
        let mut nl = Netlist::new("seq_imbalance");
        let a = nl.input("a");
        let b = nl.input("b");
        let en = nl.input("en");
        let clr = nl.input("clr");
        // Deep segment: a 6-gate XOR chain into one register.
        let mut deep = a;
        for _ in 0..6 {
            deep = nl.xor2(deep, b);
        }
        let q_deep = nl.reg(deep, en, clr, false);
        // Shallow segment: a single gate into another register.
        let shallow = nl.and2(a, b);
        let q_shallow = nl.reg(shallow, en, clr, false);
        let y = nl.or2(q_deep, q_shallow);
        nl.output("y", y);
        nl.validate().unwrap();
        let non_pedantic = lint_netlist(&nl, &LintOptions::default());
        assert!(non_pedantic.is_empty(), "{non_pedantic:?}");
        let diags = pass_stage_balance(&nl);
        assert_eq!(codes(&diags), [UFO303]);
        assert_eq!(diags[0].locus, Locus::Node(q_shallow.0));
    }

    #[test]
    fn balanced_ranks_and_register_chains_stay_quiet() {
        let mut nl = Netlist::new("seq_balanced");
        let a = nl.input("a");
        let b = nl.input("b");
        let en = nl.input("en");
        let clr = nl.input("clr");
        let s1 = nl.xor2(a, b);
        let q1 = nl.reg(s1, en, clr, false);
        // Latency-matching chain: q2's data pin is a register — exempt.
        let q2 = nl.reg(q1, en, clr, false);
        let s2 = nl.xor2(q2, b);
        let q3 = nl.reg(s2, en, clr, false);
        nl.output("y", q3);
        nl.validate().unwrap();
        assert!(pass_stage_balance(&nl).is_empty());
    }
}
