"""Layer-2 JAX evaluation workloads (build-time only).

Two compute graphs, both calling the Layer-1 Pallas kernels, AOT-lowered
to HLO text by :mod:`compile.aot` and executed from Rust via PJRT:

* :func:`verify_netlist` — functional-verification workload: evaluate an
  encoded gate netlist on 256 packed random vectors (8 uint32 words × 32
  lanes per input).
* :func:`systolic_workload` — the 16×16 output-stationary systolic GEMM
  tile (fused-MAC semantics) used by the end-to-end example to stream a
  real int8 workload through the architecture the generated MAC hardware
  implements.

Nothing in this module runs at request time; the Rust coordinator loads
the lowered artifacts once and feeds them concrete buffers.
"""

import jax.numpy as jnp

from .kernels import netlist_eval as ne
from .kernels import systolic as sy


def verify_netlist(ops, f0, f1, f2, words, *, size="small"):
    """Evaluate every node of the encoded netlist on packed vectors.

    Returns the full node-value buffer; the Rust side extracts the output
    slots it cares about (it knows the node indices).
    """
    return (ne.netlist_eval(ops, f0, f1, f2, words, size=size),)


def systolic_workload(a, b, c):
    """One 16×16×K_STEPS fused-MAC tile: ``C + A @ B`` (int32 exact)."""
    return (sy.systolic_mac(a, b, c),)


def example_args(kind, size="small"):
    """Shape/dtype specs used for AOT lowering."""
    if kind == "netlist":
        max_nodes, max_inputs = ne.SIZES[size]
        i32 = lambda n: jnp.zeros((n,), jnp.int32)  # noqa: E731
        return (
            i32(max_nodes),
            i32(max_nodes),
            i32(max_nodes),
            i32(max_nodes),
            jnp.zeros((ne.BATCH, max_inputs), jnp.uint32),
        )
    if kind == "systolic":
        return (
            jnp.zeros((sy.PES, sy.K_STEPS), jnp.int32),
            jnp.zeros((sy.K_STEPS, sy.PES), jnp.int32),
            jnp.zeros((sy.PES, sy.PES), jnp.int32),
        )
    raise ValueError(f"unknown artifact kind {kind}")
