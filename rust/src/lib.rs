//! # UFO-MAC — Unified Framework for Optimization of Multipliers and MACs
//!
//! A full reproduction of *"UFO-MAC: A Unified Framework for Optimization of
//! High-Performance Multipliers and Multiply-Accumulators"* (Zuo et al.,
//! ICCAD 2024), built as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the arithmetic-synthesis framework: partial
//!   product generation, optimal compressor trees with ILP stage assignment
//!   and interconnect-order optimization, non-uniform-arrival CPA synthesis
//!   with the FDC timing model, fused MACs, baselines (GOMIL, RL-MUL,
//!   commercial-IP proxy), a from-scratch MILP solver, a gate-level netlist
//!   IR with logical-effort STA, equivalence checking, functional modules
//!   (FIR filter, systolic array) and a design-space-exploration coordinator.
//! - **Layer 2 (python/compile/model.py)** — JAX evaluation workloads
//!   (batched netlist functional verification, systolic-array GEMM).
//! - **Layer 1 (python/compile/kernels/)** — Pallas kernels for those
//!   workloads, AOT-lowered to HLO text and executed from Rust via PJRT
//!   (`runtime` module). Python never runs on the request path.
//!
//! ## Quickstart
//!
//! Everything compiles through one path: describe *what* you want as a
//! [`api::DesignRequest`], hand it to a [`api::SynthEngine`], get back an
//! `Arc<`[`api::DesignArtifact`]`>` — netlist, STA report, verification
//! status. The engine owns the shared cell library, timing models and STA,
//! and keeps a content-addressed cache keyed by the request's canonical
//! fingerprint, so identical requests (DSE sweeps, Pareto studies,
//! repeated module instantiation) are synthesized exactly once.
//!
//! ```no_run
//! use ufo_mac::api::{DesignRequest, EngineConfig, SynthEngine};
//! use ufo_mac::baselines::Method;
//! use ufo_mac::multiplier::Strategy;
//!
//! // One engine per process (or use the global one behind the legacy API).
//! let engine = SynthEngine::new(EngineConfig {
//!     verify_vectors: 1 << 10, // simulator equivalence per design
//!     ..EngineConfig::default()
//! });
//!
//! // Single design.
//! let art = engine.compile(&DesignRequest::multiplier(8))?;
//! assert_eq!(art.verified, Some(true));
//! println!("{:.4} ns / {:.1} µm²", art.sta.critical_delay_ns, art.sta.area_um2);
//!
//! // Batch fan-out over the thread pool; duplicates hit the cache.
//! let grid: Vec<_> = [8usize, 16]
//!     .into_iter()
//!     .flat_map(|n| {
//!         Method::ALL.into_iter().map(move |m| {
//!             DesignRequest::method(m, n, Strategy::TradeOff, false)
//!         })
//!     })
//!     .collect();
//! let artifacts = engine.compile_batch(&grid);
//! println!("cache: {:?}", engine.cache_stats());
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! The pre-engine constructors (`MultiplierSpec::build`,
//! `baselines::build_design`, `modules::{fir_report,systolic_report}`,
//! `coordinator::evaluate_point`) remain as thin shims over the
//! process-global engine — see the [`api`] module docs for the mapping
//! from each legacy entry point to its request form.
//!
//! For long-lived use, the [`server`] module wraps an engine in a
//! newline-delimited-JSON compile service (`ufo-mac serve`) whose cache
//! persists across restarts when the engine is built with
//! [`api::EngineConfig::cache_dir`] — see `PROTOCOL.md` for the wire
//! format and the on-disk cache layout.
//!
//! See `ARCHITECTURE.md` at the repository root for the module-by-module
//! map of the pipeline, including the incremental timing engine
//! ([`sta::IncrementalSta`]) and the parallel ILP search
//! ([`ilp::SolveOptions::threads`]).

#![warn(missing_docs)]
// The crate is unsafe-free except for one audited slice reinterpretation
// in `ir::Netlist::fanin_slice` (allowed locally); `lint` additionally
// forbids unsafe outright.
#![deny(unsafe_code)]

pub mod analysis;
pub mod api;
pub mod baselines;
pub mod coordinator;
pub mod cpa;
pub mod ct;
pub mod equiv;
pub mod ilp;
pub mod ir;
pub mod lint;
pub mod modules;
pub mod multiplier;
pub mod ppg;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod sta;
pub mod synth;

pub mod bench;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
