//! Design-space-exploration coordinator.
//!
//! Orchestrates the experiment sweeps behind the paper's Pareto plots and
//! tables: fan out (method × width × strategy) generation jobs over a
//! thread pool, evaluate each design with the STA engine (and optionally
//! verify it through the PJRT netlist-eval artifact), extract Pareto
//! frontiers, and persist JSON reports.

pub mod pool;

use crate::baselines::{build_design, BaselineBudget, Method};
use crate::multiplier::Strategy;
use crate::runtime::Runtime;
use crate::sta::Sta;
use crate::util::Json;
use crate::Result;
use std::path::Path;

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub method: Method,
    pub n: usize,
    pub strategy: Strategy,
    pub mac: bool,
    pub delay_ns: f64,
    pub area_um2: f64,
    pub power_mw: f64,
    pub num_gates: usize,
    pub ct_stages: usize,
    /// Simulator-based equivalence result.
    pub verified: bool,
    /// PJRT artifact cross-check (None if artifacts unavailable).
    pub pjrt_verified: Option<bool>,
}

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub widths: Vec<usize>,
    pub methods: Vec<Method>,
    pub strategies: Vec<Strategy>,
    pub mac: bool,
    pub workers: usize,
    pub budget: BaselineBudget,
    /// Sampled-equivalence vector budget for non-exhaustive widths.
    pub verify_vectors: usize,
    /// Cross-check through PJRT when artifacts exist.
    pub use_pjrt: bool,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            widths: vec![8, 16, 32],
            methods: Method::ALL.to_vec(),
            strategies: vec![
                Strategy::AreaDriven,
                Strategy::TimingDriven,
                Strategy::TradeOff,
            ],
            mac: false,
            workers: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
            budget: BaselineBudget::default(),
            verify_vectors: 1 << 12,
            use_pjrt: false,
        }
    }
}

/// Evaluate one (method, width, strategy) point.
pub fn evaluate_point(
    method: Method,
    n: usize,
    strategy: Strategy,
    mac: bool,
    budget: &BaselineBudget,
    verify_vectors: usize,
    rt: Option<&Runtime>,
) -> Result<DesignPoint> {
    let design = build_design(method, n, strategy, mac, budget)?;
    let sta = Sta::default();
    let rep = sta.analyze(&design.netlist);
    let equiv = crate::equiv::check_multiplier_with(&design, verify_vectors)?;
    let pjrt_verified = match rt {
        Some(rt) if rt.has_artifact("netlist_eval_small") => {
            crate::runtime::verify_design_pjrt(rt, &design, 1).ok()
        }
        _ => None,
    };
    Ok(DesignPoint {
        method,
        n,
        strategy,
        mac,
        delay_ns: rep.critical_delay_ns,
        area_um2: rep.area_um2,
        power_mw: rep.power_mw,
        num_gates: rep.num_gates,
        ct_stages: design.ct_stages,
        verified: equiv.passed,
        pjrt_verified,
    })
}

/// Run a full sweep in parallel.
pub fn run_sweep(cfg: &SweepConfig) -> Vec<DesignPoint> {
    let mut items = Vec::new();
    for &n in &cfg.widths {
        for &m in &cfg.methods {
            for &s in &cfg.strategies {
                items.push((m, n, s));
            }
        }
    }
    let mac = cfg.mac;
    let budget = cfg.budget;
    let vectors = cfg.verify_vectors;
    let use_pjrt = cfg.use_pjrt;
    pool::par_map(cfg.workers, items, move |(m, n, s)| {
        let rt = if use_pjrt {
            Runtime::new(crate::runtime::default_artifact_dir()).ok()
        } else {
            None
        };
        evaluate_point(m, n, s, mac, &budget, vectors, rt.as_ref())
    })
    .into_iter()
    .filter_map(|r| r.ok())
    .collect()
}

/// Indices of the (delay, area) Pareto frontier, sorted by delay.
pub fn pareto_front(points: &[DesignPoint]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        points[a]
            .delay_ns
            .partial_cmp(&points[b].delay_ns)
            .unwrap()
            .then(points[a].area_um2.partial_cmp(&points[b].area_um2).unwrap())
    });
    let mut front = Vec::new();
    let mut best_area = f64::INFINITY;
    for i in idx {
        if points[i].area_um2 < best_area - 1e-9 {
            best_area = points[i].area_um2;
            front.push(i);
        }
    }
    front
}

/// True iff `a` Pareto-dominates `b` (≤ in both, < in one).
pub fn dominates(a: &DesignPoint, b: &DesignPoint) -> bool {
    a.delay_ns <= b.delay_ns + 1e-12
        && a.area_um2 <= b.area_um2 + 1e-9
        && (a.delay_ns < b.delay_ns - 1e-12 || a.area_um2 < b.area_um2 - 1e-9)
}

/// Serialize points as a JSON report.
pub fn points_json(points: &[DesignPoint]) -> Json {
    Json::arr(
        points
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("method", Json::str(p.method.name())),
                    ("n", Json::num(p.n as f64)),
                    ("strategy", Json::str(format!("{:?}", p.strategy))),
                    ("mac", Json::Bool(p.mac)),
                    ("delay_ns", Json::num(p.delay_ns)),
                    ("area_um2", Json::num(p.area_um2)),
                    ("power_mw", Json::num(p.power_mw)),
                    ("num_gates", Json::num(p.num_gates as f64)),
                    ("ct_stages", Json::num(p.ct_stages as f64)),
                    ("verified", Json::Bool(p.verified)),
                    (
                        "pjrt_verified",
                        match p.pjrt_verified {
                            Some(v) => Json::Bool(v),
                            None => Json::Null,
                        },
                    ),
                ])
            })
            .collect(),
    )
}

/// Persist a JSON report under `dir`.
pub fn save_report(dir: impl AsRef<Path>, name: &str, json: &Json) -> Result<()> {
    std::fs::create_dir_all(dir.as_ref())?;
    let path = dir.as_ref().join(format!("{name}.json"));
    std::fs::write(&path, json.render())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evaluate_point_verifies_and_reports() {
        let p = evaluate_point(
            Method::UfoMac,
            8,
            Strategy::TradeOff,
            false,
            &BaselineBudget { rlmul_iters: 4, seed: 3 },
            1 << 10,
            None,
        )
        .unwrap();
        assert!(p.verified);
        assert!(p.delay_ns > 0.0 && p.area_um2 > 0.0);
    }

    #[test]
    fn sweep_covers_grid() {
        let cfg = SweepConfig {
            widths: vec![4],
            methods: vec![Method::UfoMac, Method::Gomil],
            strategies: vec![Strategy::TradeOff],
            mac: false,
            workers: 2,
            budget: BaselineBudget { rlmul_iters: 2, seed: 1 },
            verify_vectors: 256,
            use_pjrt: false,
        };
        let points = run_sweep(&cfg);
        assert_eq!(points.len(), 2);
        assert!(points.iter().all(|p| p.verified));
    }

    #[test]
    fn pareto_front_is_monotone() {
        let mk = |d: f64, a: f64| DesignPoint {
            method: Method::UfoMac,
            n: 8,
            strategy: Strategy::TradeOff,
            mac: false,
            delay_ns: d,
            area_um2: a,
            power_mw: 0.0,
            num_gates: 0,
            ct_stages: 0,
            verified: true,
            pjrt_verified: None,
        };
        let pts = vec![mk(1.0, 10.0), mk(2.0, 5.0), mk(1.5, 20.0), mk(3.0, 4.0), mk(0.5, 30.0)];
        let front = pareto_front(&pts);
        // Front: (0.5,30) (1.0,10) (2.0,5) (3.0,4); (1.5,20) dominated.
        assert_eq!(front.len(), 4);
        assert!(!front.contains(&2));
        // strictly decreasing area along increasing delay
        for w in front.windows(2) {
            assert!(pts[w[0]].delay_ns <= pts[w[1]].delay_ns);
            assert!(pts[w[0]].area_um2 > pts[w[1]].area_um2);
        }
    }

    #[test]
    fn dominates_semantics() {
        let mk = |d: f64, a: f64| DesignPoint {
            method: Method::UfoMac,
            n: 8,
            strategy: Strategy::TradeOff,
            mac: false,
            delay_ns: d,
            area_um2: a,
            power_mw: 0.0,
            num_gates: 0,
            ct_stages: 0,
            verified: true,
            pjrt_verified: None,
        };
        assert!(dominates(&mk(1.0, 1.0), &mk(2.0, 2.0)));
        assert!(dominates(&mk(1.0, 1.0), &mk(1.0, 2.0)));
        assert!(!dominates(&mk(1.0, 3.0), &mk(2.0, 2.0)));
        assert!(!dominates(&mk(1.0, 1.0), &mk(1.0, 1.0)));
    }

    #[test]
    fn report_serializes() {
        let p = evaluate_point(
            Method::Commercial,
            4,
            Strategy::AreaDriven,
            false,
            &BaselineBudget { rlmul_iters: 2, seed: 2 },
            256,
            None,
        )
        .unwrap();
        let j = points_json(&[p]);
        let s = j.render();
        assert!(s.contains("Commercial IP"));
        assert!(s.contains("delay_ns"));
    }
}
