//! Functional equivalence checking (the paper's ABC step, §5.1).
//!
//! Combinational designs are verified against the integer golden model:
//! exhaustively for small operand widths (formally complete), and with
//! structured + random vectors beyond that (corner patterns — all-zeros,
//! all-ones, walking ones, alternating masks — plus packed random lanes).
//! The PJRT-backed variant (netlist-eval artifact executed from the Rust
//! request path) lives in [`crate::runtime`] and is exercised by the
//! examples.
//!
//! ## Parallel sweeps (EXPERIMENTS.md §Perf)
//!
//! The vector stream is organized as an indexed sequence of 64-lane
//! batches whose contents depend only on the batch index — exhaustive
//! batches enumerate the operand space positionally, sampled batches
//! derive their RNG seed from the index. Workers on
//! [`crate::coordinator::pool::scoped_workers`] claim batch indices from
//! an atomic cursor, each with its own simulation buffers over one shared
//! zero-copy [`CompiledNetlist`]. Failure selection is **deterministic**:
//! the reported counterexample is the first failing lane of the
//! lowest-index failing batch, so every worker count (including 1)
//! reports the identical counterexample — pinned by
//! `rust/tests/ir_flat.rs`.
//!
//! ## Wide lanes
//!
//! [`EquivOptions::width`] selects the simulator lane width `W`: each
//! claimed unit of work is a **group of `W` consecutive plan batches**
//! (`[g·W, g·W + W)`), packed into one stride-`W` slab and evaluated in a
//! single wide sweep. The plan itself is untouched — batch `k`'s 64
//! vectors are the same for every `W` — and groups are scanned slot-by-
//! slot in plan order, with failures recorded under their *plan-batch*
//! index. The reported counterexample and vector count are therefore
//! byte-identical for every lane width and worker count (also pinned by
//! `rust/tests/ir_flat.rs`); `W` only sets how many batches amortize one
//! walk of the netlist.

use crate::coordinator::pool;
use crate::multiplier::Design;
use crate::sim::{self, wide_lane_value, ClockedSim, CompiledNetlist};
use crate::Result;
use anyhow::bail;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Outcome of an equivalence run.
#[derive(Debug, Clone)]
pub struct EquivReport {
    /// Whether every checked vector matched the golden model.
    pub passed: bool,
    /// Vectors simulated (on failure: the deterministic count up to and
    /// including the failing batch, independent of worker count).
    pub vectors: usize,
    /// Whether the whole input space was covered.
    pub exhaustive: bool,
    /// First failing `(a, b, c, got, want)` if any.
    pub counterexample: Option<(u128, u128, u128, u128, u128)>,
}

/// Knobs for an equivalence run.
#[derive(Debug, Clone, Copy)]
pub struct EquivOptions {
    /// Sampled-vector budget (ignored by exhaustive runs, which cover the
    /// whole space).
    pub budget: usize,
    /// Worker threads for the batch sweep. The counterexample and vector
    /// count are identical for every thread count; small runs (fewer than
    /// 8 batches) fall back to a single inline worker.
    pub threads: usize,
    /// Simulator lane width (one of [`crate::sim::SUPPORTED_WIDTHS`]):
    /// each worker evaluates `width` consecutive plan batches per wide
    /// sweep. Reports are byte-identical for every width — this is purely
    /// a throughput knob. Defaults to [`crate::sim::default_width`].
    pub width: usize,
}

impl Default for EquivOptions {
    fn default() -> Self {
        EquivOptions { budget: 1 << 14, threads: default_threads(), width: sim::default_width() }
    }
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(8)
}

/// Verify a multiplier/MAC design. Exhaustive when the total input space
/// `2^(bits)` is at most `2^20`; sampled otherwise (default budget),
/// sweeping batches in parallel across the available cores.
pub fn check_multiplier(design: &Design) -> Result<EquivReport> {
    check_multiplier_opts(design, &EquivOptions::default())
}

/// As [`check_multiplier`] with an explicit sampled-vector budget.
pub fn check_multiplier_with(design: &Design, budget: usize) -> Result<EquivReport> {
    check_multiplier_opts(design, &EquivOptions { budget, ..Default::default() })
}

/// Fully parameterized equivalence run.
///
/// Operand widths come from the design itself (`a`/`b`/`c` pin vectors),
/// so rectangular formats are swept over their own per-operand ranges, and
/// the golden model ([`Design::expected`]) applies the design's signedness.
pub fn check_multiplier_opts(design: &Design, opts: &EquivOptions) -> Result<EquivReport> {
    if design.pipeline.is_some() {
        return check_pipelined(design, opts);
    }
    let total_bits = design.a.len() + design.b.len() + design.c.len();
    let plan = if total_bits <= 20 {
        VectorPlan::exhaustive(design)
    } else {
        VectorPlan::sampled(design, opts.budget)
    };
    Ok(run_plan(design, &plan, opts.threads, opts.width))
}

/// Bounded sequential equivalence for a pipelined design: unroll the
/// clocked simulator over each vector batch and compare the
/// latency-shifted outputs against the combinational golden model
/// ([`Design::expected`]).
///
/// Reuses the same deterministic [`VectorPlan`] as the combinational
/// sweep (exhaustive when the operand space is at most `2^20`), so the
/// counterexample and vector count are worker-count independent. Each
/// batch is driven from reset with `pipe_en = 1, pipe_clr = 0` on every
/// lane, operands held for `latency + 1` cycles, and the product read
/// after the pipeline has filled — the bounded-unrolling model of
/// "the pipeline computes the same function, `k` cycles later".
/// Reset/stall/clear semantics are covered by `rust/tests/sequential.rs`
/// on top of this.
pub fn check_pipelined(design: &Design, opts: &EquivOptions) -> Result<EquivReport> {
    let Some(info) = design.pipeline.as_ref() else {
        bail!("check_pipelined on a combinational design '{}'", design.netlist.name);
    };
    let total_bits = design.a.len() + design.b.len() + design.c.len();
    if design.netlist.num_inputs() != total_bits + 2 {
        bail!(
            "pipelined design '{}' has {} inputs, want {} operand bits + en + clr",
            design.netlist.name,
            design.netlist.num_inputs(),
            total_bits
        );
    }
    let plan = if total_bits <= 20 {
        VectorPlan::exhaustive(design)
    } else {
        VectorPlan::sampled(design, opts.budget)
    };
    Ok(run_plan_clocked(design, &plan, opts.threads, opts.width, info.stages))
}

/// As [`check_pipelined`] with an explicit sampled-vector budget.
pub fn check_pipelined_with(design: &Design, budget: usize) -> Result<EquivReport> {
    check_pipelined(design, &EquivOptions { budget, ..Default::default() })
}

// -------------------------------------------------------------------
// Deterministic batch plan.
// -------------------------------------------------------------------

/// An indexed plan of 64-lane vector batches: batch `k`'s contents are a
/// pure function of `k`, which is what makes the parallel sweep
/// deterministic.
struct VectorPlan {
    exhaustive: bool,
    /// Total vectors when every batch runs (exhaustive space, or corners +
    /// padded random budget).
    total: usize,
    /// Number of batches (`ceil` of the per-phase vector counts by 64).
    batches: usize,
    /// Exhaustive enumeration dims (`b` and `c` spaces; `a` is the
    /// quotient).
    nb: u128,
    nc: u128,
    /// Sampled: precomputed corner triples (seed order preserved).
    corners: Vec<(u128, u128, u128)>,
    /// Sampled: batches covering `corners`.
    corner_batches: usize,
    /// Sampled: per-operand masks for random lanes.
    amask: u128,
    bmask: u128,
    cmask: u128,
}

impl VectorPlan {
    fn exhaustive(design: &Design) -> VectorPlan {
        let na = 1u128 << design.a.len() as u32;
        let nb = 1u128 << design.b.len() as u32;
        let nc = if design.c.is_empty() { 1u128 } else { 1u128 << design.c.len() as u32 };
        // total_bits <= 20 ⇒ the product fits comfortably in usize.
        let total = (na * nb * nc) as usize;
        VectorPlan {
            exhaustive: true,
            total,
            batches: total.div_ceil(64),
            nb,
            nc,
            corners: Vec::new(),
            corner_batches: 0,
            amask: 0,
            bmask: 0,
            cmask: 0,
        }
    }

    fn sampled(design: &Design, budget: usize) -> VectorPlan {
        let a_bits = design.a.len();
        let b_bits = design.b.len();
        let c_bits = design.c.len();
        let amask = (1u128 << a_bits) - 1;
        let bmask = (1u128 << b_bits) - 1;
        let cmask = if c_bits == 0 { 0 } else { (1u128 << c_bits) - 1 };
        // Corner vectors: boundary operands and walking ones, per operand.
        let mut corners = Vec::new();
        for &a in &corner_list(a_bits) {
            for &b in &corner_list(b_bits) {
                let c = (a.wrapping_mul(31) ^ b) & cmask;
                corners.push((a, b, c));
            }
        }
        let corner_batches = corners.len().div_ceil(64);
        let random_batches = budget.saturating_sub(corners.len()).div_ceil(64);
        VectorPlan {
            exhaustive: false,
            total: corners.len() + 64 * random_batches,
            batches: corner_batches + random_batches,
            nb: 0,
            nc: 0,
            corners,
            corner_batches,
            amask,
            bmask,
            cmask,
        }
    }

    /// Fill `out` with batch `k`'s vectors (at most 64).
    fn fill(&self, k: usize, out: &mut Vec<(u128, u128, u128)>) {
        out.clear();
        if self.exhaustive {
            let start = 64 * k;
            let end = (start + 64).min(self.total);
            for idx in start..end {
                let idx = idx as u128;
                let c = idx % self.nc;
                let rest = idx / self.nc;
                let b = rest % self.nb;
                let a = rest / self.nb;
                out.push((a, b, c));
            }
        } else if k < self.corner_batches {
            let start = 64 * k;
            let end = (start + 64).min(self.corners.len());
            out.extend_from_slice(&self.corners[start..end]);
        } else {
            // Random batch: the RNG stream is derived from the batch index,
            // never from worker identity or claim order.
            let j = (k - self.corner_batches) as u64;
            let mut rng = crate::util::Rng::seed_from_u64(
                0xE9E9 ^ (j + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            );
            for _ in 0..64 {
                let a = (u128::from(rng.next_u64()) << 64 | u128::from(rng.next_u64()))
                    & self.amask;
                let b = (u128::from(rng.next_u64()) << 64 | u128::from(rng.next_u64()))
                    & self.bmask;
                let c = (u128::from(rng.next_u64()) << 64 | u128::from(rng.next_u64()))
                    & self.cmask;
                out.push((a, b, c));
            }
        }
    }

    /// Vectors covered by batches `0..=k` — the deterministic `vectors`
    /// count reported when batch `k` fails.
    fn vectors_through(&self, k: usize) -> usize {
        if self.exhaustive {
            (64 * (k + 1)).min(self.total)
        } else if k < self.corner_batches {
            (64 * (k + 1)).min(self.corners.len())
        } else {
            self.corners.len() + 64 * (k + 1 - self.corner_batches)
        }
    }
}

/// Boundary operands and walking ones for one operand width.
fn corner_list(bits: usize) -> Vec<u128> {
    let mask = (1u128 << bits) - 1;
    let mut corners: Vec<u128> = vec![0, 1, mask, mask.saturating_sub(1), mask >> 1, (mask >> 1) + 1];
    for k in 0..bits {
        corners.push(1u128 << k);
        corners.push(mask ^ (1u128 << k));
    }
    corners.sort();
    corners.dedup();
    corners.retain(|&c| c <= mask);
    corners
}

/// Pack one batch of `(a, b, c)` triples into slot `slot` of a
/// stride-`width` input slab (zeroed by the caller). Inputs are created in
/// a-then-b-then-c order by the generators, so operands pack straight into
/// lane words; any trailing input words beyond the operand bits (the
/// pipelined netlists' `pipe_en`/`pipe_clr` control ordinals) are left for
/// the caller to set.
fn pack_operands_wide(
    design: &Design,
    slab: &mut [u64],
    width: usize,
    slot: usize,
    batch: &[(u128, u128, u128)],
) {
    let a_bits = design.a.len();
    let b_bits = design.b.len();
    let c_bits = design.c.len();
    for (lane, (a, b, c)) in batch.iter().enumerate() {
        let bit = 1u64 << lane;
        for k in 0..a_bits {
            if a >> k & 1 == 1 {
                slab[k * width + slot] |= bit;
            }
        }
        for k in 0..b_bits {
            if b >> k & 1 == 1 {
                slab[(a_bits + k) * width + slot] |= bit;
            }
        }
        for k in 0..c_bits {
            if c >> k & 1 == 1 {
                slab[(a_bits + b_bits + k) * width + slot] |= bit;
            }
        }
    }
}

/// Scan a completed wide sweep slot-by-slot in plan order and report the
/// first mismatching lane as `(plan_batch_offset, cex)` — the in-group
/// counterpart of the global minimum-failing-batch selection.
fn scan_group(
    design: &Design,
    view: &[u64],
    width: usize,
    batches: &[Vec<(u128, u128, u128)>],
) -> Option<(usize, (u128, u128, u128, u128, u128))> {
    for (w, batch) in batches.iter().enumerate() {
        for (lane, (a, b, c)) in batch.iter().enumerate() {
            let got = wide_lane_value(view, width, w, &design.product, lane as u32);
            let want = design.expected(*a, *b, *c);
            if got != want {
                return Some((w, (*a, *b, *c, got, want)));
            }
        }
    }
    None
}

/// Execute a plan with `threads` workers claiming **groups** of `width`
/// consecutive batch indices from an atomic cursor; each group is one wide
/// sweep. Any worker that finds a failure records `(plan_batch, cex)` and
/// lowers the shared fail bound; workers stop claiming groups past it. The
/// reported counterexample is the one from the minimum failing plan-batch
/// index, so the result is independent of both the worker count and the
/// lane width.
fn run_plan(design: &Design, plan: &VectorPlan, threads: usize, width: usize) -> EquivReport {
    let comp = CompiledNetlist::compile(&design.netlist);
    let threads = if plan.batches < 8 { 1 } else { threads.max(1).min(plan.batches) };
    let n_in = design.netlist.num_inputs();
    let next = AtomicUsize::new(0);
    let first_fail = AtomicUsize::new(usize::MAX);
    let failures: Mutex<Vec<(usize, (u128, u128, u128, u128, u128))>> = Mutex::new(Vec::new());
    pool::scoped_workers(threads, |_worker| {
        let mut buf: Vec<u64> = Vec::new();
        let mut slab: Vec<u64> = Vec::new();
        let mut batches: Vec<Vec<(u128, u128, u128)>> =
            (0..width).map(|_| Vec::with_capacity(64)).collect();
        loop {
            let g = next.fetch_add(1, Ordering::Relaxed);
            let base = g * width;
            // Group claims are monotonic, so every group at or below the
            // one holding a recorded failure has been claimed by some
            // worker; skipping groups whose batches all lie above the
            // current bound can never drop the minimum failing batch.
            if base >= plan.batches || base > first_fail.load(Ordering::Relaxed) {
                break;
            }
            let count = width.min(plan.batches - base);
            slab.clear();
            slab.resize(n_in * width, 0);
            for (w, b) in batches.iter_mut().enumerate().take(count) {
                plan.fill(base + w, b);
                pack_operands_wide(design, &mut slab, width, w, b);
            }
            for b in batches.iter_mut().skip(count) {
                b.clear();
            }
            comp.run_wide_into(width, &mut buf, &slab);
            if let Some((w, cex)) = scan_group(design, &buf, width, &batches[..count]) {
                first_fail.fetch_min(base + w, Ordering::Relaxed);
                failures.lock().unwrap().push((base + w, cex));
            }
        }
    });
    let failures = failures.into_inner().unwrap();
    match failures.into_iter().min_by_key(|&(k, _)| k) {
        Some((k, cex)) => EquivReport {
            passed: false,
            vectors: plan.vectors_through(k),
            exhaustive: plan.exhaustive,
            counterexample: Some(cex),
        },
        None => EquivReport {
            passed: true,
            vectors: plan.total,
            exhaustive: plan.exhaustive,
            counterexample: None,
        },
    }
}

/// Clocked twin of [`run_plan`]: the same atomic group cursor, shared
/// fail bound and minimum-failing-batch selection, with each worker
/// driving its own wide [`ClockedSim`] over the shared netlist — one
/// reset + `latency + 1` edges verifies `width` plan batches at once
/// (every slot's lanes are independent). Deterministic for every worker
/// count and lane width, exactly like the combinational sweep.
fn run_plan_clocked(
    design: &Design,
    plan: &VectorPlan,
    threads: usize,
    width: usize,
    latency: usize,
) -> EquivReport {
    let threads = if plan.batches < 8 { 1 } else { threads.max(1).min(plan.batches) };
    let total = design.a.len() + design.b.len() + design.c.len();
    let next = AtomicUsize::new(0);
    let first_fail = AtomicUsize::new(usize::MAX);
    let failures: Mutex<Vec<(usize, (u128, u128, u128, u128, u128))>> = Mutex::new(Vec::new());
    pool::scoped_workers(threads, |_worker| {
        let mut sim = ClockedSim::new_wide(&design.netlist, width);
        let mut slab: Vec<u64> = Vec::new();
        let mut batches: Vec<Vec<(u128, u128, u128)>> =
            (0..width).map(|_| Vec::with_capacity(64)).collect();
        loop {
            let g = next.fetch_add(1, Ordering::Relaxed);
            let base = g * width;
            if base >= plan.batches || base > first_fail.load(Ordering::Relaxed) {
                break;
            }
            let count = width.min(plan.batches - base);
            slab.clear();
            slab.resize((total + 2) * width, 0);
            for (w, b) in batches.iter_mut().enumerate().take(count) {
                plan.fill(base + w, b);
                pack_operands_wide(design, &mut slab, width, w, b);
                slab[total * width + w] = !0; // pipe_en: run every lane
                // pipe_clr stays 0: never clear
            }
            for b in batches.iter_mut().skip(count) {
                b.clear();
            }
            sim.reset();
            for _ in 0..latency {
                sim.step(&slab);
            }
            // The product was latched at edge `latency`; the next sweep's
            // pre-edge view exposes it.
            sim.step(&slab);
            if let Some((w, cex)) = scan_group(design, sim.values(), width, &batches[..count]) {
                first_fail.fetch_min(base + w, Ordering::Relaxed);
                failures.lock().unwrap().push((base + w, cex));
            }
        }
    });
    let failures = failures.into_inner().unwrap();
    match failures.into_iter().min_by_key(|&(k, _)| k) {
        Some((k, cex)) => EquivReport {
            passed: false,
            vectors: plan.vectors_through(k),
            exhaustive: plan.exhaustive,
            counterexample: Some(cex),
        },
        None => EquivReport {
            passed: true,
            vectors: plan.total,
            exhaustive: plan.exhaustive,
            counterexample: None,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::{MultiplierSpec, OperandFormat};

    #[test]
    fn passes_signed_rectangular_mac_exhaustive() {
        let d = MultiplierSpec::new_fmt(OperandFormat::signed_rect(3, 4))
            .fused_mac(true)
            .build()
            .unwrap();
        let r = check_multiplier(&d).unwrap();
        assert!(r.passed && r.exhaustive);
        assert_eq!(r.vectors, 1 << 14); // 3 + 4 + 7 bits
    }

    #[test]
    fn sampled_mode_per_operand_masks() {
        // 16×8 unsigned: 24 operand bits force the sampled path; per-operand
        // masks must keep b inside its own 8-bit range.
        let d = MultiplierSpec::new_fmt(OperandFormat::rect(16, 8)).build().unwrap();
        let r = check_multiplier_with(&d, 1024).unwrap();
        assert!(r.passed && !r.exhaustive);
    }

    #[test]
    fn passes_correct_small_multiplier() {
        let d = MultiplierSpec::new(4).build().unwrap();
        let r = check_multiplier(&d).unwrap();
        assert!(r.passed);
        assert!(r.exhaustive);
        assert_eq!(r.vectors, 256);
    }

    #[test]
    fn passes_correct_mac_exhaustive() {
        let d = MultiplierSpec::new(3).fused_mac(true).build().unwrap();
        let r = check_multiplier(&d).unwrap();
        assert!(r.passed && r.exhaustive);
        assert_eq!(r.vectors, 1 << 12); // 3+3+6 bits
    }

    #[test]
    fn sampled_mode_for_16bit() {
        let d = MultiplierSpec::new(16).build().unwrap();
        let r = check_multiplier_with(&d, 2048).unwrap();
        assert!(r.passed);
        assert!(!r.exhaustive);
        assert!(r.vectors >= 2048);
    }

    #[test]
    fn detects_injected_fault() {
        // Break the design by remapping one product bit to another node.
        let mut d = MultiplierSpec::new(4).build().unwrap();
        d.product[3] = d.product[4];
        let r = check_multiplier(&d).unwrap();
        assert!(!r.passed);
        let (a, b, c, got, want) = r.counterexample.unwrap();
        assert_eq!(got, {
            let _ = (a, b, c);
            got
        });
        assert_ne!(got, want);
    }

    #[test]
    fn exhaustive_enumeration_matches_nested_loops() {
        // The positional index → (a, b, c) decode must reproduce the
        // canonical a-outer/b-middle/c-inner order.
        let d = MultiplierSpec::new(3).fused_mac(true).build().unwrap();
        let plan = VectorPlan::exhaustive(&d);
        let mut expect = Vec::new();
        for a in 0..8u128 {
            for b in 0..8u128 {
                for c in 0..64u128 {
                    expect.push((a, b, c));
                }
            }
        }
        let mut got = Vec::new();
        let mut batch = Vec::with_capacity(64);
        for k in 0..plan.batches {
            plan.fill(k, &mut batch);
            got.extend_from_slice(&batch);
        }
        assert_eq!(got, expect);
        assert_eq!(plan.vectors_through(plan.batches - 1), plan.total);
    }

    fn build_pipelined(n: usize, stages: usize, fused: bool) -> Design {
        let lib = crate::ir::CellLib::nangate45();
        let tm = crate::synth::CompressorTiming::from_lib(&lib);
        let mut spec = MultiplierSpec::new(n).pipeline_stages(stages);
        if fused {
            spec = spec.fused_mac(true);
        }
        spec.build_with(&lib, &tm).unwrap()
    }

    #[test]
    fn pipelined_multiplier_exhaustive() {
        for stages in [1usize, 2, 3] {
            let d = build_pipelined(4, stages, false);
            let r = check_pipelined(&d, &EquivOptions::default()).unwrap();
            assert!(r.passed, "stages={stages}: cex {:?}", r.counterexample);
            assert!(r.exhaustive);
            assert_eq!(r.vectors, 256);
        }
    }

    #[test]
    fn pipelined_fused_mac_exhaustive() {
        let d = build_pipelined(3, 2, true);
        // The default entry point routes pipelined designs to the
        // clocked checker automatically.
        let r = check_multiplier(&d).unwrap();
        assert!(r.passed, "cex {:?}", r.counterexample);
        assert!(r.exhaustive);
        assert_eq!(r.vectors, 1 << 12);
    }

    #[test]
    fn pipelined_fault_detected() {
        let mut d = build_pipelined(4, 2, false);
        d.product[3] = d.product[4];
        let r = check_pipelined(&d, &EquivOptions::default()).unwrap();
        assert!(!r.passed);
        let (_, _, _, got, want) = r.counterexample.unwrap();
        assert_ne!(got, want);
    }

    #[test]
    fn check_pipelined_rejects_combinational() {
        let lib = crate::ir::CellLib::nangate45();
        let tm = crate::synth::CompressorTiming::from_lib(&lib);
        let d = MultiplierSpec::new(4).build_with(&lib, &tm).unwrap();
        assert!(check_pipelined(&d, &EquivOptions::default()).is_err());
    }

    #[test]
    fn fault_report_identical_across_widths() {
        let mut d = MultiplierSpec::new(4).build().unwrap();
        d.product[3] = d.product[4];
        let base = check_multiplier_opts(&d, &EquivOptions { budget: 1 << 10, threads: 1, width: 1 })
            .unwrap();
        assert!(!base.passed);
        for width in [2usize, 4, 8] {
            let r =
                check_multiplier_opts(&d, &EquivOptions { budget: 1 << 10, threads: 3, width })
                    .unwrap();
            assert_eq!(r.passed, base.passed, "width {width}");
            assert_eq!(r.vectors, base.vectors, "width {width}");
            assert_eq!(r.counterexample, base.counterexample, "width {width}");
        }
    }

    #[test]
    fn pipelined_fault_report_identical_across_widths() {
        let mut d = build_pipelined(4, 2, false);
        d.product[3] = d.product[4];
        let base =
            check_pipelined(&d, &EquivOptions { budget: 1 << 8, threads: 1, width: 1 }).unwrap();
        assert!(!base.passed);
        for width in [2usize, 4, 8] {
            let r =
                check_pipelined(&d, &EquivOptions { budget: 1 << 8, threads: 2, width }).unwrap();
            assert_eq!(r.vectors, base.vectors, "width {width}");
            assert_eq!(r.counterexample, base.counterexample, "width {width}");
        }
    }

    #[test]
    fn sampled_plan_is_batch_index_deterministic() {
        let d = MultiplierSpec::new(16).build().unwrap();
        let plan = VectorPlan::sampled(&d, 2048);
        let (mut b1, mut b2) = (Vec::new(), Vec::new());
        // Refilling any batch yields identical vectors (no shared RNG
        // state), including a corner batch and a random batch.
        for k in [0usize, plan.corner_batches, plan.batches - 1] {
            plan.fill(k, &mut b1);
            plan.fill(k, &mut b2);
            assert_eq!(b1, b2, "batch {k}");
            assert!(!b1.is_empty());
        }
        assert!(plan.total >= 2048);
    }
}
