//! Server smoke tests: the in-process serve loop (compile → identical
//! compile → stats), in-flight coalescing, the TCP transport, the
//! cross-engine disk tier, and a verbatim replay of every wire example in
//! `PROTOCOL.md`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use ufo_mac::api::{DesignRequest, EngineConfig, SynthEngine};
use ufo_mac::server::{compile_line, Server};
use ufo_mac::util::Json;

fn server() -> Server {
    Server::new(Arc::new(SynthEngine::new(EngineConfig::default())))
}

fn scratch(tag: &str) -> std::path::PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "ufo_server_{tag}_{}_{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn result_str<'a>(doc: &'a Json, key: &str) -> Option<&'a str> {
    doc.get("result").and_then(|r| r.get(key)).and_then(|s| s.as_str())
}

// ---------------------------------------------------------------------
// The serve loop end-to-end: compile → identical compile through the
// scheduled loop, then stats. Responses arrive in completion order and
// are correlated by id (a `stats` sent alongside would be answered
// *first* — it classifies urgent — so it is checked afterwards, where its
// counters are deterministic).
// ---------------------------------------------------------------------
#[test]
fn serve_loop_compile_hit_stats() {
    let srv = server();
    let req = DesignRequest::multiplier(6);
    let input = format!("{}\n{}\n", compile_line(1, &req), compile_line(2, &req));
    let mut out = Vec::new();
    srv.serve(input.as_bytes(), &mut out, 1).unwrap();
    let lines: Vec<Json> = String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| Json::parse(l).unwrap())
        .collect();
    assert_eq!(lines.len(), 2);
    // The first admitted compile always synthesizes; the identical second
    // one must hit the cache (same class → FIFO, so ids stay in order).
    assert_eq!(lines[0].get("id").unwrap().as_f64(), Some(1.0));
    assert_eq!(result_str(&lines[0], "source"), Some("compiled"));
    assert_eq!(lines[1].get("id").unwrap().as_f64(), Some(2.0));
    assert_eq!(
        result_str(&lines[1], "source"),
        Some("memory"),
        "the second identical request must be a cache hit"
    );
    let stats = Json::parse(&srv.handle_line(r#"{"cmd":"stats","id":3}"#)).unwrap();
    let cache = stats.get("result").unwrap().get("cache").unwrap();
    assert!(cache.get("hits").unwrap().as_f64().unwrap() >= 1.0);
    assert_eq!(cache.get("misses").unwrap().as_f64().unwrap(), 1.0);
    assert_eq!(stats.get("result").unwrap().get("served").unwrap().as_f64().unwrap(), 2.0);
}

// ---------------------------------------------------------------------
// Pipelined requests over the wire: the compile summary carries the
// pipeline object (stages / latency / registers); combinational
// responses carry an explicit null.
// ---------------------------------------------------------------------
#[test]
fn pipelined_compile_reports_pipeline_metadata() {
    use ufo_mac::multiplier::MultiplierSpec;
    let srv = server();
    let req = DesignRequest::from_spec(
        &MultiplierSpec::new(6).fused_mac(true).pipeline_stages(2),
    );
    let resp = Json::parse(&srv.handle_line(&compile_line(1, &req))).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
    let pipe = resp.get("result").unwrap().get("pipeline").unwrap();
    assert_eq!(pipe.get("stages").unwrap().as_f64(), Some(2.0), "{resp:?}");
    assert_eq!(pipe.get("latency").unwrap().as_f64(), Some(2.0), "{resp:?}");
    // The final rank alone registers every product bit (12 for 6×6 MAC).
    assert!(pipe.get("registers").unwrap().as_f64().unwrap() >= 12.0, "{resp:?}");

    let comb = Json::parse(&srv.handle_line(&compile_line(2, &DesignRequest::multiplier(6))))
        .unwrap();
    assert!(
        matches!(comb.get("result").unwrap().get("pipeline"), Some(Json::Null)),
        "combinational artifacts report pipeline: null, got {comb:?}"
    );
}

// ---------------------------------------------------------------------
// Coalescing: N simultaneous identical requests, exactly one synthesis.
// ---------------------------------------------------------------------
#[test]
fn simultaneous_identical_requests_coalesce_onto_one_compile() {
    let srv = server();
    let line = compile_line(1, &DesignRequest::multiplier(9));
    let n = 8;
    let barrier = Barrier::new(n);
    let sources = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for _ in 0..n {
            s.spawn(|| {
                barrier.wait();
                let resp = Json::parse(&srv.handle_line(&line)).unwrap();
                assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
                sources
                    .lock()
                    .unwrap()
                    .push(result_str(&resp, "source").unwrap().to_string());
            });
        }
    });
    let sources = sources.into_inner().unwrap();
    let compiled = sources.iter().filter(|s| *s == "compiled").count();
    assert_eq!(compiled, 1, "exactly one synthesis: {sources:?}");
    let stats = Json::parse(&srv.handle_line(r#"{"cmd":"stats","id":2}"#)).unwrap();
    let cache = stats.get("result").unwrap().get("cache").unwrap();
    let coalesced = sources.iter().filter(|s| *s == "coalesced").count() as f64;
    assert_eq!(cache.get("coalesced").unwrap().as_f64().unwrap(), coalesced, "{sources:?}");
    assert_eq!(cache.get("entries").unwrap().as_f64().unwrap(), 1.0);
    // Only the one real synthesis counts as a miss; coalesced waiters are
    // reclassified.
    assert_eq!(cache.get("misses").unwrap().as_f64().unwrap(), 1.0, "{sources:?}");
}

// ---------------------------------------------------------------------
// TCP transport: real sockets, shutdown closes the connection.
// ---------------------------------------------------------------------
#[test]
fn tcp_round_trip_and_shutdown() {
    use std::io::{BufRead, BufReader, Write};
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let srv = Arc::new(server());
    let accept = Arc::clone(&srv);
    // The listener loop runs forever; leave it detached (the process ends
    // with the test binary).
    std::thread::spawn(move || {
        let _ = accept.serve_listener(listener);
    });
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    writeln!(stream, "{}", compile_line(1, &DesignRequest::multiplier(4))).unwrap();
    writeln!(stream, "{}", r#"{"cmd":"shutdown","id":2}"#).unwrap();
    stream.flush().unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    let mut by_id = std::collections::HashMap::new();
    for line in reader.lines() {
        let Ok(line) = line else { break };
        let doc = Json::parse(&line).unwrap();
        let id = doc.get("id").unwrap().as_f64().unwrap() as u64;
        by_id.insert(id, doc);
        if by_id.len() == 2 {
            break;
        }
    }
    assert_eq!(result_str(&by_id[&1], "source"), Some("compiled"));
    assert_eq!(by_id[&2].get("ok").unwrap().as_bool(), Some(true));
}

// ---------------------------------------------------------------------
// Acceptance: a design compiled by one server is served from the disk
// cache by a *fresh* engine/server over the same directory — no
// recompute, visible in the stats hit counters.
// ---------------------------------------------------------------------
#[test]
fn fresh_server_serves_from_disk_without_recompute() {
    let dir = scratch("disk");
    let engine_at = || {
        Arc::new(SynthEngine::new(EngineConfig {
            cache_dir: Some(dir.clone()),
            ..EngineConfig::default()
        }))
    };
    let line = compile_line(1, &DesignRequest::multiplier(5));
    {
        let first = Server::new(engine_at());
        let resp = Json::parse(&first.handle_line(&line)).unwrap();
        assert_eq!(result_str(&resp, "source"), Some("compiled"));
    } // first server and engine dropped
    let second = Server::new(engine_at());
    let resp = Json::parse(&second.handle_line(&line)).unwrap();
    assert_eq!(result_str(&resp, "source"), Some("disk"), "{resp:?}");
    let stats = Json::parse(&second.handle_line(r#"{"cmd":"stats","id":2}"#)).unwrap();
    let cache = stats.get("result").unwrap().get("cache").unwrap();
    assert_eq!(cache.get("disk_hits").unwrap().as_f64().unwrap(), 1.0);
    assert_eq!(cache.get("misses").unwrap().as_f64().unwrap(), 0.0, "no recompute");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// Every documented wire example, replayed verbatim.
// ---------------------------------------------------------------------

fn obj_keys(j: &Json) -> Vec<String> {
    j.as_obj().map(|m| m.keys().cloned().collect()).unwrap_or_default()
}

/// The disk-format example in PROTOCOL.md (the one fence tagged plain
/// `json`) must match real entries: same envelope keys, same magic, same
/// version.
#[test]
fn protocol_md_disk_entry_example_matches_real_entries() {
    use ufo_mac::api::persist;
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../PROTOCOL.md");
    let text = std::fs::read_to_string(&path).unwrap();
    let mut example = None;
    let mut lines = text.lines();
    while let Some(line) = lines.next() {
        if line.trim() == "```json" {
            let mut body = String::new();
            for l in lines.by_ref() {
                if l.trim() == "```" {
                    break;
                }
                body.push_str(l);
                body.push('\n');
            }
            assert!(example.is_none(), "PROTOCOL.md should have exactly one disk-format example");
            example = Some(body);
        }
    }
    let documented = Json::parse(&example.expect("disk-format example present")).unwrap();

    let dir = scratch("entry_example");
    let engine = SynthEngine::new(EngineConfig {
        cache_dir: Some(dir.clone()),
        ..EngineConfig::default()
    });
    let art = engine.compile(&DesignRequest::multiplier(4)).unwrap();
    let entry = std::fs::read_to_string(persist::entry_path(&dir, art.fingerprint)).unwrap();
    let actual = Json::parse(&entry).unwrap();

    assert_eq!(obj_keys(&documented), obj_keys(&actual), "entry envelope keys");
    assert_eq!(
        actual.get("magic").unwrap().as_str(),
        documented.get("magic").unwrap().as_str()
    );
    assert_eq!(
        actual.get("version").unwrap().as_f64(),
        documented.get("version").unwrap().as_f64()
    );
    // The documented checksum/fingerprint are illustrative but must have
    // the real shape: 32 hex digits.
    for key in ["checksum", "fingerprint"] {
        for doc in [&documented, &actual] {
            let s = doc.get(key).unwrap().as_str().unwrap();
            assert_eq!(s.len(), 32, "{key} must be 32 hex digits, got '{s}'");
            assert!(s.chars().all(|c| c.is_ascii_hexdigit()), "{key}: '{s}'");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn protocol_md_examples_replay() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../PROTOCOL.md");
    let text = std::fs::read_to_string(&path).unwrap();
    // Collect (request, documented frames, documented response) triples in
    // document order. A ```json stream``` fence between a request and its
    // response documents the progress frames of a `"stream": true`
    // exchange, one NDJSON frame per line.
    let mut triples: Vec<(String, Vec<String>, String)> = Vec::new();
    let mut pending: Option<(String, Vec<String>)> = None;
    let mut lines = text.lines();
    while let Some(line) = lines.next() {
        let tag = line.trim();
        if tag != "```json request" && tag != "```json stream" && tag != "```json response" {
            continue;
        }
        let mut body = String::new();
        for l in lines.by_ref() {
            if l.trim() == "```" {
                break;
            }
            body.push_str(l);
            body.push('\n');
        }
        match tag {
            "```json request" => {
                assert!(pending.is_none(), "request block without a following response block");
                pending = Some((body.trim().to_string(), Vec::new()));
            }
            "```json stream" => {
                let p = pending.as_mut().expect("stream block without a preceding request");
                p.1.extend(body.trim().lines().map(str::to_string));
            }
            _ => {
                let (req, frames) =
                    pending.take().expect("response block without a preceding request");
                triples.push((req, frames, body));
            }
        }
    }
    assert!(pending.is_none(), "trailing request block without a response");
    assert!(
        triples.len() >= 12,
        "PROTOCOL.md should document ≥12 exchanges, found {}",
        triples.len()
    );
    assert!(
        triples.iter().any(|(_, frames, _)| !frames.is_empty()),
        "PROTOCOL.md should document at least one streamed exchange"
    );

    // One server replays the whole document in order, so the cache-state
    // progression (compiled → memory) matches the narrative.
    let srv = server();
    for (req, doc_frames, documented) in &triples {
        assert_eq!(req.lines().count(), 1, "wire requests are single NDJSON lines:\n{req}");
        let mut output = srv.handle_line_all(req);
        assert!(!output.is_empty(), "no output for {req}");
        let actual = Json::parse(&output.pop().unwrap())
            .unwrap_or_else(|e| panic!("unparsable response for {req}: {e}"));
        let documented = Json::parse(documented)
            .unwrap_or_else(|e| panic!("unparsable documented response for {req}: {e}"));

        // Progress frames: same count, same shape, never an envelope.
        assert_eq!(
            output.len(),
            doc_frames.len(),
            "frame count diverges for {req}: {output:?}"
        );
        for (af, df) in output.iter().zip(doc_frames) {
            let af = Json::parse(af).unwrap_or_else(|e| panic!("unparsable frame for {req}: {e}"));
            let df = Json::parse(df)
                .unwrap_or_else(|e| panic!("unparsable documented frame for {req}: {e}"));
            assert_eq!(obj_keys(&df), obj_keys(&af), "frame keys diverge for {req}");
            assert!(af.get("ok").is_none(), "frames must not carry 'ok' for {req}: {af:?}");
            assert_eq!(af.get("event").and_then(|e| e.as_str()), Some("progress"), "{req}");
            for key in ["done", "total"] {
                assert_eq!(
                    df.get(key).and_then(|v| v.as_f64()),
                    af.get(key).and_then(|v| v.as_f64()),
                    "frame '{key}' diverges for {req}"
                );
            }
            if let Some(ds) = df.get("source").and_then(|s| s.as_str()) {
                assert_eq!(
                    Some(ds),
                    af.get("source").and_then(|s| s.as_str()),
                    "frame source diverges for {req}"
                );
            }
        }

        assert_eq!(
            documented.get("ok").and_then(|b| b.as_bool()),
            actual.get("ok").and_then(|b| b.as_bool()),
            "ok flag diverges for {req}: {actual:?}"
        );
        assert_eq!(
            obj_keys(&documented),
            obj_keys(&actual),
            "envelope keys diverge for {req}"
        );
        let (doc_res, act_res) = (documented.get("result"), actual.get("result"));
        if let (Some(d @ Json::Obj(_)), Some(a)) = (doc_res, act_res) {
            assert_eq!(obj_keys(d), obj_keys(a), "result keys diverge for {req}");
            // When the doc pins a cache source (compiled vs memory), the
            // real server must reproduce it.
            if let Some(ds) = d.get("source").and_then(|s| s.as_str()) {
                assert_eq!(
                    Some(ds),
                    a.get("source").and_then(|s| s.as_str()),
                    "source diverges for {req}"
                );
            }
        }
    }
}
