//! END-TO-END DRIVER (AI-acceleration scenario, paper §5.3 / Table 2).
//!
//! Exercises the full three-layer stack on a real small workload, proving
//! all layers compose:
//!
//!   1. L3 generates an 8-bit UFO-MAC **fused MAC** gate netlist (the PE),
//!      verifies it in the Rust simulator, then cross-checks it through
//!      the **PJRT netlist-eval artifact** (L1 Pallas kernel, AOT-lowered).
//!   2. L3 reports the 16×16 systolic array's area/WNS/power per method
//!      (Table 2 shape).
//!   3. L3 streams a real int8 GEMM workload — synthetic image patches ×
//!      a fixed filter bank, the workload systolic arrays exist for —
//!      through the **PJRT systolic artifact** tile by tile from the Rust
//!      request loop (Python never runs here), cross-checks every tile
//!      against the integer golden GEMM, and reports latency/throughput.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `make artifacts && cargo run --release --example systolic_array`

use std::time::Instant;
use ufo_mac::baselines::Method;
use ufo_mac::modules::systolic::{build_pe, systolic_report};
use ufo_mac::multiplier::Strategy;
use ufo_mac::runtime::{self, Runtime, K_STEPS, PES};
use ufo_mac::util::Table;

fn main() -> ufo_mac::Result<()> {
    // ---- 1. Generate + verify the PE (fused MAC) ------------------------
    let pe = build_pe(Method::UfoMac, 8, Strategy::TradeOff)?;
    let equiv = ufo_mac::equiv::check_multiplier_with(&pe, 1 << 13)?;
    assert!(equiv.passed, "PE failed simulator equivalence");
    println!("PE (8-bit UFO-MAC fused MAC): simulator equivalence PASS ({} vectors)", equiv.vectors);

    let rt = Runtime::new(runtime::default_artifact_dir())?;
    if rt.has_artifact("netlist_eval_small") {
        let ok = runtime::verify_design_pjrt(&rt, &pe, 4)?;
        assert!(ok, "PE failed PJRT artifact equivalence");
        println!("PE: PJRT netlist-eval equivalence PASS (platform: {})", rt.platform());
    } else {
        println!("PJRT artifacts missing — run `make artifacts` first");
        return Ok(());
    }

    // ---- 2. Array-level hardware report (Table 2 shape) ----------------
    let mut table = Table::new(&["method", "WNS(ns)@1GHz", "area(µm²)", "power(mW)"]);
    for m in Method::ALL {
        let r = systolic_report(m, 8, Strategy::TradeOff, 1e9)?;
        table.row(vec![
            m.name().into(),
            format!("{:.4}", r.wns_ns),
            format!("{:.0}", r.area_um2),
            format!("{:.3}", r.power_mw),
        ]);
    }
    println!("\n16×16 systolic array, 8-bit PEs @ 1 GHz:\n{}", table.render());

    // ---- 3. Real workload through the PJRT systolic artifact -----------
    // Workload: 64 image patches (16×K each, int8, synthetic but
    // structured) times a fixed 16-filter bank, tiled to the array.
    let tiles = 64usize;
    let mut rng = ufo_mac::util::Rng::seed_from_u64(0xA11C);
    // filter bank: K_STEPS × PES, reused across tiles (weight-stationary
    // reuse pattern at the workload level).
    let filters: Vec<i32> = (0..K_STEPS * PES)
        .map(|i| ((i * 37) % 255) as i32 - 127)
        .collect();

    let mut total_macs = 0u64;
    let mut checked = 0usize;
    let t0 = Instant::now();
    for tile in 0..tiles {
        // "image patch": PES × K_STEPS int8 with smooth structure + noise.
        let patch: Vec<i32> = (0..PES * K_STEPS)
            .map(|i| {
                let base = ((i / K_STEPS) as f64 * 0.8 + (i % K_STEPS) as f64 * 0.15).sin();
                ((base * 90.0) as i32 + (rng.below(21) as i32 - 10)).clamp(-128, 127)
            })
            .collect();
        let acc = vec![0i32; PES * PES];
        let out = rt.systolic(&patch, &filters, &acc, 8)?;
        total_macs += (PES * PES * K_STEPS) as u64;
        // Golden integer GEMM cross-check on every tile.
        for i in 0..PES {
            for j in 0..PES {
                let want: i64 = (0..K_STEPS)
                    .map(|k| i64::from(patch[i * K_STEPS + k]) * i64::from(filters[k * PES + j]))
                    .sum();
                assert_eq!(i64::from(out[i * PES + j]), want, "tile {tile} ({i},{j})");
                checked += 1;
            }
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("workload: {tiles} tiles ({} MACs) in {:.3} s through PJRT", total_macs, dt);
    println!("  throughput: {:.2} M MAC/s (request-path, artifact-executed)", total_macs as f64 / dt / 1e6);
    println!("  mean tile latency: {:.3} ms", dt / tiles as f64 * 1e3);
    println!("  golden cross-check: {checked} outputs verified ✓");

    // Hardware-model projection: the generated array at its achieved clock.
    let r = systolic_report(Method::UfoMac, 8, Strategy::TimingDriven, 1e9)?;
    let f_max_ghz = 1.0 / (r.period_ns() - r.wns_ns);
    let hw_macs_per_s = f_max_ghz * 1e9 * (PES * PES) as f64;
    println!(
        "\nhardware projection: f_max ≈ {:.2} GHz ⇒ {:.1} G MAC/s for the generated array",
        f_max_ghz,
        hw_macs_per_s / 1e9
    );
    println!("END-TO-END OK");
    Ok(())
}
