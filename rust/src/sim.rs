//! Bit-parallel netlist simulation.
//!
//! Simulates a [`Netlist`] on packed input vectors by assigning one vector
//! per bit lane of a `u64` word — the classic "parallel pattern"
//! simulation trick. This is the engine behind equivalence checking
//! ([`crate::equiv`]) and the toggle-based dynamic-power estimate in
//! [`crate::sta`]; the same levelized evaluation is what the Pallas
//! `netlist_eval` kernel performs on the PJRT side with u32 lanes.
//!
//! ## Wide lanes (EXPERIMENTS.md §Perf)
//!
//! The kernel is lane-width-configurable: a node's value is a **block of
//! `W` consecutive `u64` words** (`W ∈ {1, 2, 4, 8}`, i.e. up to 512
//! vectors per sweep). All node values live in one contiguous slab with
//! stride `W` — node `i` occupies `slab[i*W .. (i+1)*W]`, and likewise for
//! the primary-input slab. The inner loop is monomorphized per width
//! ([`CompiledNetlist::run_wide_into`] dispatches to a `const W` kernel),
//! so each opcode's `W`-word sweep is a straight-line, SIMD-friendly loop
//! over adjacent memory. `W = 1` is byte-identical to the classic 64-lane
//! layout. Slot `w` of a wide run computes exactly what an independent
//! 64-lane run over slot `w`'s input words would — widening never changes
//! results, only how many vectors amortize one topological walk.
//!
//! Since the netlist IR itself stores nodes as flat opcode/fanin arrays,
//! [`CompiledNetlist`] is a **zero-copy borrow** of those arrays — the
//! seed implementation paid an O(nodes) re-flattening pass (enum walk +
//! per-gate `Vec` deref) before every equivalence run; construction is now
//! free (EXPERIMENTS.md §Perf).

use crate::ir::netlist::{OP_CONST0, OP_CONST1, OP_INPUT, OP_REG};
use crate::ir::{Netlist, NodeId};

/// Lane widths the monomorphized kernels support (words per node; `W`
/// words = `64·W` vectors per sweep).
pub const SUPPORTED_WIDTHS: [usize; 4] = [1, 2, 4, 8];

/// Widest supported lane block (512 vectors per sweep).
pub const MAX_WIDTH: usize = 8;

/// The process-default lane width for width-agnostic callers (equivalence
/// sweeps, toggle extraction). Reads `UFO_SIM_WIDTH` (must be one of
/// [`SUPPORTED_WIDTHS`]); defaults to 4 — wide enough to amortize the
/// netlist walk, narrow enough that per-worker slabs stay cache-resident.
/// Every result is width-independent by construction, so this is purely a
/// throughput knob.
pub fn default_width() -> usize {
    match std::env::var("UFO_SIM_WIDTH").ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(w) if SUPPORTED_WIDTHS.contains(&w) => w,
        _ => 4,
    }
}

/// Smallest supported lane width whose `64·W` lanes cover `lanes` vectors.
/// Panics above `64 ·` [`MAX_WIDTH`] (512) vectors.
pub fn width_for_lanes(lanes: usize) -> usize {
    let need = lanes.div_ceil(64);
    *SUPPORTED_WIDTHS
        .iter()
        .find(|&&w| w >= need)
        .unwrap_or_else(|| panic!("{lanes} lanes exceed the {}-lane slab maximum", 64 * MAX_WIDTH))
}

/// A netlist viewed as a flat instruction stream: one `(op, f0, f1, f2)`
/// record per node, no per-gate heap indirection. This is a zero-copy
/// borrow of the netlist's own struct-of-arrays storage (the IR and the
/// simulator share one encoding: opcodes 0–10 = `CellKind::opcode`,
/// [`OP_CONST0`], [`OP_CONST1`], [`OP_INPUT`] with the input ordinal in
/// `f0`) — the §Perf-optimized inner loop for equivalence checking and
/// toggle extraction, identical to the PJRT artifact encoding.
#[derive(Debug, Clone, Copy)]
pub struct CompiledNetlist<'a> {
    ops: &'a [u8],
    fanin: &'a [[u32; 3]],
    n_inputs: usize,
}

impl<'a> CompiledNetlist<'a> {
    /// Borrow a netlist as the simulator's flat op list. Zero-copy: the
    /// netlist already stores this encoding.
    ///
    /// Panics on a sequential netlist: this simulator is combinational
    /// (the unchecked hot loop would read a register's record as an input
    /// ordinal). Sequential netlists go through [`ClockedSim`].
    pub fn compile(nl: &'a Netlist) -> Self {
        assert!(
            !nl.is_sequential(),
            "CompiledNetlist is combinational; use sim::ClockedSim for '{}' ({} registers)",
            nl.name,
            nl.num_regs()
        );
        CompiledNetlist { ops: nl.ops(), fanin: nl.fanin_records(), n_inputs: nl.num_inputs() }
    }

    /// Number of compiled ops (== netlist nodes).
    pub fn len(&self) -> usize {
        self.ops.len()
    }
    /// Whether the program is empty.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
    /// Number of primary inputs the program samples.
    pub fn num_inputs(&self) -> usize {
        self.n_inputs
    }

    /// Evaluate into `buf` (resized as needed). `input_words[k]` feeds the
    /// k-th primary input. Equivalent to [`CompiledNetlist::run_wide_into`]
    /// at width 1.
    pub fn run_into(&self, buf: &mut Vec<u64>, input_words: &[u64]) {
        self.run_wide_into(1, buf, input_words);
    }

    /// Evaluate `width` 64-lane blocks at once (`width` ∈
    /// [`SUPPORTED_WIDTHS`]). `input_slab` holds `width` consecutive words
    /// per primary input (input `k` occupies `input_slab[k*width ..
    /// (k+1)*width]`); `buf` is resized to `len() * width` with the same
    /// stride. Slot `w` of every node's block is exactly the value an
    /// independent [`CompiledNetlist::run_into`] over slot `w`'s input
    /// words would produce — width never changes results.
    pub fn run_wide_into(&self, width: usize, buf: &mut Vec<u64>, input_slab: &[u64]) {
        assert_eq!(input_slab.len(), self.n_inputs * width, "input slab size");
        if buf.len() != self.ops.len() * width {
            buf.resize(self.ops.len() * width, 0);
        }
        match width {
            1 => self.run_w::<1>(buf, input_slab),
            2 => self.run_w::<2>(buf, input_slab),
            4 => self.run_w::<4>(buf, input_slab),
            8 => self.run_w::<8>(buf, input_slab),
            other => panic!("unsupported lane width {other} (supported: {SUPPORTED_WIDTHS:?})"),
        }
    }

    /// The monomorphized stride-`W` sweep: per opcode, a straight-line
    /// `W`-word loop over adjacent slab memory (SIMD-friendly).
    fn run_w<const W: usize>(&self, buf: &mut [u64], input_slab: &[u64]) {
        let p = buf.as_mut_ptr();
        let inp = input_slab.as_ptr();
        // SAFETY: the fanin records come straight from a `Netlist` whose
        // construction (`Netlist::gate`) enforces `fanin < i < len`, so
        // every `g` read at node `i` targets a block below `i*W` that this
        // sweep already wrote; input ordinals are bounded by the asserted
        // `input_slab` length. Reads and the write go through one raw
        // pointer, so no reference aliasing is involved. Dropping the
        // bounds checks is worth ~20% on the equivalence-sweep hot loop
        // (EXPERIMENTS.md §Perf).
        let g = |k: u32, w: usize| -> u64 { unsafe { *p.add(k as usize * W + w) } };
        let st = |off: usize, v: u64| unsafe { *p.add(off) = v };
        let ld = |k: u32, w: usize| -> u64 { unsafe { *inp.add(k as usize * W + w) } };
        for i in 0..self.ops.len() {
            let [f0, f1, f2] = self.fanin[i];
            let base = i * W;
            match self.ops[i] {
                0 => {
                    for w in 0..W {
                        st(base + w, g(f0, w));
                    }
                }
                1 => {
                    for w in 0..W {
                        st(base + w, !g(f0, w));
                    }
                }
                2 => {
                    for w in 0..W {
                        st(base + w, g(f0, w) & g(f1, w));
                    }
                }
                3 => {
                    for w in 0..W {
                        st(base + w, g(f0, w) | g(f1, w));
                    }
                }
                4 => {
                    for w in 0..W {
                        st(base + w, !(g(f0, w) & g(f1, w)));
                    }
                }
                5 => {
                    for w in 0..W {
                        st(base + w, !(g(f0, w) | g(f1, w)));
                    }
                }
                6 => {
                    for w in 0..W {
                        st(base + w, g(f0, w) ^ g(f1, w));
                    }
                }
                7 => {
                    for w in 0..W {
                        st(base + w, !(g(f0, w) ^ g(f1, w)));
                    }
                }
                8 => {
                    for w in 0..W {
                        st(base + w, !((g(f0, w) & g(f1, w)) | g(f2, w)));
                    }
                }
                9 => {
                    for w in 0..W {
                        st(base + w, !((g(f0, w) | g(f1, w)) & g(f2, w)));
                    }
                }
                10 => {
                    for w in 0..W {
                        let (a, bb, c) = (g(f0, w), g(f1, w), g(f2, w));
                        st(base + w, (a & bb) | (a & c) | (bb & c));
                    }
                }
                OP_CONST0 => {
                    for w in 0..W {
                        st(base + w, 0);
                    }
                }
                OP_CONST1 => {
                    for w in 0..W {
                        st(base + w, !0);
                    }
                }
                _ => {
                    for w in 0..W {
                        st(base + w, ld(f0, w));
                    }
                }
            }
        }
    }
}

/// Reusable simulation buffer (one word per node).
#[derive(Debug, Default)]
pub struct Simulator {
    words: Vec<u64>,
}

impl Simulator {
    /// Fresh simulator (the per-netlist "program" is the netlist's own
    /// flat storage, so there is nothing to cache beyond the word buffer).
    pub fn new() -> Self {
        Self::default()
    }

    /// Evaluate the netlist on 64 packed input vectors.
    ///
    /// `input_words[k]` holds lane-packed values for the k-th primary input
    /// (in creation order). Returns the packed words of every node; index
    /// with [`NodeId::index`].
    pub fn run(&mut self, nl: &Netlist, input_words: &[u64]) -> &[u64] {
        let comp = CompiledNetlist::compile(nl);
        comp.run_into(&mut self.words, input_words);
        &self.words
    }

    /// Evaluate the netlist on `width` 64-lane blocks at once. The input
    /// slab and the returned node slab use stride `width` (node `i` at
    /// `[i*width .. (i+1)*width]`); read lanes with [`wide_lane_value`].
    pub fn run_wide(&mut self, nl: &Netlist, width: usize, input_slab: &[u64]) -> &[u64] {
        let comp = CompiledNetlist::compile(nl);
        comp.run_wide_into(width, &mut self.words, input_slab);
        &self.words
    }

    /// Packed word for one node after [`Simulator::run`].
    #[inline]
    pub fn word(&self, id: NodeId) -> u64 {
        self.words[id.index()]
    }

    /// Extract the named outputs as packed words.
    pub fn output_words(&self, nl: &Netlist) -> Vec<(String, u64)> {
        nl.outputs().map(|(n, id)| (n.to_string(), self.words[id.index()])).collect()
    }
}

/// Cycle-accurate, bit-parallel simulator for **sequential** netlists —
/// the clocked counterpart of [`CompiledNetlist`].
///
/// Like the combinational simulator it evaluates 64 independent vectors at
/// once (one per bit lane of a `u64`), but register state is carried
/// across [`ClockedSim::step`] calls. Each step models one clock cycle:
///
/// 1. a full combinational sweep in which every [`crate::ir::OP_REG`] node
///    presents its *current* state `q`, then
/// 2. the synchronous update `q ← clr ? init : (en ? d : q)` per register,
///    per lane, read from the fully evaluated sweep — which is what makes
///    feedback (`d` referencing a later node) well-defined.
///
/// [`ClockedSim::reset`] models the asynchronous reset: every register
/// returns to its init value and the cycle counter restarts. Construction
/// applies it, so a fresh simulator is already in the reset state.
#[derive(Debug, Clone)]
pub struct ClockedSim<'a> {
    ops: &'a [u8],
    fanin: &'a [[u32; 3]],
    n_inputs: usize,
    /// Lane width: words per node/register block (see [`SUPPORTED_WIDTHS`]).
    width: usize,
    /// Dense register ordinal per node (`u32::MAX` for non-registers).
    state_ix: Vec<u32>,
    /// Lane-broadcast init word per register (all-ones or all-zeros).
    init_words: Vec<u64>,
    /// Current register state, `width` words per register (stride `width`).
    state: Vec<u64>,
    /// Node values of the most recent [`ClockedSim::step`] sweep
    /// (`width` words per node, stride `width`).
    words: Vec<u64>,
    /// Clock edges since the last reset.
    cycles: u64,
}

impl<'a> ClockedSim<'a> {
    /// Borrow a netlist (sequential or combinational — a register-free
    /// netlist simply has no state and `step` degenerates to one
    /// combinational sweep per call). 64 lanes; see
    /// [`ClockedSim::new_wide`] for the multi-word variant.
    pub fn new(nl: &'a Netlist) -> Self {
        Self::new_wide(nl, 1)
    }

    /// As [`ClockedSim::new`] with `width` 64-lane blocks per node
    /// (`width` ∈ [`SUPPORTED_WIDTHS`]). All slabs — inputs to
    /// [`ClockedSim::step`], node values, register state — use stride
    /// `width`. Each slot's lanes evolve exactly as an independent
    /// width-1 simulator over that slot's stimulus would.
    pub fn new_wide(nl: &'a Netlist, width: usize) -> Self {
        assert!(
            SUPPORTED_WIDTHS.contains(&width),
            "unsupported lane width {width} (supported: {SUPPORTED_WIDTHS:?})"
        );
        let n = nl.len();
        let mut state_ix = vec![u32::MAX; n];
        let mut init_words = Vec::with_capacity(nl.num_regs());
        for i in 0..n {
            if nl.ops()[i] == OP_REG {
                state_ix[i] = init_words.len() as u32;
                let init = match nl.node(NodeId(i as u32)) {
                    crate::ir::Node::Reg { init, .. } => init,
                    _ => unreachable!("opcode says register"),
                };
                init_words.push(if init { !0u64 } else { 0 });
            }
        }
        let mut state = Vec::with_capacity(init_words.len() * width);
        for &iw in &init_words {
            state.extend(std::iter::repeat(iw).take(width));
        }
        ClockedSim {
            ops: nl.ops(),
            fanin: nl.fanin_records(),
            n_inputs: nl.num_inputs(),
            width,
            state_ix,
            init_words,
            state,
            words: vec![0u64; n * width],
            cycles: 0,
        }
    }

    /// Asynchronous reset: every register back to its init value, cycle
    /// counter to zero. Node words keep their last sweep (stale until the
    /// next step).
    pub fn reset(&mut self) {
        for (six, &iw) in self.init_words.iter().enumerate() {
            self.state[six * self.width..(six + 1) * self.width].fill(iw);
        }
        self.cycles = 0;
    }

    /// Advance one clock cycle: evaluate the combinational sweep against
    /// `input_words` (`width` lane-packed words per primary input, stride
    /// `width`, creation order) with registers presenting their current
    /// state, then latch. Returns the node-value slab of the sweep (the
    /// *pre-edge* view: a register's own block is the state it held during
    /// this cycle).
    pub fn step(&mut self, input_words: &[u64]) -> &[u64] {
        let wd = self.width;
        assert_eq!(input_words.len(), self.n_inputs * wd, "input word count");
        let n = self.ops.len();
        for i in 0..n {
            let [f0, f1, f2] = self.fanin[i];
            let base = i * wd;
            for w in 0..wd {
                let v = match self.ops[i] {
                    0 => self.words[f0 as usize * wd + w],
                    1 => !self.words[f0 as usize * wd + w],
                    2 => self.words[f0 as usize * wd + w] & self.words[f1 as usize * wd + w],
                    3 => self.words[f0 as usize * wd + w] | self.words[f1 as usize * wd + w],
                    4 => !(self.words[f0 as usize * wd + w] & self.words[f1 as usize * wd + w]),
                    5 => !(self.words[f0 as usize * wd + w] | self.words[f1 as usize * wd + w]),
                    6 => self.words[f0 as usize * wd + w] ^ self.words[f1 as usize * wd + w],
                    7 => !(self.words[f0 as usize * wd + w] ^ self.words[f1 as usize * wd + w]),
                    8 => !((self.words[f0 as usize * wd + w]
                        & self.words[f1 as usize * wd + w])
                        | self.words[f2 as usize * wd + w]),
                    9 => !((self.words[f0 as usize * wd + w]
                        | self.words[f1 as usize * wd + w])
                        & self.words[f2 as usize * wd + w]),
                    10 => {
                        let (a, b, c) = (
                            self.words[f0 as usize * wd + w],
                            self.words[f1 as usize * wd + w],
                            self.words[f2 as usize * wd + w],
                        );
                        (a & b) | (a & c) | (b & c)
                    }
                    OP_CONST0 => 0,
                    OP_CONST1 => !0,
                    OP_INPUT => input_words[f0 as usize * wd + w],
                    OP_REG => self.state[self.state_ix[i] as usize * wd + w],
                    other => panic!("unknown opcode {other} at node {i}"),
                };
                self.words[base + w] = v;
            }
        }
        // Latch phase: d/en/clr are read from the completed sweep, so a
        // feedback d (later node id) sees this cycle's settled value.
        for i in 0..n {
            if self.ops[i] != OP_REG {
                continue;
            }
            let [d, en, clr] = self.fanin[i];
            let six = self.state_ix[i] as usize;
            let iw = self.init_words[six];
            for w in 0..wd {
                let (dv, env, clrv) = (
                    self.words[d as usize * wd + w],
                    self.words[en as usize * wd + w],
                    self.words[clr as usize * wd + w],
                );
                let q = self.state[six * wd + w];
                self.state[six * wd + w] = (clrv & iw) | (!clrv & ((env & dv) | (!env & q)));
            }
        }
        self.cycles += 1;
        &self.words
    }

    /// Node-value slab of the most recent sweep (stride
    /// [`ClockedSim::width`]; at width 1, index with [`NodeId::index`]).
    #[inline]
    pub fn values(&self) -> &[u64] {
        &self.words
    }

    /// First packed word (slot 0) for one node after the most recent
    /// sweep.
    #[inline]
    pub fn word(&self, id: NodeId) -> u64 {
        self.words[id.index() * self.width]
    }

    /// Lane width: words per node block.
    #[inline]
    pub fn width(&self) -> usize {
        self.width
    }

    /// Clock edges applied since construction or the last reset.
    #[inline]
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Number of primary inputs each step samples.
    #[inline]
    pub fn num_inputs(&self) -> usize {
        self.n_inputs
    }
}

/// Interpret a slice of output nodes as a little-endian unsigned integer for
/// one specific lane.
pub fn lane_value(words: &[u64], bits: &[NodeId], lane: u32) -> u128 {
    let mut v = 0u128;
    for (k, b) in bits.iter().enumerate() {
        v |= u128::from(words[b.index()] >> lane & 1) << k;
    }
    v
}

/// [`lane_value`] over a stride-`width` node slab: reads lane `lane` of
/// slot `slot` (`slot < width`) for every output bit. `wide_lane_value(w,
/// 1, 0, bits, lane)` is exactly `lane_value(w, bits, lane)`.
pub fn wide_lane_value(
    words: &[u64],
    width: usize,
    slot: usize,
    bits: &[NodeId],
    lane: u32,
) -> u128 {
    debug_assert!(slot < width);
    let mut v = 0u128;
    for (k, b) in bits.iter().enumerate() {
        v |= u128::from(words[b.index() * width + slot] >> lane & 1) << k;
    }
    v
}

/// Interpret a slice of output nodes as a little-endian **two's-complement**
/// integer for one specific lane (the MSB is the sign bit) — the signed
/// counterpart of [`lane_value`] used to verify signed operand formats.
pub fn lane_value_signed(words: &[u64], bits: &[NodeId], lane: u32) -> i128 {
    crate::util::sign_extend(lane_value(words, bits, lane), bits.len())
}

/// Pack per-lane bit values into input words: `assignments[lane][input]`.
///
/// Up to 64 assignments pack into one word per input (the classic layout,
/// directly usable with [`Simulator::run`]). More than 64 emit a
/// stride-`W` slab — `W` = [`width_for_lanes`]`(assignments.len())` words
/// per input, lane `L` in slot `L / 64`, bit `L % 64` — for
/// [`Simulator::run_wide`] / [`CompiledNetlist::run_wide_into`] at that
/// width. Panics above `64 ·` [`MAX_WIDTH`] (512) assignments.
pub fn pack_lanes(assignments: &[Vec<bool>]) -> Vec<u64> {
    assert!(!assignments.is_empty());
    let width = width_for_lanes(assignments.len());
    let n_inputs = assignments[0].len();
    let mut words = vec![0u64; n_inputs * width];
    for (lane, assign) in assignments.iter().enumerate() {
        assert_eq!(assign.len(), n_inputs);
        let (slot, bit) = (lane / 64, 1u64 << (lane % 64));
        for (i, b) in assign.iter().enumerate() {
            if *b {
                words[i * width + slot] |= bit;
            }
        }
    }
    words
}

/// Count output toggles between consecutive random vectors for every node —
/// the activity factor feeding the dynamic-power report.
///
/// Combinational netlists run `rounds`×64 random vectors (xorshift-seeded,
/// deterministic) through the compiled evaluator; netlists with registers
/// are routed through [`clocked_toggle_activity`] instead — `rounds`
/// clocked cycles of fresh random stimulus from the same seed, so measured
/// activity is cycle-accurate (registers toggle on actual state
/// transitions, not on a combinational re-evaluation that ignores state).
/// Returns per-node toggle probability in [0,1]. All buffers (current and
/// previous node words, input words) are allocated once and reused across
/// rounds — the seed implementation cloned the first round's buffer and
/// allocated a fresh input-word `Vec` per round (EXPERIMENTS.md §Perf).
pub fn toggle_activity(nl: &Netlist, rounds: usize, seed: u64) -> Vec<f64> {
    toggle_activity_wide(nl, rounds, seed, default_width())
}

/// [`toggle_activity`] with an explicit lane width: each wide sweep
/// evaluates up to `width` consecutive 64-lane rounds of the *same*
/// deterministic xorshift64* stimulus stream (slot `w` of sweep `g` holds
/// the draws round `g·width + w` would consume), and toggles are counted
/// between every consecutive round pair — within a sweep slot-to-slot,
/// and across sweeps via the carried last-round values. The returned
/// activities are therefore **bit-identical for every width** (pinned by
/// tests); width only sets how many rounds amortize one netlist walk.
///
/// Sequential netlists route through [`clocked_toggle_activity`]
/// regardless of `width`: cycles form a serial state recurrence, so there
/// are no independent rounds to batch (see ARCHITECTURE.md §Hot paths).
pub fn toggle_activity_wide(nl: &Netlist, rounds: usize, seed: u64, width: usize) -> Vec<f64> {
    if nl.is_sequential() {
        return clocked_toggle_activity(nl, rounds, seed);
    }
    assert!(
        SUPPORTED_WIDTHS.contains(&width),
        "unsupported lane width {width} (supported: {SUPPORTED_WIDTHS:?})"
    );
    let comp = CompiledNetlist::compile(nl);
    let mut state = seed | 1;
    let mut rng = move || {
        // xorshift64* — deterministic, dependency-free
        state ^= state >> 12;
        state ^= state << 25;
        state ^= state >> 27;
        state.wrapping_mul(0x2545_F491_4F6C_DD1D)
    };
    let n_in = nl.num_inputs();
    let n = nl.len();
    let mut toggles = vec![0u64; n];
    let mut total_pairs = 0u64;
    let mut cur: Vec<u64> = Vec::new();
    // Last finished round's node words — the cross-sweep toggle partner.
    let mut prev_last = vec![0u64; n];
    let mut slab = vec![0u64; n_in * width];
    let mut done = 0usize;
    while done < rounds {
        let cnt = width.min(rounds - done);
        // Slot w consumes exactly the n_in draws narrow round done+w
        // would, in the same order — the per-round word streams (and so
        // the counts) are width-independent.
        for w in 0..cnt {
            for k in 0..n_in {
                slab[k * width + w] = rng();
            }
        }
        for w in cnt..width {
            for k in 0..n_in {
                slab[k * width + w] = 0;
            }
        }
        comp.run_wide_into(width, &mut cur, &slab);
        for w in 0..cnt {
            if done + w == 0 {
                continue; // the very first round has no predecessor
            }
            if w == 0 {
                for i in 0..n {
                    toggles[i] += (cur[i * width] ^ prev_last[i]).count_ones() as u64;
                }
            } else {
                for i in 0..n {
                    toggles[i] +=
                        (cur[i * width + w] ^ cur[i * width + w - 1]).count_ones() as u64;
                }
            }
            total_pairs += 64;
        }
        for i in 0..n {
            prev_last[i] = cur[i * width + cnt - 1];
        }
        done += cnt;
    }
    toggles
        .iter()
        .map(|&t| if total_pairs == 0 { 0.0 } else { t as f64 / total_pairs as f64 })
        .collect()
}

/// Cycle-accurate toggle counting for sequential netlists: drive a
/// [`ClockedSim`] from reset for `rounds` cycles of fresh 64-lane random
/// stimulus (same xorshift discipline and seed interpretation as the
/// combinational path) and count per-node toggles between consecutive
/// pre-edge value views. Register nodes therefore toggle exactly when
/// their latched state changes between cycles.
pub fn clocked_toggle_activity(nl: &Netlist, rounds: usize, seed: u64) -> Vec<f64> {
    let mut sim = ClockedSim::new(nl);
    let mut state = seed | 1;
    let mut rng = move || {
        state ^= state >> 12;
        state ^= state << 25;
        state ^= state >> 27;
        state.wrapping_mul(0x2545_F491_4F6C_DD1D)
    };
    let mut toggles = vec![0u64; nl.len()];
    let mut total_pairs = 0u64;
    let mut prev: Vec<u64> = Vec::new();
    let mut words = vec![0u64; sim.num_inputs()];
    for cycle in 0..rounds {
        for w in words.iter_mut() {
            *w = rng();
        }
        let cur = sim.step(&words);
        if cycle > 0 {
            for (i, &c) in cur.iter().enumerate() {
                toggles[i] += (c ^ prev[i]).count_ones() as u64;
            }
            total_pairs += 64;
        }
        prev.clear();
        prev.extend_from_slice(cur);
    }
    toggles
        .iter()
        .map(|&t| if total_pairs == 0 { 0.0 } else { t as f64 / total_pairs as f64 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Netlist;

    /// 2-bit ripple adder built from discrete gates.
    fn adder2() -> (Netlist, Vec<NodeId>) {
        let mut nl = Netlist::new("add2");
        let a: Vec<_> = (0..2).map(|i| nl.input(format!("a{i}"))).collect();
        let b: Vec<_> = (0..2).map(|i| nl.input(format!("b{i}"))).collect();
        // bit 0: half adder
        let s0 = nl.xor2(a[0], b[0]);
        let c0 = nl.and2(a[0], b[0]);
        // bit 1: full adder
        let x1 = nl.xor2(a[1], b[1]);
        let s1 = nl.xor2(x1, c0);
        let g1 = nl.and2(a[1], b[1]);
        let p1 = nl.and2(x1, c0);
        let c1 = nl.or2(g1, p1);
        nl.output("s0", s0);
        nl.output("s1", s1);
        nl.output("c", c1);
        (nl, vec![s0, s1, c1])
    }

    #[test]
    fn adder2_exhaustive() {
        let (nl, bits) = adder2();
        // all 16 combinations fit in 16 lanes
        let assigns: Vec<Vec<bool>> = (0..16u32)
            .map(|v| vec![v & 1 != 0, v >> 1 & 1 != 0, v >> 2 & 1 != 0, v >> 3 & 1 != 0])
            .collect();
        let words = pack_lanes(&assigns);
        let mut sim = Simulator::new();
        let vals = sim.run(&nl, &words).to_vec();
        for v in 0..16u32 {
            let a = v & 3;
            let b = v >> 2 & 3;
            let got = lane_value(&vals, &bits, v);
            assert_eq!(got, u128::from(a + b), "a={a} b={b}");
        }
    }

    #[test]
    fn lane_value_signed_reads_twos_complement() {
        let (nl, bits) = adder2();
        // a = 3, b = 2 → s = 5 = 0b101 → signed over 3 bits = -3.
        let words = pack_lanes(&[vec![true, true, false, true]]);
        let mut sim = Simulator::new();
        let vals = sim.run(&nl, &words).to_vec();
        assert_eq!(lane_value(&vals, &bits, 0), 5);
        assert_eq!(lane_value_signed(&vals, &bits, 0), -3);
        assert_eq!(lane_value_signed(&vals, &bits[..2], 0), 1); // 0b01
        assert_eq!(lane_value_signed(&vals, &[], 0), 0);
    }

    #[test]
    fn constants_evaluate() {
        let mut nl = Netlist::new("c");
        let one = nl.constant(true);
        let zero = nl.constant(false);
        let o = nl.and2(one, zero);
        let o2 = nl.or2(one, zero);
        nl.output("and", o);
        nl.output("or", o2);
        let mut sim = Simulator::new();
        sim.run(&nl, &[]);
        assert_eq!(sim.word(o), 0);
        assert_eq!(sim.word(o2), !0);
    }

    #[test]
    fn compiled_is_zero_copy_of_the_netlist() {
        let (nl, _) = adder2();
        let comp = CompiledNetlist::compile(&nl);
        assert_eq!(comp.len(), nl.len());
        assert_eq!(comp.num_inputs(), nl.num_inputs());
        assert!(std::ptr::eq(comp.ops.as_ptr(), nl.ops().as_ptr()));
        assert!(std::ptr::eq(comp.fanin.as_ptr(), nl.fanin_records().as_ptr()));
    }

    /// Toggle flip-flop: q feeds back through an inverter into its own d.
    /// Built with the sanctioned feedback recipe (`reg_raw` seed +
    /// `set_reg_data` patch).
    fn toggle_ff() -> (Netlist, NodeId, NodeId, NodeId) {
        let mut nl = Netlist::new("tff");
        let en = nl.input("en");
        let clr = nl.input("clr");
        let q = nl.reg_raw(0, en.0, clr.0, false);
        let nq = nl.inv(q);
        nl.set_reg_data(q, nq);
        nl.output("q", q);
        nl.validate().unwrap();
        (nl, q, en, clr)
    }

    #[test]
    fn clocked_toggle_ff_counts_edges() {
        let (nl, q, _, _) = toggle_ff();
        let mut sim = ClockedSim::new(&nl);
        // en=1, clr=0 on every lane: q alternates 0,1,0,1,... Each step
        // returns the *pre-edge* view, so sweep k shows the state after
        // k-1 edges: (k-1) mod 2.
        for sweep in 1..=6u64 {
            let view = sim.step(&[!0, 0]);
            let expect = if (sweep - 1) % 2 == 0 { 0u64 } else { !0 };
            assert_eq!(view[q.index()], expect, "sweep {sweep}");
            assert_eq!(sim.cycles(), sweep);
        }
    }

    #[test]
    fn clocked_en_stalls_and_clr_clears() {
        let (nl, q, _, _) = toggle_ff();
        let mut sim = ClockedSim::new(&nl);
        sim.step(&[!0, 0]); // edge 1: q becomes 1
        sim.step(&[0, 0]); // en=0: hold
        sim.step(&[0, 0]); // still holding
        let view = sim.step(&[0, 0]);
        assert_eq!(view[q.index()], !0, "held the toggled value across stalls");
        // clr wins over en: q returns to init (0) even with en=1.
        sim.step(&[!0, !0]);
        let view = sim.step(&[0, 0]);
        assert_eq!(view[q.index()], 0, "clr returns to init");
    }

    #[test]
    fn clocked_reset_restores_init_state() {
        let (nl, q, _, _) = toggle_ff();
        let mut sim = ClockedSim::new(&nl);
        sim.step(&[!0, 0]);
        sim.step(&[0, 0]);
        assert_eq!(sim.word(q), !0);
        sim.reset();
        assert_eq!(sim.cycles(), 0);
        let view = sim.step(&[0, 0]);
        assert_eq!(view[q.index()], 0, "init state after reset");
    }

    #[test]
    fn clocked_two_rank_pipeline_has_two_cycle_latency() {
        // x → reg → reg: the input value appears at the second rank's
        // output exactly two edges later.
        let mut nl = Netlist::new("pipe2");
        let x = nl.input("x");
        let en = nl.constant(true);
        let clr = nl.constant(false);
        let r1 = nl.reg(x, en, clr, false);
        let r2 = nl.reg(r1, en, clr, false);
        nl.output("y", r2);
        let mut sim = ClockedSim::new(&nl);
        let pattern = 0xDEAD_BEEF_0BAD_F00Du64;
        sim.step(&[pattern]); // edge 1: r1 captures pattern
        sim.step(&[0]); // edge 2: r2 captures pattern
        let view = sim.step(&[0]); // sweep 3 shows r2 = pattern
        assert_eq!(view[r2.index()], pattern);
        assert_eq!(view[r1.index()], 0, "rank 1 moved on");
    }

    #[test]
    fn clocked_matches_combinational_on_register_free_netlists() {
        let (nl, bits) = adder2();
        let assigns: Vec<Vec<bool>> = (0..16u32)
            .map(|v| vec![v & 1 != 0, v >> 1 & 1 != 0, v >> 2 & 1 != 0, v >> 3 & 1 != 0])
            .collect();
        let words = pack_lanes(&assigns);
        let mut clocked = ClockedSim::new(&nl);
        let cw = clocked.step(&words).to_vec();
        let mut sim = Simulator::new();
        let sw = sim.run(&nl, &words).to_vec();
        assert_eq!(cw, sw);
        let _ = bits;
    }

    #[test]
    #[should_panic(expected = "combinational")]
    fn combinational_compile_rejects_sequential() {
        let (nl, _, _, _) = toggle_ff();
        let _ = CompiledNetlist::compile(&nl);
    }

    #[test]
    fn toggle_activity_sane() {
        let (nl, _) = adder2();
        let act = toggle_activity(&nl, 32, 42);
        // inputs are random ⇒ toggle prob near 0.5; all activities in [0,1]
        for (i, a) in act.iter().enumerate() {
            assert!((0.0..=1.0).contains(a), "node {i} activity {a}");
        }
        let inputs = nl.inputs();
        for id in inputs {
            assert!((act[id.index()] - 0.5).abs() < 0.1);
        }
    }

    #[test]
    fn toggle_activity_is_width_independent() {
        // The wide sweep replays the same per-round RNG stream and counts
        // the same consecutive-round pairs, so every width reports
        // bit-identical activities — including rounds that don't divide
        // the width (trailing partial sweep).
        let (nl, _) = adder2();
        for rounds in [0usize, 1, 2, 5, 17, 32] {
            let narrow = toggle_activity_wide(&nl, rounds, 42, 1);
            for w in [2usize, 4, 8] {
                let wide = toggle_activity_wide(&nl, rounds, 42, w);
                assert_eq!(narrow, wide, "rounds={rounds} width={w}");
            }
        }
    }

    #[test]
    fn pack_lanes_65_vectors_emits_stride_2_slab() {
        // Satellite regression: the seed's hard `len <= 64` assert is gone.
        // 65 assignments need two words per input; lane 64 lands in slot 1
        // bit 0.
        let n_inputs = 3;
        let assigns: Vec<Vec<bool>> = (0..65u32)
            .map(|v| (0..n_inputs).map(|k| (v >> k) & 1 != 0 || v == 64).collect())
            .collect();
        let words = pack_lanes(&assigns);
        assert_eq!(words.len(), n_inputs * 2, "stride-2 slab");
        for (lane, assign) in assigns.iter().enumerate() {
            let (slot, bit) = (lane / 64, lane % 64);
            for (i, &b) in assign.iter().enumerate() {
                assert_eq!(words[i * 2 + slot] >> bit & 1 == 1, b, "lane {lane} input {i}");
            }
        }
        // And the slab simulates: all 65 lanes of a wide run agree with
        // narrow runs over each slot.
        let (nl, bits) = adder2();
        let assigns: Vec<Vec<bool>> = (0..65u32)
            .map(|v| {
                let v = v % 16;
                vec![v & 1 != 0, v >> 1 & 1 != 0, v >> 2 & 1 != 0, v >> 3 & 1 != 0]
            })
            .collect();
        let slab = pack_lanes(&assigns);
        let mut sim = Simulator::new();
        let vals = sim.run_wide(&nl, 2, &slab).to_vec();
        for (lane, _) in assigns.iter().enumerate() {
            let v = (lane % 16) as u32;
            let got = wide_lane_value(&vals, 2, lane / 64, &bits, (lane % 64) as u32);
            assert_eq!(got, u128::from((v & 3) + (v >> 2 & 3)), "lane {lane}");
        }
    }

    #[test]
    fn wide_run_slots_match_independent_narrow_runs() {
        // Slot w of a width-W run must be bit-identical to a narrow run
        // over slot w's input words — the invariant every wide consumer
        // (equiv, toggle extraction) relies on.
        let (nl, _) = adder2();
        let comp = CompiledNetlist::compile(&nl);
        let mut rng_state = 0x1234_5678_9ABC_DEFFu64;
        let mut rng = move || {
            rng_state ^= rng_state >> 12;
            rng_state ^= rng_state << 25;
            rng_state ^= rng_state >> 27;
            rng_state.wrapping_mul(0x2545_F491_4F6C_DD1D)
        };
        let n_in = nl.num_inputs();
        let blocks: Vec<Vec<u64>> =
            (0..8).map(|_| (0..n_in).map(|_| rng()).collect()).collect();
        let mut narrow: Vec<Vec<u64>> = Vec::new();
        for b in &blocks {
            let mut buf = Vec::new();
            comp.run_into(&mut buf, b);
            narrow.push(buf);
        }
        for width in [1usize, 2, 4, 8] {
            let mut slab = vec![0u64; n_in * width];
            for (w, b) in blocks.iter().take(width).enumerate() {
                for (k, &word) in b.iter().enumerate() {
                    slab[k * width + w] = word;
                }
            }
            let mut buf = Vec::new();
            comp.run_wide_into(width, &mut buf, &slab);
            for w in 0..width {
                for i in 0..nl.len() {
                    assert_eq!(
                        buf[i * width + w],
                        narrow[w][i],
                        "width {width} slot {w} node {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn wide_clocked_slots_match_independent_narrow_sims() {
        let (nl, q, _, _) = toggle_ff();
        // Per-slot stimulus: slot w toggles en/clr with a different phase.
        let stim: Vec<[Vec<u64>; 2]> = (0..4)
            .map(|w| {
                let en: Vec<u64> = (0..6).map(|c| if (c + w) % 2 == 0 { !0u64 } else { 0 }).collect();
                let clr: Vec<u64> = (0..6).map(|c| if c == 3 + w { !0u64 } else { 0 }).collect();
                [en, clr]
            })
            .collect();
        // Narrow reference per slot.
        let mut narrow_q: Vec<Vec<u64>> = Vec::new();
        for s in &stim {
            let mut sim = ClockedSim::new(&nl);
            narrow_q.push((0..6).map(|c| sim.step(&[s[0][c], s[1][c]])[q.index()]).collect());
        }
        // One wide sim drives all four slots at once.
        let mut wide = ClockedSim::new_wide(&nl, 4);
        assert_eq!(wide.width(), 4);
        for c in 0..6usize {
            let mut slab = vec![0u64; 2 * 4];
            for (w, s) in stim.iter().enumerate() {
                slab[w] = s[0][c]; // en is input 0
                slab[4 + w] = s[1][c]; // clr is input 1
            }
            let view = wide.step(&slab).to_vec();
            for w in 0..4 {
                assert_eq!(view[q.index() * 4 + w], narrow_q[w][c], "cycle {c} slot {w}");
            }
        }
    }
}
