//! The one compile path.
//!
//! [`SynthEngine`] owns everything the legacy entry points used to rebuild
//! per call — the characterized [`CellLib`], the derived
//! [`CompressorTiming`], the [`Sta`] engine, and (when configured) the
//! PJRT [`Runtime`] — plus the content-addressed design cache. Every
//! synthesis in the crate funnels through [`SynthEngine::compile`]:
//! `MultiplierSpec::build`, `baselines::build_design`, the module report
//! helpers and `coordinator::run_sweep` are all thin shims over it, so a
//! repeated request is served from cache as the same `Arc`.

use super::cache::{CacheStats, CacheTier, DesignCache};
use super::request::{DesignRequest, Fingerprint, MethodRequest, ModuleKind};
use crate::analysis::{self, AnalysisOptions, AnalysisReport};
use crate::baselines::{self, BaselineBudget};
use crate::coordinator::pool;
use crate::ir::{CellLib, Netlist, NodeId};
use crate::lint::{self, LintOptions, LintReport, Severity};
use crate::modules::{self, ModuleReport};
use crate::multiplier::{DatapathTrace, Design};
use crate::runtime::{default_artifact_dir, verify_design_pjrt, Runtime};
use crate::sta::{Sta, StaReport, TimingStats};
use crate::synth::CompressorTiming;
use crate::Result;
use anyhow::anyhow;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Engine construction knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Simulator-equivalence budget per compiled design; `0` skips
    /// verification (the legacy `MultiplierSpec::build` behaviour).
    pub verify_vectors: usize,
    /// Cross-check compiled designs through the PJRT artifacts when the
    /// runtime and artifact files are available.
    pub use_pjrt: bool,
    /// Worker threads for [`SynthEngine::compile_batch`].
    pub workers: usize,
    /// Mutex shards of the design cache.
    pub cache_shards: usize,
    /// Directory of the persistent disk cache tier; `None` (the default)
    /// keeps the cache in-memory only. With a directory, every compiled
    /// artifact is written through to a checksummed entry file and served
    /// back — across process restarts — without recompiling (see
    /// `PROTOCOL.md` for the entry format).
    pub cache_dir: Option<PathBuf>,
    /// Lint gate: a freshly synthesized design whose [`LintReport`]
    /// reaches this severity is rejected (the compile fails *before* any
    /// equivalence simulation). `None` disables the gate; the default
    /// denies [`Severity::Error`]. The report itself is stored on the
    /// artifact either way.
    pub lint_deny: Option<Severity>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            verify_vectors: 0,
            use_pjrt: false,
            workers: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
            cache_shards: 16,
            cache_dir: None,
            lint_deny: Some(Severity::Error),
        }
    }
}

/// How a [`SynthEngine::compile_traced`] call obtained its artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompileSource {
    /// In-memory cache hit.
    Memory,
    /// Persistent disk-tier hit (fresh process, warm cache).
    Disk,
    /// Freshly synthesized by this call.
    Compiled,
    /// Deduplicated onto a concurrent identical compile (this call waited
    /// for the in-flight leader instead of synthesizing again).
    Coalesced,
}

impl CompileSource {
    /// Stable wire key (`source` field of server compile responses).
    pub fn key(&self) -> &'static str {
        match self {
            CompileSource::Memory => "memory",
            CompileSource::Disk => "disk",
            CompileSource::Compiled => "compiled",
            CompileSource::Coalesced => "coalesced",
        }
    }
}

/// One in-flight compile: waiters block on the condvar until the leader
/// publishes the outcome (`anyhow::Error` is not `Clone`, so failures
/// travel as rendered strings).
#[derive(Default)]
struct Flight {
    slot: Mutex<Option<std::result::Result<Arc<DesignArtifact>, String>>>,
    cv: Condvar,
}

impl Flight {
    fn wait(&self) -> std::result::Result<Arc<DesignArtifact>, String> {
        let mut slot = self.slot.lock().unwrap();
        while slot.is_none() {
            slot = self.cv.wait(slot).unwrap();
        }
        slot.clone().unwrap()
    }

    fn publish(&self, outcome: std::result::Result<Arc<DesignArtifact>, String>) {
        *self.slot.lock().unwrap() = Some(outcome);
        self.cv.notify_all();
    }
}

/// The compiled payload of an artifact.
#[derive(Debug, Clone)]
pub enum ArtifactBody {
    /// A multiplier / MAC design (multiplier-family and method requests).
    Design(Design),
    /// A FIR pipeline stage: multiplier + stage adder, plus the clocked
    /// Table-1 report.
    FirStage { netlist: Netlist, y: Vec<NodeId>, report: ModuleReport },
    /// A systolic processing element (fused MAC) plus the clocked Table-2
    /// array report.
    SystolicPe { pe: Design, report: ModuleReport },
}

/// An immutable compiled design, shared by `Arc` out of the cache.
#[derive(Debug, Clone)]
pub struct DesignArtifact {
    /// The canonical form of the request that produced this artifact.
    pub request: DesignRequest,
    /// Content hash of the canonical request (the cache key).
    pub fingerprint: Fingerprint,
    /// STA of [`Self::netlist`] (clocked at the request frequency for
    /// module requests, at the engine default otherwise).
    pub sta: StaReport,
    /// Cumulative timing-evaluation work behind this artifact: the CPA
    /// optimization's incremental delay-cache passes, the candidate-scoring
    /// STA sweeps, the engine's own analysis pass, and (for module
    /// requests) the inner design's work. `timing.retime_fraction()` < 1
    /// means the incremental engines skipped re-evaluation work that
    /// from-scratch re-timing would have paid.
    pub timing: TimingStats,
    /// The compiled payload.
    pub body: ArtifactBody,
    /// Simulator equivalence (None when the engine skips verification or
    /// the body has no multiplier semantics).
    pub verified: Option<bool>,
    /// PJRT artifact cross-check (None without runtime/artifacts).
    pub pjrt_verified: Option<bool>,
    /// Static-analysis report of the compiled payload — the full
    /// structural + datapath sweep for freshly synthesized designs,
    /// structural-only for module bodies. `None` for artifacts rehydrated
    /// from disk entries written before the lint subsystem existed.
    pub lint: Option<LintReport>,
    /// Abstract-interpretation report ([`crate::analysis`]): proven
    /// constants, static activity, word-level intervals and the UFO4xx
    /// diagnostics. `None` for artifacts rehydrated from disk entries
    /// written before the analysis subsystem existed.
    pub analysis: Option<AnalysisReport>,
}

impl DesignArtifact {
    /// The multiplier/MAC design, when the body has one.
    pub fn design(&self) -> Option<&Design> {
        match &self.body {
            ArtifactBody::Design(d) => Some(d),
            ArtifactBody::SystolicPe { pe, .. } => Some(pe),
            ArtifactBody::FirStage { .. } => None,
        }
    }

    /// The gate-level netlist of whatever was compiled.
    pub fn netlist(&self) -> &Netlist {
        match &self.body {
            ArtifactBody::Design(d) => &d.netlist,
            ArtifactBody::SystolicPe { pe, .. } => &pe.netlist,
            ArtifactBody::FirStage { netlist, .. } => netlist,
        }
    }

    /// Pipeline metadata, when the compiled body is a pipelined design.
    pub fn pipeline(&self) -> Option<&crate::multiplier::PipelineInfo> {
        self.design().and_then(|d| d.pipeline.as_ref())
    }

    /// The clocked module report (FIR / systolic requests only).
    pub fn module_report(&self) -> Option<&ModuleReport> {
        match &self.body {
            ArtifactBody::FirStage { report, .. } | ArtifactBody::SystolicPe { report, .. } => {
                Some(report)
            }
            ArtifactBody::Design(_) => None,
        }
    }
}

/// The unified synthesis engine (see module docs).
pub struct SynthEngine {
    cfg: EngineConfig,
    lib: CellLib,
    tm: CompressorTiming,
    sta: Sta,
    runtime: Option<Mutex<Runtime>>,
    cache: DesignCache,
    /// Fingerprint → in-flight compile, for request coalescing.
    inflight: Mutex<HashMap<u128, Arc<Flight>>>,
    coalesced: AtomicU64,
}

impl SynthEngine {
    /// Build an engine: characterize the cell library once, derive the
    /// compressor timing model, construct the STA engine and an empty
    /// design cache (and a PJRT runtime when configured).
    pub fn new(cfg: EngineConfig) -> Self {
        let lib = CellLib::nangate45();
        let tm = CompressorTiming::from_lib(&lib);
        let sta = Sta::with_lib(lib.clone());
        let runtime = if cfg.use_pjrt {
            Runtime::new(default_artifact_dir()).ok().map(Mutex::new)
        } else {
            None
        };
        let cache = match cfg.cache_dir.clone() {
            Some(dir) => DesignCache::with_disk(cfg.cache_shards, dir),
            None => DesignCache::new(cfg.cache_shards),
        };
        SynthEngine {
            cfg,
            lib,
            tm,
            sta,
            runtime,
            cache,
            inflight: Mutex::new(HashMap::new()),
            coalesced: AtomicU64::new(0),
        }
    }

    /// The configuration this engine was built with.
    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// The shared cell library (callers needing raw netlist construction).
    pub fn lib(&self) -> &CellLib {
        &self.lib
    }

    /// The shared compressor timing model.
    pub fn timing(&self) -> &CompressorTiming {
        &self.tm
    }

    /// The shared STA engine (default clock).
    pub fn sta(&self) -> &Sta {
        &self.sta
    }

    /// Hit/miss/entry counters of the design cache (including compiles
    /// avoided by in-flight coalescing).
    pub fn cache_stats(&self) -> CacheStats {
        CacheStats { coalesced: self.coalesced.load(Ordering::Relaxed), ..self.cache.stats() }
    }

    /// Drop all cached in-memory artifacts (hit/miss counters and
    /// disk-tier entries survive).
    pub fn clear_cache(&self) {
        self.cache.clear();
    }

    /// Whether `req` would be served from a cache tier (memory-resident
    /// artifact or disk entry file) rather than freshly synthesized. A
    /// pure probe: no counters move, nothing is deserialized or promoted.
    /// The server uses this to classify incoming compiles for scheduling
    /// (cached ⇒ urgent — see [`crate::server::sched`]); it is a
    /// heuristic, so a racing insert between probe and compile only
    /// affects priority, never the compiled result.
    pub fn is_cached(&self, req: &DesignRequest) -> bool {
        self.cache.contains(req.fingerprint())
    }

    /// Compile a request, serving identical requests from the cache.
    ///
    /// The request is canonicalized first, so every spelling of the same
    /// design — explicit spec, method shorthand, differing dead fields —
    /// resolves to one artifact. Concurrent identical requests are
    /// *coalesced*: N simultaneous compiles of one fingerprint trigger
    /// exactly one synthesis, and the other N−1 callers wait for it.
    ///
    /// ```
    /// use ufo_mac::api::{DesignRequest, EngineConfig, SynthEngine};
    ///
    /// let engine = SynthEngine::new(EngineConfig::default());
    /// let art = engine.compile(&DesignRequest::multiplier(4))?;
    /// assert!(art.sta.critical_delay_ns > 0.0);
    ///
    /// // The second compile of the same request is the identical Arc.
    /// let again = engine.compile(&DesignRequest::multiplier(4))?;
    /// assert!(std::sync::Arc::ptr_eq(&art, &again));
    /// assert!(engine.cache_stats().hits >= 1);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn compile(&self, req: &DesignRequest) -> Result<Arc<DesignArtifact>> {
        self.compile_traced(req).map(|(art, _)| art)
    }

    /// [`SynthEngine::compile`] plus *how* the artifact was obtained — a
    /// memory hit, a disk-tier hit, a fresh synthesis, or a wait on a
    /// coalesced in-flight compile. The server's wire responses surface
    /// this as their `source` field.
    pub fn compile_traced(
        &self,
        req: &DesignRequest,
    ) -> Result<(Arc<DesignArtifact>, CompileSource)> {
        let canon = req.canonical();
        let fp = canon.fingerprint_of_canonical();
        if let Some((hit, tier)) = self.cache.get_traced(fp) {
            let src = match tier {
                CacheTier::Memory => CompileSource::Memory,
                CacheTier::Disk => CompileSource::Disk,
            };
            return Ok((hit, src));
        }
        // Miss: either join the in-flight compile for this fingerprint or
        // become its leader.
        let flight = {
            let mut map = self.inflight.lock().unwrap();
            if let Some(f) = map.get(&fp.0) {
                let f = f.clone();
                drop(map);
                self.coalesced.fetch_add(1, Ordering::Relaxed);
                // This caller triggered no fresh synthesis — reclassify
                // the miss the lookup just recorded.
                self.cache.forgive_miss();
                return match f.wait() {
                    Ok(art) => Ok((art, CompileSource::Coalesced)),
                    Err(e) => Err(anyhow!("coalesced compile failed: {e}")),
                };
            }
            let f = Arc::new(Flight::default());
            map.insert(fp.0, f.clone());
            f
        };
        // Leader path. The guard publishes an error to any waiters even if
        // synthesis panics (compile_batch catches the panic; without the
        // guard the waiters would block forever).
        struct Lead<'a> {
            eng: &'a SynthEngine,
            fp: Fingerprint,
            flight: Arc<Flight>,
            done: bool,
        }
        impl Lead<'_> {
            fn finish(&mut self, outcome: std::result::Result<Arc<DesignArtifact>, String>) {
                if self.done {
                    return;
                }
                self.done = true;
                self.flight.publish(outcome);
                self.eng.inflight.lock().unwrap().remove(&self.fp.0);
            }
        }
        impl Drop for Lead<'_> {
            fn drop(&mut self) {
                self.finish(Err("synthesis panicked".to_string()));
            }
        }
        let mut lead = Lead { eng: self, fp, flight, done: false };
        // A previous leader may have finished between our miss and our
        // registration; re-check (without skewing the counters) before
        // paying for a synthesis. Reporting that case as a memory hit
        // keeps the invariant that exactly one caller per synthesis ever
        // observes `Compiled`.
        if let Some(hit) = self.cache.peek(fp) {
            self.cache.miss_to_hit();
            lead.finish(Ok(hit.clone()));
            return Ok((hit, CompileSource::Memory));
        }
        match self.build_artifact(&canon, fp).map(|art| self.cache.insert(fp, art)) {
            Ok(art) => {
                lead.finish(Ok(art.clone()));
                Ok((art, CompileSource::Compiled))
            }
            Err(e) => {
                lead.finish(Err(format!("{e:#}")));
                Err(e)
            }
        }
    }

    /// Compile many requests on the coordinator thread pool
    /// ([`pool::par_map_scoped`]), preserving input order — `result[i]`
    /// always corresponds to `reqs[i]`. Duplicate requests collapse onto
    /// one cache entry (identical `Arc`s in the output), and duplicates
    /// that start *concurrently* on separate workers are coalesced onto
    /// one synthesis. A synthesis panic is contained to its own row as an
    /// `Err` rather than tearing down the whole batch.
    ///
    /// ```
    /// use ufo_mac::api::{DesignRequest, EngineConfig, SynthEngine};
    ///
    /// let engine = SynthEngine::new(EngineConfig::default());
    /// let reqs: Vec<_> = [3usize, 4, 4].iter().map(|&n| DesignRequest::multiplier(n)).collect();
    /// let arts = engine.compile_batch(&reqs);
    /// assert_eq!(arts.len(), 3);
    /// // Rows 1 and 2 are the same request, therefore the same artifact.
    /// let (a, b) = (arts[1].as_ref().unwrap(), arts[2].as_ref().unwrap());
    /// assert!(std::sync::Arc::ptr_eq(a, b));
    /// ```
    pub fn compile_batch(&self, reqs: &[DesignRequest]) -> Vec<Result<Arc<DesignArtifact>>> {
        self.compile_batch_traced(reqs).into_iter().map(|r| r.map(|(a, _)| a)).collect()
    }

    /// [`SynthEngine::compile_batch`] with per-row [`CompileSource`]s (the
    /// server's `batch` command reports them per result row).
    pub fn compile_batch_traced(
        &self,
        reqs: &[DesignRequest],
    ) -> Vec<Result<(Arc<DesignArtifact>, CompileSource)>> {
        let one = |req: &DesignRequest| -> Result<(Arc<DesignArtifact>, CompileSource)> {
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.compile_traced(req)))
                .unwrap_or_else(|_| Err(anyhow!("synthesis panicked for {req:?}")))
        };
        if reqs.len() <= 1 || self.cfg.workers <= 1 {
            return reqs.iter().map(one).collect();
        }
        pool::par_map_scoped(self.cfg.workers, reqs.to_vec(), |req| one(&req))
    }

    /// Compile (or fetch) a request and return its static-analysis report
    /// alongside the artifact and how it was obtained.
    ///
    /// Cached artifacts reuse the report stored at synthesis time;
    /// artifacts rehydrated from pre-lint disk entries fall back to a
    /// fresh structural-only sweep of the cached netlist (the datapath
    /// evidence is never persisted). The `ufo-mac lint` CLI and the
    /// server's `lint` command are thin wrappers over this.
    pub fn lint(
        &self,
        req: &DesignRequest,
    ) -> Result<(LintReport, Arc<DesignArtifact>, CompileSource)> {
        let (art, src) = self.compile_traced(req)?;
        let report = match &art.lint {
            Some(r) => r.clone(),
            None => LintReport::from_diagnostics(lint::lint_netlist(
                art.netlist(),
                &LintOptions::default(),
            )),
        };
        Ok((report, art, src))
    }

    /// Compile (or fetch) a request and return its abstract-interpretation
    /// report alongside the artifact and how it was obtained.
    ///
    /// Cached artifacts reuse the report stored at synthesis time;
    /// artifacts rehydrated from pre-analysis disk entries fall back to a
    /// fresh netlist-level sweep (the design-level cross-check needs the
    /// operand structure, which module bodies lack anyway). The `ufo-mac
    /// analyze` CLI and the server's `analyze` command are thin wrappers
    /// over this.
    pub fn analyze(
        &self,
        req: &DesignRequest,
    ) -> Result<(AnalysisReport, Arc<DesignArtifact>, CompileSource)> {
        let (art, src) = self.compile_traced(req)?;
        let report = match &art.analysis {
            Some(r) => r.clone(),
            None => match art.design() {
                Some(d) => analysis::analyze_design(d, &self.analysis_options()).report,
                None => analysis::analyze_netlist(art.netlist(), &self.analysis_options()).report,
            },
        };
        Ok((report, art, src))
    }

    /// The engine's analysis configuration: default lattice knobs, the
    /// engine's worker budget for the per-level parallel sweeps (results
    /// are worker-count independent; only wall time changes).
    fn analysis_options(&self) -> AnalysisOptions {
        AnalysisOptions { workers: self.cfg.workers, ..AnalysisOptions::default() }
    }

    // ---------------------------------------------------------------

    fn build_artifact(&self, canon: &DesignRequest, fp: Fingerprint) -> Result<DesignArtifact> {
        match canon {
            DesignRequest::Multiplier(m) => {
                let (design, trace) = m.to_spec().build_with_trace(&self.lib, &self.tm)?;
                self.finish_design(canon.clone(), fp, design, Some(&trace))
            }
            DesignRequest::Method(mr) => {
                let (design, trace) = self.build_method(mr)?;
                self.finish_design(canon.clone(), fp, design, Some(&trace))
            }
            DesignRequest::Module(m) => {
                // The stage/PE wraps an inner method design that is itself
                // cached — every clock target shares one inner compile.
                let inner = DesignRequest::Method(MethodRequest {
                    method: m.method,
                    n: m.n,
                    signedness: crate::ppg::Signedness::Unsigned,
                    strategy: m.strategy,
                    mac: m.module == ModuleKind::Systolic,
                    budget: BaselineBudget::default(),
                });
                let inner_art = self.compile(&inner)?;
                let design = inner_art
                    .design()
                    .ok_or_else(|| anyhow!("inner artifact carries no design"))?;
                let sta = Sta { clock_ghz: m.freq_hz / 1e9, ..self.sta.clone() };
                match m.module {
                    ModuleKind::Fir => {
                        let (netlist, y) = modules::fir::stage_from_design(design)?;
                        let rep = sta.analyze(&netlist);
                        let mut timing = inner_art.timing;
                        timing.merge(&TimingStats::full_pass(netlist.len()));
                        let report = modules::fir::report_from_stage(&rep, m.n, m.freq_hz);
                        // Module bodies carry no datapath trace (the stage
                        // adder is not a compressor tree); structural-only.
                        let lint_rep = LintReport::from_diagnostics(lint::lint_netlist(
                            &netlist,
                            &LintOptions::default(),
                        ));
                        self.lint_gate(&lint_rep)?;
                        // Module bodies are bare netlists: the semantic
                        // sweep runs without the design-level cross-check.
                        let analysis_rep =
                            analysis::analyze_netlist(&netlist, &self.analysis_options()).report;
                        Ok(DesignArtifact {
                            request: canon.clone(),
                            fingerprint: fp,
                            sta: rep,
                            timing,
                            body: ArtifactBody::FirStage { netlist, y, report },
                            verified: None,
                            pjrt_verified: None,
                            lint: Some(lint_rep),
                            analysis: Some(analysis_rep),
                        })
                    }
                    ModuleKind::Systolic => {
                        let rep = sta.analyze(&design.netlist);
                        let mut timing = inner_art.timing;
                        timing.merge(&TimingStats::full_pass(design.netlist.len()));
                        let report = modules::systolic::report_from_pe(&rep, m.n, m.freq_hz);
                        // The PE *is* the inner design's netlist — its full
                        // lint and analysis (run when the inner compile
                        // finished) carry over unchanged.
                        let lint_rep = inner_art.lint.clone();
                        let analysis_rep = inner_art.analysis.clone();
                        Ok(DesignArtifact {
                            request: canon.clone(),
                            fingerprint: fp,
                            sta: rep,
                            timing,
                            body: ArtifactBody::SystolicPe { pe: design.clone(), report },
                            verified: inner_art.verified,
                            pjrt_verified: inner_art.pjrt_verified,
                            lint: lint_rep,
                            analysis: analysis_rep,
                        })
                    }
                }
            }
        }
    }

    /// Build a method-form request (post-canonicalization this is only the
    /// search-based RL-MUL, but any method compiles correctly).
    fn build_method(&self, mr: &MethodRequest) -> Result<(Design, DatapathTrace)> {
        let fmt = crate::ppg::OperandFormat {
            signedness: mr.signedness,
            a_bits: mr.n,
            b_bits: mr.n,
        };
        let spec = baselines::method_spec_fmt(
            mr.method,
            fmt,
            mr.strategy,
            mr.mac,
            &mr.budget,
            &self.lib,
        );
        spec.build_with_trace(&self.lib, &self.tm)
    }

    /// Fail the compile when the report reaches the configured deny
    /// severity. The rendered diagnostics travel in the error so callers
    /// (CLI, server) surface *what* was wrong, not just that the gate fired.
    fn lint_gate(&self, report: &LintReport) -> Result<()> {
        if let Some(deny) = self.cfg.lint_deny {
            if report.denies(deny) {
                return Err(anyhow!("lint gate rejected the design:\n{report}"));
            }
        }
        Ok(())
    }

    fn finish_design(
        &self,
        request: DesignRequest,
        fingerprint: Fingerprint,
        design: Design,
        trace: Option<&DatapathTrace>,
    ) -> Result<DesignArtifact> {
        let sta = self.sta.analyze(&design.netlist);
        // Build-time work (the CPA's incremental optimize loop) plus the
        // engine's own full analysis pass.
        let mut timing = design.timing;
        timing.merge(&TimingStats::full_pass(design.netlist.len()));
        // Static analysis gates the compile *before* simulation is paid
        // for: a malformed candidate never reaches the equivalence sweep.
        let lint_rep = lint::lint_design(&design, trace, &self.lib, &LintOptions::default());
        self.lint_gate(&lint_rep)?;
        // Semantic sweep: abstract interpretation over the final netlist
        // plus the design-level weight-conservation cross-check. Findings
        // are stored, not gated — `ufo-mac analyze --deny` is the policy
        // point (legitimate designs prove constants, e.g. Booth/B-W
        // injection bits, which must not fail compiles).
        let analysis_rep = analysis::analyze_design(&design, &self.analysis_options()).report;
        let verified = if self.cfg.verify_vectors > 0 {
            // Single-threaded sweep: compiles already fan out across the
            // engine's worker pool (compile_batch, the server), so a
            // parallel inner verify would only oversubscribe the cores.
            // Lane width comes from the process-wide default (UFO_SIM_WIDTH)
            // — reports are width-independent, so this is purely throughput.
            let opts = crate::equiv::EquivOptions {
                budget: self.cfg.verify_vectors,
                threads: 1,
                ..Default::default()
            };
            Some(crate::equiv::check_multiplier_opts(&design, &opts)?.passed)
        } else {
            None
        };
        let pjrt_verified = self.pjrt_check(&design);
        Ok(DesignArtifact {
            request,
            fingerprint,
            sta,
            timing,
            body: ArtifactBody::Design(design),
            verified,
            pjrt_verified,
            lint: Some(lint_rep),
            analysis: Some(analysis_rep),
        })
    }

    fn pjrt_check(&self, design: &Design) -> Option<bool> {
        // The PJRT netlist encoding is combinational-only (no register
        // opcode in the kernel wire format); pipelined designs are covered
        // by the clocked equivalence sweep instead.
        if design.pipeline.is_some() {
            return None;
        }
        // One runtime, one lock: PJRT verification serializes across batch
        // workers. Fine for the cross-check's sample sizes; per-worker
        // runtimes would trade memory (a compiled executable cache each)
        // for parallel verification if this ever dominates.
        let rt = self.runtime.as_ref()?.lock().unwrap();
        if rt.has_artifact("netlist_eval_small") {
            verify_design_pjrt(&rt, design, 1).ok()
        } else {
            None
        }
    }
}

static GLOBAL_ENGINE: OnceLock<Arc<SynthEngine>> = OnceLock::new();

/// The process-wide engine behind the legacy shims
/// (`MultiplierSpec::build`, `baselines::build_design`, the module report
/// helpers). Default config: no per-compile verification, no PJRT.
///
/// Its cache is unbounded and lives for the process: long-running services
/// iterating over unbounded request spaces (e.g. RL-MUL seed sweeps, where
/// every budget/seed pair is a distinct fingerprint) should either call
/// [`SynthEngine::clear_cache`] between phases or use a scoped engine.
pub fn global() -> Arc<SynthEngine> {
    GLOBAL_ENGINE.get_or_init(|| Arc::new(SynthEngine::new(EngineConfig::default()))).clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Method;
    use crate::multiplier::{MultiplierSpec, Strategy};

    #[test]
    fn repeated_compile_is_cached_and_identical() {
        let eng = SynthEngine::new(EngineConfig::default());
        let req = DesignRequest::multiplier(6);
        let a = eng.compile(&req).unwrap();
        let b = eng.compile(&req).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second compile must be the cached Arc");
        let s = eng.cache_stats();
        assert!(s.hits >= 1, "stats {s:?}");
        assert_eq!(a.fingerprint, req.fingerprint());
    }

    #[test]
    fn method_and_spec_share_one_artifact() {
        let eng = SynthEngine::new(EngineConfig::default());
        let via_method =
            eng.compile(&DesignRequest::method(Method::UfoMac, 6, Strategy::TradeOff, false)).unwrap();
        let via_spec = eng
            .compile(&DesignRequest::from_spec(
                &MultiplierSpec::new(6).strategy(Strategy::TradeOff),
            ))
            .unwrap();
        assert!(Arc::ptr_eq(&via_method, &via_spec));
    }

    #[test]
    fn verification_is_engine_config() {
        let eng = SynthEngine::new(EngineConfig { verify_vectors: 256, ..Default::default() });
        let art = eng.compile(&DesignRequest::multiplier(4)).unwrap();
        assert_eq!(art.verified, Some(true));
        assert!(art.sta.critical_delay_ns > 0.0);
    }

    #[test]
    fn artifacts_carry_a_clean_lint_report() {
        let eng = SynthEngine::new(EngineConfig::default());
        for req in [
            DesignRequest::multiplier(4),
            DesignRequest::fir(Method::UfoMac, 4, Strategy::TradeOff, 1e9),
            DesignRequest::systolic(Method::UfoMac, 4, Strategy::TradeOff, 1e9),
        ] {
            let art = eng.compile(&req).unwrap();
            let rep = art.lint.as_ref().expect("fresh compiles store a lint report");
            assert!(rep.is_clean(), "{req:?}: {rep}");
            // The lint entry point reuses the stored report.
            let (again, _, _) = eng.lint(&req).unwrap();
            assert!(again.is_clean());
        }
    }

    #[test]
    fn artifacts_carry_an_analysis_report() {
        let eng = SynthEngine::new(EngineConfig::default());
        for req in [
            DesignRequest::multiplier(4),
            DesignRequest::fir(Method::UfoMac, 4, Strategy::TradeOff, 1e9),
            DesignRequest::systolic(Method::UfoMac, 4, Strategy::TradeOff, 1e9),
        ] {
            let art = eng.compile(&req).unwrap();
            let rep = art.analysis.as_ref().expect("fresh compiles store an analysis report");
            assert_eq!(rep.nodes, art.netlist().len(), "{req:?}");
            assert!(!rep.denies(Severity::Error), "{req:?}: {rep}");
            assert!(rep.mean_activity > 0.0, "{req:?}");
            // The analyze entry point reuses the stored report.
            let (again, _, _) = eng.analyze(&req).unwrap();
            assert_eq!(&again, rep);
        }
    }

    #[test]
    fn pipelined_compile_verifies_through_the_clocked_sweep() {
        let eng = SynthEngine::new(EngineConfig { verify_vectors: 256, ..Default::default() });
        let req = DesignRequest::from_spec(&MultiplierSpec::new(4).pipeline_stages(2));
        let art = eng.compile(&req).unwrap();
        // The equivalence budget routes to the bounded sequential check;
        // the PJRT cross-check abstains (combinational-only encoding).
        assert_eq!(art.verified, Some(true));
        assert_eq!(art.pjrt_verified, None);
        assert!(art.sta.critical_delay_ns > 0.0);
    }

    #[test]
    fn lint_gate_rejects_malformed_plan_without_simulation() {
        // An infeasible explicit CT plan must fail the compile at the
        // static-analysis layer — with a verification budget configured,
        // reaching the equivalence sweep would mean simulating a tree that
        // cannot even be built.
        let eng = SynthEngine::new(EngineConfig { verify_vectors: 256, ..Default::default() });
        let plan = crate::ct::StagePlan { f: vec![vec![9, 0, 0]], h: vec![vec![0, 0, 0]] };
        let req = DesignRequest::from_spec(&MultiplierSpec::new(2).with_plan(plan));
        let err = format!("{:#}", eng.compile(&req).unwrap_err());
        assert!(err.contains("UFO1"), "error must carry the lint code: {err}");
    }

    #[test]
    fn compile_results_expose_timing_stats() {
        let eng = SynthEngine::new(EngineConfig::default());
        let art = eng.compile(&DesignRequest::multiplier(8)).unwrap();
        let t = art.timing;
        // The engine's own analysis pass plus the CPA candidate scoring
        // all surface here.
        assert!(t.full_passes >= 2, "{t:?}");
        assert!(t.nodes_total >= art.netlist().len() as u64, "{t:?}");
        assert!(t.retime_fraction() <= 1.0);
    }

    #[test]
    fn concurrent_identical_compiles_coalesce() {
        let eng = SynthEngine::new(EngineConfig::default());
        let req = DesignRequest::multiplier(7);
        let n = 8;
        let barrier = std::sync::Barrier::new(n);
        let sources = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..n {
                s.spawn(|| {
                    barrier.wait();
                    let (_, src) = eng.compile_traced(&req).unwrap();
                    sources.lock().unwrap().push(src);
                });
            }
        });
        let sources = sources.into_inner().unwrap();
        // Exactly one synthesis; everyone else waited (or, if they raced
        // in after the leader finished, hit the cache).
        let compiled =
            sources.iter().filter(|s| **s == CompileSource::Compiled).count();
        assert_eq!(compiled, 1, "{sources:?}");
        let s = eng.cache_stats();
        let coalesced =
            sources.iter().filter(|s| **s == CompileSource::Coalesced).count() as u64;
        assert_eq!(s.coalesced, coalesced, "{sources:?}");
        // Coalesced and converted lookups are reclassified: only the one
        // real synthesis remains a miss.
        assert_eq!(s.misses, 1, "{s:?} {sources:?}");
    }

    #[test]
    fn failed_compile_propagates_to_coalesced_waiters() {
        // Width 0 fails deterministically; N concurrent callers must all
        // see an error (none may hang on the in-flight entry).
        let eng = SynthEngine::new(EngineConfig::default());
        let req = DesignRequest::multiplier(0);
        let barrier = std::sync::Barrier::new(4);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    barrier.wait();
                    assert!(eng.compile(&req).is_err());
                });
            }
        });
        // The in-flight table must be empty again (errors are not cached).
        assert!(eng.inflight.lock().unwrap().is_empty());
    }

    #[test]
    fn module_requests_share_the_inner_design() {
        let eng = SynthEngine::new(EngineConfig::default());
        let a = eng.compile(&DesignRequest::fir(Method::UfoMac, 4, Strategy::TradeOff, 1e9)).unwrap();
        assert!(a.module_report().is_some());
        // A second clock target re-uses the cached inner multiplier: the
        // only new compile is the stage itself.
        let before = eng.cache_stats();
        let b = eng.compile(&DesignRequest::fir(Method::UfoMac, 4, Strategy::TradeOff, 2e9)).unwrap();
        let after = eng.cache_stats();
        assert!(after.hits > before.hits, "inner design must be a cache hit");
        let (ra, rb) = (a.module_report().unwrap(), b.module_report().unwrap());
        assert!(rb.wns_ns < ra.wns_ns, "tighter clock must tighten WNS");
    }
}
