//! §3.4/§3.5 — interconnection-order optimization and CT construction.
//!
//! Given a [`StagePlan`], this module instantiates the compressor tree into
//! a netlist slice by slice (`Slice_{i,j}` = the compressors of stage `i`,
//! column `j`). Within each slice, the bijection between arriving partial
//! products (sources) and compressor ports / pass-throughs (sinks) is the
//! design space the paper opens up (Figure 4 shows >10 % delay spread over
//! random orders). Strategies:
//!
//! - [`OrderStrategy::Optimized`] — the paper's ILP objective solved
//!   exactly per slice: the permutation-matrix program (Eq. 19-23)
//!   restricted to one slice *is* a bottleneck assignment problem, which
//!   [`crate::ilp::assignment::bottleneck_assignment`] solves exactly
//!   (min-max completion, min-sum tie-break). Slices are processed in
//!   stage order so each slice sees the exact arrival times produced by
//!   the previous one — the same information flow as the monolithic ILP,
//!   decomposed for tractability (documented in DESIGN.md).
//! - [`OrderStrategy::Naive`] — sources connect to ports in arrival order
//!   (what a straightforward RTL generator does).
//! - [`OrderStrategy::Random`] — a seeded random bijection (drives the
//!   Figure-4 experiment).

use super::stage::StagePlan;
use crate::ilp::assignment::bottleneck_assignment;
use crate::ir::Netlist;
use crate::synth::{full_adder, half_adder, CompressorTiming, Sig};
use crate::util::Rng;

/// Interconnect-order strategy for CT construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OrderStrategy {
    /// Exact per-slice bottleneck assignment (the paper's ILP objective).
    Optimized,
    /// Sources connect to ports in arrival order.
    Naive,
    /// Seeded random bijection (the Figure-4 experiment).
    Random(u64),
}

/// The compressed output: per column, the (at most two) result bits, plus
/// the arrival estimate profile that drives CPA optimization.
#[derive(Debug, Clone)]
pub struct CtOutput {
    /// `rows[j]` = the 1-2 output bits of column `j`.
    pub rows: Vec<Vec<Sig>>,
    /// Worst model-estimated arrival per column (the Figure-1 trapezoid).
    pub profile: Vec<f64>,
    /// Stages actually realized.
    pub stages: usize,
    /// Exact per-stage arrival snapshots recorded during construction:
    /// `stage_profiles[i][j]` = worst arrival of column `j` *after* stage
    /// `i` fired. Recorded for free while building; the final snapshot *is*
    /// [`CtOutput::profile`] (reused, not recomputed), and the
    /// intermediate ones validate the model-level
    /// [`super::StageTiming`] snapshots in tests.
    pub stage_profiles: Vec<Vec<f64>>,
}

impl CtOutput {
    /// Worst arrival estimate over all columns.
    pub fn max_arrival(&self) -> f64 {
        self.profile.iter().copied().fold(0.0, f64::max)
    }
}

/// Port descriptor used for slice assignment.
#[derive(Debug, Clone, Copy)]
enum Sink {
    Fa { comp: usize, port: usize },
    Ha { comp: usize, port: usize },
    Pass,
}

/// Build the compressor tree into `nl` following `plan`, using `strategy`
/// for intra-slice interconnection order.
///
/// `columns` provides the initial per-column signals (from the PPG) and is
/// consumed. Panics if `plan` is inconsistent with the column populations
/// (callers validate plans against Algorithm-1 counts first).
pub fn build_ct(
    nl: &mut Netlist,
    tm: &CompressorTiming,
    columns: Vec<Vec<Sig>>,
    plan: &StagePlan,
    strategy: OrderStrategy,
) -> CtOutput {
    let w = plan.width().max(columns.len());
    let mut state: Vec<Vec<Sig>> = columns;
    state.resize(w, Vec::new());
    let mut rng = match strategy {
        OrderStrategy::Random(seed) => Some(Rng::seed_from_u64(seed)),
        _ => None,
    };
    // The plan fixes the gate population exactly: 5 gates per 3:2 and 2 per
    // 2:2 compressor. One up-front reservation keeps node insertion from
    // reallocating mid-build (EXPERIMENTS.md §Perf, `netlist_build_64x64`).
    let (total_fa, total_ha) = plan.compressor_totals();
    nl.reserve(5 * total_fa + 2 * total_ha);

    let column_worst = |state: &[Vec<Sig>]| -> Vec<f64> {
        state.iter().map(|c| c.iter().map(|s| s.t).fold(0.0, f64::max)).collect()
    };
    let mut stage_profiles: Vec<Vec<f64>> = Vec::with_capacity(plan.stages());

    // Per-slice scratch, hoisted out of the stage loop and reused so the
    // steady state of the build is allocation-free: sources/sinks/cost
    // rows/compressor-port tables all keep their high-water capacity.
    let mut next: Vec<Vec<Sig>> = vec![Vec::new(); w];
    let mut sources: Vec<Sig> = Vec::new();
    let mut sinks: Vec<Sink> = Vec::new();
    let mut cost: Vec<Vec<f64>> = Vec::new();
    let mut perm: Vec<usize> = Vec::new();
    let mut fa_in: Vec<[Option<Sig>; 3]> = Vec::new();
    let mut ha_in: Vec<[Option<Sig>; 2]> = Vec::new();

    for i in 0..plan.stages() {
        for col in next.iter_mut() {
            col.clear();
        }
        for j in 0..w {
            let (nf, nh) = if j < plan.width() {
                (plan.f[i][j], plan.h[i][j])
            } else {
                (0, 0)
            };
            // Drain the column into the reusable source buffer; the column
            // Vec keeps its capacity for the ping-ponged next stage.
            sources.clear();
            sources.append(&mut state[j]);
            let m = sources.len();
            assert!(
                3 * nf + 2 * nh <= m,
                "slice ({i},{j}): {m} sources cannot feed {nf}×3:2 + {nh}×2:2"
            );

            // Sink list: FA ports, HA ports, then pass-throughs.
            sinks.clear();
            for c in 0..nf {
                for p in 0..3 {
                    sinks.push(Sink::Fa { comp: c, port: p });
                }
            }
            for c in 0..nh {
                for p in 0..2 {
                    sinks.push(Sink::Ha { comp: c, port: p });
                }
            }
            while sinks.len() < m {
                sinks.push(Sink::Pass);
            }

            // Decide the bijection source→sink.
            match strategy {
                OrderStrategy::Naive => {
                    perm.clear();
                    perm.extend(0..m);
                }
                OrderStrategy::Random(_) => {
                    perm.clear();
                    perm.extend(0..m);
                    rng.as_mut().unwrap().shuffle(&mut perm);
                }
                OrderStrategy::Optimized => {
                    if m == 0 {
                        perm.clear();
                    } else {
                        // cost[u][v] = arrival(u) + worst port→output delay(v)
                        while cost.len() < m {
                            cost.push(Vec::new());
                        }
                        for (u, s) in sources.iter().enumerate() {
                            let row = &mut cost[u];
                            row.clear();
                            row.extend(sinks.iter().map(|snk| {
                                s.t + match snk {
                                    Sink::Fa { port, .. } => tm.fa_port_worst(*port),
                                    Sink::Ha { .. } => tm.ha_port_worst(),
                                    Sink::Pass => 0.0,
                                }
                            }));
                        }
                        perm = bottleneck_assignment(&cost[..m]).0;
                    }
                }
            }

            // Gather per-compressor inputs.
            fa_in.clear();
            fa_in.resize(nf, [None; 3]);
            ha_in.clear();
            ha_in.resize(nh, [None; 2]);
            for (u, &v) in perm.iter().enumerate() {
                match sinks[v] {
                    Sink::Fa { comp, port } => fa_in[comp][port] = Some(sources[u]),
                    Sink::Ha { comp, port } => ha_in[comp][port] = Some(sources[u]),
                    Sink::Pass => next[j].push(sources[u]),
                }
            }

            // Instantiate.
            for ins in &fa_in {
                let out = full_adder(nl, tm, ins[0].unwrap(), ins[1].unwrap(), ins[2].unwrap());
                next[j].push(out.sum);
                if j + 1 < w {
                    next[j + 1].push(out.carry);
                }
            }
            for ins in &ha_in {
                let out = half_adder(nl, tm, ins[0].unwrap(), ins[1].unwrap());
                next[j].push(out.sum);
                if j + 1 < w {
                    next[j + 1].push(out.carry);
                }
            }
        }
        std::mem::swap(&mut state, &mut next);
        stage_profiles.push(column_worst(&state));
    }

    for (j, col) in state.iter().enumerate() {
        assert!(col.len() <= 2, "column {j} ended with {} bits", col.len());
    }
    // The CPA profile is the final stage's snapshot, recorded above.
    let profile: Vec<f64> =
        stage_profiles.last().cloned().unwrap_or_else(|| column_worst(&state));
    CtOutput { rows: state, profile, stages: plan.stages(), stage_profiles }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ct::counts::CtCounts;
    use crate::ct::stage::assign_greedy;
    use crate::ir::{CellLib, Netlist};
    use crate::sim::{pack_lanes, Simulator};

    /// Build a full CT for an n×n AND-array and check the two output rows
    /// sum to a·b for every (a, b).
    fn check_ct(n: usize, strategy: OrderStrategy) {
        let lib = CellLib::nangate45();
        let tm = CompressorTiming::from_lib(&lib);
        let mut nl = Netlist::new("ct");
        let a: Vec<_> = (0..n).map(|i| nl.input(format!("a{i}"))).collect();
        let b: Vec<_> = (0..n).map(|i| nl.input(format!("b{i}"))).collect();
        let m = crate::ppg::and_array(&mut nl, &lib, &a, &b);
        let counts = CtCounts::from_populations(&m.counts());
        let plan = assign_greedy(&counts);
        plan.validate(&counts).unwrap();
        let mut cols = m.columns;
        cols.resize(counts.width(), vec![]);
        let out = build_ct(&mut nl, &tm, cols, &plan, strategy);
        nl.validate().unwrap();

        let mut sim = Simulator::new();
        let all: Vec<(u32, u32)> =
            (0..1u32 << n).flat_map(|x| (0..1u32 << n).map(move |y| (x, y))).collect();
        for chunk in all.chunks(64) {
            let assigns: Vec<Vec<bool>> = chunk
                .iter()
                .map(|(x, y)| {
                    (0..n).map(|k| x >> k & 1 != 0).chain((0..n).map(|k| y >> k & 1 != 0)).collect()
                })
                .collect();
            let words = pack_lanes(&assigns);
            let vals = sim.run(&nl, &words).to_vec();
            for (lane, (x, y)) in chunk.iter().enumerate() {
                let mut total = 0u128;
                for (j, col) in out.rows.iter().enumerate() {
                    for s in col {
                        total += u128::from(vals[s.node.index()] >> lane as u32 & 1) << j;
                    }
                }
                assert_eq!(total, u128::from(*x) * u128::from(*y), "{strategy:?} {x}*{y}");
            }
        }
    }

    #[test]
    fn ct_4x4_correct_all_strategies() {
        check_ct(4, OrderStrategy::Naive);
        check_ct(4, OrderStrategy::Optimized);
        check_ct(4, OrderStrategy::Random(17));
    }

    #[test]
    fn ct_5x5_correct_optimized() {
        check_ct(5, OrderStrategy::Optimized);
    }

    #[test]
    fn optimized_order_not_slower_than_naive() {
        // Model-estimate comparison on a 16-bit CT.
        let n = 16;
        let lib = CellLib::nangate45();
        let tm = CompressorTiming::from_lib(&lib);
        let build = |strategy| {
            let mut nl = Netlist::new("ct");
            let a: Vec<_> = (0..n).map(|i| nl.input(format!("a{i}"))).collect();
            let b: Vec<_> = (0..n).map(|i| nl.input(format!("b{i}"))).collect();
            let m = crate::ppg::and_array(&mut nl, &lib, &a, &b);
            let counts = CtCounts::from_populations(&m.counts());
            let plan = assign_greedy(&counts);
            let mut cols = m.columns;
            cols.resize(counts.width(), vec![]);
            build_ct(&mut nl, &tm, cols, &plan, strategy).max_arrival()
        };
        let opt = build(OrderStrategy::Optimized);
        let naive = build(OrderStrategy::Naive);
        assert!(opt <= naive + 1e-9, "optimized {opt} vs naive {naive}");
    }

    #[test]
    fn stage_profiles_recorded_and_consistent_with_model_snapshot() {
        let n = 8;
        let lib = CellLib::nangate45();
        let tm = CompressorTiming::from_lib(&lib);
        let mut nl = Netlist::new("ct");
        let a: Vec<_> = (0..n).map(|i| nl.input(format!("a{i}"))).collect();
        let b: Vec<_> = (0..n).map(|i| nl.input(format!("b{i}"))).collect();
        let m = crate::ppg::and_array(&mut nl, &lib, &a, &b);
        let counts = CtCounts::from_populations(&m.counts());
        let plan = assign_greedy(&counts);
        let model = plan.timing(&counts.initial, &tm);
        let mut cols = m.columns;
        cols.resize(counts.width(), vec![]);
        let out = build_ct(&mut nl, &tm, cols, &plan, OrderStrategy::Optimized);
        // One exact snapshot per stage; the last one is the CPA profile.
        assert_eq!(out.stage_profiles.len(), plan.stages());
        assert_eq!(out.stage_profiles.last().unwrap(), &out.profile);
        // The once-computed model snapshot tracks the exact profile: same
        // width, and its worst column is an upper-envelope-style estimate
        // of the exact worst (worst-per-column aggregation is pessimistic,
        // allow slack both ways).
        let exact_max = out.max_arrival();
        let model_max =
            model.final_profile().iter().copied().fold(0.0f64, f64::max);
        assert_eq!(model.final_profile().len(), out.profile.len());
        assert!(model_max > 0.5 * exact_max && model_max < 3.0 * exact_max,
            "model {model_max} vs exact {exact_max}");
    }

    #[test]
    fn random_orders_spread_delays() {
        // Figure 4's premise: order affects delay. Ten random seeds must
        // produce at least two distinct arrival estimates.
        let n = 8;
        let lib = CellLib::nangate45();
        let tm = CompressorTiming::from_lib(&lib);
        let mut seen = Vec::new();
        for seed in 0..10 {
            let mut nl = Netlist::new("ct");
            let a: Vec<_> = (0..n).map(|i| nl.input(format!("a{i}"))).collect();
            let b: Vec<_> = (0..n).map(|i| nl.input(format!("b{i}"))).collect();
            let m = crate::ppg::and_array(&mut nl, &lib, &a, &b);
            let counts = CtCounts::from_populations(&m.counts());
            let plan = assign_greedy(&counts);
            let mut cols = m.columns;
            cols.resize(counts.width(), vec![]);
            let out = build_ct(&mut nl, &tm, cols, &plan, OrderStrategy::Random(seed));
            seen.push(out.max_arrival());
        }
        let min = seen.iter().copied().fold(f64::MAX, f64::min);
        let max = seen.iter().copied().fold(f64::MIN, f64::max);
        assert!(max > min, "no delay spread across random orders");
    }
}
