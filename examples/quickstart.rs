//! Quickstart: generate an 8×8 UFO-MAC multiplier, verify it exhaustively,
//! inspect the compressor-tree arrival profile (the Figure-1 trapezoid),
//! and compare against the commercial-IP proxy.
//!
//! Run: `cargo run --release --example quickstart`

use ufo_mac::baselines::{build_design, BaselineBudget, Method};
use ufo_mac::multiplier::{MultiplierSpec, Strategy};
use ufo_mac::sta::Sta;

fn main() -> ufo_mac::Result<()> {
    // 1. One-liner: UFO-MAC 8×8 multiplier with the trade-off strategy.
    let design = MultiplierSpec::new(8).strategy(Strategy::TradeOff).build()?;
    let sta = Sta::default();
    let rep = sta.analyze(&design.netlist);
    println!("UFO-MAC 8×8 multiplier");
    println!("  {} gates, {:.1} µm², {:.4} ns, {:.4} mW @1GHz",
        rep.num_gates, rep.area_um2, rep.critical_delay_ns, rep.power_mw);

    // 2. Exhaustive equivalence (all 65 536 operand pairs).
    let equiv = ufo_mac::equiv::check_multiplier(&design)?;
    assert!(equiv.passed && equiv.exhaustive);
    println!("  equivalence: PASS ({} vectors, exhaustive)", equiv.vectors);

    // 3. The non-uniform CT output profile that drives CPA optimization.
    println!("\nCT arrival profile (ns):");
    let max = design.profile.iter().copied().fold(0.0f64, f64::max);
    for (j, t) in design.profile.iter().enumerate() {
        println!("  col {j:>2}  {t:.4}  {}", "#".repeat((t / max * 40.0) as usize));
    }
    let (r1, r2) = ufo_mac::cpa::detect_regions(&design.profile);
    println!("  → region 1 (RCA): [0,{r1})  region 2 (Sklansky): [{r1},{r2})  region 3 (carry-inc): [{r2},{})",
        design.profile.len());

    // 4. Head-to-head with the commercial proxy at the same strategy.
    let com = build_design(Method::Commercial, 8, Strategy::TradeOff, false,
        &BaselineBudget::default())?;
    let rep_c = sta.analyze(&com.netlist);
    println!("\nCommercial-IP proxy 8×8: {:.1} µm², {:.4} ns", rep_c.area_um2, rep_c.critical_delay_ns);
    println!("UFO-MAC delta: area {:+.1}%, delay {:+.1}%",
        (rep.area_um2 / rep_c.area_um2 - 1.0) * 100.0,
        (rep.critical_delay_ns / rep_c.critical_delay_ns - 1.0) * 100.0);
    Ok(())
}
