//! Minimal benchmark harness (criterion is not vendored in this image).
//!
//! Provides warmup + repeated timed runs with mean/median/min and a
//! machine-readable JSON line per benchmark, so `cargo bench` output can be
//! captured into `bench_output.txt` and EXPERIMENTS.md the same way a
//! criterion run would be.

use crate::util::Json;
use std::time::{Duration, Instant};

/// One measured statistic set, in nanoseconds.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub samples: usize,
}

impl Stats {
    fn from_samples(mut ns: Vec<f64>) -> Stats {
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        Stats {
            mean_ns: ns.iter().sum::<f64>() / n as f64,
            median_ns: ns[n / 2],
            min_ns: ns[0],
            max_ns: ns[n - 1],
            samples: n,
        }
    }
}

fn fmt_time(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Bench runner. Each `cargo bench` target constructs one of these.
pub struct Bench {
    suite: String,
    /// Target per-benchmark measurement budget.
    pub budget: Duration,
    /// Max sample count per benchmark.
    pub max_samples: usize,
}

impl Bench {
    pub fn new(suite: impl Into<String>) -> Self {
        // Honour a quick mode for CI-style smoke runs.
        let quick = std::env::var("UFO_BENCH_QUICK").is_ok();
        Bench {
            suite: suite.into(),
            budget: if quick { Duration::from_millis(200) } else { Duration::from_secs(2) },
            max_samples: if quick { 5 } else { 30 },
        }
    }

    /// Time `f` repeatedly; prints one human line + one JSON line.
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Stats {
        // Warmup.
        let t0 = Instant::now();
        let mut warm = 0;
        while t0.elapsed() < self.budget / 10 && warm < 3 {
            std::hint::black_box(f());
            warm += 1;
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.max_samples
            && (samples.len() < 3 || start.elapsed() < self.budget)
        {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_secs_f64() * 1e9);
        }
        let stats = Stats::from_samples(samples);
        println!(
            "bench {}/{name}: mean {} median {} min {} ({} samples)",
            self.suite,
            fmt_time(stats.mean_ns),
            fmt_time(stats.median_ns),
            fmt_time(stats.min_ns),
            stats.samples
        );
        println!(
            "BENCH_JSON {}",
            Json::obj(vec![
                ("suite", Json::str(self.suite.clone())),
                ("name", Json::str(name)),
                ("mean_ns", Json::num(stats.mean_ns)),
                ("median_ns", Json::num(stats.median_ns)),
                ("min_ns", Json::num(stats.min_ns)),
                ("samples", Json::num(stats.samples as f64)),
            ])
            .render()
        );
        stats
    }

    /// Report a scalar metric (area, delay, R², …) rather than a time — the
    /// figure/table benches are metric reproductions, not microbenchmarks.
    pub fn metric(&self, name: &str, value: f64, unit: &str) {
        println!("metric {}/{name}: {value:.6} {unit}", self.suite);
        println!(
            "BENCH_JSON {}",
            Json::obj(vec![
                ("suite", Json::str(self.suite.clone())),
                ("name", Json::str(name)),
                ("value", Json::num(value)),
                ("unit", Json::str(unit)),
            ])
            .render()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = Stats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 3.0);
        assert_eq!(s.median_ns, 2.0);
        assert!((s.mean_ns - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(500.0).contains("ns"));
        assert!(fmt_time(5_000.0).contains("µs"));
        assert!(fmt_time(5_000_000.0).contains("ms"));
        assert!(fmt_time(5e9).contains(" s"));
    }

    #[test]
    fn bench_runs_and_reports() {
        std::env::set_var("UFO_BENCH_QUICK", "1");
        let b = Bench::new("test");
        let s = b.bench("noop", || 1 + 1);
        assert!(s.samples >= 3);
        assert!(s.min_ns >= 0.0);
    }
}
