//! Compressor-tree optimization (§3 of the paper).
//!
//! Pipeline: [`counts`] (Algorithm 1 optimal compressor counts) →
//! [`stage`] (§3.3 stage assignment: greedy ASAP / exact ILP /
//! GOMIL-style column-serial) → [`interconnect`] (§3.5 interconnection
//! order: exact per-slice assignment / naive / random) → a gate-level
//! netlist plus the non-uniform output arrival profile that drives CPA
//! optimization (§4). [`baseline`] provides Wallace and Dadda schedules on
//! the same plumbing.

pub mod baseline;
pub mod counts;
pub mod interconnect;
pub mod stage;

pub use baseline::{dadda_plan, plan_totals, wallace_plan};
pub use counts::CtCounts;
pub use interconnect::{build_ct, CtOutput, OrderStrategy};
pub use stage::{
    assign_column_serial, assign_greedy, assign_ilp, assign_ilp_with, StagePlan, StageTiming,
};

use crate::ilp::SolveOptions;
use crate::ir::Netlist;
use crate::synth::{CompressorTiming, Sig};

/// Compressor-tree family selector used by the multiplier/MAC generators
/// and the benchmark sweeps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CtArchitecture {
    /// UFO-MAC: Algorithm-1 counts + min-stage assignment + optimized
    /// interconnection order.
    UfoMac,
    /// UFO-MAC counts/stages with the exact §3.3 ILP stage assigner.
    UfoMacIlp,
    /// Wallace ASAP schedule, naive order.
    Wallace,
    /// Dadda just-in-time schedule, naive order (commercial-IP proxy CT).
    Dadda,
    /// GOMIL proxy: area-optimal counts, column-serial stages, naive order.
    Gomil,
}

/// What [`synthesize`] decided on the way to gates: the built two-row
/// output plus the stage plan (and, for count-driven architectures, the
/// Algorithm-1 counts) it executed. The lint subsystem's datapath passes
/// consume this evidence instead of re-deriving the tree from gates.
#[derive(Debug, Clone)]
pub struct CtSynthesis {
    /// The compressed two-row output (what [`synthesize`] returns).
    pub out: CtOutput,
    /// The stage plan that was executed.
    pub plan: StagePlan,
    /// Algorithm-1 counts the plan implements — `Some` only for the
    /// count-driven architectures (UFO-MAC, UFO-MAC-ILP, GOMIL); Wallace
    /// and Dadda schedules are population-driven and carry no counts.
    pub counts: Option<CtCounts>,
}

/// Build a compressor tree of the chosen architecture over `columns`.
///
/// Returns the compressed two-row output; the netlist gains all compressor
/// cells. `order_override` forces a specific interconnect strategy (used by
/// the Figure-4 experiment); otherwise each architecture uses its default.
pub fn synthesize(
    nl: &mut Netlist,
    tm: &CompressorTiming,
    columns: Vec<Vec<Sig>>,
    arch: CtArchitecture,
    order_override: Option<OrderStrategy>,
) -> CtOutput {
    synthesize_traced(nl, tm, columns, arch, order_override).out
}

/// [`synthesize`] that also returns the stage plan / counts it executed,
/// so callers (the multiplier builder feeding [`crate::lint`]) can
/// cross-check the built tree without re-deriving the schedule.
pub fn synthesize_traced(
    nl: &mut Netlist,
    tm: &CompressorTiming,
    columns: Vec<Vec<Sig>>,
    arch: CtArchitecture,
    order_override: Option<OrderStrategy>,
) -> CtSynthesis {
    let populations: Vec<usize> = columns.iter().map(|c| c.len()).collect();
    let (plan, counts, default_order) = match arch {
        CtArchitecture::UfoMac => {
            let c = CtCounts::from_populations(&populations);
            (assign_greedy(&c), Some(c), OrderStrategy::Optimized)
        }
        CtArchitecture::UfoMacIlp => {
            // The greedy plan is computed once and handed to the exact ILP
            // as its stage horizon and fallback incumbent.
            let c = CtCounts::from_populations(&populations);
            let opts = SolveOptions {
                time_limit: std::time::Duration::from_secs(30),
                ..Default::default()
            };
            let greedy = assign_greedy(&c);
            (assign_ilp_with(&c, greedy, &opts).0, Some(c), OrderStrategy::Optimized)
        }
        CtArchitecture::Wallace => (wallace_plan(&populations), None, OrderStrategy::Naive),
        CtArchitecture::Dadda => (dadda_plan(&populations), None, OrderStrategy::Naive),
        CtArchitecture::Gomil => {
            let c = CtCounts::from_populations(&populations);
            (assign_column_serial(&c), Some(c), OrderStrategy::Naive)
        }
    };
    let order = order_override.unwrap_or(default_order);
    let mut cols = columns;
    cols.resize(plan.width().max(cols.len()), Vec::new());
    let out = build_ct(nl, tm, cols, &plan, order);
    CtSynthesis { out, plan, counts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::CellLib;
    use crate::sim::{pack_lanes, Simulator};

    fn exhaustive_check(arch: CtArchitecture, n: usize) {
        let lib = CellLib::nangate45();
        let tm = CompressorTiming::from_lib(&lib);
        let mut nl = Netlist::new("ct");
        let a: Vec<_> = (0..n).map(|i| nl.input(format!("a{i}"))).collect();
        let b: Vec<_> = (0..n).map(|i| nl.input(format!("b{i}"))).collect();
        let m = crate::ppg::and_array(&mut nl, &lib, &a, &b);
        let out = synthesize(&mut nl, &tm, m.columns, arch, None);
        let mut sim = Simulator::new();
        let all: Vec<(u32, u32)> =
            (0..1u32 << n).flat_map(|x| (0..1u32 << n).map(move |y| (x, y))).collect();
        for chunk in all.chunks(64) {
            let assigns: Vec<Vec<bool>> = chunk
                .iter()
                .map(|(x, y)| {
                    (0..n).map(|k| x >> k & 1 != 0).chain((0..n).map(|k| y >> k & 1 != 0)).collect()
                })
                .collect();
            let words = pack_lanes(&assigns);
            let vals = sim.run(&nl, &words).to_vec();
            for (lane, (x, y)) in chunk.iter().enumerate() {
                let mut total = 0u128;
                for (j, col) in out.rows.iter().enumerate() {
                    for s in col {
                        total += u128::from(vals[s.node.index()] >> lane as u32 & 1) << j;
                    }
                }
                assert_eq!(total, u128::from(*x) * u128::from(*y), "{arch:?} {x}*{y}");
            }
        }
    }

    #[test]
    fn all_architectures_correct_4x4() {
        for arch in [
            CtArchitecture::UfoMac,
            CtArchitecture::Wallace,
            CtArchitecture::Dadda,
            CtArchitecture::Gomil,
        ] {
            exhaustive_check(arch, 4);
        }
    }

    #[test]
    fn ilp_architecture_correct_3x3() {
        exhaustive_check(CtArchitecture::UfoMacIlp, 3);
    }

    #[test]
    fn rectangular_and_signed_matrices_compress_correctly() {
        // The CT layer is population-driven: nothing in counts/stage/order
        // may assume the 2n-1 square-multiplier shape. Feed it a 3×5
        // rectangular AND array and a signed 4×4 Baugh–Wooley matrix and
        // check the two-row output still sums to the matrix value.
        let lib = CellLib::nangate45();
        let tm = CompressorTiming::from_lib(&lib);
        for (na, nb, signed) in [(3usize, 5usize, false), (5, 3, false), (4, 4, true)] {
            let mut nl = Netlist::new("ct-rect");
            let a: Vec<_> = (0..na).map(|i| nl.input(format!("a{i}"))).collect();
            let b: Vec<_> = (0..nb).map(|i| nl.input(format!("b{i}"))).collect();
            let m = if signed {
                crate::ppg::and_array_signed(&mut nl, &lib, &a, &b, na + nb)
            } else {
                crate::ppg::and_array(&mut nl, &lib, &a, &b)
            };
            let out = synthesize(&mut nl, &tm, m.columns, CtArchitecture::UfoMac, None);
            nl.validate().unwrap();
            let modulus = 1u128 << (na + nb);
            let mut sim = Simulator::new();
            let all: Vec<(u32, u32)> = (0..1u32 << na)
                .flat_map(|x| (0..1u32 << nb).map(move |y| (x, y)))
                .collect();
            for chunk in all.chunks(64) {
                let assigns: Vec<Vec<bool>> = chunk
                    .iter()
                    .map(|(x, y)| {
                        (0..na)
                            .map(|k| x >> k & 1 != 0)
                            .chain((0..nb).map(|k| y >> k & 1 != 0))
                            .collect()
                    })
                    .collect();
                let words = pack_lanes(&assigns);
                let vals = sim.run(&nl, &words).to_vec();
                for (lane, (x, y)) in chunk.iter().enumerate() {
                    let mut total = 0u128;
                    for (j, col) in out.rows.iter().enumerate() {
                        for s in col {
                            total += u128::from(vals[s.node.index()] >> lane as u32 & 1) << j;
                        }
                    }
                    let want = if signed {
                        let sx = crate::util::sign_extend(u128::from(*x), na);
                        let sy = crate::util::sign_extend(u128::from(*y), nb);
                        (sx * sy).rem_euclid(modulus as i128) as u128
                    } else {
                        u128::from(*x) * u128::from(*y)
                    };
                    assert_eq!(total % modulus, want % modulus, "{na}x{nb} signed={signed} {x}*{y}");
                }
            }
        }
    }

    #[test]
    fn gomil_tree_is_taller_than_ufo() {
        let lib = CellLib::nangate45();
        let tm = CompressorTiming::from_lib(&lib);
        let stages = |arch| {
            let mut nl = Netlist::new("ct");
            let a: Vec<_> = (0..8).map(|i| nl.input(format!("a{i}"))).collect();
            let b: Vec<_> = (0..8).map(|i| nl.input(format!("b{i}"))).collect();
            let m = crate::ppg::and_array(&mut nl, &lib, &a, &b);
            synthesize(&mut nl, &tm, m.columns, arch, None).stages
        };
        assert!(stages(CtArchitecture::Gomil) > stages(CtArchitecture::UfoMac));
    }
}
