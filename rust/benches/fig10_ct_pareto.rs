//! Figure 10 — Pareto frontiers of synthesized compressor trees
//! (8/16/32-bit). Methods: UFO-MAC CT, RL-MUL CT, commercial-proxy (Dadda)
//! CT. GOMIL is excluded exactly as in the paper ("GOMIL's compressor tree
//! is merged into its RTL and cannot be exactly decoupled").

use ufo_mac::baselines::rlmul;
use ufo_mac::bench::Bench;
use ufo_mac::ct::{self, CtArchitecture, OrderStrategy};
use ufo_mac::ir::{CellLib, Netlist};
use ufo_mac::sta::Sta;
use ufo_mac::synth::CompressorTiming;

#[derive(Clone, Copy)]
struct Point {
    delay_ns: f64,
    area_um2: f64,
}

fn ct_point(n: usize, arch: Option<CtArchitecture>, rlmul_iters: Option<usize>) -> Point {
    let lib = CellLib::nangate45();
    let tm = CompressorTiming::from_lib(&lib);
    let mut nl = Netlist::new("ct");
    let a: Vec<_> = (0..n).map(|i| nl.input(format!("a{i}"))).collect();
    let b: Vec<_> = (0..n).map(|i| nl.input(format!("b{i}"))).collect();
    let m = ufo_mac::ppg::and_array(&mut nl, &lib, &a, &b);
    let out = match (arch, rlmul_iters) {
        (Some(arch), _) => ct::synthesize(&mut nl, &tm, m.columns, arch, None),
        (None, Some(iters)) => {
            let res = rlmul::search(&m.columns, iters, 0xF16);
            let mut cols = m.columns;
            cols.resize(res.plan.width().max(cols.len()), Vec::new());
            ct::build_ct(&mut nl, &tm, cols, &res.plan, OrderStrategy::Naive)
        }
        _ => unreachable!(),
    };
    for (j, col) in out.rows.iter().enumerate() {
        for (k, s) in col.iter().enumerate() {
            nl.output(format!("o{j}_{k}"), s.node);
        }
    }
    let sta = Sta { activity_rounds: 0, ..Sta::default() };
    let rep = sta.analyze(&nl);
    Point { delay_ns: rep.critical_delay_ns, area_um2: rep.area_um2 }
}

fn main() {
    let bench = Bench::new("fig10_ct_pareto");
    let quick = std::env::var("UFO_BENCH_QUICK").is_ok();
    let widths: &[usize] = if quick { &[8, 16] } else { &[8, 16, 32] };
    let rl_iters = if quick { 8 } else { 40 };

    println!("\nFigure 10 reproduction: compressor-tree (delay, area) points");
    for &n in widths {
        let ufo = ct_point(n, Some(CtArchitecture::UfoMac), None);
        let rl = ct_point(n, None, Some(rl_iters));
        let com = ct_point(n, Some(CtArchitecture::Dadda), None);
        let wal = ct_point(n, Some(CtArchitecture::Wallace), None);
        println!("  {n:>2}-bit  UFO-MAC    {:.4} ns  {:.1} µm²", ufo.delay_ns, ufo.area_um2);
        println!("  {n:>2}-bit  RL-MUL     {:.4} ns  {:.1} µm²", rl.delay_ns, rl.area_um2);
        println!("  {n:>2}-bit  commercial {:.4} ns  {:.1} µm²", com.delay_ns, com.area_um2);
        println!("  {n:>2}-bit  (wallace)  {:.4} ns  {:.1} µm²", wal.delay_ns, wal.area_um2);
        bench.metric(&format!("ufo_delay_{n}"), ufo.delay_ns, "ns");
        bench.metric(&format!("ufo_area_{n}"), ufo.area_um2, "um2");
        bench.metric(&format!("rlmul_delay_{n}"), rl.delay_ns, "ns");
        bench.metric(&format!("rlmul_area_{n}"), rl.area_um2, "um2");
        bench.metric(&format!("commercial_delay_{n}"), com.delay_ns, "ns");
        bench.metric(&format!("commercial_area_{n}"), com.area_um2, "um2");

        // Paper's qualitative claim: UFO-MAC CT is not dominated.
        let dominated = (rl.delay_ns <= ufo.delay_ns && rl.area_um2 < ufo.area_um2)
            || (com.delay_ns <= ufo.delay_ns && com.area_um2 < ufo.area_um2)
            || (rl.delay_ns < ufo.delay_ns && rl.area_um2 <= ufo.area_um2)
            || (com.delay_ns < ufo.delay_ns && com.area_um2 <= ufo.area_um2);
        assert!(!dominated, "{n}-bit: UFO-MAC CT dominated by a baseline");
    }

    bench.bench("ufo_ct_build_16bit", || ct_point(16, Some(CtArchitecture::UfoMac), None));
}
