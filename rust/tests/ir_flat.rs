//! Flat-IR acceptance tests (the struct-of-arrays tentpole): the flat
//! netlist must be observationally identical to the classic enum-per-node
//! IR — bit-identical STA arrivals and loads, identical simulation words,
//! identical area/gate-count/depth and byte-identical Verilog and
//! serialization — across the tier-1 design families. The parallel
//! equivalence sweep must report the identical counterexample and vector
//! count for every worker count and every lane width, and each slot of a
//! wide-lane run must match an independent narrow run bit for bit.

use ufo_mac::api::persist::{netlist_from_json, netlist_to_json};
use ufo_mac::equiv::{self, EquivOptions};
use ufo_mac::ir::{CellLib, Netlist, Node, NodeId};
use ufo_mac::multiplier::{Design, MultiplierSpec, OperandFormat};
use ufo_mac::ppg::PpgKind;
use ufo_mac::sim::{CompiledNetlist, Simulator};
use ufo_mac::sta::{node_arrival_ns, Sta};
use ufo_mac::synth::verilog;
use ufo_mac::util::Rng;

/// One design per tier-1 family: plain UFO multiplier, Booth PPG, fused
/// MAC, separate MAC, signed rectangular.
fn families() -> Vec<Design> {
    vec![
        MultiplierSpec::new(8).build().unwrap(),
        MultiplierSpec::new(4).ppg(PpgKind::Booth4).build().unwrap(),
        MultiplierSpec::new(4).fused_mac(true).build().unwrap(),
        MultiplierSpec::new(4).separate_mac(true).build().unwrap(),
        MultiplierSpec::new_fmt(OperandFormat::signed_rect(3, 5)).build().unwrap(),
    ]
}

/// Reference loads computed the seed way, over `Node` views.
fn view_loads(nl: &Netlist, lib: &CellLib) -> Vec<f64> {
    let mut load = vec![0.0f64; nl.len()];
    for n in nl.iter() {
        if let Node::Gate { kind, fanin } = n {
            let cin = lib.params(kind).input_cap;
            for f in fanin {
                load[f.index()] += cin;
            }
        }
    }
    for (_, id) in nl.outputs() {
        load[id.index()] += lib.output_load;
    }
    load
}

#[test]
fn flat_sta_matches_view_reference_bit_for_bit() {
    let sta = Sta { activity_rounds: 0, ..Sta::default() };
    for d in families() {
        let nl = &d.netlist;
        let ctx = nl.name.clone();
        // Loads: view accumulation == flat accumulation, bit for bit.
        let loads = view_loads(nl, &sta.lib);
        assert_eq!(loads, nl.loads(&sta.lib), "{ctx}: loads");
        // Arrivals: the seed per-node view formula == the flat sweep.
        let mut at = vec![0.0f64; nl.len()];
        for i in 0..nl.len() {
            at[i] = node_arrival_ns(&sta.lib, nl.node(NodeId(i as u32)), &at, loads[i]);
        }
        assert_eq!(at, sta.arrivals_ns(nl), "{ctx}: arrivals");
        // Report quantities served by the O(1) counter / cached topology.
        let rep = sta.analyze(nl);
        let view_gates = nl.iter().filter(|n| matches!(n, Node::Gate { .. })).count();
        assert_eq!(rep.num_gates, view_gates, "{ctx}: gate count");
        let mut depths = vec![0u32; nl.len()];
        for (i, n) in nl.iter().enumerate() {
            if let Node::Gate { fanin, .. } = n {
                depths[i] = 1 + fanin.iter().map(|f| depths[f.index()]).max().unwrap_or(0);
            }
        }
        let view_depth = nl.outputs().map(|(_, id)| depths[id.index()]).max().unwrap_or(0);
        assert_eq!(rep.depth, view_depth, "{ctx}: depth");
        let view_area: f64 = nl
            .iter()
            .map(|n| match n {
                Node::Gate { kind, .. } => sta.lib.params(kind).area_um2,
                _ => 0.0,
            })
            .sum();
        assert_eq!(rep.area_um2, view_area, "{ctx}: area");
    }
}

#[test]
fn flat_simulation_matches_view_interpreter() {
    // A seed-style interpreter over Node views vs the zero-copy compiled
    // run — every node word must agree, on every family.
    let mut rng = Rng::seed_from_u64(0xF1A7);
    for d in families() {
        let nl = &d.netlist;
        for _ in 0..4 {
            let words: Vec<u64> = (0..nl.num_inputs()).map(|_| rng.next_u64()).collect();
            let mut view_vals = vec![0u64; nl.len()];
            let mut next_input = 0usize;
            for (i, n) in nl.iter().enumerate() {
                view_vals[i] = match n {
                    Node::Input { .. } => {
                        let w = words[next_input];
                        next_input += 1;
                        w
                    }
                    Node::Const(v) => {
                        if v {
                            !0u64
                        } else {
                            0u64
                        }
                    }
                    Node::Gate { kind, fanin } => {
                        let a = view_vals[fanin[0].index()];
                        let b = fanin.get(1).map_or(0, |f| view_vals[f.index()]);
                        let c = fanin.get(2).map_or(0, |f| view_vals[f.index()]);
                        kind.eval(a, b, c)
                    }
                    Node::Reg { .. } => unreachable!("tier-1 families are combinational"),
                };
            }
            let comp = CompiledNetlist::compile(nl);
            let mut buf = Vec::new();
            comp.run_into(&mut buf, &words);
            assert_eq!(buf, view_vals, "{}: compiled vs view interpreter", nl.name);
            let mut sim = Simulator::new();
            assert_eq!(sim.run(nl, &words), &view_vals[..], "{}: simulator", nl.name);
        }
    }
}

#[test]
fn verilog_is_identical_after_view_roundtrip() {
    // Rebuilding a netlist through the Node-view API must reproduce the
    // emitted Verilog byte for byte — the views carry complete structure.
    for d in families() {
        let nl = &d.netlist;
        let mut rebuilt = Netlist::new(nl.name.clone());
        for n in nl.iter() {
            match n {
                Node::Input { name, arrival_ns } => {
                    rebuilt.input_at(name, arrival_ns);
                }
                Node::Const(v) => {
                    rebuilt.constant(v);
                }
                Node::Gate { kind, fanin } => {
                    rebuilt.gate(kind, fanin);
                }
                Node::Reg { .. } => unreachable!("tier-1 families are combinational"),
            }
        }
        for (name, id) in nl.outputs() {
            rebuilt.output(name, id);
        }
        rebuilt.validate().unwrap();
        assert_eq!(verilog::emit(nl), verilog::emit(&rebuilt), "{}", nl.name);
    }
}

#[test]
fn persisted_netlist_roundtrips_from_flat_arrays() {
    // netlist_to_json reads the flat arrays directly; the reconstruction
    // must re-serialize byte-identically and simulate identically.
    let mut rng = Rng::seed_from_u64(0x5E7A);
    for d in families() {
        let j = netlist_to_json(&d.netlist);
        let back = netlist_from_json(&j).unwrap();
        assert_eq!(j.render(), netlist_to_json(&back).render(), "{}", d.netlist.name);
        assert_eq!(back.len(), d.netlist.len());
        assert_eq!(back.num_inputs(), d.netlist.num_inputs());
        assert_eq!(back.num_outputs(), d.netlist.num_outputs());
        let words: Vec<u64> =
            (0..d.netlist.num_inputs()).map(|_| rng.next_u64()).collect();
        let mut sim = Simulator::new();
        let orig = sim.run(&d.netlist, &words).to_vec();
        let mut sim2 = Simulator::new();
        assert_eq!(sim2.run(&back, &words), &orig[..], "{}", d.netlist.name);
    }
}

#[test]
fn parallel_equiv_reports_identical_counterexamples() {
    // Inject a fault, then sweep every lane width {1,2,4,8} with 1/2/4/7
    // workers: the counterexample, the vector count and the exhaustive
    // flag must be identical across the whole grid — the batch plan and
    // min-index failure selection are worker-count- and width-free.
    let mut small = MultiplierSpec::new(8).build().unwrap();
    small.product[5] = small.product[6]; // exhaustive path (16 operand bits)
    let mut big = MultiplierSpec::new(16).build().unwrap();
    big.product[9] = big.product[3]; // sampled path (32 operand bits)
    for d in [&small, &big] {
        let first = equiv::check_multiplier_opts(
            d,
            &EquivOptions { budget: 4096, threads: 1, width: 1 },
        )
        .unwrap();
        assert!(!first.passed, "{}: fault not detected", d.netlist.name);
        assert!(first.counterexample.is_some());
        for width in [1usize, 2, 4, 8] {
            for threads in [1usize, 2, 4, 7] {
                let r = equiv::check_multiplier_opts(
                    d,
                    &EquivOptions { budget: 4096, threads, width },
                )
                .unwrap();
                let ctx = format!("{} w={width} t={threads}", d.netlist.name);
                assert_eq!(r.passed, first.passed, "{ctx}");
                assert_eq!(r.exhaustive, first.exhaustive, "{ctx}");
                assert_eq!(r.vectors, first.vectors, "{ctx}");
                assert_eq!(
                    r.counterexample, first.counterexample,
                    "{ctx}: counterexample depends on width/worker count"
                );
            }
        }
    }
}

#[test]
fn parallel_equiv_matches_serial_on_passing_designs() {
    let d = MultiplierSpec::new(16).fused_mac(true).build().unwrap();
    let serial = equiv::check_multiplier_opts(
        &d,
        &EquivOptions { budget: 2048, threads: 1, width: 1 },
    )
    .unwrap();
    assert!(serial.passed);
    assert!(!serial.exhaustive);
    assert!(serial.vectors >= 2048);
    for width in [1usize, 4, 8] {
        let parallel = equiv::check_multiplier_opts(
            &d,
            &EquivOptions { budget: 2048, threads: 4, width },
        )
        .unwrap();
        assert!(parallel.passed, "w={width}");
        assert!(!parallel.exhaustive, "w={width}");
        assert_eq!(serial.vectors, parallel.vectors, "w={width}");
    }
}

#[test]
fn wide_lane_slots_match_narrow_reference_on_tier1_families() {
    // The width invariant: slot w of a width-W run over a stride-W slab is
    // bit-identical to an independent 64-lane run over slot w's input
    // words — for every node, every family, every supported width.
    let mut rng = Rng::seed_from_u64(0x51DE);
    for d in families() {
        let nl = &d.netlist;
        let comp = CompiledNetlist::compile(nl);
        let n_in = nl.num_inputs();
        for width in [2usize, 4, 8] {
            // Independent random inputs per slot, interleaved stride-W.
            let per_slot: Vec<Vec<u64>> = (0..width)
                .map(|_| (0..n_in).map(|_| rng.next_u64()).collect())
                .collect();
            let mut slab = vec![0u64; n_in * width];
            for (w, words) in per_slot.iter().enumerate() {
                for (k, &word) in words.iter().enumerate() {
                    slab[k * width + w] = word;
                }
            }
            let mut wide = Vec::new();
            comp.run_wide_into(width, &mut wide, &slab);
            for (w, words) in per_slot.iter().enumerate() {
                let mut narrow = Vec::new();
                comp.run_into(&mut narrow, words);
                for i in 0..nl.len() {
                    assert_eq!(
                        wide[i * width + w],
                        narrow[i],
                        "{}: node {i} slot {w} width {width}",
                        nl.name
                    );
                }
            }
        }
    }
}
