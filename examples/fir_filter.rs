//! Signal-processing scenario (paper §5.3, Table 1): build 5-tap FIR
//! filters from every method's multipliers, check the stage datapath
//! functionally against a software FIR on a real signal, and print the
//! Table-1-style comparison.
//!
//! Run: `cargo run --release --example fir_filter -- --width 8`

use ufo_mac::baselines::Method;
use ufo_mac::modules::fir::{build_fir_stage, fir_report, TAPS};
use ufo_mac::multiplier::Strategy;
use ufo_mac::sim::{lane_value, pack_lanes, Simulator};
use ufo_mac::util::{Args, Table};

fn main() -> ufo_mac::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("width", 8);

    // --- functional check: stream a synthetic "audio" signal through the
    // transposed FIR built from the UFO-MAC stage netlist.
    let (stage, y_bits) = build_fir_stage(Method::UfoMac, n, Strategy::TradeOff)?;
    let im = stage.input_map();
    let order = stage.inputs();
    let pos = |name: &str| order.iter().position(|o| *o == im[name]).unwrap();
    let mask = (1u32 << n) - 1;
    // low-pass-ish coefficient set
    let h: Vec<u32> = (0..TAPS).map(|k| (((k + 1) * 3) as u32) & mask).collect();
    let signal: Vec<u32> =
        (0..32).map(|t| ((8.0 * ((t as f64) * 0.7).sin().abs()) as u32 + t % 3) & mask).collect();

    let mut sim = Simulator::new();
    let mut hw = Vec::new();
    // Transposed FIR state: z[k] carries tap k's partial sum.
    let mut z = vec![0u64; TAPS + 1];
    for &x in &signal {
        let mut znext = vec![0u64; TAPS + 1];
        for k in 0..TAPS {
            // stage k computes x*h[k] + z[k+1]
            let mut assign = vec![false; stage.num_inputs()];
            for bit in 0..n {
                assign[pos(&format!("a{bit}"))] = x >> bit & 1 == 1;
                assign[pos(&format!("b{bit}"))] = h[k] >> bit & 1 == 1;
            }
            for bit in 0..2 * n {
                assign[pos(&format!("z{bit}"))] = z[k + 1] >> bit & 1 == 1;
            }
            let words = pack_lanes(&[assign]);
            let vals = sim.run(&stage, &words).to_vec();
            znext[k] = lane_value(&vals, &y_bits, 0) as u64;
        }
        z = znext;
        hw.push(z[0]);
    }
    // software golden FIR
    let mut sw = Vec::new();
    for t in 0..signal.len() {
        let mut acc = 0u64;
        for (k, &hk) in h.iter().enumerate() {
            if t >= k {
                acc += u64::from(signal[t - k]) * u64::from(hk);
            }
        }
        sw.push(acc & ((1 << (2 * n)) - 1));
    }
    assert_eq!(hw, sw, "hardware FIR disagrees with software FIR");
    println!("functional: 5-tap FIR matches software on {}-sample signal ✓", signal.len());

    // --- Table-1-style report across methods and constraints.
    for (label, freq) in [("area-driven", 660e6), ("timing-driven", 2e9), ("trade-off", 1e9)] {
        let strategy = match label {
            "area-driven" => Strategy::AreaDriven,
            "timing-driven" => Strategy::TimingDriven,
            _ => Strategy::TradeOff,
        };
        let mut table = Table::new(&["method", "WNS(ns)", "area(µm²)", "power(mW)"]);
        for m in Method::ALL {
            let r = fir_report(m, n, strategy, freq)?;
            table.row(vec![
                m.name().into(),
                format!("{:.4}", r.wns_ns),
                format!("{:.0}", r.area_um2),
                format!("{:.3}", r.power_mw),
            ]);
        }
        println!("\n{n}-bit FIR, {label} @ {:.0} MHz:\n{}", freq / 1e6, table.render());
    }
    Ok(())
}
