//! §4.3 / Algorithm 2 — timing-driven prefix-graph optimization.
//!
//! Sweeps bits MSB→LSB; for each bit whose estimated delay (input arrival
//! profile + FDC model over the extracted sub-prefix tree) violates the
//! target, applies one of the two Figure-9 transformations:
//!
//! - **depth-opt** — re-associate the deepest critical-path node
//!   (`GRAPHOPT`), trading a duplicated span for one level less depth;
//! - **fanout-opt** — the same re-association applied at the node whose
//!   non-trivial fan-in has the highest fanout, splitting a hot node.
//!
//! `GRAPHOPT(p)`: with `x = ntf(p)` internal, create `s = tf(p) ∘ tf(x)`
//! and rewire `p = s ∘ ntf(x)`. The graph is re-topologized after each
//! application (our IR keeps fan-ins before consumers).
//!
//! The inner loop is *incremental*: [`DelayCache`] keeps the per-node
//! delay estimates (plus the fanout and blue-mask inputs they depend on)
//! alive across transforms and, after each `GRAPHOPT`, re-evaluates only
//! the nodes whose estimate can have moved — the rewired nodes, nodes
//! whose fanout or colour changed, and their fan-out cones — instead of
//! re-running the whole-graph DP per move. Estimates are bit-identical to
//! [`estimate_bit_delays`] (asserted in debug builds after every move).

use super::graph::{PIdx, PNode, PrefixGraph, NONE};
use super::timing::{blue_mask, fdc_features, FdcModel};
use crate::sta::TimingStats;

/// The Eq.-27 cost model evaluated at one node, given the estimates of its
/// fan-ins — the shared formula of [`estimate_bit_delays`] (full DP) and
/// [`DelayCache`] (incremental re-evaluation).
#[inline]
fn node_est(
    g: &PrefixGraph,
    i: PIdx,
    est: &[f64],
    arrivals: &[f64],
    model: &FdcModel,
    blue: &[bool],
    fo: &[usize],
) -> f64 {
    let nd = g.node(i);
    if nd.is_leaf() {
        // pg stage (half of the intercept) happens at the leaf.
        arrivals.get(nd.msb).copied().unwrap_or(0.0) + model.b * 0.5
    } else {
        let (k_node, k_fan) =
            if blue[i] { (model.k[3], model.k[1]) } else { (model.k[2], model.k[0]) };
        let cost = k_node + k_fan * (fo[i].saturating_sub(1)) as f64;
        est[nd.tf].max(est[nd.ntf]) + cost
    }
}

/// Per-bit delay estimate: an *arrival-aware* DP over the graph applying
/// the FDC cost model node by node — `est(node) = max(est(children)) +
/// k_type + k_fanout·(fanout − 1)` with leaves seeded by the input
/// arrival profile. This is the Eq.-27 model evaluated along real timing
/// paths rather than the depth-critical path, so Algorithm 2's
/// accept/reject decisions track the STA (fanout splits on early-but-hot
/// nodes are visible as improvements).
pub fn estimate_bit_delays(g: &PrefixGraph, arrivals: &[f64], model: &FdcModel) -> Vec<f64> {
    let fo = g.fanouts();
    let blue = blue_mask(g);
    let mut est = vec![0.0f64; g.nodes.len()];
    for i in 0..g.nodes.len() {
        est[i] = node_est(g, i, &est, arrivals, model, &blue, &fo);
    }
    (0..g.n)
        .map(|bit| {
            let r = g.roots[bit];
            if r == NONE {
                0.0
            } else {
                // final sum XOR = the other half of the intercept.
                est[r] + model.b * 0.5
            }
        })
        .collect()
}

/// Incremental evaluator of the Eq.-27 arrival-aware delay model.
///
/// Caches per-node estimates together with the two global quantities they
/// depend on (fanout counts and the blue mask). After a
/// [`graphopt_tracked`] transform, [`DelayCache::update`] carries every
/// surviving node's cached values across the re-topologization remap and
/// re-evaluates only:
///
/// - brand-new nodes (the duplicated span `s`),
/// - nodes whose fanout count or black/blue colour changed (their own cost
///   term moved),
/// - nodes downstream of any re-evaluated node whose estimate actually
///   changed (the fan-out cone).
///
/// Skipped nodes keep values that a full DP would reproduce exactly, so
/// the cache is always bit-identical to [`estimate_bit_delays`].
///
/// Scope note: each update still recomputes the fanout counts and blue
/// mask wholesale (cheap integer sweeps — the blue mask is a global
/// reverse propagation with no cheap incremental form) and diffs them;
/// what the dirty-cone machinery saves, and what
/// [`DelayCache::stats`] counts, is the *delay-model evaluations*
/// (`node_est` calls), the float-heavy part of the DP.
#[derive(Debug, Clone)]
pub struct DelayCache {
    est: Vec<f64>,
    fo: Vec<usize>,
    blue: Vec<bool>,
    stats: TimingStats,
}

impl DelayCache {
    /// Build the cache with one full DP over `g`.
    pub fn new(g: &PrefixGraph, arrivals: &[f64], model: &FdcModel) -> Self {
        let fo = g.fanouts();
        let blue = blue_mask(g);
        let mut est = vec![0.0f64; g.nodes.len()];
        for i in 0..g.nodes.len() {
            est[i] = node_est(g, i, &est, arrivals, model, &blue, &fo);
        }
        DelayCache { est, fo, blue, stats: TimingStats::full_pass(g.nodes.len()) }
    }

    /// Per-bit delays projected from the cached node estimates (matches
    /// [`estimate_bit_delays`] exactly).
    pub fn bit_delays(&self, g: &PrefixGraph, model: &FdcModel) -> Vec<f64> {
        (0..g.n).map(|bit| self.bit_delay(g, model, bit)).collect()
    }

    /// One bit's cached delay — an O(1) read (the inner loop checks single
    /// bits without materializing the whole projection).
    pub fn bit_delay(&self, g: &PrefixGraph, model: &FdcModel, bit: usize) -> f64 {
        let r = g.roots[bit];
        if r == NONE {
            0.0
        } else {
            self.est[r] + model.b * 0.5
        }
    }

    /// Worst cached per-bit delay (allocation-free).
    pub fn worst(&self, g: &PrefixGraph, model: &FdcModel) -> f64 {
        (0..g.n).map(|bit| self.bit_delay(g, model, bit)).fold(0.0f64, f64::max)
    }

    /// Re-time the cache after a transform, given the old→new index remap
    /// returned by [`graphopt_tracked`] / [`retopologize`]. Only the dirty
    /// cone is re-evaluated.
    pub fn update(&mut self, g: &PrefixGraph, arrivals: &[f64], model: &FdcModel, remap: &[PIdx]) {
        let len = g.nodes.len();
        let fo = g.fanouts();
        let blue = blue_mask(g);
        let mut est = vec![0.0f64; len];
        let mut known = vec![false; len];
        let mut known_fo = vec![usize::MAX; len];
        let mut known_blue = vec![false; len];
        for (old, &new) in remap.iter().enumerate() {
            if new == NONE || old >= self.est.len() {
                continue; // dead node, or created after the cache's snapshot
            }
            est[new] = self.est[old];
            known_fo[new] = self.fo[old];
            known_blue[new] = self.blue[old];
            known[new] = true;
        }
        let mut changed = vec![false; len];
        let mut retimed = 0u64;
        for i in 0..len {
            let nd = g.node(i);
            let stale = !known[i]
                || (!nd.is_leaf()
                    && (fo[i] != known_fo[i]
                        || blue[i] != known_blue[i]
                        || changed[nd.tf]
                        || changed[nd.ntf]));
            if stale {
                let v = node_est(g, i, &est, arrivals, model, &blue, &fo);
                retimed += 1;
                if !known[i] || v != est[i] {
                    changed[i] = true;
                }
                est[i] = v;
            }
        }
        self.est = est;
        self.fo = fo;
        self.blue = blue;
        self.stats.incremental_passes += 1;
        self.stats.nodes_retimed += retimed;
        self.stats.nodes_total += len as u64;
    }

    /// Roll the cached estimates back to `snapshot` (a clone taken before
    /// a rejected transform) while *keeping* the work counters — the
    /// evaluation work of a rejected move was still performed.
    pub fn restore_from(&mut self, snapshot: &DelayCache) {
        self.est.clone_from(&snapshot.est);
        self.fo.clone_from(&snapshot.fo);
        self.blue.clone_from(&snapshot.blue);
    }

    /// Cumulative evaluation counters (full vs incremental work).
    pub fn stats(&self) -> TimingStats {
        self.stats
    }
}

/// FDC-feature-based prediction per bit (Eq. 27 evaluated on the critical
/// path features) — kept for the Figure-8 fidelity study.
pub fn predict_bit_delays(g: &PrefixGraph, model: &FdcModel) -> Vec<f64> {
    fdc_features(g).iter().map(|f| model.predict(f)).collect()
}

/// Apply `GRAPHOPT` at node `p`. Returns false if `ntf(p)` is a leaf (no
/// transformation possible). The graph is re-topologized on success.
pub fn graphopt(g: &mut PrefixGraph, p: PIdx) -> bool {
    graphopt_tracked(g, p).is_some()
}

/// [`graphopt`] that also returns the old→new node-index remap of the
/// re-topologization (dead nodes map to [`NONE`]; the freshly created span
/// node is the remap's last entry). [`DelayCache::update`] consumes the
/// remap to re-time only the transform's dirty cone. `None` means the
/// transform did not apply and `g` is untouched.
pub fn graphopt_tracked(g: &mut PrefixGraph, p: PIdx) -> Option<Vec<PIdx>> {
    let pn = g.node(p);
    if pn.is_leaf() {
        return None;
    }
    let x = pn.ntf;
    let xn = g.node(x);
    if xn.is_leaf() {
        return None;
    }
    // s = tf(p) ∘ tf(x): spans [msb_p : lsb(tf(x))].
    let tf_p = g.node(pn.tf);
    let tf_x = g.node(xn.tf);
    // Release-mode invariant (UFO104 class): the transform only preserves
    // prefix semantics when the two trivial fan-ins are span-adjacent; a
    // violation here would silently rewire the carry network.
    assert_eq!(tf_p.lsb, tf_x.msb + 1, "GRAPHOPT on non-adjacent spans");
    let s = PNode { msb: tf_p.msb, lsb: tf_x.lsb, tf: pn.tf, ntf: xn.tf };
    g.nodes.push(s);
    let s_idx = g.nodes.len() - 1;
    g.nodes[p].tf = s_idx;
    g.nodes[p].ntf = xn.ntf;
    Some(retopologize(g))
}

/// Restore the fan-ins-before-consumers node order after in-place rewiring
/// (DFS from the roots; dead nodes dropped). Returns the old→new index
/// remap (dead nodes map to [`NONE`]).
pub fn retopologize(g: &mut PrefixGraph) -> Vec<PIdx> {
    let mut remap = vec![NONE; g.nodes.len()];
    let mut out: Vec<PNode> = Vec::with_capacity(g.nodes.len());
    for i in 0..g.n {
        remap[i] = i;
        out.push(g.nodes[i]);
    }
    // Iterative postorder.
    let mut stack: Vec<(PIdx, bool)> =
        g.roots.iter().filter(|&&r| r != NONE).map(|&r| (r, false)).collect();
    while let Some((i, expanded)) = stack.pop() {
        if remap[i] != NONE {
            continue;
        }
        let nd = g.nodes[i];
        if nd.is_leaf() {
            continue; // already mapped
        }
        if expanded {
            let mut m = nd;
            m.tf = remap[nd.tf];
            m.ntf = remap[nd.ntf];
            // Release-mode invariant (UFO104 class): the postorder pushes
            // both children before re-expanding, so an unmapped child
            // means the traversal itself is broken.
            assert!(m.tf != NONE && m.ntf != NONE, "child not mapped");
            remap[i] = out.len();
            out.push(m);
        } else {
            stack.push((i, true));
            stack.push((nd.tf, false));
            stack.push((nd.ntf, false));
        }
    }
    for r in g.roots.iter_mut() {
        if *r != NONE {
            *r = remap[*r];
        }
    }
    g.nodes = out;
    remap
}

/// Critical (deepest, fanout tie-break) path from `root` down to a leaf.
fn critical_path(g: &PrefixGraph, root: PIdx) -> Vec<PIdx> {
    let depths = g.depths();
    let fo = g.fanouts();
    let mut path = Vec::new();
    let mut cur = root;
    loop {
        path.push(cur);
        let nd = g.node(cur);
        if nd.is_leaf() {
            break;
        }
        let (dt, du) = (depths[nd.tf], depths[nd.ntf]);
        cur = if dt > du || (dt == du && fo[nd.tf] >= fo[nd.ntf]) { nd.tf } else { nd.ntf };
    }
    path
}

/// Nodes of the sub-prefix tree rooted at `root`.
fn subtree(g: &PrefixGraph, root: PIdx) -> Vec<PIdx> {
    let mut seen = vec![false; g.nodes.len()];
    let mut stack = vec![root];
    let mut out = Vec::new();
    while let Some(i) = stack.pop() {
        if seen[i] {
            continue;
        }
        seen[i] = true;
        out.push(i);
        let nd = g.node(i);
        if !nd.is_leaf() {
            stack.push(nd.tf);
            stack.push(nd.ntf);
        }
    }
    out
}

/// Outcome of one optimization run.
#[derive(Debug, Clone)]
pub struct OptReport {
    /// Accepted `GRAPHOPT` applications.
    pub transforms: usize,
    /// Whether every bit's estimate met the target.
    pub met_all: bool,
    /// Worst per-bit delay estimate of the returned graph (ns).
    pub worst_delay_est: f64,
    /// Model-evaluation work: how many prefix nodes the incremental
    /// [`DelayCache`] re-timed vs what per-move full DPs would have cost.
    pub timing: TimingStats,
}

/// Algorithm 2: optimize `g` so each bit's estimated delay meets
/// `target_ns`, given the CT output `arrivals` profile.
///
/// Move evaluation is incremental: one [`DelayCache`] survives the whole
/// run, and each candidate transform re-times only its dirty cone
/// ([`DelayCache::update`]); rejected moves restore the cached estimates
/// alongside the graph snapshot. `OptReport::timing` reports the work
/// saved.
pub fn optimize(
    g: &mut PrefixGraph,
    arrivals: &[f64],
    target_ns: f64,
    model: &FdcModel,
    max_transforms: usize,
) -> OptReport {
    let mut transforms = 0usize;
    let mut cache = DelayCache::new(g, arrivals, model);
    // Track the best graph seen globally (a transform can improve its
    // target bit while regressing another; never return worse than start).
    let mut best_graph = g.clone();
    let mut best_worst = cache.worst(g, model);
    'outer: loop {
        let est = cache.bit_delays(g, model);
        let violated: Vec<usize> = (0..g.n).rev().filter(|&j| est[j] > target_ns + 1e-12).collect();
        if violated.is_empty() {
            break;
        }
        let mut improved_any = false;
        for j in violated {
            if transforms >= max_transforms {
                break 'outer;
            }
            let root = g.roots[j];
            if root == NONE {
                continue;
            }
            let depths = g.depths();
            let span = g.node(root).span();
            let min_depth = (span as f64).log2().ceil() as usize;
            // Line 7: depth-opt when depth exceeds the log2 bound (+1 for
            // LSB-side pg grouping); fanout-opt otherwise.
            let target = if depths[root] > min_depth + 1 {
                // depth-opt: deepest critical-path node with internal ntf.
                critical_path(g, root)
                    .iter()
                    .copied()
                    .filter(|&p| !g.node(p).is_leaf() && !g.node(g.node(p).ntf).is_leaf())
                    .max_by_key(|&p| depths[p])
            } else {
                // fanout-opt: node whose ntf has the highest fanout (> 1).
                let fo = g.fanouts();
                subtree(g, root)
                    .into_iter()
                    .filter(|&p| {
                        let nd = g.node(p);
                        !nd.is_leaf() && !g.node(nd.ntf).is_leaf() && fo[nd.ntf] > 1
                    })
                    .max_by_key(|&p| fo[g.node(p).ntf])
            };
            let Some(target) = target else { continue };
            // Snapshots are taken only once a transform is actually
            // attempted (graph + cached estimates, for the revert path).
            let before = cache.bit_delay(g, model, j);
            let snapshot = g.clone();
            let snap_cache = cache.clone();
            if let Some(remap) = graphopt_tracked(g, target) {
                cache.update(g, arrivals, model, &remap);
                debug_assert_eq!(
                    cache.bit_delays(g, model),
                    estimate_bit_delays(g, arrivals, model),
                    "incremental cache diverged from the full DP"
                );
                if cache.bit_delay(g, model, j) < before - 1e-12 {
                    transforms += 1;
                    improved_any = true;
                    let w = cache.worst(g, model);
                    if w < best_worst - 1e-12 {
                        best_worst = w;
                        best_graph = g.clone();
                    }
                } else {
                    // Non-improving transform: revert graph *and* cache
                    // (keeps area in check and guarantees monotone
                    // progress / termination). Work counters survive the
                    // revert — the evaluation was still paid for.
                    *g = snapshot;
                    cache.restore_from(&snap_cache);
                }
            }
        }
        if !improved_any {
            break;
        }
    }
    if cache.worst(g, model) > best_worst + 1e-12 {
        *g = best_graph;
    }
    g.prune();
    // Release-mode invariant: every transform above must preserve prefix
    // semantics, so the optimized graph still validates. The per-move
    // cache-identity debug_assert stays debug-only (it is O(n) per move);
    // this single exit check is what release/server builds rely on.
    if let Err(e) = g.validate() {
        panic!("GRAPHOPT produced an invalid prefix graph: {e}");
    }
    let mut timing = cache.stats();
    let est = estimate_bit_delays(g, arrivals, model);
    timing.merge(&TimingStats::full_pass(g.nodes.len()));
    let worst = est.iter().copied().fold(0.0f64, f64::max);
    OptReport {
        transforms,
        met_all: est.iter().all(|&e| e <= target_ns + 1e-9),
        worst_delay_est: worst,
        timing,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpa::graph::{ripple, sklansky};
    use crate::cpa::netlist::standalone_adder;
    use crate::sim::{lane_value, pack_lanes, Simulator};

    fn check_adds(g: &PrefixGraph) {
        let n = g.n;
        let (nl, sum) = standalone_adder(g, None);
        nl.validate().unwrap();
        let mut rng = crate::util::Rng::seed_from_u64(3);
        let mut sim = Simulator::new();
        let mask = (1u64 << n) - 1;
        let pairs: Vec<(u64, u64)> =
            (0..64).map(|_| (rng.next_u64() & mask, rng.next_u64() & mask)).collect();
        let assigns: Vec<Vec<bool>> = pairs
            .iter()
            .map(|(x, y)| (0..n).flat_map(|k| [x >> k & 1 != 0, y >> k & 1 != 0]).collect())
            .collect();
        let words = pack_lanes(&assigns);
        let vals = sim.run(&nl, &words).to_vec();
        for (lane, (x, y)) in pairs.iter().enumerate() {
            assert_eq!(lane_value(&vals, &sum, lane as u32), u128::from(x + y));
        }
    }

    #[test]
    fn graphopt_preserves_function_and_reduces_depth() {
        // On a ripple chain, repeated depth-opt must approach log depth.
        let mut g = ripple(16);
        let d0 = g.depth();
        let model = FdcModel::default_prior();
        let arrivals = vec![0.0; 16];
        optimize(&mut g, &arrivals, 0.0 /* unreachable target */, &model, 200);
        g.validate().unwrap();
        assert!(g.depth() < d0, "depth {} not reduced from {}", g.depth(), d0);
        check_adds(&g);
    }

    #[test]
    fn graphopt_single_step_valid() {
        let mut g = ripple(8);
        // root of bit 7 has ntf = root of bit 6 (internal) — transformable.
        let p = g.roots[7];
        assert!(graphopt(&mut g, p));
        g.validate().unwrap();
        check_adds(&g);
    }

    #[test]
    fn optimize_meets_loose_target_without_transforms() {
        let mut g = sklansky(16);
        let model = FdcModel::default_prior();
        let rep = optimize(&mut g, &vec![0.0; 16], 100.0, &model, 100);
        assert!(rep.met_all);
        assert_eq!(rep.transforms, 0);
    }

    #[test]
    fn optimize_respects_arrival_profile() {
        // Late-arriving middle bits (the CT trapezoid) drive estimates.
        let arr: Vec<f64> =
            (0..16).map(|i| if (4..12).contains(&i) { 0.3 } else { 0.1 }).collect();
        let g = ripple(16);
        let model = FdcModel::default_prior();
        let est = estimate_bit_delays(&g, &arr, &model);
        // Bit 15's subtree includes the late middle bits ⇒ est must exceed
        // the model-only delay.
        let est0 = estimate_bit_delays(&g, &vec![0.0; 16], &model);
        assert!(est[15] > est0[15]);
    }

    #[test]
    fn fanout_opt_splits_hot_nodes() {
        // One fanout-opt application at the node whose ntf is hottest must
        // lower that ntf's fanout by one and preserve the function.
        let mut g = sklansky(32);
        let fo = g.fanouts();
        let (p, hot_span, hot_fo) = (g.n..g.nodes.len())
            .filter(|&p| {
                let nd = g.node(p);
                !g.node(nd.ntf).is_leaf() && fo[nd.ntf] > 1
            })
            .map(|p| {
                let x = g.node(p).ntf;
                (p, (g.node(x).msb, g.node(x).lsb), fo[x])
            })
            .max_by_key(|&(_, _, f)| f)
            .unwrap();
        assert!(graphopt(&mut g, p));
        g.validate().unwrap();
        // The hot span's total fanout (summed over duplicates) dropped.
        let fo2 = g.fanouts();
        let hot_fo_after: usize = (g.n..g.nodes.len())
            .filter(|&i| (g.node(i).msb, g.node(i).lsb) == hot_span)
            .map(|i| fo2[i])
            .max()
            .unwrap_or(0);
        assert!(hot_fo_after < hot_fo, "hot fanout {hot_fo}→{hot_fo_after}");
        check_adds(&g);
    }

    #[test]
    fn optimize_with_unreachable_target_terminates_and_stays_correct() {
        let mut g = sklansky(32);
        let model = FdcModel::default_prior();
        let rep = optimize(&mut g, &vec![0.0; 32], 0.0, &model, 64);
        assert!(!rep.met_all);
        g.validate().unwrap();
        check_adds(&g);
        // The incremental cache must have avoided per-move full DPs.
        assert!(rep.timing.incremental_passes > 0);
        assert!(rep.timing.nodes_retimed < rep.timing.nodes_total);
    }

    #[test]
    fn delay_cache_matches_full_dp_across_random_transforms() {
        // Identity invariant: after every tracked GRAPHOPT, the cache's
        // projected bit delays equal a from-scratch estimate_bit_delays.
        let mut g = sklansky(24);
        let model = FdcModel::default_prior();
        let arrivals: Vec<f64> = (0..24).map(|i| 0.05 * ((i % 7) as f64)).collect();
        let mut cache = DelayCache::new(&g, &arrivals, &model);
        assert_eq!(cache.bit_delays(&g, &model), estimate_bit_delays(&g, &arrivals, &model));
        let mut rng = crate::util::Rng::seed_from_u64(9);
        let mut applied = 0;
        for _ in 0..200 {
            if applied >= 12 {
                break;
            }
            let candidates: Vec<usize> = (g.n..g.nodes.len())
                .filter(|&i| {
                    let nd = g.node(i);
                    !nd.is_leaf() && !g.node(nd.ntf).is_leaf()
                })
                .collect();
            if candidates.is_empty() {
                break;
            }
            let p = candidates[rng.index(candidates.len())];
            if let Some(remap) = graphopt_tracked(&mut g, p) {
                cache.update(&g, &arrivals, &model, &remap);
                assert_eq!(
                    cache.bit_delays(&g, &model),
                    estimate_bit_delays(&g, &arrivals, &model),
                    "cache diverged after transform {applied}"
                );
                applied += 1;
            }
        }
        assert!(applied > 0, "no transform applied");
        let s = cache.stats();
        assert!(s.nodes_retimed < s.nodes_total, "incremental updates must skip work: {s:?}");
    }
}
