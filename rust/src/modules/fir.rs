//! 5-tap transposed-form FIR filter (Table 1).
//!
//! Transposed form: `y[t] = h0·x[t] + z1[t-1]`, `zk[t] = h_k·x[t] +
//! z_{k+1}[t-1]` — every pipeline stage is one multiplier followed by one
//! adder, registered. The combinational path that sets the achievable
//! frequency is therefore `multiplier → 2n-bit adder`, which
//! [`build_fir_stage`] instantiates from the generated multiplier design;
//! [`fir_report`] aggregates the full 5-tap filter (5 multipliers,
//! 4 stage adders, pipeline registers).

use super::{ModuleReport, DFF_AREA_UM2, DFF_ENERGY_FJ};
use crate::api::{engine, DesignRequest};
use crate::baselines::Method;
use crate::cpa::{self, CpaColumn, PrefixStructure};
use crate::ir::{Netlist, NodeId};
use crate::multiplier::{Design, Strategy};
use crate::sta::StaReport;
use crate::synth::Sig;
use crate::Result;

/// Tap count of the Table-1 filter.
pub const TAPS: usize = 5;

/// Report for one FIR configuration.
pub type FirReport = ModuleReport;

/// Wrap a generated multiplier design into one transposed-FIR pipeline
/// stage: `x × h + z` where `z` is the previous stage's registered output
/// (arrives at t = 0, like `x`/`h`). Returns the netlist and the stage's
/// output bits. This is the engine's inner path for FIR requests.
pub fn stage_from_design(mult: &Design) -> Result<(Netlist, Vec<NodeId>)> {
    // Stage adder width follows the multiplier's actual product width
    // (a_bits + b_bits), so rectangular formats wrap correctly.
    let w = mult.product.len();
    let mut nl = mult.netlist.clone();
    let z: Vec<NodeId> = (0..w).map(|i| nl.input(format!("z{i}"))).collect();
    let cols: Vec<CpaColumn> = (0..w)
        .map(|j| CpaColumn {
            a: Sig::new(mult.product[j], 0.0),
            b: Some(Sig::new(z[j], 0.0)),
        })
        .collect();
    // The stage adder is a regular structure (the FIR wrapper does not see
    // the CT profile; UFO's advantage lives inside the multiplier).
    let g = cpa::build(PrefixStructure::Sklansky, w);
    let out = cpa::expand(&mut nl, &g, &cols);
    let mut y = out.sum;
    y.truncate(w); // registered width (transposed FIR keeps w + guard in practice)
    for (i, &bit) in y.iter().enumerate() {
        nl.output(format!("y{i}"), bit);
    }
    nl.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok((nl, y))
}

/// Build one transposed-FIR pipeline stage for a method's multiplier.
///
/// Shim over the unified engine: the inner multiplier comes from the
/// process-global design cache. New code should compile
/// [`DesignRequest::fir`] instead.
pub fn build_fir_stage(method: Method, n: usize, strategy: Strategy) -> Result<(Netlist, Vec<NodeId>)> {
    let art = engine().compile(&DesignRequest::method(method, n, strategy, false))?;
    stage_from_design(art.design().expect("method artifact carries a design"))
}

/// Project a measured stage STA report onto the full 5-tap filter.
///
/// Area/power: 5 multipliers + 4 stage adders (one stage netlist measured,
/// scaled) + pipeline registers (4 stages × 2n bits + 5×n coefficient
/// registers + n-bit input register).
pub fn report_from_stage(rep: &StaReport, n: usize, freq_hz: f64) -> FirReport {
    let period_ns = 1e9 / freq_hz;
    let wns_ns = period_ns - rep.critical_delay_ns;
    let regs = (TAPS - 1) * 2 * n + TAPS * n + n;
    // 5 multiplier+adder stages ≈ 5 × (stage area) minus the 5th stage's
    // adder (tap 4 has no incoming z) — keep the symmetric over-count of
    // one adder as margin for the output register stage.
    let area_um2 = TAPS as f64 * rep.area_um2 + regs as f64 * DFF_AREA_UM2;
    let power_mw = TAPS as f64 * rep.power_mw
        + regs as f64 * DFF_ENERGY_FJ * (freq_hz / 1e9) / 1000.0;
    FirReport { freq_hz, wns_ns, area_um2, power_mw }
}

/// Full 5-tap FIR report under a clock target.
///
/// Shim over the unified engine ([`DesignRequest::fir`]); repeated calls
/// are served from the content-addressed cache.
pub fn fir_report(method: Method, n: usize, strategy: Strategy, freq_hz: f64) -> Result<FirReport> {
    let art = engine().compile(&DesignRequest::fir(method, n, strategy, freq_hz))?;
    Ok(art.module_report().expect("fir artifact carries a report").clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{lane_value, pack_lanes, Simulator};

    #[test]
    fn fir_stage_computes_x_h_plus_z() {
        let (nl, y) = build_fir_stage(Method::UfoMac, 4, Strategy::TradeOff).unwrap();
        let im = nl.input_map();
        let mut sim = Simulator::new();
        let mut rng = crate::util::Rng::seed_from_u64(21);
        for _ in 0..8 {
            let x = rng.below(16) as u32;
            let h = rng.below(16) as u32;
            let z = rng.below(200) as u32;
            let mut assigns = vec![false; nl.num_inputs()];
            let order: Vec<NodeId> = nl.inputs();
            let pos = |id: NodeId| order.iter().position(|&o| o == id).unwrap();
            for k in 0..4 {
                assigns[pos(im[&format!("a{k}")])] = x >> k & 1 == 1;
                assigns[pos(im[&format!("b{k}")])] = h >> k & 1 == 1;
            }
            for k in 0..8 {
                assigns[pos(im[&format!("z{k}")])] = z >> k & 1 == 1;
            }
            let words = pack_lanes(&[assigns]);
            let vals = sim.run(&nl, &words).to_vec();
            let got = lane_value(&vals, &y, 0);
            assert_eq!(got, u128::from((x * h + z) & 0xff), "x={x} h={h} z={z}");
        }
    }

    #[test]
    fn fir_report_fields_consistent() {
        let r = fir_report(Method::UfoMac, 8, Strategy::AreaDriven, 660e6).unwrap();
        assert!(r.area_um2 > 0.0);
        assert!(r.power_mw > 0.0);
        assert!(r.wns_ns < r.period_ns());
        // 660 MHz period is ~1.51 ns.
        assert!((r.period_ns() - 1.515).abs() < 0.01);
    }

    #[test]
    fn ufo_fir_no_worse_than_gomil_fir() {
        let u = fir_report(Method::UfoMac, 8, Strategy::TimingDriven, 2e9).unwrap();
        let g = fir_report(Method::Gomil, 8, Strategy::TimingDriven, 2e9).unwrap();
        assert!(u.wns_ns >= g.wns_ns - 1e-9, "ufo {} vs gomil {}", u.wns_ns, g.wns_ns);
    }
}
