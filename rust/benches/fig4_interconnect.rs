//! Figure 4 — critical-path delay distribution of random interconnection
//! orders on one fixed CT stage structure.
//!
//! The paper synthesizes 10 000 random orders of an identical tree and
//! reports >10 % delay spread. We regenerate the experiment with the STA
//! engine on an 8-bit CT (sample count scaled to the 1-core testbed) and
//! additionally report where the optimized and naive orders fall.

use ufo_mac::bench::Bench;
use ufo_mac::ct::{assign_greedy, build_ct, CtCounts, OrderStrategy};
use ufo_mac::ir::{CellLib, Netlist};
use ufo_mac::sta::Sta;
use ufo_mac::synth::CompressorTiming;

fn ct_delay(n: usize, order: OrderStrategy) -> f64 {
    let lib = CellLib::nangate45();
    let tm = CompressorTiming::from_lib(&lib);
    let mut nl = Netlist::new("ct");
    let a: Vec<_> = (0..n).map(|i| nl.input(format!("a{i}"))).collect();
    let b: Vec<_> = (0..n).map(|i| nl.input(format!("b{i}"))).collect();
    let m = ufo_mac::ppg::and_array(&mut nl, &lib, &a, &b);
    let counts = CtCounts::from_populations(&m.counts());
    let plan = assign_greedy(&counts);
    let mut cols = m.columns;
    cols.resize(counts.width(), vec![]);
    let out = build_ct(&mut nl, &tm, cols, &plan, order);
    for (j, col) in out.rows.iter().enumerate() {
        for (k, s) in col.iter().enumerate() {
            nl.output(format!("o{j}_{k}"), s.node);
        }
    }
    let sta = Sta { activity_rounds: 0, ..Sta::default() };
    sta.analyze(&nl).critical_delay_ns
}

fn main() {
    let bench = Bench::new("fig4_interconnect");
    let n = 8;
    let samples = if std::env::var("UFO_BENCH_QUICK").is_ok() { 100 } else { 2000 };

    let mut delays: Vec<f64> = Vec::with_capacity(samples);
    for seed in 0..samples as u64 {
        delays.push(ct_delay(n, OrderStrategy::Random(seed)));
    }
    delays.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let min = delays[0];
    let max = delays[delays.len() - 1];
    let mean = delays.iter().sum::<f64>() / delays.len() as f64;
    let spread_pct = (max - min) / min * 100.0;

    println!("\nFigure 4 reproduction: {samples} random interconnect orders, {n}-bit CT");
    println!("  min {min:.4} ns   mean {mean:.4} ns   max {max:.4} ns");
    println!("  spread: {spread_pct:.1}% (paper: >10%)");
    // 10-bin histogram (the figure's shape).
    let bins = 10;
    let mut hist = vec![0usize; bins];
    for &d in &delays {
        let b = (((d - min) / (max - min + 1e-12)) * bins as f64) as usize;
        hist[b.min(bins - 1)] += 1;
    }
    for (i, h) in hist.iter().enumerate() {
        let lo = min + (max - min) * i as f64 / bins as f64;
        println!("  {lo:.4} ns | {}", "#".repeat(h * 60 / samples.max(1)));
    }

    let opt = ct_delay(n, OrderStrategy::Optimized);
    let naive = ct_delay(n, OrderStrategy::Naive);
    let order_impact_pct = (max - opt) / opt * 100.0;
    println!("  optimized order: {opt:.4} ns   naive order: {naive:.4} ns");
    println!(
        "  order impact (worst random vs optimized): {order_impact_pct:.1}% \
         (paper: interconnect order moves CT delay by >10%)"
    );
    // Fidelity note (EXPERIMENTS.md): under our fixed-drive logical-effort
    // STA, random orders concentrate near the worst case — almost every
    // random bijection leaves some latest-arriving signal on a slow A/B
    // port, so the max-over-paths barely moves. The paper's synthesized
    // histogram is wider because DC re-sizes gates per netlist. The >10%
    // *impact of ordering* is preserved as the optimized-vs-random gap.

    bench.metric("random_spread_pct", spread_pct, "%");
    bench.metric("order_impact_pct", order_impact_pct, "%");
    bench.metric("optimized_delay", opt, "ns");
    bench.metric("naive_delay", naive, "ns");
    bench.metric("random_min", min, "ns");
    bench.metric("random_max", max, "ns");
    // Timing microbench: one full CT construction + STA with optimization.
    bench.bench("ct_build_optimized_8bit", || ct_delay(8, OrderStrategy::Optimized));

    // The optimized order must sit at (or within noise of) the very best
    // of the random sample — with thousands of samples a lucky draw can
    // tie it to sub-picosecond precision.
    assert!(opt <= min * 1.005, "optimized order must match the best random order");
    assert!(
        order_impact_pct > 5.0,
        "interconnect order must matter (got {order_impact_pct:.1}%)"
    );
}
