//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! coordinator's request path. Python never runs here — `make artifacts`
//! lowers the Layer-1/2 kernels once; this module compiles and caches the
//! executables on the in-process PJRT CPU client.
//!
//! Two workloads (see `python/compile/model.py`):
//! - `netlist_eval_{small,large}` — batched functional verification of an
//!   encoded gate netlist (u32-packed lanes);
//! - `systolic{8,16}` — the 16×16 output-stationary fused-MAC GEMM tile.
//!
//! The `xla` PJRT binding is only present in images that vendor that
//! toolchain, so the executing [`Runtime`] is compiled behind the `pjrt`
//! cargo feature. The default build substitutes a stub with the identical
//! API whose [`Runtime::has_artifact`] always reports `false`, so every
//! caller (the [`crate::api::SynthEngine`], the coordinator, the CLI
//! `verify` subcommand) degrades to simulator-only verification.

use crate::ir::Netlist;
use crate::multiplier::Design;
use crate::Result;
use anyhow::bail;
use std::path::PathBuf;

/// Size buckets — keep in sync with `python/compile/kernels/netlist_eval.py`.
pub const SMALL: (usize, usize) = (2048, 72);
/// Large size bucket `(max nodes, max inputs)`.
pub const LARGE: (usize, usize) = (8192, 144);
/// uint32 words per input (256 vectors per execution).
pub const BATCH: usize = 8;
/// Systolic geometry — keep in sync with `python/compile/kernels/systolic.py`.
pub const PES: usize = 16;
/// Reduction steps per systolic execution.
pub const K_STEPS: usize = 64;

/// Opcodes of the artifact encoding (extends `CellKind::opcode`).
const OP_CONST0: i32 = 11;
const OP_CONST1: i32 = 12;
const OP_INPUT: i32 = 13;
// Not part of the artifact encoding: the kernels are combinational
// evaluators, so `encode_netlist` rejects any netlist carrying this
// opcode instead of shipping a node the kernel would misinterpret.
const OP_REG: i32 = 14;

// The artifact opcodes and the IR's flat-storage opcodes are one scheme —
// `encode_netlist` relies on it to copy columns without translation.
const _: () = {
    assert!(crate::ir::OP_CONST0 as i32 == OP_CONST0);
    assert!(crate::ir::OP_CONST1 as i32 == OP_CONST1);
    assert!(crate::ir::OP_INPUT as i32 == OP_INPUT);
    assert!(crate::ir::OP_REG as i32 == OP_REG);
};

/// A netlist encoded for the PJRT evaluator.
#[derive(Debug, Clone)]
pub struct EncodedNetlist {
    /// Per-node opcode.
    pub ops: Vec<i32>,
    /// First fan-in index per node.
    pub f0: Vec<i32>,
    /// Second fan-in index per node.
    pub f1: Vec<i32>,
    /// Third fan-in index per node.
    pub f2: Vec<i32>,
    /// Node count.
    pub n_nodes: usize,
    /// Primary-input count.
    pub n_inputs: usize,
    /// Bucket name: "small" or "large".
    pub bucket: &'static str,
}

/// Encode a netlist into the padded artifact format.
///
/// The IR's flat storage already uses this opcode scheme (gate opcodes,
/// const-0/1, input-with-ordinal-in-`f0`), so encoding is a column-wise
/// widen-and-copy of the opcode/fanin arrays into the padded `i32` buffers
/// — no node walk, no enum reconstruction.
pub fn encode_netlist(nl: &Netlist) -> Result<EncodedNetlist> {
    if nl.is_sequential() {
        bail!(
            "netlist '{}' has {} registers; the artifact encoding is combinational-only",
            nl.name,
            nl.num_regs()
        );
    }
    let n_nodes = nl.len();
    let n_inputs = nl.num_inputs();
    let (bucket, (max_nodes, _max_inputs)) = if n_nodes <= SMALL.0 && n_inputs <= SMALL.1 {
        ("small", SMALL)
    } else if n_nodes <= LARGE.0 && n_inputs <= LARGE.1 {
        ("large", LARGE)
    } else {
        bail!("netlist too large for artifacts: {n_nodes} nodes / {n_inputs} inputs");
    };
    let mut ops = vec![OP_CONST0; max_nodes];
    let mut f0 = vec![0i32; max_nodes];
    let mut f1 = vec![0i32; max_nodes];
    let mut f2 = vec![0i32; max_nodes];
    let src_ops = nl.ops();
    let src_fan = nl.fanin_records();
    for i in 0..n_nodes {
        // The IR's u8 opcodes coincide with the artifact's i32 opcodes,
        // including the const/input markers (asserted in the unit tests).
        ops[i] = src_ops[i] as i32;
        let rec = src_fan[i];
        // Unused slots are zero in the flat records, matching the padded
        // encoding; inputs carry their ordinal in slot 0.
        f0[i] = rec[0] as i32;
        f1[i] = rec[1] as i32;
        f2[i] = rec[2] as i32;
    }
    Ok(EncodedNetlist { ops, f0, f1, f2, n_nodes, n_inputs, bucket })
}

#[cfg(feature = "pjrt")]
mod pjrt_runtime {
    use super::{EncodedNetlist, BATCH, K_STEPS, LARGE, PES, SMALL};
    use crate::Result;
    use anyhow::{anyhow, bail, Context};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    /// The PJRT runtime: CPU client + compiled-executable cache.
    pub struct Runtime {
        client: xla::PjRtClient,
        artifact_dir: PathBuf,
        exes: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    }

    impl Runtime {
        /// Create a runtime over an artifact directory (default `artifacts/`).
        pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
            Ok(Runtime {
                client,
                artifact_dir: artifact_dir.as_ref().to_path_buf(),
                exes: Mutex::new(HashMap::new()),
            })
        }

        /// True if the artifact file exists (lets callers degrade gracefully
        /// before `make artifacts` has run).
        pub fn has_artifact(&self, name: &str) -> bool {
            self.artifact_dir.join(format!("{name}.hlo.txt")).exists()
        }

        /// PJRT platform name (e.g. `cpu`).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn ensure_compiled(&self, name: &str) -> Result<()> {
            let mut exes = self.exes.lock().unwrap();
            if exes.contains_key(name) {
                return Ok(());
            }
            let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            exes.insert(name.to_string(), exe);
            Ok(())
        }

        fn run(&self, name: &str, args: &[xla::Literal]) -> Result<xla::Literal> {
            self.ensure_compiled(name)?;
            let exes = self.exes.lock().unwrap();
            let exe = exes.get(name).unwrap();
            let result =
                exe.execute::<xla::Literal>(args).map_err(|e| anyhow!("execute {name}: {e:?}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result {name}: {e:?}"))?;
            // Artifacts are lowered with return_tuple=True.
            lit.to_tuple1().map_err(|e| anyhow!("untuple {name}: {e:?}"))
        }

        /// Evaluate an encoded netlist on `BATCH` packed uint32 words per
        /// input. Returns the full node-value buffer `[BATCH][max_nodes]`.
        pub fn eval_netlist(
            &self,
            enc: &EncodedNetlist,
            words: &[Vec<u32>], // [BATCH][n_inputs]
        ) -> Result<Vec<Vec<u32>>> {
            let (max_nodes, max_inputs) = if enc.bucket == "small" { SMALL } else { LARGE };
            assert_eq!(words.len(), BATCH);
            let ops = xla::Literal::vec1(enc.ops.as_slice());
            let f0 = xla::Literal::vec1(enc.f0.as_slice());
            let f1 = xla::Literal::vec1(enc.f1.as_slice());
            let f2 = xla::Literal::vec1(enc.f2.as_slice());
            let mut flat = vec![0u32; BATCH * max_inputs];
            for (b, row) in words.iter().enumerate() {
                assert!(row.len() <= max_inputs);
                flat[b * max_inputs..b * max_inputs + row.len()].copy_from_slice(row);
            }
            let words_lit = xla::Literal::vec1(flat.as_slice())
                .reshape(&[BATCH as i64, max_inputs as i64])
                .map_err(|e| anyhow!("reshape words: {e:?}"))?;
            let name = format!("netlist_eval_{}", enc.bucket);
            let out = self.run(&name, &[ops, f0, f1, f2, words_lit])?;
            let v: Vec<u32> = out.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            assert_eq!(v.len(), BATCH * max_nodes);
            Ok(v.chunks(max_nodes).map(|c| c.to_vec()).collect())
        }

        /// One systolic tile: `c + a·b`. Operands travel as i32 but must be
        /// in the range of the modelled hardware variant (int8 or int16
        /// MACs) — checked here, matching the generated gate-level PE's
        /// width contract.
        pub fn systolic(
            &self,
            a: &[i32], // [PES][K_STEPS] row-major
            b: &[i32], // [K_STEPS][PES]
            c: &[i32], // [PES][PES]
            operand_bits: u32,
        ) -> Result<Vec<i32>> {
            assert_eq!(a.len(), PES * K_STEPS);
            assert_eq!(b.len(), K_STEPS * PES);
            assert_eq!(c.len(), PES * PES);
            let lim = 1i32 << (operand_bits - 1);
            if a.iter().chain(b).any(|&v| v < -lim || v >= lim) {
                bail!("operand outside int{operand_bits} range");
            }
            let a_lit = xla::Literal::vec1(a)
                .reshape(&[PES as i64, K_STEPS as i64])
                .map_err(|e| anyhow!("{e:?}"))?;
            let b_lit = xla::Literal::vec1(b)
                .reshape(&[K_STEPS as i64, PES as i64])
                .map_err(|e| anyhow!("{e:?}"))?;
            let c_lit = xla::Literal::vec1(c)
                .reshape(&[PES as i64, PES as i64])
                .map_err(|e| anyhow!("{e:?}"))?;
            let out = self.run("systolic", &[a_lit, b_lit, c_lit])?;
            out.to_vec().map_err(|e| anyhow!("{e:?}"))
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_runtime::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub_runtime {
    use super::EncodedNetlist;
    use crate::Result;
    use anyhow::bail;
    use std::path::{Path, PathBuf};

    /// API-identical stand-in for the PJRT runtime in builds without the
    /// `pjrt` feature. Reports every artifact as unavailable so callers
    /// fall back to the bit-parallel simulator path.
    pub struct Runtime {
        #[allow(dead_code)]
        artifact_dir: PathBuf,
    }

    impl Runtime {
        /// Stub constructor (always succeeds; nothing is loaded).
        pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
            Ok(Runtime { artifact_dir: artifact_dir.as_ref().to_path_buf() })
        }

        /// Always `false`: without the feature nothing can execute, so
        /// artifacts are reported missing even if the files exist.
        pub fn has_artifact(&self, _name: &str) -> bool {
            false
        }

        /// Stub platform description.
        pub fn platform(&self) -> String {
            "stub (built without the `pjrt` feature)".to_string()
        }

        /// Always errors: rebuild with `--features pjrt` to execute.
        pub fn eval_netlist(
            &self,
            _enc: &EncodedNetlist,
            _words: &[Vec<u32>],
        ) -> Result<Vec<Vec<u32>>> {
            bail!("PJRT runtime unavailable: rebuild with `--features pjrt`");
        }

        /// Always errors: rebuild with `--features pjrt` to execute.
        pub fn systolic(
            &self,
            _a: &[i32],
            _b: &[i32],
            _c: &[i32],
            _operand_bits: u32,
        ) -> Result<Vec<i32>> {
            bail!("PJRT runtime unavailable: rebuild with `--features pjrt`");
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub_runtime::Runtime;

/// Verify a design through the PJRT netlist-eval artifact on `rounds`
/// batches of 256 random vectors each + corner vectors. This is the
/// cross-check between the Rust simulator semantics and the AOT kernel.
pub fn verify_design_pjrt(rt: &Runtime, design: &Design, rounds: usize) -> Result<bool> {
    let enc = encode_netlist(&design.netlist)?;
    let mut rng = crate::util::Rng::seed_from_u64(0x7e57);
    let a_bits = design.a.len();
    let b_bits = design.b.len();
    let c_bits = design.c.len();
    let amask = (1u128 << a_bits) - 1;
    let bmask = (1u128 << b_bits) - 1;
    let cmask = if c_bits == 0 { 0u128 } else { (1u128 << c_bits) - 1 };
    for round in 0..rounds {
        // 256 vectors: lane l of word w encodes test (w*32 + l).
        let mut tests: Vec<(u128, u128, u128)> = Vec::with_capacity(BATCH * 32);
        for t in 0..BATCH * 32 {
            let tv = if round == 0 && t < 4 {
                [(0, 0, 0), (amask, bmask, 0), (amask, 1, 1 & cmask), (1, bmask, cmask)][t]
            } else {
                (
                    u128::from(rng.next_u64()) & amask,
                    u128::from(rng.next_u64()) & bmask,
                    u128::from(rng.next_u64()) & cmask,
                )
            };
            tests.push(tv);
        }
        // Pack into words per input node order (a bits, b bits, c bits).
        let mut words = vec![vec![0u32; enc.n_inputs]; BATCH];
        for (t, (a, b, c)) in tests.iter().enumerate() {
            let (w, lane) = (t / 32, t % 32);
            let mut idx = 0;
            for k in 0..a_bits {
                if a >> k & 1 == 1 {
                    words[w][idx] |= 1 << lane;
                }
                idx += 1;
            }
            for k in 0..b_bits {
                if b >> k & 1 == 1 {
                    words[w][idx] |= 1 << lane;
                }
                idx += 1;
            }
            for k in 0..c_bits {
                if c >> k & 1 == 1 {
                    words[w][idx] |= 1 << lane;
                }
                idx += 1;
            }
        }
        let buf = rt.eval_netlist(&enc, &words)?;
        for (t, (a, b, c)) in tests.iter().enumerate() {
            let (w, lane) = (t / 32, t % 32);
            let mut got = 0u128;
            for (k, bit) in design.product.iter().enumerate() {
                got |= u128::from(buf[w][bit.index()] >> lane & 1) << k;
            }
            if got != design.golden(*a, *b, *c) {
                return Ok(false);
            }
        }
    }
    Ok(true)
}

/// Default artifact directory (workspace-relative).
pub fn default_artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Default persistent design-cache directory (workspace-relative; the
/// `serve` subcommand's `--cache-dir` default — same convention as
/// [`default_artifact_dir`]).
pub fn default_cache_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("design_cache")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::MultiplierSpec;

    #[test]
    fn encoding_matches_simulator_semantics() {
        // encode → interpret in Rust must equal the Simulator.
        let d = MultiplierSpec::new(4).build().unwrap();
        let enc = encode_netlist(&d.netlist).unwrap();
        assert_eq!(enc.bucket, "small");
        assert_eq!(enc.n_inputs, 8);
        let mut rng = crate::util::Rng::seed_from_u64(5);
        let words: Vec<u32> = (0..enc.n_inputs).map(|_| rng.next_u64() as u32).collect();
        // kernel-semantics interpreter (u32 lanes)
        let mut buf = vec![0u32; enc.n_nodes];
        for i in 0..enc.n_nodes {
            let a = buf.get(enc.f0[i] as usize).copied().unwrap_or(0);
            let b = buf.get(enc.f1[i] as usize).copied().unwrap_or(0);
            let c = buf.get(enc.f2[i] as usize).copied().unwrap_or(0);
            buf[i] = match enc.ops[i] {
                0 => a,
                1 => !a,
                2 => a & b,
                3 => a | b,
                4 => !(a & b),
                5 => !(a | b),
                6 => a ^ b,
                7 => !(a ^ b),
                8 => !((a & b) | c),
                9 => !((a | b) & c),
                10 => (a & b) | (a & c) | (b & c),
                11 => 0,
                12 => !0,
                13 => words[enc.f0[i] as usize],
                op => panic!("bad opcode {op}"),
            };
        }
        // simulator on the same lanes
        let mut sim = crate::sim::Simulator::new();
        let w64: Vec<u64> = words.iter().map(|&w| u64::from(w)).collect();
        let vals = sim.run(&d.netlist, &w64);
        for i in 0..enc.n_nodes {
            assert_eq!(buf[i], vals[i] as u32, "node {i}");
        }
    }

    #[test]
    fn encoding_rejects_oversized() {
        let mut nl = crate::ir::Netlist::new("big");
        let a = nl.input("a");
        let mut last = a;
        for _ in 0..LARGE.0 {
            last = nl.inv(last);
        }
        nl.output("o", last);
        assert!(encode_netlist(&nl).is_err());
    }

    #[test]
    fn bucket_selection() {
        let small = MultiplierSpec::new(8).build().unwrap();
        assert_eq!(encode_netlist(&small.netlist).unwrap().bucket, "small");
        let large = MultiplierSpec::new(32).build().unwrap();
        assert_eq!(encode_netlist(&large.netlist).unwrap().bucket, "large");
    }

    #[test]
    fn stub_runtime_degrades_gracefully() {
        // In both build modes `Runtime::new` succeeds; without the `pjrt`
        // feature every artifact reports missing and eval errors cleanly.
        let rt = Runtime::new(default_artifact_dir()).unwrap();
        if cfg!(not(feature = "pjrt")) {
            assert!(!rt.has_artifact("netlist_eval_small"));
            assert!(rt.platform().contains("stub"));
        }
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_roundtrip_if_artifacts_present() {
        // Full PJRT path — exercised once `make artifacts` has run.
        let dir = default_artifact_dir();
        if !dir.join("netlist_eval_small.hlo.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(&dir).unwrap();
        let d = MultiplierSpec::new(8).build().unwrap();
        assert!(verify_design_pjrt(&rt, &d, 2).unwrap());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn systolic_pjrt_if_artifacts_present() {
        let dir = default_artifact_dir();
        if !dir.join("systolic.hlo.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(&dir).unwrap();
        let mut rng = crate::util::Rng::seed_from_u64(9);
        let a: Vec<i32> = (0..PES * K_STEPS).map(|_| i32::from(rng.next_u64() as i8)).collect();
        let b: Vec<i32> = (0..K_STEPS * PES).map(|_| i32::from(rng.next_u64() as i8)).collect();
        let c: Vec<i32> = vec![0; PES * PES];
        let out = rt.systolic(&a, &b, &c, 8).unwrap();
        for i in 0..PES {
            for j in 0..PES {
                let want: i64 = (0..K_STEPS)
                    .map(|k| i64::from(a[i * K_STEPS + k]) * i64::from(b[k * PES + j]))
                    .sum();
                assert_eq!(i64::from(out[i * PES + j]), want, "({i},{j})");
            }
        }
    }
}
