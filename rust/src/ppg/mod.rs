//! Partial product generation (§2.1).
//!
//! Produces the column-wise partial-product bit matrix that the compressor
//! tree consumes. Two generators are provided:
//!
//! - [`PpgKind::AndArray`] — the paper's baseline `N²`-AND-gate PPG;
//! - [`PpgKind::Booth4`] — radix-4 (modified) Booth recoding for unsigned
//!   operands, halving the number of partial-product rows (the structure
//!   commercial multiplier IP uses at larger widths).
//!
//! For the fused MAC architecture (§2.3) the accumulator operand is injected
//! directly as extra rows of the matrix (see [`PpMatrix::add_addend`]), so
//! the CT absorbs the accumulation for free — the paper's headline MAC
//! optimization.

use crate::ir::{CellLib, Netlist, NodeId};
use crate::synth::Sig;

/// Partial-product generator selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PpgKind {
    /// Unsigned AND-gate array.
    AndArray,
    /// Radix-4 modified Booth recoding.
    Booth4,
}

/// Column-indexed partial-product matrix: `columns[j]` holds the bits of
/// weight `2^j`, each with the timing-model arrival estimate.
#[derive(Debug, Clone)]
pub struct PpMatrix {
    /// `columns[j]` = partial-product bits of weight `2^j`.
    pub columns: Vec<Vec<Sig>>,
    /// Operand widths that produced the matrix (for reports).
    pub n_bits: usize,
}

impl PpMatrix {
    /// Column population counts — the `PP_j` input of Algorithm 1.
    pub fn counts(&self) -> Vec<usize> {
        self.columns.iter().map(|c| c.len()).collect()
    }

    /// Widen to at least `n` columns.
    pub fn ensure_columns(&mut self, n: usize) {
        while self.columns.len() < n {
            self.columns.push(Vec::new());
        }
    }

    /// Inject an addend operand (for fused MACs): bit `k` of `bits` lands in
    /// column `k`.
    pub fn add_addend(&mut self, bits: &[Sig]) {
        self.ensure_columns(bits.len());
        for (k, s) in bits.iter().enumerate() {
            self.columns[k].push(*s);
        }
    }

    /// Max column height (reported as the CT's input rank).
    pub fn max_height(&self) -> usize {
        self.columns.iter().map(|c| c.len()).max().unwrap_or(0)
    }
}

/// Build the AND-array PPG for `a[0..n] × b[0..n]` into `nl`.
///
/// Returns the matrix over `2n-1` columns; arrival estimates equal one AND
/// stage at nominal load.
pub fn and_array(nl: &mut Netlist, lib: &CellLib, a: &[NodeId], b: &[NodeId]) -> PpMatrix {
    let n = a.len();
    assert_eq!(n, b.len(), "and_array expects equal operand widths");
    let d_and = lib.delay_ns(crate::ir::CellKind::And2, 2.0);
    let mut columns = vec![Vec::new(); 2 * n - 1];
    for (i, &ai) in a.iter().enumerate() {
        for (j, &bj) in b.iter().enumerate() {
            let g = nl.and2(ai, bj);
            columns[i + j].push(Sig::new(g, d_and));
        }
    }
    PpMatrix { columns, n_bits: n }
}

/// Radix-4 Booth digit selector output for one row bit.
///
/// Digit `d ∈ {-2,-1,0,1,2}` is encoded by (neg, one, two):
/// `pp_bit_k = neg ⊕ (one·a_k + two·a_{k-1})`, with the +1 correction for
/// negative digits injected as a separate LSB bit.
struct BoothRow {
    bits: Vec<Sig>,
    neg: Sig,
}

/// Build a radix-4 Booth PPG for unsigned `a × b`.
///
/// Unsigned operands are zero-extended by two bits so that the top digit is
/// non-negative; rows are sign-extended with the standard `~s, s, s`
/// compaction trick and negative rows add their `+1` correction bit into the
/// row's LSB column.
pub fn booth4(nl: &mut Netlist, lib: &CellLib, a: &[NodeId], b: &[NodeId]) -> PpMatrix {
    let n = a.len();
    booth4_wide(nl, lib, a, b, 2 * n)
}

/// Radix-4 Booth PPG exact mod `2^out_cols` — fused MACs need one extra
/// column (`2n+1`) so the accumulator sum's MSB stays exact.
pub fn booth4_wide(
    nl: &mut Netlist,
    lib: &CellLib,
    a: &[NodeId],
    b: &[NodeId],
    out_cols: usize,
) -> PpMatrix {
    use crate::ir::CellKind::*;
    let n = a.len();
    assert_eq!(n, b.len());
    assert!(out_cols >= 2 * n);
    let zero = nl.constant(false);
    let d_sel = lib.delay_ns(Xor2, 2.0) + lib.delay_ns(Aoi21, 2.0) + lib.delay_ns(Inv, 2.0);

    // Booth digits over b (zero-extended): digit i looks at b[2i+1], b[2i], b[2i-1].
    let n_rows = n / 2 + 1;
    let bit = |idx: isize, nl: &Netlist| -> NodeId {
        let _ = nl;
        if idx < 0 || idx as usize >= n {
            zero
        } else {
            b[idx as usize]
        }
    };

    let mut rows: Vec<BoothRow> = Vec::with_capacity(n_rows);
    for r in 0..n_rows {
        let hi = bit(2 * r as isize + 1, nl);
        let mid = bit(2 * r as isize, nl);
        let lo = bit(2 * r as isize - 1, nl);
        // one  = mid ⊕ lo  (|d| == 1)
        // two  = hi ⊕ mid ? …precisely: two = (hi·!mid·!lo) + (!hi·mid·lo)
        // neg  = hi·!(mid·lo)  → for zero-extended unsigned top digit hi=0.
        let one = nl.xor2(mid, lo);
        let eq_ml = nl.xnor2(mid, lo);
        let two = {
            let x = nl.xor2(hi, mid);
            nl.and2(x, eq_ml)
        };
        let neg = {
            let ml = nl.and2(mid, lo);
            let nml = nl.inv(ml);
            nl.and2(hi, nml)
        };
        // Row bits k = 0..n: pp_k = neg ⊕ (one·a_k | two·a_{k-1})
        let mut bits = Vec::with_capacity(n + 1);
        for k in 0..=n {
            let ak = if k < n { a[k] } else { zero };
            let ak1 = if k >= 1 { a[k - 1] } else { zero };
            let t1 = nl.and2(one, ak);
            let t2 = nl.and2(two, ak1);
            let or = nl.or2(t1, t2);
            let pp = nl.xor2(or, neg);
            bits.push(Sig::new(pp, d_sel));
        }
        rows.push(BoothRow { bits, neg: Sig::new(neg, d_sel) });
    }

    // Assemble columns with exact sign-extension compaction. Row r (base
    // column 2r, bits over base..base+n) contributes, mod 2^{2n}:
    //
    //   bits  +  neg·2^base            (the +1 of the two's complement)
    //         +  neg·(ones ≥ base+n+1) (sign extension)
    //
    // and  neg·(ones ≥ base+n+1) ≡ (~neg)·2^{base+n+1} − 2^{base+n+1}.
    // The per-row `−2^{base+n+1}` terms fold into one global constant C
    // injected as constant bits — the standard "(~s) + constant" trick,
    // made exact mod 2^{2n}.
    let mut columns = vec![Vec::new(); out_cols];
    for (r, row) in rows.iter().enumerate() {
        let base = 2 * r;
        for (k, s) in row.bits.iter().enumerate() {
            if base + k < columns.len() {
                columns[base + k].push(*s);
            }
        }
        // +1 correction for negative rows lands at the row LSB column.
        columns[base].push(row.neg);
        // (~neg) at base+n+1.
        if base + n + 1 < columns.len() {
            let ns = nl.inv(row.neg.node);
            columns[base + n + 1].push(Sig::new(ns, d_sel));
        }
    }
    // Global constant C = (− Σ_r 2^{2r+n+1}) mod 2^{2n}.
    let modulus = 1u128 << out_cols;
    let mut c_const = 0u128;
    for r in 0..rows.len() {
        let shift = 2 * r + n + 1;
        if shift < out_cols {
            c_const = (c_const + modulus - (1u128 << shift)) % modulus;
        }
    }
    if c_const != 0 {
        let one_const = nl.constant(true);
        for j in 0..out_cols {
            if c_const >> j & 1 == 1 {
                columns[j].push(Sig::new(one_const, 0.0));
            }
        }
    }
    PpMatrix { columns, n_bits: n }
}

/// Build a PPG of the requested kind.
pub fn generate(
    nl: &mut Netlist,
    lib: &CellLib,
    kind: PpgKind,
    a: &[NodeId],
    b: &[NodeId],
) -> PpMatrix {
    match kind {
        PpgKind::AndArray => and_array(nl, lib, a, b),
        PpgKind::Booth4 => booth4(nl, lib, a, b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{CellLib, Netlist};
    use crate::sim::{pack_lanes, Simulator};

    /// Sum a PP matrix numerically per lane (golden reduction).
    fn matrix_value(vals: &[u64], m: &PpMatrix, lane: u32) -> u128 {
        let mut total = 0u128;
        for (j, col) in m.columns.iter().enumerate() {
            for s in col {
                total += u128::from(vals[s.node.index()] >> lane & 1) << j;
            }
        }
        total
    }

    fn check_ppg(kind: PpgKind, n: usize, mask: u128) {
        let lib = CellLib::nangate45();
        let mut nl = Netlist::new("ppg");
        let a: Vec<_> = (0..n).map(|i| nl.input(format!("a{i}"))).collect();
        let b: Vec<_> = (0..n).map(|i| nl.input(format!("b{i}"))).collect();
        let m = generate(&mut nl, &lib, kind, &a, &b);
        nl.validate().unwrap();
        let mut sim = Simulator::new();
        // Exhaust 4-bit × 4-bit in 64-lane batches.
        let all: Vec<(u32, u32)> =
            (0..1u32 << n).flat_map(|x| (0..1u32 << n).map(move |y| (x, y))).collect();
        for chunk in all.chunks(64) {
            let assigns: Vec<Vec<bool>> = chunk
                .iter()
                .map(|(x, y)| {
                    (0..n).map(|k| x >> k & 1 != 0).chain((0..n).map(|k| y >> k & 1 != 0)).collect()
                })
                .collect();
            let words = pack_lanes(&assigns);
            let vals = sim.run(&nl, &words).to_vec();
            for (lane, (x, y)) in chunk.iter().enumerate() {
                let got = matrix_value(&vals, &m, lane as u32) & mask;
                assert_eq!(
                    got,
                    u128::from(*x) * u128::from(*y) & mask,
                    "{kind:?} {x}*{y}"
                );
            }
        }
    }

    #[test]
    fn and_array_4x4_exhaustive() {
        check_ppg(PpgKind::AndArray, 4, !0);
    }

    #[test]
    fn booth4_4x4_exhaustive_mod_2n() {
        // Booth rows are exact mod 2^(2n) after compaction-trim.
        check_ppg(PpgKind::Booth4, 4, (1u128 << 8) - 1);
    }

    #[test]
    fn booth4_3x3_exhaustive_mod_2n() {
        check_ppg(PpgKind::Booth4, 3, (1u128 << 6) - 1);
    }

    #[test]
    fn and_array_counts_are_triangular() {
        let lib = CellLib::nangate45();
        let mut nl = Netlist::new("ppg");
        let a: Vec<_> = (0..8).map(|i| nl.input(format!("a{i}"))).collect();
        let b: Vec<_> = (0..8).map(|i| nl.input(format!("b{i}"))).collect();
        let m = and_array(&mut nl, &lib, &a, &b);
        assert_eq!(m.counts(), vec![1, 2, 3, 4, 5, 6, 7, 8, 7, 6, 5, 4, 3, 2, 1]);
        assert_eq!(m.max_height(), 8);
    }

    #[test]
    fn booth_has_fewer_rows() {
        let lib = CellLib::nangate45();
        let mut nl = Netlist::new("ppg");
        let a: Vec<_> = (0..16).map(|i| nl.input(format!("a{i}"))).collect();
        let b: Vec<_> = (0..16).map(|i| nl.input(format!("b{i}"))).collect();
        let mb = booth4(&mut nl, &lib, &a, &b);
        // Radix-4 Booth max column height ≈ n/2+2 < n for n = 16.
        assert!(mb.max_height() <= 11, "booth height {}", mb.max_height());
    }

    #[test]
    fn addend_injection_for_mac() {
        let lib = CellLib::nangate45();
        let mut nl = Netlist::new("mac-ppg");
        let a: Vec<_> = (0..4).map(|i| nl.input(format!("a{i}"))).collect();
        let b: Vec<_> = (0..4).map(|i| nl.input(format!("b{i}"))).collect();
        let c: Vec<_> = (0..8).map(|i| nl.input(format!("c{i}"))).collect();
        let mut m = and_array(&mut nl, &lib, &a, &b);
        m.add_addend(&c.iter().map(|&n| Sig::new(n, 0.0)).collect::<Vec<_>>());
        // columns 0..6 are the 4×4 triangle +1; column 7 holds only c7
        assert_eq!(m.counts(), vec![2, 3, 4, 5, 4, 3, 2, 1]);
    }
}
