//! Figure 13 — ILP runtime vs. bit width.
//!
//! The paper plots Gurobi wall-time for the compressor-assignment and
//! interconnect-order ILPs (3600 s cap, 128 threads). We time the in-tree
//! solvers on the same two problem families: the exact §3.3 stage
//! assignment MILP (branch & bound; time-limited exactly like the paper's
//! runs) and the per-slice §3.5 interconnect assignment solved across a
//! full CT construction. The reproducible signal is the growth *shape*
//! (fast at 8 bits, steep growth toward 32).

use std::time::{Duration, Instant};
use ufo_mac::bench::Bench;
use ufo_mac::ct::{assign_ilp, CtCounts, OrderStrategy};
use ufo_mac::ilp::SolveOptions;
use ufo_mac::ir::{CellLib, Netlist};
use ufo_mac::synth::CompressorTiming;

fn mult_counts(n: usize) -> CtCounts {
    let pp: Vec<usize> = (0..2 * n - 1).map(|j| n.min(j + 1).min(2 * n - 1 - j)).collect();
    CtCounts::from_populations(&pp)
}

fn interconnect_time(n: usize) -> f64 {
    let lib = CellLib::nangate45();
    let tm = CompressorTiming::from_lib(&lib);
    let mut nl = Netlist::new("ct");
    let a: Vec<_> = (0..n).map(|i| nl.input(format!("a{i}"))).collect();
    let b: Vec<_> = (0..n).map(|i| nl.input(format!("b{i}"))).collect();
    let m = ufo_mac::ppg::and_array(&mut nl, &lib, &a, &b);
    let counts = CtCounts::from_populations(&m.counts());
    let plan = ufo_mac::ct::assign_greedy(&counts);
    let mut cols = m.columns;
    cols.resize(counts.width(), vec![]);
    let t = Instant::now();
    let _ = ufo_mac::ct::build_ct(&mut nl, &tm, cols, &plan, OrderStrategy::Optimized);
    t.elapsed().as_secs_f64()
}

fn main() {
    let bench = Bench::new("fig13_ilp_runtime");
    let quick = std::env::var("UFO_BENCH_QUICK").is_ok();
    // Paper cap: 3600 s. Scaled cap for this testbed.
    let cap = if quick { Duration::from_secs(5) } else { Duration::from_secs(60) };

    println!("\nFigure 13 reproduction: optimization runtime vs width");
    println!("  stage-assignment MILP (cap {:?}):", cap);
    let widths: &[usize] = if quick { &[4, 6, 8] } else { &[4, 6, 8, 12, 16] };
    let mut last = 0.0f64;
    for &n in widths {
        let counts = mult_counts(n);
        let opts = SolveOptions { time_limit: cap, ..Default::default() };
        let t = Instant::now();
        let (plan, nodes) = assign_ilp(&counts, &opts);
        let dt = t.elapsed().as_secs_f64();
        plan.validate(&counts).unwrap();
        println!("    {n:>2}-bit: {dt:>8.3} s  ({nodes} B&B nodes, {} stages)", plan.stages());
        bench.metric(&format!("stage_ilp_seconds_{n}"), dt, "s");
        last = last.max(dt);
    }

    println!("  interconnect-order optimization (full CT, exact per-slice):");
    for &n in if quick { &[8usize, 16][..] } else { &[8usize, 16, 32, 64][..] } {
        let dt = interconnect_time(n);
        println!("    {n:>2}-bit: {dt:>8.3} s");
        bench.metric(&format!("interconnect_seconds_{n}"), dt, "s");
    }

    // Growth-shape sanity: the largest stage-ILP width costs the most.
    let t_small = {
        let counts = mult_counts(4);
        let opts = SolveOptions { time_limit: cap, ..Default::default() };
        let t = Instant::now();
        let _ = assign_ilp(&counts, &opts);
        t.elapsed().as_secs_f64()
    };
    assert!(last >= t_small, "runtime must grow with width");

    bench.bench("stage_ilp_6bit", || {
        let counts = mult_counts(6);
        let opts = SolveOptions { time_limit: Duration::from_secs(10), ..Default::default() };
        assign_ilp(&counts, &opts)
    });
}
