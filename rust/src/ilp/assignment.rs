//! Exact assignment-problem solvers.
//!
//! The §3.5 interconnect-order ILP is, per slice, a bijection between
//! arriving partial products (sources) and compressor ports (sinks) that
//! minimizes the worst completion time — a *bottleneck assignment problem*.
//! Its permutation-matrix formulation is what the paper hands to Gurobi; we
//! solve it exactly with binary search over the completion-time threshold +
//! bipartite matching, then break ties by minimizing the *sum* of completion
//! times with a Hungarian pass restricted to threshold-feasible edges (so
//! non-critical ports are also assigned sensibly, which matters for the
//! next stage's profile).

/// Maximum-cardinality bipartite matching (Kuhn's algorithm) restricted to
/// `allowed[u][v]`. Returns `match_of_sink[v] = Some(u)`.
fn kuhn_matching(n: usize, allowed: &[Vec<bool>]) -> Vec<Option<usize>> {
    let mut match_v: Vec<Option<usize>> = vec![None; n];
    fn try_augment(
        u: usize,
        allowed: &[Vec<bool>],
        seen: &mut [bool],
        match_v: &mut [Option<usize>],
    ) -> bool {
        for v in 0..allowed[u].len() {
            if allowed[u][v] && !seen[v] {
                seen[v] = true;
                if match_v[v].is_none()
                    || try_augment(match_v[v].unwrap(), allowed, seen, match_v)
                {
                    match_v[v] = Some(u);
                    return true;
                }
            }
        }
        false
    }
    for u in 0..n {
        let mut seen = vec![false; n];
        try_augment(u, allowed, &mut seen, &mut match_v);
    }
    match_v
}

/// Exact bottleneck assignment: find a permutation `perm` (source u → sink
/// `perm[u]`) minimizing `max_u cost[u][perm[u]]`; among those, minimize the
/// sum of costs. `cost` must be square.
pub fn bottleneck_assignment(cost: &[Vec<f64>]) -> (Vec<usize>, f64) {
    let n = cost.len();
    if n == 0 {
        return (vec![], 0.0);
    }
    debug_assert!(cost.iter().all(|r| r.len() == n));

    // Binary search over the sorted set of distinct costs.
    let mut values: Vec<f64> = cost.iter().flatten().copied().collect();
    values.sort_by(|a, b| a.partial_cmp(b).unwrap());
    values.dedup_by(|a, b| (*a - *b).abs() < 1e-12);

    let feasible = |thr: f64| -> bool {
        let allowed: Vec<Vec<bool>> =
            cost.iter().map(|row| row.iter().map(|&c| c <= thr + 1e-12).collect()).collect();
        kuhn_matching(n, &allowed).iter().filter(|m| m.is_some()).count() == n
    };

    let (mut lo, mut hi) = (0usize, values.len() - 1);
    debug_assert!(feasible(values[hi]));
    while lo < hi {
        let mid = (lo + hi) / 2;
        if feasible(values[mid]) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    let threshold = values[lo];

    // Min-sum refinement among threshold-feasible edges (Hungarian).
    let big = threshold * (n as f64) + 1e6;
    let masked: Vec<Vec<f64>> = cost
        .iter()
        .map(|row| row.iter().map(|&c| if c <= threshold + 1e-12 { c } else { big }).collect())
        .collect();
    let perm = hungarian(&masked);
    (perm, threshold)
}

/// Hungarian algorithm (Jonker-Volgenant style O(n³)) for min-sum
/// assignment on a square cost matrix. Returns `perm[u] = v`.
pub fn hungarian(cost: &[Vec<f64>]) -> Vec<usize> {
    let n = cost.len();
    if n == 0 {
        return vec![];
    }
    const INF: f64 = f64::INFINITY;
    // 1-indexed potentials/links per the classic formulation.
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; n + 1];
    let mut p = vec![0usize; n + 1]; // p[j] = row matched to column j
    let mut way = vec![0usize; n + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![INF; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            for j in 1..=n {
                if !used[j] {
                    let cur = cost[i0 - 1][j - 1] - u[i0] - v[j];
                    if cur < minv[j] {
                        minv[j] = cur;
                        way[j] = j0;
                    }
                    if minv[j] < delta {
                        delta = minv[j];
                        j1 = j;
                    }
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut perm = vec![0usize; n];
    for j in 1..=n {
        if p[j] != 0 {
            perm[p[j] - 1] = j - 1;
        }
    }
    perm
}

#[cfg(test)]
mod tests {
    use super::*;

    fn max_cost(cost: &[Vec<f64>], perm: &[usize]) -> f64 {
        perm.iter().enumerate().map(|(u, &v)| cost[u][v]).fold(f64::MIN, f64::max)
    }

    #[test]
    fn hungarian_known_optimum() {
        let cost = vec![
            vec![4.0, 1.0, 3.0],
            vec![2.0, 0.0, 5.0],
            vec![3.0, 2.0, 2.0],
        ];
        let perm = hungarian(&cost);
        let total: f64 = perm.iter().enumerate().map(|(u, &v)| cost[u][v]).sum();
        assert!((total - 5.0).abs() < 1e-9, "total {total} perm {perm:?}");
    }

    #[test]
    fn bottleneck_beats_greedy_diagonal() {
        // Diagonal has max 9; optimal bottleneck is 3.
        let cost = vec![
            vec![9.0, 1.0, 2.0],
            vec![1.0, 9.0, 3.0],
            vec![2.0, 3.0, 9.0],
        ];
        let (perm, thr) = bottleneck_assignment(&cost);
        assert!(thr <= 3.0 + 1e-9, "thr {thr}");
        assert!((max_cost(&cost, &perm) - thr).abs() < 1e-9);
        // perm is a permutation
        let mut seen = vec![false; 3];
        for &v in &perm {
            assert!(!seen[v]);
            seen[v] = true;
        }
    }

    #[test]
    fn bottleneck_exhaustive_cross_check() {
        // Compare against brute force on random 5×5 matrices.
        let mut seed = 0xdeadbeefu64;
        let mut rng = move || {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            (seed % 1000) as f64 / 100.0
        };
        for _ in 0..20 {
            let n = 5;
            let cost: Vec<Vec<f64>> = (0..n).map(|_| (0..n).map(|_| rng()).collect()).collect();
            let (_, thr) = bottleneck_assignment(&cost);
            // brute force over permutations
            let mut best = f64::INFINITY;
            let mut idx: Vec<usize> = (0..n).collect();
            permute(&mut idx, 0, &mut |perm| {
                let m = perm.iter().enumerate().map(|(u, &v)| cost[u][v]).fold(f64::MIN, f64::max);
                if m < best {
                    best = m;
                }
            });
            assert!((thr - best).abs() < 1e-9, "thr {thr} best {best}");
        }
    }

    fn permute(idx: &mut Vec<usize>, k: usize, f: &mut impl FnMut(&[usize])) {
        if k == idx.len() {
            f(idx);
            return;
        }
        for i in k..idx.len() {
            idx.swap(k, i);
            permute(idx, k + 1, f);
            idx.swap(k, i);
        }
    }

    #[test]
    fn matches_ilp_formulation_on_small_instance() {
        // The paper's permutation-matrix ILP (Eq. 19-23) and the
        // combinatorial solver must agree on the bottleneck value.
        use crate::ilp::{solve, LinExpr, Model, Sense, SolveOptions};
        let cost = vec![
            vec![3.0, 7.0, 1.0],
            vec![5.0, 2.0, 6.0],
            vec![4.0, 4.0, 8.0],
        ];
        let n = 3;
        let mut m = Model::new();
        let mut z = vec![vec![]; n];
        for u in 0..n {
            for v in 0..n {
                z[u].push(m.bin(format!("z{u}{v}")));
            }
        }
        let mx = m.cont("M", 0.0, 1e4);
        for u in 0..n {
            let row: Vec<_> = (0..n).map(|v| (z[u][v], 1.0)).collect();
            m.constrain(LinExpr::of(&row), Sense::Eq, 1.0);
            let col: Vec<_> = (0..n).map(|v| (z[v][u], 1.0)).collect();
            m.constrain(LinExpr::of(&col), Sense::Eq, 1.0);
            for v in 0..n {
                // M >= cost[u][v] * z[u][v]
                m.constrain(
                    LinExpr::of(&[(mx, 1.0), (z[u][v], -cost[u][v])]),
                    Sense::Ge,
                    0.0,
                );
            }
        }
        m.minimize(LinExpr::of(&[(mx, 1.0)]));
        let sol = solve(&m, &SolveOptions::default());
        assert!(sol.ok());
        let (_, thr) = bottleneck_assignment(&cost);
        assert!((sol.value(mx) - thr).abs() < 1e-6, "ilp {} comb {thr}", sol.value(mx));
    }
}
