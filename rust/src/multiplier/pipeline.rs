//! Arrival-profile-driven pipeline-cut insertion.
//!
//! Turns a combinational design into a k-stage pipeline by slicing the
//! netlist along STA arrival thresholds and rebuilding it with register
//! ranks between slices — the registered `always_ff` MACs every exemplar
//! in SNIPPETS.md ships, grown automatically from the same arrival
//! information UFO-MAC's CPA optimizer already exploits (§IV): cuts land
//! where the measured slack runs out, not at fixed structural boundaries.
//!
//! The IR is append-only, so cuts cannot be *inserted*; instead the
//! netlist is **rebuilt** in node order. Nodes keep their topological
//! order, every gate is assigned the slice its arrival time falls in
//! (`slice = #{j in 1..k : T·j/k < arrival}`), and a fanin crossing from
//! slice `s` to slice `s' > s` is routed through a lazily grown chain of
//! `s' - s` registers. Arrival monotonicity along fanin edges guarantees
//! cuts only ever go forward. Primary outputs are registered at rank `k`,
//! so the pipeline latency is exactly `k` cycles.
//!
//! All data registers share two fresh control inputs appended after the
//! operand inputs (operand ordinals are preserved): `pipe_en` (hold the
//! whole pipeline when low) and `pipe_clr` (synchronously return every
//! rank to zero). Driving `en = 1, clr = 0` gives the pure pipeline the
//! equivalence checker unrolls. Constants are time-invariant and are
//! never piped.

use crate::ir::netlist::{OP_CONST0, OP_CONST1, OP_INPUT};
use crate::ir::{CellKind, CellLib, Netlist, Node, NodeId};
use crate::sta::Sta;

/// How a [`super::Design`] was pipelined — carried on the design so the
/// engine, persistence layer and Verilog emitter agree on the clocked
/// interface.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PipelineInfo {
    /// Number of register ranks (== pipeline latency in cycles).
    pub stages: usize,
    /// The shared `pipe_en` control input (all data registers stall
    /// together when it is low).
    pub en: NodeId,
    /// The shared `pipe_clr` control input (synchronous clear to the
    /// reset state).
    pub clr: NodeId,
}

impl PipelineInfo {
    /// Cycles between presenting operands and the matching product
    /// appearing at the outputs (with `en` held high).
    pub fn latency(&self) -> usize {
        self.stages
    }
}

/// Result of [`insert_pipeline`]: the rebuilt sequential netlist plus the
/// id remapping the caller needs to fix up its interface metadata.
#[derive(Debug)]
pub struct PipelinedNetlist {
    /// The rebuilt netlist (original name suffixed `_p{k}`).
    pub netlist: Netlist,
    /// New id of each original node *at its own slice* (pre-piping).
    /// Inputs keep ordinal order, so operand bit vectors remap through
    /// this table.
    pub base: Vec<NodeId>,
    /// New ids of the original primary outputs, in output order — these
    /// are the rank-`k` registers.
    pub outputs: Vec<NodeId>,
    /// Pipeline control metadata (shared `en`/`clr`, stage count).
    pub info: PipelineInfo,
}

/// Grow the register chain for original node `i` up to `rank` and return
/// the new id carrying its value at that rank. `piped` is the lazily
/// filled `(node × rank)` table; time-invariant nodes (constants) are
/// returned untouched.
#[allow(clippy::too_many_arguments)]
fn pipe(
    out: &mut Netlist,
    piped: &mut [Option<NodeId>],
    k: usize,
    time_invariant: &[bool],
    slice: &[usize],
    en: NodeId,
    clr: NodeId,
    i: usize,
    rank: usize,
) -> NodeId {
    let row = i * (k + 1);
    if time_invariant[i] {
        return piped[row + slice[i]].expect("constant built before use");
    }
    debug_assert!(rank >= slice[i], "cuts only go forward");
    if let Some(id) = piped[row + rank] {
        return id;
    }
    let mut r = rank;
    while piped[row + r].is_none() {
        r -= 1; // slice[i] is always populated, so this terminates
    }
    let mut cur = piped[row + r].expect("base rank populated");
    for rr in r + 1..=rank {
        cur = out.reg(cur, en, clr, false);
        piped[row + rr] = Some(cur);
    }
    cur
}

/// Rebuild `nl` as a `stages`-rank pipeline cut along its STA arrival
/// profile (see the module docs for the slicing rule). `nl` must be
/// combinational; panics on an already-sequential netlist.
pub fn insert_pipeline(nl: &Netlist, lib: &CellLib, stages: usize) -> PipelinedNetlist {
    assert!(stages >= 1, "a pipeline needs at least one register rank");
    assert!(!nl.is_sequential(), "cannot re-pipeline a sequential netlist");
    let k = stages;
    let sta = Sta { activity_rounds: 0, ..Sta::with_lib(lib.clone()) };
    let at = sta.arrivals_ns(nl);
    let total = at.iter().copied().fold(0.0f64, f64::max);
    let ops = nl.ops();
    let fan = nl.fanin_records();
    let n = nl.len();

    // Slice assignment: gates fall in the arrival band their output lands
    // in; inputs and constants sit in slice 0. Arrival is strictly
    // increasing along fanin edges, so slice(fanin) <= slice(gate).
    let slice: Vec<usize> = (0..n)
        .map(|i| {
            if ops[i] > 10 || total <= 0.0 {
                return 0;
            }
            let mut s = 0usize;
            for j in 1..k {
                if total * (j as f64) / (k as f64) < at[i] {
                    s = j;
                }
            }
            s
        })
        .collect();
    let time_invariant: Vec<bool> =
        ops.iter().map(|&op| op == OP_CONST0 || op == OP_CONST1).collect();

    let mut out = Netlist::new(format!("{}_p{k}", nl.name));
    let mut base = vec![NodeId(0); n];
    // Inputs first, in node order — creation order defines the ordinal,
    // so operand ordinals are preserved and the two control inputs land
    // *after* them (ordinals n_in and n_in + 1).
    for i in 0..n {
        if ops[i] == OP_INPUT {
            if let Node::Input { name, arrival_ns } = nl.node(NodeId(i as u32)) {
                base[i] = out.input_at(name, arrival_ns);
            }
        }
    }
    let en = out.input("pipe_en");
    let clr = out.input("pipe_clr");

    let mut piped: Vec<Option<NodeId>> = vec![None; n * (k + 1)];
    for i in 0..n {
        let row = i * (k + 1);
        match ops[i] {
            OP_INPUT => {
                piped[row] = Some(base[i]);
            }
            OP_CONST0 | OP_CONST1 => {
                let id = out.constant(ops[i] == OP_CONST1);
                base[i] = id;
                piped[row] = Some(id);
            }
            op if op <= 10 => {
                let kind = CellKind::ALL[op as usize];
                let s = slice[i];
                let arity = kind.arity();
                let rec = fan[i];
                let mut f = [NodeId(0); 3];
                for (slot, &src) in f.iter_mut().zip(rec.iter()).take(arity) {
                    *slot = pipe(
                        &mut out,
                        &mut piped,
                        k,
                        &time_invariant,
                        &slice,
                        en,
                        clr,
                        src as usize,
                        s,
                    );
                }
                let id = out.gate(kind, &f[..arity]);
                base[i] = id;
                piped[row + s] = Some(id);
            }
            other => panic!("cannot pipeline opcode {other} at node {i}"),
        }
    }

    // Primary outputs are registered at rank k: the product of the
    // operands presented on cycle t appears on cycle t + k.
    let mut outputs = Vec::with_capacity(nl.num_outputs());
    for (name, id) in nl.outputs() {
        let nid = pipe(
            &mut out,
            &mut piped,
            k,
            &time_invariant,
            &slice,
            en,
            clr,
            id.index(),
            k,
        );
        out.output(name, nid);
        outputs.push(nid);
    }

    PipelinedNetlist { netlist: out, base, outputs, info: PipelineInfo { stages: k, en, clr } }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{lane_value, ClockedSim};

    fn mul4() -> crate::multiplier::Design {
        let lib = CellLib::nangate45();
        let tm = crate::synth::CompressorTiming::from_lib(&lib);
        crate::multiplier::MultiplierSpec::new(4).build_with(&lib, &tm).unwrap()
    }

    #[test]
    fn pipeline_preserves_function_with_latency() {
        let d = mul4();
        for k in 1..=3usize {
            let p = insert_pipeline(&d.netlist, &CellLib::nangate45(), k);
            p.netlist.validate().unwrap();
            assert!(p.netlist.num_regs() > 0, "k={k} produced no registers");
            // Stream 64 exhaustive (a, b) pairs per lane-batch and check
            // the product appears k cycles later.
            let mut sim = ClockedSim::new(&p.netlist);
            let n_in = p.netlist.num_inputs();
            let mut words = vec![0u64; n_in];
            // en = 1, clr = 0 on every lane; ordinals are a0..a3 b0..b3
            // then pipe_en, pipe_clr.
            words[n_in - 2] = !0;
            for lane in 0..64u32 {
                let a = u64::from(lane) & 0xF;
                let b = u64::from(lane) >> 4;
                for bit in 0..4 {
                    if a >> bit & 1 != 0 {
                        words[bit] |= 1 << lane;
                    }
                    if b >> bit & 1 != 0 {
                        words[4 + bit] |= 1 << lane;
                    }
                }
            }
            for _ in 0..k {
                sim.step(&words);
            }
            let view = sim.step(&words).to_vec();
            for lane in 0..64u32 {
                let a = u128::from(lane) & 0xF;
                let b = u128::from(lane) >> 4;
                let got = lane_value(&view, &p.outputs, lane);
                assert_eq!(got, a * b & 0xFF, "k={k} lane={lane}");
            }
        }
    }

    #[test]
    fn control_inputs_follow_the_operands() {
        let d = mul4();
        let p = insert_pipeline(&d.netlist, &CellLib::nangate45(), 2);
        let n_in = p.netlist.num_inputs();
        assert_eq!(n_in, d.netlist.num_inputs() + 2);
        assert_eq!(p.info.en.index(), n_in - 2);
        assert_eq!(p.info.clr.index(), n_in - 1);
        assert_eq!(p.info.latency(), 2);
        // Operand remap: same ordinal order, and with operands created
        // first in the builder the ids are even identical.
        for &a in &d.a {
            assert_eq!(p.base[a.index()], a);
        }
    }

    #[test]
    fn deeper_pipelines_cut_the_critical_segment() {
        let d = mul4();
        let lib = CellLib::nangate45();
        let sta = Sta { activity_rounds: 0, ..Sta::with_lib(lib.clone()) };
        let base = sta.analyze(&d.netlist).critical_delay_ns;
        for k in [2usize, 3] {
            let p = insert_pipeline(&d.netlist, &lib, k);
            let seg = sta.analyze(&p.netlist).critical_delay_ns;
            assert!(
                seg < base,
                "k={k}: segment {seg} not below combinational {base}"
            );
        }
    }

    #[test]
    fn clr_clears_and_en_stalls_the_whole_pipeline() {
        let d = mul4();
        let p = insert_pipeline(&d.netlist, &CellLib::nangate45(), 2);
        let n_in = p.netlist.num_inputs();
        let mut sim = ClockedSim::new(&p.netlist);
        // a = 3, b = 5 on all lanes, en = 1.
        let mut words = vec![0u64; n_in];
        words[0] = !0;
        words[1] = !0;
        words[4] = !0;
        words[6] = !0;
        words[n_in - 2] = !0;
        sim.step(&words);
        sim.step(&words);
        let view = sim.step(&words).to_vec();
        assert_eq!(lane_value(&view, &p.outputs, 0), 15);
        // Stall: en = 0, junk operands — outputs must hold.
        let mut stall = vec![0u64; n_in];
        stall[2] = !0;
        let view = sim.step(&stall).to_vec();
        assert_eq!(lane_value(&view, &p.outputs, 0), 15, "stall must hold the product");
        // Clear: one clr cycle flushes every rank to zero.
        let mut clr = vec![0u64; n_in];
        clr[n_in - 1] = !0;
        sim.step(&clr);
        let view = sim.step(&stall).to_vec();
        assert_eq!(lane_value(&view, &p.outputs, 0), 0, "clr must flush the pipeline");
    }
}
