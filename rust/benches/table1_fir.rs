//! Table 1 — 5-tap FIR filters built from every method's multipliers,
//! under the paper's three constraint regimes and clock targets:
//! area-driven (660M/500M/400M), timing-driven (2G/1G/660M), trade-off
//! (1G/660M/500M) for 8/16/32-bit. Reports Freq/WNS/Area/Power rows.

use ufo_mac::baselines::Method;
use ufo_mac::bench::Bench;
use ufo_mac::modules::fir_report;
use ufo_mac::multiplier::Strategy;
use ufo_mac::util::Table;

fn main() {
    let bench = Bench::new("table1_fir");
    let quick = std::env::var("UFO_BENCH_QUICK").is_ok();
    let widths: &[usize] = if quick { &[8] } else { &[8, 16, 32] };

    // (label, strategy, freq per width index) — the paper's Table 1 grid.
    let regimes: [(&str, Strategy, [f64; 3]); 3] = [
        ("area-driven", Strategy::AreaDriven, [660e6, 500e6, 400e6]),
        ("timing-driven", Strategy::TimingDriven, [2e9, 1e9, 660e6]),
        ("trade-off", Strategy::TradeOff, [1e9, 660e6, 500e6]),
    ];

    println!("\nTable 1 reproduction: 5-tap FIR filters");
    for (label, strategy, freqs) in regimes {
        for (wi, &n) in widths.iter().enumerate() {
            let freq = freqs[wi];
            let mut table =
                Table::new(&["method", "freq", "WNS(ns)", "area(µm²)", "power(mW)"]);
            let mut rows = Vec::new();
            for m in Method::ALL {
                let r = fir_report(m, n, strategy, freq).unwrap();
                table.row(vec![
                    m.name().into(),
                    format!("{:.0}M", freq / 1e6),
                    format!("{:.4}", r.wns_ns),
                    format!("{:.0}", r.area_um2),
                    format!("{:.3}", r.power_mw),
                ]);
                rows.push((m, r));
            }
            println!("\n{label}, {n}-bit @ {:.0} MHz:\n{}", freq / 1e6, table.render());
            let ufo = rows.iter().find(|(m, _)| *m == Method::UfoMac).unwrap().1.clone();
            let com =
                rows.iter().find(|(m, _)| *m == Method::Commercial).unwrap().1.clone();
            bench.metric(&format!("{label}_{n}_ufo_area"), ufo.area_um2, "um2");
            bench.metric(&format!("{label}_{n}_ufo_wns"), ufo.wns_ns, "ns");
            bench.metric(&format!("{label}_{n}_commercial_area"), com.area_um2, "um2");
            bench.metric(&format!("{label}_{n}_commercial_wns"), com.wns_ns, "ns");
            // Table-1 shape: UFO-MAC's WNS is the best (least negative)
            // or ties within tolerance under the timing regime.
            if matches!(strategy, Strategy::TimingDriven) {
                let best_wns =
                    rows.iter().map(|(_, r)| r.wns_ns).fold(f64::NEG_INFINITY, f64::max);
                assert!(
                    ufo.wns_ns >= best_wns - 0.02,
                    "{label} {n}-bit: UFO WNS {:.4} vs best {:.4}",
                    ufo.wns_ns,
                    best_wns
                );
            }
        }
    }

    bench.bench("fir_report_ufo_8bit", || {
        fir_report(Method::UfoMac, 8, Strategy::TradeOff, 1e9).unwrap()
    });
}
