//! Server observability counters: per-command latency histograms,
//! per-priority-class queue gauges, and lifetime totals.
//!
//! Everything on the request path is lock-free atomics — recording a
//! latency sample is one `leading_zeros` plus one `fetch_add`, with no
//! allocation — so the observability layer costs nothing measurable per
//! command. Rendering ([`Metrics`] accessors plus the server's
//! `metrics_json`) allocates, but only when a `metrics` command (or
//! `ufo-mac serve --metrics` reporter) asks for a snapshot.

use super::sched::Priority;
use crate::util::Json;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// Number of log-2 latency buckets: bucket `i` counts samples whose
/// latency in microseconds satisfies `floor(log2(max(us, 1))) == i`, i.e.
/// `us` in `[2^i, 2^(i+1))` (bucket 0 also absorbs sub-microsecond
/// samples). 24 buckets span 1 µs to ~16.8 s, past any plausible sweep.
pub const BUCKETS: usize = 24;

/// Wire-command keys, one latency histogram each, in the (alphabetical)
/// order they render in the `metrics` response.
pub const COMMANDS: [&str; 8] =
    ["analyze", "batch", "compile", "lint", "metrics", "shutdown", "stats", "sweep"];

/// Fixed-size log-bucketed latency histogram over atomic counters.
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> LatencyHistogram {
        LatencyHistogram { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    /// Record one sample. Allocation-free: bucket index is
    /// `floor(log2(µs))` via `leading_zeros`, clamped to the last bucket.
    pub fn record(&self, latency: Duration) {
        let us = u64::try_from(latency.as_micros()).unwrap_or(u64::MAX);
        let idx = (63 - u64::leading_zeros(us | 1)) as usize;
        self.buckets[idx.min(BUCKETS - 1)].fetch_add(1, Ordering::Relaxed);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Snapshot as `{"buckets":[…],"count":N}`. The buckets array is
    /// trimmed after the last non-empty bucket (an idle command renders
    /// `[]`), so entry `i` — when present — is the count for the
    /// `[2^i, 2^(i+1))` µs band.
    pub fn to_json(&self) -> Json {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let used = counts.iter().rposition(|&c| c > 0).map_or(0, |i| i + 1);
        let total: u64 = counts.iter().sum();
        Json::obj(vec![
            (
                "buckets",
                Json::arr(counts[..used].iter().map(|&c| Json::num(c as f64)).collect()),
            ),
            ("count", Json::num(total as f64)),
        ])
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

/// Aggregate server metrics: uptime, jobs completed, progress frames
/// emitted, admitted-but-unanswered queue depth per priority class, and
/// one [`LatencyHistogram`] per wire command (admission → final
/// envelope, so queueing delay is included).
pub struct Metrics {
    start: Instant,
    jobs_completed: AtomicU64,
    progress_frames: AtomicU64,
    depths: [AtomicUsize; 3],
    hists: [LatencyHistogram; COMMANDS.len()],
}

impl Metrics {
    /// Fresh metrics; uptime starts now.
    pub fn new() -> Metrics {
        Metrics {
            start: Instant::now(),
            jobs_completed: AtomicU64::new(0),
            progress_frames: AtomicU64::new(0),
            depths: std::array::from_fn(|_| AtomicUsize::new(0)),
            hists: std::array::from_fn(|_| LatencyHistogram::new()),
        }
    }

    /// A job entered class `class` (admission).
    pub fn job_admitted(&self, class: Priority) {
        self.depths[class.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// A job left class `class` (answered, or dropped because its
    /// connection died).
    pub fn job_settled(&self, class: Priority) {
        self.depths[class.index()].fetch_sub(1, Ordering::Relaxed);
    }

    /// A final envelope was written. `cmd` is the wire-command key for
    /// the latency histogram (`None` for protocol errors, which have no
    /// command class but still count as completed jobs).
    pub fn job_completed(&self, cmd: Option<&str>, latency: Duration) {
        self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        if let Some(key) = cmd {
            if let Some(i) = COMMANDS.iter().position(|&c| c == key) {
                self.hists[i].record(latency);
            }
        }
    }

    /// One `{"event":"progress",…}` frame was written.
    pub fn frame_emitted(&self) {
        self.progress_frames.fetch_add(1, Ordering::Relaxed);
    }

    /// Final envelopes written over the server's lifetime.
    pub fn jobs_completed(&self) -> u64 {
        self.jobs_completed.load(Ordering::Relaxed)
    }

    /// Progress frames written over the server's lifetime.
    pub fn progress_frames(&self) -> u64 {
        self.progress_frames.load(Ordering::Relaxed)
    }

    /// Admitted-but-unanswered jobs summed over all classes (the `stats`
    /// command's `queue_depth`).
    pub fn queue_depth_total(&self) -> usize {
        self.depths.iter().map(|d| d.load(Ordering::Relaxed)).sum()
    }

    /// Time since construction.
    pub fn uptime(&self) -> Duration {
        self.start.elapsed()
    }

    /// Per-class queue depths as `{"bulk":…,"interactive":…,"urgent":…}`.
    pub fn queue_json(&self) -> Json {
        Json::obj(
            Priority::ALL
                .iter()
                .map(|&p| {
                    (p.key(), Json::num(self.depths[p.index()].load(Ordering::Relaxed) as f64))
                })
                .collect(),
        )
    }

    /// Per-command latency histograms keyed by wire command — every key
    /// in [`COMMANDS`] is always present, so the response shape is
    /// stable whether or not a command has run yet.
    pub fn latency_json(&self) -> Json {
        Json::obj(
            COMMANDS.iter().zip(&self.hists).map(|(&key, h)| (key, h.to_json())).collect(),
        )
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_by_log2_microseconds() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(0)); // bucket 0
        h.record(Duration::from_micros(1)); // bucket 0
        h.record(Duration::from_micros(3)); // bucket 1
        h.record(Duration::from_micros(1024)); // bucket 10
        h.record(Duration::from_secs(3600)); // clamped to the last bucket
        assert_eq!(h.count(), 5);
        let j = h.to_json();
        let buckets = j.get("buckets").unwrap().as_arr().unwrap();
        assert_eq!(buckets.len(), BUCKETS); // clamp filled the last bucket
        assert_eq!(buckets[0].as_f64().unwrap(), 2.0);
        assert_eq!(buckets[1].as_f64().unwrap(), 1.0);
        assert_eq!(buckets[10].as_f64().unwrap(), 1.0);
        assert_eq!(buckets[BUCKETS - 1].as_f64().unwrap(), 1.0);
        assert_eq!(j.get("count").unwrap().as_f64().unwrap(), 5.0);
    }

    #[test]
    fn idle_histogram_renders_empty_buckets() {
        let j = LatencyHistogram::new().to_json();
        assert!(j.get("buckets").unwrap().as_arr().unwrap().is_empty());
        assert_eq!(j.get("count").unwrap().as_f64().unwrap(), 0.0);
    }

    #[test]
    fn gauges_and_totals_round_trip() {
        let m = Metrics::new();
        m.job_admitted(Priority::Bulk);
        m.job_admitted(Priority::Urgent);
        assert_eq!(m.queue_depth_total(), 2);
        let q = m.queue_json();
        assert_eq!(q.get("urgent").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(q.get("bulk").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(q.get("interactive").unwrap().as_f64().unwrap(), 0.0);
        m.job_settled(Priority::Bulk);
        m.job_completed(Some("sweep"), Duration::from_millis(12));
        m.job_completed(None, Duration::from_micros(5));
        m.frame_emitted();
        assert_eq!(m.queue_depth_total(), 1);
        assert_eq!(m.jobs_completed(), 2);
        assert_eq!(m.progress_frames(), 1);
        let lat = m.latency_json();
        for key in COMMANDS {
            assert!(lat.get(key).is_some(), "missing {key}");
        }
        assert_eq!(lat.get("sweep").unwrap().get("count").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(lat.get("compile").unwrap().get("count").unwrap().as_f64().unwrap(), 0.0);
    }
}
