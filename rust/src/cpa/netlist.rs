//! Prefix graph → gate-level netlist expansion.
//!
//! Consumes the two compressed rows from the CT (or two adder operands) and
//! a [`PrefixGraph`], emitting pg logic, black/blue prefix cells and the
//! final sum XORs. Columns whose second operand bit is absent short-circuit
//! to `p = a, g = 0`; the graph's generate chain still treats them
//! uniformly (the constant is a real node, folded by the simulator).

use super::graph::{PrefixGraph, NONE};
use super::timing::blue_mask;
use crate::ir::{Netlist, NodeId};
use crate::synth::{black_node, blue_node, Sig};

/// One CPA input column: the first bit and (optionally) the second.
#[derive(Debug, Clone, Copy)]
pub struct CpaColumn {
    /// First operand bit.
    pub a: Sig,
    /// Second operand bit (absent columns use `p = a, g = 0`).
    pub b: Option<Sig>,
}

/// Result of CPA expansion.
#[derive(Debug, Clone)]
pub struct CpaOut {
    /// Sum bits, LSB first — `width` bits plus the carry-out appended as
    /// the MSB (so callers get the full `width+1`-bit result).
    pub sum: Vec<NodeId>,
}

/// Expand `graph` over `cols` into `nl`.
///
/// `graph.n` must equal `cols.len()`. The carry-out (`G[n-1:0]`) becomes the
/// final sum bit.
pub fn expand(nl: &mut Netlist, graph: &PrefixGraph, cols: &[CpaColumn]) -> CpaOut {
    let n = graph.n;
    assert_eq!(n, cols.len(), "CPA width mismatch");
    let blue = blue_mask(graph);
    let live = graph.live_mask();

    // The expansion's gate population is bounded by the graph shape: ≤ 2
    // pg gates per column, ≤ 3 gates per live prefix node (black = 3,
    // blue = 2), n − 1 sum XORs, and at most one shared constant. One
    // up-front reservation keeps the whole CPA build from reallocating
    // (EXPERIMENTS.md §Perf, `netlist_build_64x64`).
    let live_prefix = live[n..].iter().filter(|&&l| l).count();
    nl.reserve(2 * n + 3 * live_prefix + n);

    // pg generation per bit.
    let mut p = Vec::with_capacity(n);
    let mut g = Vec::with_capacity(n);
    let mut zero: Option<NodeId> = None;
    for c in cols {
        match c.b {
            Some(b) => {
                p.push(nl.xor2(c.a.node, b.node));
                g.push(nl.and2(c.a.node, b.node));
            }
            None => {
                let z = *zero.get_or_insert_with(|| nl.constant(false));
                p.push(c.a.node);
                g.push(z);
            }
        }
    }

    // Prefix nodes in topological order.
    let mut node_g: Vec<NodeId> = vec![NodeId(0); graph.nodes.len()];
    let mut node_p: Vec<Option<NodeId>> = vec![None; graph.nodes.len()];
    for i in 0..n {
        node_g[i] = g[i];
        node_p[i] = Some(p[i]);
    }
    for i in n..graph.nodes.len() {
        if !live[i] {
            continue;
        }
        let nd = graph.node(i);
        let (gh, ph) = (node_g[nd.tf], node_p[nd.tf].expect("tf propagate required"));
        let gl = node_g[nd.ntf];
        if blue[i] {
            node_g[i] = blue_node(nl, gh, ph, gl);
        } else {
            let pl = node_p[nd.ntf].expect("ntf propagate required for black node");
            let (gg, pp) = black_node(nl, gh, ph, gl, pl);
            node_g[i] = gg;
            node_p[i] = Some(pp);
        }
    }

    // Sums: s_0 = p_0; s_i = p_i ⊕ c_{i-1}; s_n = c_{n-1} (carry-out).
    let mut sum = Vec::with_capacity(n + 1);
    sum.push(p[0]);
    for i in 1..n {
        let c_prev = node_g[graph.roots[i - 1]];
        sum.push(nl.xor2(p[i], c_prev));
    }
    sum.push(node_g[graph.roots[n - 1]]);
    CpaOut { sum }
}

/// Convenience: build a standalone `n`-bit adder netlist (fresh inputs,
/// given prefix graph), returning the netlist and its sum outputs. Used by
/// the Figure-8 dataset generator and adder unit tests.
pub fn standalone_adder(graph: &PrefixGraph, arrivals: Option<&[f64]>) -> (Netlist, Vec<NodeId>) {
    let n = graph.n;
    let mut nl = Netlist::new(format!("adder{n}"));
    let cols: Vec<CpaColumn> = (0..n)
        .map(|i| {
            let t = arrivals.map_or(0.0, |a| a[i]);
            let a = nl.input_at(format!("a{i}"), t);
            let b = nl.input_at(format!("b{i}"), t);
            CpaColumn { a: Sig::new(a, t), b: Some(Sig::new(b, t)) }
        })
        .collect();
    let out = expand(&mut nl, graph, &cols);
    for (i, &s) in out.sum.iter().enumerate() {
        nl.output(format!("s{i}"), s);
    }
    (nl, out.sum)
}

/// Check that a root for every bit exists (pruned graphs keep roots).
pub fn check_roots(graph: &PrefixGraph) -> bool {
    graph.roots.iter().all(|&r| r != NONE)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpa::graph::{
        brent_kung, carry_increment, han_carlson, hybrid_regions, kogge_stone, ripple, sklansky,
        PrefixGraph,
    };
    use crate::sim::{lane_value, pack_lanes, Simulator};

    fn exhaustive_add_check(graph: &PrefixGraph) {
        let n = graph.n;
        let (nl, sum) = standalone_adder(graph, None);
        nl.validate().unwrap();
        let mut sim = Simulator::new();
        let all: Vec<(u32, u32)> =
            (0..1u32 << n).flat_map(|x| (0..1u32 << n).map(move |y| (x, y))).collect();
        for chunk in all.chunks(64) {
            let assigns: Vec<Vec<bool>> = chunk
                .iter()
                .map(|(x, y)| {
                    (0..n)
                        .flat_map(|k| [x >> k & 1 != 0, y >> k & 1 != 0])
                        .collect()
                })
                .collect();
            let words = pack_lanes(&assigns);
            let vals = sim.run(&nl, &words).to_vec();
            for (lane, (x, y)) in chunk.iter().enumerate() {
                let got = lane_value(&vals, &sum, lane as u32);
                assert_eq!(got, u128::from(x + y), "{} + {}", x, y);
            }
        }
    }

    #[test]
    fn adders_exhaustive_5bit() {
        for g in [
            ripple(5),
            sklansky(5),
            kogge_stone(5),
            brent_kung(5),
            han_carlson(5),
            carry_increment(5, 2),
            hybrid_regions(5, 1, 3, 2),
        ] {
            exhaustive_add_check(&g);
        }
    }

    #[test]
    fn adders_exhaustive_4bit_and_3bit() {
        for n in [3usize, 4] {
            for g in [ripple(n), sklansky(n), kogge_stone(n), brent_kung(n), han_carlson(n)] {
                exhaustive_add_check(&g);
            }
        }
    }

    #[test]
    fn random_check_16bit() {
        let mut rng = crate::util::Rng::seed_from_u64(77);
        for g in [sklansky(16), brent_kung(16), kogge_stone(16), hybrid_regions(16, 4, 10, 4)] {
            let (nl, sum) = standalone_adder(&g, None);
            let mut sim = Simulator::new();
            let pairs: Vec<(u32, u32)> = (0..64)
                .map(|_| (rng.next_u64() as u32 & 0xffff, rng.next_u64() as u32 & 0xffff))
                .collect();
            let assigns: Vec<Vec<bool>> = pairs
                .iter()
                .map(|(x, y)| (0..16).flat_map(|k| [x >> k & 1 != 0, y >> k & 1 != 0]).collect())
                .collect();
            let words = pack_lanes(&assigns);
            let vals = sim.run(&nl, &words).to_vec();
            for (lane, (x, y)) in pairs.iter().enumerate() {
                assert_eq!(lane_value(&vals, &sum, lane as u32), u128::from(x + y));
            }
        }
    }

    #[test]
    fn missing_second_operand_column() {
        // 3-column CPA where column 1 has a single bit.
        let g = ripple(3);
        let mut nl = Netlist::new("c");
        let a0 = nl.input("a0");
        let b0 = nl.input("b0");
        let a1 = nl.input("a1");
        let a2 = nl.input("a2");
        let b2 = nl.input("b2");
        let cols = vec![
            CpaColumn { a: Sig::new(a0, 0.0), b: Some(Sig::new(b0, 0.0)) },
            CpaColumn { a: Sig::new(a1, 0.0), b: None },
            CpaColumn { a: Sig::new(a2, 0.0), b: Some(Sig::new(b2, 0.0)) },
        ];
        let out = expand(&mut nl, &g, &cols);
        let mut sim = Simulator::new();
        for v in 0..32u32 {
            let bits = [v & 1 != 0, v >> 1 & 1 != 0, v >> 2 & 1 != 0, v >> 3 & 1 != 0, v >> 4 & 1 != 0];
            let words = pack_lanes(&[bits.to_vec()]);
            let vals = sim.run(&nl, &words).to_vec();
            let got = lane_value(&vals, &out.sum, 0);
            let expect = (bits[0] as u32 + bits[1] as u32)
                + 2 * (bits[2] as u32)
                + 4 * ((bits[3] as u32) + (bits[4] as u32));
            assert_eq!(got, u128::from(expect), "v={v}");
        }
    }
}
